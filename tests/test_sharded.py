"""Sharded-lowering integration test: a scaled-down version of the dry-run
(8 host devices via the tests/_multidevice.py subprocess harness, so the
main test process keeps 1 device). Asserts lower+compile succeeds for a
reduced arch on a (1,2,2,2) training mesh and that the collective parser
finds traffic."""
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import json, dataclasses
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.core import dsgd
    from repro.models import build_model
    from repro.models.sharding import (TRAIN_RULES, activation_sharding,
                                       resolve)
    from repro.optim import make_optimizer
    from repro.utils.hlo import collective_bytes

    cfg = get_config("olmo-1b").reduced(d_model=256)
    cfg = cfg.replace(dist=dataclasses.replace(cfg.dist, scan_layers=False,
                                               agents_per_pod=2))
    model = build_model(cfg)
    mesh = jax.make_mesh((1, 2, 2, 2), ("pod", "agent", "fsdp", "model"),
                         devices=jax.devices())
    m = 2
    opt = make_optimizer("adamw", 1e-3)
    key = jax.random.PRNGKey(0)
    state_shapes = jax.eval_shape(
        lambda k: dsgd.init_state(model.init_params, opt, m, k), key)
    params_ps = resolve(model.param_spec(), state_shapes["params"], mesh,
                        TRAIN_RULES, prefix=(("pod", "agent"),))
    state_ps = {"params": params_ps,
                "opt": {"m": params_ps, "v": params_ps, "step_count": P()},
                "step": P()}
    B, S = 8, 64
    batch = {"tokens": jax.ShapeDtypeStruct((m, B, S), jnp.int32),
             "targets": jax.ShapeDtypeStruct((m, B, S), jnp.int32),
             "mask": jax.ShapeDtypeStruct((m, B, S), jnp.float32)}
    bp = {k: P(("pod", "agent"), "fsdp") for k in batch}
    step = dsgd.make_dsgd_step(model.loss_fn, opt, monitor=False)
    named = lambda t: jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), t,
        is_leaf=lambda x: isinstance(x, P))
    fn = jax.jit(step, in_shardings=(named(state_ps), named(bp),
                                     NamedSharding(mesh, P()),
                                     NamedSharding(mesh, P())))
    W = jax.ShapeDtypeStruct((m, m), jnp.float32)
    k_sds = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    with activation_sharding(mesh, TRAIN_RULES):
        compiled = fn.lower(state_shapes, batch, W, k_sds).compile()
    ma = compiled.memory_analysis()
    per_kind, total, counts = collective_bytes(compiled.as_text())
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jaxlib returns [dict]
        ca = ca[0] if ca else {}
    print(json.dumps({
        "ok": True,
        "temp_gb": ma.temp_size_in_bytes / 1e9,
        "coll_bytes": total,
        "kinds": sorted(per_kind),
        "flops": ca.get("flops", 0.0),
    }))
""")


@pytest.mark.slow
@pytest.mark.multidevice
def test_sharded_train_step_lowers_and_has_collectives(multidevice):
    rec = multidevice(SCRIPT, devices=8, timeout=540)
    assert rec["ok"]
    assert rec["coll_bytes"] > 0  # gossip + TP collectives present
    assert rec["flops"] > 0
