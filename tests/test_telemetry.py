"""Unified telemetry subsystem (repro/telemetry): per-agent metric
panels from the segment scan, the versioned deterministic event log +
wall-clock sidecar, latency histograms, and the serving engine's
snapshot/reset counters.

Key invariants pinned here:

* telemetry NEVER perturbs the trajectory — the segment's final panels
  are BIT-identical with the metric panels on or off;
* the per-agent columns decompose the scalar metrics exactly (loss is
  the mean of loss_agent, consensus is sqrt(mean(dist_to_mean^2)));
* wire bytes follow the engine's exact cost model — idle W rows pay 0,
  DEAD agents pay 0, RESYNC agents pay the full-precision pull;
* round metrics aggregate over ALL H local steps (mean + max) — the old
  driver reported only the LAST step's grad norm, hiding spikes;
* the deterministic event stream is byte-reproducible, schema-validated
  at emit time, and resume-safe via truncate-to-seq.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dsgd, topology
from repro.optim import make_optimizer
from repro.telemetry import metrics as tmetrics
from repro.telemetry.events import (EventLog, make_run_id, read_events,
                                    validate_stream, wall_path)
from repro.telemetry.latency import (Histogram, default_bounds,
                                     histogram_set)

pytestmark = pytest.mark.telemetry


def _toy_problem(m=4, dim=12, classes=4):
    def init_params(rng):
        k1, _ = jax.random.split(rng)
        return {"w": jax.random.normal(k1, (dim, classes)) * 0.1,
                "b": jnp.zeros(classes)}

    def loss_fn(p, batch, rng=None):
        x, y = batch
        lg = x @ p["w"] + p["b"]
        nll = jnp.mean(jax.nn.logsumexp(lg, -1)
                       - jnp.take_along_axis(lg, y[:, None], -1)[:, 0])
        return nll, {}

    return init_params, loss_fn


def _segment_inputs(S, H, m, dim, classes, seed=0):
    rng = np.random.default_rng(seed)
    Ws = np.stack([topology.random_matching(m, 0.5, rng)
                   for _ in range(S)])
    bx = jnp.asarray(rng.normal(size=(S, H, m, 8, dim)).astype(np.float32))
    by = jnp.asarray(rng.integers(0, classes,
                                  size=(S, H, m, 8)).astype(np.int32))
    return jnp.asarray(Ws, jnp.float32), (bx, by)


# --------------------------------------------- round metric aggregation


def test_round_grad_norm_aggregates_all_local_steps():
    """Regression: make_dsgd_round reported gns[-1] — ONLY the final
    local step's grad norm — so a gradient spike at any earlier step was
    invisible. The metric is now the mean over all H steps plus an
    explicit max. A 50x input spike at LOCAL STEP 0 (of 3) must move
    both; under the old last-step metric the spiked run reported the
    same grad_norm as the clean one."""
    m, H, dim, classes = 4, 3, 12, 4
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("sgd", 1e-4)  # tiny lr: step-0 spike does not
    # meaningfully move the params, so the LAST step stays clean
    key = jax.random.PRNGKey(0)
    round_fn = dsgd.make_dsgd_round(loss_fn, opt, H)
    rng = np.random.default_rng(0)
    bx = jnp.asarray(rng.normal(size=(H, m, 8, dim)).astype(np.float32))
    by = jnp.asarray(rng.integers(0, classes, size=(H, m, 8)), jnp.int32)
    W = jnp.asarray(topology.ring(m), jnp.float32)

    state = dsgd.init_state(init_params, opt, m, key)
    _, base = round_fn(state, (bx, by), W, jax.random.PRNGKey(1))
    spiked = bx.at[0].multiply(50.0)  # spike ONLY local step 0
    state = dsgd.init_state(init_params, opt, m, key)
    _, spike = round_fn(state, (spiked, by), W, jax.random.PRNGKey(1))

    # the spike is visible in BOTH aggregates (the old gns[-1] metric
    # would have reported ~base["grad_norm"] for the spiked run)
    assert float(spike["grad_norm"]) > 5 * float(base["grad_norm"])
    assert float(spike["grad_norm_max"]) > 10 * float(
        base["grad_norm_max"])
    assert float(spike["grad_norm_max"]) > float(spike["grad_norm"])
    # clean run: max stays within the same order as the mean
    assert float(base["grad_norm_max"]) < 3 * float(base["grad_norm"])


# ------------------------------------------------ per-agent panel scan


def test_segment_per_agent_metrics_decompose_scalars():
    """telemetry=True adds five (S, m) columns to the segment's single
    device_get; they must decompose the scalar metrics exactly and
    follow the codec byte model (idle W rows pay 0)."""
    m, H, S, dim, classes = 4, 2, 4, 12, 4
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("adamw", 1e-2)
    state, spec = dsgd.init_panel_state(init_params, opt, m,
                                        jax.random.PRNGKey(0),
                                        wire="int8")
    seg = dsgd.make_panel_segment(loss_fn, opt, H, spec, telemetry=True)
    Ws, batches = _segment_inputs(S, H, m, dim, classes)
    _, mets = seg(state, batches, Ws, jax.random.PRNGKey(7))
    mets = jax.device_get(mets)

    for k in ("loss_agent", "grad_norm_agent", "dist_to_mean"):
        assert mets[k].shape == (S, m), k
    # scalar loss is the mean of the per-agent column
    np.testing.assert_allclose(np.mean(mets["loss_agent"], axis=1),
                               mets["loss"], rtol=1e-5)
    # consensus Xi decomposes as sqrt(mean(dist_to_mean^2))
    np.testing.assert_allclose(
        np.sqrt(np.mean(mets["dist_to_mean"] ** 2, axis=1)),
        mets["consensus"], rtol=1e-4)
    assert np.all(mets["grad_norm_agent"] > 0)
    # no fault plan: every agent LIVE every round
    np.testing.assert_array_equal(mets["live"], np.ones((S, m), np.int32))
    # exact codec cost model: idle (identity) rows of W pay 0 bytes,
    # communicating rows pay wire_total_bytes (int8 payload + scales)
    idle = np.all(np.asarray(Ws) == np.eye(m, dtype=np.float32), axis=2)
    expect = np.where(idle, 0, spec.wire_total_bytes)
    np.testing.assert_array_equal(mets["wire_bytes"], expect)


def test_segment_liveness_metrics_follow_trits():
    """DEAD rows report 0 loss and 0 wire bytes; RESYNC rows pay the
    full-precision pull; the live column is the trit mask verbatim."""
    m, H, S, dim, classes = 4, 2, 3, 12, 4
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("adamw", 1e-2)
    state, spec = dsgd.init_panel_state(init_params, opt, m,
                                        jax.random.PRNGKey(0),
                                        wire="int8")
    seg = dsgd.make_panel_segment(loss_fn, opt, H, spec, telemetry=True)
    _, batches = _segment_inputs(S, H, m, dim, classes)
    # degraded Ws: dead/resync agents hold identity rows (the schedule's
    # contract); agents 1,2 gossip every round, agent 3 idles
    W = np.eye(m, dtype=np.float32)
    W[1, 1] = W[2, 2] = 0.5
    W[1, 2] = W[2, 1] = 0.5
    Ws = jnp.asarray(np.stack([W] * S))
    live = jnp.asarray(np.array([[1, 1, 1, 1],
                                 [0, 1, 1, 1],    # agent 0 dead
                                 [2, 1, 1, 1]]),  # agent 0 resyncs
                       jnp.int32)
    active = jnp.ones((S,), bool)
    glob = jnp.zeros((S,), bool)
    _, mets = seg(state, batches, Ws, jax.random.PRNGKey(7), active,
                  glob, live)
    mets = jax.device_get(mets)

    np.testing.assert_array_equal(mets["live"], np.asarray(live))
    bytes_full = tmetrics.wire_bytes_model(spec)[1]
    wire = mets["wire_bytes"]
    # round 0 all-live: agent 0 idle (identity row) pays 0, the gossip
    # pair pays the codec bytes, idle agent 3 pays 0
    np.testing.assert_array_equal(
        wire[0], [0, spec.wire_total_bytes, spec.wire_total_bytes, 0])
    assert wire[1][0] == 0                  # DEAD: nothing on the wire
    assert wire[2][0] == bytes_full         # RESYNC: full-precision pull
    # non-live agents took no local step: per-agent loss/gn report 0
    assert mets["loss_agent"][1][0] == 0.0
    assert mets["loss_agent"][2][0] == 0.0
    assert mets["grad_norm_agent"][1][0] == 0.0
    assert mets["loss_agent"][1][1] > 0.0


def test_telemetry_never_perturbs_trajectory():
    """The no-perturbation invariant: the segment's final panels are
    BIT-identical with telemetry on or off (per-agent metrics are pure
    reads of arrays the round already materialized)."""
    m, H, S, dim, classes = 4, 2, 4, 12, 4
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("adamw", 1e-2)
    Ws, batches = _segment_inputs(S, H, m, dim, classes)
    finals, scalars = [], []
    for telemetry in (False, True):
        state, spec = dsgd.init_panel_state(init_params, opt, m,
                                            jax.random.PRNGKey(0),
                                            wire="int8")
        seg = dsgd.make_panel_segment(loss_fn, opt, H, spec,
                                      telemetry=telemetry)
        state, mets = seg(state, batches, Ws, jax.random.PRNGKey(7))
        finals.append(jax.device_get(state["panel"]))
        scalars.append({k: np.asarray(v) for k, v in mets.items()
                        if k in ("loss", "grad_norm", "grad_norm_max",
                                 "consensus")})
    for k in finals[0]:
        assert np.array_equal(finals[0][k], finals[1][k]), k
    for k in scalars[0]:
        np.testing.assert_array_equal(scalars[0][k], scalars[1][k])


def test_round_wire_bytes_unit():
    W = jnp.asarray(np.eye(4, dtype=np.float32))
    z = tmetrics.round_wire_bytes(W, bytes_wire=10, bytes_full=40)
    np.testing.assert_array_equal(np.asarray(z), 0)  # identity: all idle
    W = W.at[0, 0].set(0.5).at[0, 1].set(0.5)
    W = W.at[1, 1].set(0.5).at[1, 0].set(0.5)
    b = tmetrics.round_wire_bytes(W, bytes_wire=10, bytes_full=40)
    np.testing.assert_array_equal(np.asarray(b), [10, 10, 0, 0])
    # a delta codec's global round: communicating rows pay full storage
    b = tmetrics.round_wire_bytes(W, bytes_wire=10, bytes_full=40,
                                  full_bandwidth=jnp.asarray(True))
    np.testing.assert_array_equal(np.asarray(b), [40, 40, 0, 0])
    # liveness trits: DEAD pays 0, RESYNC pays the full pull
    lv = jnp.asarray([0, 1, 2, 1], jnp.int32)
    b = tmetrics.round_wire_bytes(W, bytes_wire=10, bytes_full=40, lv=lv)
    np.testing.assert_array_equal(np.asarray(b), [0, 10, 40, 0])


# ------------------------------------------------------------ event log


def _emit_rounds(log, lo, hi):
    for r in range(lo, hi):
        log.emit("round", round=r, loss=1.0 / (r + 1), grad_norm=0.5,
                 grad_norm_max=0.9, consensus=0.1, comm_cost_P=float(r))


def test_eventlog_stream_valid_and_deterministic(tmp_path):
    pa, pb = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    for p in (pa, pb):
        with EventLog(p, run_id="abc") as log:
            log.emit("run_start", run_id="abc", schema=1,
                     config={"seed": 0})
            _emit_rounds(log, 0, 3)
            log.emit("merge", round=2, operator="uniform")
            log.emit("eval", round=2, merged_eval=0.3, local_eval=0.4)
            log.emit("run_end", rounds=3, final_loss=0.25, comm_cost_P=2.0)
    assert validate_stream(pa) == []
    with open(pa, "rb") as fa, open(pb, "rb") as fb:
        assert fa.read() == fb.read()  # byte-reproducible
    evs = read_events(pa)
    assert [e["seq"] for e in evs] == list(range(len(evs)))
    assert all("t" not in e for e in evs)  # no wall clock in the stream


def test_eventlog_rejects_schema_violations(tmp_path):
    log = EventLog(str(tmp_path / "e.jsonl"))
    with pytest.raises(ValueError, match="unknown event type"):
        log.emit("nope", x=1)
    with pytest.raises(ValueError, match="missing required field"):
        log.emit("round", round=0, loss=1.0)
    with pytest.raises(ValueError, match="unknown field"):
        log.emit("merge", round=0, operator="uniform", wallclock=1.23)
    with pytest.raises(ValueError, match="is not a"):
        log.emit("merge", round="zero", operator="uniform")
    # per-agent columns are typed lists
    with pytest.raises(ValueError, match="live"):
        log.emit("round", round=0, loss=1.0, grad_norm=0.5,
                 grad_norm_max=0.9, consensus=0.1, comm_cost_P=0.0,
                 live=[1.5, 2.5])
    log.close()
    assert not os.path.getsize(str(tmp_path / "e.jsonl"))


def test_validate_stream_catches_gaps_and_round_dups(tmp_path):
    p = str(tmp_path / "bad.jsonl")
    rec = {"type": "round", "round": 1, "loss": 1.0, "grad_norm": 0.1,
           "grad_norm_max": 0.1, "consensus": 0.0, "comm_cost_P": 0.0}
    with open(p, "w") as f:
        f.write(json.dumps({**rec, "seq": 0}) + "\n")
        f.write(json.dumps({**rec, "seq": 2}) + "\n")   # seq gap
        f.write(json.dumps({**rec, "seq": 2}) + "\n")   # duplicated round
    errs = validate_stream(p)
    assert any("seq" in e for e in errs)
    assert any("duplicated or missing round" in e for e in errs)


def test_eventlog_truncate_resume_byte_identical(tmp_path):
    """The fault_smoke contract in miniature: a stream interrupted after
    round 1 and resumed (truncate back to the checkpointed seq, re-emit
    the replayed rounds) ends byte-identical to the uninterrupted one."""
    pa, pb = str(tmp_path / "base.jsonl"), str(tmp_path / "kill.jsonl")
    with EventLog(pa, run_id="r") as log:
        log.emit("run_start", run_id="r", schema=1, config={})
        _emit_rounds(log, 0, 4)
        log.emit("run_end", rounds=4, final_loss=0.2, comm_cost_P=3.0)

    with EventLog(pb, run_id="r") as log:      # first life: dies after
        log.emit("run_start", run_id="r", schema=1, config={})
        _emit_rounds(log, 0, 2)                # rounds 0,1 emitted
    # "checkpoint" was taken at seq=2 (run_start + round 0): the second
    # life truncates back and replays round 1 exactly once
    with EventLog(pb, run_id="r", resume_at=2) as log:
        assert log.seq == 2
        _emit_rounds(log, 1, 4)
        log.emit("run_end", rounds=4, final_loss=0.2, comm_cost_P=3.0)
    with open(pa, "rb") as fa, open(pb, "rb") as fb:
        assert fa.read() == fb.read()
    assert validate_stream(pb) == []
    # the sidecar keeps BOTH lives (operational history, never compared)
    assert os.path.exists(wall_path(pb))


def test_eventlog_truncate_refuses_short_file(tmp_path):
    p = str(tmp_path / "s.jsonl")
    with EventLog(p) as log:
        _emit_rounds(log, 0, 2)
    with pytest.raises(ValueError, match="expects 5 events"):
        EventLog.truncate_file(p, 5)
    with pytest.raises(FileNotFoundError):
        EventLog.truncate_file(str(tmp_path / "missing.jsonl"), 3)
    assert EventLog.truncate_file(str(tmp_path / "missing.jsonl"), 0) == 0


def test_emit_op_goes_to_sidecar_only(tmp_path):
    p = str(tmp_path / "e.jsonl")
    with EventLog(p, run_id="r") as log:
        log.emit("run_start", run_id="r", schema=1, config={})
        log.emit_op("checkpoint_save", step=3, bytes=100, dt=0.5)
        log.emit("run_end", rounds=0, final_loss=0.0, comm_cost_P=0.0)
    assert len(read_events(p)) == 2  # sidecar records never in-stream
    wall = read_events(wall_path(p))
    ops = [w for w in wall if w.get("op") == "checkpoint_save"]
    assert len(ops) == 1 and ops[0]["step"] == 3 and "t" in ops[0]
    assert validate_stream(p) == []


def test_make_run_id_deterministic():
    a = make_run_id({"seed": 0, "arch": "olmo-1b"})
    b = make_run_id({"arch": "olmo-1b", "seed": 0})  # key order ignored
    assert a == b and len(a) == 12 and int(a, 16) >= 0
    assert make_run_id({"seed": 1, "arch": "olmo-1b"}) != a


# ----------------------------------------------------- latency histogram


def test_histogram_percentiles_and_weights():
    h = Histogram()
    for _ in range(50):
        h.record(1e-3)
    h.record(1e-1, n=50)  # weighted record: one value, 50 counts
    assert h.n == 100
    assert h.mean == pytest.approx(0.0505, rel=1e-6)
    assert h.vmin == 1e-3 and h.vmax == 1e-1
    assert h.percentile(50) <= 2e-3      # inside the 1 ms bucket
    assert h.percentile(90) >= 5e-2      # inside the 100 ms bucket
    assert h.percentile(0) == 1e-3       # clamped to observed min
    assert h.percentile(100) == 1e-1
    s = h.summary()
    assert s["count"] == 100 and s["p50_s"] <= s["p90_s"] <= s["p99_s"]
    su = h.summary_us()
    assert su["p50_us"] == pytest.approx(s["p50_s"] * 1e6, rel=1e-3)
    assert sum(h.to_dict()["buckets"].values()) == 100


def test_histogram_reset_and_merge():
    h = Histogram()
    h.record(1e-3, n=5)
    h.reset()
    assert h.n == 0 and h.summary() == {"count": 0}
    assert h.percentile(50) == 0.0
    a, b = Histogram(), Histogram()
    a.record(1e-3, n=2)
    b.record(1e-2, n=3)
    a.merge(b)
    assert a.n == 5 and a.vmax == 1e-2
    # ladder mismatches refuse loudly, naming the divergence: a length
    # mismatch reports both sizes, an equal-length value mismatch names
    # the first differing index and both bounds (merging across ladders
    # would silently mis-bin every sample)
    with pytest.raises(ValueError, match=r"65 bounds vs 2"):
        a.merge(Histogram(bounds=np.array([1.0, 2.0])))
    skewed = default_bounds()
    skewed[3] *= 1.1  # still increasing (ladder step is ~1.33x)
    with pytest.raises(ValueError, match=r"index 3 \(") as ei:
        a.merge(Histogram(bounds=skewed))
    assert "vs" in str(ei.value)
    with pytest.raises(ValueError, match="increasing"):
        Histogram(bounds=np.array([2.0, 1.0]))
    assert set(histogram_set(("x", "y"))) == {"x", "y"}


# ------------------------------------------- serving engine counters


@pytest.mark.serve
def test_engine_snapshot_reset_pins_occupancy(tmp_path):
    """Regression: ServingEngine.stats was never resettable, so
    occupancy averaged over warmup/compile ticks. reset() discards them;
    a full-occupancy run afterwards must report exactly 1.0, and the
    latency histograms must count only post-reset activity. The request
    lifecycle also lands in the event stream, schema-valid."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = get_config("olmo-1b").reduced(d_model=64, vocab=64, layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    ev = str(tmp_path / "serve.jsonl")
    log = EventLog(ev, run_id="t")
    eng = ServingEngine(model, params, max_concurrency=2, max_len=48,
                        events=log)

    def reqs(rids, max_new):
        out = []
        for rid in rids:
            toks = np.asarray(jax.random.randint(
                jax.random.fold_in(jax.random.PRNGKey(1), rid), (8,), 0,
                cfg.vocab_size), np.int32)
            out.append(Request(rid=rid, tokens=toks, max_new=max_new))
        return out

    eng.serve(reqs([100], 2))     # warmup: compile ticks pollute stats
    assert eng.snapshot()["ticks"] >= 1
    eng.reset()
    assert eng.snapshot()["ticks"] == 0
    assert eng.hists["ttft_s"].n == 0

    out = eng.serve(reqs([0, 1], 4))
    assert {len(v) for v in out.values()} == {4}
    snap = eng.snapshot()
    # both slots admitted up front, retired together: every tick is full
    assert snap["ticks"] == 3     # prefill emits tok 1; 3 decode steps
    assert snap["occupancy"] == 1.0
    lat = snap["latency"]
    assert lat["ttft_s"]["count"] == 2
    assert lat["queue_wait_s"]["count"] == 2
    assert lat["decode_step_s"]["count"] == 3
    assert lat["per_token_s"]["count"] == 2
    assert lat["ttft_s"]["p50_s"] > 0
    assert snap["histograms"]["ttft_s"]["buckets"]
    log.close()
    assert validate_stream(ev) == []
    kinds = [e["type"] for e in read_events(ev)]
    assert kinds.count("request_submit") == 3   # warmup + 2
    assert kinds.count("request_admit") == 3
    assert kinds.count("request_retire") == 3
