import os
import sys

# Make the _hypothesis_stub fallback importable regardless of invocation dir.
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

# Tests run single-device (the dry-run sets its own 512-device env in a
# subprocess); keep CPU math deterministic-ish and quiet.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def multidevice():
    """Run a script on an N-device forced-host CPU platform in a subprocess
    and return its last-stdout-line JSON (see tests/_multidevice.py)."""
    from _multidevice import run_multidevice
    return run_multidevice

