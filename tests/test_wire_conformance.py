"""Codec conformance suite: one parametrized harness over EVERY entry in
``repro.wire.CODECS``.

Each test body is codec-GENERIC — it reads only the shared contract
surface (``needs_key`` / ``error_feedback`` / ``delta_mix`` attributes,
``payload_bytes`` / ``total_bytes`` / ``wire_payload`` accounting,
``residual`` / ``init_err`` error-feedback state mapping) and never
branches on a codec's NAME. Registering a new codec in ``CODECS`` is all
it takes to put it under the full contract:

* idle W = I rounds are bit-exact through the segment driver (the codec
  is skipped entirely; the EF state passes through untouched);
* ``payload_bytes`` / ``total_bytes`` match the ``.nbytes`` of the
  actual encoded wire arrays, and ``PanelSpec.wire_payload_bytes`` /
  ``wire_total_bytes`` agree with the codec's own accounting;
* the error-feedback residual is bounded by the carried signal per
  encode and telescopes over rounds of a constant input (the
  time-averaged transmitted view converges to the input);
* stochastic rounding is unbiased in expectation over PRNG keys
  (empirical-standard-error bound, so no codec-specific scale enters
  the harness); deterministic codecs are key-invariant;
* the Pallas kernel path is bit-identical to the XLA/ref path;
* draws are bit-identical eager vs jitted (and sharded vs replicated
  when the host has devices to shard over) — the
  ``threefry_partitionable`` contract;
* idle ROWS of a dense mix (unmatched agents) keep exact parameters and
  EF state; a global merge collapses the consensus distance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import wire as wire_mod
from repro.core import dsgd
from repro.core import panel as panel_mod
from repro.optim import make_optimizer
from test_panel import _segment_inputs, _toy_problem

pytestmark = pytest.mark.wire

CODEC_NAMES = sorted(wire_mod.CODECS)


def _panel(m, d, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(m, d)) * scale, jnp.float32)


def _key_for(codec, seed=0):
    return jax.random.PRNGKey(seed) if codec.needs_key else None


def _err_for(codec, x, cold: bool = False):
    """Engine-faithful EF state (codec.init_err), or a COLD state seeded
    from a zero panel — nonvacuous for mirror codecs whose warm init
    already matches the input."""
    if not codec.error_feedback:
        return None
    return codec.init_err(jnp.zeros_like(x) if cold else x)


# ------------------------------------------------------------ registry


@pytest.mark.parametrize("name", CODEC_NAMES)
def test_registry_contract(name):
    codec = wire_mod.get_codec(name)
    assert codec is wire_mod.CODECS[name]
    assert codec.name == name
    assert wire_mod.get_codec(codec) is codec  # instance pass-through
    assert isinstance(codec.needs_key, bool)
    assert isinstance(codec.error_feedback, bool)
    assert isinstance(codec.delta_mix, bool)
    m, d = 3, 257
    pb = codec.payload_bytes(m, d, jnp.float32)
    tb = codec.total_bytes(m, d, jnp.float32)
    assert 0 < pb <= tb
    # accounting is per-row linear: rows scale the byte counts exactly
    assert codec.payload_bytes(2 * m, d, jnp.float32) == 2 * pb
    assert codec.total_bytes(2 * m, d, jnp.float32) == 2 * tb


# ----------------------------------------------------- byte accounting


@pytest.mark.parametrize("name", CODEC_NAMES)
def test_payload_bytes_match_encoded_size(name):
    """payload_bytes/total_bytes must equal the .nbytes of the ACTUAL
    wire arrays (odd width exercises nibble/index packing tails), and
    the spec-level accounting must agree with the codec's."""
    codec = wire_mod.get_codec(name)
    m, d = 3, 333
    x = _panel(m, d, seed=5)
    payload, meta = codec.wire_payload(x, key=_key_for(codec),
                                       err=_err_for(codec, x, cold=True))
    pb = sum(int(a.nbytes) for a in payload)
    tb = pb + sum(int(a.nbytes) for a in meta)
    assert pb == codec.payload_bytes(m, d, jnp.float32), name
    assert tb == codec.total_bytes(m, d, jnp.float32), name
    spec = panel_mod.with_wire(panel_mod.make_spec({"w": x}), name)
    assert spec.wire_payload_bytes == codec.payload_bytes(1, d, "float32")
    assert spec.wire_total_bytes == codec.total_bytes(1, d, "float32")
    assert spec.wire_bytes == spec.wire_total_bytes  # back-compat alias


# -------------------------------------------------- encode/err contract


@pytest.mark.parametrize("name", CODEC_NAMES)
def test_encode_error_state_contract(name):
    """EF codecs refuse a missing err and never grow the residual beyond
    the carried signal; residual-free codecs pass err through untouched
    and do not fold it into the payload."""
    codec = wire_mod.get_codec(name)
    x = _panel(4, 64, seed=7)
    key = _key_for(codec)
    if codec.error_feedback:
        with pytest.raises(ValueError, match="err"):
            codec.encode(x, key=key)
        err = _err_for(codec, x, cold=True)
        res0 = codec.residual(x, err)
        xhat, back, new_err = codec.encode(x, key=key, err=err)
        res1 = codec.residual(x, new_err)
        assert xhat.shape == x.shape and res1 is not None
        carried = float(jnp.max(jnp.abs(x + res0))) + 1e-4
        assert float(jnp.max(jnp.abs(res1))) <= 1.5 * carried
        assert bool(jnp.all(jnp.isfinite(back(xhat.astype(jnp.float32)))))
    else:
        e0 = jnp.full_like(x, 0.01)
        xhat_e, _, e1 = codec.encode(x, key=key, err=e0)
        np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))
        assert codec.residual(x, e0) is e0  # identity residual mapping
        xhat, _, none_err = codec.encode(x, key=key)
        assert none_err is None
        np.testing.assert_array_equal(np.asarray(xhat),
                                      np.asarray(xhat_e))


@pytest.mark.parametrize("name", CODEC_NAMES)
def test_ef_residual_telescopes_on_constant_input(name):
    """T encodes of a CONSTANT input: the effective residual never blows
    up, and the late-window time average of the transmitted view
    converges to the input at the O(max residual / T) feedback rate."""
    codec = wire_mod.get_codec(name)
    if not codec.error_feedback:
        pytest.skip("contract applies to error-feedback codecs")
    m, d, T = 3, 48, 48
    x = _panel(m, d, seed=11)
    err = _err_for(codec, x, cold=True)
    keys = jax.random.split(jax.random.PRNGKey(2), T)
    xhats, max_res = [], 0.0
    for t in range(T):
        key = keys[t] if codec.needs_key else None
        xhat, _, err = codec.encode(x, key=key, err=err)
        xhats.append(xhat.astype(jnp.float32))
        max_res = max(max_res,
                      float(jnp.max(jnp.abs(codec.residual(x, err)))))
    assert max_res <= 1.5 * float(jnp.max(jnp.abs(x))) + 1e-4
    late = jnp.mean(jnp.stack(xhats[T // 2:]), axis=0)
    gap = float(jnp.max(jnp.abs(late - x)))
    assert gap <= 6.0 * max_res / T + 1e-6, (gap, max_res)


# ------------------------------------------------- stochastic rounding


@pytest.mark.parametrize("name", CODEC_NAMES)
def test_stochastic_unbiased_or_deterministic(name):
    """Key-driven codecs: E_key[xhat] == x within 6 empirical standard
    errors per element (no codec-specific scale enters the bound).
    Key-free codecs: encode is deterministic and key-invariant."""
    codec = wire_mod.get_codec(name)
    m, d = 3, 40
    x = _panel(m, d, seed=13)
    err = _err_for(codec, x, cold=True)
    if codec.needs_key:
        N = 256
        keys = jax.random.split(jax.random.PRNGKey(3), N)
        xhats = jax.vmap(
            lambda k: codec.encode(x, key=k, err=err)[0]
            .astype(jnp.float32))(keys)
        mean_err = jnp.abs(jnp.mean(xhats, axis=0) - x)
        se = jnp.std(xhats, axis=0) / np.sqrt(N)
        # 6 empirical standard errors, plus a per-row quantization-step
        # slack for the small-p binomial corner: an element whose true
        # flip probability is O(1/N) can show zero flips (se = 0) while
        # carrying an O(step/N) bias — estimate the step from the
        # observed row spread, no codec-specific scale involved
        step = jnp.max(jnp.max(xhats, axis=0) - jnp.min(xhats, axis=0),
                       axis=1, keepdims=True)
        assert bool(jnp.all(mean_err <= 6.0 * se + 6.0 * step / N
                            + 1e-7)), name
    else:
        a, _, _ = codec.encode(x, key=None, err=err)
        b, _, _ = codec.encode(x, key=jax.random.PRNGKey(0), err=err)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- kernel / jit parity


@pytest.mark.parametrize("name", CODEC_NAMES)
def test_pallas_path_matches_ref_path(name):
    """encode(use_pallas=True) must be bit-identical to the XLA/ref
    path given the same key and EF state (non-divisible width exercises
    the kernels' padded tails)."""
    codec = wire_mod.get_codec(name)
    x = _panel(5, 333, seed=17)
    key = _key_for(codec, seed=4)
    err = _err_for(codec, x, cold=True)
    a, _, ea = codec.encode(x, key=key, err=err, use_pallas=False)
    b, _, eb = codec.encode(x, key=key, err=err, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    if codec.error_feedback:
        np.testing.assert_array_equal(np.asarray(ea), np.asarray(eb))


@pytest.mark.parametrize("name", CODEC_NAMES)
def test_draws_bit_identical_sharded_vs_replicated(name):
    """A jitted encode with the input sharded over rows must produce the
    same bits as the jitted replicated encode — the scoped
    ``threefry_partitionable`` contract: SPMD partitioning must not
    change the stochastic-rounding draw. (Eager-vs-jit bit identity is
    deliberately NOT asserted: XLA CPU lowers f32 division to a 1-ulp
    reciprocal multiply under jit, and the engine always runs jitted —
    consistency across jitted lowerings is the real contract.) With a
    single local device the sharded program degenerates to the
    replicated one; CI forces an 8-device host so the split is real."""
    codec = wire_mod.get_codec(name)
    m, d = 4, 96
    x = _panel(m, d, seed=19)
    key = _key_for(codec, seed=6)
    err = _err_for(codec, x, cold=True)

    def enc(xx, ee):
        xhat, _, ne = codec.encode(xx, key=key, err=ee)
        return xhat, ne

    ja, je = jax.jit(enc)(x, err)
    from jax.sharding import Mesh, NamedSharding
    from jax.sharding import PartitionSpec as P
    ndev = min(4, jax.device_count())
    mesh = Mesh(np.asarray(jax.devices()[:ndev]), ("rows",))
    sh = NamedSharding(mesh, P("rows", None))
    xs = jax.device_put(x, sh)
    es = jax.device_put(err, sh) if err is not None else None
    sa, se_ = jax.jit(enc)(xs, es)
    np.testing.assert_array_equal(np.asarray(ja), np.asarray(sa))
    if codec.error_feedback:
        np.testing.assert_array_equal(np.asarray(je), np.asarray(se_))


# --------------------------------------------------- engine contracts


@pytest.mark.parametrize("name", CODEC_NAMES)
def test_idle_segment_bitexact(name):
    """A schedule of W = I rounds communicates nothing, so EVERY codec
    must leave the segment driver bit-identical to the no-policy run
    (codec skipped, wire-key fold_in not perturbing the local-step rng)
    and its EF state exactly at the init value."""
    m, H, S, dim, classes = 4, 2, 3, 10, 3
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("adamw", 1e-2)
    _, (bx, by) = _segment_inputs(S, H, m, dim, classes)
    Ws = jnp.broadcast_to(jnp.eye(m, dtype=jnp.float32), (S, m, m))

    def run(wire):
        pstate, spec = dsgd.init_panel_state(
            init_params, opt, m, jax.random.PRNGKey(0), wire=wire)
        err0 = jax.tree.map(lambda v: v + 0.0,
                            pstate.get("wire_err", {}))  # donated below
        seg_fn = dsgd.make_panel_segment(loss_fn, opt, H, spec)
        out = seg_fn(pstate, (bx, by), Ws, jax.random.PRNGKey(1))
        return out, err0

    (base, base_mets), _ = run(None)
    (ps, mets), err0 = run(name)
    for a, b in zip(jax.tree.leaves(base["panel"]),
                    jax.tree.leaves(ps["panel"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(base_mets["loss"]),
                                  np.asarray(mets["loss"]))
    np.testing.assert_array_equal(np.asarray(base_mets["consensus"]),
                                  np.asarray(mets["consensus"]))
    if "wire_err" in ps:  # EF state untouched by idle rounds
        for k, v in ps["wire_err"].items():
            np.testing.assert_array_equal(np.asarray(v),
                                          np.asarray(err0[k]))


@pytest.mark.parametrize("name", CODEC_NAMES)
def test_idle_rows_exact_in_dense_mix(name):
    """Unmatched agents (identity rows of W) communicate nothing — every
    codec must restore their params and EF state exactly; matched rows
    may move."""
    codec = wire_mod.get_codec(name)
    m, d = 4, 64
    x = _panel(m, d, seed=23)
    W = jnp.asarray([[0.5, 0.5, 0, 0], [0.5, 0.5, 0, 0],
                     [0, 0, 1.0, 0], [0, 0, 0, 1.0]], jnp.float32)
    spec = panel_mod.with_wire(panel_mod.make_spec({"w": x}), name)
    err = _err_for(codec, x, cold=True)
    kw = dict(spec=spec, key=_key_for(codec, seed=8))
    if err is not None:
        out, new_err = panel_mod.mix_dense(
            {"float32": x}, W, err={"float32": err}, **kw)
        np.testing.assert_array_equal(
            np.asarray(new_err["float32"][2:]), np.asarray(err[2:]))
    else:
        out = panel_mod.mix_dense({"float32": x}, W, **kw)
    np.testing.assert_array_equal(np.asarray(out["float32"][2:]),
                                  np.asarray(x[2:]))
    assert bool(jnp.any(out["float32"][:2] != x[:2]))


@pytest.mark.parametrize("name", CODEC_NAMES)
def test_global_merge_collapses_consensus(name):
    """One global merge through any codec leaves every agent on the same
    row (lossy codecs merge the same decoded panel for everyone; delta
    codecs run their full-bandwidth sync)."""
    codec = wire_mod.get_codec(name)
    m, d = 4, 52
    x = _panel(m, d, seed=29)
    spec = panel_mod.with_wire(panel_mod.make_spec({"w": x}), name)
    err = _err_for(codec, x)
    kw = dict(spec=spec, key=_key_for(codec, seed=9))
    if err is not None:
        merged, _ = panel_mod.global_merge(
            {"float32": x}, err={"float32": err}, **kw)
    else:
        merged = panel_mod.global_merge({"float32": x}, **kw)
    xi = float(panel_mod.consensus_distance(merged))
    assert xi <= 1e-6, (name, xi)
