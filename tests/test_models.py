"""Model-component correctness: MoE dispatch vs dense reference, mLSTM
chunkwise vs naive recurrence, RG-LRU scan vs step-by-step, chunked CE vs
direct, rope invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: property tests skip gracefully
    from _hypothesis_stub import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.base import LayerSpec
from repro.models import moe as moe_mod
from repro.models import recurrent as rec
from repro.models.layers import (apply_rope, chunked_softmax_xent, init_mlp,
                                 apply_norm, init_norm)


# ---------------------------------------------------------------- MoE


def _moe_cfg(E=4, k=2, cap=10.0):
    cfg = get_config("arctic-480b").reduced(d_model=64, experts=E)
    m = dataclasses.replace(cfg.moe, top_k=k, capacity_factor=cap)
    return cfg.replace(moe=m)


def test_moe_matches_dense_reference_no_drops():
    """With a huge capacity factor no tokens drop; the gather/scatter path
    must equal the dense compute-everything reference."""
    cfg = _moe_cfg(cap=100.0)
    key = jax.random.PRNGKey(0)
    params = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y1, aux1 = moe_mod.moe_forward(params, x, cfg=cfg, act_name=cfg.act)
    y2, aux2 = moe_mod.moe_ref(params, x, cfg=cfg, act_name=cfg.act)
    np.testing.assert_allclose(y1, y2, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(aux1, aux2, atol=1e-6)


def test_moe_capacity_drops_reduce_output():
    cfg_lo = _moe_cfg(cap=0.25)
    cfg_hi = _moe_cfg(cap=100.0)
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg_hi)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg_hi.d_model))
    y_lo, _ = moe_mod.moe_forward(params, x, cfg=cfg_lo, act_name="silu")
    y_hi, _ = moe_mod.moe_forward(params, x, cfg=cfg_hi, act_name="silu")
    # dropped tokens -> some outputs reduced to shared/dense-only part
    assert float(jnp.mean(jnp.abs(y_lo))) < float(jnp.mean(jnp.abs(y_hi)))


def test_moe_aux_loss_balanced_router_is_minimal():
    cfg = _moe_cfg(E=4, k=1)
    E = 4
    T = 64
    # perfectly balanced probs -> aux = E * sum(1/E * 1/E) * E? == 1
    probs = jnp.full((T, E), 1.0 / E)
    # craft via _route: monkey-instance — test the formula directly
    counts = jnp.full((E,), T / E)
    frac = counts / T
    aux = E * jnp.sum(frac * probs.mean(0))
    assert float(aux) == pytest.approx(1.0, abs=1e-5)


def test_deepseek_sigmoid_router_weights_normalised():
    cfg = get_config("deepseek-v3-671b").reduced()
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.d_model))
    w, sel, aux = moe_mod._route(x, params, cfg.moe)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert sel.shape == (8, cfg.moe.top_k)


# ---------------------------------------------------------------- mLSTM


def _naive_mlstm(q, k, v, logf, logi):
    """Step-by-step stabilised mLSTM recurrence (ground truth)."""
    B, H, S, dh = q.shape
    C = np.zeros((B, H, dh, dh), np.float64)
    n = np.zeros((B, H, dh), np.float64)
    m = np.full((B, H), -1e30, np.float64)
    hs = np.zeros((B, H, S, dh), np.float64)
    q, k, v = (np.asarray(t, np.float64) for t in (q, k, v))
    logf, logi = np.asarray(logf, np.float64), np.asarray(logi, np.float64)
    for t in range(S):
        m_new = np.maximum(logf[..., t] + m, logi[..., t])
        fp = np.exp(logf[..., t] + m - m_new)
        ip = np.exp(logi[..., t] - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * (
            k[:, :, t, :, None] * v[:, :, t, None, :])
        n = fp[..., None] * n + ip[..., None] * k[:, :, t]
        m = m_new
        num = np.einsum("bhde,bhd->bhe", C, q[:, :, t])
        den = np.maximum(np.abs(np.einsum("bhd,bhd->bh", n, q[:, :, t])),
                         np.exp(-m))
        hs[:, :, t] = num / den[..., None]
    return hs


@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (48, 16)])
def test_mlstm_chunkwise_matches_naive(S, chunk):
    B, H, dh = 2, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, H, S, dh))
    k = jax.random.normal(ks[1], (B, H, S, dh))
    v = jax.random.normal(ks[2], (B, H, S, dh))
    logi = jax.random.normal(ks[3], (B, H, S))
    logf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, S)) + 2.0)

    st0 = (jnp.zeros((B, H, dh, dh)), jnp.zeros((B, H, dh)),
           jnp.full((B, H), -1e30))
    outs = []
    state = st0
    for c0 in range(0, S, chunk):
        sl = slice(c0, c0 + chunk)
        h, state = rec._mlstm_chunk(q[:, :, sl], k[:, :, sl], v[:, :, sl],
                                    logf[:, :, sl], logi[:, :, sl], state)
        outs.append(h)
    got = jnp.concatenate(outs, axis=2)
    want = _naive_mlstm(q, k, v, logf, logi)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-3)


def test_mlstm_decode_continues_prefill():
    cfg = get_config("xlstm-1.3b").reduced()
    params = rec.init_mlstm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    full, _ = rec.mlstm_forward(params, x, cfg=cfg, mode="train")
    y, state = rec.mlstm_forward(params, x[:, :S - 1], cfg=cfg,
                                 mode="prefill")
    y2, _ = rec.mlstm_forward(params, x[:, S - 1:], cfg=cfg, mode="decode",
                              state=state)
    np.testing.assert_allclose(y2[:, 0], full[:, -1], atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------- RG-LRU


def test_rglru_decode_matches_scan():
    cfg = get_config("recurrentgemma-2b").reduced()
    params = rec.init_rglru(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    full, _ = rec.rglru_forward(params, x, cfg=cfg, mode="train")
    state = rec.init_rglru_state(cfg, B)
    outs = []
    for t in range(S):
        y, state = rec.rglru_forward(params, x[:, t:t + 1], cfg=cfg,
                                     mode="decode", state=state)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(got, full, atol=1e-4, rtol=1e-3)


def test_slstm_decode_matches_scan():
    cfg = get_config("xlstm-1.3b").reduced()
    params = rec.init_slstm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    full, _ = rec.slstm_forward(params, x, cfg=cfg, mode="train")
    state = rec.init_slstm_state(cfg, B)
    outs = []
    for t in range(S):
        y, state = rec.slstm_forward(params, x[:, t:t + 1], cfg=cfg,
                                     mode="decode", state=state)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(got, full, atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------- layers


@given(S=st.sampled_from([16, 33, 64]), chunk=st.sampled_from([7, 16, 64]))
@settings(max_examples=12, deadline=None)
def test_chunked_xent_matches_direct(S, chunk):
    B, d, V = 2, 16, 50
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h = jax.random.normal(ks[0], (B, S, d))
    w = jax.random.normal(ks[1], (d, V)) * 0.3
    t = jax.random.randint(ks[2], (B, S), 0, V)
    mask = (jnp.arange(S)[None] < S - 2).astype(jnp.float32) * jnp.ones((B, 1))
    nll, cnt = chunked_softmax_xent(h, w, t, mask, chunk)
    lg = (h @ w).astype(jnp.float32)
    ref = jnp.sum((jax.nn.logsumexp(lg, -1) - jnp.take_along_axis(
        lg, t[..., None], -1)[..., 0]) * mask)
    np.testing.assert_allclose(nll, ref, rtol=1e-5, atol=1e-4)
    assert float(cnt) == float(mask.sum())


def test_rope_preserves_norm_and_relativity():
    B, S, H, hd = 1, 8, 1, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    pos = jnp.arange(S)[None]
    y = apply_rope(x, pos)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, hd))
    def dot(i, j):
        qi = apply_rope(q, jnp.array([[i]]))
        kj = apply_rope(k, jnp.array([[j]]))
        return float(jnp.sum(qi * kj))
    assert dot(3, 1) == pytest.approx(dot(7, 5), abs=1e-4)


def test_nonparam_ln_has_no_params():
    p = init_norm("nonparam_ln", 16)
    assert p == {}
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 16)) * 5 + 3
    y = apply_norm(p, x, "nonparam_ln")
    np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(jnp.var(y, -1), 1.0, atol=1e-3)
