"""Property tests for the flat-panel engine (hypothesis; falls back to the
offline ``_hypothesis_stub`` shim, which reports each property as SKIPPED).

Covers the two contracts everything else leans on:

* ``to_panel``/``from_panel`` is an exact round-trip for ANY mixed-dtype
  agent-stacked pytree — odd leaf shapes, scalars-per-agent, duplicate
  dtypes, bf16/f16/int32 groups (no silent promotion, no value change);
* ``mix_dense`` with a doubly-stochastic W preserves the agent-mean of
  every column (the invariant the paper's convergence analysis rests on)
  and is an exact no-op for W = I.
"""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: dev extra not installed
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import panel as panel_mod

DTYPES = ["float32", "bfloat16", "float16", "int32"]

leaf_shapes = st.lists(
    st.tuples(st.integers(1, 5), st.integers(0, 3)).map(
        lambda t: tuple(np.random.default_rng(t[0] * 7 + t[1]).integers(
            1, 8, size=t[1]))),
    min_size=1, max_size=5)

tree_strategy = st.fixed_dictionaries({
    "m": st.integers(1, 6),
    "shapes": leaf_shapes,
    "dtypes": st.lists(st.sampled_from(DTYPES), min_size=5, max_size=5),
    "seed": st.integers(0, 2**31 - 1),
})


def _build_tree(m, shapes, dtypes, seed):
    rng = np.random.default_rng(seed)
    tree = {}
    for i, shp in enumerate(shapes):
        dt = dtypes[i % len(dtypes)]
        if dt == "int32":
            arr = rng.integers(-100, 100, size=(m,) + shp).astype(np.int32)
        else:
            arr = rng.normal(size=(m,) + shp).astype(np.float32)
        tree[f"leaf{i}"] = jnp.asarray(arr).astype(dt)
    return tree


def _doubly_stochastic(m, seed):
    """Average of a few permutation matrices — exactly doubly stochastic."""
    rng = np.random.default_rng(seed)
    W = np.zeros((m, m))
    n = 4
    for _ in range(n):
        W[np.arange(m), rng.permutation(m)] += 1.0 / n
    return jnp.asarray(W, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(tree_strategy)
def test_panel_roundtrip_exact(cfg):
    tree = _build_tree(**cfg)
    spec = panel_mod.make_spec(tree)
    assert spec.rows == cfg["m"]
    assert spec.width == sum(
        int(np.prod(x.shape[1:])) for x in jax.tree.leaves(tree))
    back = panel_mod.from_panel(panel_mod.to_panel(tree, spec), spec)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert bool(jnp.all(a == b))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_mix_dense_preserves_agent_mean(m, d, seed):
    rng = np.random.default_rng(seed)
    pan = {"float32": jnp.asarray(rng.normal(size=(m, d)), jnp.float32)}
    W = _doubly_stochastic(m, seed)
    out = panel_mod.mix_dense(pan, W)
    np.testing.assert_allclose(
        np.mean(np.asarray(out["float32"], np.float64), axis=0),
        np.mean(np.asarray(pan["float32"], np.float64), axis=0),
        atol=1e-5, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_mix_dense_identity_is_noop(m, d, seed):
    rng = np.random.default_rng(seed)
    pan = {"float32": jnp.asarray(rng.normal(size=(m, d)), jnp.float32)}
    out = panel_mod.mix_dense(pan, jnp.eye(m))
    np.testing.assert_array_equal(np.asarray(out["float32"]),
                                  np.asarray(pan["float32"]))


@settings(max_examples=25, deadline=None)
@given(tree_strategy)
def test_global_merge_collapses_consensus(cfg):
    tree = {k: v for k, v in _build_tree(**cfg).items()
            if not jnp.issubdtype(v.dtype, jnp.integer)}
    if not tree:
        return
    spec = panel_mod.make_spec(tree)
    pan = panel_mod.to_panel(tree, spec)
    merged = panel_mod.global_merge(pan)
    assert float(panel_mod.consensus_distance(merged)) < 1e-2
