"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes kernel bodies in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.topology import fully_connected, random_matching, ring
from repro.kernels.flash_attention import flash_attention_bh
from repro.kernels.gossip_mix import gossip_mix_panel
from repro.kernels.ops import flash_attention, gossip_mix
from repro.kernels.ref import attention_ref, gossip_mix_ref


@pytest.mark.parametrize("S,hd,block", [
    (128, 64, 64), (256, 64, 128), (256, 128, 64), (512, 32, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(S, hd, block, dtype):
    B, H = 2, 2
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, H, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd), dtype)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=block, block_k=block)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(jnp.float32), ref, atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_sliding_window(window):
    B, S, H, hd = 1, 256, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, hd)) for kk in ks)
    ref = attention_ref(q, k, v, causal=True, window=window)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_attention_gqa_expansion():
    B, S, H, Kv, hd = 2, 128, 8, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Kv, hd))
    v = jax.random.normal(ks[2], (B, S, Kv, hd))
    ref = attention_ref(q, jnp.repeat(k, H // Kv, 2),
                        jnp.repeat(v, H // Kv, 2), causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("m,D,block_d", [
    (4, 64, 32), (8, 1000, 512), (16, 4096, 512), (8, 333, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_mix_panel_sweep(m, D, block_d, dtype):
    rng = np.random.default_rng(0)
    W = jnp.asarray(random_matching(m, 0.7, rng), jnp.float32)
    theta = jax.random.normal(jax.random.PRNGKey(3), (m, D), dtype)
    ref = gossip_mix_ref(W, theta)
    out = gossip_mix_panel(W, theta, block_d=block_d)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("topo", ["ring", "full"])
def test_gossip_mix_pytree_matches_dense(topo):
    m = 8
    W = jnp.asarray(ring(m) if topo == "ring" else fully_connected(m),
                    jnp.float32)
    tree = {"a": jax.random.normal(jax.random.PRNGKey(4), (m, 17, 5)),
            "b": {"c": jax.random.normal(jax.random.PRNGKey(5), (m, 33))}}
    out = gossip_mix(W, tree)
    from repro.core.gossip import mix_dense
    ref = mix_dense(tree, W)
    for o, r in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(o, r, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("window", [None, 48])
def test_blockwise_xla_attention_matches_sdpa(window):
    """The flash-style XLA path (used by the dry-run §Perf variants) must
    match the materialised-score reference."""
    import dataclasses
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import attention as attn
    cfg = get_config("yi-34b").reduced()
    lspec = dataclasses.replace(cfg.layer_period[0], window=window)
    params = attn.init_gqa(jax.random.PRNGKey(0), cfg)
    B, S = 2, 96
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    y_ref, _ = attn.gqa_forward(params, x, cfg=cfg, lspec=lspec,
                                positions=pos, mode="train")
    cfg2 = cfg.replace(dist=dataclasses.replace(cfg.dist, attn_block=32))
    y_blk, _ = attn.gqa_forward(params, x, cfg=cfg2, lspec=lspec,
                                positions=pos, mode="train")
    np.testing.assert_allclose(y_blk, y_ref, atol=2e-4, rtol=2e-4)


def test_gossip_mix_preserves_mean():
    """Doubly-stochastic mixing preserves the average model — the invariant
    the paper's final merge relies on."""
    m = 8
    rng = np.random.default_rng(1)
    theta = jax.random.normal(jax.random.PRNGKey(6), (m, 257))
    for t in range(5):
        W = jnp.asarray(random_matching(m, 0.5, rng), jnp.float32)
        theta2 = gossip_mix_panel(W, theta)
        np.testing.assert_allclose(theta2.mean(0), theta.mean(0), atol=1e-5)
        theta = theta2
