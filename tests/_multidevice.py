"""Reusable multi-device subprocess harness for CPU-only test hosts.

JAX fixes the device count at first backend init, so a test that needs an
N-device mesh cannot force it inside the main pytest process (conftest.py
already initialised a 1-device CPU backend). The pattern — shared by
tests/test_sharded.py and tests/test_panel_sharded.py — is to run a small
script in a SUBPROCESS with ``--xla_force_host_platform_device_count=N``
set before jax imports, have the script print ONE JSON line as its last
stdout line, and assert on the parsed record in the parent.

Use the ``multidevice`` conftest fixture (preferred) or call
:func:`run_multidevice` directly.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SRC_DIR = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "src"))


def run_multidevice(script: str, devices: int = 8, timeout: int = 540,
                    env: dict | None = None):
    """Run ``script`` in a fresh python on an N-device forced-host CPU
    platform; return the parsed JSON from its LAST stdout line.

    The child env gets XLA_FLAGS (device count), JAX_PLATFORMS=cpu and
    PYTHONPATH=src pre-set, so scripts need no os.environ preamble."""
    full_env = dict(os.environ)
    flags = full_env.get("XLA_FLAGS", "")
    full_env["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={devices}".strip())
    full_env["JAX_PLATFORMS"] = "cpu"
    full_env["PYTHONPATH"] = (
        SRC_DIR + os.pathsep + full_env["PYTHONPATH"]
        if full_env.get("PYTHONPATH") else SRC_DIR)
    if env:
        full_env.update(env)
    out = subprocess.run([sys.executable, "-c", script], env=full_env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (
        f"multidevice subprocess failed (rc={out.returncode}):\n"
        f"{out.stderr[-4000:]}")
    lines = [ln for ln in out.stdout.strip().splitlines() if ln.strip()]
    assert lines, f"subprocess printed nothing; stderr:\n{out.stderr[-2000:]}"
    try:
        return json.loads(lines[-1])
    except json.JSONDecodeError as e:  # pragma: no cover - debug aid
        raise AssertionError(
            f"last stdout line is not JSON: {lines[-1]!r}\n"
            f"stderr:\n{out.stderr[-2000:]}") from e
