"""Per-architecture smoke tests: REDUCED same-family variants (<=2 layers,
d_model<=512, <=4 experts) run one forward + one decentralized train step on
CPU, asserting output shapes and no NaNs. Also checks the param-spec trees
match the param trees structurally (sharding cannot silently drift)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import dsgd
from repro.models import build_model
from repro.optim import make_optimizer

ARCHS = ["gemma-2b", "phi3-mini-3.8b", "arctic-480b", "qwen2-vl-72b",
         "xlstm-1.3b", "seamless-m4t-medium", "deepseek-v3-671b",
         "recurrentgemma-2b", "olmo-1b", "yi-34b"]


def make_batch(cfg, B=2, S=32, key=None, lead=()):
    key = jax.random.PRNGKey(0) if key is None else key
    ks = jax.random.split(key, 4)
    shp = lead + (B, S)
    batch = {"tokens": jax.random.randint(ks[0], shp, 0, cfg.vocab_size),
             "targets": jax.random.randint(ks[1], shp, 0, cfg.vocab_size),
             "mask": jnp.ones(shp, jnp.float32)}
    if cfg.mm_prefix > 0:
        batch["patch_embeds"] = jax.random.normal(
            ks[2], lead + (B, cfg.mm_prefix, cfg.d_model))
    if cfg.encoder_layers:
        batch["frame_embeds"] = jax.random.normal(
            ks[3], lead + (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512 and cfg.num_layers <= 2
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)

    # forward
    batch = make_batch(cfg)
    loss, mets = model.loss_fn(params, batch, key)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    # one decentralized train step with m=2 agents + pairwise gossip
    m = 2
    opt = make_optimizer("adamw", 1e-3)
    state = dsgd.init_state(model.init_params, opt, m, key)
    step = jax.jit(dsgd.make_dsgd_step(model.loss_fn, opt))
    abatch = make_batch(cfg, lead=(m,))
    W = jnp.full((m, m), 0.5, jnp.float32)
    new_state, mets = step(state, abatch, W, key)
    assert bool(jnp.isfinite(mets["loss"])), f"{arch}: train step NaN"
    for leaf in jax.tree.leaves(new_state["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf))), f"{arch}: NaN params"
    # after W = full merge, agents agree
    from repro.core.consensus import consensus_distance
    assert float(consensus_distance(new_state["params"])) < 1e-4


@pytest.mark.parametrize("arch", ARCHS)
def test_param_spec_structure_matches(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    spec = model.param_spec()

    def is_spec_leaf(s):
        return isinstance(s, tuple) and all(
            isinstance(e, (str, tuple, type(None))) for e in s)

    # tree.map raises if the structures don't match
    checked = jax.tree.map(
        lambda s, x: len([n for n in s if n is not None]) <= len(x.shape),
        spec, shapes, is_leaf=is_spec_leaf)
    assert all(jax.tree.leaves(checked))


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_spec_structure_matches(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    caches = jax.eval_shape(lambda: model.init_cache(2, 16))
    spec = model.cache_spec()

    def is_spec_leaf(s):
        return isinstance(s, tuple) and all(
            isinstance(e, (str, tuple, type(None))) for e in s)

    checked = jax.tree.map(lambda s, x: True, spec, caches,
                           is_leaf=is_spec_leaf)
    assert all(jax.tree.leaves(checked))


@pytest.mark.parametrize("arch", ["gemma-2b", "gemma-2b-sw", "yi-34b",
                                  "deepseek-v3-671b", "xlstm-1.3b",
                                  "recurrentgemma-2b",
                                  "seamless-m4t-medium", "qwen2-vl-72b"])
def test_prefill_decode_matches_full_forward(arch):
    """Teacher-forced decode must reproduce the full-sequence forward."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init_params(key)
    B, S, T = 2, 24, 8  # prompt 24, decode 8 more

    full_batch = make_batch(cfg, B=B, S=S + T, key=key)
    toks = full_batch["tokens"]

    mm_len = cfg.mm_prefix if cfg.mm_prefix > 0 else 0

    def prefill_logits(upto):
        b = {k: (v[:, :upto] if k in ("tokens", "targets", "mask") else v)
             for k, v in full_batch.items()}
        b.pop("targets", None)
        b.pop("mask", None)
        return model.prefill(params, b, max_len=S + T + mm_len)

    logits_ref, _ = prefill_logits(S + T)

    logits, caches = prefill_logits(S)
    mm = mm_len
    for i in range(T):
        logits, caches = model.decode_step(
            params, caches, toks[:, S + i:S + i + 1],
            jnp.asarray(S + i + mm, jnp.int32))
    err = float(jnp.max(jnp.abs(logits - logits_ref)))
    assert err < 2e-2, f"{arch}: decode drift {err}"
