"""Resumable checkpointing: the versioned blob format (bit-exact mixed
f32/bf16 round-trips, writable restores, atomic writes, corrupt/torn
detection, structure-drift errors naming the offending key, legacy
format), the manifest-based Checkpointer (retention, async commits,
fingerprint guard, corrupt-latest fallback), segment-level bit-exact
resume through the lossy-wire + statistical-merger engine, and sharded
save -> restore -> re-shard parity on the debug mesh."""
import json
import os
import textwrap

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest

from repro.checkpoint import CheckpointCorruptError, Checkpointer
from repro.checkpoint import io as ckpt_io
from repro.checkpoint import restore, save
from repro.core import dsgd, topology
from repro.optim import make_optimizer


def _mixed_state(m=4, seed=0):
    """A full panel train state with MIXED dtype groups (bf16 params ride
    along): int8_ef residuals + fisher statistics panels included."""
    def init_params(rng):
        k1, k2 = jax.random.split(rng)
        return {"w": jax.random.normal(k1, (6, 3)) * 0.1,
                "e": jax.random.normal(k2, (5,), jnp.bfloat16),
                "b": jnp.zeros(3)}

    opt = make_optimizer("adamw", 1e-2)
    return dsgd.init_panel_state(init_params, opt, m,
                                 jax.random.PRNGKey(seed), wire="int8_ef",
                                 merger="fisher")


def _randomized(state, seed=1):
    """Fill every leaf with fresh values (the init state's zeros would
    round-trip trivially)."""
    rng = np.random.default_rng(seed)
    return jax.tree.map(
        lambda x: jnp.asarray(
            rng.normal(size=x.shape).astype(np.float32)).astype(x.dtype),
        state)


# ------------------------------------------------------------ blob format


def test_roundtrip_full_state_bit_exact(tmp_path):
    state, _ = _mixed_state()
    state = _randomized(state)
    path = str(tmp_path / "s.ckpt")
    save(path, state)
    back = restore(path, state)
    ref = jax.tree_util.tree_flatten_with_path(state)[0]
    for (kp, a), b in zip(ref, jax.tree.leaves(back)):
        assert np.asarray(a).dtype == b.dtype, kp
        np.testing.assert_array_equal(np.asarray(a), b)
    assert {"float32", "bfloat16"} <= set(state["panel"])


def test_restore_returns_writable_arrays(tmp_path):
    """Regression: np.frombuffer views are READ-ONLY; a restore must copy
    so jax donation / in-place host mutation work downstream."""
    state, _ = _mixed_state()
    path = str(tmp_path / "s.ckpt")
    save(path, state)
    back = restore(path, state)
    for leaf in jax.tree.leaves(back):
        assert leaf.flags.writeable
        leaf.flat[0] = leaf.flat[0]  # must not raise


def test_save_is_atomic_no_stray_tmp(tmp_path):
    state, _ = _mixed_state()
    save(str(tmp_path / "s.ckpt"), state)
    assert sorted(os.listdir(tmp_path)) == ["s.ckpt"]


def test_meta_round_trips_pcg64_state(tmp_path):
    rng = np.random.default_rng(123)
    rng.normal(size=17)  # advance so the state is non-trivial
    path = str(tmp_path / "s.ckpt")
    save(path, {"x": jnp.zeros(3)},
         meta={"rng": rng.bit_generator.state, "round": 7})
    _, meta = restore(path, {"x": jnp.zeros(3)}, with_meta=True)
    assert meta["round"] == 7
    rng2 = np.random.default_rng(0)
    rng2.bit_generator.state = meta["rng"]
    np.testing.assert_array_equal(rng.normal(size=5), rng2.normal(size=5))


def test_restore_errors_name_the_offending_key(tmp_path):
    like = {"a": jnp.zeros((2, 3)), "b": jnp.zeros(4, jnp.bfloat16)}
    path = str(tmp_path / "s.ckpt")
    save(path, like)
    with pytest.raises(KeyError, match="missing key '.*c'"):
        restore(path, {**like, "c": jnp.zeros(1)})
    with pytest.raises(ValueError, match="keys the reference tree does "
                                         "not.*'b'"):
        restore(path, {"a": like["a"]})
    with pytest.raises(ValueError, match="'a' has shape"):
        restore(path, {**like, "a": jnp.zeros((3, 2))})
    with pytest.raises(ValueError, match="'b' has dtype"):
        restore(path, {**like, "b": jnp.zeros(4, jnp.float16)})


def test_corrupt_and_torn_files_detected(tmp_path):
    state = {"x": jnp.arange(64, dtype=jnp.float32)}
    path = str(tmp_path / "s.ckpt")
    save(path, state)
    blob = open(path, "rb").read()
    with open(path, "wb") as f:  # torn write: truncated tail
        f.write(blob[: len(blob) // 2])
    with pytest.raises(CheckpointCorruptError):
        restore(path, state)
    flipped = bytearray(blob)
    flipped[-8] ^= 0xFF  # bit rot: checksum must catch it
    with open(path, "wb") as f:
        f.write(bytes(flipped))
    with pytest.raises(CheckpointCorruptError, match="checksum"):
        restore(path, state)


def test_legacy_flat_format_still_restores(tmp_path):
    state, _ = _mixed_state()
    state = _randomized(state, seed=3)
    flat = ckpt_io._flatten_to_host(state)
    legacy = msgpack.packb(
        {k: {"dtype": np.dtype(a.dtype).name, "shape": list(a.shape),
             "data": a.tobytes()} for k, a in flat.items()})
    path = str(tmp_path / "legacy.ckpt")
    with open(path, "wb") as f:
        f.write(legacy)
    back, meta = restore(path, state, with_meta=True)
    assert meta == {}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), b)


# ------------------------------------------------------------ Checkpointer


def test_checkpointer_retention_and_manifest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, fingerprint={"run": "a"})
    like = {"x": jnp.zeros(8)}
    for step in (1, 2, 3):
        ck.save(step, {"x": jnp.full(8, float(step))})
    assert ck.latest_step() == 3
    files = sorted(f for f in os.listdir(tmp_path) if f.endswith(".ckpt"))
    assert files == ["step_00000002.ckpt", "step_00000003.ckpt"]
    man = json.load(open(tmp_path / "MANIFEST.json"))
    assert [c["step"] for c in man["checkpoints"]] == [2, 3]
    assert man["fingerprint"] == {"run": "a"}
    assert all(c["bytes"] > 0 and "crc" in c for c in man["checkpoints"])
    step, tree, _ = ck.restore_latest(like)
    assert step == 3
    np.testing.assert_array_equal(tree["x"], np.full(8, 3.0))


def test_checkpointer_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(5, {"x": jnp.arange(4.0)}, meta={"round": 5}, block=False)
    ck.wait()
    step, tree, meta = ck.restore_latest({"x": jnp.zeros(4)})
    assert step == 5 and meta["round"] == 5
    np.testing.assert_array_equal(tree["x"], np.arange(4.0))


def test_checkpointer_corrupt_latest_falls_back(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(1, {"x": jnp.full(4, 1.0)})
    ck.save(2, {"x": jnp.full(4, 2.0)})
    latest = tmp_path / "step_00000002.ckpt"
    blob = latest.read_bytes()
    latest.write_bytes(blob[: len(blob) // 2])
    with pytest.warns(RuntimeWarning, match="corrupt"):
        step, tree, _ = ck.restore_latest({"x": jnp.zeros(4)})
    assert step == 1
    np.testing.assert_array_equal(tree["x"], np.full(4, 1.0))


def test_checkpointer_finds_orphan_checkpoints(tmp_path):
    """A checkpoint whose manifest update was lost (crash between file
    and manifest write) is still picked up by the directory scan."""
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(1, {"x": jnp.full(4, 1.0)})
    save(str(tmp_path / "step_00000009.ckpt"), {"x": jnp.full(4, 9.0)})
    step, tree, _ = ck.restore_latest({"x": jnp.zeros(4)})
    assert step == 9
    np.testing.assert_array_equal(tree["x"], np.full(4, 9.0))


def test_checkpointer_fingerprint_guard(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2,
                      fingerprint={"seed": 0, "wire": "int8_ef"})
    ck.save(1, {"x": jnp.zeros(2)})
    # same fingerprint reopens fine
    Checkpointer(str(tmp_path), keep=2,
                 fingerprint={"seed": 0, "wire": "int8_ef"})
    with pytest.raises(ValueError, match="seed"):
        Checkpointer(str(tmp_path), keep=2,
                     fingerprint={"seed": 1, "wire": "int8_ef"})


# ------------------------------------------------------- bit-exact resume


def test_segment_resume_bit_exact(tmp_path):
    """Launcher resume contract at the engine level: save after segment
    1, restore, run segment 2 — the final state matches the
    uninterrupted two-segment run BIT-exactly, through the int8_ef wire
    (stochastic rounding) and the fisher (non-uniform) merger."""
    m, H, dim, classes = 4, 2, 8, 3

    def init_params(rng):
        k1, _ = jax.random.split(rng)
        return {"w": jax.random.normal(k1, (dim, classes)) * 0.1,
                "b": jnp.zeros(classes)}

    def loss_fn(p, batch, rng=None):
        x, y = batch
        lg = x @ p["w"] + p["b"]
        return jnp.mean(jax.nn.logsumexp(lg, -1)
                        - jnp.take_along_axis(lg, y[:, None], -1)[:, 0]), {}

    opt = make_optimizer("adamw", 1e-2)
    host = np.random.default_rng(0)
    segs = []
    for _ in range(2):  # two segments of 2 rounds; last round is global
        Ws = np.stack([topology.random_matching(m, 0.9, host),
                       topology.fully_connected(m)])
        bx = host.normal(size=(2, H, m, 8, dim)).astype(np.float32)
        by = host.integers(0, classes, size=(2, H, m, 8)).astype(np.int32)
        segs.append((jnp.asarray(Ws, jnp.float32),
                     (jnp.asarray(bx), jnp.asarray(by)),
                     jnp.asarray([False, True])))

    def run(resume_from=None):
        st, spec = dsgd.init_panel_state(
            init_params, opt, m, jax.random.PRNGKey(0), wire="int8_ef",
            merger="fisher")
        seg = dsgd.make_panel_segment(loss_fn, opt, H, spec)
        key = jax.random.PRNGKey(7)
        start = 0
        if resume_from is not None:
            rec = jax.tree.map(jnp.asarray, restore(
                resume_from, {"state": st, "key": key}))
            st, key = rec["state"], rec["key"]
            start = 1
        for i in range(start, 2):
            Ws, batches, glob = segs[i]
            key, k = jax.random.split(key)
            st, _ = seg(st, batches, Ws, k, None, glob)
            if i == 0:
                save(str(tmp_path / "mid.ckpt"), {"state": st, "key": key})
        return jax.tree.map(np.asarray, st)

    full = run()
    resumed = run(resume_from=str(tmp_path / "mid.ckpt"))
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------- sharded state

SHARDED_ROUNDTRIP_SCRIPT = textwrap.dedent("""
    import json, os, tempfile
    import jax, jax.numpy as jnp, numpy as np
    from repro.checkpoint import restore, save
    from repro.core import dsgd
    from repro.launch import mesh as mesh_mod
    from repro.optim import make_optimizer

    mesh = mesh_mod.make_debug_mesh(agents=2, fsdp=2, model=2)
    m, dim, classes = 2, 16, 4

    def init_params(rng):
        k1, _ = jax.random.split(rng)
        return {"w": jax.random.normal(k1, (dim, classes)) * 0.1,
                "b": jnp.zeros(classes)}

    opt = make_optimizer("adamw", 1e-2)
    st, spec = dsgd.init_panel_state(init_params, opt, m,
                                     jax.random.PRNGKey(0), mesh=mesh,
                                     wire="int8_ef", merger="fisher")
    rng = np.random.default_rng(1)
    st = jax.tree.map(
        lambda x: jax.device_put(
            jnp.asarray(rng.normal(size=x.shape).astype(np.float32)
                        ).astype(x.dtype), x.sharding), st)
    path = os.path.join(tempfile.mkdtemp(), "s.ckpt")
    save(path, st)
    host = restore(path, st)
    shardings = dsgd.panel_state_shardings(st, spec)
    placed = jax.device_put(host, shardings)
    exact = all(np.array_equal(np.asarray(a), np.asarray(b)) for a, b in
                zip(jax.tree.leaves(st), jax.tree.leaves(placed)))
    resharded = all(
        b.sharding.is_equivalent_to(sh, b.ndim)
        for sh, b in zip(jax.tree.leaves(shardings),
                         jax.tree.leaves(placed)))
    row_sharded = placed["panel"]["float32"].sharding.is_equivalent_to(
        shardings["panel"]["float32"], 2)
    print(json.dumps({"exact": exact, "resharded": resharded,
                      "row_sharded": bool(row_sharded),
                      "devices": jax.device_count()}))
""")


def test_sharded_save_restore_reshard_parity(multidevice):
    """A spec-sharded state saves from the (1,2,2,2) debug mesh, restores
    on host, and re-shards to the exact same values and layout."""
    rec = multidevice(SHARDED_ROUNDTRIP_SCRIPT, devices=8)
    assert rec["devices"] == 8
    assert rec["exact"] is True
    assert rec["resharded"] is True
    assert rec["row_sharded"] is True
