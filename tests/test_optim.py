"""Optimizers + checkpoint + HLO parser unit tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.optim import adamw, make_optimizer, sgd, warmup_cosine
from repro.utils.hlo import collective_bytes, shape_bytes


def test_sgd_step():
    opt = sgd(0.5)
    p = {"w": jnp.array([1.0, 2.0])}
    s = opt.init(p)
    g = {"w": jnp.array([1.0, -2.0])}
    p2, s2 = opt.update(g, s, p)
    np.testing.assert_allclose(p2["w"], [0.5, 3.0])


def test_sgd_momentum_accumulates():
    opt = sgd(1.0, momentum=0.9)
    p = {"w": jnp.zeros(1)}
    s = opt.init(p)
    g = {"w": jnp.ones(1)}
    p, s = opt.update(g, s, p)
    np.testing.assert_allclose(p["w"], [-1.0])
    p, s = opt.update(g, s, p)
    np.testing.assert_allclose(p["w"], [-1.0 - 1.9])


def test_adamw_matches_manual_first_step():
    opt = adamw(1e-1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.array([1.0])}
    s = opt.init(p)
    g = {"w": jnp.array([0.5])}
    p2, _ = opt.update(g, s, p)
    # first adam step moves by ~lr * sign(g)
    np.testing.assert_allclose(p2["w"], p["w"] - 0.1 * 0.5 / (0.5 + 1e-8),
                               rtol=1e-4)


def test_adamw_weight_decay_shrinks():
    opt = adamw(1e-2, weight_decay=0.5)
    p = {"w": jnp.array([10.0])}
    s = opt.init(p)
    g = {"w": jnp.array([0.0])}
    p2, _ = opt.update(g, s, p)
    assert float(p2["w"][0]) < 10.0


def test_optimizers_vmappable():
    opt = make_optimizer("adamw", 1e-3)
    m = 3
    p = {"w": jnp.ones((m, 4))}
    s = jax.vmap(opt.init)(p)
    g = {"w": jnp.ones((m, 4)) * jnp.arange(1, m + 1)[:, None]}
    p2, s2 = jax.vmap(opt.update)(g, s, p)
    assert p2["w"].shape == (m, 4)
    # per-agent optimizer states diverge with per-agent gradients
    assert not np.allclose(s2["v"]["w"][0], s2["v"]["w"][2])


def test_warmup_cosine_monotone_warmup():
    f = warmup_cosine(1.0, 100, warmup=10)
    assert float(f(0)) == 0.0
    assert float(f(5)) < float(f(10))
    assert float(f(10)) == pytest.approx(1.0, rel=1e-3)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.array([1, 2], jnp.int32)}}
    path = os.path.join(tmp_path, "ck.msgpack")
    save(path, tree)
    out = restore(path, jax.tree.map(jnp.zeros_like, tree))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_shape_bytes():
    assert shape_bytes("f32[2,3]{1,0}") == 24
    assert shape_bytes("bf16[128]") == 256
    assert shape_bytes("(f32[2], s32[4])") == 24
    assert shape_bytes("pred[]") == 1


def test_collective_bytes_parser():
    hlo = """
HloModule jit_step

%body.1 (p: (f32[8])) -> (f32[8]) {
  %x = f32[1024]{0} all-gather(%p), dims={0}
  ROOT %t = (f32[8]) tuple()
}

ENTRY %main () -> f32[] {
  %w = f32[16,16]{1,0} parameter(0)
  %ag = f32[256,128]{1,0} all-gather(%w), replica_groups={}
  %ar = f32[64]{0} all-reduce(%w), to_apply=%add
  %cp = f32[32]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %wl = (f32[8]) while(%t), condition=%c, body=%body.1, backend_config={"known_trip_count":{"n":"12"}}
}
"""
    per_kind, total, counts = collective_bytes(hlo)
    assert per_kind["all-gather"] == 256 * 128 * 4 + 12 * 1024 * 4
    assert per_kind["all-reduce"] == 64 * 4
    assert per_kind["collective-permute"] == 32 * 4
    assert counts["all-gather"] == 13
