"""Serving engine: batched generate, greedy determinism, merged-model flow."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import dsgd
from repro.core.gossip import merged_model
from repro.models import build_model
from repro.optim import make_optimizer
from repro.serving import generate


def test_generate_shapes_and_determinism():
    cfg = get_config("olmo-1b").reduced(d_model=128, vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (3, 16), 0,
                                          cfg.vocab_size)}
    out1 = generate(model, params, batch, 6)
    out2 = generate(model, params, batch, 6)
    assert out1.shape == (3, 6)
    np.testing.assert_array_equal(out1, out2)  # greedy is deterministic
    assert out1.dtype == np.int32
    assert (out1 >= 0).all() and (out1 < cfg.padded_vocab).all()


def test_generate_temperature_sampling_varies():
    cfg = get_config("olmo-1b").reduced(d_model=128, vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab_size)}
    a = generate(model, params, batch, 8, temperature=2.0,
                 rng=jax.random.PRNGKey(2))
    b = generate(model, params, batch, 8, temperature=2.0,
                 rng=jax.random.PRNGKey(3))
    assert not np.array_equal(a, b)


def test_serve_the_merged_model_end_to_end():
    """Train decentralized -> merge -> serve: the paper's full pipeline."""
    cfg = get_config("olmo-1b").reduced(d_model=64, vocab=64)
    model = build_model(cfg)
    m = 2
    opt = make_optimizer("adamw", 1e-3)
    state = dsgd.init_state(model.init_params, opt, m, jax.random.PRNGKey(0))
    step = jax.jit(dsgd.make_dsgd_step(model.loss_fn, opt))
    key = jax.random.PRNGKey(1)
    for t in range(2):
        key, k1, k2 = jax.random.split(key, 3)
        batch = {"tokens": jax.random.randint(k1, (m, 2, 16), 0, 64),
                 "targets": jax.random.randint(k2, (m, 2, 16), 0, 64),
                 "mask": jnp.ones((m, 2, 16), jnp.float32)}
        W = jnp.eye(m) if t == 0 else jnp.full((m, m), 1.0 / m)
        state, _ = step(state, batch, W.astype(jnp.float32), key)
    merged = merged_model(state["params"])
    out = generate(model, merged, {"tokens": jnp.zeros((2, 8), jnp.int32)}, 4)
    assert out.shape == (2, 4)


def test_generate_vlm_with_prefix():
    cfg = get_config("qwen2-vl-72b").reduced(d_model=128, vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab_size),
             "patch_embeds": jax.random.normal(jax.random.PRNGKey(2),
                                               (2, cfg.mm_prefix,
                                                cfg.d_model))}
    out = generate(model, params, batch, 4)
    assert out.shape == (2, 4)
