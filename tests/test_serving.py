"""Serving engine: OOV-safe sampling, donated caches, continuous batching
(slot lifecycle, bit-exact parity with single-request generate), merged-model
checkpoint round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.configs import get_config
from repro.core import dsgd
from repro.core.gossip import merged_model
from repro.models import build_model
from repro.optim import make_optimizer
from repro.serving import (Request, ServingEngine, generate, make_decode_fn,
                           make_prefill_fn, mask_oov, sample_token)

pytestmark = pytest.mark.serve


def _tiny(arch="olmo-1b", d=64, vocab=64, **kw):
    cfg = get_config(arch).reduced(d_model=d, vocab=vocab, **kw)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(i, S, vocab):
    key = jax.random.fold_in(jax.random.PRNGKey(1), i)
    return np.asarray(jax.random.randint(key, (S,), 0, vocab), np.int32)


def _batch_of(req):
    b = {"tokens": jnp.asarray(req.tokens[None])}
    for k, v in req.extras.items():
        b[k] = jnp.asarray(v)[None]
    return b


# ---------------------------------------------------------------------------
# basic generate (pre-existing behavior)
# ---------------------------------------------------------------------------


def test_generate_shapes_and_determinism():
    cfg, model, params = _tiny(d=128, vocab=128)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (3, 16), 0,
                                          cfg.vocab_size)}
    out1 = generate(model, params, batch, 6)
    out2 = generate(model, params, batch, 6)
    assert out1.shape == (3, 6)
    np.testing.assert_array_equal(out1, out2)  # greedy is deterministic
    assert out1.dtype == np.int32
    assert (out1 >= 0).all() and (out1 < cfg.vocab_size).all()


def test_generate_temperature_sampling_varies():
    cfg, model, params = _tiny(d=128, vocab=128)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab_size)}
    a = generate(model, params, batch, 8, temperature=2.0,
                 rng=jax.random.PRNGKey(2))
    b = generate(model, params, batch, 8, temperature=2.0,
                 rng=jax.random.PRNGKey(3))
    assert not np.array_equal(a, b)


def test_generate_vlm_with_prefix():
    cfg, model, params = _tiny("qwen2-vl-72b", d=128, vocab=128)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab_size),
             "patch_embeds": jax.random.normal(jax.random.PRNGKey(2),
                                               (2, cfg.mm_prefix,
                                                cfg.d_model))}
    out = generate(model, params, batch, 4)
    assert out.shape == (2, 4)


# ---------------------------------------------------------------------------
# bugfix: sampling must never emit out-of-vocab (padded_vocab tail)
# ---------------------------------------------------------------------------


def test_sample_token_masks_padded_vocab_tail():
    # craft logits whose maximum sits in the padding tail
    logits = jnp.zeros((2, 16)).at[:, 13].set(100.0).at[0, 3].set(1.0)
    tok = sample_token(logits, jax.random.PRNGKey(0), 0.0, vocab_size=10)
    np.testing.assert_array_equal(np.asarray(tok), [3, 0])
    for s in range(8):
        tok = sample_token(logits, jax.random.PRNGKey(s), 1.0, vocab_size=10)
        assert (np.asarray(tok) < 10).all()
    # unmasked, the tail wins — the bug this guards against
    assert (np.asarray(jnp.argmax(logits, -1)) == 13).all()
    masked = mask_oov(logits, 10)
    assert np.isneginf(np.asarray(masked)[:, 10:]).all()


def test_generate_never_emits_oov_ids():
    """padded_vocab (256) > vocab_size (250): the head's random-init padding
    columns must never be sampled, greedy or tempered."""
    cfg, model, params = _tiny(vocab=250)
    assert cfg.padded_vocab > cfg.vocab_size
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                                          cfg.vocab_size)}
    greedy = generate(model, params, batch, 8)
    temped = generate(model, params, batch, 8, temperature=1.5,
                      rng=jax.random.PRNGKey(2))
    assert (greedy < cfg.vocab_size).all() and (greedy >= 0).all()
    assert (temped < cfg.vocab_size).all() and (temped >= 0).all()


def test_engine_never_emits_oov_ids():
    cfg, model, params = _tiny(vocab=250)
    eng = ServingEngine(model, params, max_concurrency=2, max_len=24,
                        temperature=1.5, rng=jax.random.PRNGKey(3))
    reqs = [Request(rid=i, tokens=_prompt(i, 8, cfg.vocab_size), max_new=8)
            for i in range(3)]
    out = eng.serve(reqs)
    for v in out.values():
        assert (v < cfg.vocab_size).all() and (v >= 0).all()


# ---------------------------------------------------------------------------
# bugfix: donated caches — no per-step reallocation, no per-token host sync
# ---------------------------------------------------------------------------


def _leaf_ptrs(tree):
    return sorted(x.unsafe_buffer_pointer()
                  for x in jax.tree_util.tree_leaves(tree))


def test_decode_fn_donates_cache_in_place():
    cfg, model, params = _tiny()
    prefill = make_prefill_fn(model, max_len=32)
    logits, caches = prefill(params, {"tokens": jnp.asarray(
        _prompt(0, 8, cfg.vocab_size)[None])})
    decode = make_decode_fn(model)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    before = _leaf_ptrs(caches)
    old_leaves = jax.tree_util.tree_leaves(caches)
    _, new_caches = decode(params, caches, tok, jnp.asarray(8, jnp.int32))
    # the donated input buffers are consumed...
    assert all(x.is_deleted() for x in old_leaves)
    # ...and the new cache aliases exactly the same device buffers
    assert _leaf_ptrs(new_caches) == before


def test_engine_cache_buffer_persists_across_ticks():
    cfg, model, params = _tiny()
    eng = ServingEngine(model, params, max_concurrency=2, max_len=32)
    eng.submit(Request(rid=0, tokens=_prompt(0, 8, cfg.vocab_size),
                       max_new=6))
    eng.admit()
    ptrs = _leaf_ptrs(eng.caches)
    for _ in range(4):
        eng.step()
    assert _leaf_ptrs(eng.caches) == ptrs  # same buffers, every tick
    # admission (insert) also updates the donated buffer in place
    eng.submit(Request(rid=1, tokens=_prompt(1, 8, cfg.vocab_size),
                       max_new=4))
    eng.admit()
    assert _leaf_ptrs(eng.caches) == ptrs


# ---------------------------------------------------------------------------
# continuous batching: parity, slot lifecycle, EOS, mixed batches
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,kw", [
    ("olmo-1b", {}),                      # GQA, tied embeddings
    ("recurrentgemma-2b", {"layers": 3}),  # RG-LRU + local sliding window
    ("seamless-m4t-medium", {}),          # enc-dec: padded cross-KV rows
])
def test_continuous_batching_bit_identical_to_sequential(arch, kw):
    """N heterogeneous requests through the slotted engine produce
    bit-identical tokens to N single-request generate calls (temp 0)."""
    cfg, model, params = _tiny(arch, **kw)
    max_len = 48
    eng = ServingEngine(model, params, max_concurrency=3, max_len=max_len)
    reqs = []
    for i in range(5):
        S = [8, 12][i % 2]
        extras = {}
        if cfg.encoder_layers:
            extras["frame_embeds"] = np.asarray(jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(7), i),
                (S, cfg.d_model)))
        reqs.append(Request(rid=i, tokens=_prompt(i, S, cfg.vocab_size),
                            max_new=4 + (i % 3), extras=extras))
    out = eng.serve(reqs)
    assert eng.stats["admitted"] == 5 and eng.stats["retired"] == 5
    assert 0.0 < eng.occupancy <= 1.0
    for r in reqs:
        ref = generate(model, params, _batch_of(r), r.max_new,
                       max_len=max_len)[0]
        np.testing.assert_array_equal(out[r.rid], ref)


def test_mixed_batch_multimodal_prefix_parity():
    """VLM requests with and without a patch-embed prefix share slots."""
    cfg, model, params = _tiny("qwen2-vl-72b")
    max_len = 48
    eng = ServingEngine(model, params, max_concurrency=3, max_len=max_len)
    reqs = []
    for i in range(4):
        extras = {}
        if i % 2 == 0:
            extras["patch_embeds"] = np.asarray(jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(8), i),
                (cfg.mm_prefix, cfg.d_model)))
        reqs.append(Request(rid=i, tokens=_prompt(i, 8, cfg.vocab_size),
                            max_new=5, extras=extras))
    out = eng.serve(reqs)
    for r in reqs:
        ref = generate(model, params, _batch_of(r), r.max_new,
                       max_len=max_len)[0]
        np.testing.assert_array_equal(out[r.rid], ref)


def test_slot_insert_evict_reuse():
    cfg, model, params = _tiny()
    eng = ServingEngine(model, params, max_concurrency=2, max_len=32)
    r0 = Request(rid="a", tokens=_prompt(0, 8, cfg.vocab_size), max_new=12)
    r1 = Request(rid="b", tokens=_prompt(1, 8, cfg.vocab_size), max_new=12)
    eng.submit(r0)
    eng.submit(r1)
    eng.admit()
    assert eng.free_slots() == [] and eng.live_slots() == [0, 1]
    eng.step()
    # evict slot 0 mid-flight: slot frees, survivor is unperturbed
    eng.evict(0)
    assert eng.free_slots() == [0]
    out = eng.serve([])  # drain slot 1
    ref1 = generate(model, params, _batch_of(r1), r1.max_new, max_len=32)[0]
    np.testing.assert_array_equal(out["b"], ref1)
    # the evicted slot is reusable and serves a fresh request correctly
    r2 = Request(rid="c", tokens=_prompt(2, 8, cfg.vocab_size), max_new=6)
    out = eng.serve([r2])
    assert eng.stats["admitted"] == 3
    ref2 = generate(model, params, _batch_of(r2), r2.max_new, max_len=32)[0]
    np.testing.assert_array_equal(out["c"], ref2)


def test_eos_retires_slot_and_stops_generate():
    cfg, model, params = _tiny()
    req = Request(rid=0, tokens=_prompt(0, 8, cfg.vocab_size), max_new=10)
    free = generate(model, params, _batch_of(req), 10, max_len=32)[0]
    eos = int(free[2])  # declare a token the model emits to be "EOS"
    j = int(np.argmax(free == eos))  # first occurrence in the free run
    # generate: rows stop at eos and the tail is eos-padded
    out = generate(model, params, _batch_of(req), 10, max_len=32,
                   eos_id=eos)[0]
    np.testing.assert_array_equal(out[:j + 1], free[:j + 1])
    assert (out[j:] == eos).all()
    # engine: the slot retires at eos and the freed slot admits the queue
    eng = ServingEngine(model, params, max_concurrency=1, max_len=32,
                        eos_id=eos)
    nxt = Request(rid=1, tokens=_prompt(1, 8, cfg.vocab_size), max_new=4)
    served = eng.serve([req, nxt])
    assert list(served[0]) == list(free[:j + 1])  # ends AT the eos token
    assert served[0][-1] == eos
    assert eng.stats["admitted"] == 2 and eng.stats["retired"] == 2
    assert len(served[1]) == 4


def test_engine_rejects_oversized_request():
    cfg, model, params = _tiny()
    eng = ServingEngine(model, params, max_concurrency=1, max_len=16)
    eng.submit(Request(rid=0, tokens=_prompt(0, 12, cfg.vocab_size),
                       max_new=8))
    with pytest.raises(ValueError, match="max_len"):
        eng.admit()


# ---------------------------------------------------------------------------
# the paper's pipeline: train -> single global merge -> save -> serve
# ---------------------------------------------------------------------------


def test_serve_the_merged_model_end_to_end():
    """Train decentralized -> merge -> serve: the paper's full pipeline."""
    cfg, model, params = _tiny(vocab=64)
    m = 2
    opt = make_optimizer("adamw", 1e-3)
    state = dsgd.init_state(model.init_params, opt, m, jax.random.PRNGKey(0))
    step = jax.jit(dsgd.make_dsgd_step(model.loss_fn, opt))
    key = jax.random.PRNGKey(1)
    for t in range(2):
        key, k1, k2 = jax.random.split(key, 3)
        batch = {"tokens": jax.random.randint(k1, (m, 2, 16), 0, 64),
                 "targets": jax.random.randint(k2, (m, 2, 16), 0, 64),
                 "mask": jnp.ones((m, 2, 16), jnp.float32)}
        W = jnp.eye(m) if t == 0 else jnp.full((m, m), 1.0 / m)
        state, _ = step(state, batch, W.astype(jnp.float32), key)
    merged = merged_model(state["params"])
    out = generate(model, merged, {"tokens": jnp.zeros((2, 8), jnp.int32)}, 4)
    assert out.shape == (2, 4)


def test_merged_checkpoint_roundtrip_through_engine(tmp_path):
    """--save-merged -> serve --restore: the checkpointed merged artifact
    serves bit-identically to the in-memory merged model."""
    cfg, model, params = _tiny(vocab=64)
    m = 2
    opt = make_optimizer("adamw", 1e-3)
    state = dsgd.init_state(model.init_params, opt, m, jax.random.PRNGKey(0))
    step = jax.jit(dsgd.make_dsgd_step(model.loss_fn, opt))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (m, 2, 16), 0, 64),
             "targets": jax.random.randint(key, (m, 2, 16), 0, 64),
             "mask": jnp.ones((m, 2, 16), jnp.float32)}
    state, _ = step(state, batch, jnp.full((m, m), 0.5, jnp.float32), key)
    merged = merged_model(state["params"])
    path = str(tmp_path / "merged.msgpack")
    save(path, merged)
    # restore into a DIFFERENT init to prove the artifact carries the model
    template = model.init_params(jax.random.PRNGKey(9))
    restored = restore(path, template)
    req = Request(rid=0, tokens=_prompt(0, 8, cfg.vocab_size), max_new=6)
    eng = ServingEngine(model, restored, max_concurrency=2, max_len=32)
    out = eng.serve([req])
    ref = generate(model, merged, _batch_of(req), 6, max_len=32)[0]
    np.testing.assert_array_equal(out[0], ref)
