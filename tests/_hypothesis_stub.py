"""Fallback shim for ``hypothesis`` in offline containers.

The property-test modules do ``from hypothesis import given, settings,
strategies as st`` at import time; when hypothesis is not installable the
whole module (and every plain test in it) used to die at collection. This
stub mirrors just enough of the API that collection succeeds and each
property test reports as SKIPPED instead. Install the ``dev`` extra
(``pip install -e .[dev]``) to run the real property tests.
"""
from __future__ import annotations

import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        # Zero-arg replacement: the strategy-driven parameters must not be
        # visible to pytest or it would go looking for fixtures of the
        # same names.
        def _skipped():
            pytest.skip("hypothesis not installed (dev extra)")
        _skipped.__name__ = fn.__name__
        _skipped.__doc__ = fn.__doc__
        return _skipped
    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _Inert:
    """Absorbs any chained use of a strategy: ``st.lists(...)``,
    ``st.tuples(...).map(f)``, ``st.sampled_from(...).filter(g)`` — every
    attribute access and call returns the same inert object."""

    def __call__(self, *a, **k):
        return self

    def __getattr__(self, name):
        return self


class _Strategies:
    """Any ``st.<name>`` lookup returns an inert placeholder."""

    def __getattr__(self, name):
        return _Inert()


strategies = _Strategies()
