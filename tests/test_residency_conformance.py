"""Storage-codec conformance suite: one parametrized harness over EVERY
entry in ``repro.residency.STORAGE``.

Each test body is storage-GENERIC — it reads only the shared contract
surface (``needs_key``, ``init`` / ``write`` / ``read`` / ``maybe_read``
/ ``zero_like``, ``transform_fwd`` / ``transform_inv``,
``resident_bytes`` accounting) and never branches on a codec's NAME.
Registering a new storage in ``STORAGE`` is all it takes to put it
under the full contract:

* ``resident_bytes`` equals the ``.nbytes`` of the actual stored
  arrays (odd widths exercise the grouped-scale ceil tails) and is
  linear in rows; ``PanelSpec.storage_bytes`` and the telemetry
  ``resident_bytes_model`` agree with it;
* ``zero_like`` is bit-identical to ``init(zeros)`` and decodes to
  exact zeros (the RESYNC canonical re-init contract);
* round-trip error is bounded by half a quantization step in the
  codec's TRANSFORM domain (identity for linear codecs, signed-sqrt
  for the companded int8 moment storages);
* stochastic rounding is unbiased over PRNG keys in the transform
  domain (the value domain picks up a small positive Jensen bias on
  companded codecs — the safe direction for Adam's second moment);
  deterministic storages are key-invariant;
* the Pallas kernel path is bit-identical to the XLA/ref path, and
  sharded writes match replicated ones (``threefry_partitionable``);
* an all-f32 residency policy leaves the spec AND a full segment run
  byte-identical to the no-policy engine; quantized-moment policies
  track the f32 run's loss; dead agents' STORED rows (q and scale
  sidecars) pass through a segment bit-exactly;
* checkpoints round-trip every stored representation bit-exactly, and
  v1 blobs (same table schema, pre-packed-blob header) still load.
"""
import json
import textwrap
import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest

from repro import merging as merging_mod
from repro import residency as res_mod
from repro import telemetry
from repro.checkpoint import io as ckpt_io
from repro.core import dsgd
from repro.core import panel as panel_mod
from repro.optim import make_optimizer
from repro.kernels import opt_fused
from repro.kernels import ref as ref_kernels
from repro.telemetry.metrics import (fused_moments_auto,
                                     moment_traffic_model,
                                     resident_bytes_model)
from test_panel import _segment_inputs, _toy_problem

pytestmark = pytest.mark.residency

STORAGE_NAMES = sorted(res_mod.STORAGE)


def _panel(m, d, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(m, d)) * scale, jnp.float32)


def _moment_panel(m, d, seed):
    """Adam-v-like panel: strictly positive, wide dynamic range."""
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        np.square(rng.normal(size=(m, d)))
        * np.exp(rng.normal(size=(m, d)) * 2.0) * 1e-4, jnp.float32)


def _key_for(st, seed=0):
    return jax.random.PRNGKey(seed) if st.needs_key else None


def _leaves_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------ registry


@pytest.mark.parametrize("name", STORAGE_NAMES)
def test_registry_contract(name):
    st = res_mod.get_storage(name)
    assert st is res_mod.STORAGE[name]
    assert st.name == name
    assert res_mod.get_storage(st) is st  # instance pass-through
    assert isinstance(st.needs_key, bool)
    m, d = 3, 257
    rb = st.resident_bytes(m, d)
    assert 0 < rb <= m * d * 4
    # accounting is per-row linear: rows scale the byte count exactly
    assert st.resident_bytes(2 * m, d) == 2 * rb


def test_unknown_storage_and_kind_fail_at_parse_time():
    with pytest.raises(ValueError, match="unknown storage"):
        res_mod.get_storage("int7")
    with pytest.raises(ValueError, match="unknown state kinds"):
        res_mod.parse_policy("params=int8")
    with pytest.raises(ValueError, match="unknown storage"):
        res_mod.parse_policy("moments=int7")
    assert res_mod.parse_policy(None) == {}
    assert res_mod.parse_policy("int8") == {"moments": "int8"}
    assert res_mod.parse_policy("moments=int8,stats=bf16") == {
        "moments": "int8", "stats": "bf16"}


# ----------------------------------------------------- byte accounting


@pytest.mark.parametrize("name", STORAGE_NAMES)
def test_resident_bytes_match_stored_nbytes(name):
    """resident_bytes must equal the .nbytes of the ACTUAL stored arrays
    (odd width exercises the grouped-scale ceil tail), and the
    spec-level / telemetry accounting must agree with the codec's."""
    st = res_mod.get_storage(name)
    m, d = 3, 333
    stored = st.init(_moment_panel(m, d, seed=5))
    nb = sum(int(a.nbytes) for a in jax.tree.leaves(stored))
    assert nb == st.resident_bytes(m, d), name

    x = _panel(1, d, seed=5)
    spec = panel_mod.with_residency(panel_mod.make_spec({"w": x}),
                                    {"moments": name})
    assert spec.storage_bytes("moments") == st.resident_bytes(1, d)
    opt = make_optimizer("adamw", 1e-2)
    model = resident_bytes_model(spec, opt)
    assert model["moments"] == 2 * st.resident_bytes(1, d)
    assert model["params"] == 4 * d
    # "total" counts STORED bytes only; decode-time f32 views are the
    # separate transient term and peak = stored + transient
    assert model["total"] == (model["params"] + model["moments"]
                              + model["wire_err"] + model["merge_stat"])
    assert model["peak"] == model["total"] + model["transient_bytes"]
    assert model["transient_bytes"] >= 0
    if name == "f32":
        assert model["transient_bytes"] == 0
    fused = fused_moments_auto(spec, opt)
    if fused:  # fused grouped-int8 decode never materializes f32 moments
        assert resident_bytes_model(spec, opt, fused=False)[
            "transient_bytes"] > model["transient_bytes"]
        assert model["transient_bytes"] == 0
    elif name != "f32":  # unfused non-f32 moments decode to 2 f32 panels
        assert model["transient_bytes"] >= 2 * 4 * d


# ----------------------------------------------------- codec contract


@pytest.mark.parametrize("name", STORAGE_NAMES)
def test_write_requires_key_iff_stochastic(name):
    st = res_mod.get_storage(name)
    x = _moment_panel(2, 64, seed=7)
    if st.needs_key:
        with pytest.raises(ValueError, match="key"):
            st.write(x)
    else:
        a = st.write(x)
        b = st.write(x, key=jax.random.PRNGKey(0))
        _leaves_equal(a, b)  # key-invariant


@pytest.mark.parametrize("name", STORAGE_NAMES)
def test_zero_like_is_init_zeros(name):
    """zero_like must be BIT-identical to init(zeros) — the RESYNC
    canonical re-init rule — and decode to exact zeros."""
    st = res_mod.get_storage(name)
    z = jnp.zeros((3, 96), jnp.float32)
    stored = st.init(_moment_panel(3, 96, seed=9))
    _leaves_equal(st.zero_like(stored), st.init(z))
    assert float(jnp.max(jnp.abs(st.read(st.zero_like(stored))))) == 0.0


@pytest.mark.parametrize("name", STORAGE_NAMES)
def test_roundtrip_bounded_in_transform_domain(name):
    """read(init(x)) must sit within half a quantization step of x in
    the codec's transform domain; maybe_read(read(...)) is idempotent
    (an already-decoded f32 view passes through untouched)."""
    st = res_mod.get_storage(name)
    x = _moment_panel(4, 320, seed=11)
    stored = st.init(x)
    back = st.read(stored)
    assert back.dtype == jnp.float32
    y, yhat = st.transform_fwd(x), st.transform_fwd(back)
    err = jnp.abs(yhat - y)
    if isinstance(stored, dict):  # int8 family: step == stored scale
        g = st.group or x.shape[1]
        step = jnp.repeat(stored["scale"], g, axis=1)[:, :x.shape[1]]
        assert bool(jnp.all(err <= 0.5 * step * (1 + 1e-5) + 1e-12)), name
    else:  # dtype-cast family: half a ulp at the value's scale
        eps = jnp.finfo(stored.dtype).eps
        assert bool(jnp.all(err <= 0.5 * eps * jnp.abs(y) + 1e-12)), name
    decoded = st.maybe_read(back)
    np.testing.assert_array_equal(np.asarray(decoded), np.asarray(back))
    _leaves_equal(st.read(stored), st.maybe_read(stored))


@pytest.mark.parametrize("name", STORAGE_NAMES)
def test_stochastic_unbiased_in_transform_domain(name):
    """Key-driven storages: E_key[decode] == x within 6 empirical
    standard errors per element IN THE TRANSFORM DOMAIN (companded
    codecs' SR is unbiased on sign(x)*sqrt(|x|); squaring back adds a
    positive Jensen term, so the value domain is NOT where the bound
    holds). Same small-p binomial slack as the wire harness."""
    st = res_mod.get_storage(name)
    if not st.needs_key:
        pytest.skip("deterministic storage (key-invariance covered)")
    m, d = 3, 40
    x = _moment_panel(m, d, seed=13)
    y = st.transform_fwd(x)
    N = 256
    keys = jax.random.split(jax.random.PRNGKey(3), N)
    yhats = jax.vmap(
        lambda k: st.transform_fwd(st.read(st.write(x, key=k))))(keys)
    mean_err = jnp.abs(jnp.mean(yhats, axis=0) - y)
    se = jnp.std(yhats, axis=0) / np.sqrt(N)
    step = jnp.max(jnp.max(yhats, axis=0) - jnp.min(yhats, axis=0),
                   axis=1, keepdims=True)
    assert bool(jnp.all(mean_err <= 6.0 * se + 6.0 * step / N
                        + 1e-7)), name


# ------------------------------------------------- kernel / jit parity


@pytest.mark.parametrize("name", STORAGE_NAMES)
def test_pallas_path_matches_ref_path(name):
    """write/read with use_pallas=True must be bit-identical to the
    XLA/ref path given the same key (non-divisible width exercises the
    kernels' padded tails)."""
    st = res_mod.get_storage(name)
    x = _moment_panel(5, 333, seed=17)
    key = _key_for(st, seed=4)
    a = st.write(x, key=key, use_pallas=False)
    b = st.write(x, key=key, use_pallas=True)
    _leaves_equal(a, b)
    np.testing.assert_array_equal(
        np.asarray(st.read(a, use_pallas=False)),
        np.asarray(st.read(b, use_pallas=True)))


@pytest.mark.parametrize("name", STORAGE_NAMES)
def test_writes_bit_identical_sharded_vs_replicated(name):
    """A jitted write with the input sharded over rows must store the
    same bits as the jitted replicated write — the scoped
    ``threefry_partitionable`` contract, same as the wire codecs'."""
    st = res_mod.get_storage(name)
    m, d = 4, 96
    x = _moment_panel(m, d, seed=19)
    key = _key_for(st, seed=6)
    enc = jax.jit(lambda xx: st.write(xx, key=key))
    ja = enc(x)
    from jax.sharding import Mesh, NamedSharding
    from jax.sharding import PartitionSpec as P
    ndev = min(4, jax.device_count())
    mesh = Mesh(np.asarray(jax.devices()[:ndev]), ("rows",))
    xs = jax.device_put(x, NamedSharding(mesh, P("rows", None)))
    _leaves_equal(ja, enc(xs))


def test_grouped_single_group_matches_per_row():
    """An Int8Storage whose group covers the whole width must store the
    exact bits of the per-row layout (one scale per row either way)."""
    d = 200
    per_row = res_mod.Int8Storage("a")
    one_group = res_mod.Int8Storage("b", group=512)
    x = _moment_panel(3, d, seed=21)
    key = jax.random.PRNGKey(5)
    a, b = per_row.write(x, key=key), one_group.write(x, key=key)
    assert a["scale"].shape == b["scale"].shape == (3, 1)
    _leaves_equal(a, b)


# --------------------------------------------------- engine contracts


def _run_segment(policy, live=None, seed=0, fused=None, use_pallas=False):
    m, H, S, dim, classes = 4, 2, 3, 10, 3
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("adamw", 1e-2)
    Ws, (bx, by) = _segment_inputs(S, H, m, dim, classes, seed=seed)
    pstate, spec = dsgd.init_panel_state(
        init_params, opt, m, jax.random.PRNGKey(0), residency=policy)
    before = jax.tree.map(lambda v: v + 0.0, pstate)  # donated below
    seg_fn = dsgd.make_panel_segment(loss_fn, opt, H, spec, fused=fused,
                                     use_pallas=use_pallas)
    out, mets = seg_fn(pstate, (bx, by), Ws, jax.random.PRNGKey(1),
                       live=live)
    return spec, before, out, mets


def test_f32_policy_is_byte_identical_to_no_policy():
    """Explicit f32 entries are dropped from the spec, and the full
    segment run (state AND metrics) is bit-identical to the engine
    that never saw a policy."""
    pol = {"moments": "f32", "stats": "f32", "wire_err": "f32"}
    spec_a, _, out_a, mets_a = _run_segment(None)
    spec_b, _, out_b, mets_b = _run_segment(pol)
    assert spec_a == spec_b
    assert spec_b.residency == ()
    _leaves_equal(out_a, out_b)
    _leaves_equal(mets_a, mets_b)


@pytest.mark.parametrize("name", [n for n in STORAGE_NAMES if n != "f32"])
def test_quantized_moments_track_f32_run(name):
    """Every non-identity storage on the moments must keep the toy
    segment's loss trajectory within tolerance of the f32 engine
    (bf16/companded-int8 moment error does not derail AdamW)."""
    _, _, _, base = _run_segment(None)
    _, _, out, mets = _run_segment({"moments": name})
    assert all(np.isfinite(np.asarray(mets["loss"]).ravel()))
    delta = float(np.max(np.abs(np.asarray(mets["loss"])
                                - np.asarray(base["loss"]))))
    assert delta <= 0.05, (name, delta)
    # stored moments really are the quantized rep, not silent f32
    mom = out["opt"]["m"]["float32"]
    if name == "bf16":
        assert mom.dtype == jnp.bfloat16
    else:
        assert mom["q"].dtype == jnp.int8


@pytest.mark.parametrize("name", [n for n in STORAGE_NAMES if n != "f32"])
def test_dead_rows_pass_through_stored_bits(name):
    """An agent DEAD for the whole segment must keep its STORED moment
    representation — q and scale sidecar rows, not just the decoded
    view — bit-exactly, same as the f32 engine's liveness contract."""
    m, S, dead = 4, 3, 2
    live = np.ones((S, m), np.int32)
    live[:, dead] = 0
    _, before, out, _ = _run_segment({"moments": name},
                                     live=jnp.asarray(live))
    for mk in ("m", "v"):
        b, a = before["opt"][mk]["float32"], out["opt"][mk]["float32"]
        for leaf_b, leaf_a in zip(jax.tree.leaves(b), jax.tree.leaves(a)):
            np.testing.assert_array_equal(
                np.asarray(leaf_b)[dead], np.asarray(leaf_a)[dead])
            assert bool(jnp.any(leaf_a[0] != leaf_b[0]))  # live rows move


def test_merge_decode_stats_accepts_stored_or_decoded():
    """merging.decode_stats must decode a stored stat rep and pass an
    already-decoded f32 view through untouched (idempotence)."""
    x = _panel(1, 64, seed=23)
    spec = panel_mod.with_residency(
        panel_mod.with_merger(panel_mod.make_spec({"w": x}), "var"),
        {"stats": "int8r"})
    st = res_mod.get_storage("int8r")
    raw = _panel(4, 64, seed=25, scale=0.3)
    stats = {"second": {"float32": st.init(raw)}}
    once = merging_mod.decode_stats(stats, spec)
    assert once["second"]["float32"].dtype == jnp.float32
    twice = merging_mod.decode_stats(once, spec)
    _leaves_equal(once, twice)
    np.testing.assert_array_equal(
        np.asarray(once["second"]["float32"]),
        np.asarray(st.read(st.init(raw))))


# ------------------------------------------------ fused moment update


FUSED_NAMES = [n for n in STORAGE_NAMES
               if getattr(res_mod.get_storage(n), "fused_update", False)]


def test_fused_eligibility_predicate():
    """fused_moments_auto — the single predicate the segment driver,
    the accounting models and the launcher consult — must admit exactly
    the grouped-int8 moment storages under an optimizer exposing the
    shared core/hyper, and nothing else."""
    x = _panel(1, 64, seed=41)

    def spec_for(policy):
        return panel_mod.with_residency(panel_mod.make_spec({"w": x}),
                                        policy)

    opt = make_optimizer("adamw", 1e-2)
    assert sorted(FUSED_NAMES) == ["int8", "int8g"]
    for name in FUSED_NAMES:
        assert fused_moments_auto(spec_for({"moments": name}), opt)
    # per-row int8 needs a full-D second sweep for the fresh row scale;
    # f32/bf16 have no decode to fuse; sgd exposes no elementwise core
    for bad in ({"moments": "int8r"}, {"moments": "bf16"}, {}):
        assert not fused_moments_auto(spec_for(bad), opt)
    assert not fused_moments_auto(spec_for({"moments": "int8"}),
                                  make_optimizer("sgd", 1e-2))


def test_fused_flag_refused_when_inapplicable():
    """fused=True on a spec/optimizer the kernel cannot serve must fail
    at build time, not silently fall back."""
    init_params, loss_fn = _toy_problem(2, 10, 3)
    opt = make_optimizer("adamw", 1e-2)
    _, spec = dsgd.init_panel_state(init_params, opt, 2,
                                    jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="fused"):
        dsgd.make_panel_segment(loss_fn, opt, 2, spec, fused=True)


@pytest.mark.parametrize("name", FUSED_NAMES)
@pytest.mark.parametrize("d", [256, 300, 333])
def test_fused_kernel_matches_ref_bit_exact(name, d):
    """The Pallas fused kernel must be bit-identical to the XLA ref
    composition under jit — including partial trailing scale groups
    (d=300/333 are not multiples of either group size) and per-agent
    diverged step counts. Both sides are jitted: the engine only ever
    runs the kernel inside jit, and eager-vs-jit differs by FMA
    contraction, which is not the contract under test."""
    import functools
    from repro.wire.codec import _uniform
    st = res_mod.get_storage(name)
    m = 3
    g = _panel(m, d, seed=31, scale=0.1)
    p = _panel(m, d, seed=32)
    mst = st.init(_moment_panel(m, d, seed=33))
    vst = st.init(_moment_panel(m, d, seed=34))
    um = _uniform(jax.random.PRNGKey(7), (m, d))
    uv = _uniform(jax.random.PRNGKey(8), (m, d))
    opt = make_optimizer("adamw", 1e-2)
    # rows rejoined at different rounds => per-agent bias corrections
    lr, bc1, bc2 = opt.hyper(jnp.asarray([1, 7, 3]))
    fn = functools.partial(
        opt_fused.adamw_fused_int8, group=st.group, core=opt.core,
        transform_fwd=st.transform_fwd, transform_inv=st.transform_inv)
    a = jax.jit(functools.partial(fn, use_pallas=True))(
        g, p, mst["q"], mst["scale"], vst["q"], vst["scale"],
        um, uv, lr, bc1, bc2)
    b = jax.jit(functools.partial(fn, use_pallas=False))(
        g, p, mst["q"], mst["scale"], vst["q"], vst["scale"],
        um, uv, lr, bc1, bc2)
    _leaves_equal(a, b)
    p_new, qm, sm, qv, sv = a
    G = -(-d // (st.group or d))
    assert qm.shape == qv.shape == (m, d)
    assert qm.dtype == qv.dtype == jnp.int8
    assert sm.shape == sv.shape == (m, G)
    assert bool(jnp.all(jnp.isfinite(p_new)))
    assert bool(jnp.any(p_new != p))  # the update actually ran
    # the re-encoded moments carry fresh scales of the UPDATED values
    assert bool(jnp.any(sm != mst["scale"]))


@pytest.mark.parametrize("name", FUSED_NAMES)
@pytest.mark.parametrize("use_pallas", [False, True])
def test_fused_segment_bit_identical_to_unfused(name, use_pallas):
    """The fused path must reproduce the unfused decode->update->encode
    segment at matched keys. The contract the issue demands is SR-noise
    tolerance; the implementation delivers strictly more — the fused
    kernel consumes the SAME uniform panels from the SAME key folds, so
    the trajectories (state AND metrics) are bit-identical, which is
    what makes fused-by-default trajectory-preserving."""
    _, _, a_out, a_mets = _run_segment({"moments": name}, fused=False,
                                       use_pallas=use_pallas)
    _, _, b_out, b_mets = _run_segment({"moments": name}, fused=True,
                                       use_pallas=use_pallas)
    _leaves_equal(a_out, b_out)
    _leaves_equal(a_mets, b_mets)


@pytest.mark.parametrize("name", FUSED_NAMES)
def test_fused_dead_and_resync_stored_rows(name):
    """Liveness under the fused path: a DEAD agent's stored q/scale
    rows pass through the segment bit-exactly (never decoded, never
    re-encoded), and a RESYNC rejoin re-inits its moment rows to the
    canonical zero_like bits (q=0, scale=1/127) — same contracts the
    unfused engine honors."""
    m, S, dead, rej = 4, 3, 2, 3
    live = np.ones((S, m), np.int32)
    live[:, dead] = 0
    live[:, rej] = 0
    live[S - 1, rej] = 2  # rejoins (RESYNC) on the last round
    st = res_mod.get_storage(name)
    _, before, out, _ = _run_segment({"moments": name},
                                     live=jnp.asarray(live), fused=True)
    for mk in ("m", "v"):
        b = before["opt"][mk]["float32"]
        a = out["opt"][mk]["float32"]
        z = st.zero_like(a)
        for lb, la, lz in zip(jax.tree.leaves(b), jax.tree.leaves(a),
                              jax.tree.leaves(z)):
            np.testing.assert_array_equal(np.asarray(lb)[dead],
                                          np.asarray(la)[dead])
            np.testing.assert_array_equal(np.asarray(lz)[rej],
                                          np.asarray(la)[rej])
            assert bool(jnp.any(la[0] != lb[0]))  # live rows move


@pytest.mark.parametrize("name", FUSED_NAMES)
def test_fused_moment_traffic_model(name):
    """The analytic bytes-moved model must show the fused path paying
    stored-rep traffic only, and the unfused path paying >= 3x more
    (the 16-bytes/scalar f32 round-trip the kernel eliminates)."""
    x = _panel(1, 4096, seed=43)
    spec = panel_mod.with_residency(panel_mod.make_spec({"w": x}),
                                    {"moments": name})
    opt = make_optimizer("adamw", 1e-2)
    tf = moment_traffic_model(spec, opt, local_steps=2, fused=True)
    tu = moment_traffic_model(spec, opt, local_steps=2, fused=False)
    assert tf["transient_bytes_per_step"] == 0
    assert tf["bytes_per_step"] == tf["stored_bytes_per_step"]
    assert tf["bytes_per_round"] == 2 * tf["bytes_per_step"]
    assert tu["stored_bytes_per_step"] == tf["stored_bytes_per_step"]
    assert tu["bytes_per_round"] / tf["bytes_per_round"] >= 3.0
    # auto inference agrees with the explicit flag on a fused-eligible
    # spec (this is what the bench and the round events report)
    assert moment_traffic_model(spec, opt, local_steps=2) == tf


FUSED_SHARDED_SCRIPT = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import dsgd, topology
    from repro.launch import mesh as mesh_mod
    from repro.optim import make_optimizer
    from repro.telemetry.metrics import fused_moments_auto

    mesh = mesh_mod.make_debug_mesh(agents=2, fsdp=2, model=2)
    # m = 2: the f32 mix has no reassociation freedom, so sharded vs
    # replicated equality is exact, not approximate
    m, H, S, dim, classes = 2, 2, 3, 16, 4

    def init_params(rng):
        k1, _ = jax.random.split(rng)
        return {"w": jax.random.normal(k1, (dim, classes)) * 0.1,
                "b": jnp.zeros(classes)}

    def loss_fn(p, batch, rng=None):
        x, y = batch
        lg = x @ p["w"] + p["b"]
        nll = jnp.mean(jax.nn.logsumexp(lg, -1)
                       - jnp.take_along_axis(lg, y[:, None], -1)[:, 0])
        return nll, {}

    opt = make_optimizer("adamw", 1e-2)
    rng = np.random.default_rng(0)
    Ws = jnp.asarray(np.stack([topology.random_matching(m, 1.0, rng)
                               for _ in range(S)]), jnp.float32)
    bx = jnp.asarray(rng.normal(size=(S, H, m, 8, dim)).astype(np.float32))
    by = jnp.asarray(rng.integers(
        0, classes, size=(S, H, m, 8)).astype(np.int32))

    def run(mesh_arg, fused):
        pstate, spec = dsgd.init_panel_state(
            init_params, opt, m, jax.random.PRNGKey(0),
            residency={"moments": "int8"}, mesh=mesh_arg)
        kw = {"fused": fused}
        if mesh_arg is not None:
            kw["in_shardings"] = (
                dsgd.panel_state_shardings(pstate, spec),
                (NamedSharding(mesh_arg,
                               P(None, None, ("pod", "agent"))),) * 2,
                NamedSharding(mesh_arg, P()), NamedSharding(mesh_arg, P()))
        seg_fn = dsgd.make_panel_segment(loss_fn, opt, H, spec, **kw)
        out, mets = seg_fn(pstate, (bx, by), Ws, jax.random.PRNGKey(1))
        return spec, out, mets

    spec_s, out_sf, mets_sf = run(mesh, True)   # sharded, fused
    _, out_su, mets_su = run(mesh, False)       # sharded, unfused
    _, out_rf, mets_rf = run(None, True)        # replicated, fused

    def max_err(at, bt):
        return max(float(jnp.max(jnp.abs(
            jnp.asarray(a, jnp.float32) - jnp.asarray(b, jnp.float32))))
            for a, b in zip(jax.tree.leaves(at), jax.tree.leaves(bt)))

    # kernel-level parity: the fused update op with row-sharded inputs
    # must store the same bits as the replicated op (same uniforms,
    # partitionable PRNG) — the fused analog of the storage codecs'
    # sharded-write contract
    import functools
    from repro import residency as res_mod
    from repro.kernels import opt_fused
    from repro.wire.codec import _uniform
    st = res_mod.get_storage("int8")
    mm, d = 4, 300
    rng2 = np.random.default_rng(7)
    g = jnp.asarray(rng2.normal(size=(mm, d)) * 0.1, jnp.float32)
    p = jnp.asarray(rng2.normal(size=(mm, d)), jnp.float32)
    mv = jnp.asarray(np.square(rng2.normal(size=(2, mm, d))) * 1e-4,
                     jnp.float32)
    mst, vst = st.init(mv[0]), st.init(mv[1])
    um = _uniform(jax.random.PRNGKey(7), (mm, d))
    uv = _uniform(jax.random.PRNGKey(8), (mm, d))
    lr, bc1, bc2 = opt.hyper(jnp.asarray([1, 5, 2, 9]))
    op = jax.jit(functools.partial(
        opt_fused.adamw_fused_int8, group=st.group, core=opt.core,
        transform_fwd=st.transform_fwd, transform_inv=st.transform_inv,
        use_pallas=False))
    row = NamedSharding(mesh, P(("pod", "agent"), None))
    shard = lambda x: jax.device_put(x, row)
    repl = op(g, p, mst["q"], mst["scale"], vst["q"], vst["scale"],
              um, uv, lr, bc1, bc2)
    shrd = op(shard(g), shard(p), shard(mst["q"]), shard(mst["scale"]),
              shard(vst["q"]), shard(vst["scale"]), shard(um),
              shard(uv), lr, bc1, bc2)
    kernel_err = max_err(repl, shrd)

    print(json.dumps({
        "fused_auto": bool(fused_moments_auto(spec_s, opt)),
        "stored_int8":
            bool(out_sf["opt"]["m"]["float32"]["q"].dtype == jnp.int8),
        "kernel_shard_vs_repl_err": kernel_err,
        "fused_vs_unfused_sharded_state_err": max_err(out_sf, out_su),
        "fused_vs_unfused_sharded_mets_err": max_err(mets_sf, mets_su),
        "panel_gap_vs_replicated":
            max_err(out_sf["panel"], out_rf["panel"]),
        "loss_gap_vs_replicated":
            max_err(mets_sf["loss"], mets_rf["loss"]),
    }))
""")


@pytest.fixture(scope="module")
def fused_sharded():
    from _multidevice import run_multidevice
    return run_multidevice(FUSED_SHARDED_SCRIPT, devices=8, timeout=420)


@pytest.mark.multidevice
def test_fused_sharded_parity(fused_sharded):
    """On the (1,2,2,2) debug mesh the fused path falls back to the
    shardable ref composition. Three parity contracts: (1) the fused
    update OP with row-sharded inputs stores the same bits as the
    replicated op (partitionable PRNG, same uniforms); (2) the fused
    SEGMENT is bit-identical to the unfused segment on the same mesh —
    sharding does not reopen the fused/unfused equivalence; (3) the
    sharded run tracks the replicated run within the wire-segment
    tolerance (exact equality across placements is not a property of
    the base engine: fsdp reductions reassociate and SR amplifies the
    ulps into whole quantization steps, identically in both paths)."""
    assert fused_sharded["fused_auto"] is True
    assert fused_sharded["stored_int8"] is True
    assert fused_sharded["kernel_shard_vs_repl_err"] == 0.0
    assert fused_sharded["fused_vs_unfused_sharded_state_err"] == 0.0
    assert fused_sharded["fused_vs_unfused_sharded_mets_err"] == 0.0
    assert fused_sharded["panel_gap_vs_replicated"] <= 2e-6
    assert fused_sharded["loss_gap_vs_replicated"] <= 2e-6


# ------------------------------------------------------- checkpointing


@pytest.mark.parametrize("name", STORAGE_NAMES)
def test_checkpoint_roundtrip_stored_rep(name):
    """A policy-bearing panel state must save/restore every stored
    representation (int8 q + f32 scale sidecars included) bit-exactly."""
    init_params, _ = _toy_problem(4, 10, 3)
    opt = make_optimizer("adamw", 1e-2)
    pstate, _ = dsgd.init_panel_state(
        init_params, opt, 4, jax.random.PRNGKey(0),
        residency={"moments": name})
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        path = f"{td}/state.ckpt"
        ckpt_io.save(path, pstate, meta={"residency": name})
        like = jax.tree.map(
            lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), pstate)
        back, meta = ckpt_io.restore(path, like, with_meta=True)
    assert meta == {"residency": name}
    _leaves_equal(pstate, back)


def test_checkpoint_residency_policy_guard(tmp_path):
    """A v2 blob saved with ``residency=`` records the policy; restoring
    under a DIFFERENT engine policy must refuse with an error naming
    every mismatched kind and both storages, instead of decoding stored
    q/scale bits with the wrong codec. Policy-blind restores and
    unstamped blobs keep loading."""
    init_params, _ = _toy_problem(2, 10, 3)
    opt = make_optimizer("adamw", 1e-2)
    pol = {"moments": "int8", "stats": "bf16"}
    pstate, _ = dsgd.init_panel_state(
        init_params, opt, 2, jax.random.PRNGKey(0), residency=pol)
    like = jax.tree.map(lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype),
                        pstate)
    path = str(tmp_path / "pol.ckpt")
    ckpt_io.save(path, pstate, meta={"round": 3}, residency=pol)
    # matching policy and policy-blind restores both pass; user meta
    # rides alongside the reserved stamp untouched
    _leaves_equal(pstate, ckpt_io.restore(path, like,
                                          expect_residency=pol))
    back, meta = ckpt_io.restore(path, like, with_meta=True)
    _leaves_equal(pstate, back)
    assert meta["round"] == 3
    assert meta[ckpt_io.RESIDENCY_META_KEY] == pol
    # wrong storage on a recorded kind: named with both sides
    with pytest.raises(ValueError, match=r"moments.*'int8'.*'int8g'"):
        ckpt_io.restore(path, like, expect_residency={
            "moments": "int8g", "stats": "bf16"})
    # kinds compare over the UNION: an absent kind is the f32 identity,
    # so dropping 'stats' from the engine policy is also a mismatch
    with pytest.raises(ValueError, match=r"stats.*'bf16'.*'f32'"):
        ckpt_io.restore(path, like,
                        expect_residency={"moments": "int8"})
    # a pre-stamp blob (no recorded policy) passes any expectation
    path2 = str(tmp_path / "nostamp.ckpt")
    ckpt_io.save(path2, pstate)
    _leaves_equal(pstate, ckpt_io.restore(path2, like,
                                          expect_residency=pol))


def test_checkpointer_residency_guard_raises_not_falls_back(tmp_path):
    """Checkpointer(residency=...) stamps every save and restore_latest
    RAISES on a policy mismatch rather than warning and falling back to
    an older sibling (every sibling carries the same stamp — a silent
    fallback would hide the misconfiguration)."""
    init_params, _ = _toy_problem(2, 10, 3)
    opt = make_optimizer("adamw", 1e-2)
    pol = {"moments": "int8"}
    pstate, _ = dsgd.init_panel_state(
        init_params, opt, 2, jax.random.PRNGKey(0), residency=pol)
    like = jax.tree.map(lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype),
                        pstate)
    d = str(tmp_path / "ckpts")
    ck = ckpt_io.Checkpointer(d, residency=pol)
    ck.save(1, pstate)
    ck.save(2, pstate)
    ck.wait()
    step, back, _ = ckpt_io.Checkpointer(
        d, residency=pol).restore_latest(like)
    assert step == 2
    _leaves_equal(pstate, back)
    with pytest.raises(ValueError, match="residency"):
        ckpt_io.Checkpointer(
            d, residency={"moments": "int8g"}).restore_latest(like)
    with pytest.raises(ValueError, match="residency"):
        ckpt_io.Checkpointer(
            d, residency={"moments": "f32"}).restore_latest(like)
    # a policy-less Checkpointer is policy-BLIND (expected None skips
    # the guard) — structure drift still trips _rebuild's keyed errors
    step, _, _ = ckpt_io.Checkpointer(d).restore_latest(like)
    assert step == 2


def test_checkpoint_restore_continue_bitexact(tmp_path):
    """save → restore → run a segment must reproduce the uninterrupted
    run bit-exactly under a quantized policy (the stored q/scale bits,
    not a dequantized approximation, are what round-trips)."""
    m, H, S, dim, classes = 4, 2, 2, 10, 3
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("adamw", 1e-2)
    Ws, (bx, by) = _segment_inputs(S, H, m, dim, classes)
    pstate, spec = dsgd.init_panel_state(
        init_params, opt, m, jax.random.PRNGKey(0),
        residency={"moments": "int8", "stats": "bf16"})
    seg_fn = dsgd.make_panel_segment(loss_fn, opt, H, spec)
    mid, _ = seg_fn(pstate, (bx, by), Ws, jax.random.PRNGKey(1))

    path = str(tmp_path / "mid.ckpt")
    ckpt_io.save(path, mid)
    like = jax.tree.map(lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype),
                        mid)
    restored = ckpt_io.restore(path, like)

    Ws2, (bx2, by2) = _segment_inputs(S, H, m, dim, classes, seed=1)
    key2 = jax.random.PRNGKey(2)
    cont, cmets = seg_fn(jax.tree.map(jnp.asarray, restored),
                         (bx2, by2), Ws2, key2)
    base, bmets = seg_fn(mid, (bx2, by2), Ws2, key2)
    _leaves_equal(base, cont)
    _leaves_equal(bmets, cmets)


def test_checkpoint_v1_blob_still_loads(tmp_path):
    """The v1 header (same flat array table, version=1) must keep
    loading under the v2 reader — old run checkpoints stay live."""
    assert 1 in ckpt_io.READABLE_VERSIONS
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "q": np.arange(4, dtype=np.int8)}
    payload = msgpack.packb(
        {k: {"dtype": a.dtype.name, "shape": list(a.shape),
             "data": a.tobytes()} for k, a in tree.items()})
    meta_bytes = json.dumps({"v": 1}).encode()
    blob = msgpack.packb({
        "version": 1, "meta": meta_bytes, "payload": payload,
        "crc": zlib.crc32(meta_bytes + payload) & 0xFFFFFFFF})
    path = tmp_path / "v1.ckpt"
    path.write_bytes(blob)
    back, meta = ckpt_io.restore(str(path), tree, with_meta=True)
    assert meta == {"v": 1}
    _leaves_equal(tree, back)
    with pytest.raises(ckpt_io.CheckpointCorruptError, match="version"):
        bad = msgpack.packb({"version": 99, "meta": meta_bytes,
                             "payload": payload, "crc": 0})
        (tmp_path / "v99.ckpt").write_bytes(bad)
        ckpt_io.restore(str(tmp_path / "v99.ckpt"), tree)


# ----------------------------------------------------- snapshot export


def test_snapshot_exporter_and_cli(tmp_path, capsys):
    """The EventLog sink folds rounds (resident_bytes included, schema
    v2) into an atomic snapshot; the offline CLI replays the stream to
    the same reduction."""
    events = str(tmp_path / "events.jsonl")
    snap_path = str(tmp_path / "snap.json")
    snap = telemetry.SnapshotExporter(snap_path, every=1)
    log = telemetry.EventLog(events, run_id="t", sidecar=False, sink=snap)
    log.emit("run_start", run_id="t", schema=telemetry.SCHEMA_VERSION,
             config={"residency": "moments=int8"})
    for r in range(3):
        log.emit("round", round=r, loss=1.0 - r * 0.1, grad_norm=1.0,
                 grad_norm_max=1.0, consensus=0.1, comm_cost_P=1.0,
                 resident_bytes=7_135_723)
    log.emit("eval", round=2, merged_eval=0.7, local_eval=0.8)
    log.emit("run_end", rounds=3, final_loss=0.8, comm_cost_P=3.0)
    log.close()
    final = snap.close()
    assert final["resident_bytes_per_agent"] == 7_135_723
    assert final["events"]["round"] == 3
    assert final["last_round"]["round"] == 2
    assert final["evals"] == [
        {"round": 2, "merged_eval": 0.7, "local_eval": 0.8}]
    with open(snap_path) as f:
        assert json.load(f) == final
    # the stream itself stays schema-valid (round.resident_bytes is v2)
    assert telemetry.validate_stream(events) == []
    # offline CLI replays to the same reduction
    from repro.telemetry import export as export_mod
    out2 = str(tmp_path / "replay.json")
    assert export_mod.main([events, "--out", out2]) == 0
    with open(out2) as f:
        replay = json.load(f)
    assert replay == final
    assert "6 events" in capsys.readouterr().out
