"""Property tests for the temporal communication schedulers
(core/schedule.py).

The three contracts the PR 4/5 engine work leans on:

* **Budget identity** — the accumulated ``round_cost`` of a run equals
  the per-kind budget recomputed independently from the ``last_kind``
  mask (global = 2P ring AllReduce, idle = 0, gossip = participating
  fraction), EXCEPT where a gossip matrix numerically coincides with the
  fully-connected 1/m average — the documented W-fingerprint
  false-positive class (m = 2 matched pair, 3-agent ring) that
  ``last_kind`` / the explicit ``global_rounds`` mask exists to resolve.
* **Mask agreement** — ``last_kind`` agrees with the W sequence:
  'global' rounds emit exactly the 1/m matrix, 'idle' rounds exactly I,
  and the W-fingerprint reproduces the mask everywhere EXCEPT the
  coincidence class; every emitted W is doubly stochastic.
* **Registry round-trip** — ``make_schedule`` builds every ``SCHEDULES``
  name (and only those), carrying the merger tag through.

Deterministic sweeps always run; the hypothesis properties widen the
same contracts over random (m, rounds, seed) and fall back to the
offline ``_hypothesis_stub`` (reported as SKIPPED) when hypothesis is
not installed.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: dev extra not installed
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import topology as topo
from repro.core.schedule import SCHEDULES, Schedule, make_schedule

SWEEP = [
    ("constant", {}),
    ("local", {}),
    ("windowed", {"start": 2, "end": 5}),
    ("final_merge", {}),
    ("periodic", {"period": 3}),
    ("adaptive", {"kappa": 0.5}),
]
assert {n for n, _ in SWEEP} == set(SCHEDULES), "sweep covers the registry"


def _monitor(t: int, seed: int = 0):
    """Synthetic decaying monitor driving the adaptive scheduler: the
    consensus/grad-norm ratio crosses the kappa band at some rounds."""
    rng = np.random.default_rng(seed * 1000 + t)
    g = 1.0 / (1.0 + 0.3 * t)
    xi = float(rng.uniform(0.0, 1.2)) * g
    return {"grad_norm": g, "consensus": xi}


def _drive(name, kwargs, m, rounds, seed=0):
    """Run a scheduler for its full horizon; returns per-round records
    (W, kind, cost) plus the schedule object."""
    sched = make_schedule(name, m, rounds, seed=seed, **kwargs)
    recs = []
    for t in range(rounds):
        W = sched.mixing_matrix(t, _monitor(t, seed))
        recs.append((W, sched.last_kind, sched.round_cost(W)))
    return recs, sched


def _expected_cost(kind, W, m):
    """Budget model recomputed from the kind mask (the ground truth the
    engine consumes via ``global_rounds``)."""
    if kind == "global":
        return 2.0
    if kind == "idle":
        return 0.0
    return float(np.sum(np.diag(W) < 1.0 - 1e-12)) / m


def _is_full(W, m):
    return np.array_equal(W, topo.fully_connected(m))


def _check_run(name, kwargs, m, rounds, seed):
    recs, sched = _drive(name, kwargs, m, rounds, seed)
    budget = 0.0
    expected_budget = 0.0
    for t, (W, kind, cost) in enumerate(recs):
        # every W doubly stochastic
        np.testing.assert_allclose(W.sum(axis=0), 1.0, atol=1e-12)
        np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-12)
        assert np.all(W >= -1e-15)
        # mask agreement with the W sequence
        if kind == "global":
            assert _is_full(W, m), (name, t)
        elif kind == "idle":
            assert np.array_equal(W, topo.identity(m)), (name, t)
        else:
            assert kind == "gossip", (name, t, kind)
        # the W fingerprint reproduces the mask EXCEPT the documented
        # coincidence class: a gossip matrix that numerically equals the
        # 1/m average (m=2 matched pair, 3-ring) — exactly why the
        # engine takes the explicit global_rounds mask
        if _is_full(W, m) != (kind == "global"):
            assert kind == "gossip" and _is_full(W, m), (name, t)
        # cost model agreement, modulo the same coincidence on cost
        exp = _expected_cost(kind, W, m)
        if kind == "gossip" and _is_full(W, m):
            assert cost == 2.0  # fingerprint-priced as an AllReduce
            exp = cost
        else:
            assert cost == pytest.approx(exp, abs=1e-12), (name, t)
        budget += cost
        expected_budget += exp
    assert budget == pytest.approx(expected_budget, abs=1e-9)
    return recs, sched


# ------------------------------------------------- deterministic sweep


@pytest.mark.parametrize("m", [2, 3, 4, 8])
@pytest.mark.parametrize("name,kwargs", SWEEP)
def test_budget_and_mask_agree(name, kwargs, m):
    _check_run(name, kwargs, m, rounds=12, seed=0)


@pytest.mark.parametrize("name,kwargs", SWEEP)
def test_kind_masks_match_scheduler_semantics(name, kwargs):
    m, rounds = 4, 12
    recs, sched = _drive(name, kwargs, m, rounds, seed=1)
    kinds = [kind for _, kind, _ in recs]
    if name == "constant":
        assert "global" not in kinds and "idle" not in kinds
    if name == "local":
        assert kinds == ["idle"] * rounds
    if name == "final_merge":
        assert [k == "global" for k in kinds] == (
            [False] * (rounds - 1) + [True])
    if name == "periodic":
        period = kwargs["period"]
        assert [k == "global" for k in kinds] == [
            (t + 1) % period == 0 for t in range(rounds)]
    if name == "windowed":
        s, e = kwargs["start"], kwargs["end"]
        assert [k == "global" for k in kinds] == [
            s <= t < e for t in range(rounds)]
    if name == "adaptive":
        assert [k == "global" for k in kinds] == [
            t in sched.global_rounds for t in range(rounds)]


def test_make_schedule_roundtrips_registry():
    m, rounds = 4, 8
    for name, kwargs in SWEEP:
        sched = make_schedule(name, m, rounds, merger="ties", **kwargs)
        assert isinstance(sched, SCHEDULES[name])
        assert type(sched) is SCHEDULES[name]
        assert sched.merger == "ties"  # the engine's single source
        assert (sched.m, sched.rounds) == (m, rounds)
    with pytest.raises(ValueError, match="unknown schedule"):
        make_schedule("warmup", m, rounds)


def test_last_kind_starts_unset():
    sched = make_schedule("constant", 4, 4)
    assert sched.last_kind is None
    sched.mixing_matrix(0)
    assert sched.last_kind in ("global", "idle", "gossip")


# ------------------------------------------------ hypothesis widening


@settings(max_examples=30, deadline=None)
@given(st.sampled_from([n for n, _ in SWEEP]), st.integers(2, 9),
       st.integers(1, 20), st.integers(0, 2**31 - 1))
def test_budget_identity_property(name, m, rounds, seed):
    """Budget + mask agreement for all six schedulers over random
    (m, rounds, seed) — including the m=2/m=3 coincidence regimes."""
    kwargs = dict(SWEEP)[name]
    if name == "windowed":
        kwargs = {"start": min(2, rounds - 1), "end": min(5, rounds)}
    if name == "periodic":
        kwargs = {"period": max(1, rounds // 3)}
    _check_run(name, kwargs, m, rounds, seed)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(2, 16), st.integers(0, 2**31 - 1))
def test_gossip_fingerprint_false_positives_only_at_coincidence(
        m, rounds, seed):
    """The W-fingerprint (W == 1/m average) may disagree with last_kind
    ONLY by flagging a gossip round whose matrix coincides with the
    average — it must never miss a true global round."""
    recs, _ = _drive("periodic", {"period": 2}, m, rounds, seed)
    for W, kind, _ in recs:
        if kind == "global":
            assert _is_full(W, m)
        if not _is_full(W, m):
            assert kind != "global"
