"""Flat-panel engine validation: PanelSpec dtype preservation, fused-op
parity against the per-leaf tree-map reference path, Pallas panel_reduce
kernel vs oracle, the donated scanned segment driver, and state
panelize/unpanelize roundtrips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dsgd, gossip, topology
from repro.core import panel as panel_mod
from repro.core.consensus import consensus_distance, consensus_distance_tree
from repro.optim import make_optimizer


def _mixed_tree(m=8, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {"w": jax.random.normal(ks[0], (m, 17, 5)),
            "emb": jax.random.normal(ks[1], (m, 33), jnp.bfloat16),
            "nest": {"b": jax.random.normal(ks[2], (m, 9))}}


# ------------------------------------------------------------ spec/panel


def test_spec_preserves_mixed_dtypes_no_promotion():
    """Regression for kernels/ops.py:_flatten_panel: a bf16+f32 pytree must
    flatten into per-dtype panels with NO silent upcast (the old
    jnp.concatenate promoted bf16 leaves to f32, doubling wire bytes)."""
    tree = _mixed_tree()
    spec = panel_mod.make_spec(tree)
    pan = panel_mod.to_panel(tree, spec)
    assert set(pan) == {"float32", "bfloat16"}
    assert pan["bfloat16"].dtype == jnp.bfloat16
    assert pan["float32"].dtype == jnp.float32
    assert pan["bfloat16"].shape == (8, 33)
    assert pan["float32"].shape == (8, 17 * 5 + 9)
    # wire bytes: bf16 leaves pay 2 bytes, not 4
    promoted = spec.width * 4
    assert spec.wire_bytes == 33 * 2 + (17 * 5 + 9) * 4 < promoted


def test_panel_roundtrip_exact():
    tree = _mixed_tree()
    spec = panel_mod.make_spec(tree)
    back = panel_mod.from_panel(panel_mod.to_panel(tree, spec), spec)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert bool(jnp.all(a == b))


def test_gossip_mix_kernel_preserves_dtypes():
    """ops.gossip_mix on a mixed-dtype pytree: one kernel call per dtype
    group, output dtypes unchanged."""
    from repro.kernels.ops import gossip_mix
    tree = _mixed_tree()
    W = jnp.asarray(topology.ring(8), jnp.float32)
    out = gossip_mix(W, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype and a.shape == b.shape
    ref = gossip.mix_dense_tree(tree, W)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-2, rtol=2e-2)


# ------------------------------------------------ fused ops vs tree path


@pytest.mark.parametrize("wire", [None, jnp.bfloat16])
def test_mix_dense_panel_matches_tree(wire):
    tree = {"x": jax.random.normal(jax.random.PRNGKey(1), (8, 40)),
            "y": jax.random.normal(jax.random.PRNGKey(2), (8, 7, 3))}
    W = jnp.asarray(topology.random_matching(
        8, 0.7, np.random.default_rng(0)), jnp.float32)
    a = gossip.mix_dense(tree, W, wire_dtype=wire)
    b = gossip.mix_dense_tree(tree, W, wire_dtype=wire)
    tol = 2e-2 if wire is not None else 1e-5
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(x, y, atol=tol, rtol=tol)


def test_mix_pairwise_panel_matches_tree():
    m = 8
    W = topology.random_matching(m, 0.8, np.random.default_rng(3))
    partner = jnp.asarray(topology.partner_array(W), jnp.int32)
    tree = {"x": jax.random.normal(jax.random.PRNGKey(3), (m, 13))}
    a = gossip.mix_pairwise(tree, partner)
    b = gossip.mix_pairwise_tree(tree, partner)
    np.testing.assert_allclose(a["x"], b["x"], atol=1e-6)


def test_global_merge_and_merged_model_mixed_dtype():
    """Acceptance: the panel engine's merged model matches
    gossip.global_merge within f32 tolerance on a MIXED-dtype pytree."""
    tree = _mixed_tree(seed=4)
    gm_p = gossip.global_merge(tree)
    gm_t = gossip.global_merge_tree(tree)
    for a, b in zip(jax.tree.leaves(gm_p), jax.tree.leaves(gm_t)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-5)
    mm_p = gossip.merged_model(tree)
    mm_t = gossip.merged_model_tree(tree)
    for a, b in zip(jax.tree.leaves(mm_p), jax.tree.leaves(mm_t)):
        assert a.dtype == jnp.float32  # merged model is f32 in both engines
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_consensus_distance_panel_matches_tree():
    tree = _mixed_tree(seed=5)
    a = float(consensus_distance(tree))
    b = float(consensus_distance_tree(tree))
    assert a == pytest.approx(b, rel=1e-5)


# ------------------------------------------------------ panel_reduce kernel


@pytest.mark.parametrize("m,D,block_d", [
    (4, 64, 32), (8, 1000, 512), (16, 333, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_panel_reduce_kernel_vs_ref(m, D, block_d, dtype):
    from repro.kernels.panel_reduce import panel_mean_consensus
    from repro.kernels.ref import panel_mean_consensus_ref
    theta = jax.random.normal(jax.random.PRNGKey(6), (m, D), dtype)
    mean, sq = panel_mean_consensus(theta, block_d=block_d)
    rmean, rsq = panel_mean_consensus_ref(theta)
    np.testing.assert_allclose(mean, rmean, atol=1e-5, rtol=1e-5)
    assert float(sq) == pytest.approx(float(rsq), rel=1e-5)


def test_panel_stats_wrapper():
    from repro.kernels.ops import panel_stats
    tree = _mixed_tree(seed=7)
    merged, xi = panel_stats(tree)
    ref = gossip.merged_model_tree(tree)
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(ref)):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
    assert float(xi) == pytest.approx(
        float(consensus_distance_tree(tree)), rel=1e-5)


def test_consensus_distance_pallas_path():
    tree = {"x": jax.random.normal(jax.random.PRNGKey(8), (8, 700))}
    spec = panel_mod.make_spec(tree)
    pan = panel_mod.to_panel(tree, spec)
    a = float(panel_mod.consensus_distance(pan, use_pallas=True))
    b = float(consensus_distance_tree(tree))
    assert a == pytest.approx(b, rel=1e-5)


# ------------------------------------------------------ segment driver


def _toy_problem(m=8, dim=12, classes=4):
    def init_params(rng):
        k1, _ = jax.random.split(rng)
        return {"w": jax.random.normal(k1, (dim, classes)) * 0.1,
                "b": jnp.zeros(classes)}

    def loss_fn(p, batch, rng=None):
        x, y = batch
        lg = x @ p["w"] + p["b"]
        nll = jnp.mean(jax.nn.logsumexp(lg, -1)
                       - jnp.take_along_axis(lg, y[:, None], -1)[:, 0])
        return nll, {}

    return init_params, loss_fn


def _segment_inputs(S, H, m, dim, classes, seed=0):
    rng = np.random.default_rng(seed)
    Ws = np.stack([topology.random_matching(m, 0.5, rng) for _ in range(S)])
    bx = jnp.asarray(rng.normal(size=(S, H, m, 8, dim)).astype(np.float32))
    by = jnp.asarray(rng.integers(0, classes, size=(S, H, m, 8)).astype(np.int32))
    return jnp.asarray(Ws, jnp.float32), (bx, by)


@pytest.mark.parametrize("opt_name", ["sgd", "adamw"])
def test_panel_segment_matches_tree_rounds(opt_name):
    """The donated scanned segment must reproduce the tree-state round
    driver exactly (same rng schedule, same batches, same W sequence)."""
    m, H, S, dim, classes = 8, 3, 4, 12, 4
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer(opt_name, 1e-2)
    key = jax.random.PRNGKey(0)
    tstate = dsgd.init_state(init_params, opt, m, key)
    pstate, spec = dsgd.init_panel_state(init_params, opt, m, key)
    round_fn = jax.jit(dsgd.make_dsgd_round(loss_fn, opt, H))
    seg_fn = dsgd.make_panel_segment(loss_fn, opt, H, spec)

    Ws, (bx, by) = _segment_inputs(S, H, m, dim, classes)
    key2 = jax.random.PRNGKey(42)
    rngs = jax.random.split(key2, S)
    ts = tstate
    for t in range(S):
        ts, mets_t = round_fn(ts, (bx[t], by[t]), Ws[t], rngs[t])
    ps, mets_p = seg_fn(pstate, (bx, by), Ws, key2)

    final = panel_mod.from_panel(ps["panel"], spec)
    for a, b in zip(jax.tree.leaves(final), jax.tree.leaves(ts["params"])):
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-6)
    assert mets_p["loss"].shape == (S,)
    assert float(mets_p["loss"][-1]) == pytest.approx(
        float(mets_t["loss"]), rel=1e-5)
    assert float(mets_p["consensus"][-1]) == pytest.approx(
        float(mets_t["consensus"]), rel=1e-4)
    assert int(ps["step"]) == S * H


def test_panel_segment_donates_state():
    """The scanned round must NOT retain the old state buffer: with
    donate_argnums the input panels are consumed in place."""
    m, H, S, dim, classes = 4, 2, 2, 6, 3
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("sgd", 1e-2)
    # probe: does this backend actually delete donated buffers?
    probe = jnp.ones((4,))
    jax.jit(lambda x: x * 2, donate_argnums=(0,))(probe)
    if not probe.is_deleted():
        pytest.skip("backend does not implement buffer donation")
    pstate, spec = dsgd.init_panel_state(init_params, opt, m,
                                         jax.random.PRNGKey(0))
    seg_fn = dsgd.make_panel_segment(loss_fn, opt, H, spec)
    Ws, batches = _segment_inputs(S, H, m, dim, classes)
    old_bufs = jax.tree.leaves(pstate)
    new_state, _ = seg_fn(pstate, batches, Ws, jax.random.PRNGKey(1))
    assert all(x.is_deleted() for x in old_bufs)
    assert not any(x.is_deleted() for x in jax.tree.leaves(new_state))


def test_panel_segment_final_merge_collapses_consensus():
    m, H, dim, classes = 8, 2, 10, 3
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("sgd", 1e-2)
    pstate, spec = dsgd.init_panel_state(init_params, opt, m,
                                         jax.random.PRNGKey(0))
    seg_fn = dsgd.make_panel_segment(loss_fn, opt, H, spec)
    rng = np.random.default_rng(0)
    Ws = np.stack([topology.random_matching(m, 0.5, rng),
                   topology.fully_connected(m)])
    bx = jnp.asarray(rng.normal(size=(2, H, m, 8, dim)).astype(np.float32))
    by = jnp.asarray(rng.integers(0, classes, size=(2, H, m, 8)).astype(np.int32))
    ps, mets = seg_fn(pstate, (bx, by), jnp.asarray(Ws, jnp.float32),
                      jax.random.PRNGKey(1))
    assert float(mets["consensus"][-1]) < 1e-3  # global merge => Xi ~ 0
    tree = panel_mod.from_panel(ps["panel"], spec)
    for x in jax.tree.leaves(tree):
        np.testing.assert_allclose(np.asarray(x[0]), np.asarray(x[-1]),
                                   atol=1e-5)


def test_panel_segment_idle_rounds_ignore_wire_dtype():
    """W == I rounds communicate nothing, so a bf16 wire must not quantize
    them: local-only training is bitwise identical under any wire dtype."""
    m, H, S, dim, classes = 4, 2, 3, 8, 3
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("sgd", 1e-2)
    Ws = jnp.asarray(np.stack([topology.identity(m)] * S), jnp.float32)
    rng = np.random.default_rng(1)
    bx = jnp.asarray(rng.normal(size=(S, H, m, 8, dim)).astype(np.float32))
    by = jnp.asarray(rng.integers(0, classes, size=(S, H, m, 8)).astype(np.int32))
    finals = []
    for wire in (None, jnp.bfloat16):
        pstate, spec = dsgd.init_panel_state(init_params, opt, m,
                                             jax.random.PRNGKey(0))
        seg_fn = dsgd.make_panel_segment(loss_fn, opt, H, spec,
                                         wire_dtype=wire)
        ps, _ = seg_fn(pstate, (bx, by), Ws, jax.random.PRNGKey(1))
        finals.append(ps["panel"])
    for a, b in zip(jax.tree.leaves(finals[0]), jax.tree.leaves(finals[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_panelize_unpanelize_roundtrip():
    m = 4
    init_params, _ = _toy_problem(m)
    opt = make_optimizer("adamw", 1e-3)
    key = jax.random.PRNGKey(2)
    tstate = dsgd.init_state(init_params, opt, m, key)
    spec = panel_mod.make_spec(tstate["params"])
    ps = dsgd.panelize_state(tstate, spec)
    back = dsgd.unpanelize_state(ps, spec)
    for a, b in zip(jax.tree.leaves(tstate), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the panel init path agrees with panelizing the tree init
    pstate, spec2 = dsgd.init_panel_state(init_params, opt, m, key)
    assert spec2 == spec
    for a, b in zip(jax.tree.leaves(pstate["panel"]),
                    jax.tree.leaves(ps["panel"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
