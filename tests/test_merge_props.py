"""Merge-operator subsystem properties (repro/merging + kernels/merge_ops).

Hypothesis-driven properties (falling back to the offline
``_hypothesis_stub`` shim) plus plain contract tests:

* ``uniform`` is a bit-exact alias of the pre-subsystem engine: its
  merge_row equals ``panel.merged`` bitwise, and a ``make_panel_segment``
  run on a with_merger('uniform') spec produces a bit-identical final
  panel (same bytes hash) to the no-merger spec;
* degenerate statistics recover the mean: explicit uniform weights for
  ``weighted``, fresh (zero-variance / zero-Fisher) stats for ``var`` and
  ``fisher``;
* permutation-of-agents equivariance: permuting panel rows (and stats
  rows, and the weight vector) leaves every operator's merged row
  unchanged;
* idempotence on identical rows: a consensus panel (with fresh stats)
  merges to the row itself under every operator;
* TIES with trim=1.0 reduces to the pure sign-elected mean of deviations;
* Pallas merge kernels (kernels/merge_ops.py) are BIT-identical to the
  kernels/ref.py oracles, including non-divisible D (padded tails);
* every non-uniform operator runs through ``make_panel_segment``
  end-to-end (global round collapses consensus to exactly 0, statistics
  panels update, wire codecs compose);
* the tree-path oracle: ``merge_stacked`` / ``counterfactual_eval`` and
  the scanned codec-aware ``gossip_merge_rounds``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: dev extra not installed
    from _hypothesis_stub import given, settings, strategies as st

from repro import merging as merging_mod
from repro.core import dsgd, gossip, topology
from repro.core import merge as merge_mod
from repro.core import panel as panel_mod
from repro.kernels import merge_ops as merge_kernels
from repro.kernels import ref as ref_mod
from repro.optim import make_optimizer
from test_panel import _toy_problem

pytestmark = pytest.mark.merge

ALL_MERGERS = tuple(sorted(merging_mod.MERGERS))
NON_UNIFORM = tuple(n for n in ALL_MERGERS if n != "uniform")


def _panel(m, d, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(m, d)) * scale, jnp.float32)


def _fresh_stats(name, pan):
    mg = merging_mod.get_merger(name)
    return (mg.init_stats(pan) or None) if mg.stat_panels else None


def _rich_stats(name, pan, seed=0):
    """Fresh stats plus a couple of update steps so they are non-trivial
    (heterogeneous weights) for the equivariance/permutation tests."""
    mg = merging_mod.get_merger(name)
    if not mg.stat_panels:
        return None
    stats = mg.init_stats(pan)
    fake_g = {k: _panel(*v.shape, seed + 7) * 0.3 for k, v in pan.items()}
    fake_p = {k: v + _panel(*v.shape, seed + 8) * 0.1
              for k, v in pan.items()}
    for _ in range(2):
        if mg.local_stat:
            stats = mg.update_local(stats, fake_g)
        if mg.round_stat:
            stats = mg.update_round(stats, fake_p)
    return stats


# ----------------------------------------------------- uniform alias


def test_uniform_merge_row_bitexact_vs_panel_merged():
    pan = {"float32": _panel(8, 97, 0),
           "bfloat16": _panel(8, 33, 1).astype(jnp.bfloat16)}
    row = merging_mod.get_merger("uniform").merge_row(pan)
    ref = panel_mod.merged(pan)
    for k in pan:
        np.testing.assert_array_equal(np.asarray(row[k]),
                                      np.asarray(ref[k]))
        assert row[k].dtype == jnp.float32


def test_segment_uniform_merger_bitexact_vs_premerge_engine():
    """Acceptance: --merge uniform produces the SAME final panel bytes as
    the pre-subsystem engine (the merger hook must not perturb the fused
    path, the rng schedule, or the global-round matmul)."""
    m, H, S, dim, classes = 4, 2, 3, 10, 3
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("adamw", 1e-2)
    rng = np.random.default_rng(3)
    Ws = jnp.asarray(np.stack([topology.random_matching(m, 0.8, rng),
                               topology.identity(m),
                               topology.fully_connected(m)]), jnp.float32)
    bx = jnp.asarray(rng.normal(size=(S, H, m, 8, dim)).astype(np.float32))
    by = jnp.asarray(rng.integers(0, classes,
                                  size=(S, H, m, 8)).astype(np.int32))

    def run(merger):
        st, spec = dsgd.init_panel_state(init_params, opt, m,
                                         jax.random.PRNGKey(0),
                                         merger=merger)
        seg = dsgd.make_panel_segment(loss_fn, opt, H, spec)
        ps, _ = seg(st, (bx, by), Ws, jax.random.PRNGKey(1))
        return ps["panel"]

    base, uni = run(None), run("uniform")
    for k in base:
        assert (np.asarray(base[k]).tobytes()
                == np.asarray(uni[k]).tobytes())


# -------------------------------------- degenerate stats -> the mean


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 8), st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_uniform_weights_weighted_recovers_mean(m, d, seed):
    pan = {"float32": _panel(m, d, seed)}
    mean = jnp.mean(pan["float32"], axis=0)
    row = merging_mod.get_merger("weighted").merge_row(
        pan, weights=jnp.full((m,), 1.0 / m))
    np.testing.assert_allclose(np.asarray(row["float32"]),
                               np.asarray(mean), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(["var", "fisher"]), st.integers(2, 8),
       st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_fresh_stats_var_fisher_recover_mean(name, m, d, seed):
    """Zero variance / zero Fisher => equal per-coordinate weights =>
    the uniform mean (the eps floor is shared by every agent)."""
    pan = {"float32": _panel(m, d, seed)}
    mg = merging_mod.get_merger(name)
    row = mg.merge_row(pan, stats=mg.init_stats(pan))
    np.testing.assert_allclose(np.asarray(row["float32"]),
                               np.asarray(jnp.mean(pan["float32"], 0)),
                               atol=1e-5, rtol=1e-5)


# -------------------------------------------- operator-level properties


@pytest.mark.parametrize("name", ALL_MERGERS)
def test_permutation_of_agents_equivariance(name):
    """Merging is symmetric in the agents: permuting panel rows (plus
    stats rows and the weight vector) must not change the merged row."""
    m, d = 6, 41
    pan = {"float32": _panel(m, d, 11)}
    stats = _rich_stats(name, pan, seed=11)
    w = jnp.asarray(np.random.default_rng(5).uniform(0.1, 1.0, m),
                    jnp.float32)
    perm = jnp.asarray([3, 0, 5, 1, 4, 2])
    pan_p = {k: v[perm] for k, v in pan.items()}
    stats_p = (None if stats is None else
               {n: {k: v[perm] for k, v in s.items()}
                for n, s in stats.items()})
    mg = merging_mod.get_merger(name)
    kw = {"weights": w[perm] if name == "weighted" else None}
    a = mg.merge_row(pan, stats=stats,
                     weights=w if name == "weighted" else None)
    b = mg.merge_row(pan_p, stats=stats_p, **kw)
    np.testing.assert_allclose(np.asarray(a["float32"]),
                               np.asarray(b["float32"]),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("name", ALL_MERGERS)
def test_idempotent_on_identical_rows(name):
    """A consensus panel (all agents identical, fresh stats) must merge
    to the row itself under every operator."""
    m, d = 5, 37
    row0 = _panel(1, d, 21)[0]
    pan = {"float32": jnp.broadcast_to(row0[None], (m, d))}
    mg = merging_mod.get_merger(name)
    out = mg.merge_row(pan, stats=_fresh_stats(name, pan))
    np.testing.assert_allclose(np.asarray(out["float32"]),
                               np.asarray(row0), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(2, 48), st.integers(0, 2**31 - 1))
def test_ties_full_trim_is_sign_elected_mean(m, d, seed):
    """TiesMerger(trim=1.0) keeps every deviation: the merged row is the
    reference mean + the mean of deviations agreeing with the elected
    column sign (computed independently here)."""
    x = _panel(m, d, seed)
    row = merging_mod.TiesMerger(trim=1.0).merge_row({"float32": x})
    x64 = np.asarray(x, np.float64)
    ref = x64.mean(0)
    tau = np.asarray(x - jnp.mean(x, 0)[None], np.float32)
    s = np.where(tau.sum(0) >= 0.0, 1.0, -1.0)
    agree = (tau * s[None]) > 0.0
    cnt = agree.sum(0)
    dev = np.where(cnt > 0, (tau * agree).sum(0) / np.maximum(cnt, 1), 0.0)
    np.testing.assert_allclose(np.asarray(row["float32"]), ref + dev,
                               atol=1e-5, rtol=1e-5)


def test_ties_elects_majority_sign_and_trims():
    """Hand-built column: 3 agents push +1, one pushes -3 — the elected
    sign is +, the dissenting deviation is excluded, and a harsh trim
    (top 50% per row) drops small-magnitude deviations entirely."""
    # deviations sum to 0 per column (true deviations from the mean)
    x = jnp.asarray([[1.0, 0.1], [1.0, 0.1], [1.0, -0.1], [-3.0, -0.1]],
                    jnp.float32)
    pan = {"float32": x + 5.0}  # shift: mean 5, deviations = x
    row = merging_mod.TiesMerger(trim=1.0).merge_row(pan)
    # col 0: elected + (sum = 0 -> ties to +), mean of the three +1s
    np.testing.assert_allclose(float(row["float32"][0]), 5.0 + 1.0,
                               rtol=1e-6)
    # col 1: elected + (ties to +), mean of the two +0.1s
    np.testing.assert_allclose(float(row["float32"][1]), 5.0 + 0.1,
                               rtol=1e-5)
    # trim=0.5 keeps each row's single largest-magnitude deviation: the
    # 0.1s vanish, col 1 has no survivor -> pure reference mean
    row = merging_mod.TiesMerger(trim=0.5).merge_row(pan)
    np.testing.assert_allclose(float(row["float32"][1]), 5.0, atol=1e-6)


def test_ties_trim_validation():
    with pytest.raises(ValueError, match="trim"):
        merging_mod.TiesMerger(trim=0.0)
    with pytest.raises(ValueError, match="trim"):
        merging_mod.TiesMerger(trim=1.5)


# ------------------------------------------------- kernel bit-parity


@pytest.mark.parametrize("m,D,block_d", [(4, 64, 32), (8, 333, 128),
                                         (3, 1000, 512)])
def test_weighted_colmerge_kernel_matches_ref(m, D, block_d):
    x = _panel(m, D, seed=m * 100 + D)
    w = jnp.asarray(np.random.default_rng(D).uniform(1e-3, 2.0, (m, D)),
                    jnp.float32)
    a = merge_kernels.weighted_colmerge(x, w, block_d=block_d)
    b = ref_mod.weighted_colmerge_ref(x, w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("m,D,block_d", [(4, 64, 32), (8, 333, 128),
                                         (3, 1000, 512)])
@pytest.mark.parametrize("trim", [0.2, 1.0])
def test_ties_colmerge_kernel_matches_ref(m, D, block_d, trim):
    x = _panel(m, D, seed=m * 10 + D)
    tau = x - jnp.mean(x, axis=0)[None]
    thresh = ref_mod.ties_thresh_ref(tau, trim)
    a = merge_kernels.ties_colmerge(tau, thresh, block_d=block_d)
    b = ref_mod.ties_colmerge_ref(tau, thresh)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", ["var", "fisher", "ties"])
def test_merge_row_pallas_path_matches_xla(name):
    """use_pallas=True (interpret mode) routes the column reductions
    through kernels/merge_ops — same bits as the XLA oracle path."""
    pan = {"float32": _panel(6, 700, 31)}
    mg = merging_mod.get_merger(name)
    stats = _rich_stats(name, pan, seed=31)
    a = mg.merge_row(pan, stats=stats, use_pallas=False)
    b = mg.merge_row(pan, stats=stats, use_pallas=True, block_d=256)
    np.testing.assert_array_equal(np.asarray(a["float32"]),
                                  np.asarray(b["float32"]))


# ------------------------------------------------------ engine e2e


def _segment_run(merger, wire=None, m=4, H=2, dim=10, classes=3, seed=0):
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("adamw", 1e-2)
    rng = np.random.default_rng(seed)
    Ws = jnp.asarray(np.stack([topology.random_matching(m, 1.0, rng),
                               topology.fully_connected(m)]), jnp.float32)
    bx = jnp.asarray(rng.normal(size=(2, H, m, 8, dim)).astype(np.float32))
    by = jnp.asarray(rng.integers(0, classes,
                                  size=(2, H, m, 8)).astype(np.int32))
    st, spec = dsgd.init_panel_state(init_params, opt, m,
                                     jax.random.PRNGKey(0), wire=wire,
                                     merger=merger)
    seg = dsgd.make_panel_segment(loss_fn, opt, H, spec)
    ps, mets = seg(st, (bx, by), Ws, jax.random.PRNGKey(1))
    return ps, mets, spec


@pytest.mark.parametrize("name", NON_UNIFORM)
def test_segment_nonuniform_operator_end_to_end(name):
    """Every non-uniform operator runs through make_panel_segment: the
    final fully-connected round dispatches to merging.merge_panel, all
    rows come back identical (consensus EXACTLY 0 after the broadcast),
    and the statistics panels (when any) have been updated."""
    ps, mets, spec = _segment_run(name)
    assert float(mets["consensus"][-1]) == 0.0
    tree = panel_mod.from_panel(ps["panel"], spec)
    for x in jax.tree.leaves(tree):
        np.testing.assert_array_equal(np.asarray(x[0]), np.asarray(x[-1]))
        assert bool(jnp.all(jnp.isfinite(x)))
    mg = merging_mod.get_merger(name)
    if mg.stat_panels:
        assert sorted(ps["merge_stat"]) == sorted(mg.stat_panels)
        assert any(bool(jnp.any(v != 0.0))
                   for s in ps["merge_stat"].values() for v in s.values())


def test_segment_nonuniform_differs_from_uniform_but_matches_oracle():
    """The in-engine global round must agree with the TREE-path oracle
    (merge_stacked on the pre-merge panel + the same stats), and a
    non-degenerate operator must actually differ from the uniform mean."""
    name = "ties"
    m, H = 4, 2
    init_params, loss_fn = _toy_problem(m, 10, 3)
    opt = make_optimizer("adamw", 1e-2)
    rng = np.random.default_rng(5)
    W_gossip = jnp.asarray(topology.random_matching(m, 1.0, rng),
                           jnp.float32)[None]
    bx = jnp.asarray(rng.normal(size=(1, H, m, 8, 10)).astype(np.float32))
    by = jnp.asarray(rng.integers(0, 3, size=(1, H, m, 8)).astype(np.int32))
    st, spec = dsgd.init_panel_state(init_params, opt, m,
                                     jax.random.PRNGKey(0), merger=name)
    # donate=False: this test reuses the intermediate state for both the
    # merge round and the idle-round oracle reconstruction
    seg = dsgd.make_panel_segment(loss_fn, opt, H, spec, donate=False)
    # round 1: gossip only -> heterogeneous pre-merge panel
    ps, _ = seg(st, (bx, by), W_gossip, jax.random.PRNGKey(1))
    pre = panel_mod.from_panel(ps["panel"], spec)
    oracle = merge_mod.merge_stacked(pre, merger=name)
    # round 2: the global merge itself (fresh batches, full W)
    W_full = jnp.asarray(topology.fully_connected(m), jnp.float32)[None]
    ps2, _ = seg(ps, (bx, by), W_full, jax.random.PRNGKey(2))
    post = panel_mod.from_panel(ps2["panel"], spec)
    # oracle merged the pre-merge panel; the engine ran H more local steps
    # before ITS merge, so compare the engine against the oracle of its
    # own pre-merge state instead: rebuild it via a local-only round
    W_idle = jnp.asarray(topology.identity(m), jnp.float32)[None]
    ps_local, _ = seg(ps, (bx, by), W_idle, jax.random.PRNGKey(2))
    pre2 = panel_mod.from_panel(ps_local["panel"], spec)
    oracle2 = merge_mod.merge_stacked(pre2, merger=name)
    for a, b in zip(jax.tree.leaves(post), jax.tree.leaves(oracle2)):
        np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)
    uni = merge_mod.merge_stacked(pre2)  # uniform on the same state
    gap = max(float(jnp.max(jnp.abs(a - b))) for a, b in
              zip(jax.tree.leaves(oracle2), jax.tree.leaves(uni)))
    assert gap > 1e-4


def test_global_rounds_mask_overrides_w_fingerprint():
    """At m=2 a matched gossip pair's W IS bitwise the 1/m average, so
    the W fingerprint alone would misroute plain gossip rounds through a
    non-uniform operator; the explicit global_rounds mask (what the
    launcher passes from Schedule.last_kind) must override it both ways."""
    m, H = 2, 1
    init_params, loss_fn = _toy_problem(m, 10, 3)
    opt = make_optimizer("sgd", 1e-2)
    rng = np.random.default_rng(7)
    W_pair = jnp.asarray([[0.5, 0.5], [0.5, 0.5]], jnp.float32)[None]
    bx = jnp.asarray(rng.normal(size=(1, H, m, 8, 10)).astype(np.float32))
    by = jnp.asarray(rng.integers(0, 3, size=(1, H, m, 8)).astype(np.int32))

    def run(merger, glob):
        st, spec = dsgd.init_panel_state(init_params, opt, m,
                                         jax.random.PRNGKey(0),
                                         merger=merger)
        seg = dsgd.make_panel_segment(loss_fn, opt, H, spec)
        ps, _ = seg(st, (bx, by), W_pair, jax.random.PRNGKey(1),
                    None, glob)
        return ps["panel"]

    base = run(None, None)                       # uniform engine
    # marked NOT-global: the ties operator must stay out of the way —
    # the round is plain gossip, bit-identical to the uniform engine
    gossip = run("ties", jnp.asarray([False]))
    for k in base:
        np.testing.assert_array_equal(np.asarray(base[k]),
                                      np.asarray(gossip[k]))
    # marked global: the operator runs (mask says so, and at m=2 the
    # fingerprint would agree) — rows identical but != the plain mix
    merged = run("ties", jnp.asarray([True]))
    for k in merged:
        np.testing.assert_array_equal(np.asarray(merged[k][0]),
                                      np.asarray(merged[k][1]))
    assert any(bool(jnp.any(merged[k] != base[k])) for k in base)


def test_segment_stats_merger_requires_state():
    """A statistical operator on the spec without its merge_stat panels
    must fail loudly (mirrors the wire_err contract)."""
    m, H = 4, 2
    init_params, loss_fn = _toy_problem(m, 10, 3)
    opt = make_optimizer("sgd", 1e-2)
    st, spec = dsgd.init_panel_state(init_params, opt, m,
                                     jax.random.PRNGKey(0))
    spec_f = panel_mod.with_merger(spec, "fisher")
    seg = dsgd.make_panel_segment(loss_fn, opt, H, spec_f)
    Ws = jnp.asarray(topology.fully_connected(m), jnp.float32)[None]
    bx = jnp.zeros((1, H, m, 8, 10), jnp.float32)
    by = jnp.zeros((1, H, m, 8), jnp.int32)
    with pytest.raises(ValueError, match="merge_stat"):
        seg(st, (bx, by), Ws, jax.random.PRNGKey(1))


def test_swa_merge_skips_the_parameter_wire():
    """SwaMerger merges the ACCUMULATORS — the parameter panel never
    travels, so merge_panel must skip the codec entirely (no stochastic
    key needed even under an int8 policy, EF residual untouched): the
    idle-round rule applied to a stats-only merge."""
    x = _panel(4, 24, 13)
    pan = {"float32": x}
    spec = panel_mod.with_wire(panel_mod.make_spec({"w": x}), "int8_ef")
    mg = merging_mod.get_merger("swa")
    stats = mg.init_stats(pan)
    e0 = {"float32": jnp.full_like(x, 0.01)}
    # no key: an int8 encode would raise; the swa merge must not
    mixed, row, e1 = merging_mod.merge_panel(pan, mg, stats=stats,
                                             spec=spec, err=e0)
    np.testing.assert_array_equal(np.asarray(e1["float32"]),
                                  np.asarray(e0["float32"]))
    np.testing.assert_allclose(np.asarray(row["float32"]),
                               np.asarray(jnp.mean(x, 0)), atol=1e-6)
    # a panel-consuming merger under the same spec DOES demand the key
    with pytest.raises(ValueError, match="stochastic"):
        merging_mod.merge_panel(pan, "ties", spec=spec, err=e0)


def test_segment_wire_codec_composes_with_merger():
    """int8_ef wire + fisher merger: the merge round encodes the payload
    through the codec (residual updated) and still collapses consensus."""
    ps, mets, spec = _segment_run("fisher", wire="int8_ef")
    assert float(mets["consensus"][-1]) == 0.0
    assert any(bool(jnp.any(v != 0.0)) for v in ps["wire_err"].values())


# ---------------------------------------------- spec hook + registry


def test_with_merger_validation():
    spec = panel_mod.make_spec({"w": _panel(2, 8, 0)})
    assert spec.merger == "uniform"
    assert panel_mod.with_merger(spec, "ties").merger == "ties"
    assert panel_mod.with_merger(spec, None).merger == "uniform"
    with pytest.raises(ValueError, match="unknown merge operator"):
        panel_mod.with_merger(spec, "tias")
    with pytest.raises(ValueError, match="registry NAME"):
        panel_mod.with_merger(spec, merging_mod.TiesMerger(trim=0.5))


def test_get_merger_instance_passthrough():
    mg = merging_mod.TiesMerger(trim=0.7)
    assert merging_mod.get_merger(mg) is mg
    assert merging_mod.get_merger("swa") is merging_mod.MERGERS["swa"]


def test_stats_mergers_refuse_missing_stats():
    pan = {"float32": _panel(3, 8, 2)}
    for name in ("var", "fisher", "swa"):
        with pytest.raises(ValueError, match="stats"):
            merging_mod.get_merger(name).merge_row(pan)


# ------------------------------------------- tree-path oracle + C.3.4


def test_counterfactual_eval_merger_does_not_modify_state():
    theta = {"x": _panel(6, 23, 9)}
    before = jax.tree.map(lambda x: x.copy(), theta)
    for name in ("uniform", "ties", "weighted"):
        _ = merge_mod.counterfactual_eval(
            lambda p: float(jnp.sum(p["x"])), theta, merger=name)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(theta)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gossip_merge_rounds_scanned_matches_host_loop_bitexact():
    """The scanned rewrite must reproduce the old per-round host loop
    bit-for-bit in the default (f32, no codec) configuration."""
    m = 8
    theta = {"x": _panel(m, 29, 2)}
    sampler = topology.make_sampler("exponential", m)
    out = merge_mod.gossip_merge_rounds(theta, sampler, 3,
                                        np.random.default_rng(0))
    spec = panel_mod.make_spec(theta)
    pan = panel_mod.to_panel(theta, spec)
    rng = np.random.default_rng(0)
    for t in range(3):
        pan = panel_mod.mix_dense(pan, jnp.asarray(sampler(t, rng),
                                                   jnp.float32))
    ref = panel_mod.from_panel(pan, spec)
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.asarray(ref["x"]))
    # log2(m) exponential rounds realise the exact global average
    target = gossip.merged_model(theta)
    assert float(jnp.max(jnp.abs(out["x"] - target["x"][None]))) < 1e-4
    # the folded-mean consensus trace decays to ~0 as the merge converges
    out2, xis = merge_mod.gossip_merge_rounds(
        theta, sampler, 3, np.random.default_rng(0), return_xi=True)
    np.testing.assert_array_equal(np.asarray(out2["x"]),
                                  np.asarray(out["x"]))
    assert xis.shape == (3,) and float(xis[-1]) < 1e-4 < float(xis[0])


def test_gossip_merge_rounds_codec_aware():
    m = 8
    theta = {"x": _panel(m, 64, 3)}
    sampler = topology.make_sampler("exponential", m)
    f32 = merge_mod.gossip_merge_rounds(theta, sampler, 3,
                                        np.random.default_rng(0))
    bf16 = merge_mod.gossip_merge_rounds(theta, sampler, 3,
                                         np.random.default_rng(0),
                                         wire="bf16")
    gap = float(jnp.max(jnp.abs(f32["x"] - bf16["x"])))
    assert 0.0 < gap < 2e-2  # quantized, but within bf16 tolerance
    i8 = merge_mod.gossip_merge_rounds(theta, sampler, 3,
                                       np.random.default_rng(0),
                                       wire="int8",
                                       key=jax.random.PRNGKey(0))
    assert float(jnp.max(jnp.abs(f32["x"] - i8["x"]))) < 0.05
    with pytest.raises(ValueError, match="error-feedback"):
        merge_mod.gossip_merge_rounds(theta, sampler, 3,
                                      np.random.default_rng(0),
                                      wire="int8_ef",
                                      key=jax.random.PRNGKey(0))
