"""Elastic-run liveness: degraded mixing matrices, the per-agent live
mask through the scanned segment (dead rows bit-exact, survivors match
the surviving-subgraph oracle, rejoin resyncs without perturbing
survivors), masked merge operators and masked tree-oracle merges, and
the FaultPlan parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dsgd, faults, gossip, topology
from repro.core import panel as panel_mod
from repro.core.schedule import make_schedule
from repro.merging import MERGERS, get_merger
from repro.optim import make_optimizer


def _toy_problem(m=8, dim=12, classes=4):
    def init_params(rng):
        k1, _ = jax.random.split(rng)
        return {"w": jax.random.normal(k1, (dim, classes)) * 0.1,
                "b": jnp.zeros(classes)}

    def loss_fn(p, batch, rng=None):
        x, y = batch
        lg = x @ p["w"] + p["b"]
        nll = jnp.mean(jax.nn.logsumexp(lg, -1)
                       - jnp.take_along_axis(lg, y[:, None], -1)[:, 0])
        return nll, {}

    return init_params, loss_fn


def _batches(S, H, m, dim, classes, rng):
    bx = jnp.asarray(rng.normal(size=(S, H, m, 8, dim)).astype(np.float32))
    by = jnp.asarray(rng.integers(0, classes,
                                  size=(S, H, m, 8)).astype(np.int32))
    return bx, by


def _rows(state, idx):
    """Slice agent rows out of every (m, ...) leaf of a panel state (the
    scalar step is kept) — builds the surviving-subgraph oracle state."""
    m = next(iter(state["panel"].values())).shape[0]

    def f(x):
        if getattr(x, "ndim", 0) >= 1 and x.shape[0] == m:
            return x[jnp.asarray(idx)]
        return x

    return jax.tree.map(f, state)


def _host(state):
    return jax.tree.map(np.asarray, state)


# ------------------------------------------------- degraded topologies


def test_degrade_to_live_doubly_stochastic():
    rng = np.random.default_rng(0)
    W = topology.random_matching(8, 0.7, rng)
    live = np.array([1, 0, 1, 1, 0, 1, 1, 1], bool)
    Wd = topology.degrade_to_live(W, live)
    np.testing.assert_allclose(Wd.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(Wd.sum(1), 1.0, atol=1e-12)
    for k in np.flatnonzero(~live):
        np.testing.assert_array_equal(Wd[k], np.eye(8)[k])
        np.testing.assert_array_equal(Wd[:, k], np.eye(8)[k])
    # all-live is the identity transform
    np.testing.assert_array_equal(
        topology.degrade_to_live(W, np.ones(8, bool)), W)


def test_fully_connected_live_sub_allreduce():
    live = np.array([0, 1, 1, 0, 1], bool)
    W = topology.fully_connected_live(live)
    np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-12)
    sub = W[np.ix_(live, live)]
    np.testing.assert_allclose(sub, np.full((3, 3), 1 / 3))
    np.testing.assert_array_equal(
        topology.fully_connected_live(np.zeros(4, bool)), np.eye(4))


def test_schedule_degrades_w_and_reports_live():
    m, rounds = 5, 8
    plan = faults.FaultPlan.parse(m, "2@1-4;4@6")
    sf = make_schedule("final_merge", m, rounds, seed=3, faults=plan)
    s0 = make_schedule("final_merge", m, rounds, seed=3)
    for t in range(rounds):
        Wf = sf.mixing_matrix(t)
        W = s0.mixing_matrix(t)
        lv = sf.last_live
        np.testing.assert_array_equal(lv, plan.mask(t))
        assert s0.last_live is None
        alive = lv == faults.LIVE
        # a RESYNC agent is dead FOR THE MATRIX (identity row); the
        # fault-free twin consumed the same rng, so the same W draw
        if sf.last_kind == "global":
            np.testing.assert_allclose(
                Wf, topology.fully_connected_live(alive), atol=1e-12)
        else:
            np.testing.assert_allclose(
                Wf, topology.degrade_to_live(W, alive), atol=1e-12)


# ------------------------------------------------------ fault plans


def test_fault_plan_mask_and_parse_roundtrip():
    plan = faults.FaultPlan.parse(6, "2@5-9; 0@3")
    assert str(plan) == "0@3;2@5-9"
    assert faults.FaultPlan.parse(6, str(plan)).events == plan.events
    np.testing.assert_array_equal(plan.mask(4), [0, 1, 1, 1, 1, 1])
    np.testing.assert_array_equal(plan.mask(5), [0, 1, 0, 1, 1, 1])
    np.testing.assert_array_equal(plan.mask(9), [0, 1, 2, 1, 1, 1])
    np.testing.assert_array_equal(plan.mask(10), [0, 1, 1, 1, 1, 1])
    assert not faults.FaultPlan(4)
    assert plan


@pytest.mark.parametrize("spec", [
    "9@1",        # agent out of range
    "1@5-5",      # rejoin must be after kill
    "1@2;1@4",    # second event after an open-ended kill
    "1@2-6;1@4",  # overlapping kill/rejoin windows
    "1@x",        # unparsable
    "oops",
])
def test_fault_plan_rejects(spec):
    with pytest.raises(ValueError):
        faults.FaultPlan.parse(4, spec)


# --------------------------------------------- segment liveness parity


def test_all_live_mask_is_noop():
    """live == all-ones must reproduce live=None through the lossy-wire
    + statistical-merger path: params/moments/stats BIT-exact; the EF
    residual is allowed one ulp (the live path is a different compiled
    graph, and XLA may fuse the codec's x + err - decode differently)."""
    m, H, S, dim, classes = 4, 2, 3, 8, 3
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("adamw", 1e-2)
    rng = np.random.default_rng(0)
    Ws = np.stack([topology.random_matching(m, 0.8, rng) for _ in range(2)]
                  + [topology.fully_connected(m)])
    Ws = jnp.asarray(Ws, jnp.float32)
    glob = jnp.asarray([False, False, True])
    batches = _batches(S, H, m, dim, classes, rng)
    finals = []
    for live in (None, jnp.ones((S, m), jnp.int32)):
        st, spec = dsgd.init_panel_state(
            init_params, opt, m, jax.random.PRNGKey(0), wire="int8_ef",
            merger="fisher")
        seg = dsgd.make_panel_segment(loss_fn, opt, H, spec)
        st, _ = seg(st, batches, Ws, jax.random.PRNGKey(1), None, glob,
                    live)
        finals.append(_host(st))
    ref, got = finals
    for part in ("panel", "opt", "merge_stat", "step"):
        for a, b in zip(jax.tree.leaves(ref[part]),
                        jax.tree.leaves(got[part])):
            np.testing.assert_array_equal(a, b)
    for k in ref["wire_err"]:
        np.testing.assert_allclose(ref["wire_err"][k], got["wire_err"][k],
                                   atol=1e-7)


def test_kill_mid_segment_dead_rows_bit_exact():
    """From its kill round on, EVERY state row of a dead agent (params,
    both moments, EF residual, merge statistics) passes through
    untouched."""
    m, H, dim, classes = 4, 2, 8, 3
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("adamw", 1e-2)
    st, spec = dsgd.init_panel_state(
        init_params, opt, m, jax.random.PRNGKey(0), wire="int8_ef",
        merger="fisher")
    seg = dsgd.make_panel_segment(loss_fn, opt, H, spec)
    rng = np.random.default_rng(1)
    glob = jnp.asarray([False, False, False, True])

    # phase 1: two all-live rounds, so the dead rows are NON-trivial
    Ws1 = jnp.asarray(np.stack(
        [topology.random_matching(m, 0.9, rng) for _ in range(2)]),
        jnp.float32)
    b1 = _batches(2, H, m, dim, classes, rng)
    st, _ = seg(st, b1, Ws1, jax.random.PRNGKey(1), None, glob[:2])
    snap = _host(st)

    # phase 2: agent 3 dies; its rows must stay at their phase-1 values
    live = np.ones(m, bool)
    live[3] = False
    Ws2 = np.stack([topology.degrade_to_live(
        topology.random_matching(m, 0.9, rng), live),
        topology.fully_connected_live(live)])
    b2 = _batches(2, H, m, dim, classes, rng)
    st = jax.tree.map(jnp.asarray, snap)
    st, _ = seg(st, b2, jnp.asarray(Ws2, jnp.float32),
                jax.random.PRNGKey(2), None, glob[2:],
                jnp.asarray(np.stack([live, live]), jnp.int32))
    out = _host(st)
    for part in ("panel", "wire_err"):
        for k in out[part]:
            np.testing.assert_array_equal(out[part][k][3], snap[part][k][3])
    for mom in ("m", "v"):
        for k in out["opt"][mom]:
            np.testing.assert_array_equal(out["opt"][mom][k][3],
                                          snap["opt"][mom][k][3])
    for name in out["merge_stat"]:
        for k in out["merge_stat"][name]:
            np.testing.assert_array_equal(out["merge_stat"][name][k][3],
                                          snap["merge_stat"][name][k][3])
    # ... and the survivors did move
    assert not np.array_equal(out["panel"]["float32"][0],
                              snap["panel"]["float32"][0])


def test_survivors_match_subgraph_oracle():
    """With agent 3 dead from round 0, the survivors' trajectory equals
    an m'=3 run on the degraded W's live sub-block (the loss ignores its
    rng, so the m-dependent per-agent rng split is immaterial)."""
    m, H, S, dim, classes = 4, 2, 4, 8, 3
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("sgd", 1e-2)
    st4, spec = dsgd.init_panel_state(init_params, opt, m,
                                      jax.random.PRNGKey(0))
    seg = dsgd.make_panel_segment(loss_fn, opt, H, spec, donate=False)
    live = np.array([1, 1, 1, 0], bool)
    rng = np.random.default_rng(2)
    Ws = np.stack([topology.degrade_to_live(
        topology.random_matching(m, 0.9, rng), live) for _ in range(S - 1)]
        + [topology.fully_connected_live(live)])
    glob = jnp.asarray([False] * (S - 1) + [True])
    bx, by = _batches(S, H, m, dim, classes, rng)
    lv = jnp.asarray(np.stack([live] * S), jnp.int32)
    out4, _ = seg(st4, (bx, by), jnp.asarray(Ws, jnp.float32),
                  jax.random.PRNGKey(1), None, glob, lv)

    st3 = _rows(st4, [0, 1, 2])
    out3, _ = seg(st3, (bx[:, :, :3], by[:, :, :3]),
                  jnp.asarray(Ws[:, :3, :3], jnp.float32),
                  jax.random.PRNGKey(1), None, glob)
    for k in out4["panel"]:
        np.testing.assert_allclose(np.asarray(out4["panel"][k][:3]),
                                   np.asarray(out3["panel"][k]),
                                   atol=1e-6, rtol=1e-6)
    # the dead agent never trained: still at its init row
    np.testing.assert_array_equal(np.asarray(out4["panel"]["float32"][3]),
                                  np.asarray(st4["panel"]["float32"][3]))


def test_rejoin_resyncs_without_perturbing_survivors():
    """Plan A (agent 1 rejoins at round 3) and plan B (agent 1 dead for
    good) must give BIT-identical survivor rows; the rejoiner comes back
    holding the live agents' post-mix mean with freshly zeroed moments."""
    m, H, S, dim, classes = 4, 2, 4, 8, 3
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("adamw", 1e-2)
    rng = np.random.default_rng(3)
    raw = [topology.random_matching(m, 0.9, rng) for _ in range(S)]
    bx, by = _batches(S, H, m, dim, classes, rng)
    outs = []
    for spec_str in ("1@1-3", "1@1"):
        plan = faults.FaultPlan.parse(m, spec_str)
        lv = np.stack([plan.mask(t) for t in range(S)])
        Ws = np.stack([topology.degrade_to_live(
            raw[t], lv[t] == faults.LIVE) for t in range(S)])
        st, spec = dsgd.init_panel_state(init_params, opt, m,
                                         jax.random.PRNGKey(0))
        seg = dsgd.make_panel_segment(loss_fn, opt, H, spec)
        st, _ = seg(st, (bx, by), jnp.asarray(Ws, jnp.float32),
                    jax.random.PRNGKey(1), None, None,
                    jnp.asarray(lv, jnp.int32))
        outs.append(_host(st))
    rejoin, gone = outs
    surv = [0, 2, 3]
    for k in rejoin["panel"]:
        np.testing.assert_array_equal(rejoin["panel"][k][surv],
                                      gone["panel"][k][surv])
    # the rejoined row is the live agents' post-mix mean ...
    for k in rejoin["panel"]:
        np.testing.assert_allclose(
            rejoin["panel"][k][1],
            rejoin["panel"][k][surv].astype(np.float32).mean(0).astype(
                rejoin["panel"][k].dtype),
            atol=1e-6)
    # ... with re-initialized (zero) moments, unlike the dead row's
    for mom in ("m", "v"):
        for k in rejoin["opt"][mom]:
            np.testing.assert_array_equal(
                rejoin["opt"][mom][k][1],
                np.zeros_like(rejoin["opt"][mom][k][1]))
        assert any(np.any(gone["opt"][mom][k][1])
                   for k in gone["opt"][mom])


# --------------------------------------------- masked merge operators


@pytest.mark.parametrize("name", sorted(MERGERS))
def test_masked_merge_row_matches_subpanel(name):
    """merge_row(live=mask) must equal the operator on the live agents'
    sub-panel for EVERY registered operator — dead rows contribute
    nothing, not even through normalization terms."""
    m = 6
    live = np.array([1, 0, 1, 1, 0, 1], bool)
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    panel = {"float32": jax.random.normal(ks[0], (m, 24)),
             "bfloat16": jax.random.normal(ks[1], (m, 10), jnp.bfloat16)}
    gpan = {k: jax.random.normal(ks[2], v.shape).astype(v.dtype)
            for k, v in panel.items()}
    mg = get_merger(name)
    stats = mg.init_stats(panel)
    if stats:
        stats = mg.update_local(stats, gpan)
        stats = mg.update_round(stats, panel)
    sub = jnp.asarray(np.flatnonzero(live))
    sub_panel = {k: v[sub] for k, v in panel.items()}
    sub_stats = ({n: {k: v[sub] for k, v in s.items()}
                  for n, s in stats.items()} if stats else None)
    full = mg.merge_row(panel, stats or None, live=jnp.asarray(live))
    ref = mg.merge_row(sub_panel, sub_stats)
    for k in full:
        np.testing.assert_allclose(np.asarray(full[k], np.float32),
                                   np.asarray(ref[k], np.float32),
                                   atol=1e-5, rtol=1e-5)


def test_gossip_tree_oracle_masked_merge():
    """gossip.global_merge_tree(live=) — live rows take the live mean,
    dead rows pass through; merged_model_tree(live=) averages live rows
    only."""
    m = 5
    live = jnp.asarray(np.array([1, 1, 0, 1, 0], bool))
    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    tree = {"w": jax.random.normal(ks[0], (m, 7, 3)),
            "b": jax.random.normal(ks[1], (m, 4), jnp.bfloat16)}
    out = gossip.global_merge_tree(tree, live=live)
    idx = np.flatnonzero(np.asarray(live))
    for k in tree:
        x = np.asarray(tree[k], np.float32)
        y = np.asarray(out[k], np.float32)
        mean = x[idx].mean(0)
        for i in range(m):
            if live[i]:
                np.testing.assert_allclose(
                    y[i], mean.astype(np.asarray(tree[k]).dtype
                                      ).astype(np.float32), atol=1e-6)
            else:
                np.testing.assert_array_equal(np.asarray(out[k][i]),
                                              np.asarray(tree[k][i]))
    mm = gossip.merged_model_tree(tree, live=live)
    for k in tree:
        np.testing.assert_allclose(
            np.asarray(mm[k]),
            np.asarray(tree[k], np.float32)[idx].mean(0), atol=1e-6)


def test_panel_masked_merged_and_consensus():
    m = 6
    live = jnp.asarray(np.array([1, 0, 1, 1, 0, 1], bool))
    idx = np.flatnonzero(np.asarray(live))
    x = jax.random.normal(jax.random.PRNGKey(11), (m, 20))
    pan = {"float32": x}
    row = panel_mod.merged(pan, live=live)
    np.testing.assert_allclose(np.asarray(row["float32"]),
                               np.asarray(x)[idx].mean(0), atol=1e-6)
    xi = float(panel_mod.consensus_distance(pan, live=live))
    sub = np.asarray(x)[idx]
    ref = np.sqrt(((sub - sub.mean(0)) ** 2).sum() / len(idx))
    assert xi == pytest.approx(ref, rel=1e-5)
