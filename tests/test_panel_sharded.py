"""Sharded flat-panel engine vs the per-leaf ``gossip.*_tree`` oracle.

The (m, D) panel is row-sharded over ('pod','agent') and D-sharded over
'fsdp' on the (1,2,2,2) debug training mesh (8 forced host devices in a
subprocess — tests/_multidevice.py). Asserts:

* fused ``mix_dense`` matches the tree oracle BIT-FOR-BIT in f32 (both
  paths do the same f32-accumulating matmul; m=2 leaves no reassociation
  freedom) and within bf16 tolerance in wire mode (the tree path casts W
  to the wire dtype, the panel path keeps W f32 — intentionally different
  rounding);
* ``global_merge`` / ``consensus_distance`` match exactly;
* the lowered fused mix carries fsdp-LOCAL collective traffic: nonzero,
  but strictly less than a full-panel (replicated-D) exchange because
  each fsdp shard only moves its own column slice;
* the int8 wire codec (repro/wire) partitions cleanly: the sharded fused
  mix draws bit-identical stochastic rounding to the replicated engine
  given the same key (wire keys fold in sorted-group order, independent
  of the mesh), stays within one quantization step of the f32 mix, and
  the codec-aware ``PanelSpec.wire_bytes`` orders int8 < bf16 < f32;
* the full ``make_panel_segment`` step compiles on the training-mesh
  axes with nonzero collective bytes and reproduces the tree-state round
  driver.

The debug mesh mirrors make_training_mesh's ('pod','agent','fsdp','model')
axes at CPU scale; launch/dryrun.py --variant panel runs the identical
lowering on the full 256-chip mesh.
"""
import textwrap

import pytest

from _multidevice import run_multidevice

PARITY_SCRIPT = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import gossip, topology
    from repro.core import panel as panel_mod
    from repro.core.consensus import consensus_distance_tree
    from repro.launch import mesh as mesh_mod
    from repro.utils.hlo import collective_bytes

    mesh = mesh_mod.make_debug_mesh(agents=2, fsdp=2, model=2)
    m = 2
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    # mixed dtypes; f32 group width 128 and bf16 width 34 both divide the
    # 2-way fsdp axis; the (m, 9) leaf makes the f32 offsets non-trivial
    tree = {"w": jax.random.normal(ks[0], (m, 17, 7)),
            "b": jax.random.normal(ks[1], (m, 9)),
            "e": jax.random.normal(ks[2], (m, 34), jnp.bfloat16)}
    spec = panel_mod.shard_spec(panel_mod.make_spec(tree), mesh)
    pan = panel_mod.to_panel(tree, spec)
    W = jnp.asarray(topology.random_matching(
        m, 1.0, np.random.default_rng(0)), jnp.float32)

    rec = {"pspecs": {k: str(ps) for k, ps in spec.pspecs},
           "shardings": {k: str(v.sharding) for k, v in pan.items()}}

    def max_err(a_tree, b_tree):
        return max(float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(a_tree), jax.tree.leaves(b_tree)))

    # fused sharded mix vs per-leaf oracle — f32 exact, bf16-wire approx
    mix = jax.jit(lambda p, W: panel_mod.mix_dense(p, W, spec=spec))
    rec["mix_err"] = max_err(panel_mod.from_panel(mix(pan, W), spec),
                             gossip.mix_dense_tree(tree, W))
    mix_bf16 = jax.jit(lambda p, W: panel_mod.mix_dense(
        p, W, wire_dtype=jnp.bfloat16, spec=spec))
    rec["mix_bf16_err"] = max_err(
        panel_mod.from_panel(mix_bf16(pan, W), spec),
        gossip.mix_dense_tree(tree, W, wire_dtype=jnp.bfloat16))

    # merge + consensus monitor
    gm = jax.jit(lambda p: panel_mod.global_merge(p, spec=spec))(pan)
    rec["merge_err"] = max_err(panel_mod.from_panel(gm, spec),
                               gossip.global_merge_tree(tree))
    rec["consensus"] = float(jax.jit(
        lambda p: panel_mod.consensus_distance(p, spec=spec))(pan))
    rec["consensus_ref"] = float(consensus_distance_tree(tree))
    mm = jax.jit(lambda p: panel_mod.merged(p, spec=spec))(pan)
    rec["merged_err"] = max_err(panel_mod.from_panel(mm, spec, cast=False),
                                gossip.merged_model_tree(tree))

    # int8 wire codec on the debug mesh: the sharded fused mix must draw
    # the SAME stochastic rounding as the replicated engine (wire keys are
    # folded in sorted-group order, independent of partitioning) and land
    # within one quantization step of the f32 mix
    spec_i8 = panel_mod.with_wire(spec, "int8")
    repl_i8 = panel_mod.with_wire(panel_mod.make_spec(tree), "int8")
    wkey = jax.random.PRNGKey(5)
    mix_i8 = jax.jit(lambda p, W: panel_mod.mix_dense(p, W, spec=spec_i8,
                                                      key=wkey))
    out_i8 = mix_i8(pan, W)
    rec["mix_int8_shard_vs_repl_err"] = max_err(
        panel_mod.from_panel(out_i8, spec_i8),
        panel_mod.from_panel(
            panel_mod.mix_dense(panel_mod.to_panel(tree, repl_i8), W,
                                spec=repl_i8, key=wkey), repl_i8))
    rec["mix_int8_vs_f32_err"] = max_err(
        panel_mod.from_panel(out_i8, spec_i8),
        panel_mod.from_panel(mix(pan, W), spec))
    # one int8 quantization step per dtype group: max per-row scale
    rec["int8_step"] = max(
        float(jnp.max(jnp.abs(x.astype(jnp.float32)))) / 127.0
        for x in pan.values())
    rec["wire_bytes"] = {
        "f32": spec.wire_bytes,
        "bf16": panel_mod.with_wire(spec, "bf16").wire_bytes,
        "int8": spec_i8.wire_bytes}

    # collective traffic of the lowered fused mix: fsdp-local
    per_kind, total, counts = collective_bytes(
        mix.lower(pan, W).compile().as_text())
    rec["coll_bytes"] = total
    rec["coll_kinds"] = sorted(per_kind)
    rec["full_exchange_bytes"] = m * spec.wire_bytes
    print(json.dumps(rec))
""")

SEGMENT_SCRIPT = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import dsgd, topology
    from repro.core import panel as panel_mod
    from repro.launch import mesh as mesh_mod
    from repro.optim import make_optimizer
    from repro.utils.hlo import collective_bytes

    mesh = mesh_mod.make_debug_mesh(agents=2, fsdp=2, model=2)
    m, H, S, dim, classes = 2, 2, 3, 16, 4

    def init_params(rng):
        k1, _ = jax.random.split(rng)
        return {"w": jax.random.normal(k1, (dim, classes)) * 0.1,
                "b": jnp.zeros(classes)}

    def loss_fn(p, batch, rng=None):
        x, y = batch
        lg = x @ p["w"] + p["b"]
        nll = jnp.mean(jax.nn.logsumexp(lg, -1)
                       - jnp.take_along_axis(lg, y[:, None], -1)[:, 0])
        return nll, {}

    opt = make_optimizer("adamw", 1e-2)
    pstate, spec = dsgd.init_panel_state(init_params, opt, m,
                                         jax.random.PRNGKey(0), mesh=mesh)
    in_sh = (dsgd.panel_state_shardings(pstate, spec),
             (NamedSharding(mesh, P(None, None, ("pod", "agent"))),) * 2,
             NamedSharding(mesh, P()), NamedSharding(mesh, P()))
    seg_fn = dsgd.make_panel_segment(loss_fn, opt, H, spec,
                                     in_shardings=in_sh)
    rng = np.random.default_rng(0)
    Ws = jnp.asarray(np.stack([topology.random_matching(m, 1.0, rng)
                               for _ in range(S)]), jnp.float32)
    bx = jnp.asarray(rng.normal(size=(S, H, m, 8, dim)).astype(np.float32))
    by = jnp.asarray(rng.integers(
        0, classes, size=(S, H, m, 8)).astype(np.int32))
    per_kind, total, counts = collective_bytes(
        seg_fn.lower(pstate, (bx, by), Ws,
                     jax.random.PRNGKey(1)).compile().as_text())

    ps, mets = seg_fn(pstate, (bx, by), Ws, jax.random.PRNGKey(1))

    # tree-state oracle on the SAME mesh: init_state(shardings=) places the
    # agent-stacked leaves (and optimizer moments) row-wise on (pod, agent)
    row_sh = NamedSharding(mesh, P(("pod", "agent")))
    leaf_sh = {"w": row_sh, "b": row_sh}
    ts = dsgd.init_state(init_params, opt, m, jax.random.PRNGKey(0),
                         shardings=leaf_sh)
    placed_ok = all(
        x.sharding.is_equivalent_to(row_sh, x.ndim)
        for x in list(jax.tree.leaves(ts["params"]))
        + list(jax.tree.leaves({k: v for k, v in ts["opt"].items()
                                if k in ("m", "v", "mu")})))
    round_fn = jax.jit(dsgd.make_dsgd_round(loss_fn, opt, H))
    rngs = jax.random.split(jax.random.PRNGKey(1), S)
    for t in range(S):
        ts, mets_t = round_fn(ts, (bx[t], by[t]), Ws[t], rngs[t])
    final = panel_mod.from_panel(ps["panel"], spec)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in
              zip(jax.tree.leaves(final), jax.tree.leaves(ts["params"])))
    print(json.dumps({
        "pspecs": {k: str(p) for k, p in spec.pspecs},
        "coll_bytes": total, "coll_kinds": sorted(per_kind),
        "param_err": err,
        "loss_gap": abs(float(mets["loss"][-1]) - float(mets_t["loss"])),
        "consensus_gap": abs(float(mets["consensus"][-1])
                             - float(mets_t["consensus"])),
        "tree_state_placed": placed_ok,
        "step": int(ps["step"])}))
""")


MERGE_SCRIPT = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro import merging
    from repro.core import dsgd, topology
    from repro.core import panel as panel_mod
    from repro.launch import mesh as mesh_mod
    from repro.optim import make_optimizer

    mesh = mesh_mod.make_debug_mesh(agents=2, fsdp=2, model=2)
    # m = 4 rows on the 2-device agent axis (2 rows/device): m = 2 would
    # make TIES degenerate (pairwise deviations are exact +/-d, so the
    # sign election ties and flips on f32 reassociation noise)
    m, H, S, dim, classes = 4, 1, 2, 16, 4

    def init_params(rng):
        k1, _ = jax.random.split(rng)
        return {"w": jax.random.normal(k1, (dim, classes)) * 0.1,
                "b": jnp.zeros(classes)}

    def loss_fn(p, batch, rng=None):
        x, y = batch
        lg = x @ p["w"] + p["b"]
        nll = jnp.mean(jax.nn.logsumexp(lg, -1)
                       - jnp.take_along_axis(lg, y[:, None], -1)[:, 0])
        return nll, {}

    opt = make_optimizer("adamw", 1e-2)
    rng = np.random.default_rng(0)
    # one forced pairwise exchange, then the operator's global merge
    Ws = jnp.asarray(np.stack([topology.random_matching(m, 1.0, rng),
                               topology.fully_connected(m)]), jnp.float32)
    bx = jnp.asarray(rng.normal(size=(S, H, m, 8, dim)).astype(np.float32))
    by = jnp.asarray(rng.integers(
        0, classes, size=(S, H, m, 8)).astype(np.int32))

    def run(name, use_mesh):
        st, spec = dsgd.init_panel_state(
            init_params, opt, m, jax.random.PRNGKey(0),
            mesh=mesh if use_mesh else None, merger=name)
        seg = dsgd.make_panel_segment(loss_fn, opt, H, spec)
        ps, mets = seg(st, (bx, by), Ws, jax.random.PRNGKey(1))
        return ps, mets, spec

    from repro.core import merge as merge_mod
    rec = {"segment": {}, "merge_row": {}}
    for name in sorted(merging.MERGERS):
        ps, mets, spec = run(name, True)
        row_gap = max(float(jnp.max(jnp.abs(
            x[0] - x[-1]))) for x in jax.tree.leaves(
            panel_mod.from_panel(ps["panel"], spec)))
        # jitted panel counterfactual on the mesh: the post-merge panel
        # has identical rows, so EVERY operator's counterfactual must
        # return ~row 0 (regression: a tree round-trip through a fresh
        # unsharded spec miscompiles under the idle 'model' axis,
        # doubling values — the engine-spec path must not)
        cf = jax.jit(lambda p, s: merge_mod.merged_panel_tree(
            p, spec, stats=s))(ps["panel"], ps.get("merge_stat"))
        cf_gap = max(float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b[0].astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(cf), jax.tree.leaves(
                panel_mod.from_panel(ps["panel"], spec))))
        rec["segment"][name] = {
            "consensus_final": float(mets["consensus"][-1]),
            "row_gap": row_gap, "cf_panel_gap": cf_gap,
            "finite": bool(all(jnp.all(jnp.isfinite(v))
                               for v in ps["panel"].values()))}

    # operator parity in isolation, sharded vs replicated, on a GENERIC
    # mixed-dtype panel (rows independent: sign elections / thresholds
    # are far from ties, unlike a freshly-gossiped panel whose paired
    # deviations make TIES election a coin flip on reduction noise)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    tree = {"w": jax.random.normal(ks[0], (4, 17, 7)),
            "b": jax.random.normal(ks[1], (4, 9)),
            "e": jax.random.normal(ks[2], (4, 34), jnp.bfloat16)}
    repl_spec = panel_mod.make_spec(tree)
    shard_specx = panel_mod.shard_spec(repl_spec, mesh)
    pan_r = panel_mod.to_panel(tree, repl_spec)
    pan_s = panel_mod.to_panel(tree, shard_specx)
    for name in sorted(merging.MERGERS):
        mg = merging.get_merger(name)
        stats_r = mg.init_stats(pan_r) or None
        if stats_r is not None and mg.round_stat:
            fake = {k: v + 0.05 * jnp.sign(v).astype(v.dtype)
                    for k, v in pan_r.items()}
            stats_r = mg.update_round(stats_r, fake)
        if stats_r is not None and mg.local_stat:
            stats_r = mg.update_local(
                stats_r, {k: 0.1 * v.astype(jnp.float32)
                          for k, v in pan_r.items()})
        stats_s = (None if stats_r is None else
                   {n: {k: panel_mod.place(v, shard_specx.sharding(k))
                        for k, v in s.items()}
                    for n, s in stats_r.items()})
        row_r = jax.jit(lambda p, s: mg.merge_row(
            p, stats=s, spec=repl_spec))(pan_r, stats_r)
        row_s = jax.jit(lambda p, s: mg.merge_row(
            p, stats=s, spec=shard_specx))(pan_s, stats_s)
        rec["merge_row"][name] = max(
            float(jnp.max(jnp.abs(row_s[k] - row_r[k]))) for k in row_r)
    print(json.dumps(rec))
""")


WIRE_SEGMENT_SCRIPT = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import dsgd, topology
    from repro.launch import mesh as mesh_mod
    from repro.optim import make_optimizer

    mesh = mesh_mod.make_debug_mesh(agents=2, fsdp=2, model=2)
    m, H, S, dim, classes = 4, 2, 3, 12, 4

    def init_params(rng):
        k1, _ = jax.random.split(rng)
        return {"w": jax.random.normal(k1, (dim, classes)) * 0.1,
                "b": jnp.zeros(classes)}

    def loss_fn(p, batch, rng=None):
        x, y = batch
        lg = x @ p["w"] + p["b"]
        nll = jnp.mean(jax.nn.logsumexp(lg, -1)
                       - jnp.take_along_axis(lg, y[:, None], -1)[:, 0])
        return nll, {}

    opt = make_optimizer("adamw", 1e-2)
    r3 = np.random.default_rng(0)
    bx = jnp.asarray(r3.normal(size=(S, H, m, 8, dim)).astype(np.float32))
    by = jnp.asarray(r3.integers(0, classes,
                                 size=(S, H, m, 8)).astype(np.int32))
    r3 = np.random.default_rng(3)
    Ws = jnp.asarray(np.stack([topology.random_matching(m, 1.0, r3),
                               topology.fully_connected(m),
                               topology.random_matching(m, 1.0, r3)]),
                     jnp.float32)
    glob = jnp.asarray([False, True, False])

    def run(wire, use_mesh):
        ps, spec = dsgd.init_panel_state(
            init_params, opt, m, jax.random.PRNGKey(0),
            mesh=mesh if use_mesh else None, wire=wire)
        seg = dsgd.make_panel_segment(loss_fn, opt, H, spec)
        out, mets = seg(ps, (bx, by), Ws, jax.random.PRNGKey(1), None,
                        glob)
        return out, mets

    rec = {}
    for wire in ("int4", "int4_ef", "topk"):
        out_r, mets_r = run(wire, False)
        out_s, mets_s = run(wire, True)
        gap = max(float(jnp.max(jnp.abs(
            out_s["panel"][k].astype(jnp.float32)
            - out_r["panel"][k].astype(jnp.float32))))
            for k in out_r["panel"])
        egap = (max(float(jnp.max(jnp.abs(
            out_s["wire_err"][k] - out_r["wire_err"][k])))
            for k in out_r["wire_err"])
            if "wire_err" in out_r else None)
        rec[wire] = {
            "panel_gap": gap, "err_gap": egap,
            "consensus_global": float(mets_s["consensus"][1]),
            "finite": bool(np.all(np.isfinite(
                np.asarray(mets_s["loss"])))),
        }
    print(json.dumps(rec))
""")


@pytest.fixture(scope="module")
def parity():
    return run_multidevice(PARITY_SCRIPT, devices=8, timeout=420)


@pytest.fixture(scope="module")
def wire_segment():
    return run_multidevice(WIRE_SEGMENT_SCRIPT, devices=8, timeout=420)


@pytest.fixture(scope="module")
def segment():
    return run_multidevice(SEGMENT_SCRIPT, devices=8, timeout=420)


@pytest.fixture(scope="module")
def merge_ops():
    return run_multidevice(MERGE_SCRIPT, devices=8, timeout=420)


@pytest.mark.multidevice
class TestShardedPanelParity:
    def test_spec_shards_rows_and_columns(self, parity):
        # both dtype groups divide the mesh axes, so both shard fully
        assert parity["pspecs"]["float32"] == \
            "PartitionSpec(('pod', 'agent'), 'fsdp')"
        assert parity["pspecs"]["bfloat16"] == \
            "PartitionSpec(('pod', 'agent'), 'fsdp')"

    def test_mix_dense_bitwise_f32(self, parity):
        assert parity["mix_err"] == 0.0

    def test_mix_dense_bf16_wire_tolerance(self, parity):
        assert 0.0 <= parity["mix_bf16_err"] < 2e-2

    def test_global_merge_and_merged_model(self, parity):
        assert parity["merge_err"] == 0.0
        assert parity["merged_err"] == 0.0

    def test_consensus_distance(self, parity):
        assert parity["consensus"] == pytest.approx(
            parity["consensus_ref"], rel=1e-6)

    def test_mix_int8_sharded_matches_replicated_bitwise(self, parity):
        # same key => same stochastic rounding, whatever the partitioning
        assert parity["mix_int8_shard_vs_repl_err"] == 0.0

    def test_mix_int8_within_one_quantization_step_of_f32(self, parity):
        # mixing is a convex combination of quantized rows, so the
        # deviation from the f32 mix is bounded by ~one per-row scale
        # (+ bf16 storage rounding on the bf16 group)
        assert 0.0 < parity["mix_int8_vs_f32_err"] <= \
            2.0 * parity["int8_step"]

    def test_wire_bytes_codec_ordering(self, parity):
        wb = parity["wire_bytes"]
        assert wb["int8"] < wb["bf16"] < wb["f32"]

    def test_collectives_are_fsdp_local(self, parity):
        # nonzero traffic on the agent axis, but strictly less than a
        # replicated-D exchange: each fsdp shard moves only its columns
        assert parity["coll_bytes"] > 0
        assert parity["coll_bytes"] < parity["full_exchange_bytes"]
        assert parity["coll_kinds"]


@pytest.mark.multidevice
class TestShardedPanelSegment:
    def test_segment_compiles_with_collectives(self, segment):
        assert segment["coll_bytes"] > 0
        assert segment["coll_kinds"]

    def test_segment_matches_tree_round_driver(self, segment):
        assert segment["param_err"] < 1e-6
        assert segment["loss_gap"] < 1e-6
        assert segment["consensus_gap"] < 1e-5
        assert segment["step"] == 6  # S * H

    def test_init_state_places_tree_leaves(self, segment):
        # dsgd.init_state(shardings=...) put params + moments on the mesh
        assert segment["tree_state_placed"]


@pytest.mark.multidevice
@pytest.mark.wire
class TestShardedWireCodecSegments:
    """int4/int4_ef/topk through make_panel_segment on the debug training
    mesh: the D-sharded engine reproduces the replicated engine at the
    psum-ulp floor (the partitionable-threefry draw and the delta-mix
    matmul must not depend on the partitioning), the EF/mirror panels
    agree, and the global round collapses consensus (int4 within its
    quantization step; topk exactly — its merge is the full-bandwidth
    round)."""

    def test_sharded_segment_matches_replicated(self, wire_segment):
        for name, r in wire_segment.items():
            assert r["finite"], name
            assert r["panel_gap"] <= 2e-6, (name, r)
            if r["err_gap"] is not None:
                assert r["err_gap"] <= 2e-6, (name, r)

    def test_topk_global_round_collapses_consensus(self, wire_segment):
        assert wire_segment["topk"]["consensus_global"] == 0.0


@pytest.mark.multidevice
@pytest.mark.merge
class TestShardedMergeOperators:
    """Every merge operator through make_panel_segment on the debug
    training mesh: the global round collapses consensus, and the
    D-sharded engine reproduces the replicated engine within the f32
    reduction-reassociation noise the sharded GRAD compute already has
    (uniform at that floor; the statistical operators add only their own
    fsdp-partitioned column reductions on top)."""

    def test_all_operators_segment_consensus_collapses(self, merge_ops):
        for name, r in merge_ops["segment"].items():
            assert r["consensus_final"] == 0.0, name
            assert r["row_gap"] == 0.0, name
            assert r["finite"], name

    def test_jitted_panel_counterfactual_on_mesh(self, merge_ops):
        # post-merge rows are identical, so the jitted counterfactual of
        # ANY operator must return ~row 0; a tree round-trip through a
        # fresh unsharded spec used to DOUBLE values under the idle
        # 'model' axis — the engine-spec path (merged_panel_tree) must
        # stay at the psum-ulp floor
        for name, r in merge_ops["segment"].items():
            assert r["cf_panel_gap"] < 1e-5, (name, r)

    def test_merge_row_sharded_parity(self, merge_ops):
        # the sharded mean lowers to a cross-device psum whose reduction
        # order differs from the replicated sum by ~1 ulp; every operator
        # must stay at that floor
        for name, err in merge_ops["merge_row"].items():
            assert err < 1e-5, (name, err)
