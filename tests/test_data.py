"""Data pipeline: Dirichlet partitioning + synthetic generators."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: property tests skip gracefully
    from _hypothesis_stub import given, settings, strategies as st

from repro.data.dirichlet import dirichlet_partition, heterogeneity
from repro.data.synthetic import (SyntheticClassification, SyntheticLM,
                                  make_agent_batches, make_agent_lm_batches)


@given(m=st.sampled_from([2, 8, 16]), alpha=st.sampled_from([0.1, 1.0, 10.0]),
       seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_partition_covers_all_examples_once(m, alpha, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=500)
    parts = dirichlet_partition(labels, m, alpha, rng, min_per_agent=0)
    allidx = np.concatenate([p for p in parts if len(p)])
    assert len(allidx) == 500
    assert len(np.unique(allidx)) == 500


def test_small_alpha_more_heterogeneous():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, size=4000)
    h_small = np.mean([heterogeneity(
        dirichlet_partition(labels, 8, 0.1, np.random.default_rng(s)),
        labels, 10) for s in range(5)])
    h_big = np.mean([heterogeneity(
        dirichlet_partition(labels, 8, 100.0, np.random.default_rng(s)),
        labels, 10) for s in range(5)])
    assert h_small > h_big + 0.2


def test_classification_batches_shapes():
    ds = SyntheticClassification(n_train=512, n_test=128)
    parts = ds.partition(4, 0.1)
    xb, yb = make_agent_batches(ds, parts, 16, np.random.default_rng(0))
    assert xb.shape == (4, 16, ds.dim) and yb.shape == (4, 16)


def test_lm_domain_skew_changes_statistics():
    lm = SyntheticLM(vocab=64, num_domains=4, seed=0)
    rng = np.random.default_rng(0)
    d0 = lm.sample(np.array([1.0, 0, 0, 0]), 64, 64, rng)
    d3 = lm.sample(np.array([0, 0, 0, 1.0]), 64, 64, rng)
    h0 = np.bincount(d0.ravel(), minlength=64) / d0.size
    h3 = np.bincount(d3.ravel(), minlength=64) / d3.size
    tv = 0.5 * np.abs(h0 - h3).sum()
    assert tv > 0.3  # clearly different token distributions


def test_lm_agent_batches_structure():
    lm = SyntheticLM(vocab=32, num_domains=4)
    mix = lm.domain_mixtures(3, 0.1)
    b = make_agent_lm_batches(lm, mix, 4, 16, np.random.default_rng(0))
    assert b["tokens"].shape == (3, 4, 16)
    assert (b["targets"][:, :, :-1] == b["tokens"][:, :, 1:]).all()
