"""Property tests (hypothesis) for the paper's core machinery: topology,
gossip, consensus contraction (Lemma D.1), schedules, merging."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: property tests skip gracefully
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import consensus, gossip, topology as topo
from repro.core.merge import gossip_merge_rounds, weighted_merge
from repro.core.schedule import make_schedule

AGENTS = st.sampled_from([2, 4, 8, 16])


@given(m=AGENTS, seed=st.integers(0, 1000), prob=st.floats(0.0, 1.0))
@settings(max_examples=40, deadline=None)
def test_random_matching_doubly_stochastic(m, seed, prob):
    W = topo.random_matching(m, prob, np.random.default_rng(seed))
    assert topo.is_doubly_stochastic(W)


@given(m=AGENTS, t=st.integers(0, 20))
@settings(max_examples=30, deadline=None)
def test_named_topologies_doubly_stochastic(m, t):
    for W in (topo.ring(m), topo.exponential(m), topo.fully_connected(m),
              topo.exponential_round(m, t)):
        assert topo.is_doubly_stochastic(W)


def test_spectral_p_ordering():
    m = 16
    p_full = topo.spectral_p(topo.fully_connected(m))
    p_ring = topo.spectral_p(topo.ring(m))
    p_id = topo.spectral_p(topo.identity(m))
    assert p_full == pytest.approx(1.0, abs=1e-9)
    assert p_id == pytest.approx(0.0, abs=1e-9)
    assert 0.0 < p_ring < 1.0
    # better-connected graphs have larger p (Eq. 10's p)
    assert p_full > p_ring > p_id


def test_expected_p_random_graph_theta1():
    """Random matchings achieve p = Theta(1) (paper §5.2 'Why limited but
    nonzero communication enables mergeability')."""
    m = 16
    rng = np.random.default_rng(0)
    p = topo.expected_p(topo.make_sampler("random", m, 0.2), m, 400, rng)
    assert p > 0.05  # bounded away from 0 despite ~20% activation


@given(seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_consensus_contraction_lemma_d1(seed):
    """E||Theta W - bar||^2 <= (1-p) ||Theta - bar||^2 (Assumption 1),
    checked empirically for the random-matching topology."""
    m = 8
    rng = np.random.default_rng(seed)
    theta = {"w": jnp.asarray(rng.normal(size=(m, 40)), jnp.float32)}
    xi0 = float(consensus.consensus_distance(theta)) ** 2
    xis = []
    for t in range(50):
        W = jnp.asarray(topo.random_matching(m, 0.5, rng), jnp.float32)
        mixed = gossip.mix_dense(theta, W)
        xis.append(float(consensus.consensus_distance(mixed)) ** 2)
    assert np.mean(xis) < xi0  # contraction on average
    for xi in xis:
        assert xi <= xi0 + 1e-5  # never expands (doubly stochastic)


def test_global_merge_equals_mean():
    m = 4
    theta = {"a": jnp.arange(m * 6, dtype=jnp.float32).reshape(m, 6)}
    merged = gossip.global_merge(theta)
    np.testing.assert_allclose(merged["a"][0], theta["a"].mean(0), atol=1e-6)
    np.testing.assert_allclose(merged["a"][2], theta["a"].mean(0), atol=1e-6)
    # equivalent to mixing with the fully-connected W
    densed = gossip.mix_dense(theta, jnp.asarray(
        topo.fully_connected(m), jnp.float32))
    np.testing.assert_allclose(merged["a"], densed["a"], atol=1e-6)


def test_pairwise_mix_matches_dense_matching():
    m = 8
    rng = np.random.default_rng(3)
    W = topo.random_matching(m, 0.8, rng)
    partner = jnp.asarray(topo.partner_array(W), jnp.int32)
    theta = {"x": jax.random.normal(jax.random.PRNGKey(0), (m, 13))}
    a = gossip.mix_dense(theta, jnp.asarray(W, jnp.float32))
    b = gossip.mix_pairwise(theta, partner)
    np.testing.assert_allclose(a["x"], b["x"], atol=1e-6)


@given(w=st.lists(st.floats(0.01, 10.0), min_size=4, max_size=4))
@settings(max_examples=20, deadline=None)
def test_weighted_merge_convexity(w):
    m = 4
    theta = {"x": jax.random.normal(jax.random.PRNGKey(1), (m, 7))}
    out = weighted_merge(theta, jnp.asarray(w))
    lo = theta["x"].min(0) - 1e-5
    hi = theta["x"].max(0) + 1e-5
    assert bool(jnp.all(out["x"] >= lo)) and bool(jnp.all(out["x"] <= hi))


def test_gossip_merge_rounds_approaches_global_merge():
    """Appendix C.3.4: several exponential-gossip rounds approximate the
    perfect global merge."""
    m = 8
    theta = {"x": jax.random.normal(jax.random.PRNGKey(2), (m, 29))}
    target = gossip.merged_model(theta)
    sampler = topo.make_sampler("exponential", m)
    approx = gossip_merge_rounds(theta, sampler, rounds=3,
                                 rng=np.random.default_rng(0))
    err = float(jnp.max(jnp.abs(approx["x"] - target["x"][None])))
    assert err < 1e-4  # log2(8)=3 rounds of exponential pairing = exact


def test_dsgd_step_pairwise_impl_takes_partner_array():
    """gossip_impl='pairwise' steps receive the (m,) partner array in the
    W slot (regression: this branch used to pass partner=None)."""
    from repro.core import dsgd
    from repro.optim import make_optimizer
    m = 4

    def init_params(rng):
        return {"w": jax.random.normal(rng, (3,))}

    def loss_fn(p, batch, rng=None):
        return jnp.sum(jnp.square(p["w"])), {}

    opt = make_optimizer("sgd", 0.0, weight_decay=0.0, momentum=0.0)
    state = dsgd.init_state(init_params, opt, m, jax.random.PRNGKey(0))
    step = jax.jit(dsgd.make_dsgd_step(loss_fn, opt, gossip_impl="pairwise"))
    W = topo.random_matching(m, 1.0, np.random.default_rng(0))
    partner = jnp.asarray(topo.partner_array(W), jnp.int32)
    batch = jnp.zeros((m, 1))
    new_state, mets = step(state, batch, partner, jax.random.PRNGKey(1))
    # lr=0: the local step is a no-op, so the result IS the pairwise mix
    ref = gossip.mix_pairwise_tree(state["params"], partner)
    np.testing.assert_allclose(new_state["params"]["w"], ref["w"], atol=1e-6)
    assert bool(jnp.isfinite(mets["loss"]))


def test_schedules_place_global_rounds_correctly():
    m, T = 8, 50
    s = make_schedule("final_merge", m, T)
    assert not s.is_global(0) and not s.is_global(T - 2)
    assert s.is_global(T - 1)
    w = make_schedule("windowed", m, T, start=10, end=15)
    assert w.is_global(12) and not w.is_global(15)
    p = make_schedule("periodic", m, T, period=10)
    assert p.is_global(9) and p.is_global(19) and not p.is_global(10)


def test_schedule_costs_match_paper_cost_model():
    """O(mRPT + 2mP): sparse rounds cost ~R*P per agent, AllReduce 2P."""
    m, T = 16, 100
    s = make_schedule("final_merge", m, T, prob=0.2, seed=0)
    costs = [s.round_cost(s.mixing_matrix(t)) for t in range(T)]
    assert costs[-1] == 2.0  # final AllReduce
    mean_sparse = np.mean(costs[:-1])
    assert 0.05 < mean_sparse < 0.4  # ~R=0.2 participation


def test_u_term_negative_under_progressive_sharpening():
    """On a quartic-ish loss with aligned curvature the U-term estimator
    should produce a finite scalar; sign depends on the landscape (sanity:
    runs, finite)."""
    m = 4

    def loss_fn(p, batch):
        x = p["x"]
        return jnp.sum(x ** 4) + 0.1 * jnp.sum(x ** 2), {}

    params = {"x": jnp.stack([jnp.array([1.0 + 0.1 * k, -1.0])
                              for k in range(m)])}
    u = consensus.u_term(loss_fn, params, None)
    assert bool(jnp.isfinite(u))
