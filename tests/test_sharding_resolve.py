"""Regression tests for the sharding resolver.

The trailing-dim alignment matters: stacked layer params carry extra leading
(agent, n_rep) dims; an early version aligned specs from the front, which
silently model-sharded w_in's *contraction* dim and produced 4x collective
blow-ups in the dry-run. These tests pin the correct behaviour.
"""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.sharding import logical as L
from repro.models.sharding import resolve_leaf
from repro.utils import flops as flops_mod


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"pod": 1, "agent": 16, "fsdp": 1, "model": 16})
RULES = {"fsdp": "fsdp", "model": "model", "expert": "model"}


def test_trailing_alignment_with_stacked_dims():
    # (m, n_rep, d, ff) with spec ("fsdp","model") must shard ff, NOT d
    ps = resolve_leaf(L("fsdp", "model"), (16, 16, 2048, 8192), MESH, RULES,
                      prefix=(("pod", "agent"),))
    assert ps == P(("pod", "agent"), None, None, "model")


def test_unstacked_embed():
    ps = resolve_leaf(L("fsdp", "model"), (16, 50432, 2048), MESH, RULES,
                      prefix=(("pod", "agent"),))
    assert ps == P(("pod", "agent"), None, "model")


def test_non_divisible_axis_dropped():
    # kv_dim 8 heads not divisible by model=16 -> replicated
    ps = resolve_leaf(L(None, "model"), (4, 2048, 8), MESH, RULES)
    assert ps == P(None, None, None)


def test_expert_rule_maps_to_model_axis():
    ps = resolve_leaf(L("expert", "fsdp", None), (2, 3, 128, 7168, 2048),
                      MESH, RULES, prefix=(("pod", "agent"),))
    assert ps == P(("pod", "agent"), None, "model", None, None)


def test_model_flops_scaling():
    """6·N·D scaling: train flops ~3x prefill flops for the same tokens;
    MoE active < total."""
    from repro.configs import INPUT_SHAPES, get_config
    from repro.models import build_model
    model = build_model(get_config("olmo-1b"))
    tr = flops_mod.model_flops(model, INPUT_SHAPES["train_4k"])
    assert tr["total"] == tr["active"]  # dense
    assert tr["model_flops"] == 6 * tr["active"] * tr["tokens"]
    moe = build_model(get_config("arctic-480b"))
    cm = flops_mod.param_counts(moe)
    assert cm["active"] < 0.3 * cm["total"]  # 128-expert top-2 sparsity


def test_decode_flops_tiny_vs_prefill():
    from repro.configs import INPUT_SHAPES, get_config
    from repro.models import build_model
    model = build_model(get_config("olmo-1b"))
    d = flops_mod.model_flops(model, INPUT_SHAPES["decode_32k"])
    p = flops_mod.model_flops(model, INPUT_SHAPES["prefill_32k"])
    assert d["model_flops"] < p["model_flops"] / 1000
