"""Wire-codec subsystem properties (repro/wire + kernels/wire_quant).

Hypothesis-driven properties (falling back to the offline
``_hypothesis_stub`` shim, which reports them as SKIPPED) plus plain
contract tests that always run:

* **stochastic rounding is unbiased** in expectation over PRNG keys:
  averaging dequant(quant(x, key_i)) over many independent keys converges
  to x at the statistical 1/sqrt(N) rate (per-element error bounded by a
  6-sigma band in units of the per-row scale);
* **int8 round-trip error contracts**: one encode is within one scale of
  the input (half a scale for round-to-nearest), and under error feedback
  the residual telescopes — the T-round mean of the transmitted panels
  deviates from a CONSTANT input by at most O(scale/T), so the feedback
  loop cancels quantization bias across rounds;
* **W = I idle rounds are bit-exact under every codec**: a full
  ``make_panel_segment`` run whose schedule never communicates produces
  bit-identical state for f32/bf16/int8/int8_ef and the no-policy engine
  (idle rounds skip the codec entirely; the wire key derivation must not
  disturb the local-step rng schedule), and the error-feedback residual
  panel stays exactly zero;
* codec-aware ``PanelSpec.wire_bytes`` (the >=3.5x int8 claim), per-group
  policies, key-handling errors, and bit-parity of the Pallas
  quantize/dequantize kernels against the ``kernels/ref.py`` oracles.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: dev extra not installed
    from _hypothesis_stub import given, settings, strategies as st

from repro import wire as wire_mod
from repro.core import dsgd
from repro.core import panel as panel_mod
from repro.kernels import ref as ref_mod
from repro.kernels import wire_quant
from repro.optim import make_optimizer
from test_panel import _segment_inputs, _toy_problem

pytestmark = pytest.mark.wire

ALL_CODECS = ("f32", "bf16", "int8", "int8_ef")


def _panel(m, d, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(m, d)) * scale, jnp.float32)


# ------------------------------------------------- stochastic rounding


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(1, 48), st.integers(0, 2**31 - 1))
def test_stochastic_rounding_unbiased_over_keys(m, d, seed):
    """E_key[decode(encode(x, key))] == x: the mean over N independent
    keys lands within 6 standard errors (scale/(2 sqrt(N)) per element)."""
    x = _panel(m, d, seed)
    codec = wire_mod.get_codec("int8")
    scale = ref_mod.int8_scale_ref(x)
    N = 256
    keys = jax.random.split(jax.random.PRNGKey(seed ^ 0x5EED), N)
    xhats = jax.vmap(lambda k: codec.encode(x, key=k)[0])(keys)
    err = jnp.abs(jnp.mean(xhats, axis=0) - x)
    bound = 6.0 * scale / (2.0 * np.sqrt(N))
    assert bool(jnp.all(err <= bound)), float(jnp.max(err / scale))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 128), st.integers(0, 2**31 - 1))
def test_int8_roundtrip_error_bounded(m, d, seed):
    """|decode(encode(x)) - x| <= scale stochastically, <= scale/2 for
    round-to-nearest; all-zero rows survive exactly (scale guard)."""
    x = _panel(m, d, seed).at[0].set(0.0)
    scale = ref_mod.int8_scale_ref(x)
    xh_sr, _, _ = wire_mod.get_codec("int8").encode(
        x, key=jax.random.PRNGKey(seed))
    det = wire_mod.Int8Codec("int8_det", stochastic=False)
    xh_rn, _, _ = det.encode(x)
    eps = 1e-6
    assert bool(jnp.all(jnp.abs(xh_sr - x) <= scale * (1 + eps)))
    assert bool(jnp.all(jnp.abs(xh_rn - x) <= scale * (0.5 + eps)))
    assert bool(jnp.all(xh_sr[0] == 0.0)) and bool(jnp.all(xh_rn[0] == 0.0))


def test_stochastic_encode_is_key_deterministic():
    x = _panel(4, 32, 0)
    codec = wire_mod.get_codec("int8")
    a, _, _ = codec.encode(x, key=jax.random.PRNGKey(3))
    b, _, _ = codec.encode(x, key=jax.random.PRNGKey(3))
    c, _, _ = codec.encode(x, key=jax.random.PRNGKey(4))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert bool(jnp.any(a != c))


def test_stochastic_codec_requires_key():
    x = _panel(2, 8, 1)
    with pytest.raises(ValueError, match="key"):
        wire_mod.get_codec("int8").encode(x)
    with pytest.raises(ValueError, match="stochastic"):
        panel_mod.mix_dense(
            {"float32": x}, jnp.eye(2),
            spec=panel_mod.with_wire(panel_mod.make_spec(
                {"w": x}), "int8"))


# ----------------------------------------------------- error feedback


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(1, 48), st.integers(0, 2**31 - 1))
def test_error_feedback_residual_telescopes(m, d, seed):
    """EF identity per round: xhat_t + e_t == x + e_{t-1} (up to f32
    rounding), residual bounded by one scale; telescoping over T rounds of
    a CONSTANT input, |mean_t(xhat_t) - x| <= (|e_0| + |e_T|)/T — the
    feedback loop cancels quantization bias across rounds."""
    x = _panel(m, d, seed)
    codec = wire_mod.get_codec("int8_ef")
    err = jnp.zeros_like(x)
    T = 64
    keys = jax.random.split(jax.random.PRNGKey(seed), T)
    acc = jnp.zeros_like(x)
    for t in range(T):
        prev = err
        xhat, _, err = codec.encode(x, key=keys[t], err=prev)
        scale = ref_mod.int8_scale_ref(x + prev)
        assert bool(jnp.all(jnp.abs(err) <= scale * (1 + 1e-6)))
        np.testing.assert_allclose(np.asarray(xhat + err),
                                   np.asarray(x + prev), atol=1e-5)
        acc = acc + xhat
    scale0 = ref_mod.int8_scale_ref(x)
    assert bool(jnp.all(jnp.abs(acc / T - x) <= 2.5 * scale0 / T + 1e-6))


def test_error_feedback_refused_on_tree_path():
    """int8_ef must FAIL LOUDLY on the residual-less per-leaf path and the
    stateless gossip wrappers instead of silently degrading to int8."""
    from repro.core import gossip
    tree = {"w": _panel(4, 8, 0)}
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="error-feedback"):
        gossip.mix_dense_tree(tree, jnp.eye(4), wire="int8_ef", key=key)
    with pytest.raises(ValueError, match="error-feedback"):
        gossip.global_merge(tree, wire="int8_ef", key=key)
    init_params, loss_fn = _toy_problem(4)
    with pytest.raises(ValueError, match="error-feedback"):
        dsgd.make_dsgd_round(loss_fn, make_optimizer("sgd", 1e-2), 2,
                             wire="int8_ef")


def test_unmatched_rows_stay_exact_in_dense_mix_under_int8():
    """A random matching leaves unmatched agents with identity rows in W;
    those agents communicate nothing, so the dense mix must restore their
    params (and EF residual) exactly — only matched rows carry
    quantization. Panel and tree paths agree on which rows are exact."""
    from repro.core import gossip
    m = 4
    x = _panel(m, 24, 8)
    pan = {"float32": x}
    # agents 0, 1 matched; 2, 3 unmatched (identity rows)
    W = jnp.asarray([[0.5, 0.5, 0, 0], [0.5, 0.5, 0, 0],
                     [0, 0, 1.0, 0], [0, 0, 0, 1.0]], jnp.float32)
    key = jax.random.PRNGKey(4)
    spec = panel_mod.with_wire(panel_mod.make_spec({"w": x}), "int8")
    out = panel_mod.mix_dense(pan, W, spec=spec, key=key)["float32"]
    np.testing.assert_array_equal(np.asarray(out[2:]), np.asarray(x[2:]))
    assert bool(jnp.any(out[:2] != x[:2]))
    # EF residual of unmatched rows passes through untouched
    spec_ef = panel_mod.with_wire(panel_mod.make_spec({"w": x}), "int8_ef")
    e0 = {"float32": jnp.full_like(x, 0.01)}
    _, e1 = panel_mod.mix_dense(pan, W, spec=spec_ef, key=key, err=e0)
    np.testing.assert_array_equal(np.asarray(e1["float32"][2:]),
                                  np.asarray(e0["float32"][2:]))
    # tree path: same exact-row semantics (leaf-wise scales elsewhere)
    t = gossip.mix_dense_tree({"w": x}, W, wire="int8", key=key)
    np.testing.assert_array_equal(np.asarray(t["w"][2:]),
                                  np.asarray(x[2:]))


def test_idle_pairwise_rows_stay_exact_under_int8():
    """partner[k] == k idles agent k — nothing travels its wire, so no
    codec may touch its row: params (and the EF residual) stay bit-exact
    while matched rows mix quantized payloads. Panel and tree paths
    agree."""
    from repro.core import gossip
    m = 4
    x = _panel(m, 24, 6)
    pan = {"float32": x}
    spec = panel_mod.with_wire(
        panel_mod.make_spec({"w": x}), "int8")
    partner = jnp.asarray([0, 1, 3, 2], jnp.int32)  # 0, 1 idle; 2<->3
    key = jax.random.PRNGKey(2)
    mixed = panel_mod.mix_pairwise(pan, partner, spec=spec, key=key)
    out = mixed["float32"]
    np.testing.assert_array_equal(np.asarray(out[:2]), np.asarray(x[:2]))
    assert bool(jnp.any(out[2:] != x[2:]))
    # EF residual of idle rows passes through untouched
    e0 = {"float32": jnp.full_like(x, 0.01)}
    spec_ef = panel_mod.with_wire(panel_mod.make_spec({"w": x}), "int8_ef")
    _, e1 = panel_mod.mix_pairwise(pan, partner, spec=spec_ef, key=key,
                                   err=e0)
    np.testing.assert_array_equal(np.asarray(e1["float32"][:2]),
                                  np.asarray(e0["float32"][:2]))
    assert bool(jnp.any(e1["float32"][2:] != e0["float32"][2:]))
    # tree path mirrors the panel semantics (leaf-wise scales for matched
    # rows, bit-exact idle rows)
    t = gossip.mix_pairwise_tree({"w": x}, partner, wire="int8", key=key)
    np.testing.assert_array_equal(np.asarray(t["w"][:2]),
                                  np.asarray(x[:2]))


def test_plain_int8_does_not_update_residual():
    """The non-EF int8 codec must pass a supplied residual through
    untouched (error_feedback=False means no accumulation semantics)."""
    x = _panel(3, 16, 2)
    e0 = jnp.ones_like(x) * 0.01
    k = jax.random.PRNGKey(0)
    xhat, _, e1 = wire_mod.get_codec("int8").encode(x, key=k, err=e0)
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))
    # ... and must not fold it into the payload either (re-injecting the
    # same bias every round)
    xhat_no_err, _, _ = wire_mod.get_codec("int8").encode(x, key=k)
    np.testing.assert_array_equal(np.asarray(xhat),
                                  np.asarray(xhat_no_err))


def test_ef_codec_requires_residual():
    """An error-feedback codec with no residual must raise, not silently
    degrade to plain int8 (dropping the accumulated correction)."""
    x = _panel(2, 8, 3)
    with pytest.raises(ValueError, match="err"):
        wire_mod.get_codec("int8_ef").encode(x, key=jax.random.PRNGKey(0))
    spec = panel_mod.with_wire(panel_mod.make_spec({"w": x}), "int8_ef")
    with pytest.raises(ValueError, match="err"):
        panel_mod.global_merge({"float32": x}, spec=spec,
                               key=jax.random.PRNGKey(0))


def test_tree_driver_idle_rounds_bitexact_under_int8():
    """The tree-state round driver must skip the codec on W == I rounds
    (mirrors the panel engine's idle guard): an int8 run over idle-only
    rounds is bit-identical to the uncompressed run."""
    from repro.core import topology  # noqa: F401  (parity with panel test)
    m, H, dim, classes = 4, 2, 10, 3
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("adamw", 1e-2)
    _, (bx, by) = _segment_inputs(2, H, m, dim, classes)
    W = jnp.eye(m, dtype=jnp.float32)

    def run(wire):
        state = dsgd.init_state(init_params, opt, m, jax.random.PRNGKey(0))
        round_fn = jax.jit(dsgd.make_dsgd_round(loss_fn, opt, H,
                                                wire=wire))
        for t in range(2):
            state, _ = round_fn(state, (bx[t], by[t]), W,
                                jax.random.PRNGKey(t))
        return state

    a, b = run(None), run("int8")
    for x, y in zip(jax.tree.leaves(a["params"]),
                    jax.tree.leaves(b["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------- idle rounds, segment


@pytest.mark.parametrize("codec", ALL_CODECS)
def test_idle_segment_bitexact_under_every_codec(codec):
    """A schedule of W = I rounds communicates nothing, so EVERY codec
    must leave the engine bit-identical to the no-policy run: the idle
    branch skips the codec, and the wire-key fold_in must not perturb the
    local-step rng schedule. The EF residual stays exactly zero."""
    m, H, S, dim, classes = 4, 2, 3, 10, 3
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("adamw", 1e-2)
    _, (bx, by) = _segment_inputs(S, H, m, dim, classes)
    Ws = jnp.broadcast_to(jnp.eye(m, dtype=jnp.float32), (S, m, m))

    def run(wire):
        pstate, spec = dsgd.init_panel_state(
            init_params, opt, m, jax.random.PRNGKey(0), wire=wire)
        seg_fn = dsgd.make_panel_segment(loss_fn, opt, H, spec)
        return seg_fn(pstate, (bx, by), Ws, jax.random.PRNGKey(1))

    base, base_mets = run(None)
    ps, mets = run(codec)
    for a, b in zip(jax.tree.leaves(base["panel"]),
                    jax.tree.leaves(ps["panel"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(base_mets["loss"]),
                                  np.asarray(mets["loss"]))
    np.testing.assert_array_equal(np.asarray(base_mets["consensus"]),
                                  np.asarray(mets["consensus"]))
    if codec == "int8_ef":
        assert all(bool(jnp.all(v == 0.0))
                   for v in ps["wire_err"].values())


def test_int8_ef_segment_runs_and_merges():
    """Communicating segment under int8_ef: the residual panel becomes
    nonzero after a gossip round, and the final fully-connected round
    still collapses consensus (merge through the codec)."""
    m, H, dim, classes = 4, 2, 10, 3
    from repro.core import topology
    init_params, loss_fn = _toy_problem(m, dim, classes)
    opt = make_optimizer("sgd", 1e-2)
    pstate, spec = dsgd.init_panel_state(
        init_params, opt, m, jax.random.PRNGKey(0), wire="int8_ef")
    seg_fn = dsgd.make_panel_segment(loss_fn, opt, H, spec)
    rng = np.random.default_rng(0)
    Ws = jnp.asarray(np.stack([topology.random_matching(m, 1.0, rng),
                               topology.fully_connected(m)]), jnp.float32)
    bx = jnp.asarray(rng.normal(size=(2, H, m, 8, dim)).astype(np.float32))
    by = jnp.asarray(rng.integers(0, classes,
                                  size=(2, H, m, 8)).astype(np.int32))
    ps, mets = seg_fn(pstate, (bx, by), Ws, jax.random.PRNGKey(1))
    assert any(bool(jnp.any(v != 0.0)) for v in ps["wire_err"].values())
    # int8 merge is approximate: rows agree to within a quantization step
    tree = panel_mod.from_panel(ps["panel"], spec)
    for x in jax.tree.leaves(tree):
        np.testing.assert_allclose(np.asarray(x[0]), np.asarray(x[-1]),
                                   atol=0.05)


# --------------------------------------- folded consensus mean


def test_mix_dense_mean_rows_bitexact_and_mean_matches():
    """The 1^T/m-augmented matmul must leave the first m rows bit-identical
    to plain mix_dense, and its extra row must equal the column mean of the
    mixed panel for a doubly-stochastic W."""
    m, d = 8, 96
    pan = {"float32": _panel(m, d, 5)}
    rng = np.random.default_rng(5)
    W = np.zeros((m, m))
    for _ in range(4):
        W[np.arange(m), rng.permutation(m)] += 0.25
    W = jnp.asarray(W, jnp.float32)
    mixed, mean, _ = panel_mod.mix_dense_mean(pan, W)
    plain = panel_mod.mix_dense(pan, W)
    np.testing.assert_array_equal(np.asarray(mixed["float32"]),
                                  np.asarray(plain["float32"]))
    np.testing.assert_allclose(
        np.asarray(mean["float32"]),
        np.mean(np.asarray(mixed["float32"], np.float64), axis=0),
        atol=1e-6)
    np.testing.assert_allclose(
        float(panel_mod.consensus_from_mean(mixed, mean)),
        float(panel_mod.consensus_distance(mixed)), rtol=1e-5)
    # Pallas fold path on a non-f32 group: the kernel stores its output
    # in the payload dtype, but the mean must come back f32-precise (not
    # rounded through the extra bf16 output row)
    pan_bf = {"bfloat16": pan["float32"].astype(jnp.bfloat16)}
    _, mean_bf, _ = panel_mod.mix_dense_mean(pan_bf, W, use_pallas=True)
    np.testing.assert_allclose(
        np.asarray(mean_bf["bfloat16"]),
        np.mean(np.asarray(pan_bf["bfloat16"].astype(jnp.float32),
                           np.float64), axis=0), atol=1e-5)


# --------------------------------------------- codec-aware wire bytes


def test_wire_bytes_codec_aware():
    m, d = 4, 4096
    tree = {"w": jnp.zeros((m, d), jnp.float32)}
    spec = panel_mod.make_spec(tree)
    assert spec.wire_bytes == 4 * d                       # f32 identity
    assert panel_mod.with_wire(spec, "bf16").wire_bytes == 2 * d
    i8 = panel_mod.with_wire(spec, "int8").wire_bytes
    assert i8 == d + 4                                    # payload + scale
    assert spec.wire_bytes / i8 >= 3.5                    # acceptance bar
    assert panel_mod.with_wire(spec, "int8_ef").wire_bytes == i8


def test_wire_bytes_payload_vs_total_formulas():
    """Regression pinning the payload/total split: ``wire_payload_bytes``
    counts the quantized values alone, ``wire_total_bytes`` adds
    scale/index metadata (per-row int8 scale, per-128-column int4 group
    scales, packed top-k indices), and ``wire_bytes`` stays the total
    (the pre-split name under-distinguished the two). Odd width pins the
    nibble/packing ceilings."""
    d = 4097  # odd AND not a multiple of the int4 scale group
    tree = {"w": jnp.zeros((2, d), jnp.float32)}
    spec = panel_mod.make_spec(tree)

    def bytes_of(name):
        s = panel_mod.with_wire(spec, name)
        return s.wire_payload_bytes, s.wire_total_bytes

    assert bytes_of("f32") == (4 * d, 4 * d)
    assert bytes_of("bf16") == (2 * d, 2 * d)
    assert bytes_of("int8") == (d, d + 4)
    assert bytes_of("int8_ef") == (d, d + 4)
    groups = -(-d // 128)
    assert bytes_of("int4") == ((d + 1) // 2,
                                (d + 1) // 2 + 4 * groups)
    assert bytes_of("int4_ef") == bytes_of("int4")
    codec = wire_mod.get_codec("topk")
    k = codec.k_of(d)
    assert k == int(d * codec.density)
    assert codec.idx_bytes(d) == 2                        # 13-bit indices
    assert bytes_of("topk") == (4 * k, 4 * k + 2 * k)
    # the headline ratios on the VALUES payload: int4 is 8x f32, topk is
    # (1/density)x f32 before index overhead
    assert 4 * d / bytes_of("int4")[0] == pytest.approx(8.0, rel=1e-3)
    assert 4 * d / bytes_of("topk")[0] == pytest.approx(
        1.0 / codec.density, rel=1e-2)


def test_wire_policy_per_group_and_validation():
    m = 2
    tree = {"emb": jnp.zeros((m, 64), jnp.bfloat16),
            "w": jnp.zeros((m, 128), jnp.float32)}
    spec = panel_mod.make_spec(tree)
    mixed = panel_mod.with_wire(spec, {"float32": "int8",
                                       "bfloat16": "bf16"})
    assert mixed.wire_of("float32") == "int8"
    assert mixed.wire_of("bfloat16") == "bf16"
    assert mixed.wire_bytes == (128 + 4) + 64 * 2
    # unlisted groups fall back to the f32 identity (storage bytes)
    part = panel_mod.with_wire(spec, {"float32": "int8"})
    assert part.wire_of("bfloat16") == "f32"
    assert part.wire_bytes == (128 + 4) + 64 * 2  # bf16 storage = 2B
    with pytest.raises(ValueError, match="unknown wire codec"):
        panel_mod.with_wire(spec, "int7")
    with pytest.raises(ValueError, match="unknown dtype groups"):
        panel_mod.with_wire(spec, {"fp32": "int8"})  # typo'd group key
    with pytest.raises(ValueError, match="not both"):
        panel_mod.mix_dense(panel_mod.to_panel(tree, mixed),
                            jnp.eye(m), wire_dtype=jnp.bfloat16,
                            spec=mixed)


# ------------------------------------------------- kernel bit-parity


@pytest.mark.parametrize("m,D,block_d", [(4, 64, 32), (8, 333, 128),
                                         (3, 1000, 512)])
@pytest.mark.parametrize("stochastic", [False, True])
def test_wire_quant_kernels_match_ref(m, D, block_d, stochastic):
    """Pallas quantize/dequantize (interpret mode) are bit-identical to
    the kernels/ref.py oracles, including non-divisible D (padded tails)
    and with the same uniform draws."""
    x = _panel(m, D, seed=m * 1000 + D)
    scale = ref_mod.int8_scale_ref(x)
    u = (jax.random.uniform(jax.random.PRNGKey(0), x.shape, jnp.float32)
         if stochastic else None)
    q_k, s_k = wire_quant.quantize_int8_panel(x, scale, u,
                                              block_d=block_d)
    q_r = ref_mod.quantize_int8_ref(x, scale, u)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(scale))
    deq_k = wire_quant.dequantize_int8_panel(q_k, scale, block_d=block_d)
    np.testing.assert_array_equal(
        np.asarray(deq_k), np.asarray(ref_mod.dequantize_int8_ref(q_r,
                                                                  scale)))


def test_codec_pallas_path_matches_xla_path():
    """Int8Codec(use_pallas=True) must produce the same bits as the XLA
    ref path given the same key (the kernels share the uniform input)."""
    x = _panel(5, 200, 9)
    key = jax.random.PRNGKey(11)
    codec = wire_mod.get_codec("int8")
    a, _, _ = codec.encode(x, key=key, use_pallas=False)
    b, _, _ = codec.encode(x, key=key, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("m,D,group,block_d",
                         [(4, 64, 32, 64), (3, 333, 128, 256),
                          (5, 1000, 128, 384)])
@pytest.mark.parametrize("stochastic", [False, True])
def test_int4_kernels_match_ref(m, D, group, block_d, stochastic):
    """Pallas int4 quantize/pack/unpack/dequantize (interpret mode) are
    bit-identical to the kernels/ref.py oracles, including non-divisible
    D (padded tails, partial scale groups, odd nibble tails) and with
    the same uniform draws; pack -> unpack is an exact inverse."""
    x = _panel(m, D, seed=m * 100 + D)
    scale = ref_mod.int4_group_scale_ref(x, group)
    assert scale.shape == (m, -(-D // group))
    u = (jax.random.uniform(jax.random.PRNGKey(1), x.shape, jnp.float32)
         if stochastic else None)
    q_k, s_k = wire_quant.quantize_int4_panel(x, scale, u, group=group,
                                              block_d=block_d)
    q_r = ref_mod.quantize_int4_ref(x, scale, u, group)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(scale))
    p_k = wire_quant.pack_int4_panel(q_r, block_d=block_d)
    p_r = ref_mod.pack_int4_ref(q_r)
    assert p_r.shape == (m, (D + 1) // 2) and p_r.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    uq_k = wire_quant.unpack_int4_panel(p_r, D, block_d=block_d)
    uq_r = ref_mod.unpack_int4_ref(p_r, D)
    np.testing.assert_array_equal(np.asarray(uq_k), np.asarray(uq_r))
    np.testing.assert_array_equal(np.asarray(uq_r), np.asarray(q_r))
    d_k = wire_quant.dequantize_int4_panel(q_r, scale, group=group,
                                           block_d=block_d)
    np.testing.assert_array_equal(
        np.asarray(d_k),
        np.asarray(ref_mod.dequantize_int4_ref(q_r, scale, group)))


@pytest.mark.parametrize("m,D,block_d", [(4, 64, 32), (8, 333, 128),
                                         (3, 1000, 512)])
def test_sparsify_topk_kernel_matches_ref(m, D, block_d):
    """Pallas top-k threshold sparsifier (interpret mode) is
    bit-identical to sparsify_topk_ref, keeps exactly k survivors per
    row for tie-free inputs, and the threshold is the k-th largest
    magnitude (computed outside the kernel like the int8 scales)."""
    x = _panel(m, D, seed=m * 7 + D)
    k = max(1, D // 8)
    thresh = ref_mod.topk_threshold_ref(x, k)
    s_k = wire_quant.sparsify_topk_panel(x, thresh, block_d=block_d)
    s_r = ref_mod.sparsify_topk_ref(x, thresh)
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
    assert int(jnp.sum(s_r != 0.0)) == m * k
    np.testing.assert_array_equal(
        np.asarray(wire_quant.sparsify_topk_panel(x, k=k,
                                                  block_d=block_d)),
        np.asarray(s_r))
