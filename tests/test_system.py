"""End-to-end behaviour tests for the paper's system.

Validates (at CPU scale) the paper's three headline claims:
  1. a single final global merging massively improves global test accuracy
     under sparse gossip + non-IID data;
  2. local-only training is NOT mergeable (merged ~ chance);
  3. the merged/counterfactual model beats local models throughout training.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus, dsgd, gossip
from repro.core.schedule import make_schedule
from repro.data.synthetic import SyntheticClassification, make_agent_batches
from repro.optim import make_optimizer

M = 8


def make_problem(seed=0):
    """Shared with benchmarks: depth-2 ReLU MLP, Dirichlet(0.1) non-IID."""
    from benchmarks.common import make_problem as mp
    return mp(seed=seed)


def run(schedule_name, rounds=80, seed=0, **kw):
    ds, parts, init_params, loss_fn, acc = make_problem(seed)
    opt = make_optimizer("sgd", 0.1, weight_decay=0.0)
    state = dsgd.init_state(init_params, opt, M, jax.random.PRNGKey(seed))
    step = jax.jit(dsgd.make_dsgd_step(loss_fn, opt))
    sched = make_schedule(schedule_name, M, rounds, prob=0.2, seed=seed, **kw)
    rng_np = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed + 1)
    monitor = {}
    for t in range(rounds):
        W = sched.mixing_matrix(t, monitor)
        xb, yb = make_agent_batches(ds, parts, 32, rng_np)
        key, k = jax.random.split(key)
        state, mets = step(state, (jnp.asarray(xb), jnp.asarray(yb)),
                           jnp.asarray(W, jnp.float32), k)
        monitor = {"grad_norm": float(mets["grad_norm"]),
                   "consensus": float(mets["consensus"])}
    local = float(jnp.mean(jax.vmap(acc)(state["params"])))
    merged = float(acc(gossip.merged_model(state["params"])))
    return state, local, merged, acc


def test_final_merge_recovers_performance():
    """Paper Fig.1: single global merging >> local models under sparse
    gossip + alpha=0.1 heterogeneity."""
    state, local, merged, acc = run("constant")
    assert merged > local + 0.05, (local, merged)
    assert merged > 0.30


def test_local_only_not_mergeable():
    """Paper Fig.2c orange: no communication => merging does NOT help."""
    _, local, merged, _ = run("local")
    # merged model of fully-local training stays near chance (10 classes)
    assert merged < 0.25, merged


def test_mergeability_requires_nonzero_communication():
    _, local_c, merged_c, _ = run("constant", rounds=60)
    _, local_l, merged_l, _ = run("local", rounds=60)
    # limited-but-nonzero communication enables mergeability
    assert merged_c - local_c > merged_l - local_l + 0.03


def test_final_merge_schedule_collapses_consensus():
    state, local, merged, _ = run("final_merge", rounds=40)
    xi = float(consensus.consensus_distance(state["params"]))
    assert xi < 1e-3  # all agents identical after the merge
    assert abs(local - merged) < 1e-5


def test_adaptive_schedule_runs_and_communicates_late():
    ds, parts, init_params, loss_fn, acc = make_problem()
    opt = make_optimizer("sgd", 0.1, weight_decay=0.0)
    state = dsgd.init_state(init_params, opt, M, jax.random.PRNGKey(0))
    step = jax.jit(dsgd.make_dsgd_step(loss_fn, opt))
    sched = make_schedule("adaptive", M, 60, kappa=2.0, seed=0)
    rng_np = np.random.default_rng(0)
    key = jax.random.PRNGKey(1)
    monitor = {}
    for t in range(60):
        W = sched.mixing_matrix(t, monitor)
        xb, yb = make_agent_batches(ds, parts, 32, rng_np)
        key, k = jax.random.split(key)
        state, mets = step(state, (jnp.asarray(xb), jnp.asarray(yb)),
                           jnp.asarray(W, jnp.float32), k)
        monitor = {"grad_norm": float(mets["grad_norm"]),
                   "consensus": float(mets["consensus"])}
    # controller fired at least once and the merged model is decent
    merged = float(acc(gossip.merged_model(state["params"])))
    assert merged > 0.25


def test_counterfactual_eval_does_not_modify_state():
    ds, parts, init_params, loss_fn, acc = make_problem()
    opt = make_optimizer("sgd", 0.1)
    state = dsgd.init_state(init_params, opt, M, jax.random.PRNGKey(0))
    before = jax.tree.map(lambda x: x.copy(), state["params"])
    from repro.core.merge import counterfactual_eval
    _ = counterfactual_eval(acc, state["params"])
    after = state["params"]
    assert all(bool(jnp.all(a == b)) for a, b in zip(
        jax.tree.leaves(before), jax.tree.leaves(after)))
