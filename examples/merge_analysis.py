"""Mergeability analysis (paper Fig. 2c + §4.3): tracks the counterfactual
globally-averaged model during training under (a) sparse gossip and (b) zero
communication, printing the merged-vs-local accuracy gap and the consensus
distance Xi_t — with communication the merged model leads throughout; with
no communication it stays near chance.

Run:  PYTHONPATH=src python examples/merge_analysis.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.common import run_schedule  # noqa: E402


def main():
    for name, label in (("constant", "sparse gossip R=0.2"),
                        ("local", "no communication")):
        out = run_schedule(name, rounds=80, seed=1, track=True)
        c = out["curves"]
        print(f"== {label} ==")
        print("  round  local  merged(counterfactual)  Xi")
        steps = list(range(0, 80, 5)) + [79]
        for i, (l, m, x) in enumerate(zip(c["local"], c["merged"], c["xi"])):
            print(f"  {steps[i]:5d}  {l:.3f}  {m:.3f}                 {x:8.2f}")
        print(f"  final merged-local gap: {out['merged']-out['local']:+.3f}")


if __name__ == "__main__":
    main()
