"""Quickstart: the paper's effect in ~60 seconds on CPU.

Trains 8 decentralized agents on a non-IID (Dirichlet alpha=0.1) synthetic
classification task with sparse random gossip (R=0.2), then applies ONE
global merging — and prints the local vs merged global test accuracy, plus
the no-communication ablation showing merging only works with (limited but)
nonzero communication.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.common import run_schedule  # noqa: E402


def main():
    print("== decentralized SGD, 8 agents, Dirichlet(0.1), R=0.2 gossip ==")
    out = run_schedule("constant", rounds=80, seed=0)
    print(f"  local models (avg global acc) : {out['local']:.3f}")
    print(f"  after ONE global merging      : {out['merged']:.3f}")
    print(f"  merge gain                    : {out['merged']-out['local']:+.3f}")
    print(f"  communication spent           : {out['comm_P']:.1f} x model size")

    print("== ablation: zero communication ==")
    out0 = run_schedule("local", rounds=80, seed=0)
    print(f"  local models                  : {out0['local']:.3f}")
    print(f"  merged model                  : {out0['merged']:.3f}  "
          "(no mergeability without communication)")


if __name__ == "__main__":
    main()
