"""End-to-end driver: decentralized LM pretraining with a final global merge.

Thin wrapper over ``repro.launch.train`` that (a) defaults to a ~100M-param
olmo-family model for a few hundred rounds — the full-fat configuration used
on a pod — and (b) offers ``--tiny`` for a CPU-feasible run of the same code
path. The merged artifact can be served with examples/serve_merged.py.

Pod-scale (default):   ~100M params, 300 rounds x 4 local steps.
CPU (this container):  python examples/train_decentralized.py --tiny
"""
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def main():
    tiny = "--tiny" in sys.argv
    argv = [a for a in sys.argv[1:] if a != "--tiny"]
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "olmo-1b",
           "--schedule", "final_merge",
           "--save-merged", "results/merged_olmo.msgpack"]
    if tiny:
        cmd += ["--preset", "cpu", "--agents", "4", "--rounds", "12",
                "--local-steps", "2", "--batch", "4", "--seq", "64"]
    else:
        # ~100M-parameter variant: olmo-1b trimmed to 8 layers / d=1024,
        # a few hundred rounds. On a pod drop --preset to use the full mesh.
        cmd += ["--preset", "cpu", "--agents", "8", "--rounds", "300",
                "--local-steps", "4", "--batch", "8", "--seq", "128"]
    cmd += argv
    env = {"PYTHONPATH": str(ROOT / "src")}
    import os
    env = {**os.environ, **env}
    raise SystemExit(subprocess.call(cmd, cwd=ROOT, env=env))


if __name__ == "__main__":
    main()
