"""Serve the merged model produced by decentralized training.

Restores the single-model artifact written by train_decentralized.py
(``--save-merged``) and streams heterogeneous requests through the
continuous-batching serving engine (4 decode slots, 8 requests).

Run:  PYTHONPATH=src python examples/serve_merged.py [--restore path]
"""
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def main():
    cmd = [sys.executable, "-m", "repro.launch.serve", "--arch", "olmo-1b",
           "--preset", "cpu", "--concurrency", "4", "--requests", "8",
           "--prompt-len", "32", "--max-new", "16"]
    ckpt = ROOT / "results/merged_olmo.msgpack"
    if ckpt.exists() and "--restore" not in sys.argv:
        cmd += ["--restore", str(ckpt)]
    cmd += sys.argv[1:]
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src")}
    raise SystemExit(subprocess.call(cmd, cwd=ROOT, env=env))


if __name__ == "__main__":
    main()
