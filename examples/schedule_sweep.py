"""Window-allocation sweep (paper Fig. 2a/2b): place ONE fully-connected
communication window at different phases of training, print final global
accuracy per placement — late placement should win.

Run:  PYTHONPATH=src python examples/schedule_sweep.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.common import run_schedule  # noqa: E402


def main():
    rounds, nwin = 80, 5
    win = rounds // nwin
    print(f"{rounds} rounds, one AllReduce window of {win} rounds, "
          "sparse R=0.2 gossip elsewhere")
    results = []
    for i in range(nwin):
        out = run_schedule("windowed", rounds=rounds, seed=0,
                           start=i * win, end=(i + 1) * win)
        results.append(out)
        bar = "#" * int(out["merged"] * 40)
        print(f"  window [{i*win:3d},{(i+1)*win:3d}) merged_acc="
              f"{out['merged']:.3f} {bar}")
    gain = results[-1]["merged"] - results[0]["merged"]
    print(f"late-window minus early-window: {gain:+.3f} "
          "(paper: allocate communication late)")


if __name__ == "__main__":
    main()
