"""Serve a (merged) model: batched prefill + decode.

CPU demo: ``PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b
--preset cpu --batch 4 --prompt-len 32 --max-new 16`` — optionally restoring
the artifact produced by ``launch.train --save-merged``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore
from repro.configs import get_config
from repro.models import build_model
from repro.serving import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--preset", default="cpu", choices=["cpu", "pod"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--restore", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "cpu":
        cfg = cfg.reduced(d_model=128, layers=2, vocab=256)
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init_params(key)
    if args.restore:
        params = restore(args.restore, params)
        print("restored", args.restore)

    B, S = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.mm_prefix > 0:
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.mm_prefix, cfg.d_model))
    if cfg.encoder_layers:
        batch["frame_embeds"] = jax.random.normal(key, (B, S, cfg.d_model))

    t0 = time.time()
    out = generate(model, params, batch, args.max_new,
                   temperature=args.temperature, rng=key)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({B * args.max_new / dt:.1f} tok/s)")
    print(out[:2])


if __name__ == "__main__":
    main()
