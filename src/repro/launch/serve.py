"""Serve a (merged) model through the continuous-batching engine.

CPU demo — heterogeneous-length requests streaming through slotted decode:

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --preset cpu \
        --concurrency 4 --requests 8 --max-new 16 [--stream]

optionally restoring the artifact produced by ``launch.train
--save-merged`` via ``--restore``. ``--one-shot`` runs the plain static
batched :func:`repro.serving.generate` path instead.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.checkpoint import restore
from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServingEngine, generate


def _request_inputs(cfg, i, S, k_prompt, k_mm, k_frames):
    """Prompt + multimodal extras for demo request ``i`` (independent PRNG
    streams, folded per request)."""
    toks = jax.random.randint(jax.random.fold_in(k_prompt, i), (S,), 0,
                              cfg.vocab_size)
    extras = {}
    if cfg.mm_prefix > 0:
        extras["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(k_mm, i), (cfg.mm_prefix, cfg.d_model))
    if cfg.encoder_layers:
        extras["frame_embeds"] = jax.random.normal(
            jax.random.fold_in(k_frames, i), (S, cfg.d_model))
    return np.asarray(toks, np.int32), extras


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--preset", default="cpu", choices=["cpu", "pod"])
    ap.add_argument("--concurrency", type=int, default=4,
                    help="decode slots held live at once")
    ap.add_argument("--requests", type=int, default=8,
                    help="demo requests fed through the engine")
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="longest demo prompt (half of them use len//2)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0,
                    help="slot length; 0 = prompt+mm_prefix+max_new")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="stop token (>=0 enables early slot retirement)")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as slots emit them")
    ap.add_argument("--one-shot", action="store_true",
                    help="legacy path: one static generate() batch")
    ap.add_argument("--restore", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--events", default="",
                    help="typed request-lifecycle JSONL event stream "
                         "(submit/admit/retire + serve_start/serve_end), "
                         "schema-validated at emit time")
    ap.add_argument("--profile", default="",
                    help="capture a jax profiler trace of the serving "
                         "loop into this logdir")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "cpu":
        cfg = cfg.reduced(d_model=128, layers=2, vocab=256)
    model = build_model(cfg)
    # independent PRNG streams: params / prompts / patch embeds / frame
    # embeds / sampling (the seed path used to reuse ONE key for all five)
    k_params, k_prompt, k_mm, k_frames, k_sample = jax.random.split(
        jax.random.PRNGKey(args.seed), 5)
    params = model.init_params(k_params)
    if args.restore:
        params = restore(args.restore, params)
        print("restored", args.restore)
    eos_id = args.eos_id if args.eos_id >= 0 else None

    if args.one_shot:
        B, S = args.requests, args.prompt_len
        batch = {"tokens": jnp.stack([jnp.asarray(_request_inputs(
            cfg, i, S, k_prompt, k_mm, k_frames)[0]) for i in range(B)])}
        if cfg.mm_prefix > 0:
            batch["patch_embeds"] = jax.random.normal(
                k_mm, (B, cfg.mm_prefix, cfg.d_model))
        if cfg.encoder_layers:
            batch["frame_embeds"] = jax.random.normal(
                k_frames, (B, S, cfg.d_model))
        t0 = time.time()
        out = generate(model, params, batch, args.max_new,
                       temperature=args.temperature, rng=k_sample,
                       eos_id=eos_id)
        dt = time.time() - t0
        print(f"generated {out.shape} in {dt:.2f}s "
              f"({B * args.max_new / dt:.1f} tok/s)")
        print(out[:2])
        return

    # two prompt-length buckets -> exactly two prefill compiles
    lengths = [args.prompt_len, max(1, args.prompt_len // 2)]
    max_len = args.max_len or (args.prompt_len + max(0, cfg.mm_prefix)
                               + args.max_new)
    serve_cfg = {k: vars(args)[k] for k in (
        "arch", "preset", "concurrency", "requests", "prompt_len",
        "max_new", "temperature", "eos_id", "seed")}
    log = telemetry.EventLog(args.events or None,
                             run_id=telemetry.make_run_id(serve_cfg))
    log.emit("serve_start", run_id=log.run_id,
             schema=telemetry.SCHEMA_VERSION, config=serve_cfg)
    engine = ServingEngine(model, params, max_concurrency=args.concurrency,
                           max_len=max_len, eos_id=eos_id,
                           temperature=args.temperature, rng=k_sample,
                           events=log)
    reqs = []
    for i in range(args.requests):
        toks, extras = _request_inputs(cfg, i, lengths[i % len(lengths)],
                                       k_prompt, k_mm, k_frames)
        reqs.append(Request(rid=i, tokens=toks, max_new=args.max_new,
                            extras=extras))
    stream_cb = ((lambda rid, t: print(f"  req {rid}: {t}"))
                 if args.stream else None)
    prof = telemetry.profile_trace(args.profile,
                                   enabled=bool(args.profile)).start()
    t0 = time.time()
    out = engine.serve(reqs, stream=stream_cb)
    dt = time.time() - t0
    prof.stop()
    n_tok = sum(len(v) for v in out.values())
    snap = engine.snapshot()
    print(telemetry.format_event(log.emit(
        "serve_end", requests=len(out), tokens=n_tok,
        ticks=snap["ticks"], occupancy=snap["occupancy"])), flush=True)
    lat = snap["latency"]
    print(f"  {n_tok / dt:.1f} tok/s | "
          f"ttft p50/p99 {lat['ttft_s']['p50_s'] * 1e3:.1f}/"
          f"{lat['ttft_s']['p99_s'] * 1e3:.1f} ms | queue p50 "
          f"{lat['queue_wait_s']['p50_s'] * 1e3:.1f} ms | decode step "
          f"p50 {lat['decode_step_s']['p50_s'] * 1e3:.1f} ms | per-token "
          f"p50 {lat['per_token_s']['p50_s'] * 1e3:.1f} ms")
    log.emit_op("serve_latency", **{k: lat[k] for k in lat})
    log.close()
    for rid in sorted(out)[:2]:
        print(f"req {rid}:", out[rid])
    if args.events:
        print(f"events: {args.events}")


if __name__ == "__main__":
    main()
