"""Production meshes.

``make_production_mesh`` is the mandated serving/dry-run mesh: one v5e pod
(16x16 = 256 chips, axes ("data","model")) or two pods (2x16x16 = 512,
axes ("pod","data","model")).

``make_training_mesh`` re-views the same chips for decentralized training:
axes ("pod","agent","fsdp","model") where agent x fsdp = 16 (the pod's data
dimension). Each decentralized agent owns an fsdp x model slice and holds a
full model replica (FSDP-sharded); the agent (+pod) axes are the paper's
communication graph. Functions, not module constants — importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax
import numpy as np

MODEL_AXIS = 16
DATA_AXIS = 16
PODS = 2


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_training_mesh(agents_per_pod: int, *, multi_pod: bool = False):
    if DATA_AXIS % agents_per_pod:
        raise ValueError(f"agents_per_pod={agents_per_pod} must divide 16")
    fsdp = DATA_AXIS // agents_per_pod
    pods = PODS if multi_pod else 1
    shape = (pods, agents_per_pod, fsdp, MODEL_AXIS)
    n = int(np.prod(shape))
    return jax.make_mesh(shape, ("pod", "agent", "fsdp", "model"),
                         devices=jax.devices()[:n])


def num_agents(mesh) -> int:
    m = 1
    for ax in ("pod", "agent"):
        if ax in mesh.axis_names:
            m *= mesh.shape[ax]
    return m


def make_debug_mesh(agents: int = 2, fsdp: int = 1, model: int = 2):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    n = agents * fsdp * model
    return jax.make_mesh((1, agents, fsdp, model),
                         ("pod", "agent", "fsdp", "model"),
                         devices=jax.devices()[:n])
