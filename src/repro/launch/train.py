"""Decentralized LM training launcher.

Runs the paper's algorithm end-to-end on real data (synthetic non-IID token
streams): per-agent local AdamW/SGD steps + scheduled gossip communication +
(optionally) the single final global merging. On this CPU container use
``--preset cpu`` (tiny model, 1-device mesh); on a pod the same script drives
the production mesh.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --preset cpu \
      --rounds 20 --schedule final_merge
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.configs import get_config
from repro.core import dsgd
from repro.core.gossip import merged_model
from repro.core.schedule import make_schedule
from repro.data.synthetic import SyntheticLM, make_agent_lm_batches
from repro.models import build_model
from repro.optim import make_optimizer


def build_cpu_preset(cfg, agents):
    cfg = cfg.reduced(d_model=128, layers=2, vocab=256)
    cfg = cfg.replace(dist=dataclasses.replace(cfg.dist,
                                               agents_per_pod=agents))
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--preset", default="cpu", choices=["cpu", "pod"])
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--schedule", default="final_merge",
                    choices=["constant", "local", "windowed", "final_merge",
                             "periodic", "adaptive"])
    ap.add_argument("--window-start", type=int, default=0)
    ap.add_argument("--window-end", type=int, default=0)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--alpha", type=float, default=0.1,
                    help="Dirichlet heterogeneity")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="results/train")
    ap.add_argument("--save-merged", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "cpu":
        cfg = build_cpu_preset(cfg, args.agents)
    m = args.agents
    model = build_model(cfg)
    opt = make_optimizer(args.optimizer, args.lr, weight_decay=5e-4,
                         total_steps=args.rounds * args.local_steps)

    key = jax.random.PRNGKey(args.seed)
    state = dsgd.init_state(model.init_params, opt, m, key)

    lm = SyntheticLM(vocab=cfg.vocab_size, num_domains=8, seed=args.seed)
    mixtures = lm.domain_mixtures(m, args.alpha, seed=args.seed + 1)
    rng_np = np.random.default_rng(args.seed + 2)

    kw = {"prob": 0.2, "seed": args.seed}
    if args.schedule == "windowed":
        kw.update(start=args.window_start, end=args.window_end or
                  args.rounds // 10)
    sched = make_schedule(args.schedule, m, args.rounds, **kw)

    round_fn = jax.jit(dsgd.make_dsgd_round(model.loss_fn, opt,
                                            args.local_steps))

    def eval_loss(params, batches):
        l, _ = model.loss_fn(params, batches, None)
        return l

    eval_merged = jax.jit(lambda p, b: eval_loss(merged_model(p), b))
    eval_local = jax.jit(jax.vmap(eval_loss, in_axes=(0, None)))

    # a fixed GLOBAL eval batch (uniform domain mixture = global dist)
    glob_mix = np.ones(lm.num_domains) / lm.num_domains
    eval_batch = jax.tree.map(jnp.asarray, {
        k: v[0] for k, v in make_agent_lm_batches(
            lm, [glob_mix], 2 * args.batch, args.seq,
            np.random.default_rng(999)).items()})

    history = []
    monitor = {}
    comm_cost = 0.0
    t0 = time.time()
    for t in range(args.rounds):
        W = sched.mixing_matrix(t, monitor)
        comm_cost += sched.round_cost(W)
        hb = make_agent_lm_batches(lm, mixtures, args.batch, args.seq, rng_np)
        # (m, H, b, S) -> (H, m, b, S)
        batches = jax.tree.map(
            lambda x: jnp.asarray(np.repeat(x[None], args.local_steps, 0)),
            hb)
        key, k = jax.random.split(key)
        state, mets = round_fn(state, batches, jnp.asarray(W, jnp.float32), k)
        monitor = {"grad_norm": float(mets["grad_norm"]),
                   "consensus": float(mets["consensus"])}
        merged_l = float(eval_merged(state["params"], eval_batch))
        local_l = float(jnp.mean(eval_local(state["params"], eval_batch)))
        rec = {"round": t, "train_loss": float(mets["loss"]),
               "merged_eval": merged_l, "local_eval": local_l,
               "consensus": monitor["consensus"],
               "grad_norm": monitor["grad_norm"], "comm_cost_P": comm_cost}
        history.append(rec)
        print(f"[{t:4d}] loss={rec['train_loss']:.4f} "
              f"local={local_l:.4f} merged={merged_l:.4f} "
              f"Xi={rec['consensus']:.3f} comm={comm_cost:.1f}P", flush=True)
    print(f"total {time.time()-t0:.1f}s")

    os.makedirs(args.out, exist_ok=True)
    tag = f"{args.arch}_{args.schedule}_a{args.alpha}"
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump({"args": vars(args), "history": history}, f, indent=1)
    if args.save_merged:
        save(args.save_merged, merged_model(state["params"]))
        print("saved merged model to", args.save_merged)


if __name__ == "__main__":
    main()
