"""Decentralized LM training launcher (flat-panel engine).

Runs the paper's algorithm end-to-end on real data (synthetic non-IID token
streams): per-agent local AdamW/SGD steps + scheduled gossip communication +
(optionally) the single final global merging. The training state lives as a
persistent (m, D) parameter panel (core/panel.py); the host loop dispatches
ONE donated, scanned computation per schedule *segment* (``--segment``
rounds) with the segment's mixing matrices precomputed and stacked, H
DISTINCT batches per round (Algorithm 1's local SGD), on-device metric
accumulation, and a single device_get per segment.

On this CPU container use ``--preset cpu`` (tiny model, 1-device mesh); on a
pod the same script drives the production training mesh: ``--mesh train``
builds mesh.make_training_mesh and shards the panel rows over
('pod','agent') and the flat D axis over 'fsdp' (core/panel.shard_spec), so
the fused mix lowers to per-shard matmuls with fsdp-local collectives
instead of silently requiring replicated state. ``--mesh debug`` runs the
same lowering on the (1,2,2,2) debug mesh (needs
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --preset cpu \
      --rounds 20 --schedule final_merge
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.train --preset cpu --mesh debug --rounds 4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import merging as merging_mod
from repro import telemetry
from repro import wire as wire_mod
from repro.checkpoint import Checkpointer, save
from repro.configs import get_config
from repro.core import dsgd
from repro.core import faults as faults_mod
from repro.core import merge as merge_mod
from repro.core import panel as panel_mod
from repro.core.schedule import make_schedule
from repro.data.synthetic import SyntheticLM, make_agent_lm_batches
from repro.launch import mesh as mesh_mod
from repro.models import build_model
from repro.optim import make_optimizer
from repro.residency import parse_policy
from repro.telemetry.metrics import fused_moments_auto, resident_bytes_model


def build_mesh(kind: str, preset: str, cfg):
    """Resolve --mesh: None (single-device/replicated panels) or a
    ('pod','agent','fsdp','model') training mesh the panel is sharded on."""
    if kind == "auto":
        kind = "train" if preset == "pod" else "none"
    if kind == "none":
        return None
    if kind == "train":
        return mesh_mod.make_training_mesh(cfg.dist.agents_per_pod)
    if kind == "debug":
        need = 8
        if jax.device_count() < need:
            raise SystemExit(
                f"--mesh debug needs {need} devices; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={need}")
        return mesh_mod.make_debug_mesh(agents=2, fsdp=2, model=2)
    raise ValueError(kind)


def build_cpu_preset(cfg, agents):
    cfg = cfg.reduced(d_model=128, layers=2, vocab=256)
    cfg = cfg.replace(dist=dataclasses.replace(cfg.dist,
                                               agents_per_pod=agents))
    return cfg


def sample_segment_batches(lm, mixtures, rounds, local_steps, batch, seq,
                           rng_np):
    """(S, H, m, b, seq) batches: H DISTINCT batches per round, so every
    local step sees fresh data (Algorithm 1's local SGD; the old driver
    repeated one batch H times)."""
    per_round = []
    for _ in range(rounds):
        hs = [make_agent_lm_batches(lm, mixtures, batch, seq, rng_np)
              for _ in range(local_steps)]
        per_round.append({k: np.stack([h[k] for h in hs]) for k in hs[0]})
    return {k: jnp.asarray(np.stack([r[k] for r in per_round]))
            for k in per_round[0]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--preset", default="cpu", choices=["cpu", "pod"])
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--segment", type=int, default=8,
                    help="rounds per donated scanned dispatch (adaptive "
                         "schedule forces 1: it needs per-round feedback)")
    ap.add_argument("--schedule", default="final_merge",
                    choices=["constant", "local", "windowed", "final_merge",
                             "periodic", "adaptive"])
    ap.add_argument("--window-start", type=int, default=0)
    ap.add_argument("--window-end", type=int, default=0)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--alpha", type=float, default=0.1,
                    help="Dirichlet heterogeneity")
    ap.add_argument("--wire", default="f32",
                    choices=sorted(wire_mod.CODECS),
                    help="gossip wire codec (repro.wire): bf16 halves wire "
                         "bytes, int8 cuts them ~4x (per-agent scales + "
                         "stochastic rounding), int4 ~8x (packed nibbles, "
                         "grouped scales), *_ef adds error feedback (an "
                         "extra donated residual panel), topk ships only "
                         "the k largest innovations per agent against a "
                         "mirror panel (error feedback built in)")
    ap.add_argument("--residency", default="",
                    help="storage-codec policy for the engine's state "
                         "panels (repro.residency): 'kind=codec' pairs "
                         "joined by ',' over kinds moments/stats/wire_err "
                         "and codecs f32/bf16/int8/int8g, or a bare codec "
                         "for the moments (e.g. 'moments=int8,stats=bf16'"
                         "). Params stay f32; int8 moments cut resident "
                         "HBM ~4x per moment panel (stochastic rounding, "
                         "per-row scales; int8g = grouped scales). Empty/"
                         "f32 = the bit-exact pre-residency engine")
    ap.add_argument("--fused-moments", default="auto",
                    choices=["auto", "on", "off"],
                    help="fused int8 moment update (kernels/opt_fused.py):"
                         " decode, AdamW core and stochastic re-encode in "
                         "one kernel sweep, no transient f32 moment view "
                         "in HBM (~4x less moment traffic per local "
                         "step). auto = on whenever the --residency "
                         "moments storage is grouped int8 and the "
                         "optimizer exposes a fused core; the fused path "
                         "is trajectory-identical to the unfused one, so "
                         "'off' is a debugging/measurement switch")
    ap.add_argument("--merge", default="uniform",
                    choices=sorted(merging_mod.MERGERS),
                    help="merge operator applied on global rounds "
                         "(repro.merging): uniform mean, weighted "
                         "(inverse consensus distance), var/fisher "
                         "(per-coordinate precision weighting; extra "
                         "donated stats panels), ties (sign election + "
                         "trim), swa (merge of per-agent EMA "
                         "accumulators)")
    ap.add_argument("--eval-merged-every", type=int, default=0,
                    help="counterfactual merged-model eval cadence in "
                         "rounds (core.merge.counterfactual_eval with "
                         "--merge's operator; Fig. 2c curves). 0 = once "
                         "per segment (the previous behavior). NOTE: a "
                         "nonzero cadence re-chops the scan segments, and "
                         "the per-segment rng split means runs are only "
                         "trajectory-comparable at the SAME cadence")
    ap.add_argument("--mesh", default="auto",
                    choices=["auto", "none", "train", "debug"],
                    help="shard the (m, D) panel on a training mesh: rows "
                         "over ('pod','agent'), D over 'fsdp' (auto: train "
                         "for --preset pod, none for cpu)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="results/train")
    ap.add_argument("--save-merged", default="")
    ap.add_argument("--faults", default="",
                    help="deterministic fault plan 'AGENT@KILL[-REJOIN]' "
                         "joined by ';' (core.faults.FaultPlan.parse): the "
                         "agent is dead from round KILL, rejoins at round "
                         "REJOIN by pulling the live agents' merged model "
                         "(e.g. '2@5-9;0@3')")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="save a resumable panel checkpoint every N "
                         "SEGMENTS (0 = off); saves are asynchronous "
                         "(background commit off a host snapshot)")
    ap.add_argument("--checkpoint-dir", default="",
                    help="checkpoint directory (default: "
                         "OUT/ckpt_<run tag>)")
    ap.add_argument("--checkpoint-keep", type=int, default=3,
                    help="retain only the newest K checkpoints")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest good checkpoint in the "
                         "checkpoint directory (bit-exact continuation: "
                         "restores the panel state, rng streams, schedule "
                         "rng and round counter); starts fresh when the "
                         "directory is empty")
    ap.add_argument("--die-after-segments", type=int, default=0,
                    help="fault-injection harness hook: SIGKILL the "
                         "process after N segments (checkpoints, if "
                         "enabled, are flushed first)")
    ap.add_argument("--telemetry", action="store_true",
                    help="per-agent (S, m) metric panels from the segment "
                         "scan (loss, grad norm, distance-to-mean, "
                         "liveness, exact codec wire bytes) recorded on "
                         "each round event; same single device_get per "
                         "segment, bit-identical trajectory")
    ap.add_argument("--events", default="",
                    help="deterministic JSONL event stream path (+ a "
                         ".wall.jsonl wall-clock sidecar); default "
                         "OUT/events_<tag>.jsonl when --telemetry is on, "
                         "else console-only. Resume-safe: the stream is "
                         "truncated to the checkpointed seq so baseline "
                         "and kill+resume runs emit byte-identical files")
    ap.add_argument("--snapshot", default="",
                    help="periodic JSON telemetry snapshot path "
                         "(telemetry.SnapshotExporter riding the event "
                         "log's sink; rewritten atomically each round)")
    ap.add_argument("--profile", default="",
                    help="capture a jax profiler trace of the training "
                         "loop into this logdir (view with tensorboard/"
                         "xprof; degrades to a warning where the profiler "
                         "backend is unavailable)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "cpu":
        cfg = build_cpu_preset(cfg, args.agents)
    m = args.agents
    model = build_model(cfg)
    opt = make_optimizer(args.optimizer, args.lr, weight_decay=5e-4,
                         total_steps=args.rounds * args.local_steps)

    mesh = build_mesh(args.mesh, args.preset, cfg)
    batch_sharding = None
    if mesh is not None:
        rows = mesh_mod.num_agents(mesh)
        if m % rows:
            raise SystemExit(f"--agents {m} must be divisible by the mesh's "
                             f"pod*agent = {rows} so panel rows shard evenly")
        # (S, H, m, b, ...) batches: agent rows on the communication axes
        batch_sharding = NamedSharding(mesh, P(None, None, ("pod", "agent")))
        print(f"panel sharded on mesh {dict(mesh.shape)}")

    plan = (faults_mod.FaultPlan.parse(m, args.faults)
            if args.faults else None)

    # the schedule carries the merge operator of its global rounds; the
    # engine consumes it via the spec — sched.merger is the single source
    kw = {"prob": 0.2, "seed": args.seed, "merger": args.merge}
    if args.schedule == "windowed":
        kw.update(start=args.window_start, end=args.window_end or
                  args.rounds // 10)
    if plan is not None:
        kw["faults"] = plan
    sched = make_schedule(args.schedule, m, args.rounds, **kw)
    seg_len = 1 if args.schedule == "adaptive" else max(1, args.segment)

    if args.schedule == "adaptive" and (args.checkpoint_every or
                                        args.resume):
        raise SystemExit(
            "--checkpoint-every/--resume do not support the adaptive "
            "schedule: its controller state is host-side feedback that a "
            "checkpoint cannot replay bit-exactly")

    tag = f"{args.arch}_{args.schedule}_a{args.alpha}"
    if args.merge != "uniform":
        tag += f"_m{args.merge}"
    if args.residency:
        tag += "_r" + args.residency.replace("=", "").replace(",", "_")

    # the run configuration that DEFINES the trajectory (the checkpoint
    # fingerprint keys): checkpoint/resume/telemetry plumbing is excluded
    # so a baseline and its kill+resume twin share one run_id
    run_cfg = {k: vars(args)[k] for k in (
        "arch", "preset", "agents", "rounds", "local_steps", "batch",
        "seq", "segment", "schedule", "window_start", "window_end",
        "optimizer", "lr", "alpha", "wire", "residency", "merge",
        "eval_merged_every", "seed", "faults")}
    run_id = telemetry.make_run_id(run_cfg)
    events_path = args.events or (
        os.path.join(args.out, f"events_{tag}.jsonl")
        if args.telemetry else None)

    ckpt = None
    if args.checkpoint_every or args.resume:
        # the residency stamp guards --resume against decoding a v2
        # blob's stored-layout panels with a different --residency
        ckpt = Checkpointer(
            args.checkpoint_dir or os.path.join(args.out, "ckpt_" + tag),
            keep=args.checkpoint_keep, fingerprint=run_cfg,
            residency=parse_policy(args.residency or None))

    key = jax.random.PRNGKey(args.seed)
    state, spec = dsgd.init_panel_state(model.init_params, opt, m, key,
                                        mesh=mesh, wire=args.wire,
                                        merger=sched.merger,
                                        residency=args.residency or None)
    print(f"wire codec {args.wire}: {spec.wire_payload_bytes} B/agent "
          f"payload ({spec.wire_total_bytes} B with scales/indices) per "
          f"full-panel exchange; merge operator {spec.merger}")
    fused = {"auto": None, "on": True, "off": False}[args.fused_moments]
    fused_active = fused_moments_auto(spec, opt) if fused is None else fused
    res_bytes = resident_bytes_model(spec, opt, fused=fused_active)
    print(f"residency {args.residency or 'f32'}: "
          f"{res_bytes['total']} B/agent resident "
          f"(params {res_bytes['params']}, moments {res_bytes['moments']}, "
          f"wire_err {res_bytes['wire_err']}, "
          f"merge_stat {res_bytes['merge_stat']}); "
          f"peak {res_bytes['peak']} B/agent "
          f"(+{res_bytes['transient_bytes']} transient); "
          f"fused moments {'on' if fused_active else 'off'}")
    segment_fn = dsgd.make_panel_segment(model.loss_fn, opt,
                                         args.local_steps, spec,
                                         fused=fused,
                                         telemetry=args.telemetry)

    lm = SyntheticLM(vocab=cfg.vocab_size, num_domains=8, seed=args.seed)
    mixtures = lm.domain_mixtures(m, args.alpha, seed=args.seed + 1)
    rng_np = np.random.default_rng(args.seed + 2)

    def eval_loss(params, batches):
        l, _ = model.loss_fn(params, batches, None)
        return l

    # counterfactual merged-model eval under the run's merge operator
    # (var/fisher/swa read the engine's merge_stat panels); the panel
    # variant keeps every op constrained to the spec's mesh layout.
    # ``lv`` masks dead agents out of both the merge and the local mean
    # when a fault plan is active
    eval_merged = jax.jit(
        lambda pan, mstat, b, lv: merge_mod.counterfactual_eval_panel(
            lambda p: eval_loss(p, b), pan, spec, stats=mstat, live=lv))

    def _local_mean(pan, b, lv):
        losses = jax.vmap(eval_loss, in_axes=(0, None))(
            panel_mod.from_panel(pan, spec), b)
        if lv is None:
            return jnp.mean(losses)
        lf = lv.astype(jnp.float32)
        return jnp.sum(losses * lf) / jnp.maximum(jnp.sum(lf), 1.0)

    eval_local = jax.jit(_local_mean)

    def alive_after(r):
        """(m,) bool of agents holding a usable model after round ``r``,
        or None without a fault plan (dead agents' rows are stale
        pass-through and excluded from evals)."""
        if plan is None:
            return None
        return jnp.asarray(plan.mask(r) >= faults_mod.LIVE)

    # a fixed GLOBAL eval batch (uniform domain mixture = global dist)
    glob_mix = np.ones(lm.num_domains) / lm.num_domains
    eval_batch = jax.tree.map(jnp.asarray, {
        k: v[0] for k, v in make_agent_lm_batches(
            lm, [glob_mix], 2 * args.batch, args.seq,
            np.random.default_rng(999)).items()})

    history = []
    monitor = {}
    comm_cost = 0.0
    t = 0
    seg_idx = 0
    resume_seq = None
    if args.resume and ckpt is not None:
        rec = ckpt.restore_latest({"state": state, "key": key})
        if rec is None:
            print("resume: no checkpoint found, starting fresh")
        else:
            step, tree, meta = rec
            if mesh is not None:
                tree["state"] = jax.device_put(
                    tree["state"],
                    dsgd.panel_state_shardings(state, spec))
                tree["key"] = jax.device_put(jnp.asarray(tree["key"]))
            else:
                tree = jax.tree.map(jnp.asarray, tree)
            state, key = tree["state"], tree["key"]
            t = int(meta["round"])
            seg_idx = int(meta["segments"])
            comm_cost = float(meta["comm_cost"])
            monitor = meta["monitor"]
            history = meta["history"]
            rng_np.bit_generator.state = meta["data_rng"]
            sched.rng.bit_generator.state = meta["sched_rng"]
            resume_seq = meta.get("events_seq")
            print(f"resumed from checkpoint step {step} (round {t})")

    # the event log: deterministic stream (+ wall sidecar) when a path is
    # set, console/validation-only otherwise. On resume the stream is
    # truncated back to the checkpointed seq — replayed rounds are
    # re-emitted exactly once, keeping baseline vs kill+resume streams
    # byte-identical (scripts/fault_smoke.py pins this)
    snap = (telemetry.SnapshotExporter(args.snapshot)
            if args.snapshot else None)
    log = telemetry.EventLog(
        events_path, run_id=run_id,
        resume_at=resume_seq if events_path else None, sink=snap)
    if resume_seq is None:
        print(telemetry.format_event(log.emit(
            "run_start", run_id=run_id, schema=telemetry.SCHEMA_VERSION,
            config=run_cfg)), flush=True)
    else:
        log.emit_op("resume", round=t, segments=seg_idx, seq=log.seq)
    if ckpt is not None:
        ckpt.events = log  # sidecar checkpoint_save records
    prof = telemetry.profile_trace(args.profile,
                                   enabled=bool(args.profile)).start()
    if prof:
        log.emit_op("profile_start", logdir=args.profile)
    t0 = time.time()
    ev = args.eval_merged_every
    while t < args.rounds:
        S = min(seg_len, args.rounds - t)
        if ev > 0:  # chop segments at the eval cadence so the merged
            # counterfactual is measured exactly every ``ev`` rounds
            S = min(S, (t // ev + 1) * ev - t)
        pad = seg_len - S  # tail segment: pad to the common length so the
        # jitted scan is compiled ONCE (padded rounds are masked no-ops)
        Ws, comm_after, glob, lives = [], [], [], []
        for s in range(S):
            W = sched.mixing_matrix(t + s, monitor)
            comm_cost += sched.round_cost(W)
            comm_after.append(comm_cost)
            Ws.append(W)
            # the schedule KNOWS which rounds are global — tell the
            # engine explicitly instead of fingerprinting W (a gossip
            # matrix can coincide with the 1/m average at small m)
            glob.append(sched.last_kind == "global")
            lives.append(sched.last_live if sched.last_live is not None
                         else np.ones(m, np.int8))
        glob_host = list(glob)
        Ws += [np.eye(m)] * pad
        glob += [False] * pad
        lives += [np.ones(m, np.int8)] * pad
        Ws = jnp.asarray(np.stack(Ws), jnp.float32)
        glob = jnp.asarray(glob)
        live = (jnp.asarray(np.stack(lives), jnp.int32)
                if plan is not None else None)
        batches = sample_segment_batches(lm, mixtures, S, args.local_steps,
                                         args.batch, args.seq, rng_np)
        if pad:
            batches = {k: jnp.concatenate(
                [v, jnp.zeros((pad,) + v.shape[1:], v.dtype)]) for k, v in
                batches.items()}
        if batch_sharding is not None:
            batches = {k: jax.device_put(v, batch_sharding)
                       for k, v in batches.items()}
        active = jnp.asarray([True] * S + [False] * pad)
        key, k = jax.random.split(key)
        seg_t0 = time.perf_counter()
        state, mets = segment_fn(state, batches, Ws, k, active, glob, live)
        mets = jax.device_get(mets)  # ONE transfer for the whole segment
        mets = {k: v[:S] for k, v in mets.items()}
        monitor = {"grad_norm": float(mets["grad_norm"][-1]),
                   "consensus": float(mets["consensus"][-1])}
        # merged/local eval at the eval cadence (--eval-merged-every, or
        # every segment end when 0) and always at the final round
        do_eval = (ev == 0 or (t + S) % ev == 0 or t + S == args.rounds)
        merged_l = local_l = None
        if do_eval:
            lv_now = alive_after(t + S - 1)
            merged_l = float(eval_merged(state["panel"],
                                         state.get("merge_stat"),
                                         eval_batch, lv_now))
            local_l = float(eval_local(state["panel"], eval_batch,
                                       lv_now))
        rev = None
        for s in range(S):
            r = t + s
            if plan is not None:
                for agent, kind in plan.at(r):
                    log.emit("fault", round=r, agent=agent, kind=kind)
            extra = ({k: mets[k][s] for k in
                      ("loss_agent", "grad_norm_agent", "dist_to_mean",
                       "live", "wire_bytes")} if args.telemetry else {})
            rev = log.emit(
                "round", round=r, loss=float(mets["loss"][s]),
                grad_norm=float(mets["grad_norm"][s]),
                grad_norm_max=float(mets["grad_norm_max"][s]),
                consensus=float(mets["consensus"][s]),
                comm_cost_P=float(comm_after[s]),
                resident_bytes=int(res_bytes["total"]),
                transient_bytes=int(res_bytes["transient_bytes"]), **extra)
            if glob_host[s]:
                log.emit("merge", round=r, operator=spec.merger)
            # eval is measured once per segment (at its end); intermediate
            # rounds carry None so every record has the same schema
            last = s == S - 1
            history.append({"round": r,
                            "train_loss": float(mets["loss"][s]),
                            "consensus": float(mets["consensus"][s]),
                            "grad_norm": float(mets["grad_norm"][s]),
                            "merged_eval": merged_l if last else None,
                            "local_eval": local_l if last else None,
                            "comm_cost_P": comm_after[s]})
        t += S
        seg_idx += 1
        print(telemetry.format_event(rev), flush=True)
        if merged_l is not None:
            print(telemetry.format_event(log.emit(
                "eval", round=t - 1, merged_eval=merged_l,
                local_eval=local_l)), flush=True)
        log.emit_op("segment", seg=seg_idx, rounds=S,
                    dt=time.perf_counter() - seg_t0)
        if ckpt is not None and args.checkpoint_every and (
                seg_idx % args.checkpoint_every == 0 or t >= args.rounds):
            # async: the host snapshot happens before save() returns, so
            # the next segment is free to donate the live state.
            # events_seq checkpoints the deterministic stream's position —
            # the truncate-on-resume cursor
            ckpt.save(t, {"state": state, "key": key}, block=False, meta={
                "round": t, "segments": seg_idx, "comm_cost": comm_cost,
                "monitor": monitor, "history": history,
                "data_rng": rng_np.bit_generator.state,
                "sched_rng": sched.rng.bit_generator.state,
                "events_seq": log.seq})
        if args.die_after_segments and seg_idx >= args.die_after_segments:
            if ckpt is not None:
                ckpt.wait()
            print(f"fault injection: dying after segment {seg_idx} "
                  f"(round {t})", flush=True)
            os.kill(os.getpid(), signal.SIGKILL)
    if prof:
        prof.stop()
        log.emit_op("profile_stop", logdir=args.profile)
        print(f"profiler trace captured to {args.profile}")
    print(telemetry.format_event(log.emit(
        "run_end", rounds=args.rounds,
        final_loss=history[-1]["train_loss"] if history else 0.0,
        comm_cost_P=comm_cost)), flush=True)
    print(f"total {time.time()-t0:.1f}s")
    if ckpt is not None:
        ckpt.wait()
    log.close()
    if snap is not None:
        snap.close()
        print(f"telemetry snapshot: {args.snapshot}")
    if events_path:
        print(f"events: {events_path} (+ {telemetry.wall_path(events_path)})")

    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump({"args": vars(args), "history": history}, f, indent=1)
    if args.save_merged:
        # merge with the RUN'S operator (+ its stats), not the uniform
        # mean — the checkpoint must be the model whose merged_eval the
        # history just reported; under a fault plan only agents alive at
        # the end contribute
        save(args.save_merged, merge_mod.merged_panel_tree(
            state["panel"], spec, stats=state.get("merge_stat"),
            live=alive_after(args.rounds - 1)))
        print(f"saved {spec.merger}-merged model to", args.save_merged)


if __name__ == "__main__":
    main()
