import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e) + roofline source (g).

For every (architecture x input-shape x mesh) this lowers + compiles the
real step function against ShapeDtypeStruct inputs (no allocation), records
``memory_analysis()`` / ``cost_analysis()`` / parsed collective bytes, and
derives the three roofline terms. Results land as one JSON per pair under
``results/dryrun/``; ``python -m benchmarks.roofline`` renders the table.

Variants (the §Perf levers; "baseline" is the paper-faithful config):
  baseline      dense-W einsum gossip, remat=full, f32 wire
  merge         psum global-merge round instead of dense W   (collective /m)
  nocomm        W=I round skipped on host (no mixing op at all)
  bf16wire      gossip in bf16                               (collective /2)
  pairwise      partner-gather pairwise gossip               (collective /m)
  remat_dots    remat policy dots_saveable                   (compute down)
  nochunk       un-chunked CE loss                           (memory up)
  panel         flat-panel segment engine, panels D-sharded over 'fsdp'
                (fused mix -> per-shard matmuls, fsdp-local collectives)
  panel_bf16wire  panel engine with a bf16 gossip payload
  panel_int8wire  panel engine with the int8 stochastic-rounding wire
                codec (repro.wire; modelled payload /4 on f32 groups via
                PanelSpec.wire_bytes — the SPMD collectives still move
                dequantized f32 shards today, see ROADMAP "True int8
                collectives")
  panel_int4wire  panel engine with the packed-nibble int4 wire codec
                (grouped scales; modelled payload /8 on f32 groups)
  panel_topkwire  panel engine with the top-k sparse-innovation codec
                (mirror panel as the EF state; the mix lowers to the
                delta form x + (W - I) @ mirror, not one dense matmul)
  panel_residency_int8  panel engine with the moments=int8 residency
                policy (repro.residency: grouped signed-sqrt companded
                int8 moment storage) — the record's memory_analysis and
                ``resident_bytes_per_agent`` extra show the per-agent
                HBM drop vs the plain panel variant
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import INPUT_SHAPES, get_config, list_archs  # noqa: E402
from repro.core import dsgd  # noqa: E402
from repro.core import panel as panel_mod  # noqa: E402
from repro.launch import mesh as mesh_mod  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.models.sharding import (TRAIN_RULES, activation_sharding,  # noqa: E402
                                   resolve, serve_rules)
from repro.optim import make_optimizer  # noqa: E402
from repro.utils import flops as flops_mod  # noqa: E402
from repro.utils.hlo import collective_bytes  # noqa: E402

PEAK_FLOPS = 197e12  # bf16 / chip (v5e)
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

ARCHS = ["gemma-2b", "phi3-mini-3.8b", "arctic-480b", "qwen2-vl-72b",
         "xlstm-1.3b", "seamless-m4t-medium", "deepseek-v3-671b",
         "recurrentgemma-2b", "olmo-1b", "yi-34b"]
# long_500k policy (DESIGN.md §5): run for sub-quadratic archs; gemma-2b uses
# its sliding-window variant; others are recorded SKIPs.
LONG_OK = {"xlstm-1.3b", "recurrentgemma-2b"}
LONG_VIA_SW = {"gemma-2b": "gemma-2b-sw"}


def _leaf_is_pspec(x):
    return isinstance(x, P)


def _named(mesh, ps_tree):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), ps_tree,
                        is_leaf=_leaf_is_pspec)


def _batch_pspec(batch_shapes, lead_axes, mesh, inner_axis=None):
    """Shard leading batch dim(s); drop axes that don't divide."""
    def one(x):
        axes = [None] * len(x.shape)
        size = int(np.prod([mesh.shape[a] for a in lead_axes]))
        if x.shape and x.shape[0] % size == 0 and size > 1:
            axes[0] = lead_axes if len(lead_axes) > 1 else lead_axes[0]
        if inner_axis and len(x.shape) > 1:
            isz = mesh.shape[inner_axis]
            if x.shape[1] % isz == 0 and isz > 1:
                axes[1] = inner_axis
        return P(*axes)
    return jax.tree.map(one, batch_shapes)


def _variant_cfg(cfg, variant, scan=False):
    dist = cfg.dist
    if "dots" in variant:
        dist = dataclasses.replace(dist, remat="dots")
    if variant == "nochunk":
        dist = dataclasses.replace(dist, loss_chunk=1 << 30)
    if "flashxla" in variant:
        dist = dataclasses.replace(dist, attn_block=512)
    if "seqpar" in variant:
        dist = dataclasses.replace(dist, seq_shard=True)
    if "moeshard2" in variant:
        dist = dataclasses.replace(dist, moe_dispatch_shard="dmodel")
    elif "moeshard" in variant:
        dist = dataclasses.replace(dist, moe_dispatch_shard="tokens")
    dist = dataclasses.replace(dist, scan_layers=scan)
    return cfg.replace(dist=dist)


def build_train(cfg, shape, multi_pod, variant, scan=False):
    cfg = _variant_cfg(cfg, variant, scan=scan)
    model = build_model(cfg)
    mesh = mesh_mod.make_training_mesh(cfg.dist.agents_per_pod,
                                       multi_pod=multi_pod)
    m = mesh_mod.num_agents(mesh)
    opt = make_optimizer("adamw", 1e-4)
    key = jax.random.PRNGKey(0)
    state_shapes = jax.eval_shape(
        lambda k: dsgd.init_state(model.init_params, opt, m, k), key)
    params_ps = resolve(model.param_spec(), state_shapes["params"], mesh,
                        TRAIN_RULES, prefix=(("pod", "agent"),))
    state_ps = {"params": params_ps,
                "opt": {"m": params_ps, "v": params_ps, "step_count": P()},
                "step": P()}
    batch_shapes = model.input_specs(shape, agents=m)
    batch_ps = _batch_pspec(batch_shapes, ("pod", "agent"), mesh,
                            inner_axis="fsdp")

    impl = {"baseline": "dense", "merge": "merge", "nocomm": "none",
            "pairwise": "pairwise", "bf16wire": "dense"}.get(variant, "dense")
    wire = jnp.bfloat16 if variant == "bf16wire" else None

    if impl == "pairwise":
        def step(state, batch, partner, rng):
            # per-leaf variant: leaves carry heterogeneous shardings here,
            # so the panel path's concatenate would force resharding
            from repro.core.gossip import mix_pairwise_tree
            s = dsgd.make_dsgd_step(model.loss_fn, opt, gossip_impl="none",
                                    monitor=False)
            new_state, mets = s(state, batch, None, rng)
            new_state["params"] = mix_pairwise_tree(
                new_state["params"], partner, wire_dtype=wire)
            return new_state, mets
        w_sds = jax.ShapeDtypeStruct((m,), jnp.int32)
    else:
        step = dsgd.make_dsgd_step(model.loss_fn, opt, gossip_impl=impl,
                                   monitor=False, wire_dtype=wire)
        w_sds = jax.ShapeDtypeStruct((m, m), jnp.float32)

    key_sds = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    in_sh = (_named(mesh, state_ps), _named(mesh, batch_ps),
             NamedSharding(mesh, P()), NamedSharding(mesh, P()))
    fn = jax.jit(step, in_shardings=in_sh)
    args = (state_shapes, batch_shapes, w_sds, key_sds)
    return fn, args, mesh, TRAIN_RULES, {"agents": m}


def build_train_panel(cfg, shape, multi_pod, variant, scan=True):
    """Flat-panel segment engine on the training mesh: the (m, D) panels are
    row-sharded over ('pod','agent') and D-sharded over 'fsdp'
    (core/panel.shard_spec), the per-leaf params/grads inside the local step
    keep their model-natural layouts via ``param_shardings``, and ONE
    S=1/H=1 segment is lowered so the record's collectives show the fused
    mix as per-shard matmuls + fsdp-local gossip traffic."""
    cfg = _variant_cfg(cfg, variant, scan=scan)
    model = build_model(cfg)
    mesh = mesh_mod.make_training_mesh(cfg.dist.agents_per_pod,
                                       multi_pod=multi_pod)
    m = mesh_mod.num_agents(mesh)
    opt = make_optimizer("adamw", 1e-4)
    key = jax.random.PRNGKey(0)

    wire = ("bf16" if "bf16wire" in variant
            else "int8" if "int8wire" in variant
            else "int4" if "int4wire" in variant
            else "topk" if "topkwire" in variant else None)
    residency = {"moments": "int8"} if "residency_int8" in variant else None
    params_sds = jax.eval_shape(
        lambda k: dsgd._init_agent_params(model.init_params, m, k, False),
        key)
    spec = panel_mod.shard_spec(panel_mod.make_spec(params_sds), mesh)
    if wire is not None:
        spec = panel_mod.with_wire(spec, wire)
    if residency is not None:
        spec = panel_mod.with_residency(spec, residency)
    state_sds = jax.eval_shape(
        lambda k: dsgd.init_panel_state(model.init_params, opt, m, k,
                                        wire=wire, residency=residency)[0],
        key)
    param_ps = resolve(model.param_spec(), params_sds, mesh, TRAIN_RULES,
                       prefix=(("pod", "agent"),))
    param_sh = _named(mesh, param_ps)

    batch_shapes = model.input_specs(shape, agents=m)
    batch_ps = _batch_pspec(batch_shapes, ("pod", "agent"), mesh,
                            inner_axis="fsdp")
    # (S=1, H=1) segment wrapping: two leading scan dims, replicated
    seg_batch = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((1, 1) + s.shape, s.dtype),
        batch_shapes)
    seg_batch_ps = jax.tree.map(lambda ps: P(None, None, *ps), batch_ps,
                                is_leaf=_leaf_is_pspec)

    in_sh = (dsgd.panel_state_shardings(state_sds, spec),
             _named(mesh, seg_batch_ps),
             NamedSharding(mesh, P()), NamedSharding(mesh, P()))
    fn = dsgd.make_panel_segment(model.loss_fn, opt, 1, spec,
                                 param_shardings=param_sh,
                                 in_shardings=in_sh)
    w_sds = jax.ShapeDtypeStruct((1, m, m), jnp.float32)
    key_sds = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    args = (state_sds, seg_batch, w_sds, key_sds)
    from repro.telemetry.metrics import resident_bytes_model
    res = resident_bytes_model(spec, opt)
    return fn, args, mesh, TRAIN_RULES, {"agents": m,
                                         "panel_width": spec.width,
                                         "wire_bytes_per_agent":
                                             spec.wire_bytes,
                                         "resident_bytes_per_agent": res}


def build_serve(cfg, shape, multi_pod, variant):
    cfg = _variant_cfg(cfg, variant)
    cfg = cfg.replace(param_dtype="bfloat16", compute_dtype="bfloat16",
                      dist=dataclasses.replace(cfg.dist, remat="none"))
    model = build_model(cfg)
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    big = cfg.dist.agents_per_pod < 16  # >30B params: FSDP the weights too
    rules = serve_rules(mesh, big=big)
    data_axes = ("pod", "data") if multi_pod else ("data",)
    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(model.init_params, key)
    params_ps = resolve(model.param_spec(), params_shapes, mesh, rules)
    inputs = model.input_specs(shape, dtype=jnp.bfloat16)

    if shape.kind == "prefill":
        def step(params, batch):
            return model.prefill(params, batch, max_len=shape.seq_len)
        batch_ps = _batch_pspec(
            {k: v for k, v in inputs.items()}, data_axes, mesh)
        fn = jax.jit(step, in_shardings=(_named(mesh, params_ps),
                                         _named(mesh, batch_ps)))
        args = (params_shapes, inputs)
    else:  # decode
        caches_shapes = inputs["caches"]
        cache_ps = resolve(model.cache_spec(), caches_shapes, mesh, rules)
        tok_ps = _batch_pspec(
            {"tokens": inputs["tokens"]}, data_axes, mesh)["tokens"]

        def step(params, caches, tokens, index):
            return model.decode_step(params, caches, tokens, index)
        fn = jax.jit(step, in_shardings=(
            _named(mesh, params_ps), _named(mesh, cache_ps),
            NamedSharding(mesh, tok_ps), NamedSharding(mesh, P())))
        args = (params_shapes, caches_shapes, inputs["tokens"],
                jax.ShapeDtypeStruct((), jnp.int32))
    return fn, args, mesh, rules, {"big": big}


HEAVY_TRAIN_LAYERS = 30


def _compile_train(cfg, shape, multi_pod, variant, scan):
    """Build + compile one train step; returns (compiled, mesh, extra)."""
    fn, args, mesh, rules, extra = build_train(cfg, shape, multi_pod,
                                               variant, scan=scan)
    with activation_sharding(mesh, rules):
        lowered = fn.lower(*args)
    return lowered.compile(), mesh, extra


def run_train_extrapolated(cfg, shape, multi_pod, variant, rec):
    """Heavy archs (>=30 layers): unrolled compiles are too slow on this
    1-core CPU container, and scanned compiles undercount while-loop bodies
    in cost_analysis. Instead: compile the SAME step with n=1 and n=2 main
    periods unrolled (fast), extrapolate per-period costs linearly to the
    full depth, and take memory_analysis from the scanned full-depth compile
    (loop-carried liveness is representative there). Marked
    ``extrapolated: true`` in the record."""
    period = len(cfg.layer_period)
    front = cfg.dense_ff_first_k
    n_main = (cfg.num_layers - front) // period
    assert (cfg.num_layers - front) % period == 0, "heavy arch has tail"

    def with_reps(n):
        return cfg.replace(num_layers=front + period * n)

    t0 = time.time()
    c1, mesh, extra = _compile_train(with_reps(1), shape, multi_pod, variant,
                                     scan=False)
    c2, _, _ = _compile_train(with_reps(2), shape, multi_pod, variant,
                              scan=False)
    cfull, _, _ = _compile_train(cfg, shape, multi_pod, variant, scan=True)
    rec.update(extra)
    rec["chips"] = mesh.devices.size
    rec["extrapolated"] = True
    rec["compile_s"] = round(time.time() - t0, 2)

    def costs(c):
        ca = _cost_dict(c)
        _, coll, _ = collective_bytes(c.as_text())
        return (float(ca.get("flops", 0.0)),
                float(ca.get("bytes accessed", 0.0)), float(coll))

    f1, b1, g1 = costs(c1)
    f2, b2, g2 = costs(c2)
    lin = lambda a1, a2: a1 + (n_main - 1) * (a2 - a1)  # noqa: E731
    hlo_flops, hlo_bytes, coll_total = lin(f1, f2), lin(b1, b2), lin(g1, g2)
    rec["cost"] = {"flops_per_device": hlo_flops,
                   "bytes_per_device": hlo_bytes,
                   "per_period": {"flops": f2 - f1, "bytes": b2 - b1,
                                  "coll": g2 - g1}}
    per_kind1 = collective_bytes(c1.as_text())[0]
    per_kind2 = collective_bytes(c2.as_text())[0]
    per_kind = {k: int(lin(per_kind1.get(k, 0), per_kind2.get(k, 0)))
                for k in set(per_kind1) | set(per_kind2)}
    rec["collectives"] = {"bytes_per_device": coll_total,
                          "per_kind": per_kind,
                          "counts": collective_bytes(c2.as_text())[2]}

    ma = cfull.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    per_dev_total = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    rec["memory"]["per_device_total"] = int(per_dev_total)
    rec["memory"]["fits_16gb"] = bool(per_dev_total < 16e9)
    return rec, hlo_flops, hlo_bytes, coll_total, mesh.devices.size


def _cost_dict(compiled):
    """compiled.cost_analysis() across jaxlib versions: dict or [dict]."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    return ca


def roofline_terms(hlo_flops, hlo_bytes, coll_bytes, chips):
    return {
        "compute_s": hlo_flops / PEAK_FLOPS,
        "memory_s": hlo_bytes / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
    }


def run_pair(arch, shape_name, multi_pod, variant="baseline", outdir=None):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}_{shape_name}_{mesh_name}_{variant}"
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "variant": variant, "status": "OK"}
    t0 = time.time()
    try:
        eff_arch = arch
        if shape_name == "long_500k":
            if arch in LONG_VIA_SW:
                eff_arch = LONG_VIA_SW[arch]
                rec["note"] = "sliding-window variant (window=4096)"
            elif arch not in LONG_OK:
                rec["status"] = "SKIP"
                rec["reason"] = ("full quadratic attention family; long_500k "
                                 "reserved for sub-quadratic archs "
                                 "(DESIGN.md §5)")
                rec["wall_s"] = round(time.time() - t0, 2)
                _dump(rec, tag, outdir)
                return rec
        cfg = get_config(eff_arch)
        is_panel = variant.startswith("panel")
        if (shape.kind == "train" and not is_panel
                and cfg.num_layers >= HEAVY_TRAIN_LAYERS):
            rec, hlo_flops, hlo_bytes, coll_total, chips = (
                run_train_extrapolated(cfg, shape, multi_pod, variant, rec))
        else:
            if shape.kind == "train":
                # panel variants lower the fused segment engine directly
                # (scan-over-layers; no unrolled extrapolation pass)
                build = build_train_panel if is_panel else build_train
            else:
                build = build_serve
            fn, args, mesh, rules, extra = build(cfg, shape, multi_pod,
                                                 variant)
            rec.update(extra)
            chips = mesh.devices.size
            rec["chips"] = chips

            with activation_sharding(mesh, rules):
                lowered = fn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            rec["lower_s"] = round(t1 - t0, 2)
            rec["compile_s"] = round(t2 - t1, 2)

            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
            }
            per_dev_total = (ma.argument_size_in_bytes
                             + ma.output_size_in_bytes
                             + ma.temp_size_in_bytes
                             - ma.alias_size_in_bytes)
            rec["memory"]["per_device_total"] = int(per_dev_total)
            rec["memory"]["fits_16gb"] = bool(per_dev_total < 16e9)

            ca = _cost_dict(compiled)
            hlo_flops = float(ca.get("flops", 0.0))
            hlo_bytes = float(ca.get("bytes accessed", 0.0))
            rec["cost"] = {"flops_per_device": hlo_flops,
                           "bytes_per_device": hlo_bytes}

            txt = compiled.as_text()
            per_kind, coll_total, counts = collective_bytes(txt)
            rec["collectives"] = {"bytes_per_device": coll_total,
                                  "per_kind": per_kind, "counts": counts}

        model = build_model(get_config(eff_arch))
        mf = flops_mod.model_flops(model, shape)
        rec["model_flops"] = mf
        terms = roofline_terms(hlo_flops, hlo_bytes, coll_total, chips)
        rec["roofline"] = terms
        dom = max(terms, key=terms.get)
        rec["roofline"]["dominant"] = dom
        total_hlo = hlo_flops * chips
        rec["roofline"]["useful_flops_ratio"] = (
            (mf["model_flops"] + mf["attn_flops"]) / total_hlo
            if total_hlo else None)
    except Exception as e:  # noqa: BLE001
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = round(time.time() - t0, 2)
    _dump(rec, tag, outdir)
    return rec


def _dump(rec, tag, outdir):
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        with open(os.path.join(outdir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    ok = fail = skip = 0
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                path = os.path.join(
                    args.out, f"{arch}_{shp}_{mesh_name}_{args.variant}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("OK", "SKIP"):
                        print(f"[keep] {arch} {shp} {mesh_name}", flush=True)
                        ok += prev["status"] == "OK"
                        skip += prev["status"] == "SKIP"
                        continue
                rec = run_pair(arch, shp, mp, args.variant, args.out)
                st = rec["status"]
                ok += st == "OK"
                fail += st == "FAIL"
                skip += st == "SKIP"
                dom = rec.get("roofline", {}).get("dominant", "-")
                print(f"[{st:4s}] {arch:22s} {shp:12s} "
                      f"{'2x16x16' if mp else '16x16':8s} {args.variant:9s} "
                      f"dom={dom} wall={rec['wall_s']}s"
                      + (f" err={rec.get('error','')[:100]}"
                         if st == 'FAIL' else ""), flush=True)
    print(f"done: ok={ok} fail={fail} skip={skip}")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
