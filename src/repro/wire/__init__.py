"""Quantized-wire codec subsystem (see wire/codec.py for the contract).

The panel engine (core/panel.py) resolves a per-dtype-group policy — a
``(group, codec-name)`` table carried on ``PanelSpec.wire`` via
``panel.with_wire`` — through :func:`get_codec`; everything here is
engine-agnostic (the per-leaf ``gossip.*_tree`` oracle path uses the
same codecs per leaf)."""
from repro.wire.codec import (CODECS, Codec, DtypeCodec,  # noqa: F401
                              F32Codec, Int4Codec, Int8Codec, TopKCodec,
                              dtype_codec, get_codec)
