"""Wire codecs: pluggable compression of the gossip communication payload.

A codec controls how one dtype group's (m, D_g) panel travels during a
communication op without changing the storage dtype of the state. The
single entry point mirrors (and generalizes) the old ``panel._wire`` cast:

    xw, back, new_err = codec.encode(x, key=..., err=...,
                                     use_pallas=..., interpret=...)

``xw`` is the array the mixing math runs on — the receive-side view of
the payload (for ``int8`` that is the dequantized panel; quantization
error is already baked in, exactly what every peer reconstructs).
``back`` restores the storage dtype after mixing. ``new_err`` is the
updated error-feedback residual (input ``err`` passed through untouched
on residual-free codecs; an ``error_feedback`` codec REQUIRES ``err`` —
a missing residual raises rather than silently dropping the correction).

Codecs:

* ``f32``  — identity. The payload is the storage dtype as-is; bit-exact
  fallback (a bf16-stored group still ships 2-byte scalars — "f32" names
  full *storage* precision on the wire, not an upcast).
* ``bf16`` — the original wire-dtype lever, ported: cast to bf16 for the
  exchange, mix in bf16 with f32 accumulation, cast back. Bit-identical
  to the legacy ``wire_dtype=jnp.bfloat16`` behavior.
* ``int8`` — per-row (per-agent) symmetric scales amax/127, stochastic
  rounding driven by an explicit PRNG key (no ambient randomness: the
  key is threaded through the segment scan), 4x fewer payload bytes on
  f32 groups. ``int8_ef`` adds error feedback: the residual
  (x + e) - dequant(quant(x + e)) is returned for the caller to carry —
  the panel engine keeps it as an extra donated (m, D) f32 panel.

Kernels: ``use_pallas=True`` routes quantize/dequantize through the
Pallas kernels in ``kernels/wire_quant.py`` (same math as the
``kernels/ref.py`` oracles, bit-identical given the same uniforms);
sharded specs keep ``use_pallas=False`` so SPMD partitions the plain-XLA
ops, mirroring the panel matmul kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_mod
from repro.kernels import wire_quant


def _identity(y):
    return y


class F32Codec:
    """Identity codec: the payload is the storage dtype, untouched."""
    name = "f32"
    needs_key = False
    error_feedback = False

    def payload_bytes(self, rows: int, width: int, dtype) -> int:
        return rows * width * jnp.dtype(dtype).itemsize

    def encode(self, x, key=None, err=None, use_pallas: bool = False,
               interpret: bool = True):
        return x, _identity, err


class DtypeCodec:
    """Cast-only codec (the legacy ``wire_dtype`` lever): payload travels
    as ``wire_dtype``, the mix runs in that dtype with f32 accumulation,
    and the result is cast back to storage."""
    needs_key = False
    error_feedback = False

    def __init__(self, wire_dtype, name: str):
        self.wire_dtype = jnp.dtype(wire_dtype)
        self.name = name

    def payload_bytes(self, rows: int, width: int, dtype) -> int:
        return rows * width * self.wire_dtype.itemsize

    def encode(self, x, key=None, err=None, use_pallas: bool = False,
               interpret: bool = True):
        if x.dtype == self.wire_dtype:
            return x, _identity, err
        return (x.astype(self.wire_dtype),
                lambda y: y.astype(x.dtype), err)


class Int8Codec:
    """int8 payload with per-row scales; optionally stochastic rounding
    (key-driven) and error feedback (residual returned to the caller)."""
    SCALE_BYTES = 4  # one f32 scale per agent row

    def __init__(self, name: str, stochastic: bool = True,
                 error_feedback: bool = False):
        self.name = name
        self.stochastic = stochastic
        self.error_feedback = error_feedback

    @property
    def needs_key(self) -> bool:
        return self.stochastic

    def payload_bytes(self, rows: int, width: int, dtype) -> int:
        return rows * (width + self.SCALE_BYTES)

    def encode(self, x, key=None, err=None, use_pallas: bool = False,
               interpret: bool = True):
        if self.error_feedback and err is None:
            raise ValueError(
                f"codec '{self.name}' uses error feedback and needs the "
                "residual panel (err=...); a silent fallback to plain "
                "int8 would drop the accumulated correction")
        x32 = x.astype(jnp.float32)
        if self.error_feedback:
            # only the EF codec consumes the residual; a residual-free
            # int8 codec handed an err (e.g. state resumed from an
            # int8_ef run) must NOT fold it into the payload — it would
            # re-inject the same bias every round without ever updating it
            x32 = x32 + err
        u = None
        if self.stochastic:
            if key is None:
                raise ValueError(
                    f"codec '{self.name}' uses stochastic rounding and "
                    "needs an explicit PRNG key (key=...)")
            # partitionable threefry ONLY for the wire draw: the default
            # (non-partitionable) lowering produces different bits when
            # the draw is jitted under SPMD than eager/replicated, which
            # would break sharded-vs-replicated parity of the stochastic
            # rounding. Scoped here so the rest of the program's key
            # schedule (init, data, local steps) is untouched.
            with jax.threefry_partitionable(True):
                u = jax.random.uniform(key, x32.shape, jnp.float32)
        scale = ref_mod.int8_scale_ref(x32)
        if use_pallas:
            q, _ = wire_quant.quantize_int8_panel(x32, scale, u,
                                                  interpret=interpret)
            xhat32 = wire_quant.dequantize_int8_panel(q, scale,
                                                      interpret=interpret)
        else:
            q = ref_mod.quantize_int8_ref(x32, scale, u)
            xhat32 = ref_mod.dequantize_int8_ref(q, scale)
        new_err = (x32 - xhat32) if (self.error_feedback
                                     and err is not None) else err
        if x.dtype == jnp.float32:
            return xhat32, _identity, new_err
        return xhat32.astype(x.dtype), _identity, new_err


CODECS = {
    "f32": F32Codec(),
    "bf16": DtypeCodec(jnp.bfloat16, "bf16"),
    "int8": Int8Codec("int8", stochastic=True, error_feedback=False),
    "int8_ef": Int8Codec("int8_ef", stochastic=True, error_feedback=True),
}


def get_codec(name):
    """Resolve a codec by registry name; codec instances pass through
    (lets tests build e.g. a deterministic-rounding Int8Codec)."""
    if not isinstance(name, str) and hasattr(name, "encode"):
        return name
    try:
        return CODECS[name]
    except KeyError:
        raise ValueError(
            f"unknown wire codec {name!r}; known: {sorted(CODECS)}"
        ) from None


def dtype_codec(wire_dtype):
    """Codec for the legacy ``wire_dtype=`` argument (None -> identity)."""
    if wire_dtype is None:
        return CODECS["f32"]
    wd = jnp.dtype(wire_dtype)
    if wd == jnp.dtype(jnp.bfloat16):
        return CODECS["bf16"]
    return DtypeCodec(wd, wd.name)
