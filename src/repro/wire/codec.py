"""Wire codecs: pluggable compression of the gossip communication payload.

A codec controls how one dtype group's (m, D_g) panel travels during a
communication op without changing the storage dtype of the state. The
single entry point mirrors (and generalizes) the old ``panel._wire`` cast:

    xw, back, new_err = codec.encode(x, key=..., err=...,
                                     use_pallas=..., interpret=...)

``xw`` is the array the mixing math runs on — the receive-side view of
the payload (for ``int8``/``int4`` that is the dequantized panel;
quantization error is already baked in, exactly what every peer
reconstructs; for ``topk`` it is the updated MIRROR panel — see below).
``back`` restores the storage dtype after mixing. ``new_err`` is the
updated error-feedback state (input ``err`` passed through untouched on
residual-free codecs; an ``error_feedback`` codec REQUIRES ``err`` — a
missing residual raises rather than silently dropping the correction).

Codecs (``CODECS`` registry):

* ``f32``  — identity. The payload is the storage dtype as-is; bit-exact
  fallback (a bf16-stored group still ships 2-byte scalars — "f32" names
  full *storage* precision on the wire, not an upcast).
* ``bf16`` — the original wire-dtype lever, ported: cast to bf16 for the
  exchange, mix in bf16 with f32 accumulation, cast back. Bit-identical
  to the legacy ``wire_dtype=jnp.bfloat16`` behavior.
* ``int8`` — per-row (per-agent) symmetric scales amax/127, stochastic
  rounding driven by an explicit PRNG key (no ambient randomness: the
  key is threaded through the segment scan), 4x fewer payload bytes on
  f32 groups. ``int8_ef`` adds error feedback: the residual
  (x + e) - dequant(quant(x + e)) is returned for the caller to carry —
  the panel engine keeps it as an extra donated (m, D) f32 panel.
* ``int4`` — packed nibbles on the wire (TWO quantized values per byte,
  ``kernels/ref.py:pack_int4_ref`` layout: even column low nibble, odd
  column high) against GROUPED symmetric scales — one f32 amax/7 scale
  per row per ``group`` (default 128) columns, so outlier columns only
  poison their own group instead of the whole row. Same key-driven
  stochastic rounding as int8; ``int4_ef`` adds the same error feedback.
  ~8x fewer payload bytes than f32 (plus 4/group scale overhead). The
  encode path round-trips the ACTUAL wire bytes (quantize -> pack ->
  unpack -> dequantize), so the mixed view is exactly what came off the
  wire, never an un-packed shortcut.
* ``topk`` — per-row top-k-by-magnitude SPARSE payload: k f32 values +
  k packed indices per agent per round. Error feedback is MANDATORY and
  structural: ``err`` carries the MIRROR panel x̂ (CHOCO-SGD style) — the
  receive-side reconstruction every peer has accumulated from past
  sparse innovations, seeded with a copy of the panel at init (one
  full-precision sync; ``init_err``). Each encode transmits the k
  largest entries of the innovation x - x̂ (threshold-sparsified,
  ``sparsify_topk_ref``), returns the updated mirror x̂ + q as both the
  mixing view and ``new_err``, and the effective residual x - x̂
  telescopes: dropped coordinates stay in the innovation until a later
  round transmits them.
  ``delta_mix = True`` tells the panel engine to mix in DELTA form,
  ``x <- x + (W - I) @ x̂`` (exact W @ x when the mirror has caught up),
  instead of ``W @ xw`` — a sparse payload mixed as ``W @ Q(x)`` would
  zero every untransmitted coordinate. The shared mirror panel models
  innovations reaching every agent (exactly true for the global rounds;
  for time-varying gossip it is the standard simulation simplification —
  only neighbors' mirror columns enter the mix each round).

Byte accounting: ``payload_bytes`` counts the quantized values alone
(the "8x fewer" numerator); ``total_bytes`` adds scale / index metadata
(grouped int4 scales, packed top-k indices) — what actually crosses the
wire. ``wire_payload`` materialises the real wire arrays (payload list,
metadata list) so tests can assert the accounting against ``.nbytes``.
``residual(x, err)`` maps the carried state to the effective EF residual
(identity for ``int8_ef``; ``x - x̂`` for the mirror-carrying ``topk``).

Kernels: ``use_pallas=True`` routes quantize/dequantize/pack/sparsify
through the Pallas kernels in ``kernels/wire_quant.py`` (same math as the
``kernels/ref.py`` oracles, bit-identical given the same uniforms);
sharded specs keep ``use_pallas=False`` so SPMD partitions the plain-XLA
ops, mirroring the panel matmul kernels.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_mod
from repro.kernels import wire_quant


def _identity(y):
    return y


def _storage_back(dtype):
    """back() for a codec whose mixing view is f32: restore storage."""
    if jnp.dtype(dtype) == jnp.float32:
        return _identity
    return lambda y: y.astype(dtype)


class Codec:
    """Shared codec contract defaults (see module docstring)."""

    needs_key = False
    error_feedback = False
    delta_mix = False

    def payload_bytes(self, rows: int, width: int, dtype) -> int:
        """Wire bytes of the quantized VALUES alone for (rows, width)."""
        raise NotImplementedError

    def total_bytes(self, rows: int, width: int, dtype) -> int:
        """payload_bytes plus scale/index metadata — the full wire cost.
        Metadata-free codecs pay payload only."""
        return self.payload_bytes(rows, width, dtype)

    def residual(self, x, err):
        """Effective error-feedback residual given the carried ``err``
        state (identity by default; mirror-carrying codecs map it)."""
        return err

    def init_err(self, x):
        """Initial error-feedback state for one (m, D_g) group panel.
        Zeros for residual codecs; the mirror-carrying topk codec seeds
        its mirror with a COPY of the panel (one full-precision sync at
        init — from there only innovations travel; a zero mirror would
        make the early delta mixes pull on reconstructions that are
        arbitrarily far from the live parameters, which diverges)."""
        return jnp.zeros(x.shape, jnp.float32)

    def wire_payload(self, x, key=None, err=None):
        """The actual wire arrays: (payload list, metadata list), with
        sum(a.nbytes) matching payload_bytes / total_bytes exactly."""
        raise NotImplementedError


class F32Codec(Codec):
    """Identity codec: the payload is the storage dtype, untouched."""
    name = "f32"

    def payload_bytes(self, rows: int, width: int, dtype) -> int:
        return rows * width * jnp.dtype(dtype).itemsize

    def encode(self, x, key=None, err=None, use_pallas: bool = False,
               interpret: bool = True):
        return x, _identity, err

    def wire_payload(self, x, key=None, err=None):
        return [x], []


class DtypeCodec(Codec):
    """Cast-only codec (the legacy ``wire_dtype`` lever): payload travels
    as ``wire_dtype``, the mix runs in that dtype with f32 accumulation,
    and the result is cast back to storage."""

    def __init__(self, wire_dtype, name: str):
        self.wire_dtype = jnp.dtype(wire_dtype)
        self.name = name

    def payload_bytes(self, rows: int, width: int, dtype) -> int:
        return rows * width * self.wire_dtype.itemsize

    def encode(self, x, key=None, err=None, use_pallas: bool = False,
               interpret: bool = True):
        if x.dtype == self.wire_dtype:
            return x, _identity, err
        return (x.astype(self.wire_dtype),
                lambda y: y.astype(x.dtype), err)

    def wire_payload(self, x, key=None, err=None):
        return [x.astype(self.wire_dtype)], []


def _require_err(codec, err):
    if codec.error_feedback and err is None:
        raise ValueError(
            f"codec '{codec.name}' uses error feedback and needs the "
            "residual panel (err=...); a silent fallback would drop "
            "the accumulated correction")


def _require_key(codec, key):
    if codec.needs_key and key is None:
        raise ValueError(
            f"codec '{codec.name}' uses stochastic rounding and "
            "needs an explicit PRNG key (key=...)")


def _uniform(key, shape):
    # partitionable threefry ONLY for the wire draw: the default
    # (non-partitionable) lowering produces different bits when the draw
    # is jitted under SPMD than eager/replicated, which would break
    # sharded-vs-replicated parity of the stochastic rounding. Scoped
    # here so the rest of the program's key schedule (init, data, local
    # steps) is untouched.
    with jax.threefry_partitionable(True):
        return jax.random.uniform(key, shape, jnp.float32)


class Int8Codec(Codec):
    """int8 payload with per-row scales; optionally stochastic rounding
    (key-driven) and error feedback (residual returned to the caller)."""
    SCALE_BYTES = 4  # one f32 scale per agent row

    def __init__(self, name: str, stochastic: bool = True,
                 error_feedback: bool = False):
        self.name = name
        self.stochastic = stochastic
        self.error_feedback = error_feedback

    @property
    def needs_key(self) -> bool:
        return self.stochastic

    def payload_bytes(self, rows: int, width: int, dtype) -> int:
        return rows * width

    def total_bytes(self, rows: int, width: int, dtype) -> int:
        return rows * (width + self.SCALE_BYTES)

    def _carry_in(self, x, err):
        """The transmitted quantity x (+ residual for the EF variant)."""
        x32 = x.astype(jnp.float32)
        if self.error_feedback and err is not None:
            # only the EF codec consumes the residual; a residual-free
            # int8 codec handed an err (e.g. state resumed from an
            # int8_ef run) must NOT fold it into the payload — it would
            # re-inject the same bias every round without ever updating it
            x32 = x32 + err
        return x32

    def _quantize(self, x32, key, use_pallas: bool, interpret: bool):
        u = None
        if self.stochastic:
            _require_key(self, key)
            u = _uniform(key, x32.shape)
        scale = ref_mod.int8_scale_ref(x32)
        if use_pallas:
            q, _ = wire_quant.quantize_int8_panel(x32, scale, u,
                                                  interpret=interpret)
        else:
            q = ref_mod.quantize_int8_ref(x32, scale, u)
        return q, scale

    def encode(self, x, key=None, err=None, use_pallas: bool = False,
               interpret: bool = True):
        _require_err(self, err)
        x32 = self._carry_in(x, err)
        q, scale = self._quantize(x32, key, use_pallas, interpret)
        if use_pallas:
            xhat32 = wire_quant.dequantize_int8_panel(q, scale,
                                                      interpret=interpret)
        else:
            xhat32 = ref_mod.dequantize_int8_ref(q, scale)
        new_err = (x32 - xhat32) if (self.error_feedback
                                     and err is not None) else err
        if x.dtype == jnp.float32:
            return xhat32, _identity, new_err
        return xhat32.astype(x.dtype), _identity, new_err

    def wire_payload(self, x, key=None, err=None):
        _require_err(self, err)  # same contract as encode: never
        # silently measure Q(x) when the run would transmit Q(x + e)
        q, scale = self._quantize(self._carry_in(x, err), key, False, True)
        return [q], [scale]


class Int4Codec(Codec):
    """Packed-nibble int4 payload with grouped scales: one f32 amax/7
    scale per row per ``group`` columns, two quantized values per wire
    byte. Stochastic rounding and error feedback as in :class:`Int8Codec`;
    the encode path reconstructs the mixing view from the ACTUAL packed
    bytes (quantize -> pack -> unpack -> dequantize)."""
    SCALE_BYTES = 4  # one f32 scale per (row, column group)

    def __init__(self, name: str, stochastic: bool = True,
                 error_feedback: bool = False, group: int = 128):
        self.name = name
        self.stochastic = stochastic
        self.error_feedback = error_feedback
        self.group = group

    @property
    def needs_key(self) -> bool:
        return self.stochastic

    def n_groups(self, width: int) -> int:
        return -(-width // self.group)

    def payload_bytes(self, rows: int, width: int, dtype) -> int:
        return rows * ((width + 1) // 2)

    def total_bytes(self, rows: int, width: int, dtype) -> int:
        return (self.payload_bytes(rows, width, dtype)
                + rows * self.n_groups(width) * self.SCALE_BYTES)

    _carry_in = Int8Codec._carry_in

    def _quantize(self, x32, key, use_pallas: bool, interpret: bool):
        u = None
        if self.stochastic:
            _require_key(self, key)
            u = _uniform(key, x32.shape)
        scale = ref_mod.int4_group_scale_ref(x32, self.group)
        if use_pallas:
            q, _ = wire_quant.quantize_int4_panel(x32, scale, u,
                                                  group=self.group,
                                                  interpret=interpret)
        else:
            q = ref_mod.quantize_int4_ref(x32, scale, u, self.group)
        return q, scale

    def encode(self, x, key=None, err=None, use_pallas: bool = False,
               interpret: bool = True):
        _require_err(self, err)
        x32 = self._carry_in(x, err)
        D = x.shape[1]
        q, scale = self._quantize(x32, key, use_pallas, interpret)
        # the mixing view is rebuilt from the packed WIRE bytes — the
        # pack/unpack pair is an exact inverse for values in [-7, 7], so
        # this costs two cheap byte kernels and guarantees the math runs
        # on exactly what a receiver would reconstruct
        if use_pallas:
            packed = wire_quant.pack_int4_panel(q, interpret=interpret)
            qw = wire_quant.unpack_int4_panel(packed, D,
                                              interpret=interpret)
            xhat32 = wire_quant.dequantize_int4_panel(
                qw, scale, group=self.group, interpret=interpret)
        else:
            packed = ref_mod.pack_int4_ref(q)
            qw = ref_mod.unpack_int4_ref(packed, D)
            xhat32 = ref_mod.dequantize_int4_ref(qw, scale, self.group)
        new_err = (x32 - xhat32) if (self.error_feedback
                                     and err is not None) else err
        if x.dtype == jnp.float32:
            return xhat32, _identity, new_err
        return xhat32.astype(x.dtype), _identity, new_err

    def wire_payload(self, x, key=None, err=None):
        _require_err(self, err)  # as in Int8Codec.wire_payload
        q, scale = self._quantize(self._carry_in(x, err), key, False, True)
        return [ref_mod.pack_int4_ref(q)], [scale]


class TopKCodec(Codec):
    """Top-k sparsified payload over a mirror panel (CHOCO-style; see
    the module docstring). ``err`` carries the mirror x̂, seeded with a
    copy of the panel at init (:meth:`init_err` — one full-precision
    sync; from there only sparse innovations travel); encode transmits
    the k largest-magnitude entries of the innovation x - x̂ and returns
    the updated mirror as both the mixing view and the new carried
    state. ``delta_mix`` switches the panel engine to
    ``x <- x + (W - I) @ x̂`` mixing."""

    error_feedback = True   # the mirror IS the feedback state
    delta_mix = True
    needs_key = False       # values travel exact (f32) — nothing to dither
    VALUE_BYTES = 4

    # panels wider than this estimate the selection threshold from a
    # strided column subsample instead of an exact full-row top_k: the
    # exact k-th statistic is a full per-row sort (O(D log D) — ~48 s/row
    # panel at D=7.2M on CPU, and the same asymptotic pain on TPU), while
    # the subsampled quantile is O(sample log sample) and keeps ≈k
    # entries (the standard scalable approximate-top-k; the wire
    # accounting models exactly k). Tests exercise exact selection —
    # their panels sit far below the cutoff.
    THRESH_SAMPLE = 1 << 16

    def __init__(self, name: str = "topk", density: float = 0.125,
                 gamma: float = None, thresh_sample: int = THRESH_SAMPLE):
        if not 0.0 < density <= 1.0:
            raise ValueError(f"density must be in (0, 1], got {density}")
        self.name = name
        self.density = density
        self.thresh_sample = thresh_sample
        # CHOCO consensus step size: the delta mix x + gamma (W - I) x̂
        # must be damped in proportion to the compression — with gamma=1
        # each round injects the FULL mixing pull computed on mirrors
        # that the k-budget can only partially reconcile, and |x - x̂|
        # grows without bound (verified numerically: density 1/8,
        # gamma=1 diverges; gamma≈2*density contracts). The one-shot
        # global merge needs no damping: it is the full-bandwidth round
        # (see the engine's delta-merge path).
        self.gamma = min(1.0, 2.0 * density) if gamma is None else gamma

    def k_of(self, width: int) -> int:
        return max(1, int(width * self.density))

    def idx_bytes(self, width: int) -> int:
        """Bytes per packed index: the fewest whole bytes that address
        ``width`` columns (3 for panels up to 16M scalars)."""
        bits = max(1, math.ceil(math.log2(max(width, 2))))
        return (bits + 7) // 8

    def payload_bytes(self, rows: int, width: int, dtype) -> int:
        return rows * self.k_of(width) * self.VALUE_BYTES

    def total_bytes(self, rows: int, width: int, dtype) -> int:
        return (self.payload_bytes(rows, width, dtype)
                + rows * self.k_of(width) * self.idx_bytes(width))

    def residual(self, x, err):
        """The effective EF residual is the untransmitted innovation."""
        if err is None:
            return None
        return x.astype(jnp.float32) - err

    def init_err(self, x):
        # the mirror starts as a COPY of the panel (jnp.array copies —
        # an f32 aliasing view would break the segment driver's buffer
        # donation): one full-precision sync at init, sparse innovations
        # from then on. See Codec.init_err for why not zeros.
        return jnp.array(x, jnp.float32)

    def _threshold(self, innov):
        """Per-row selection threshold: the exact k-th largest |innov|
        up to ``thresh_sample`` columns, a strided-subsample quantile
        estimate beyond (see THRESH_SAMPLE)."""
        D = innov.shape[1]
        if D <= self.thresh_sample:
            return ref_mod.topk_threshold_ref(innov, self.k_of(D))
        stride = D // self.thresh_sample
        sub = jnp.abs(innov[:, ::stride].astype(jnp.float32))
        kk = max(1, int(sub.shape[1] * self.density))
        return jax.lax.top_k(sub, kk)[0][:, -1:]

    def encode(self, x, key=None, err=None, use_pallas: bool = False,
               interpret: bool = True):
        _require_err(self, err)
        x32 = x.astype(jnp.float32)
        innov = x32 - err
        thresh = self._threshold(innov)
        if use_pallas:
            q = wire_quant.sparsify_topk_panel(innov, thresh,
                                               interpret=interpret)
        else:
            q = ref_mod.sparsify_topk_ref(innov, thresh)
        mirror = err + q
        return mirror, _storage_back(x.dtype), mirror

    def wire_payload(self, x, key=None, err=None):
        _require_err(self, err)  # the innovation is only defined
        # against the mirror — measuring top-k of the raw panel instead
        # would be a different (and wrong) payload
        x32 = x.astype(jnp.float32)
        innov = x32 - err
        D = x.shape[1]
        k = self.k_of(D)
        _, idx = jax.lax.top_k(jnp.abs(innov), k)
        vals = jnp.take_along_axis(innov, idx, axis=1)
        nb = self.idx_bytes(D)
        shifts = jnp.arange(nb, dtype=jnp.uint32) * 8
        packed_idx = ((idx.astype(jnp.uint32)[..., None] >> shifts)
                      & 0xFF).astype(jnp.uint8)
        return [vals.astype(jnp.float32)], [packed_idx]


CODECS = {
    "f32": F32Codec(),
    "bf16": DtypeCodec(jnp.bfloat16, "bf16"),
    "int8": Int8Codec("int8", stochastic=True, error_feedback=False),
    "int8_ef": Int8Codec("int8_ef", stochastic=True, error_feedback=True),
    "int4": Int4Codec("int4", stochastic=True, error_feedback=False),
    "int4_ef": Int4Codec("int4_ef", stochastic=True, error_feedback=True),
    "topk": TopKCodec("topk", density=0.125),
}


def get_codec(name):
    """Resolve a codec by registry name; codec instances pass through
    (lets tests build e.g. a deterministic-rounding Int8Codec)."""
    if not isinstance(name, str) and hasattr(name, "encode"):
        return name
    try:
        return CODECS[name]
    except KeyError:
        raise ValueError(
            f"unknown wire codec {name!r}; known: {sorted(CODECS)}"
        ) from None


def dtype_codec(wire_dtype):
    """Codec for the legacy ``wire_dtype=`` argument (None -> identity)."""
    if wire_dtype is None:
        return CODECS["f32"]
    wd = jnp.dtype(wire_dtype)
    if wd == jnp.dtype(jnp.bfloat16):
        return CODECS["bf16"]
    return DtypeCodec(wd, wd.name)
