"""seamless-m4t-medium [audio] — 12L d_model=1024 16H d_ff=4096 vocab=256206.

Encoder-decoder transformer (12 encoder + 12 decoder layers). The
mel-spectrogram + conv feature extractor frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings
(B, S_src, d_model) consumed by the encoder; this config describes the
transformer backbone only. vocab 256206 is padded to 256256 for 16-way TP.
[arXiv:2308.11596]
"""
from repro.configs import register
from repro.configs.base import (AttentionConfig, DistConfig, LayerSpec,
                                ModelConfig)


@register("seamless-m4t-medium")
def seamless_m4t_medium() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="audio",
        num_layers=12, d_model=1024, d_ff=4096, vocab_size=256206,
        attn=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=64,
                             rope="none"),
        layer_period=(LayerSpec(mixer="gqa", ffn="swiglu"),),
        norm="layernorm", act="relu", tie_embeddings=False,
        max_seq_len=4096, encoder_layers=12, mm_prefix=-1,  # -1: encoder input
        dist=DistConfig(agents_per_pod=16),
        source="arXiv:2308.11596 (SeamlessM4T)",
    )
