"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680.

Griffin layout: (RG-LRU, RG-LRU, local attention window=2048) repeated —
26 layers = 8 full periods + 2 trailing recurrent layers. GeGLU FFN,
head_dim=256, vocab 256000. Recurrent state + local window => sub-quadratic,
runs ``long_500k``. [arXiv:2402.19427]
"""
from repro.configs import register
from repro.configs.base import (AttentionConfig, DistConfig, LayerSpec,
                                ModelConfig, RecurrentConfig)


@register("recurrentgemma-2b")
def recurrentgemma_2b() -> ModelConfig:
    period = (LayerSpec(mixer="rglru", ffn="geglu"),
              LayerSpec(mixer="rglru", ffn="geglu"),
              LayerSpec(mixer="gqa", ffn="geglu", window=2048))
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        num_layers=26, d_model=2560, d_ff=7680, vocab_size=256000,
        attn=AttentionConfig(num_heads=10, num_kv_heads=1, head_dim=256,
                             rope="rope", rope_theta=10000.0),
        layer_period=period,
        recurrent=RecurrentConfig(width=2560, conv_size=4, lru_c=8.0),
        norm="rmsnorm", act="gelu", embed_scale=True, tie_embeddings=True,
        max_seq_len=8192,
        dist=DistConfig(agents_per_pod=16),
        source="arXiv:2402.19427 (Griffin / RecurrentGemma)",
    )
