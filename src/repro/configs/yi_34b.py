"""yi-34b [dense] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

Llama-architecture GQA, SwiGLU, RoPE, RMSNorm. [arXiv:2403.04652]
"""
from repro.configs import register
from repro.configs.base import (AttentionConfig, DistConfig, LayerSpec,
                                ModelConfig)


@register("yi-34b")
def yi_34b() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense",
        num_layers=60, d_model=7168, d_ff=20480, vocab_size=64000,
        attn=AttentionConfig(num_heads=56, num_kv_heads=8, head_dim=128,
                             rope="rope", rope_theta=5000000.0),
        layer_period=(LayerSpec(mixer="gqa", ffn="swiglu"),),
        norm="rmsnorm", act="silu", tie_embeddings=False,
        max_seq_len=4096,
        dist=DistConfig(agents_per_pod=4, loss_chunk=1024),
        source="arXiv:2403.04652 (Yi)",
    )
