"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000.

Dense-MoE hybrid: every layer has a parallel dense residual MLP (d_ff=4864)
plus a 128-expert top-2 MoE (expert d_ff=4864).
[hf:Snowflake/snowflake-arctic-base]
"""
from repro.configs import register
from repro.configs.base import (AttentionConfig, DistConfig, LayerSpec,
                                ModelConfig, MoEConfig)


@register("arctic-480b")
def arctic_480b() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe",
        num_layers=35, d_model=7168, d_ff=4864, vocab_size=32000,
        attn=AttentionConfig(num_heads=56, num_kv_heads=8, head_dim=128,
                             rope="rope", rope_theta=10000.0),
        layer_period=(LayerSpec(mixer="gqa", ffn="moe"),),
        moe=MoEConfig(num_experts=128, top_k=2, expert_ff=4864,
                      dense_ff=4864, router="softmax", capacity_factor=1.25),
        norm="rmsnorm", act="silu", tie_embeddings=False,
        max_seq_len=4096,
        dist=DistConfig(agents_per_pod=2, loss_chunk=1024),
        source="hf:Snowflake/snowflake-arctic-base",
    )
