"""olmo-1b [dense] — 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304.

Non-parametric LayerNorm (no learnable scale/bias), SwiGLU, RoPE, no biases.
[arXiv:2402.00838]
"""
from repro.configs import register
from repro.configs.base import (AttentionConfig, DistConfig, LayerSpec,
                                ModelConfig)


@register("olmo-1b")
def olmo_1b() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b", family="dense",
        num_layers=16, d_model=2048, d_ff=8192, vocab_size=50304,
        attn=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=128,
                             rope="rope", rope_theta=10000.0),
        layer_period=(LayerSpec(mixer="gqa", ffn="swiglu"),),
        norm="nonparam_ln", act="silu", tie_embeddings=True,
        max_seq_len=2048,
        dist=DistConfig(agents_per_pod=16),
        source="arXiv:2402.00838 (OLMo)",
    )
