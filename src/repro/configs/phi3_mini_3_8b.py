"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.

RoPE, SwiGLU, GQA (kv=32 == MHA at this size), RMSNorm. [arXiv:2404.14219]
"""
from repro.configs import register
from repro.configs.base import (AttentionConfig, DistConfig, LayerSpec,
                                ModelConfig)


@register("phi3-mini-3.8b")
def phi3_mini() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b", family="dense",
        num_layers=32, d_model=3072, d_ff=8192, vocab_size=32064,
        attn=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=96,
                             rope="rope", rope_theta=10000.0),
        layer_period=(LayerSpec(mixer="gqa", ffn="swiglu"),),
        norm="rmsnorm", act="silu", tie_embeddings=False,
        max_seq_len=131072,
        dist=DistConfig(agents_per_pod=16),
        source="arXiv:2404.14219 (Phi-3)",
    )
