"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.

M-RoPE (temporal/height/width sections), SwiGLU, GQA. The ViT vision encoder +
projector are a STUB per the assignment: ``input_specs()`` provides precomputed
patch embeddings (``mm_prefix`` positions) of shape (B, mm_prefix, d_model);
this config describes the language transformer backbone only.
[arXiv:2409.12191]
"""
from repro.configs import register
from repro.configs.base import (AttentionConfig, DistConfig, LayerSpec,
                                ModelConfig)


@register("qwen2-vl-72b")
def qwen2_vl_72b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm",
        num_layers=80, d_model=8192, d_ff=29568, vocab_size=152064,
        attn=AttentionConfig(num_heads=64, num_kv_heads=8, head_dim=128,
                             rope="mrope", rope_theta=1000000.0,
                             mrope_sections=(16, 24, 24)),  # sums to head_dim/2
        layer_period=(LayerSpec(mixer="gqa", ffn="swiglu"),),
        norm="rmsnorm", act="silu", tie_embeddings=False,
        max_seq_len=32768, mm_prefix=256,
        dist=DistConfig(agents_per_pod=2, loss_chunk=1024),
        source="arXiv:2409.12191 (Qwen2-VL)",
    )
