"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.

GeGLU MLP, head_dim=256, RoPE, RMSNorm, embeddings scaled by sqrt(d_model),
tied embeddings. [arXiv:2403.08295]

``long_500k`` support: we expose a sliding-window variant (window=4096, gemma-2
style local attention) selectable via ``gemma_2b_sw()``; the dry-run uses it for
the long-context decode shape (see DESIGN.md §5).
"""
from repro.configs import register
from repro.configs.base import (AttentionConfig, DistConfig, LayerSpec,
                                ModelConfig)


def _base(window=None) -> ModelConfig:
    return ModelConfig(
        name="gemma-2b", family="dense",
        num_layers=18, d_model=2048, d_ff=16384, vocab_size=256000,
        attn=AttentionConfig(num_heads=8, num_kv_heads=1, head_dim=256,
                             rope="rope", rope_theta=10000.0),
        layer_period=(LayerSpec(mixer="gqa", ffn="geglu", window=window),),
        norm="rmsnorm", act="gelu", embed_scale=True, tie_embeddings=True,
        max_seq_len=8192,
        dist=DistConfig(agents_per_pod=16),
        source="arXiv:2403.08295 (Gemma)",
    )


@register("gemma-2b")
def gemma_2b() -> ModelConfig:
    return _base()


@register("gemma-2b-sw")
def gemma_2b_sw() -> ModelConfig:
    """Sliding-window variant used only for the long_500k decode shape."""
    cfg = _base(window=4096)
    return cfg.replace(name="gemma-2b-sw")
