"""Config dataclasses for models, distribution, and input shapes.

Every assigned architecture gets one ``<arch>.py`` in this package that builds a
:class:`ModelConfig` with the exact pool spec, citing its source in the header.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Attention / mixer configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope: str = "rope"  # "rope" | "mrope" | "none"
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()  # head_dim/2 split into (t, h, w) parts
    # MLA (deepseek-v3) dims; used when a layer's mixer == "mla"
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    logits_softcap: float = 0.0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int  # d_ff of each routed expert
    shared_ff: int = 0  # d_ff of the always-on shared expert (deepseek); 0 = none
    dense_ff: int = 0  # parallel dense residual MLP (arctic); 0 = none
    router: str = "softmax"  # "softmax" | "sigmoid" (deepseek-v3)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    router_dtype: str = "float32"


@dataclass(frozen=True)
class RecurrentConfig:
    """Parameters for RG-LRU / mLSTM / sLSTM mixers."""

    width: int = 0  # recurrent width (d_rnn); 0 => d_model
    conv_size: int = 4  # temporal conv in the Griffin recurrent block
    num_heads: int = 4  # heads for m/sLSTM
    lru_c: float = 8.0  # RG-LRU exponent scale
    mlstm_chunk: int = 64  # chunk length for chunkwise-parallel mLSTM


# ---------------------------------------------------------------------------
# Layer layout
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSpec:
    """One layer = mixer sublayer + (optional) ffn sublayer."""

    mixer: str  # "gqa" | "mla" | "rglru" | "mlstm" | "slstm"
    ffn: str  # "swiglu" | "geglu" | "moe" | "none"
    window: Optional[int] = None  # sliding-window size for local attention


@dataclass(frozen=True)
class DistConfig:
    """How this architecture is laid out on the production pod(s)."""

    agents_per_pod: int = 16  # decentralized agents per 256-chip pod (training)
    # fsdp size is derived: 16 // ... see launch/mesh.py
    remat: str = "full"  # "none" | "full" | "dots"
    scan_layers: bool = True  # False => unroll (dry-run: honest cost_analysis)
    loss_chunk: int = 512  # vocab-chunked CE: tokens per chunk
    attn_block: int = 0  # >0: blockwise online-softmax attention (flash-style
    #                      XLA path; kv processed in chunks of this size)
    seq_shard: bool = False  # sequence-shard the residual stream over 'model'
    moe_dispatch_shard: str = "none"  # "none" | "tokens" | "dmodel" —
    #   shard MoE dispatch gather/scatter over fsdp by tokens or by d_model
    gossip_impl: str = "dense"  # "dense" (paper-faithful W einsum) | "collective"
    gossip_dtype: str = "float32"  # wire dtype for gossip ("bfloat16" = compressed)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | ssm | audio | hybrid
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: AttentionConfig
    layer_period: Tuple[LayerSpec, ...]  # cycled to cover num_layers
    moe: Optional[MoEConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    norm: str = "rmsnorm"  # "rmsnorm" | "nonparam_ln" | "layernorm"
    act: str = "silu"
    embed_scale: bool = False  # multiply embeddings by sqrt(d_model) (gemma)
    tie_embeddings: bool = True
    max_seq_len: int = 8192
    # encoder-decoder (seamless-m4t): encoder depth; 0 => decoder-only
    encoder_layers: int = 0
    # multimodal stub: number of prefix embedding positions fed by the frontend
    mm_prefix: int = 0  # vlm: patch embeddings; audio: frame embeds feed encoder
    mtp_depth: int = 0  # deepseek multi-token-prediction extra blocks
    dense_ff_first_k: int = 0  # deepseek: first k layers use dense FFN
    dense_ff_size: int = 0  # width of those dense layers
    dist: DistConfig = field(default_factory=DistConfig)
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    source: str = ""  # citation

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        period = self.layer_period
        reps = (self.num_layers + len(period) - 1) // len(period)
        return tuple(period[i % len(period)] for i in range(self.num_layers))

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over 16-way model TP.

        Contract: the LM head projects to ``padded_vocab`` columns and the
        padding tail carries random-init weights — anything that samples
        from head logits MUST mask columns >= ``vocab_size`` to -inf first
        (serving does this in ``repro.serving.engine.sample_token``)."""
        return ((self.vocab_size + 255) // 256) * 256

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self, d_model: int = 256, layers: Optional[int] = None,
                vocab: int = 512, experts: int = 4) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests (<=2 layers, d<=512)."""
        layers = layers if layers is not None else min(2, self.num_layers)
        period = self.layer_period[: max(1, min(len(self.layer_period), layers))]
        head_dim = 32
        n_heads = max(2, d_model // 64)
        n_kv = 1 if self.attn.num_kv_heads == 1 else min(self.attn.num_kv_heads, 2)
        attn = dataclasses.replace(
            self.attn,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=head_dim,
            q_lora_rank=min(self.attn.q_lora_rank, 64) if self.attn.q_lora_rank else 0,
            kv_lora_rank=min(self.attn.kv_lora_rank, 32) if self.attn.kv_lora_rank else 0,
            qk_nope_dim=32 if self.attn.qk_nope_dim else 0,
            qk_rope_dim=16 if self.attn.qk_rope_dim else 0,
            v_head_dim=32 if self.attn.v_head_dim else 0,
            mrope_sections=(8, 4, 4) if self.attn.mrope_sections else (),
        )
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=experts, top_k=min(self.moe.top_k, 2),
                expert_ff=d_model * 2, shared_ff=d_model * 2 if self.moe.shared_ff else 0,
                dense_ff=d_model * 2 if self.moe.dense_ff else 0)
        rec = None
        if self.recurrent is not None:
            rec = dataclasses.replace(
                self.recurrent, width=0, num_heads=2, mlstm_chunk=16)
        period = tuple(
            dataclasses.replace(s, window=min(s.window, 64) if s.window else None)
            for s in period)
        return self.replace(
            num_layers=layers, d_model=d_model, d_ff=d_model * 4,
            vocab_size=vocab, attn=attn, layer_period=period, moe=moe,
            recurrent=rec, max_seq_len=256,
            encoder_layers=min(self.encoder_layers, layers),
            mm_prefix=min(self.mm_prefix, 8),
            mtp_depth=min(self.mtp_depth, 1),
            dense_ff_first_k=min(self.dense_ff_first_k, 1),
            dense_ff_size=d_model * 4 if self.dense_ff_size else 0,
            dist=dataclasses.replace(self.dist, agents_per_pod=4, loss_chunk=64),
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
