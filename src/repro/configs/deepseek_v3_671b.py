"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff=2048 vocab=129280.

MLA attention (q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64, v 128),
MoE with 1 shared + 256 routed experts top-8 (expert d_ff=2048, sigmoid
router), first 3 layers dense (d_ff 18432), MTP depth 1. [arXiv:2412.19437]
"""
from repro.configs import register
from repro.configs.base import (AttentionConfig, DistConfig, LayerSpec,
                                ModelConfig, MoEConfig)


@register("deepseek-v3-671b")
def deepseek_v3_671b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        num_layers=61, d_model=7168, d_ff=2048, vocab_size=129280,
        attn=AttentionConfig(num_heads=128, num_kv_heads=128, head_dim=128,
                             rope="rope", rope_theta=10000.0,
                             q_lora_rank=1536, kv_lora_rank=512,
                             qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
        layer_period=(LayerSpec(mixer="mla", ffn="moe"),),
        moe=MoEConfig(num_experts=256, top_k=8, expert_ff=2048,
                      shared_ff=2048, router="sigmoid", capacity_factor=1.25,
                      aux_loss_weight=0.001),
        norm="rmsnorm", act="silu", tie_embeddings=False,
        max_seq_len=131072, mtp_depth=1,
        dense_ff_first_k=3, dense_ff_size=18432,
        dist=DistConfig(agents_per_pod=2, loss_chunk=1024),
        source="arXiv:2412.19437 (DeepSeek-V3)",
    )
