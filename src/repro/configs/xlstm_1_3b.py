"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304.

sLSTM + mLSTM blocks in a 7:1 ratio (xLSTM[7:1]): each period is 7 mLSTM
blocks followed by 1 sLSTM block; 48 layers = 6 periods. ``d_ff=0``: blocks
carry their own up/down projections, there is no separate FFN sublayer.
mLSTM uses the chunkwise-parallel form (sub-quadratic), sLSTM a sequential
scan — both expose O(1)-per-token recurrent decode state, so this arch runs
``long_500k``. [arXiv:2405.04517]
"""
from repro.configs import register
from repro.configs.base import (AttentionConfig, DistConfig, LayerSpec,
                                ModelConfig, RecurrentConfig)


@register("xlstm-1.3b")
def xlstm_1_3b() -> ModelConfig:
    period = tuple([LayerSpec(mixer="mlstm", ffn="none")] * 7 +
                   [LayerSpec(mixer="slstm", ffn="none")])
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        num_layers=48, d_model=2048, d_ff=0, vocab_size=50304,
        attn=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=512,
                             rope="none"),
        layer_period=period,
        recurrent=RecurrentConfig(width=0, num_heads=4, mlstm_chunk=64),
        norm="layernorm", act="gelu", tie_embeddings=False,
        max_seq_len=2048,
        dist=DistConfig(agents_per_pod=16),
        source="arXiv:2405.04517 (xLSTM)",
    )
