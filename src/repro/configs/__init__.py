"""Architecture registry. ``get_config(arch_id)`` returns the full pool config."""
from __future__ import annotations

from repro.configs.base import (AttentionConfig, DistConfig, INPUT_SHAPES,
                                LayerSpec, ModelConfig, MoEConfig,
                                RecurrentConfig, ShapeConfig)

_REGISTRY = {}


def register(name):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(arch: str) -> ModelConfig:
    _load_all()
    key = arch.replace("_", "-")
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[key]()


def list_archs():
    _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from repro.configs import (arctic_480b, deepseek_v3_671b, gemma_2b,  # noqa: F401
                               olmo_1b, phi3_mini_3_8b, qwen2_vl_72b,
                               recurrentgemma_2b, seamless_m4t_medium,
                               xlstm_1_3b, yi_34b)


__all__ = ["get_config", "list_archs", "register", "ModelConfig", "ShapeConfig",
           "INPUT_SHAPES", "AttentionConfig", "MoEConfig", "RecurrentConfig",
           "LayerSpec", "DistConfig"]
