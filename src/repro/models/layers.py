"""Shared neural-net building blocks (pure-jnp, vmap-friendly).

Every ``init_*`` has a matching ``spec_*`` returning a logical-axis tree of
identical structure (asserted by tests for every architecture).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import constrain
from repro.models.sharding import logical as L


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(rng, d_in, d_out, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(norm_kind: str, d: int, dtype=jnp.float32):
    if norm_kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if norm_kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if norm_kind == "nonparam_ln":
        return {}
    raise ValueError(norm_kind)


def spec_norm(norm_kind: str):
    if norm_kind == "rmsnorm":
        return {"scale": L(None)}
    if norm_kind == "layernorm":
        return {"scale": L(None), "bias": L(None)}
    return {}


def apply_norm(params, x, norm_kind: str, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    if norm_kind == "rmsnorm":
        x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + eps)
        x = x * params["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(x, -1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), -1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + eps)
        if norm_kind == "layernorm":
            x = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return x.astype(dt)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta=10000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta=1000000.0):
    """Multimodal RoPE (Qwen2-VL). positions3: (3, ..., S) t/h/w position ids;
    ``sections`` splits the hd/2 frequency dims into (t, h, w) groups."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    # build per-dim positions by section
    sec = jnp.concatenate([jnp.full((s,), i, dtype=jnp.int32)
                           for i, s in enumerate(sections)])  # (hd/2,)
    # positions3: (3, B, S) -> select per freq-dim
    pos = jnp.take(positions3, sec, axis=0)  # (hd/2, B, S)
    pos = jnp.moveaxis(pos, 0, -1)  # (B, S, hd/2)
    angles = pos.astype(jnp.float32) * freqs  # (B, S, hd/2)
    angles = angles[..., None, :]  # (B, S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(rng, d, d_ff, gated=True, dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    p = {"w_in": dense_init(ks[0], d, d_ff, dtype),
         "w_out": dense_init(ks[1], d_ff, d, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d, d_ff, dtype)
    return p


def spec_mlp(gated=True):
    p = {"w_in": L("fsdp", "model"), "w_out": L("model", "fsdp")}
    if gated:
        p["w_gate"] = L("fsdp", "model")
    return p


def apply_mlp(params, x, act_fn, gated=True):
    h = x @ params["w_in"]
    h = constrain(h, ("fsdp", None, "model"))
    if gated:
        h = act_fn(x @ params["w_gate"]) * h
    else:
        h = act_fn(h)
    return h @ params["w_out"]


# ---------------------------------------------------------------------------
# embeddings + chunked cross-entropy
# ---------------------------------------------------------------------------


def init_embed(rng, vocab, d, dtype=jnp.float32):
    return {"table": (jax.random.normal(rng, (vocab, d), jnp.float32)
                      * (1.0 / np.sqrt(d))).astype(dtype)}


def spec_embed():
    return {"table": L("fsdp", "model")}


def embed_tokens(params, tokens, scale=False):
    x = jnp.take(params["table"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(np.sqrt(params["table"].shape[-1]), x.dtype)
    return x


def logits_fn(head_w, h):
    """h: (..., d); head_w: (d, V)."""
    return h @ head_w


def chunked_softmax_xent(h, head_w, targets, mask, chunk: int):
    """Cross-entropy without materialising (B,S,V) logits.

    h: (B, S, d); head_w: (d, V); targets: (B, S) int32; mask: (B, S) {0,1}.
    Scans over S in chunks, computing per-chunk logits -> logsumexp -> nll.
    Returns (sum_nll, sum_mask).
    """
    B, S, d = h.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    def chunk_loss(hc, tc, mc):
        lg = (hc @ head_w).astype(jnp.float32)  # (B, c, V)
        lg = constrain(lg, ("fsdp", None, "model"))
        lse = jax.nn.logsumexp(lg, axis=-1)
        tgt = jnp.take_along_axis(lg, tc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - tgt) * mc)

    def body(carry, xs):
        hc, tc, mc = xs
        return carry + chunk_loss(hc, tc, mc), None

    hs = h[:, : n * chunk].reshape(B, n, chunk, d).swapaxes(0, 1)
    ts = targets[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    ms = mask[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ts, ms))
    if rem:
        total = total + chunk_loss(h[:, n * chunk:], targets[:, n * chunk:],
                                   mask[:, n * chunk:])
    return total, jnp.sum(mask.astype(jnp.float32))
