"""Recurrent mixers: RG-LRU (Griffin/RecurrentGemma), mLSTM and sLSTM (xLSTM).

All three expose the same interface as attention mixers:
    forward(params, x, *, cfg, mode, state) -> (y, new_state)
with ``state`` the O(1)-per-token decode state (None in train mode), making
``long_500k`` decode feasible.

mLSTM uses the chunkwise-parallel form (sub-quadratic in S): within-chunk
quadratic attention-like weights + an inter-chunk recurrent (C, n, m) state
carried by ``lax.scan``; validated against the naive per-step recurrence in
tests. sLSTM is inherently sequential (recurrent R on h) -> ``lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.sharding import constrain, constrain_pick
from repro.models.sharding import logical as L


# ---------------------------------------------------------------------------
# RG-LRU (Real-Gated Linear Recurrent Unit) block
# ---------------------------------------------------------------------------


def _rnn_width(cfg: ModelConfig) -> int:
    r = cfg.recurrent
    return r.width or cfg.d_model


def init_rglru(rng, cfg: ModelConfig, dtype=jnp.float32):
    d, dr = cfg.d_model, _rnn_width(cfg)
    r = cfg.recurrent
    ks = jax.random.split(rng, 7)
    # Lambda init so that a = sigmoid(lam) ~ U[0.9, 0.999]^(1/c) style decays
    u = jax.random.uniform(ks[5], (dr,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    a = u ** 0.5
    lam = jnp.log(a / (1 - a))
    return {
        "w_gate_branch": dense_init(ks[0], d, dr, dtype),
        "w_x": dense_init(ks[1], d, dr, dtype),
        "conv_w": (jax.random.normal(ks[2], (r.conv_size, dr), jnp.float32)
                   * (1.0 / np.sqrt(r.conv_size))).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_a": dense_init(ks[3], dr, dr, dtype),
        "b_a": jnp.zeros((dr,), dtype),
        "w_i": dense_init(ks[4], dr, dr, dtype),
        "b_i": jnp.zeros((dr,), dtype),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(ks[6], dr, d, dtype),
    }


def spec_rglru():
    return {"w_gate_branch": L("fsdp", "model"), "w_x": L("fsdp", "model"),
            "conv_w": L(None, "model"), "conv_b": L("model"),
            "w_a": L("fsdp", "model"), "b_a": L("model"),
            "w_i": L("fsdp", "model"), "b_i": L("model"),
            "lam": L("model"), "w_out": L("model", "fsdp")}


def _causal_conv(u, w, b, carry=None):
    """u: (B,S,dr); w: (K,dr) depthwise causal conv. carry: (B,K-1,dr)."""
    K = w.shape[0]
    if carry is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    else:
        pad = carry.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)  # (B, S+K-1, dr)
    out = sum(full[:, i:i + u.shape[1]] * w[i] for i in range(K))
    new_carry = full[:, full.shape[1] - (K - 1):]
    return out + b, new_carry


def rglru_forward(params, x, *, cfg: ModelConfig, mode: str, state=None):
    r = cfg.recurrent
    B, S, _ = x.shape
    gate_branch = jax.nn.gelu(x @ params["w_gate_branch"])
    gate_branch = constrain(gate_branch, ("fsdp", None, "model"))
    u = x @ params["w_x"]
    u = constrain(u, ("fsdp", None, "model"))
    conv_carry = None if state is None else state["conv"]
    u, new_conv = _causal_conv(u, params["conv_w"], params["conv_b"], conv_carry)

    rt = jax.nn.sigmoid(u @ params["w_a"] + params["b_a"]).astype(jnp.float32)
    it = jax.nn.sigmoid(u @ params["w_i"] + params["b_i"])
    log_a = -jax.nn.softplus(-params["lam"])  # log sigmoid(lam) = log a
    log_at = r.lru_c * rt * log_a  # (B,S,dr)
    at = jnp.exp(log_at)
    gated_in = (jnp.sqrt(jnp.maximum(1.0 - at * at, 1e-12))
                * (it * u).astype(jnp.float32))

    h0 = None if state is None else state["h"].astype(jnp.float32)
    if mode == "decode" and S == 1:
        h = at[:, 0] * h0 + gated_in[:, 0]
        hs = h[:, None]
    else:
        # h_t = a_t h_{t-1} + b_t via associative scan over S
        def comb(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        a_seq, b_seq = jnp.swapaxes(at, 0, 1), jnp.swapaxes(gated_in, 0, 1)
        if h0 is not None:
            b_seq = b_seq.at[0].add(a_seq[0] * h0)
        _, hs = jax.lax.associative_scan(comb, (a_seq, b_seq))
        hs = jnp.swapaxes(hs, 0, 1)  # (B,S,dr)
        h = hs[:, -1]
    y = (gate_branch * hs.astype(x.dtype)) @ params["w_out"]
    new_state = None
    if mode != "train":
        new_state = {"h": h, "conv": new_conv}
    return y, new_state


def init_rglru_state(cfg: ModelConfig, B: int, dtype=jnp.float32):
    dr, K = _rnn_width(cfg), cfg.recurrent.conv_size
    return {"h": jnp.zeros((B, dr), jnp.float32),
            "conv": jnp.zeros((B, K - 1, dr), dtype)}


def spec_rglru_state():
    return {"h": L("data", "model"), "conv": L("data", None, "model")}


# ---------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM) — chunkwise-parallel
# ---------------------------------------------------------------------------


def init_mlstm(rng, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.recurrent.num_heads
    ks = jax.random.split(rng, 6)
    return {
        "wq": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "w_if": dense_init(ks[3], d, 2 * H, dtype),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]).astype(dtype),
        "w_og": dense_init(ks[4], d, d, dtype),
        "gn_scale": jnp.ones((d,), dtype),
        "w_out": dense_init(ks[5], d, d, dtype),
    }


def spec_mlstm():
    return {"wq": L("fsdp", "model"), "wk": L("fsdp", "model"),
            "wv": L("fsdp", "model"), "w_if": L("fsdp", None),
            "b_if": L(None), "w_og": L("fsdp", "model"),
            "gn_scale": L("model"), "w_out": L("model", "fsdp")}


def _headify(x, H):
    B, S, d = x.shape
    return x.reshape(B, S, H, d // H).transpose(0, 2, 1, 3)  # (B,H,S,dh)


def _mlstm_chunk(q, k, v, logf, logi, state):
    """One chunk. q,k,v: (B,H,c,dh); logf/logi: (B,H,c); state (C,n,m)."""
    C0, n0, m0 = state  # C0:(B,H,dh,dh) n0:(B,H,dh) m0:(B,H)
    c = q.shape[2]
    b = jnp.cumsum(logf, axis=-1)  # (B,H,c)
    u = logi - b  # (B,H,c)
    M = jnp.maximum(m0[..., None], jax.lax.cummax(u, axis=2))  # (B,H,c)
    # within-chunk decay matrix D[t,s] = exp(b_t - b_s + logi_s - (b_t + M_t))
    D = jnp.exp(u[..., None, :] - M[..., None])  # (B,H,c,c) [t,s]
    tri = jnp.tril(jnp.ones((c, c), bool))
    D = jnp.where(tri, D, 0.0)
    D = constrain_pick(D, [(-4, "fsdp")], [(-3, "model"), (-2, "model")])
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) * D  # (B,H,c,c)
    scores = constrain_pick(scores, [(-4, "fsdp")],
                            [(-3, "model"), (-2, "model")])
    intra = jnp.einsum("bhts,bhsd->bhtd", scores, v)
    # denominator uses the gate weights D (without q.k): n_t = sum_s D[t,s] k_s
    intra_n = jnp.einsum("bhts,bhsd->bhtd", D, k)
    decay0 = jnp.exp(m0[..., None] - M)  # (B,H,c)
    inter = jnp.einsum("bhtd,bhde->bhte", q, C0) * decay0[..., None]
    inter_n = jnp.einsum("bhtd,bhd->bht", q, n0) * decay0
    m_t = b + M
    num = intra + inter
    # denominator: n_t . q_t in the same stabilised space
    n_dot_q = inter_n + jnp.sum(intra_n * q, axis=-1)
    h = num / jnp.maximum(jnp.abs(n_dot_q), jnp.exp(-m_t))[..., None]
    # end-of-chunk state
    b_end = b[..., -1]  # (B,H)
    M_end = jnp.maximum(m0, jnp.max(u, axis=-1))
    a_w = jnp.exp(u - M_end[..., None])  # (B,H,c)
    C1 = (jnp.exp(m0 - M_end)[..., None, None] * C0
          + jnp.einsum("bhs,bhsd,bhse->bhde", a_w, k, v))
    C1 = constrain_pick(C1, [(-4, "fsdp")], [(-3, "model"), (-2, "model")])
    n1 = (jnp.exp(m0 - M_end)[..., None] * n0
          + jnp.einsum("bhs,bhsd->bhd", a_w, k))
    m1 = b_end + M_end
    return h, (C1, n1, m1)


def mlstm_forward(params, x, *, cfg: ModelConfig, mode: str, state=None):
    r = cfg.recurrent
    H = r.num_heads
    B, S, d = x.shape
    dh = d // H
    _hp = [(-3, "model"), (-1, "model")]  # heads else head_dim
    q = _headify(x @ params["wq"], H) * (1.0 / np.sqrt(dh))
    k = _headify(x @ params["wk"], H) * (1.0 / np.sqrt(dh))
    v = _headify(x @ params["wv"], H)
    q = constrain_pick(q, [(-4, "fsdp")], _hp)
    k = constrain_pick(k, [(-4, "fsdp")], _hp)
    v = constrain_pick(v, [(-4, "fsdp")], _hp)
    gates = (x @ params["w_if"] + params["b_if"]).astype(jnp.float32)
    logi = gates[..., :H].transpose(0, 2, 1)  # (B,H,S) pre-act i
    logf = jax.nn.log_sigmoid(gates[..., H:]).transpose(0, 2, 1)

    if state is None:
        st = (jnp.zeros((B, H, dh, dh), jnp.float32),
              jnp.zeros((B, H, dh), jnp.float32),
              jnp.full((B, H), -1e30, jnp.float32))
    else:
        st = (state["C"], state["n"], state["m"])

    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    if S == 1 and mode == "decode":
        h, st = _mlstm_chunk(qf, kf, vf, logf, logi, st)
    else:
        c = min(r.mlstm_chunk, S)
        nch = S // c
        rem = S - nch * c

        def body(carry, xs):
            qc, kc, vc, lfc, lic = xs
            h, carry = _mlstm_chunk(qc, kc, vc, lfc, lic, carry)
            return carry, h

        def split(t):  # (B,H,nch*c,...) -> (nch, B,H,c,...)
            t = t[:, :, : nch * c]
            return jnp.moveaxis(
                t.reshape(t.shape[0], t.shape[1], nch, c, *t.shape[3:]), 2, 0)

        st, hs = jax.lax.scan(body, st,
                              (split(qf), split(kf), split(vf),
                               split(logf), split(logi)))
        h = jnp.moveaxis(hs, 0, 2).reshape(B, H, nch * c, dh)
        if rem:  # trailing partial chunk
            sl = slice(nch * c, S)
            h_tail, st = _mlstm_chunk(qf[:, :, sl], kf[:, :, sl],
                                      vf[:, :, sl], logf[:, :, sl],
                                      logi[:, :, sl], st)
            h = jnp.concatenate([h, h_tail], axis=2)

    h = h.transpose(0, 2, 1, 3)  # (B,S,H,dh)
    # per-head group norm
    mu = jnp.mean(h, -1, keepdims=True)
    var = jnp.mean(jnp.square(h - mu), -1, keepdims=True)
    h = ((h - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(B, S, d)
    h = h * params["gn_scale"]
    og = jax.nn.sigmoid(x @ params["w_og"])
    y = (og * h.astype(x.dtype)) @ params["w_out"]
    new_state = None
    if mode != "train":
        new_state = {"C": st[0], "n": st[1], "m": st[2]}
    return y, new_state


def init_mlstm_state(cfg: ModelConfig, B: int):
    H = cfg.recurrent.num_heads
    dh = cfg.d_model // H
    return {"C": jnp.zeros((B, H, dh, dh), jnp.float32),
            "n": jnp.zeros((B, H, dh), jnp.float32),
            "m": jnp.full((B, H), -1e30, jnp.float32)}


def spec_mlstm_state():
    return {"C": L("data", "model", None, None), "n": L("data", "model", None),
            "m": L("data", "model")}


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory LSTM with recurrent connections) — sequential scan
# ---------------------------------------------------------------------------


def init_slstm(rng, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.recurrent.num_heads
    dh = d // H
    ks = jax.random.split(rng, 4)
    return {
        "w_gates": dense_init(ks[0], d, 4 * d, dtype),  # z, i, f, o
        "r_gates": (jax.random.normal(ks[1], (4, H, dh, dh), jnp.float32)
                    * (1.0 / np.sqrt(dh))).astype(dtype),
        "b_gates": jnp.concatenate(
            [jnp.zeros((d,)), jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
             jnp.zeros((d,))]).astype(dtype),
        "gn_scale": jnp.ones((d,), dtype),
        "w_out": dense_init(ks[3], d, d, dtype),
    }


def spec_slstm():
    return {"w_gates": L("fsdp", None), "r_gates": L(None, "model", None, None),
            "b_gates": L(None), "gn_scale": L("model"),
            "w_out": L("model", "fsdp")}


def _slstm_step(params, carry, wx_t, H, dh):
    """carry: (c, n, h, m) each (B, d=H*dh); wx_t: (B, 4d) input projection."""
    c0, n0, h0, m0 = carry
    B = c0.shape[0]
    h_heads = h0.reshape(B, H, dh)
    rec = jnp.einsum("bhd,ghde->bghe", h_heads.astype(jnp.float32),
                     params["r_gates"].astype(jnp.float32)).reshape(B, 4, H * dh)
    pre = wx_t.astype(jnp.float32).reshape(B, 4, H * dh) + rec
    z = jnp.tanh(pre[:, 0])
    i_t = pre[:, 1]
    f_t = pre[:, 2]
    o = jax.nn.sigmoid(pre[:, 3])
    logf = jax.nn.log_sigmoid(f_t)
    m1 = jnp.maximum(logf + m0, i_t)
    ip = jnp.exp(i_t - m1)
    fp = jnp.exp(logf + m0 - m1)
    c1 = fp * c0 + ip * z
    n1 = fp * n0 + ip
    h1 = o * (c1 / jnp.maximum(n1, 1e-9))
    return (c1, n1, h1, m1), h1


def slstm_forward(params, x, *, cfg: ModelConfig, mode: str, state=None):
    r = cfg.recurrent
    H = r.num_heads
    B, S, d = x.shape
    dh = d // H
    wx = x @ params["w_gates"] + params["b_gates"]  # (B,S,4d)
    wx = constrain(wx, ("fsdp", None, "model"))
    if state is None:
        carry = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(3)) + (
            jnp.full((B, d), -1e30, jnp.float32),)
    else:
        carry = (state["c"], state["n"], state["h"], state["m"])

    if S == 1 and mode == "decode":
        carry, h1 = _slstm_step(params, carry, wx[:, 0], H, dh)
        hs = h1[:, None]
    else:
        def body(c, wx_t):
            return _slstm_step(params, c, wx_t, H, dh)
        carry, hs = jax.lax.scan(body, carry, jnp.swapaxes(wx, 0, 1))
        hs = jnp.swapaxes(hs, 0, 1)  # (B,S,d)

    # per-head group norm
    hh = hs.reshape(B, S, H, dh)
    mu = jnp.mean(hh, -1, keepdims=True)
    var = jnp.mean(jnp.square(hh - mu), -1, keepdims=True)
    hn = ((hh - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(B, S, d)
    y = (hn * params["gn_scale"]).astype(x.dtype) @ params["w_out"]
    new_state = None
    if mode != "train":
        new_state = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    return y, new_state


def init_slstm_state(cfg: ModelConfig, B: int):
    d = cfg.d_model
    return {"c": jnp.zeros((B, d), jnp.float32),
            "n": jnp.zeros((B, d), jnp.float32),
            "h": jnp.zeros((B, d), jnp.float32),
            "m": jnp.full((B, d), -1e30, jnp.float32)}


def spec_slstm_state():
    return {"c": L("data", "model"), "n": L("data", "model"),
            "h": L("data", "model"), "m": L("data", "model")}
