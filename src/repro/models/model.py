"""Public model API: build_model(cfg) -> Model.

A Model bundles init / loss / prefill / decode for one architecture,
including the multimodal stubs (patch/frame embeddings provided as inputs),
the optional encoder stack (seamless-m4t) and the optional MTP head
(deepseek-v3). Everything is pure-jnp and vmap-able over a leading agent
axis (used by core/dsgd.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.models.layers import (apply_norm, chunked_softmax_xent,
                                 embed_tokens, init_embed, init_norm,
                                 spec_embed, spec_norm)
from repro.models.sharding import logical as L

MTP_WEIGHT = 0.3


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


@dataclass
class Model:
    cfg: ModelConfig
    init_params: Callable
    param_spec: Callable
    loss_fn: Callable  # (params, batch, rng) -> (loss, metrics)
    prefill: Callable  # (params, batch) -> (logits_last, caches)
    decode_step: Callable  # (params, caches, tokens, index) -> (logits, caches)
    init_cache: Callable  # (B, seq_len, dtype) -> caches
    cache_spec: Callable  # () -> logical spec tree
    input_specs: Callable  # (shape, agents) -> dict of ShapeDtypeStructs


def build_model(cfg: ModelConfig) -> Model:
    dt = _dtype(cfg.param_dtype)
    is_encdec = cfg.encoder_layers > 0
    has_mm_prefix = cfg.mm_prefix > 0  # vlm patch prefix
    V = cfg.padded_vocab

    # ----------------------------------------------------------------- init
    def init_params(rng):
        ks = jax.random.split(rng, 6)
        p = {"embed": init_embed(ks[0], V, cfg.d_model, dt),
             "final_norm": init_norm(cfg.norm, cfg.d_model, dt),
             "decoder": tfm.init_stack(ks[1], cfg, cross=is_encdec, dtype=dt)}
        if not cfg.tie_embeddings:
            p["head"] = {"w": (jax.random.normal(
                ks[2], (cfg.d_model, V), jnp.float32)
                * (1.0 / np.sqrt(cfg.d_model))).astype(dt)}
        if is_encdec:
            enc_cfg = cfg.replace(num_layers=cfg.encoder_layers,
                                  dense_ff_first_k=0)
            p["encoder"] = tfm.init_stack(ks[3], enc_cfg, dtype=dt)
            p["enc_norm"] = init_norm(cfg.norm, cfg.d_model, dt)
        if cfg.mtp_depth:
            p["mtp"] = {
                "proj": (jax.random.normal(
                    ks[4], (2 * cfg.d_model, cfg.d_model), jnp.float32)
                    * (1.0 / np.sqrt(2 * cfg.d_model))).astype(dt),
                "block": tfm._stacked_init(
                    ks[5], cfg.mtp_depth,
                    lambda k: tfm.init_block(k, cfg, cfg.layer_period[0],
                                             dtype=dt)),
                "norm": init_norm(cfg.norm, cfg.d_model, dt),
            }
        return p

    def param_spec():
        p = {"embed": spec_embed(),
             "final_norm": spec_norm(cfg.norm),
             "decoder": tfm.spec_stack(cfg, cross=is_encdec)}
        if not cfg.tie_embeddings:
            p["head"] = {"w": L("fsdp", "model")}
        if is_encdec:
            enc_cfg = cfg.replace(num_layers=cfg.encoder_layers,
                                  dense_ff_first_k=0)
            p["encoder"] = tfm.spec_stack(enc_cfg)
            p["enc_norm"] = spec_norm(cfg.norm)
        if cfg.mtp_depth:
            p["mtp"] = {"proj": L("fsdp", None),
                        "block": tfm.spec_block(cfg, cfg.layer_period[0]),
                        "norm": spec_norm(cfg.norm)}
        return p

    def head_w(params):
        if cfg.tie_embeddings:
            return params["embed"]["table"].T
        return params["head"]["w"]

    # -------------------------------------------------------------- encoder
    def run_encoder(params, frame_embeds):
        enc_cfg = cfg.replace(num_layers=cfg.encoder_layers,
                              dense_ff_first_k=0)
        B, S, _ = frame_embeds.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        h, _, _ = tfm.apply_stack(params["encoder"], frame_embeds,
                                  cfg=enc_cfg, mode="train", positions=pos,
                                  causal=False)
        return apply_norm(params["enc_norm"], h, cfg.norm)

    # ------------------------------------------------------------- embedder
    def embed_inputs(params, batch):
        """Returns (x, positions, positions3, loss_mask_prefix)."""
        tokens = batch["tokens"]
        B, S_tok = tokens.shape
        x = embed_tokens(params["embed"], tokens, scale=cfg.embed_scale)
        if has_mm_prefix and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([pe, x], axis=1)
        S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        positions3 = batch.get("positions3")
        if cfg.attn.rope == "mrope" and positions3 is None:
            positions3 = jnp.broadcast_to(positions[None], (3, B, S))
        return x, positions, positions3

    # ----------------------------------------------------------------- loss
    def loss_fn(params, batch, rng=None):
        x, positions, positions3 = embed_inputs(params, batch)
        enc_out = None
        if is_encdec:
            enc_out = run_encoder(params, batch["frame_embeds"])
        h, _, aux = tfm.apply_stack(params["decoder"], x, cfg=cfg,
                                    mode="train", positions=positions,
                                    positions3=positions3, enc_out=enc_out)
        h = apply_norm(params["final_norm"], h, cfg.norm)
        if has_mm_prefix and "patch_embeds" in batch:
            h = h[:, batch["patch_embeds"].shape[1]:]
        targets = batch["targets"]
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(targets, jnp.float32)
        hw = head_w(params)
        nll, count = chunked_softmax_xent(h, hw, targets, mask,
                                          cfg.dist.loss_chunk)
        loss = nll / jnp.maximum(count, 1.0)
        metrics = {"nll": loss, "aux": aux}
        if cfg.mtp_depth:
            # multi-token prediction: predict t+2 from h_i ++ emb(t_{i+1})
            emb_next = embed_tokens(params["embed"], batch["tokens"],
                                    scale=cfg.embed_scale)
            hm = jnp.concatenate([h[:, :-1], emb_next[:, 1:]], axis=-1)
            hm = hm @ params["mtp"]["proj"]
            blk = jax.tree.map(lambda p: p[0], params["mtp"]["block"])
            hm, _, _ = tfm.apply_block(blk, hm, cfg=cfg,
                                       lspec=cfg.layer_period[0],
                                       mode="train",
                                       positions=positions[:, :-1])
            hm = apply_norm(params["mtp"]["norm"], hm, cfg.norm)
            mtp_nll, mtp_cnt = chunked_softmax_xent(
                hm[:, :-1], hw, targets[:, 2:], mask[:, 2:],
                cfg.dist.loss_chunk)
            mtp_loss = mtp_nll / jnp.maximum(mtp_cnt, 1.0)
            metrics["mtp"] = mtp_loss
            loss = loss + MTP_WEIGHT * mtp_loss
        loss = loss + aux
        metrics["loss"] = loss
        return loss, metrics

    # ------------------------------------------------------------- serving
    def prefill(params, batch, max_len: Optional[int] = None):
        x, positions, positions3 = embed_inputs(params, batch)
        enc_out = None
        if is_encdec:
            enc_out = run_encoder(params, batch["frame_embeds"])
        S = x.shape[1]
        h, caches, _ = tfm.apply_stack(params["decoder"], x, cfg=cfg,
                                       mode="prefill", positions=positions,
                                       positions3=positions3,
                                       enc_out=enc_out,
                                       cache_max_len=max_len or S)
        h = apply_norm(params["final_norm"], h[:, -1:], cfg.norm)
        logits = (h @ head_w(params)).astype(jnp.float32)[:, 0]
        return logits, caches

    def decode_step(params, caches, tokens, index):
        """tokens: (B, 1) int32; index: absolute position(s) — a scalar
        shared by the whole batch, or a (B,) vector when every row sits at
        its own depth (continuous batching over slots)."""
        B = tokens.shape[0]
        x = embed_tokens(params["embed"], tokens, scale=cfg.embed_scale)
        idx = jnp.asarray(index, jnp.int32)
        positions = (idx.reshape(B, 1) if idx.ndim
                     else jnp.full((B, 1), idx, jnp.int32))
        positions3 = None
        if cfg.attn.rope == "mrope":
            positions3 = jnp.broadcast_to(positions[None], (3, B, 1))
        h, new_caches, _ = tfm.apply_stack(params["decoder"], x, cfg=cfg,
                                           mode="decode", positions=positions,
                                           positions3=positions3,
                                           caches=caches, index=index)
        h = apply_norm(params["final_norm"], h, cfg.norm)
        logits = (h @ head_w(params)).astype(jnp.float32)[:, 0]
        return logits, new_caches

    def init_cache(B, seq_len, dtype=None, enc_len: int = 0):
        dtype = dtype or dt
        return tfm.init_stack_cache(cfg, B, seq_len, cross=is_encdec,
                                    enc_len=enc_len or seq_len, dtype=dtype)

    def cache_spec():
        return tfm.spec_stack_cache(cfg, cross=is_encdec)

    # --------------------------------------------------------- input specs
    def input_specs(shape: ShapeConfig, agents: Optional[int] = None,
                    dtype=jnp.float32) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of a step.

        For training the global batch is split over ``agents`` with a leading
        agent axis; serving shapes have no agent axis.
        """
        S, B = shape.seq_len, shape.global_batch

        def sds(shp, dty=jnp.int32):
            return jax.ShapeDtypeStruct(shp, dty)

        if shape.kind == "train":
            m = agents or 1
            b = B // m
            lead = (m, b) if agents else (b,)
            d = {"tokens": sds(lead + (S,)),
                 "targets": sds(lead + (S,)),
                 "mask": sds(lead + (S,), jnp.float32)}
            if has_mm_prefix:
                # patch prefix replaces the first mm_prefix token positions
                d["tokens"] = sds(lead + (S - cfg.mm_prefix,))
                d["targets"] = sds(lead + (S - cfg.mm_prefix,))
                d["mask"] = sds(lead + (S - cfg.mm_prefix,), jnp.float32)
                d["patch_embeds"] = sds(lead + (cfg.mm_prefix, cfg.d_model),
                                        dtype)
            if is_encdec:
                d["frame_embeds"] = sds(lead + (S, cfg.d_model), dtype)
            return d
        if shape.kind == "prefill":
            d = {"tokens": sds((B, S))}
            if has_mm_prefix:
                d["tokens"] = sds((B, S - cfg.mm_prefix))
                d["patch_embeds"] = sds((B, cfg.mm_prefix, cfg.d_model), dtype)
            if is_encdec:
                d["frame_embeds"] = sds((B, S, cfg.d_model), dtype)
            return d
        # decode: one token + cache of seq_len
        caches = jax.eval_shape(
            lambda: init_cache(B, S, dtype=dtype, enc_len=S))
        return {"tokens": sds((B, 1)),
                "index": sds((), jnp.int32),
                "caches": caches}

    return Model(cfg=cfg, init_params=init_params, param_spec=param_spec,
                 loss_fn=loss_fn, prefill=prefill, decode_step=decode_step,
                 init_cache=init_cache, cache_spec=cache_spec,
                 input_specs=input_specs)
