"""Logical sharding specs and their resolution onto concrete meshes.

Param-spec trees mirror the param pytrees exactly; leaves are tuples of
*logical* axis names (or ``None``). :func:`resolve` substitutes logical names
with mesh axes per context:

  training  : fsdp->'fsdp', model->'model', expert->'model'   (+agent prefix)
  serve(sm) : fsdp->None,   model->'model', expert->'model'
  serve(lg) : fsdp->('pod','data'), model->'model', expert->'model'

A logical axis is silently dropped when the array dim is not divisible by the
mesh axis size (e.g. kv_heads=8 on a 16-way model axis) — XLA then replicates
that dim, which is the correct fallback.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_ACT = threading.local()


@contextmanager
def activation_sharding(mesh, rules):
    """Ambient context consulted by :func:`constrain` during tracing.

    Enter this around ``jit(...).lower(...)`` (and around execution) so model
    code emits ``with_sharding_constraint`` on its big intermediates
    (attention scores, MoE dispatch buffers, logits chunks). Without an
    active context every ``constrain`` is a no-op — CPU unit tests stay
    mesh-free."""
    old = getattr(_ACT, "v", None)
    _ACT.v = (mesh, rules)
    try:
        yield
    finally:
        _ACT.v = old


def _ctx():
    return getattr(_ACT, "v", None)


def constrain(x, names):
    """Constrain trailing dims of ``x`` by logical axis names (vmap-safe:
    names align to the LAST ``len(names)`` dims; non-divisible dims drop)."""
    ctx = _ctx()
    if ctx is None:
        return x
    mesh, rules = ctx
    names = tuple(names)[-x.ndim:]
    off = x.ndim - len(names)
    axes = [None] * x.ndim
    used = set()
    for i, name in enumerate(names):
        if name is None:
            continue
        target = rules.get(name)
        if target is None:
            continue
        key = tuple(target) if isinstance(target, (tuple, list)) else (target,)
        if used & set(key):
            continue
        size = _axis_size(mesh, target)
        if size > 1 and x.shape[off + i] % size == 0:
            axes[off + i] = target
            used.update(key)
    # NOTE: applied even when all axes are None — an explicit "replicated"
    # constraint stops sharded producers (e.g. the d-sharded embedding
    # gather) from leaking partial layouts into the residual stream.
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*axes)))


def constrain_pick(x, fixed, candidates):
    """Negative-dim constraint helper (vmap-safe: dims index from the end).

    ``fixed``: [(neg_dim, name), ...] always applied (when divisible);
    ``candidates``: ordered [(neg_dim, name), ...] — the FIRST divisible one
    is sharded. Used for attention scores / MoE buffers where the shardable
    dim depends on head/expert counts."""
    ctx = _ctx()
    if ctx is None:
        return x
    mesh, rules = ctx
    axes = [None] * x.ndim
    used = set()

    def try_set(neg_dim, name):
        dim = x.ndim + neg_dim
        if dim < 0 or axes[dim] is not None:
            return False
        target = rules.get(name)
        if target is None:
            return False
        key = tuple(target) if isinstance(target, (tuple, list)) else (target,)
        if used & set(key):
            return False
        size = _axis_size(mesh, target)
        if size > 1 and x.shape[dim] % size == 0:
            axes[dim] = target
            used.update(key)
            return True
        return False

    for nd, name in fixed:
        try_set(nd, name)
    for nd, name in candidates:
        if try_set(nd, name):
            break
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*axes)))


def logical(*names):
    """A logical spec leaf: tuple of axis names / None / tuples of names."""
    return tuple(names)


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def resolve_leaf(spec_leaf, shape, mesh, rules, prefix=()):
    """Resolve one logical spec against a concrete array shape + mesh.

    Logical names align to the TRAILING dims of the leaf — stacked leading
    dims (agent axis, per-segment n_rep) are skipped automatically; the
    ``prefix`` mesh axes claim the leading dims."""
    axes = list(prefix) + [None] * (len(shape) - len(prefix))
    names = tuple(spec_leaf)[-max(0, len(shape) - len(prefix)):]
    offset = len(shape) - len(names)
    for i, name in enumerate(names):
        dim = offset + i
        if name is None:
            continue
        target = rules.get(name, None)
        if target is None:
            continue
        size = _axis_size(mesh, target)
        if size > 1 and shape[dim] % size == 0 and axes[dim] is None:
            axes[dim] = target
    return P(*axes)


def resolve(spec_tree, shape_tree, mesh, rules, prefix=()):
    """Resolve a logical spec tree into a PartitionSpec tree.

    ``shape_tree`` is a pytree of arrays or ShapeDtypeStructs matching
    ``spec_tree``; ``prefix`` are mesh axes for leading stacked dims (e.g.
    the agent axis) prepended to every leaf.
    """
    return jax.tree.map(
        lambda s, x: resolve_leaf(s, x.shape, mesh, rules, prefix),
        spec_tree, shape_tree,
        is_leaf=lambda s: isinstance(s, tuple) and all(
            isinstance(e, (str, tuple, type(None))) for e in s),
    )


TRAIN_RULES = {"fsdp": "fsdp", "model": "model", "expert": "model",
               "data": ("pod", "agent")}

# The flat-panel engine's layout on the training mesh: panel rows (one per
# agent) live on the ('pod','agent') axes — the paper's communication graph —
# and the flattened parameter columns are FSDP-sharded. The 'model' axis
# replicates the panel: tensor parallelism applies to the model's 2D weight
# layout, which the flat D axis deliberately erases (see core/panel.py).
PANEL_ROW_AXES = ("pod", "agent")
PANEL_COL_AXES = ("fsdp",)


def panel_pspec(mesh, rows: int, width: int,
                row_axes=PANEL_ROW_AXES, col_axes=PANEL_COL_AXES) -> P:
    """PartitionSpec for one (rows, width) panel group on ``mesh``.

    Same drop-on-indivisible policy as :func:`resolve_leaf`: an axis set is
    claimed only when present on the mesh AND the dim divides by its total
    size — XLA replicates the dim otherwise, which is the correct fallback
    (e.g. an odd-width bf16 dtype group on a 2-way fsdp axis)."""
    def claim(dim, axes):
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if not axes:
            return None
        size = _axis_size(mesh, axes)
        if size <= 1 or dim % size:
            return None
        return axes if len(axes) > 1 else axes[0]

    return P(claim(rows, row_axes), claim(width, col_axes))
SERVE_RULES_SMALL = {"fsdp": None, "model": "model", "expert": "model",
                     "data": "data"}


def serve_rules(mesh, big: bool):
    data_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    da = data_axes if len(data_axes) > 1 else data_axes[0]
    rules = {"model": "model", "expert": "model", "data": da}
    rules["fsdp"] = da if big else None
    return rules
