"""Attention mixers: GQA/MQA (optional sliding window), MLA, cross-attention.

All functions are pure-jnp and vmap-friendly. Decode uses a unified cache
layout ``{"k","v","pos"}`` where ``pos`` stores the absolute position of each
cache slot (-1 = empty); sliding-window archs allocate only ``window`` slots
and write round-robin, so ``long_500k`` caches stay O(window).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttentionConfig, LayerSpec, ModelConfig
from repro.models.layers import apply_mrope, apply_norm, apply_rope, dense_init
from repro.models.sharding import constrain, constrain_pick
from repro.models.sharding import logical as L

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA / MQA
# ---------------------------------------------------------------------------


def init_gqa(rng, cfg: ModelConfig, dtype=jnp.float32):
    a = cfg.attn
    ks = jax.random.split(rng, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, a.q_dim, dtype),
        "wk": dense_init(ks[1], cfg.d_model, a.kv_dim, dtype),
        "wv": dense_init(ks[2], cfg.d_model, a.kv_dim, dtype),
        "wo": dense_init(ks[3], a.q_dim, cfg.d_model, dtype),
    }


def spec_gqa():
    return {"wq": L("fsdp", "model"), "wk": L("fsdp", "model"),
            "wv": L("fsdp", "model"), "wo": L("model", "fsdp")}


def _rope_q_or_k(x, positions, a: AttentionConfig, positions3=None):
    if a.rope == "rope":
        return apply_rope(x, positions, a.rope_theta)
    if a.rope == "mrope":
        return apply_mrope(x, positions3, a.mrope_sections, a.rope_theta)
    return x


def _mask_bias(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """q_pos: (..., Sq); k_pos: (..., Sk) -> additive bias (..., Sq, Sk)."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = kp >= 0
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(q, k, v, bias, scale):
    """q: (B,Sq,H,dq) k: (B,Sk,Kv,dq) v: (B,Sk,Kv,dv) bias: (B,Sq,Sk).

    Returns (B,Sq,H,dv); dq may differ from dv (MLA)."""
    B, Sq, H, dq = q.shape
    Kv = k.shape[2]
    dv = v.shape[-1]
    G = H // Kv
    q = q.reshape(B, Sq, Kv, G, dq)
    # shard the score tensor (B,Kv,G,Sq,Sk): kv-heads, else q-groups, else
    # the query-sequence dim (MQA with few heads)
    _fixed = [(-5, "fsdp")]
    _pick = [(-4, "model"), (-3, "model"), (-2, "model")]
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    scores = constrain_pick(scores, _fixed, _pick)
    scores = scores + bias[:, None, None, :, :]
    attn = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    attn = constrain_pick(attn, _fixed, _pick)
    out = jnp.einsum("bkgqs,bskd->bqkgd", attn, v)  # (B,Sq,Kv,G,dv)
    out = constrain_pick(out, [(-5, "fsdp")],
                         [(-3, "model"), (-2, "model"), (-1, "model")])
    return out.reshape(B, Sq, H, dv)


def _sdpa_blockwise(q, k, v, q_pos, k_pos, *, causal, window, scale,
                    block: int):
    """Flash-style online-softmax attention in pure XLA: scans KV in chunks
    of ``block`` so the (Sq, Sk) score matrix is never materialised — the
    jnp twin of kernels/flash_attention.py used by the dry-run/train path.

    q: (B,Sq,H,dq) k/v: (B,Sk,Kv,dv). Returns (B,Sq,H,dv)."""
    B, Sq, H, dq = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // Kv
    block = min(block, Sk)
    pad = (-Sk) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    nb = (Sk + pad) // block
    qr = q.reshape(B, Sq, Kv, G, dq)

    def body(carry, xs):
        m_run, l_run, acc = carry
        kb, vb, kpb = xs  # (B,block,Kv,d), (B,block)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qr, kb).astype(jnp.float32)
        s = s * scale
        ok = kpb[:, None, None, None, :] >= 0
        if causal:
            ok &= kpb[:, None, None, None, :] <= q_pos[:, None, None, :, None]
        if window is not None:
            ok &= (kpb[:, None, None, None, :]
                   > q_pos[:, None, None, :, None] - window)
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = alpha * l_run + jnp.sum(p, axis=-1)
        acc = (acc * alpha[..., None]
               + jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vb.dtype),
                            vb).astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Kv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Kv, G, Sq, dv), jnp.float32)

    def split(t):
        return jnp.moveaxis(
            t.reshape(B, nb, block, *t.shape[2:]), 1, 0)

    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      (split(k), split(v), split(k_pos)))
    out = (acc / jnp.maximum(l_f, 1e-30)[..., None]).astype(v.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, dv)


def gqa_forward(params, x, *, cfg: ModelConfig, lspec: LayerSpec,
                positions, mode: str, cache=None, index=None,
                positions3=None, causal=True, cache_max_len=None):
    """Returns (y, new_cache). mode in {"train","prefill","decode"}."""
    a = cfg.attn
    B, S, _ = x.shape
    _hpick = [(-2, "model"), (-1, "model")]
    q = (x @ params["wq"]).reshape(B, S, a.num_heads, a.head_dim)
    k = (x @ params["wk"]).reshape(B, S, a.num_kv_heads, a.head_dim)
    v = (x @ params["wv"]).reshape(B, S, a.num_kv_heads, a.head_dim)
    q = constrain_pick(q, [(-4, "fsdp")], _hpick)
    k = constrain_pick(k, [(-4, "fsdp")], _hpick)
    v = constrain_pick(v, [(-4, "fsdp")], _hpick)
    q = _rope_q_or_k(q, positions, a, positions3)
    k = _rope_q_or_k(k, positions, a, positions3)
    scale = 1.0 / np.sqrt(a.head_dim)

    if mode == "decode":
        # single-step: S == 1; write (k,v) into the cache ring/linear buffer.
        # Each batch row writes at its OWN absolute position (``positions``
        # is (B, 1)): under continuous batching every slot sits at a
        # different depth, so the write is a per-row scatter, not a shared
        # dynamic_update_slice.
        W = cache["k"].shape[1]
        idx = positions[:, 0].astype(jnp.int32)  # (B,) absolute positions
        slots = jnp.mod(idx, W)
        rows = jnp.arange(B)
        ck = cache["k"].at[rows, slots].set(k[:, 0])
        cv = cache["v"].at[rows, slots].set(v[:, 0])
        cpos = cache["pos"].at[rows, slots].set(idx)
        bias = _mask_bias(positions, cpos, causal=causal, window=lspec.window)
        y = _sdpa(q, ck, cv, bias, scale)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    else:
        pos_b = jnp.broadcast_to(positions, (B, S))
        if cfg.dist.attn_block:
            y = _sdpa_blockwise(q, k, v, pos_b, pos_b, causal=causal,
                                window=lspec.window, scale=scale,
                                block=cfg.dist.attn_block)
        else:
            bias = _mask_bias(pos_b, pos_b, causal=causal,
                              window=lspec.window)
            y = _sdpa(q, k, v, bias, scale)
        new_cache = None
        if mode == "prefill":
            new_cache = _prefill_cache(cfg, lspec, k, v, positions, B, S,
                                       cache_max_len or S)

    y = y.reshape(B, S, a.q_dim) @ params["wo"]
    return y, new_cache


def _prefill_cache(cfg, lspec, k, v, positions, B, S, max_len):
    W = cache_len(cfg, lspec, max_len)
    pos = jnp.broadcast_to(positions, (B, S)).astype(jnp.int32)
    if W >= S:
        pad = W - S
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cpos = jnp.pad(pos, ((0, 0), (0, pad)), constant_values=-1)
    else:
        # keep the trailing window, laid out so slot = pos % W (ring buffer)
        tail_k, tail_v = k[:, S - W:], v[:, S - W:]
        tail_p = pos[:, S - W:]
        slots = jnp.mod(tail_p[0], W)  # same for all batch rows
        inv = jnp.argsort(slots)
        ck, cv, cpos = tail_k[:, inv], tail_v[:, inv], tail_p[:, inv]
    return {"k": ck, "v": cv, "pos": cpos}


def cache_len(cfg: ModelConfig, lspec: LayerSpec, seq_len: int) -> int:
    return min(lspec.window, seq_len) if lspec.window else seq_len


def init_gqa_cache(cfg: ModelConfig, lspec: LayerSpec, B: int, seq_len: int,
                   dtype=jnp.float32):
    a = cfg.attn
    W = cache_len(cfg, lspec, seq_len)
    return {"k": jnp.zeros((B, W, a.num_kv_heads, a.head_dim), dtype),
            "v": jnp.zeros((B, W, a.num_kv_heads, a.head_dim), dtype),
            "pos": jnp.full((B, W), -1, jnp.int32)}


def spec_gqa_cache():
    return {"k": L("data", None, "model", None),
            "v": L("data", None, "model", None),
            "pos": L("data", None)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------


def init_mla(rng, cfg: ModelConfig, dtype=jnp.float32):
    a = cfg.attn
    ks = jax.random.split(rng, 5)
    H = a.num_heads
    return {
        "wq_a": dense_init(ks[0], cfg.d_model, a.q_lora_rank, dtype),
        "q_norm": {"scale": jnp.ones((a.q_lora_rank,), dtype)},
        "wq_b": dense_init(ks[1], a.q_lora_rank,
                           H * (a.qk_nope_dim + a.qk_rope_dim), dtype),
        "wkv_a": dense_init(ks[2], cfg.d_model,
                            a.kv_lora_rank + a.qk_rope_dim, dtype),
        "kv_norm": {"scale": jnp.ones((a.kv_lora_rank,), dtype)},
        "wkv_b": dense_init(ks[3], a.kv_lora_rank,
                            H * (a.qk_nope_dim + a.v_head_dim), dtype),
        "wo": dense_init(ks[4], H * a.v_head_dim, cfg.d_model, dtype),
    }


def spec_mla():
    return {"wq_a": L("fsdp", None), "q_norm": {"scale": L(None)},
            "wq_b": L(None, "model"), "wkv_a": L("fsdp", None),
            "kv_norm": {"scale": L(None)}, "wkv_b": L(None, "model"),
            "wo": L("model", "fsdp")}


def _mla_qkr(params, x, a, positions):
    B, S, _ = x.shape
    H = a.num_heads
    ql = apply_norm(params["q_norm"], x @ params["wq_a"], "rmsnorm")
    q = (ql @ params["wq_b"]).reshape(B, S, H, a.qk_nope_dim + a.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [a.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, a.rope_theta)
    kv = x @ params["wkv_a"]
    ckv, k_rope = jnp.split(kv, [a.kv_lora_rank], axis=-1)
    ckv = apply_norm(params["kv_norm"], ckv, "rmsnorm")
    k_rope = apply_rope(k_rope[:, :, None, :], positions, a.rope_theta)[:, :, 0]
    return q_nope, q_rope, ckv, k_rope


def mla_forward(params, x, *, cfg: ModelConfig, lspec: LayerSpec, positions,
                mode: str, cache=None, index=None, cache_max_len=None, **_):
    a = cfg.attn
    B, S, _ = x.shape
    H = a.num_heads
    q_nope, q_rope, ckv, k_rope = _mla_qkr(params, x, a, positions)
    scale = 1.0 / np.sqrt(a.qk_nope_dim + a.qk_rope_dim)
    wkv_b = params["wkv_b"].reshape(a.kv_lora_rank, H,
                                    a.qk_nope_dim + a.v_head_dim)
    wk = wkv_b[..., : a.qk_nope_dim]  # (r, H, dn)
    wv = wkv_b[..., a.qk_nope_dim:]  # (r, H, dv)

    if mode == "decode":
        W = cache["ckv"].shape[1]
        idx = positions[:, 0].astype(jnp.int32)  # (B,) per-slot positions
        slots = jnp.mod(idx, W)
        rows = jnp.arange(B)
        cc = cache["ckv"].at[rows, slots].set(ckv[:, 0])
        cr = cache["krope"].at[rows, slots].set(k_rope[:, 0])
        cpos = cache["pos"].at[rows, slots].set(idx)
        bias = _mask_bias(positions, cpos, causal=True, window=lspec.window)
        # absorbed attention: scores in latent space
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, wk)
        scores = (jnp.einsum("bqhr,bsr->bhqs", q_lat, cc)
                  + jnp.einsum("bqhd,bsd->bhqs", q_rope, cr)).astype(jnp.float32)
        scores = constrain_pick(scores, [(-4, "fsdp")],
                                [(-3, "model"), (-1, "model")])
        scores = scores * scale + bias[:, None, :, :]
        attn = jax.nn.softmax(scores, axis=-1).astype(cc.dtype)
        o_lat = jnp.einsum("bhqs,bsr->bqhr", attn, cc)
        out = jnp.einsum("bqhr,rhd->bqhd", o_lat, wv)
        new_cache = {"ckv": cc, "krope": cr, "pos": cpos}
    else:
        # materialised form for train/prefill
        k_nope = jnp.einsum("bsr,rhd->bshd", ckv, wk)
        k_nope = constrain_pick(k_nope, [(-4, "fsdp")], [(-2, "model")])
        v = jnp.einsum("bsr,rhd->bshd", ckv, wv)
        v = constrain_pick(v, [(-4, "fsdp")], [(-2, "model")])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, H, a.qk_rope_dim))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        pos_b = jnp.broadcast_to(positions, (B, S))
        bias = _mask_bias(pos_b, pos_b, causal=True, window=lspec.window)
        out = _sdpa(q, k, v, bias, scale)
        new_cache = None
        if mode == "prefill":
            W = cache_max_len or S
            pad = max(0, W - S)
            new_cache = {
                "ckv": jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))),
                "krope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
                "pos": jnp.pad(pos_b.astype(jnp.int32), ((0, 0), (0, pad)),
                               constant_values=-1)}
    y = out.reshape(B, S, H * a.v_head_dim) @ params["wo"]
    return y, new_cache


def init_mla_cache(cfg: ModelConfig, lspec: LayerSpec, B: int, seq_len: int,
                   dtype=jnp.float32):
    a = cfg.attn
    return {"ckv": jnp.zeros((B, seq_len, a.kv_lora_rank), dtype),
            "krope": jnp.zeros((B, seq_len, a.qk_rope_dim), dtype),
            "pos": jnp.full((B, seq_len), -1, jnp.int32)}


def spec_mla_cache():
    return {"ckv": L("data", None, None), "krope": L("data", None, None),
            "pos": L("data", None)}


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------


def init_cross(rng, cfg: ModelConfig, dtype=jnp.float32):
    a = cfg.attn
    ks = jax.random.split(rng, 4)
    return {"wq": dense_init(ks[0], cfg.d_model, a.q_dim, dtype),
            "wk": dense_init(ks[1], cfg.d_model, a.kv_dim, dtype),
            "wv": dense_init(ks[2], cfg.d_model, a.kv_dim, dtype),
            "wo": dense_init(ks[3], a.q_dim, cfg.d_model, dtype)}


spec_cross = spec_gqa


def cross_kv(params, enc_out, *, cfg: ModelConfig):
    """Project encoder output once; cached across decode steps.

    Carries a ``pos`` row (-1 = empty) so a cache row padded to a larger
    encoder capacity (slotted serving: rows are spliced into a
    max_len-sized buffer) keeps its padding masked out."""
    a = cfg.attn
    B, Se, _ = enc_out.shape
    k = (enc_out @ params["wk"]).reshape(B, Se, a.num_kv_heads, a.head_dim)
    v = (enc_out @ params["wv"]).reshape(B, Se, a.num_kv_heads, a.head_dim)
    pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    return {"k": k, "v": v, "pos": pos}


def cross_forward(params, x, kv, *, cfg: ModelConfig):
    """Full (non-causal) attention from decoder states to cached enc K/V."""
    a = cfg.attn
    B, S, _ = x.shape
    Se = kv["k"].shape[1]
    q = (x @ params["wq"]).reshape(B, S, a.num_heads, a.head_dim)
    if "pos" in kv:  # mask padded encoder slots (pos == -1)
        bias = jnp.where(kv["pos"][:, None, :] >= 0, 0.0, NEG_INF
                         ).astype(jnp.float32)
        bias = jnp.broadcast_to(bias, (B, S, Se))
    else:
        bias = jnp.zeros((B, S, Se), jnp.float32)
    y = _sdpa(q, kv["k"], kv["v"], bias, 1.0 / np.sqrt(a.head_dim))
    return y.reshape(B, S, a.q_dim) @ params["wo"]
