"""Decoder/encoder stack assembly with scan-over-layers.

Layers are grouped into *segments*; each segment is a repeated period of
:class:`LayerSpec` (e.g. recurrentgemma: (rglru, rglru, local-attn) x 8 with a
(rglru, rglru) tail). Per-period-position params are stacked along a leading
``n_rep`` axis and the segment is applied with ``lax.scan`` — this keeps the
HLO small (fast 512-way SPMD compiles) and mirrors production LM frameworks.

Caches/states mirror the same segment structure (stacked per group).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import recurrent as rec
from repro.models.layers import (activation, apply_mlp, apply_norm, init_mlp,
                                 init_norm, spec_mlp, spec_norm)
from repro.models.sharding import logical as L


@dataclass(frozen=True)
class Segment:
    name: str
    specs: Tuple[LayerSpec, ...]  # one period
    n_rep: int
    d_ff_override: Optional[int] = None


def build_segments(cfg: ModelConfig):
    """Split cfg.layer_specs() into scanned segments."""
    specs = list(cfg.layer_specs())
    segments = []
    if cfg.dense_ff_first_k:
        front = tuple(
            LayerSpec(mixer=s.mixer, ffn="swiglu", window=s.window)
            for s in specs[: cfg.dense_ff_first_k])
        # front layers are identical; stack them as one group repeated k times
        segments.append(Segment("front", (front[0],), cfg.dense_ff_first_k,
                                d_ff_override=cfg.dense_ff_size))
        specs = specs[cfg.dense_ff_first_k:]
    period = cfg.layer_period
    p = len(period)
    n_rep = len(specs) // p
    if n_rep > 0:
        segments.append(Segment("main", tuple(period), n_rep))
    tail = specs[n_rep * p:]
    if tail:
        segments.append(Segment("tail", tuple(tail), 1))
    return segments


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

_MIXER_INIT = {"gqa": attn.init_gqa, "mla": attn.init_mla,
               "rglru": rec.init_rglru, "mlstm": rec.init_mlstm,
               "slstm": rec.init_slstm}
_MIXER_SPEC = {"gqa": attn.spec_gqa, "mla": attn.spec_mla,
               "rglru": rec.spec_rglru, "mlstm": rec.spec_mlstm,
               "slstm": rec.spec_slstm}


def init_block(rng, cfg: ModelConfig, lspec: LayerSpec, cross: bool = False,
               d_ff_override: Optional[int] = None, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    p = {"norm1": init_norm(cfg.norm, cfg.d_model, dtype),
         "mixer": _MIXER_INIT[lspec.mixer](ks[0], cfg, dtype=dtype)}
    if cross:
        p["norm_x"] = init_norm(cfg.norm, cfg.d_model, dtype)
        p["cross"] = attn.init_cross(ks[1], cfg, dtype=dtype)
    if lspec.ffn != "none":
        p["norm2"] = init_norm(cfg.norm, cfg.d_model, dtype)
        if lspec.ffn == "moe":
            p["ffn"] = moe_mod.init_moe(ks[2], cfg, dtype=dtype)
        else:
            d_ff = d_ff_override or cfg.d_ff
            p["ffn"] = init_mlp(ks[2], cfg.d_model, d_ff, gated=True,
                                dtype=dtype)
    return p


def spec_block(cfg: ModelConfig, lspec: LayerSpec, cross: bool = False):
    p = {"norm1": spec_norm(cfg.norm), "mixer": _MIXER_SPEC[lspec.mixer]()}
    if cross:
        p["norm_x"] = spec_norm(cfg.norm)
        p["cross"] = attn.spec_cross()
    if lspec.ffn != "none":
        p["norm2"] = spec_norm(cfg.norm)
        p["ffn"] = (moe_mod.spec_moe(cfg) if lspec.ffn == "moe"
                    else spec_mlp(gated=True))
    return p


def apply_block(params, x, *, cfg: ModelConfig, lspec: LayerSpec, mode: str,
                positions, positions3=None, cache=None, index=None,
                enc_out=None, cross_kv=None, causal=True, cache_max_len=None):
    """Returns (x, new_cache_dict_or_None, aux_loss).

    ``new_cache_dict`` has keys {"mixer"[, "cross"]} in prefill/decode modes.
    """
    from repro.models.sharding import constrain
    if cfg.dist.seq_shard and mode in ("train", "prefill"):
        # Megatron-style sequence parallelism: the residual stream is
        # sequence-sharded over the tensor axis between blocks; XLA inserts
        # the gather at the first projection and the reduce-scatter after.
        x = constrain(x, ("fsdp", "model", None))
    else:
        x = constrain(x, ("fsdp", None, None))
    h = apply_norm(params["norm1"], x, cfg.norm)
    if lspec.mixer in ("gqa", "mla"):
        fwd = attn.gqa_forward if lspec.mixer == "gqa" else attn.mla_forward
        y, new_mixer = fwd(params["mixer"], h, cfg=cfg, lspec=lspec,
                           positions=positions, mode=mode, cache=cache,
                           index=index, positions3=positions3, causal=causal,
                           cache_max_len=cache_max_len)
    else:
        fwd = {"rglru": rec.rglru_forward, "mlstm": rec.mlstm_forward,
               "slstm": rec.slstm_forward}[lspec.mixer]
        y, new_mixer = fwd(params["mixer"], h, cfg=cfg, mode=mode, state=cache)
    x = x + y
    new_cross = None
    if "cross" in params:
        hx = apply_norm(params["norm_x"], x, cfg.norm)
        if cross_kv is None and enc_out is not None:
            cross_kv = attn.cross_kv(params["cross"], enc_out, cfg=cfg)
        y_x = attn.cross_forward(params["cross"], hx, cross_kv, cfg=cfg)
        x = x + y_x
        new_cross = cross_kv
    aux = jnp.zeros((), jnp.float32)
    if lspec.ffn != "none":
        h2 = apply_norm(params["norm2"], x, cfg.norm)
        if lspec.ffn == "moe":
            # prefill/decode run dropless: capacity dropping is only causal
            # in training where the whole batch is one step (see moe_forward)
            y2, aux = moe_mod.moe_forward(params["ffn"], h2, cfg=cfg,
                                          act_name=cfg.act,
                                          dropless=mode != "train")
        else:
            y2 = apply_mlp(params["ffn"], h2, activation(cfg.act), gated=True)
        x = x + y2
    if mode == "train":
        return x, None, aux
    out_cache = {"mixer": new_mixer} if new_mixer is not None else {}
    if new_cross is not None:
        out_cache["cross"] = new_cross
    return x, out_cache, aux


def init_block_cache(cfg: ModelConfig, lspec: LayerSpec, B: int, seq_len: int,
                     cross: bool, enc_len: int, dtype=jnp.float32):
    c = {}
    if lspec.mixer == "gqa":
        c["mixer"] = attn.init_gqa_cache(cfg, lspec, B, seq_len, dtype)
    elif lspec.mixer == "mla":
        c["mixer"] = attn.init_mla_cache(cfg, lspec, B, seq_len, dtype)
    elif lspec.mixer == "rglru":
        c["mixer"] = rec.init_rglru_state(cfg, B, dtype)
    elif lspec.mixer == "mlstm":
        c["mixer"] = rec.init_mlstm_state(cfg, B)
    elif lspec.mixer == "slstm":
        c["mixer"] = rec.init_slstm_state(cfg, B)
    if cross:
        a = cfg.attn
        c["cross"] = {
            "k": jnp.zeros((B, enc_len, a.num_kv_heads, a.head_dim), dtype),
            "v": jnp.zeros((B, enc_len, a.num_kv_heads, a.head_dim), dtype),
            "pos": jnp.full((B, enc_len), -1, jnp.int32)}
    return c


def spec_block_cache(cfg: ModelConfig, lspec: LayerSpec, cross: bool):
    c = {}
    if lspec.mixer == "gqa":
        c["mixer"] = attn.spec_gqa_cache()
    elif lspec.mixer == "mla":
        c["mixer"] = attn.spec_mla_cache()
    elif lspec.mixer == "rglru":
        c["mixer"] = rec.spec_rglru_state()
    elif lspec.mixer == "mlstm":
        c["mixer"] = rec.spec_mlstm_state()
    elif lspec.mixer == "slstm":
        c["mixer"] = rec.spec_slstm_state()
    if cross:
        c["cross"] = {"k": L("data", None, "model", None),
                      "v": L("data", None, "model", None),
                      "pos": L("data", None)}
    return c


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------


def _stacked_init(rng, n, init_fn):
    ks = jax.random.split(rng, n)
    ps = [init_fn(k) for k in ks]
    return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *ps)


def init_stack(rng, cfg: ModelConfig, cross: bool = False, dtype=jnp.float32):
    """Params for all segments: {seg.name: {"p{i}": stacked params}}."""
    segs = build_segments(cfg)
    out = {}
    for seg in segs:
        rng, sub = jax.random.split(rng)
        seg_p = {}
        for i, ls in enumerate(seg.specs):
            sub, k = jax.random.split(sub)
            seg_p[f"p{i}"] = _stacked_init(
                k, seg.n_rep,
                lambda kk, ls=ls: init_block(kk, cfg, ls, cross=cross,
                                             d_ff_override=seg.d_ff_override,
                                             dtype=dtype))
        out[seg.name] = seg_p
    return out


def spec_stack(cfg: ModelConfig, cross: bool = False):
    segs = build_segments(cfg)
    out = {}
    for seg in segs:
        out[seg.name] = {f"p{i}": spec_block(cfg, ls, cross=cross)
                         for i, ls in enumerate(seg.specs)}
    return out


def init_stack_cache(cfg: ModelConfig, B: int, seq_len: int,
                     cross: bool = False, enc_len: int = 0,
                     dtype=jnp.float32):
    segs = build_segments(cfg)
    out = {}
    for seg in segs:
        seg_c = {}
        for i, ls in enumerate(seg.specs):
            one = init_block_cache(cfg, ls, B, seq_len, cross, enc_len, dtype)
            seg_c[f"p{i}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (seg.n_rep,) + x.shape),
                one)
        out[seg.name] = seg_c
    return out


def spec_stack_cache(cfg: ModelConfig, cross: bool = False):
    segs = build_segments(cfg)
    return {seg.name: {f"p{i}": spec_block_cache(cfg, ls, cross)
                       for i, ls in enumerate(seg.specs)}
            for seg in segs}


def apply_stack(params, x, *, cfg: ModelConfig, mode: str, positions,
                positions3=None, caches=None, index=None, enc_out=None,
                causal=True, cache_max_len=None):
    """Run all segments. Returns (x, new_caches, aux_total).

    ``caches`` must be given for decode; for prefill it is None and fresh
    caches (sized by ``cache_max_len``) are returned; for train it is None
    and None is returned.
    """
    segs = build_segments(cfg)
    want_cache = mode in ("prefill", "decode")
    new_caches = {} if want_cache else None
    aux_total = jnp.zeros((), jnp.float32)
    remat = cfg.dist.remat

    for seg in segs:
        seg_params = params[seg.name]
        seg_cache = caches[seg.name] if caches is not None else None

        def period_body(carry, xs, seg=seg):
            h, aux = carry
            p_all, c_all = xs
            new_c = {}
            for i, ls in enumerate(seg.specs):
                blk = p_all[f"p{i}"]
                cache_i = c_all[f"p{i}"] if c_all is not None else None
                mixer_cache = cache_i.get("mixer") if cache_i else None
                cross_kv = cache_i.get("cross") if cache_i else None

                def run(blk, h, mixer_cache, cross_kv, ls=ls):
                    return apply_block(
                        blk, h, cfg=cfg, lspec=ls, mode=mode,
                        positions=positions, positions3=positions3,
                        cache=mixer_cache, index=index, enc_out=enc_out,
                        cross_kv=cross_kv, causal=causal,
                        cache_max_len=cache_max_len)

                if remat == "full" and mode == "train":
                    run = jax.checkpoint(run)
                elif remat == "dots" and mode == "train":
                    run = jax.checkpoint(
                        run, policy=jax.checkpoint_policies.dots_saveable)
                h, blk_cache, a = run(blk, h, mixer_cache, cross_kv)
                aux = aux + a
                if want_cache:
                    new_c[f"p{i}"] = blk_cache
            return (h, aux), (new_c if want_cache else 0)

        xs = (seg_params, seg_cache)
        if cfg.dist.scan_layers:
            (x, aux_total), seg_new_cache = jax.lax.scan(
                period_body, (x, aux_total), xs)
        else:  # unrolled (dry-run mode: honest per-op cost_analysis)
            ys = []
            carry = (x, aux_total)
            for rix in range(seg.n_rep):
                xs_r = jax.tree.map(lambda t: t[rix], xs)
                carry, y = period_body(carry, xs_r)
                ys.append(y)
            (x, aux_total) = carry
            seg_new_cache = (jax.tree.map(
                lambda *zs: jnp.stack(zs, 0), *ys) if want_cache else None)
        if want_cache:
            new_caches[seg.name] = seg_new_cache
    return x, new_caches, aux_total
