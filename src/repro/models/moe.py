"""Mixture-of-Experts FFN with capacity-based gather/scatter dispatch.

Design notes (TPU adaptation):
  * Experts are sharded over the ``expert``→``model`` mesh axis (expert
    parallelism); token dispatch lowers to the all-to-all / all-gather
    pattern XLA SPMD derives from the scatter into the expert-sharded buffer.
  * Dispatch uses integer ranking + scatter/gather (NOT one-hot einsums), so
    HLO FLOPs reflect only the real expert matmuls — keeps the roofline
    analysis honest (a one-hot dispatch would add a fake T·E·C·d matmul).
  * Tokens beyond an expert's capacity are dropped (standard capacity-factor
    semantics); the router aux loss pushes the load toward balance.
  * ``router="sigmoid"`` implements DeepSeek-V3 style sigmoid scoring with
    top-k renormalisation; ``"softmax"`` is the classic top-k softmax gate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import activation, dense_init
from repro.models.sharding import constrain, constrain_pick
from repro.models.sharding import logical as L


def init_moe(rng, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(rng, 8)
    scale = 1.0 / np.sqrt(d)

    def expert_bank(k, d_in, d_out):
        return (jax.random.normal(k, (m.num_experts, d_in, d_out), jnp.float32)
                * scale).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, m.num_experts, jnp.float32),
        "w_in": expert_bank(ks[1], d, m.expert_ff),
        "w_gate": expert_bank(ks[2], d, m.expert_ff),
        "w_out": expert_bank(ks[3], m.expert_ff, d),
    }
    if m.shared_ff:
        p["shared"] = {
            "w_in": dense_init(ks[4], d, m.shared_ff, dtype),
            "w_gate": dense_init(ks[5], d, m.shared_ff, dtype),
            "w_out": dense_init(ks[6], m.shared_ff, d, dtype),
        }
    if m.dense_ff:
        kk = jax.random.split(ks[7], 3)
        p["dense"] = {
            "w_in": dense_init(kk[0], d, m.dense_ff, dtype),
            "w_gate": dense_init(kk[1], d, m.dense_ff, dtype),
            "w_out": dense_init(kk[2], m.dense_ff, d, dtype),
        }
    return p


def spec_moe(cfg: ModelConfig):
    m = cfg.moe
    p = {"router": L(None, None),
         "w_in": L("expert", "fsdp", None),
         "w_gate": L("expert", "fsdp", None),
         "w_out": L("expert", None, "fsdp")}
    mlp = {"w_in": L("fsdp", "model"), "w_gate": L("fsdp", "model"),
           "w_out": L("model", "fsdp")}
    if m.shared_ff:
        p["shared"] = dict(mlp)
    if m.dense_ff:
        p["dense"] = dict(mlp)
    return p


def _route(x2, params, m: MoEConfig):
    """x2: (T, d) -> (weights (T,k), experts (T,k), aux_loss)."""
    logits = (x2.astype(jnp.float32) @ params["router"])  # (T, E)
    if m.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        w, sel = jax.lax.top_k(scores, m.top_k)
        w = w / (jnp.sum(w, -1, keepdims=True) + 1e-9)
        probs = scores / (jnp.sum(scores, -1, keepdims=True) + 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, sel = jax.lax.top_k(probs, m.top_k)
        w = w / (jnp.sum(w, -1, keepdims=True) + 1e-9)
    # load-balance aux loss: E * sum_e fraction_e * mean_prob_e
    T = x2.shape[0]
    counts = jnp.zeros((m.num_experts,), jnp.float32).at[sel.reshape(-1)].add(1.0)
    frac = counts / (T * m.top_k)
    mean_prob = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(frac * mean_prob)
    return w, sel, aux


def moe_forward(params, x, *, cfg: ModelConfig, act_name: str,
                dropless: bool = False):
    """x: (B, S, d) -> (y, aux_loss).

    ``dropless=True`` sets per-expert capacity to T (each token reaches an
    expert at most once, so nothing ever overflows). Inference MUST run
    dropless: capacity C = ceil(T*k/E*cf) depends on the total token count
    and every token's routing, so whether token i is dropped depends on
    LATER tokens — teacher-forced decode could never reproduce a full
    forward, and in serving one request's load would perturb another's
    logits. Training keeps the capped buffer (standard capacity-factor
    throughput/memory trade; the aux loss pushes the load toward balance).

    C = T is the MINIMAL static dropless capacity (adversarial routing can
    send every token to one expert, and XLA needs static shapes), but it
    makes the dispatch buffer (E, T+1, d) — at large E this dominates
    prefill activation memory. The production fix is a grouped/ragged
    expert matmul over the expert-sorted (T*k, d) layout instead of the
    scatter buffer (see ROADMAP "Dropless MoE dispatch").
    """
    m = cfg.moe
    act = activation(act_name)
    B, S, d = x.shape
    T = B * S
    x2 = x.reshape(T, d)
    w, sel, aux = _route(x2, params, m)

    E, k = m.num_experts, m.top_k
    if dropless:
        C = T
    else:
        C = max(1, int(np.ceil(T * k / E * m.capacity_factor)))
        C = min(C, T)

    flat_e = sel.reshape(-1)  # (T*k,) expert id per assignment
    # rank of each assignment within its expert via sort-based segment ranks
    # (avoids the (T*k, E) one-hot cumsum: O(Tk log Tk) and O(Tk) memory)
    Tk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)  # assignments grouped by expert
    sorted_e = flat_e[order]
    idx = jnp.arange(Tk, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, -1))
    rank_sorted = idx - run_start
    rank = jnp.zeros((Tk,), jnp.int32).at[order].set(rank_sorted)
    valid = rank < C
    rank_c = jnp.minimum(rank, C)  # overflow -> per-expert dropped column C

    # dispatch: scatter tokens into the expert-sharded (E, C+1, d) buffer
    tok_idx = jnp.repeat(jnp.arange(T), k)
    xg = x2[tok_idx]  # (T*k, d)
    disp = cfg.dist.moe_dispatch_shard
    if disp == "tokens":
        # keep the per-assignment gather token-sharded over fsdp (§Perf C it.1)
        xg = constrain(xg, ("fsdp", None))
    elif disp == "dmodel":
        # shard dispatch on d_model: scatter source and the expert buffer
        # agree on the fsdp-sharded d dim, so NO token gather is needed;
        # the expert matmul contracts the sharded d with w_in's fsdp dim
        # (partial sums + one small all-reduce) — §Perf pair C iteration 2.
        xg = constrain(xg, (None, "fsdp"))
    buf = jnp.zeros((E, C + 1, d), x.dtype).at[flat_e, rank_c].set(xg)
    he = buf[:, :C]  # (E, C, d)
    if disp == "dmodel":
        he = constrain_pick(he, [(-3, "expert"), (-1, "fsdp")], [])
    else:
        he = constrain_pick(he, [(-3, "expert")], [])

    # expert compute (einsum over the expert-sharded bank)
    h = jnp.einsum("ecd,edf->ecf", he, params["w_in"])
    h = constrain_pick(h, [(-3, "expert")], [])
    g = jnp.einsum("ecd,edf->ecf", he, params["w_gate"])
    g = constrain_pick(g, [(-3, "expert")], [])
    h = act(g) * h
    out = jnp.einsum("ecf,efd->ecd", h, params["w_out"])  # (E, C, d)
    out = constrain_pick(out, [(-3, "expert")], [])

    # combine: gather each assignment's output, weight, sum over k
    out_pad = jnp.pad(out, ((0, 0), (0, 1), (0, 0)))  # dropped column
    if disp == "dmodel":
        out_pad = constrain_pick(out_pad, [(-3, "expert"), (-1, "fsdp")], [])
    y_assign = out_pad[flat_e, rank_c]
    if disp == "tokens":
        y_assign = constrain(y_assign, ("fsdp", None))
    elif disp == "dmodel":
        y_assign = constrain(y_assign, (None, "fsdp"))
    y_assign = y_assign * (
        w.reshape(-1)[:, None] * valid[:, None]).astype(out.dtype)
    y = jnp.sum(y_assign.reshape(T, k, d), axis=1)

    if m.shared_ff:
        sh = params["shared"]
        y = y + (act(x2 @ sh["w_gate"]) * (x2 @ sh["w_in"])) @ sh["w_out"]
    if m.dense_ff:
        de = params["dense"]
        y = y + (act(x2 @ de["w_gate"]) * (x2 @ de["w_in"])) @ de["w_out"]
    return y.reshape(B, S, d), aux * m.aux_loss_weight


def moe_ref(params, x, *, cfg: ModelConfig, act_name: str):
    """Dropless dense reference (computes every expert for every token) —
    used only by tests on tiny shapes to validate the dispatch path."""
    m = cfg.moe
    act = activation(act_name)
    B, S, d = x.shape
    x2 = x.reshape(B * S, d)
    w, sel, aux = _route(x2, params, m)
    h = jnp.einsum("td,edf->tef", x2, params["w_in"])
    g = jnp.einsum("td,edf->tef", x2, params["w_gate"])
    out = jnp.einsum("tef,efd->ted", act(g) * h, params["w_out"])  # (T, E, d)
    gate = jnp.zeros((x2.shape[0], m.num_experts), out.dtype)
    gate = gate.at[jnp.arange(x2.shape[0])[:, None], sel].set(w.astype(out.dtype))
    y = jnp.einsum("te,ted->td", gate, out)
    if m.shared_ff:
        sh = params["shared"]
        y = y + (act(x2 @ sh["w_gate"]) * (x2 @ sh["w_in"])) @ sh["w_out"]
    if m.dense_ff:
        de = params["dense"]
        y = y + (act(x2 @ de["w_gate"]) * (x2 @ de["w_in"])) @ de["w_out"]
    return y.reshape(B, S, d), aux * m.aux_loss_weight
