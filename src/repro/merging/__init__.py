"""Merge-operator subsystem (see merging/ops.py for the contract).

The panel engine (core/panel.py, core/dsgd.py) resolves the operator
named on ``PanelSpec.merger`` (``panel.with_merger`` /
``dsgd.init_panel_state(merger=...)``) through :func:`get_merger` and
applies it on every GLOBAL round — including the paper's single final
merging (``launch/train.py --merge``). The tree-level oracle lives in
core/merge.py (``merge_stacked`` / ``counterfactual_eval(merger=...)``).
"""
from repro.merging.ops import (MERGERS, FisherMerger,  # noqa: F401
                               Merger, SwaMerger, TiesMerger,
                               UniformMerger, VarMerger, WeightedMerger,
                               decode_stats, get_merger, merge_panel)
