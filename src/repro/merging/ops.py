"""Panel-native merge operators: how ONE global merging combines agents.

The paper's headline result is that a single uniform global merging closes
the gap to parallel SGD; its discussion frames that as an opening for
model-merging research. This subsystem makes the merge OPERATOR pluggable
on the flat-panel engine (core/panel.py), mirroring the wire-codec
registry (repro/wire): every operator consumes the per-dtype
``{group: (m, D_g)}`` parameter panel (plus, for the statistical
operators, per-agent statistics panels carried in the segment state) and
produces ONE merged row ``{group: (D_g,) f32}``.

Operators (``MERGERS`` / :func:`get_merger`):

* ``uniform``  — the paper's merge: the per-group column mean. Bit-exact
  alias of the pre-subsystem ``panel.merged`` / ``global_merge`` path.
* ``weighted`` — per-AGENT convex weights: explicit ``weights=`` (e.g.
  softmax of held-out losses) or, by default, inverse squared consensus
  distance — agents far from the mean (stale under heterogeneity) are
  downweighted.
* ``var``      — per-COORDINATE inverse-variance (precision) weighting:
  each agent tracks an EMA mean/second-moment of its own parameter
  trajectory over rounds (two stat panels); coordinates that fluctuate
  across rounds are uncertain and get downweighted (a diagonal
  SWAG-style precision merge).
* ``fisher``   — diagonal-Fisher weighting (Matena & Raffel 2022, panel
  form): each agent accumulates an EMA of its squared gradients during
  the LOCAL steps (one stat panel, donated through the segment scan like
  PR 3's ``wire_err``); the merge is the Fisher-weighted column mean.
* ``ties``     — TIES (Yadav et al. 2023) on deviations from the mean:
  per-row top-``trim`` magnitude trim, per-column sign election, and the
  mean of surviving agreeing deviations added back to the reference row.
  Resolves sign interference that a plain mean cancels to mush.
* ``swa``      — merge of per-agent SWA/EMA accumulators maintained over
  the tail rounds (one stat panel updated once per round): averaging the
  smoothed iterates instead of the last ones.

Statistics contract: an operator with ``stat_panels`` names its per-agent
(m, D_g) f32 panels; the panel engine keeps them as
``state["merge_stat"][name]`` — donated through the segment scan, updated
via :meth:`Merger.update_local` (every local step, sees the grad panel)
and/or :meth:`Merger.update_round` (once per round, sees the param
panel). ``init_stats`` builds them from the initial panel
(``dsgd.init_panel_state(merger=...)``).

Heavy per-coordinate reductions run as Pallas TPU kernels
(kernels/merge_ops.py) with bit-identical oracles in kernels/ref.py;
sharded specs fall back to the plain-XLA oracle path so SPMD partitions
the column reductions over 'fsdp', mirroring the other panel kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import panel as panel_mod
from repro.kernels import merge_ops as merge_kernels
from repro.kernels import ref as ref_mod
from repro.telemetry.trace import scope
from repro.wire import codec as wire_codec


class Merger:
    """Base merge operator: the uniform column mean.

    Subclasses override :meth:`merge_row` (the operator itself) and, for
    statistical operators, declare ``stat_panels`` + the update hooks."""

    name = "uniform"
    stat_panels: tuple = ()   # names of per-agent (m, D_g) f32 stat panels
    local_stat = False        # update_local runs every local step (grads)
    round_stat = False        # update_round runs once per round (params)
    uses_panel = True         # merge_row reads the (wire-encoded) params

    # ---------------------------------------------------- statistics
    def init_stats(self, panel):
        """{stat_name: {group: (m, D_g) f32}} from the initial panel."""
        return {}

    def update_local(self, stats, gpan):
        """Fold one local step's grad panel into the stats."""
        return stats

    def update_round(self, stats, panel):
        """Fold one round's post-local-steps param panel into the stats."""
        return stats

    # --------------------------------------------------------- merge
    def merge_row(self, panel, stats=None, weights=None, *, spec=None,
                  use_pallas: bool = False, block_d: int = 512,
                  interpret: bool = True, live=None):
        """One merged row {group: (D_g,) f32} from the (m, D) panel.

        ``live`` ((m,) bool) restricts every operator to the live agents'
        rows: dead rows contribute NOTHING to the merged row (their
        parameters and statistics are stale), exactly as if the operator
        ran on the m'-agent sub-panel."""
        return panel_mod.merged(panel, spec=spec, use_pallas=use_pallas,
                                block_d=block_d, interpret=interpret,
                                live=live)


class UniformMerger(Merger):
    """The paper's single global merging: the per-group column mean
    (bit-exact alias of the pre-subsystem ``panel.merged`` path)."""


def _identity_back(y):
    return y


def _constrain_row(row, spec):
    if spec is None:
        return row
    return {k: panel_mod._constrain_group(v, spec, k, merged_panel=True)
            for k, v in row.items()}


def _weighted_colmerge(panel, wpanel, spec, use_pallas, block_d, interpret):
    """Per-coordinate weighted column merge over all dtype groups —
    Pallas kernel single-device, XLA oracle under a sharded spec."""
    pallas = panel_mod._pallas_ok(use_pallas, spec)
    out = {}
    for k, x in panel.items():
        if pallas:
            y = merge_kernels.weighted_colmerge(
                x.astype(jnp.float32), wpanel[k], block_d=block_d,
                interpret=interpret)
        else:
            y = ref_mod.weighted_colmerge_ref(x, wpanel[k])
        out[k] = y
    return _constrain_row(out, spec)


class WeightedMerger(Merger):
    """Per-agent convex weights: explicit ``weights=`` (m,) — e.g. from a
    held-out loss — or inverse squared consensus distance by default
    (w_k ∝ 1/(||theta_k - mean||^2 + eps), computed across all groups;
    identical rows degrade gracefully to the uniform mean)."""

    name = "weighted"

    def __init__(self, eps: float = 1e-8):
        self.eps = eps

    def agent_weights(self, panel, live=None):
        d = jnp.zeros((), jnp.float32)
        for x in panel.values():
            x32 = x.astype(jnp.float32)
            if live is None:
                mu = jnp.mean(x32, axis=0, keepdims=True)
            else:
                lw = panel_mod._live_weights(live, x32.shape[0])
                mu = jnp.tensordot(lw, x32, axes=1)[None]
            d = d + jnp.sum(jnp.square(x32 - mu), axis=1)
        w = 1.0 / (d + self.eps)
        if live is not None:
            w = w * live.astype(jnp.float32)
        return w / jnp.sum(w)

    def merge_row(self, panel, stats=None, weights=None, *, spec=None,
                  use_pallas: bool = False, block_d: int = 512,
                  interpret: bool = True, live=None):
        if weights is None:
            w = self.agent_weights(panel, live=live)
        else:
            w = jnp.asarray(weights, jnp.float32)
            if live is not None:
                w = w * live.astype(jnp.float32)
            w = w / jnp.sum(w)
        row = {k: jnp.tensordot(w, x.astype(jnp.float32), axes=1)
               for k, x in panel.items()}
        return _constrain_row(row, spec)


class VarMerger(Merger):
    """Per-coordinate inverse-variance weighting: stats are EMA mean and
    second-moment panels of each agent's parameter trajectory over rounds
    (``update_round``); the merge weights are 1/(Var + eps). Fresh stats
    (zero variance everywhere) reduce to the uniform mean."""

    name = "var"
    stat_panels = ("traj_mu", "traj_m2")
    round_stat = True

    def __init__(self, ema: float = 0.9, eps: float = 1e-8):
        self.ema = ema
        self.eps = eps

    def init_stats(self, panel):
        # jnp.array COPIES: an f32 group's .astype(f32) would alias the
        # parameter buffer and break the segment driver's donation
        mu = {k: jnp.array(x, jnp.float32) for k, x in panel.items()}
        return {"traj_mu": mu,
                "traj_m2": {k: jnp.square(v) for k, v in mu.items()}}

    def update_round(self, stats, panel):
        b = self.ema
        mu, m2 = {}, {}
        for k, x in panel.items():
            x32 = x.astype(jnp.float32)
            mu[k] = b * stats["traj_mu"][k] + (1.0 - b) * x32
            m2[k] = b * stats["traj_m2"][k] + (1.0 - b) * jnp.square(x32)
        return {"traj_mu": mu, "traj_m2": m2}

    def merge_row(self, panel, stats=None, weights=None, *, spec=None,
                  use_pallas: bool = False, block_d: int = 512,
                  interpret: bool = True, live=None):
        if stats is None:
            raise ValueError(
                "merger 'var' needs its trajectory stats panels "
                "(stats=...); build them with init_stats / "
                "init_panel_state(merger='var')")
        var = {k: jnp.maximum(stats["traj_m2"][k]
                              - jnp.square(stats["traj_mu"][k]), 0.0)
               for k in panel}
        w = {k: 1.0 / (v + self.eps) for k, v in var.items()}
        if live is not None:
            # the colmerge normalizes by the per-column weight sum, so a
            # zeroed row is excluded from both numerator and denominator
            lf = live.astype(jnp.float32)[:, None]
            w = {k: v * lf for k, v in w.items()}
        return _weighted_colmerge(panel, w, spec, use_pallas, block_d,
                                  interpret)


class FisherMerger(Merger):
    """Diagonal-Fisher weighted merge: each agent accumulates an EMA of
    its squared gradients during the local steps (F ≈ E[g^2], the
    empirical diagonal Fisher); the merge is the Fisher-weighted column
    mean with weights F + eps. Fresh stats (F = 0) reduce to the uniform
    mean."""

    name = "fisher"
    stat_panels = ("fisher",)
    local_stat = True

    def __init__(self, ema: float = 0.9, eps: float = 1e-8):
        self.ema = ema
        self.eps = eps

    def init_stats(self, panel):
        return {"fisher": {k: jnp.zeros(x.shape, jnp.float32)
                           for k, x in panel.items()}}

    def update_local(self, stats, gpan):
        b = self.ema
        return {"fisher": {
            k: b * stats["fisher"][k]
            + (1.0 - b) * jnp.square(g.astype(jnp.float32))
            for k, g in gpan.items()}}

    def merge_row(self, panel, stats=None, weights=None, *, spec=None,
                  use_pallas: bool = False, block_d: int = 512,
                  interpret: bool = True, live=None):
        if stats is None:
            raise ValueError(
                "merger 'fisher' needs its Fisher stats panel (stats=...);"
                " build it with init_stats / init_panel_state("
                "merger='fisher')")
        w = {k: stats["fisher"][k] + self.eps for k in panel}
        if live is not None:
            lf = live.astype(jnp.float32)[:, None]
            w = {k: v * lf for k, v in w.items()}
        return _weighted_colmerge(panel, w, spec, use_pallas, block_d,
                                  interpret)


class TiesMerger(Merger):
    """TIES on deviations from the mean: per-agent-row top-``trim``
    magnitude trim, per-column sign election over the survivors, and the
    agreeing (disjoint) mean of the elected deviations added back to the
    reference row. ``trim=1.0`` keeps every deviation — the pure
    sign-elected mean."""

    name = "ties"

    def __init__(self, trim: float = 0.2):
        if not 0.0 < trim <= 1.0:
            raise ValueError(f"trim fraction must be in (0, 1], got {trim}")
        self.trim = trim

    def merge_row(self, panel, stats=None, weights=None, *, spec=None,
                  use_pallas: bool = False, block_d: int = 512,
                  interpret: bool = True, live=None):
        pallas = panel_mod._pallas_ok(use_pallas, spec)
        out = {}
        for k, x in panel.items():
            x32 = x.astype(jnp.float32)
            if live is None:
                ref_row = jnp.mean(x32, axis=0)
                tau = x32 - ref_row[None]
            else:
                lw = panel_mod._live_weights(live, x32.shape[0])
                ref_row = jnp.tensordot(lw, x32, axes=1)
                # a zero tau row is inert through trim + election +
                # agreeing-mean, so masking dead rows to zero makes the
                # result exactly the live sub-panel's TIES merge
                tau = (x32 - ref_row[None]) * live.astype(
                    jnp.float32)[:, None]
            thresh = ref_mod.ties_thresh_ref(tau, self.trim)
            if pallas:
                dev = merge_kernels.ties_colmerge(tau, thresh,
                                                  block_d=block_d,
                                                  interpret=interpret)
            else:
                dev = ref_mod.ties_colmerge_ref(tau, thresh)
            out[k] = ref_row + dev
        return _constrain_row(out, spec)


class SwaMerger(Merger):
    """Merge of per-agent SWA/EMA accumulators: each agent keeps an EMA
    of its parameters over the ROUNDS (``a <- d a + (1-d) theta`` after
    each round, initialised at theta_0 — the tail rounds dominate); the
    merged row is the uniform mean of the accumulators, i.e. the merge
    averages the smoothed iterates instead of the final ones."""

    name = "swa"
    stat_panels = ("swa",)
    round_stat = True
    uses_panel = False  # the merged row comes from the accumulators only

    def __init__(self, decay: float = 0.9):
        self.decay = decay

    def init_stats(self, panel):
        # jnp.array copies (donation safety, see VarMerger.init_stats)
        return {"swa": {k: jnp.array(x, jnp.float32)
                        for k, x in panel.items()}}

    def update_round(self, stats, panel):
        d = self.decay
        return {"swa": {
            k: d * stats["swa"][k] + (1.0 - d) * x.astype(jnp.float32)
            for k, x in panel.items()}}

    def merge_row(self, panel, stats=None, weights=None, *, spec=None,
                  use_pallas: bool = False, block_d: int = 512,
                  interpret: bool = True, live=None):
        if stats is None:
            raise ValueError(
                "merger 'swa' needs its accumulator stats panel "
                "(stats=...); build it with init_stats / "
                "init_panel_state(merger='swa')")
        return panel_mod.merged(stats["swa"], spec=spec,
                                use_pallas=use_pallas, block_d=block_d,
                                interpret=interpret, live=live)


MERGERS = {
    "uniform": UniformMerger(),
    "weighted": WeightedMerger(),
    "var": VarMerger(),
    "fisher": FisherMerger(),
    "ties": TiesMerger(),
    "swa": SwaMerger(),
}


def get_merger(name):
    """Resolve a merge operator by registry name; Merger instances pass
    through (lets tests/benches build e.g. TiesMerger(trim=1.0))."""
    if not isinstance(name, str) and hasattr(name, "merge_row"):
        return name
    try:
        return MERGERS[name]
    except KeyError:
        raise ValueError(
            f"unknown merge operator {name!r}; known: {sorted(MERGERS)}"
        ) from None


def decode_stats(stats, spec):
    """Dequantize stat panels held in a residency STORAGE layout.

    Under a ``--residency stats=...`` policy the engine carries
    ``state["merge_stat"]`` in its storage encoding (e.g. int8 q+scale
    dicts); every merge entry point decodes through the spec's storage
    codec before the operator reads them. ``Storage.maybe_read`` is
    idempotent on already-decoded f32 leaves, so in-engine callers that
    decoded at round entry pass through unchanged — as do bare-spec
    (f32-residency) runs, bit-exactly."""
    if stats is None or spec is None:
        return stats
    name = spec.residency_of("stats")
    if name == "f32":
        return stats
    from repro import residency as residency_mod
    st = residency_mod.get_storage(name)
    return {sn: {g: st.maybe_read(v) for g, v in grp.items()}
            for sn, grp in stats.items()}


@scope("merge.panel")
def merge_panel(panel, merger, *, stats=None, weights=None, spec=None,
                wire_dtype=None, key=None, err=None,
                use_pallas: bool = False, block_d: int = 512,
                interpret: bool = True, live=None):
    """One global merge ROUND through an operator: every agent transmits
    its panel through the spec's wire-codec policy (exactly like
    ``panel.global_merge`` — stochastic codecs take ``key=``, error
    feedback threads ``err=``), the operator folds the decoded payloads
    into ONE merged row, and the row is broadcast back to all agents.

    The statistics panels are merge METADATA (Fisher weights, SWA
    accumulators) and do not ride the parameter wire here — compressing
    them is a follow-up, the payload accounting covers the params only.
    An operator that never reads the parameter panel
    (``uses_panel=False``, e.g. swa merging the accumulators) skips the
    codec entirely: nothing travels the parameter wire, so nothing may
    be quantized and the EF residual passes through untouched (the idle-
    round rule).

    ``live`` ((m,) bool) makes the round elastic: only live rows feed
    the operator, only live rows receive the broadcast (dead agents'
    parameter AND residual rows pass through bit-exactly — the idle-row
    rule applied per agent), and the merged row is the live sub-panel's.

    Returns ``(mixed, row, new_err)``: the broadcast (m, D) panel in
    storage dtypes, the merged {group: (D_g,) f32} row, and the updated
    EF residual (None when ``err`` is)."""
    merger = get_merger(merger)
    stats = decode_stats(stats, spec)
    pallas = panel_mod._pallas_ok(use_pallas, spec)
    delta = {k: False for k in panel}
    if merger.uses_panel:
        codecs = panel_mod._codecs(panel, spec, wire_dtype)
        keys = panel_mod._wire_keys(codecs, key)
        enc, backs = {}, {}
        new_err = {} if err is not None else None
        for k, x in panel.items():
            e = err[k] if err is not None else None
            if getattr(codecs[k], "delta_mix", False):
                # delta (mirror) codecs: a sparse payload cannot sync a
                # one-shot merge, so the GLOBAL round is their
                # full-bandwidth round (panel.global_merge delta rule):
                # the operator sees the exact panel and the mirror is
                # reset to the post-merge state below. The mirror is
                # still REQUIRED — a caller without it would leave the
                # next delta mix pulling on an arbitrarily stale mirror
                if e is None:
                    raise ValueError(
                        f"codec '{codecs[k].name}' carries a mirror "
                        "panel and needs it (err=...)")
                delta[k] = True
                enc[k] = x.astype(jnp.float32)
                backs[k] = wire_codec._storage_back(x.dtype)
                continue
            xw, back, ne = codecs[k].encode(x, key=keys[k], err=e,
                                            use_pallas=pallas,
                                            interpret=interpret)
            enc[k] = xw
            backs[k] = back
            if err is not None:
                new_err[k] = panel_mod._constrain_group(ne, spec, k)
    else:
        enc = panel
        backs = {k: _identity_back for k in panel}
        new_err = err
    row = merger.merge_row(enc, stats=stats, weights=weights, spec=spec,
                           use_pallas=use_pallas, block_d=block_d,
                           interpret=interpret, live=live)
    lcol = None if live is None else live[:, None]
    mixed = {}
    for k, x in panel.items():
        if delta[k]:
            y32 = jnp.broadcast_to(row[k][None], x.shape)
            if lcol is not None:
                # dead rows keep their params AND their mirror: they
                # did not see this merge, so the next delta mix must
                # still pull against their pre-merge mirror
                y32 = jnp.where(lcol, y32, x.astype(jnp.float32))
            mixed[k] = panel_mod._constrain_group(backs[k](y32), spec, k)
            if new_err is not None:
                ne = y32.astype(jnp.float32)
                if lcol is not None:
                    ne = jnp.where(lcol, ne, err[k])
                new_err[k] = panel_mod._constrain_group(ne, spec, k)
            continue
        y = backs[k](jnp.broadcast_to(row[k][None], x.shape)
                     .astype(enc[k].dtype))
        if lcol is not None:
            y = jnp.where(lcol, y, x)
            if new_err is not None:
                new_err[k] = jnp.where(lcol, new_err[k], err[k])
        mixed[k] = panel_mod._constrain_group(y, spec, k)
    return mixed, row, new_err
