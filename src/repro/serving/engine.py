"""Continuous-batching serving engine for the (merged) model.

The artifact decentralized training produces — after the paper's single
global merging — is ONE model; this module serves it maxtext/JetStream
style with a three-op split:

* **prefill(request)** — run the prompt at its exact length (one jit trace
  per distinct prompt length) against a cache row already sized for the
  full decode horizon (``max_len``);
* **insert(row, slot)** — splice that B=1 cache row into slot ``s`` of the
  engine's persistent slotted cache: every cache/state leaf is laid out
  ``(n_rep, max_concurrency, ...)`` and a slot is row ``s`` of axis 1
  across all layers' KV rings, recurrent states and cross-attention
  caches. The buffer is created once and DONATED through insert and step,
  so decode never reallocates it;
* **step()** — ONE jitted decode step over all slots at once, each at its
  own absolute position (per-slot position vectors), sampling one token
  per slot.

A host-side scheduler (:class:`ServingEngine`) admits queued requests into
free slots and retires slots on EOS / max-new, so heterogeneous-length
requests stream through a single compiled decode step — continuous
batching. At temperature 0 the engine is token-bit-identical to running
each request alone through :func:`generate` (pinned by tests): padded /
retired slots only ever contribute exact zeros to other rows' softmax
sums, and all per-row compute is batch-independent.

Sampling masks logits columns >= ``cfg.vocab_size`` to -inf first: the LM
head projects to ``cfg.padded_vocab`` (models/model.py) and the padding
columns carry random-init weights, so unmasked greedy/temperature sampling
can emit out-of-vocab ids.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.telemetry import annotate, histogram_set, scope


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------


def mask_oov(logits, vocab_size: Optional[int]):
    """Mask the padded-vocab tail: columns >= vocab_size go to -inf."""
    if vocab_size is None or vocab_size >= logits.shape[-1]:
        return logits
    oov = jnp.arange(logits.shape[-1]) >= vocab_size
    return jnp.where(oov, -jnp.inf, logits)


def sample_token(logits, rng, temperature: float = 0.0,
                 vocab_size: Optional[int] = None):
    """Greedy (temperature<=0) or categorical sample, never out-of-vocab.

    ``vocab_size`` is the REAL vocab; the head matmul is over
    ``padded_vocab`` whose tail columns are random-init — they must be
    masked before argmax/categorical or both can return ids outside the
    vocab."""
    logits = mask_oov(logits, vocab_size)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, logits / temperature, axis=-1).astype(
        jnp.int32)


# ---------------------------------------------------------------------------
# jitted ops
# ---------------------------------------------------------------------------


def make_prefill_fn(model, max_len: Optional[int] = None):
    def prefill(params, batch):
        with scope("serve.prefill"):
            return model.prefill(params, batch, max_len=max_len)
    return jax.jit(prefill)


def make_decode_fn(model):
    """Jitted decode step with the cache DONATED: the new cache aliases the
    input buffer in place instead of copying max_len of KV per token.
    Callers must not reuse the cache they passed in afterwards."""
    def decode(params, caches, tokens, index):
        with scope("serve.decode"):
            return model.decode_step(params, caches, tokens, index)
    return jax.jit(decode, donate_argnums=(1,))


def _tree_insert(caches, row, slot):
    """Splice a B=1 cache row (from prefill) into slot ``slot`` (axis 1 of
    every leaf) of the slotted cache. Leaves whose trailing dims are
    shorter than the engine's (cross-attention KV at the request's encoder
    length) are padded up — position leaves with -1 so the padding stays
    masked, everything else with zeros."""
    def put(path, big, r):
        r = r.astype(big.dtype)
        if r.shape[2:] != big.shape[2:]:
            cval = -1 if getattr(path[-1], "key", None) == "pos" else 0
            pads = [(0, 0), (0, 0)] + [(0, b - s) for b, s in
                                       zip(big.shape[2:], r.shape[2:])]
            r = jnp.pad(r, pads, constant_values=cval)
        return jax.lax.dynamic_update_slice_in_dim(big, r, slot, axis=1)
    with scope("serve.insert"):
        return jax.tree_util.tree_map_with_path(put, caches, row)


# ---------------------------------------------------------------------------
# one-shot generate (static batch)
# ---------------------------------------------------------------------------


def generate(model, params, batch, max_new: int, *, temperature: float = 0.0,
             rng=None, max_len: Optional[int] = None,
             eos_id: Optional[int] = None):
    """batch: model input dict with 'tokens' (B, S_prompt). Returns
    (B, max_new) generated tokens.

    The decode loop runs ON DEVICE inside one jit (lax.scan, or
    lax.while_loop with early exit when ``eos_id`` is set): tokens are
    collected in a device buffer and fetched ONCE at the end — no
    per-token host sync — and the prefill cache is donated into the loop.
    Rows that hit ``eos_id`` keep emitting ``eos_id`` and stop advancing
    their logits' influence; once every row is done the loop exits early
    so retired requests stop consuming decode steps."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    B, S = batch["tokens"].shape
    prefix = batch["patch_embeds"].shape[1] if "patch_embeds" in batch else 0
    S = S + prefix  # absolute positions include the multimodal prefix
    total = max_len or (S + max_new)
    V = model.cfg.vocab_size
    prefill = make_prefill_fn(model, max_len=total)
    logits, caches = prefill(params, batch)

    def body(carry):
        i, caches, logits, rng, done, out = carry
        rng, k = jax.random.split(rng)
        tok = sample_token(logits, k, temperature, vocab_size=V)
        if eos_id is not None:
            tok = jnp.where(done, eos_id, tok)
            done = done | (tok == eos_id)
        out = jax.lax.dynamic_update_slice(out, tok[:, None], (0, i))
        logits, caches = model.decode_step(params, caches, tok[:, None],
                                           jnp.asarray(S, jnp.int32) + i)
        return i + 1, caches, logits, rng, done, out

    @partial(jax.jit, donate_argnums=(1,))
    def loop(logits, caches, rng):
        out0 = jnp.full((B, max_new),
                        eos_id if eos_id is not None else 0, jnp.int32)
        carry = (jnp.asarray(0, jnp.int32), caches, logits, rng,
                 jnp.zeros((B,), bool), out0)
        if eos_id is None:
            carry, _ = jax.lax.scan(lambda c, _: (body(c), None), carry,
                                    None, length=max_new)
        else:
            carry = jax.lax.while_loop(
                lambda c: (c[0] < max_new) & ~jnp.all(c[4]), body, carry)
        # the cache is returned (and dropped by the caller) so the donated
        # input buffer has an output to alias — in-place for the whole loop
        return carry[1], carry[-1]

    _, out = loop(logits, caches, rng)
    return np.asarray(out)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


@dataclass
class Request:
    """One serving request: prompt ids + optional multimodal extras
    (``patch_embeds`` (P, d) / ``frame_embeds`` (S, d), unbatched)."""
    rid: Any
    tokens: np.ndarray
    max_new: int = 16
    extras: Dict[str, Any] = field(default_factory=dict)


class _Slot:
    __slots__ = ("req", "pos", "last", "out", "t_first")

    def __init__(self, req, pos, first_token, t_first=0.0):
        self.req = req
        self.pos = pos  # absolute position of the NEXT token to feed
        self.last = first_token
        self.out = [first_token]
        self.t_first = t_first  # perf_counter at first token (TTFT mark)


class ServingEngine:
    """Slotted continuous-batching engine (see module docstring).

    ``max_len`` bounds prefix + prompt + max_new per request; the slotted
    cache holds ``max_concurrency`` such rows as one persistent donated
    device buffer. ``step()`` fetches exactly one (C,) token vector per
    tick — the scheduler needs the ids to retire slots — everything else
    stays on device.

    **Telemetry.** The engine keeps its own fixed-bucket latency
    histograms (:mod:`repro.telemetry.latency`): ``ttft_s`` (submit →
    first token, covers queue + prefill), ``queue_wait_s`` (submit →
    admission), ``decode_step_s`` (one jitted step incl. the (C,) token
    fetch) and ``per_token_s`` (a retired request's steady-state decode
    rate: time from its first token to retirement over tokens-1).
    :meth:`snapshot` exports counters + occupancy + histogram summaries;
    :meth:`reset` zeroes them WITHOUT touching live slots or queued work,
    so callers can discard warmup/compile ticks (serve_bench, the serve
    CLI). Passing ``events=`` an :class:`repro.telemetry.EventLog` emits
    typed ``request_submit``/``request_admit``/``request_retire``
    records.
    """

    def __init__(self, model, params, *, max_concurrency: int = 4,
                 max_len: int = 128, eos_id: Optional[int] = None,
                 temperature: float = 0.0, rng=None, pad_id: int = 0,
                 events=None):
        self.model, self.params = model, params
        self.cfg = model.cfg
        self.C, self.max_len = int(max_concurrency), int(max_len)
        self.eos_id = eos_id
        self.temperature = float(temperature)
        self.pad_id = int(pad_id)
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.caches = model.init_cache(self.C, self.max_len,
                                       enc_len=self.max_len)
        self._empty_row = model.init_cache(1, self.max_len,
                                           enc_len=self.max_len)
        self._prefill = make_prefill_fn(model, max_len=self.max_len)
        self._insert_fn = jax.jit(_tree_insert, donate_argnums=(0,))
        V = self.cfg.vocab_size
        temp = self.temperature

        def step_fn(params, caches, tokens, index, rng):
            with scope("serve.decode"):
                logits, caches = model.decode_step(params, caches,
                                                   tokens[:, None], index)
            with scope("serve.sample"):
                tok = sample_token(logits, rng, temp, vocab_size=V)
            return caches, tok

        self._step_fn = jax.jit(step_fn, donate_argnums=(1,))
        self._slots: List[Optional[_Slot]] = [None] * self.C
        self.queue: collections.deque = collections.deque()
        self.results: Dict[Any, np.ndarray] = {}
        self.stats = {"capacity": self.C, "ticks": 0, "live_slot_ticks": 0,
                      "admitted": 0, "retired": 0, "prefill_tokens": 0}
        self.hists = histogram_set(
            ("ttft_s", "queue_wait_s", "decode_step_s", "per_token_s"))
        self._t_submit: Dict[Any, float] = {}
        self.events = events

    # ------------------------------------------------------------ telemetry
    def snapshot(self) -> Dict[str, Any]:
        """Stats snapshot: counters + occupancy + latency summaries (and
        the raw sparse histograms, for cross-engine aggregation)."""
        return {**self.stats, "occupancy": self.occupancy,
                "latency": {k: h.summary() for k, h in self.hists.items()},
                "histograms": {k: h.to_dict() for k, h in
                               self.hists.items()}}

    def reset(self):
        """Zero counters and histograms; slots, queue and results are NOT
        touched — call after warmup so occupancy/latency cover only the
        measured window (the old dict was never resettable, so occupancy
        averaged over compile ticks)."""
        for k in ("ticks", "live_slot_ticks", "admitted", "retired",
                  "prefill_tokens"):
            self.stats[k] = 0
        for h in self.hists.values():
            h.reset()

    # ----------------------------------------------------- slot primitives
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def live_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    def insert(self, row_caches, slot: int):
        """Splice a B=1 cache row into ``slot`` (donates the old buffer)."""
        self.caches = self._insert_fn(self.caches, row_caches,
                                      jnp.asarray(slot, jnp.int32))

    def evict(self, slot: int):
        """Reset ``slot`` to the empty row (pos=-1 everywhere) and free it."""
        self.insert(self._empty_row, slot)
        self._slots[slot] = None

    # ------------------------------------------------------------ schedule
    def submit(self, req: Request):
        self._t_submit[req.rid] = time.perf_counter()
        self.queue.append(req)
        if self.events is not None:
            self.events.emit(
                "request_submit", rid=req.rid,
                prompt_len=int(np.asarray(req.tokens).size),
                max_new=int(req.max_new))

    def _sample_host(self, logits) -> int:
        self._rng, k = jax.random.split(self._rng)
        return int(sample_token(logits, k, self.temperature,
                                vocab_size=self.cfg.vocab_size)[0])

    def _retire_if_done(self, slot: int):
        s = self._slots[slot]
        if len(s.out) >= s.req.max_new or (
                self.eos_id is not None and s.last == self.eos_id):
            self.results[s.req.rid] = np.asarray(s.out, np.int32)
            self._slots[slot] = None
            self.stats["retired"] += 1
            if len(s.out) > 1:
                self.hists["per_token_s"].record(
                    (time.perf_counter() - s.t_first) / (len(s.out) - 1))
            if self.events is not None:
                self.events.emit("request_retire", rid=s.req.rid,
                                 slot=slot, tick=self.stats["ticks"],
                                 tokens=len(s.out))

    def admit(self) -> int:
        """Prefill queued requests into free slots. Returns #admitted."""
        n = 0
        for slot in self.free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            t_sub = self._t_submit.pop(req.rid, None)
            if t_sub is not None:
                self.hists["queue_wait_s"].record(
                    time.perf_counter() - t_sub)
            prompt = np.asarray(req.tokens, np.int32).reshape(-1)
            batch = {"tokens": jnp.asarray(prompt[None])}
            for key, val in req.extras.items():
                batch[key] = jnp.asarray(val)[None]
            prefix = (batch["patch_embeds"].shape[1]
                      if "patch_embeds" in batch else 0)
            start = prefix + prompt.shape[0]
            if start + req.max_new > self.max_len:
                raise ValueError(
                    f"request {req.rid!r}: prefix+prompt+max_new = "
                    f"{start + req.max_new} exceeds max_len={self.max_len}")
            with annotate("serve.admit"):
                logits, row = self._prefill(self.params, batch)
                self.insert(row, slot)
                first = self._sample_host(logits)
            t_first = time.perf_counter()
            if t_sub is not None:
                self.hists["ttft_s"].record(t_first - t_sub)
            self._slots[slot] = _Slot(req, start, first, t_first)
            self.stats["admitted"] += 1
            self.stats["prefill_tokens"] += int(start)
            n += 1
            if self.events is not None:
                self.events.emit("request_admit", rid=req.rid, slot=slot,
                                 tick=self.stats["ticks"])
            self._retire_if_done(slot)  # max_new == 1 / instant EOS
        return n

    def step(self):
        """One decode step over ALL slots. Returns [(rid, token), ...] for
        the live slots (in slot order)."""
        live = self.live_slots()
        tokens = np.full((self.C,), self.pad_id, np.int32)
        index = np.zeros((self.C,), np.int32)
        for i in live:
            tokens[i] = self._slots[i].last
            index[i] = self._slots[i].pos
        self._rng, k = jax.random.split(self._rng)
        t0 = time.perf_counter()
        with annotate("serve.step"):
            self.caches, tok = self._step_fn(self.params, self.caches,
                                             jnp.asarray(tokens),
                                             jnp.asarray(index), k)
            tok = np.asarray(tok)  # the ONE host fetch per tick: (C,) int32
        self.hists["decode_step_s"].record(time.perf_counter() - t0)
        self.stats["ticks"] += 1
        self.stats["live_slot_ticks"] += len(live)
        emitted = []
        for i in live:
            s = self._slots[i]
            s.pos += 1
            s.last = int(tok[i])
            s.out.append(s.last)
            emitted.append((s.req.rid, s.last))
            self._retire_if_done(i)
        return emitted

    @property
    def occupancy(self) -> float:
        """Live-slot-steps over capacity-steps across the run so far."""
        denom = self.stats["ticks"] * self.C
        return self.stats["live_slot_ticks"] / denom if denom else 0.0

    def serve(self, requests=None, *,
              stream: Optional[Callable[[Any, int], None]] = None):
        """Run until the queue and all slots drain. Returns {rid: tokens}
        (each (n,) int32, n <= max_new, ending at eos_id if hit)."""
        for r in requests or []:
            self.submit(r)
        while self.queue or self.live_slots():
            self.admit()
            if not self.live_slots():
                continue  # everything admitted retired instantly
            for rid, t in self.step():
                if stream is not None:
                    stream(rid, t)
        out, self.results = self.results, {}
        return out
