"""Batched serving engine for the (merged) model.

The artifact decentralized training produces — after the paper's single
global merging — is ONE model; serving it is plain sharded inference:
prefill builds the KV caches / recurrent states, then a jitted decode step
appends one token per request per call (greedy or temperature sampling).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def make_prefill_fn(model, max_len: Optional[int] = None):
    def prefill(params, batch):
        return model.prefill(params, batch, max_len=max_len)
    return jax.jit(prefill)


def make_decode_fn(model):
    def decode(params, caches, tokens, index):
        return model.decode_step(params, caches, tokens, index)
    return jax.jit(decode)


def sample_token(logits, rng, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, logits / temperature, axis=-1).astype(
        jnp.int32)


def generate(model, params, batch, max_new: int, *, temperature: float = 0.0,
             rng=None, max_len: Optional[int] = None):
    """batch: model input dict with 'tokens' (B, S_prompt). Returns
    (B, max_new) generated tokens. Host-side decode loop around jitted
    prefill/decode steps."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    B, S = batch["tokens"].shape
    prefix = batch["patch_embeds"].shape[1] if "patch_embeds" in batch else 0
    S = S + prefix  # absolute positions include the multimodal prefix
    total = max_len or (S + max_new)
    prefill = make_prefill_fn(model, max_len=total)
    decode = make_decode_fn(model)
    logits, caches = prefill(params, batch)
    out = []
    tok = None
    for i in range(max_new):
        rng, k = jax.random.split(rng)
        tok = sample_token(logits, k, temperature)
        out.append(np.asarray(tok))
        logits, caches = decode(params, caches, tok[:, None],
                                jnp.asarray(S + i, jnp.int32))
    return np.stack(out, axis=1)
