from repro.serving.engine import generate, make_decode_fn, make_prefill_fn  # noqa: F401
