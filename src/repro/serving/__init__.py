from repro.serving.engine import (Request, ServingEngine,  # noqa: F401
                                  generate, make_decode_fn, make_prefill_fn,
                                  mask_oov, sample_token)
