"""Consensus-distance and mergeability diagnostics (paper §5 quantities).

* Xi_t        — consensus distance sqrt( (1/m) sum_k ||theta_k - bar||^2 )
                (= sqrt Tr Gamma^(t)).
* u_term      — Monte-Carlo estimate of the progressive-sharpening term
                grad L(bar)^T grad Tr( H(bar) Gamma )  (Theorem 1's U^(t)
                leading part) via nested JVPs; negative under Assumption 4.
* mergeability_gap — counterfactual merged-model metric minus mean local
                metric (Def. 2 operationalised).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import panel as panel_mod
from repro.core.gossip import merged_model


def consensus_distance(params_stacked) -> jnp.ndarray:
    """Xi_t over an agent-stacked pytree (leaves (m, ...)). Backed by the
    flat-panel engine: one fused mean+deviation reduction per dtype group
    instead of a Python loop over leaves."""
    spec = panel_mod.make_spec(params_stacked)
    return panel_mod.consensus_distance(
        panel_mod.to_panel(params_stacked, spec))


def consensus_distance_tree(params_stacked) -> jnp.ndarray:
    """Per-leaf reference implementation (pre-panel path)."""
    total = 0.0
    m = None
    for x in jax.tree.leaves(params_stacked):
        m = x.shape[0]
        mean = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
        total = total + jnp.sum(jnp.square(x.astype(jnp.float32) - mean))
    return jnp.sqrt(total / m)


def gamma_trace(params_stacked) -> jnp.ndarray:
    return jnp.square(consensus_distance(params_stacked))


def _tree_dot(a, b):
    return sum(jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def u_term(loss_fn, params_stacked, batch) -> jnp.ndarray:
    """Estimate grad L(bar)^T grad Tr( H(bar) Gamma^(t) ).

    Tr(H Gamma) = (1/m) sum_k d_k^T H d_k with d_k = theta_k - bar. The
    directional derivative of s(theta)=Tr(H(theta) Gamma) along grad L is
    computed with one more JVP. Cubic AD nesting — use on CPU-scale models
    (benchmarks) only.
    """
    bar = merged_model(params_stacked)
    m = jax.tree.leaves(params_stacked)[0].shape[0]
    deltas = jax.tree.map(
        lambda x, b: jax.lax.stop_gradient(x.astype(jnp.float32) - b[None]),
        params_stacked, bar)

    def scalar_loss(p):
        out = loss_fn(p, batch)
        return out[0] if isinstance(out, tuple) else out

    grad_fn = jax.grad(scalar_loss)

    def sharpness(p):
        # (1/m) sum_k d_k^T H(p) d_k  via JVP of grad
        def one(k):
            d_k = jax.tree.map(lambda d: d[k], deltas)
            _, hvp = jax.jvp(grad_fn, (p,), (d_k,))
            return _tree_dot(hvp, d_k)
        return sum(one(k) for k in range(m)) / m

    g = grad_fn(bar)
    _, dir_deriv = jax.jvp(sharpness, (bar,), (g,))
    return dir_deriv


def mergeability_gap(eval_fn, params_stacked):
    """(metric(merged), mean_k metric(theta_k), gap). ``eval_fn`` maps a
    single (non-stacked) param tree to a scalar metric (e.g. accuracy)."""
    merged = merged_model(params_stacked)
    merged_metric = eval_fn(merged)
    local = jax.vmap(eval_fn)(params_stacked)
    mean_local = jnp.mean(local)
    return merged_metric, mean_local, merged_metric - mean_local
