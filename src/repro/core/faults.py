"""Deterministic fault-injection plans for elastic decentralized runs.

A :class:`FaultPlan` scripts which agents die and rejoin at which round —
the sampled-participation view (Rodio et al.) of agent churn: a dead
agent is just an identity row of a degraded W, not an error case. The
plan is pure host-side data (no randomness of its own), so replaying the
same plan reproduces the same trajectory bit-for-bit — the property the
resume tests and the fault-injection harness lean on.

Per-round, per-agent state (``FaultPlan.mask(t)`` — (m,) int8):

* ``LIVE`` (1)   — the agent trains, communicates, and updates its
  optimizer moments / codec state / merge statistics this round.
* ``DEAD`` (0)   — the agent is down: its parameter, moment, residual
  and statistics rows pass through the round bit-exactly (the engine's
  idle-row rule, extended per agent).
* ``RESYNC`` (2) — the agent's rejoin round: it takes no local steps
  (its state is stale), receives a full-precision pull of the live
  agents' post-mix mean, and re-initializes its optimizer moments,
  wire-codec state and merge statistics from the synced parameters. It
  is fully LIVE from the next round on. Survivors are never perturbed
  by a resync (the pull is row-local).

The launcher syntax (``--faults``) is ``AGENT@KILL[-REJOIN]`` joined by
``;``: ``"2@5-9;0@3"`` kills agent 2 at round 5 (rejoining at round 9)
and agent 0 at round 3 (forever). The process-level fault mode
(SIGKILL between segments) is the launcher's ``--die-after-segments``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

DEAD, LIVE, RESYNC = 0, 1, 2


@dataclass(frozen=True)
class FaultEvent:
    """One kill (and optional rejoin) of one agent.

    The agent is DEAD for rounds ``kill_at <= t < rejoin_at``, RESYNC at
    ``t == rejoin_at``, LIVE again after; ``rejoin_at=None`` means it
    never comes back."""
    agent: int
    kill_at: int
    rejoin_at: Optional[int] = None


class FaultPlan:
    """A deterministic set of :class:`FaultEvent` for an m-agent run."""

    def __init__(self, m: int, events: Sequence[FaultEvent] = ()):
        self.m = int(m)
        evs = sorted(events, key=lambda e: (e.agent, e.kill_at))
        for e in evs:
            if not 0 <= e.agent < self.m:
                raise ValueError(
                    f"fault event agent {e.agent} out of range for m={m}")
            if e.kill_at < 0:
                raise ValueError(f"kill round must be >= 0, got {e.kill_at}")
            if e.rejoin_at is not None and e.rejoin_at <= e.kill_at:
                raise ValueError(
                    f"agent {e.agent}: rejoin round {e.rejoin_at} must be "
                    f"after its kill round {e.kill_at}")
        for a, b in zip(evs, evs[1:]):
            if a.agent == b.agent:
                if a.rejoin_at is None:
                    raise ValueError(
                        f"agent {a.agent}: event after an open-ended kill "
                        f"at round {a.kill_at}")
                if b.kill_at <= a.rejoin_at:
                    raise ValueError(
                        f"agent {a.agent}: kill at round {b.kill_at} "
                        f"overlaps the rejoin at round {a.rejoin_at}")
        self.events: Tuple[FaultEvent, ...] = tuple(evs)

    def __bool__(self) -> bool:
        return bool(self.events)

    def mask(self, t: int) -> np.ndarray:
        """(m,) int8 of DEAD/LIVE/RESYNC at round ``t``."""
        lv = np.full(self.m, LIVE, np.int8)
        for e in self.events:
            if e.rejoin_at is not None and t == e.rejoin_at:
                lv[e.agent] = RESYNC
            elif e.kill_at <= t and (e.rejoin_at is None or t < e.rejoin_at):
                lv[e.agent] = DEAD
        return lv

    def alive(self, t: int) -> np.ndarray:
        """(m,) bool — fully-participating (LIVE) agents at round ``t``."""
        return self.mask(t) == LIVE

    def at(self, t: int) -> Tuple[Tuple[int, str], ...]:
        """The plan's transitions AT round ``t``: (agent, 'kill'|'rejoin')
        tuples in deterministic (agent, kill_at) order — the telemetry
        event log's fault records."""
        out = []
        for e in self.events:
            if e.kill_at == t:
                out.append((e.agent, "kill"))
            if e.rejoin_at is not None and e.rejoin_at == t:
                out.append((e.agent, "rejoin"))
        return tuple(out)

    # ------------------------------------------------------------- text
    @classmethod
    def parse(cls, m: int, spec: str) -> "FaultPlan":
        """``"2@5-9;0@3"`` -> FaultPlan (see module docstring)."""
        events = []
        for part in spec.split(";"):
            part = part.strip()
            if not part:
                continue
            try:
                agent_s, when = part.split("@")
                if "-" in when:
                    kill_s, rejoin_s = when.split("-")
                    rejoin = int(rejoin_s)
                else:
                    kill_s, rejoin = when, None
                agent, kill = int(agent_s), int(kill_s)
            except ValueError:
                raise ValueError(
                    f"bad fault event {part!r} (want AGENT@KILL or "
                    "AGENT@KILL-REJOIN, e.g. '2@5-9;0@3')") from None
            events.append(FaultEvent(agent, kill, rejoin))
        return cls(m, events)

    def __str__(self) -> str:
        """Canonical ``parse`` syntax — stable across sessions, so it can
        sit in a checkpoint fingerprint."""
        return ";".join(
            f"{e.agent}@{e.kill_at}" + (f"-{e.rejoin_at}"
                                        if e.rejoin_at is not None else "")
            for e in self.events)

    def __repr__(self) -> str:
        return f"FaultPlan(m={self.m}, '{self}')"
