"""Gossip mixing of agent-stacked parameter pytrees.

Every leaf of an agent-stacked pytree has shape (m, ...) with the leading
axis sharded over the ('pod','agent') mesh axes. The public functions are
backed by the flat-panel engine (core/panel.py): the pytree is flattened
into per-dtype (m, D) panels and each mixing form lowers to ONE fused op
per dtype group instead of one op per leaf:

* :func:`mix_dense` — the paper-faithful general mixing-matrix form
  Theta <- Theta W: a single (m,m)x(m,D) matmul with f32 accumulation.
  Works for ANY doubly-stochastic W, including W=I.
* :func:`mix_pairwise` — optimized path for (partial) matchings:
  theta_k <- (1-w) theta_k + w theta_{partner[k]} — one gather along the
  agent axis (O(P) bytes, lowered to collective-permute/all-to-all).
* :func:`global_merge` — optimized path for the fully-connected rounds and
  the paper's single final merging: mean over the agent axis (one
  all-reduce, O(P) ring bytes) broadcast back.

``wire_dtype`` optionally casts parameters to bf16 for the communication
only (beyond-paper compression lever; see EXPERIMENTS.md §Perf).

The per-leaf originals survive as ``*_tree``: they are the reference the
panel path is validated/benchmarked against, and the right lowering when
leaves carry heterogeneous shardings (the launch/dryrun.py pod meshes,
where concatenating differently-sharded leaves would force resharding).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import panel as panel_mod
from repro.core.panel import _wire  # shared wire-cast helper


def _via_panel(op, params):
    spec = panel_mod.make_spec(params)
    return panel_mod.from_panel(op(panel_mod.to_panel(params, spec)), spec)


def mix_dense(params, W, wire_dtype=None):
    """Theta <- W Theta  (row k: sum_l W[k,l] theta_l) — one fused matmul
    per dtype group over the flattened panel."""
    return _via_panel(
        lambda p: panel_mod.mix_dense(p, W, wire_dtype=wire_dtype), params)


def mix_pairwise(params, partner, weight=0.5, wire_dtype=None):
    """theta_k <- (1-w) theta_k + w theta_{partner[k]}; partner: (m,) int32.

    partner[k] == k means agent k idles this round (no communication)."""
    return _via_panel(
        lambda p: panel_mod.mix_pairwise(p, partner, weight,
                                         wire_dtype=wire_dtype), params)


def global_merge(params, wire_dtype=None):
    """Single global merging: theta_k <- mean_l theta_l for every k."""
    return _via_panel(
        lambda p: panel_mod.global_merge(p, wire_dtype=wire_dtype), params)


def merged_model(params):
    """The (counterfactual) globally averaged model: drops the agent axis.
    One fused mean-reduce per dtype group; leaves come back f32."""
    spec = panel_mod.make_spec(params)
    return panel_mod.merged_tree(panel_mod.to_panel(params, spec), spec)


# ---------------------------------------------------------------------------
# Per-leaf tree-map reference path (pre-panel implementation).
# ---------------------------------------------------------------------------


def mix_dense_tree(params, W, wire_dtype=None):
    """Per-leaf Theta <- W Theta: one tensordot per pytree leaf."""
    def leaf(x):
        xw, back = _wire(x, wire_dtype)
        y = jnp.tensordot(W.astype(xw.dtype), xw, axes=1)
        return back(y)
    return jax.tree.map(leaf, params)


def mix_pairwise_tree(params, partner, weight=0.5, wire_dtype=None):
    """Per-leaf pairwise exchange: one gather per pytree leaf."""
    def leaf(x):
        xw, back = _wire(x, wire_dtype)
        peer = jnp.take(xw, partner, axis=0)
        return back((1.0 - weight) * xw + weight * peer.astype(xw.dtype))
    return jax.tree.map(leaf, params)


def global_merge_tree(params, wire_dtype=None):
    """Per-leaf global merging: one mean-reduce per pytree leaf."""
    def leaf(x):
        xw, back = _wire(x, wire_dtype)
        mean = jnp.mean(xw.astype(jnp.float32), axis=0, keepdims=True)
        return back(jnp.broadcast_to(mean, xw.shape).astype(xw.dtype))
    return jax.tree.map(leaf, params)


def merged_model_tree(params):
    """Per-leaf averaged model (f32 leaves, agent axis dropped)."""
    return jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32), axis=0),
                        params)


# ---------------------------------------------------------------------------
# shard_map collective variants (explicit psum over the agent mesh axes).
# Used by the optimized training step in launch/ — identical math to
# global_merge but guaranteed to lower to one all-reduce.
# ---------------------------------------------------------------------------


def global_merge_shmap(params, mesh, param_pspecs, agent_axes=("pod", "agent")):
    """Explicit all-reduce merge: pmean over the agent mesh axes under
    shard_map. ``param_pspecs`` is the full PartitionSpec tree of the
    agent-stacked params (leading dim = agent axes)."""
    axes = tuple(a for a in agent_axes if a in mesh.axis_names)

    def body(p):
        return jax.tree.map(lambda x: jax.lax.pmean(x, axes), p)

    from jax.experimental.shard_map import shard_map
    f = shard_map(body, mesh=mesh, in_specs=(param_pspecs,),
                  out_specs=param_pspecs, check_rep=False)
    return f(params)
