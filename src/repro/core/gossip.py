"""Gossip mixing of agent-stacked parameter pytrees.

Every leaf of an agent-stacked pytree has shape (m, ...) with the leading
axis sharded over the ('pod','agent') mesh axes. The public functions are
backed by the flat-panel engine (core/panel.py): the pytree is flattened
into per-dtype (m, D) panels and each mixing form lowers to ONE fused op
per dtype group instead of one op per leaf:

* :func:`mix_dense` — the paper-faithful general mixing-matrix form
  Theta <- Theta W: a single (m,m)x(m,D) matmul with f32 accumulation.
  Works for ANY doubly-stochastic W, including W=I.
* :func:`mix_pairwise` — optimized path for (partial) matchings:
  theta_k <- (1-w) theta_k + w theta_{partner[k]} — one gather along the
  agent axis (O(P) bytes, lowered to collective-permute/all-to-all).
* :func:`global_merge` — optimized path for the fully-connected rounds and
  the paper's single final merging: mean over the agent axis (one
  all-reduce, O(P) ring bytes) broadcast back.

``wire_dtype`` optionally casts parameters to bf16 for the communication
only (beyond-paper compression lever; see EXPERIMENTS.md §Perf). ``wire``
(a codec name from repro.wire — 'f32', 'bf16', 'int8', 'int8_ef',
'int4', 'int4_ef', 'topk') routes the payload through the quantized-wire
codec subsystem instead; the stochastic int8/int4 codecs need an
explicit ``key``. On the per-leaf ``*_tree`` path codecs apply
leaf-by-leaf (each leaf reshaped to its (m, size) panel, so int8 scales
are per-agent-per-LEAF and int4 group scales tile each leaf separately —
finer than the panel engine's per-dtype-group layout; the two paths
agree exactly only for scale-free codecs like f32/bf16). Codecs that
carry state are panel-engine-only and refused here: error feedback
(int8_ef/int4_ef) needs the residual panel, and the mirror-carrying
topk codec additionally mixes in delta form, which the per-leaf path
does not implement.

The per-leaf originals survive as ``*_tree``: they are the reference the
panel path is validated/benchmarked against, and the right lowering when
leaves carry heterogeneous shardings (the launch/dryrun.py pod meshes,
where concatenating differently-sharded leaves would force resharding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import wire as wire_mod
from repro.core import panel as panel_mod


def _via_panel(op, params, wire=None):
    spec = panel_mod.make_spec(params)
    if wire is not None:
        if wire_mod.get_codec(wire).error_feedback:
            raise ValueError(
                f"codec '{wire}' needs an error-feedback residual, which "
                "these stateless wrappers cannot carry; use the panel "
                "engine (dsgd.make_panel_segment) or 'int8'")
        spec = panel_mod.with_wire(spec, wire)
    return panel_mod.from_panel(op(panel_mod.to_panel(params, spec), spec),
                                spec)


def mix_dense(params, W, wire_dtype=None, wire=None, key=None):
    """Theta <- W Theta  (row k: sum_l W[k,l] theta_l) — one fused matmul
    per dtype group over the flattened panel."""
    return _via_panel(
        lambda p, s: panel_mod.mix_dense(p, W, wire_dtype=wire_dtype,
                                         spec=s, key=key), params, wire)


def mix_pairwise(params, partner, weight=0.5, wire_dtype=None, wire=None,
                 key=None):
    """theta_k <- (1-w) theta_k + w theta_{partner[k]}; partner: (m,) int32.

    partner[k] == k means agent k idles this round (no communication)."""
    return _via_panel(
        lambda p, s: panel_mod.mix_pairwise(p, partner, weight,
                                            wire_dtype=wire_dtype,
                                            spec=s, key=key), params, wire)


def global_merge(params, wire_dtype=None, wire=None, key=None):
    """Single global merging: theta_k <- mean_l theta_l for every k."""
    return _via_panel(
        lambda p, s: panel_mod.global_merge(p, wire_dtype=wire_dtype,
                                            spec=s, key=key), params, wire)


def merged_model(params):
    """The (counterfactual) globally averaged model: drops the agent axis.
    One fused mean-reduce per dtype group; leaves come back f32."""
    spec = panel_mod.make_spec(params)
    return panel_mod.merged_tree(panel_mod.to_panel(params, spec), spec)


# ---------------------------------------------------------------------------
# Per-leaf tree-map reference path (pre-panel implementation).
# ---------------------------------------------------------------------------


def _leaf_codec(wire_dtype, wire):
    """Codec shared by every leaf of one tree-path call (legacy wire_dtype
    wins, mirroring panel._codecs). Error-feedback codecs are refused:
    this path carries no residual state, so accepting them would silently
    degrade int8_ef to plain int8 — only the panel engine
    (dsgd.make_panel_segment + state["wire_err"]) honors error feedback."""
    if wire_dtype is not None:
        if wire is not None:
            raise ValueError("pass either wire_dtype= or wire=, not both")
        return wire_mod.dtype_codec(wire_dtype)
    codec = wire_mod.get_codec(wire if wire is not None else "f32")
    if codec.error_feedback:
        raise ValueError(
            f"codec '{codec.name}' needs an error-feedback residual, which "
            "the per-leaf tree path cannot carry; use the panel engine "
            "(dsgd.make_panel_segment) or a residual-free codec ('int8')")
    if getattr(codec, "delta_mix", False):
        # unreachable for the registry codecs (topk is error_feedback and
        # refused above) but guards future residual-free delta codecs:
        # this path mixes W @ payload, not x + (W - I) @ mirror
        raise ValueError(
            f"codec '{codec.name}' mixes in delta (mirror) form, which "
            "the per-leaf tree path does not implement; use the panel "
            "engine (dsgd.make_panel_segment)")
    return codec


def _encode_leaf(codec, x, key, i):
    """Apply a codec to one (m, ...) leaf: flatten to the leaf's (m, size)
    panel (int8 scales are per-agent-per-leaf here), fold the key by leaf
    index, reshape back."""
    m = x.shape[0]
    k = jax.random.fold_in(key, i) if (key is not None
                                       and codec.needs_key) else None
    xw, back, _ = codec.encode(x.reshape(m, -1), key=k)
    return xw.reshape((xw.shape[0],) + x.shape[1:]), back


def _tree_map_wire(fn, params, codec, key):
    leaves, treedef = jax.tree_util.tree_flatten(params)
    outs = []
    for i, x in enumerate(leaves):
        xw, back = _encode_leaf(codec, x, key, i)
        outs.append(back(fn(xw)))
    return jax.tree_util.tree_unflatten(treedef, outs)


def mix_dense_tree(params, W, wire_dtype=None, wire=None, key=None):
    """Per-leaf Theta <- W Theta: one tensordot per pytree leaf. Idle
    ROWS of W (rows equal to the identity row, e.g. unmatched agents in a
    matching) communicate nothing — under a lossy codec they keep their
    exact parameters (mirrors panel.mix_dense)."""
    codec = _leaf_codec(wire_dtype, wire)
    m = W.shape[0]
    idle = (None if isinstance(codec, wire_mod.F32Codec)
            else jnp.all(W == jnp.eye(m, dtype=W.dtype), axis=1))
    leaves, treedef = jax.tree_util.tree_flatten(params)
    outs = []
    for i, x in enumerate(leaves):
        xw, back = _encode_leaf(codec, x, key, i)
        y = back(jnp.tensordot(W.astype(xw.dtype), xw, axes=1))
        if idle is not None:
            y = jnp.where(idle.reshape((m,) + (1,) * (x.ndim - 1)), x, y)
        outs.append(y)
    return jax.tree_util.tree_unflatten(treedef, outs)


def mix_pairwise_tree(params, partner, weight=0.5, wire_dtype=None,
                      wire=None, key=None):
    """Per-leaf pairwise exchange: one gather per pytree leaf. Idle rows
    (partner[k] == k) keep their exact parameters — no codec touches
    them (mirrors panel.mix_pairwise)."""
    codec = _leaf_codec(wire_dtype, wire)
    m = jax.tree_util.tree_leaves(params)[0].shape[0]
    idle = partner == jnp.arange(m)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    outs = []
    for i, x in enumerate(leaves):
        xw, back = _encode_leaf(codec, x, key, i)
        peer = jnp.take(xw, partner, axis=0)
        y = back((1.0 - weight) * xw + weight * peer.astype(xw.dtype))
        outs.append(jnp.where(idle.reshape((m,) + (1,) * (x.ndim - 1)),
                              x, y))
    return jax.tree_util.tree_unflatten(treedef, outs)


def global_merge_tree(params, wire_dtype=None, wire=None, key=None,
                      live=None):
    """Per-leaf global merging: one mean-reduce per pytree leaf.

    ``live`` ((m,) bool) restricts the merge to the live agents: the
    mean is over live rows only and ONLY live rows receive it — dead
    rows pass through bit-exactly (the tree-path oracle of the engine's
    masked global rounds)."""
    codec = _leaf_codec(wire_dtype, wire)
    if live is None:
        def leaf(xw):
            mean = jnp.mean(xw.astype(jnp.float32), axis=0, keepdims=True)
            return jnp.broadcast_to(mean, xw.shape).astype(xw.dtype)

        return _tree_map_wire(leaf, params, codec, key)

    lf = live.astype(jnp.float32)
    lw = lf / jnp.maximum(jnp.sum(lf), 1.0)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    outs = []
    for i, x in enumerate(leaves):
        xw, back = _encode_leaf(codec, x, key, i)
        mean = jnp.tensordot(lw, xw.astype(jnp.float32), axes=1)
        y = back(jnp.broadcast_to(mean[None], xw.shape).astype(xw.dtype))
        outs.append(jnp.where(live.reshape((x.shape[0],)
                                           + (1,) * (x.ndim - 1)), y, x))
    return jax.tree_util.tree_unflatten(treedef, outs)


def merged_model_tree(params, live=None):
    """Per-leaf averaged model (f32 leaves, agent axis dropped).
    ``live`` ((m,) bool) averages the live agents' rows only."""
    if live is None:
        return jax.tree.map(
            lambda x: jnp.mean(x.astype(jnp.float32), axis=0), params)
    lf = live.astype(jnp.float32)
    lw = lf / jnp.maximum(jnp.sum(lf), 1.0)
    return jax.tree.map(
        lambda x: jnp.tensordot(lw, x.astype(jnp.float32), axes=1), params)


# ---------------------------------------------------------------------------
# shard_map collective variants (explicit psum over the agent mesh axes).
# Used by the optimized training step in launch/ — identical math to
# global_merge but guaranteed to lower to one all-reduce.
# ---------------------------------------------------------------------------


def global_merge_shmap(params, mesh, param_pspecs, agent_axes=("pod", "agent")):
    """Explicit all-reduce merge: pmean over the agent mesh axes under
    shard_map. ``param_pspecs`` is the full PartitionSpec tree of the
    agent-stacked params (leading dim = agent axes)."""
    axes = tuple(a for a in agent_axes if a in mesh.axis_names)

    def body(p):
        return jax.tree.map(lambda x: jax.lax.pmean(x, axes), p)

    from jax.experimental.shard_map import shard_map
    f = shard_map(body, mesh=mesh, in_specs=(param_pspecs,),
                  out_specs=param_pspecs, check_rep=False)
    return f(params)
