"""Gossip mixing of agent-stacked parameter pytrees.

Every leaf of an agent-stacked pytree has shape (m, ...) with the leading
axis sharded over the ('pod','agent') mesh axes. Three mixing paths:

* :func:`mix_dense` — the paper-faithful general mixing-matrix form
  Theta <- Theta W, one ``tensordot`` per leaf. XLA SPMD lowers the
  contraction over the sharded agent axis to an all-gather (O(m P) wire
  bytes). Works for ANY doubly-stochastic W, including W=I.
* :func:`mix_pairwise` — optimized path for (partial) matchings:
  theta_k <- (1-w) theta_k + w theta_{partner[k]} — one gather along the
  agent axis (O(P) bytes, lowered to collective-permute/all-to-all).
* :func:`global_merge` — optimized path for the fully-connected rounds and
  the paper's single final merging: mean over the agent axis (one
  all-reduce, O(P) ring bytes) broadcast back.

``wire_dtype`` optionally casts parameters to bf16 for the communication
only (beyond-paper compression lever; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _wire(x, wire_dtype):
    if wire_dtype is None or x.dtype == wire_dtype:
        return x, lambda y: y
    return x.astype(wire_dtype), lambda y: y.astype(x.dtype)


def mix_dense(params, W, wire_dtype=None):
    """Theta <- W Theta  (row k: sum_l W[k,l] theta_l)."""
    def leaf(x):
        xw, back = _wire(x, wire_dtype)
        y = jnp.tensordot(W.astype(xw.dtype), xw, axes=1)
        return back(y)
    return jax.tree.map(leaf, params)


def mix_pairwise(params, partner, weight=0.5, wire_dtype=None):
    """theta_k <- (1-w) theta_k + w theta_{partner[k]}; partner: (m,) int32.

    partner[k] == k means agent k idles this round (no communication)."""
    def leaf(x):
        xw, back = _wire(x, wire_dtype)
        peer = jnp.take(xw, partner, axis=0)
        return back((1.0 - weight) * xw + weight * peer.astype(xw.dtype))
    return jax.tree.map(leaf, params)


def global_merge(params, wire_dtype=None):
    """Single global merging: theta_k <- mean_l theta_l for every k."""
    def leaf(x):
        xw, back = _wire(x, wire_dtype)
        mean = jnp.mean(xw.astype(jnp.float32), axis=0, keepdims=True)
        return back(jnp.broadcast_to(mean, xw.shape).astype(xw.dtype))
    return jax.tree.map(leaf, params)


def merged_model(params):
    """The (counterfactual) globally averaged model: drops the agent axis."""
    return jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32), axis=0),
                        params)


# ---------------------------------------------------------------------------
# shard_map collective variants (explicit psum over the agent mesh axes).
# Used by the optimized training step in launch/ — identical math to
# global_merge but guaranteed to lower to one all-reduce.
# ---------------------------------------------------------------------------


def global_merge_shmap(params, mesh, param_pspecs, agent_axes=("pod", "agent")):
    """Explicit all-reduce merge: pmean over the agent mesh axes under
    shard_map. ``param_pspecs`` is the full PartitionSpec tree of the
    agent-stacked params (leading dim = agent axes)."""
    axes = tuple(a for a in agent_axes if a in mesh.axis_names)

    def body(p):
        return jax.tree.map(lambda x: jax.lax.pmean(x, axes), p)

    from jax.experimental.shard_map import shard_map
    f = shard_map(body, mesh=mesh, in_specs=(param_pspecs,),
                  out_specs=param_pspecs, check_rep=False)
    return f(params)
