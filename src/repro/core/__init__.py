"""The paper's contribution: temporal communication allocation + single
global merging for decentralized learning, as a composable JAX layer."""
from repro.core import (consensus, gossip, merge, panel,  # noqa: F401
                        schedule, topology)
from repro.core.dsgd import (init_panel_state, init_parallel_state,  # noqa: F401
                             init_state, make_dsgd_round, make_dsgd_step,
                             make_panel_segment, make_parallel_step,
                             panelize_state, unpanelize_state)
from repro.core.panel import PanelSpec, make_spec  # noqa: F401
