"""The paper's contribution: temporal communication allocation + single
global merging for decentralized learning, as a composable JAX layer."""
from repro.core import consensus, gossip, merge, schedule, topology  # noqa: F401
from repro.core.dsgd import (init_parallel_state, init_state,  # noqa: F401
                             make_dsgd_round, make_dsgd_step,
                             make_parallel_step)
