"""Mixing-matrix generators for decentralized communication graphs.

All matrices are doubly stochastic (Assumption 1). The paper's primary
topology is "random R": each agent activates an exchange with one random
peer with probability R (R=0.2 in the main experiments); we realise this as
a random partial matching — pairs average 50/50, unmatched agents keep their
parameters (W row = e_k).

``spectral_p(W_samples)`` estimates the consensus-contraction constant p of
Assumption 1 from E[W^T W]; for a fixed W it is 1 - lambda_2(W^T W).
"""
from __future__ import annotations

import numpy as np


def identity(m: int) -> np.ndarray:
    return np.eye(m, dtype=np.float64)


def fully_connected(m: int) -> np.ndarray:
    return np.full((m, m), 1.0 / m, dtype=np.float64)


def ring(m: int) -> np.ndarray:
    """Symmetric ring gossip: 1/3 self + 1/3 each neighbour."""
    W = np.zeros((m, m))
    for k in range(m):
        W[k, k] = 1 / 3
        W[k, (k - 1) % m] += 1 / 3
        W[k, (k + 1) % m] += 1 / 3
    return W


def exponential(m: int) -> np.ndarray:
    """One-peer exponential graph (Ying et al. 2021): static average over
    hops 2^0..2^(log2(m)-1), doubly stochastic."""
    hops = []
    h = 1
    while h < m:
        hops.append(h)
        h *= 2
    W = np.zeros((m, m))
    for k in range(m):
        W[k, k] = 1.0 / (len(hops) + 1)
        for h in hops:
            W[k, (k + h) % m] += 1.0 / (len(hops) + 1)
    # symmetrise to keep it doubly stochastic for undirected gossip
    W = 0.5 * (W + W.T)
    return W


def exponential_round(m: int, t: int) -> np.ndarray:
    """One-peer exponential graph, round t. For power-of-two m this is the
    hypercube (butterfly) matching k <-> k XOR 2^(t mod log2 m): a perfect
    matching per round, and log2(m) consecutive rounds realise the EXACT
    global average (used to approximate the final merge, Appendix C.3.4).
    Otherwise falls back to symmetric ring hops of 2^t."""
    n_hops = max(1, int(np.log2(m)))
    h = 2 ** (t % n_hops)
    W = np.zeros((m, m))
    if m & (m - 1) == 0:  # power of two: XOR pairing
        for k in range(m):
            W[k, k] += 0.5
            W[k, k ^ h] += 0.5
        return W
    for k in range(m):
        W[k, (k + h) % m] += 0.5
        W[k, (k - h) % m] += 0.5
    return W


def random_matching(m: int, prob: float, rng: np.random.Generator
                    ) -> np.ndarray:
    """Paper's "R" topology: each agent wants one random peer w.p. ``prob``;
    realised as a random partial matching (pairs average 50/50)."""
    W = np.eye(m)
    active = [k for k in range(m) if rng.random() < prob]
    rng.shuffle(active)
    for i in range(0, len(active) - 1, 2):
        a, b = active[i], active[i + 1]
        W[a, a] = W[b, b] = 0.5
        W[a, b] = W[b, a] = 0.5
    return W


def partner_array(W: np.ndarray) -> np.ndarray:
    """For pairwise-matching W: partner[k] (or k itself if idle)."""
    m = W.shape[0]
    partner = np.arange(m)
    for k in range(m):
        for l in range(m):
            if l != k and W[k, l] > 0:
                partner[k] = l
    return partner


def is_doubly_stochastic(W: np.ndarray, tol=1e-8) -> bool:
    return (np.all(W >= -tol)
            and np.allclose(W.sum(0), 1.0, atol=tol)
            and np.allclose(W.sum(1), 1.0, atol=tol))


def spectral_p(W: np.ndarray) -> float:
    """p from Assumption 1 for a fixed W: 1 - lambda_max(W^T W) on 1^perp."""
    m = W.shape[0]
    P = np.eye(m) - np.full((m, m), 1.0 / m)
    M = P @ (W.T @ W) @ P
    lam = np.max(np.linalg.eigvalsh(0.5 * (M + M.T)))
    return float(1.0 - min(max(lam, 0.0), 1.0))


def expected_p(sampler, m: int, rounds: int, rng) -> float:
    """Monte-Carlo estimate of p for a randomized topology: uses
    E_W[||Theta W - Thetabar||^2] = Tr(Theta P E[W W^T] P Theta^T)."""
    acc = np.zeros((m, m))
    for t in range(rounds):
        W = sampler(t, rng)
        acc += W @ W.T
    E = acc / rounds
    P = np.eye(m) - np.full((m, m), 1.0 / m)
    M = P @ E @ P
    lam = np.max(np.linalg.eigvalsh(0.5 * (M + M.T)))
    return float(1.0 - min(max(lam, 0.0), 1.0))


def degrade_to_live(W: np.ndarray, live) -> np.ndarray:
    """Restrict a mixing matrix to the surviving subgraph.

    Dead agents (``live[k] == False``) neither send nor receive: their
    rows AND columns become the identity e_k, and every survivor folds
    the mass it would have exchanged with dead peers back into its own
    self-loop (the lazy-repair rule). For a symmetric W (every topology
    in this module) the result is again doubly stochastic, restricted to
    the live block; for a general row-stochastic W row sums are still
    preserved. ``live`` all-True returns W unchanged (same float64
    array semantics, no fault-path drift)."""
    live = np.asarray(live, bool)
    Wd = np.array(W, np.float64)
    if live.all():
        return Wd
    m = Wd.shape[0]
    dead = ~live
    dropped = Wd[:, dead].sum(axis=1)
    Wd[:, dead] = 0.0
    Wd[dead, :] = 0.0
    idx = np.arange(m)
    Wd[idx, idx] += np.where(live, dropped, 0.0)
    Wd[idx[dead], idx[dead]] = 1.0
    return Wd


def fully_connected_live(live) -> np.ndarray:
    """Global-merge matrix over the live subgraph: every live row is the
    uniform mean over the live agents (a sub-AllReduce), dead rows stay
    the identity e_k — so under a lossy wire codec the dead agents are
    idle rows and their parameters pass through bit-exactly. Doubly
    stochastic for any live mask; all-dead degrades to the identity."""
    live = np.asarray(live, bool)
    m = live.shape[0]
    n = int(live.sum())
    if n == 0:
        return identity(m)
    W = np.zeros((m, m))
    W[np.ix_(live, live)] = 1.0 / n
    idx = np.flatnonzero(~live)
    W[idx, idx] = 1.0
    return W


def make_sampler(kind: str, m: int, prob: float = 0.2):
    """Returns sampler(t, rng) -> W for a named topology family."""
    if kind == "random":
        return lambda t, rng: random_matching(m, prob, rng)
    if kind == "ring":
        return lambda t, rng: ring(m)
    if kind == "exponential":
        return lambda t, rng: exponential_round(m, t)
    if kind == "full":
        return lambda t, rng: fully_connected(m)
    if kind == "none":
        return lambda t, rng: identity(m)
    raise ValueError(kind)
