"""Decentralized training engine (Algorithm 1 of the paper).

Two state layouts:

* **Tree state** (:func:`init_state` + :func:`make_dsgd_step` /
  :func:`make_dsgd_round`) — every leaf of params/opt_state carries a
  leading (m,) agent axis (sharded over ('pod','agent') on the production
  mesh). Mixing is per-leaf (``gossip.*_tree``): the right lowering when
  leaves carry heterogeneous shardings (launch/dryrun.py), and the
  reference baseline for the panel engine.

* **Panel state** (:func:`init_panel_state` + :func:`make_panel_segment`)
  — params and optimizer moments live as persistent per-dtype (m, D)
  panels (core/panel.py). The segment driver scans a whole SCHEDULE
  SEGMENT of rounds on device (mixing matrices precomputed and stacked),
  donates the state buffers (in-place update, no per-round host
  dispatch), mixes with ONE fused matmul per dtype group, and returns
  per-round metrics as stacked arrays — a single device_get per segment.
  This is the hot path used by launch/train.py and benchmarked in
  benchmarks/panel_bench.py.

One round = per-agent local step(s) (vmapped grad + optimizer; zero
cross-agent traffic) followed by gossip mixing with the scheduler's W^(t).

``loss_fn(params, batch, rng) -> (loss, aux)`` is any per-agent objective
(an LM from repro.models, or the benchmark classifiers).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro import merging as merging_mod
from repro import wire as wire_mod
from repro.core import gossip
from repro.core import panel as panel_mod
from repro.core.consensus import consensus_distance_tree
from repro.optim.optim import Optimizer
from repro.telemetry import metrics as tmetrics
from repro.telemetry.trace import scope


def _init_agent_params(init_params: Callable, m: int, rng,
                       same_init: bool):
    """``same_init=True`` matches the theory (theta_k^0 = theta^0); False
    matches the paper's main experiments (independent inits — the harder
    cross-initialization merge)."""
    if same_init:
        p = init_params(rng)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), p)
    return jax.vmap(init_params)(jax.random.split(rng, m))


def _place(tree, shardings):
    """device_put (concrete) / sharding-constrain (traced) a pytree onto a
    matching tree of NamedSharding (panel_mod.place per leaf)."""
    return jax.tree.map(panel_mod.place, tree, shardings)


def init_state(init_params: Callable, optimizer: Optimizer, m: int, rng,
               same_init: bool = False, shardings=None):
    """Agent-stacked train state (see _init_agent_params for same_init).

    ``shardings`` (a pytree of NamedSharding matching the params tree,
    e.g. models.sharding.resolve(...) wrapped on a training mesh) places
    the params AND the parameter-shaped optimizer moments; step counters
    stay replicated."""
    params = _init_agent_params(init_params, m, rng, same_init)
    if shardings is not None:
        params = _place(params, shardings)
    opt_state = jax.vmap(optimizer.init)(params)
    if shardings is not None:
        opt_state = {k: (_place(v, shardings) if k in _MOMENT_KEYS else v)
                     for k, v in opt_state.items()}
    return {"params": params, "opt": opt_state,
            "step": jnp.zeros((), jnp.int32)}


# fold_in tag deriving the wire-codec key from a round's rng WITHOUT
# disturbing the local-step key schedule (so f32/bf16 runs stay bit-exact
# with the pre-codec engine, and idle rounds under any codec match them)
_WIRE_KEY_TAG = 0x77697265  # "wire"


def _wire_key(rng, needed: bool):
    return jax.random.fold_in(rng, _WIRE_KEY_TAG) if needed else None


def _tree_wire_check(wire) -> bool:
    """Validate a codec name for the tree-state drivers at build time
    (error feedback needs the panel engine's residual state); returns
    whether the codec draws a stochastic-rounding key."""
    if wire is None:
        return False
    codec = wire_mod.get_codec(wire)
    if codec.error_feedback:
        raise ValueError(
            f"codec '{codec.name}' needs an error-feedback residual; the "
            "tree-state drivers carry none — use the panel engine "
            "(make_panel_segment + init_panel_state(wire=...)) or 'int8'")
    return codec.needs_key


def _mix(params, W, impl: str, wire_dtype, wire=None, key=None):
    # Per-leaf mixing: tree-state steps are the sharding-aware reference
    # path (see module docstring); the fused panel path is make_panel_segment.
    # For impl == "pairwise" the step's W argument IS the (m,) int32
    # partner array (see topology.partner_array), not an (m, m) matrix.
    if impl == "dense":
        if wire_dtype is None and wire is None:
            return gossip.mix_dense_tree(params, W)
        # W == I rounds communicate nothing, so no codec may touch the
        # state (mirrors the panel engine's idle guard; pairwise idles
        # per-row inside mix_pairwise_tree)
        m = jax.tree.leaves(params)[0].shape[0]
        idle = jnp.all(W == jnp.eye(m, dtype=W.dtype))
        return jax.lax.cond(
            idle, lambda p: p,
            lambda p: gossip.mix_dense_tree(p, W, wire_dtype, wire, key),
            params)
    if impl == "pairwise":
        return gossip.mix_pairwise_tree(params, W, wire_dtype=wire_dtype,
                                        wire=wire, key=key)
    if impl == "merge":
        return gossip.global_merge_tree(params, wire_dtype, wire, key)
    if impl == "none":
        return params
    raise ValueError(impl)


def make_dsgd_step(loss_fn: Callable, optimizer: Optimizer, *,
                   gossip_impl: str = "dense",
                   wire_dtype=None, wire=None, monitor: bool = True):
    """One communication round with ONE local step per agent.

    step(state, batch, W, rng) -> (state, metrics); batch leaves (m, b, ...).
    With gossip_impl="pairwise", pass the (m,) int32 partner array as W.
    ``wire`` names a codec from repro.wire for the gossip payload (the
    stochastic int8 codecs draw their key from the step rng via fold_in).
    Error-feedback codecs are panel-engine-only (the tree state carries
    no residual) and are refused here.
    """
    needs_key = _tree_wire_check(wire)

    def step(state, batch, W, rng):
        m = jax.tree.leaves(state["params"])[0].shape[0]
        rngs = jax.random.split(rng, m)

        def one(p, b, r):
            (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b, r)
            return g, l

        grads, losses = jax.vmap(one)(state["params"], batch, rngs)
        new_p, new_opt = jax.vmap(optimizer.update)(
            grads, state["opt"], state["params"])
        mixed = _mix(new_p, W, gossip_impl, wire_dtype, wire,
                     _wire_key(rng, needs_key))
        metrics = {"loss": jnp.mean(losses)}
        if monitor:
            gbar = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
            metrics["grad_norm"] = jnp.sqrt(sum(
                jnp.sum(jnp.square(x)) for x in jax.tree.leaves(gbar)))
            metrics["consensus"] = consensus_distance_tree(mixed)
        return {"params": mixed, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return step


def make_dsgd_round(loss_fn: Callable, optimizer: Optimizer, local_steps: int,
                    *, gossip_impl: str = "dense", wire_dtype=None,
                    wire=None, monitor: bool = True):
    """One communication round with H local steps (paper: H=100).

    step(state, batches, W, rng): batches leaves (H, m, b, ...) — scanned.
    ``wire`` as in :func:`make_dsgd_step` (error-feedback codecs refused).
    """
    needs_key = _tree_wire_check(wire)

    def round_fn(state, batches, W, rng):
        m = jax.tree.leaves(state["params"])[0].shape[0]

        def body(carry, xs):
            params, opt = carry
            batch, r = xs
            rngs = jax.random.split(r, m)

            def one(p, b, rr):
                (l, aux), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(p, b, rr)
                return g, l

            grads, losses = jax.vmap(one)(params, batch, rngs)
            new_p, new_opt = jax.vmap(optimizer.update)(grads, opt, params)
            gbar = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                              for x in jax.tree.leaves(gbar)))
            return (new_p, new_opt), (jnp.mean(losses), gn)

        rngs = jax.random.split(rng, local_steps)
        (p, o), (losses, gns) = jax.lax.scan(
            body, (state["params"], state["opt"]), (batches, rngs))
        mixed = _mix(p, W, gossip_impl, wire_dtype, wire,
                     _wire_key(rng, needs_key))
        # mean AND max over the round's H local steps: reporting gns[-1]
        # alone silently dropped a gradient spike at any earlier local
        # step (tests/test_telemetry.py pins the regression)
        metrics = {"loss": jnp.mean(losses), "grad_norm": jnp.mean(gns),
                   "grad_norm_max": jnp.max(gns)}
        if monitor:
            metrics["consensus"] = consensus_distance_tree(mixed)
        return {"params": mixed, "opt": o,
                "step": state["step"] + local_steps}, metrics

    return round_fn


# ---------------------------------------------------------------------------
# Flat-panel engine: persistent (m, D) state, donated + scanned rounds.
# ---------------------------------------------------------------------------

# Optimizer-state entries that are parameter-shaped moment trees (AdamW m/v,
# SGD momentum mu); everything else (step_count) passes through unchanged.
_MOMENT_KEYS = ("m", "v", "mu")


def _wire_needs_ef(spec) -> bool:
    return any(wire_mod.get_codec(name).error_feedback
               for _, name in spec.wire)


def _init_wire_err(pan, spec):
    """Fresh spec-sharded error-feedback panels: each dtype group's codec
    seeds its own state (zeros for the quantization residuals, a copy of
    the panel for the topk mirror — Codec.init_err)."""
    return panel_mod.shard_panel(
        {k: wire_mod.get_codec(spec.wire_of(k)).init_err(v)
         for k, v in pan.items()}, spec)


def _wire_needs_key(spec) -> bool:
    return any(wire_mod.get_codec(name).needs_key for _, name in spec.wire)


def _wire_has_delta(spec) -> bool:
    return any(getattr(wire_mod.get_codec(name), "delta_mix", False)
               for _, name in spec.wire)


def _init_merge_stats(pan, spec):
    """Fresh, spec-sharded statistics panels for the spec's merge operator
    (None when the operator keeps no statistics)."""
    mg = merging_mod.get_merger(spec.merger)
    if not mg.stat_panels:
        return None
    return {name: panel_mod.shard_panel(stat, spec)
            for name, stat in mg.init_stats(pan).items()}


def init_panel_state(init_params: Callable, optimizer: Optimizer, m: int,
                     rng, same_init: bool = False, mesh=None, wire=None,
                     merger=None):
    """Panel train state: params AND optimizer moments as per-dtype (m, D)
    panels. Returns (state, spec); the static spec is what turns panels
    back into model pytrees. The optimizer transforms are elementwise, so
    they run directly on the panel leaves — no per-leaf dispatch.

    ``mesh`` shards the panels: rows over ('pod','agent'), D over 'fsdp'
    (panel_mod.shard_spec); the optimizer-moment panels mirror the
    parameter panel layout exactly.

    ``wire`` attaches a wire-codec policy to the spec (panel_mod.with_wire:
    a codec name for every dtype group, or a per-group dict). An
    error-feedback codec adds ``state["wire_err"]`` — one f32 panel per
    dtype group, laid out exactly like the parameter panel, seeded by the
    group's codec (Codec.init_err) and donated through the segment scan.
    For int8_ef/int4_ef that panel is the zero-initialised quantization
    residual; for the topk codec it is the MIRROR x̂ — the receive-side
    reconstruction every peer accumulates from past sparse innovations,
    seeded with a copy of the initial panel (one full-precision sync).

    ``merger`` names the merge operator global rounds apply
    (panel_mod.with_merger, repro.merging). A statistical operator
    (var/fisher/swa) adds ``state["merge_stat"]`` — its per-agent f32
    statistics panels, parameter-panel layout, donated through the scan
    and updated by the segment driver."""
    params = _init_agent_params(init_params, m, rng, same_init)
    spec = panel_mod.make_spec(params)
    if mesh is not None:
        spec = panel_mod.shard_spec(spec, mesh)
    if wire is not None:
        spec = panel_mod.with_wire(spec, wire)
    if merger is not None:
        spec = panel_mod.with_merger(spec, merger)
    pan = panel_mod.to_panel(params, spec)
    opt_state = jax.vmap(optimizer.init)(pan)
    if spec.sharded:
        opt_state = {k: (panel_mod.shard_panel(v, spec)
                         if k in _MOMENT_KEYS else v)
                     for k, v in opt_state.items()}
    state = {"panel": pan, "opt": opt_state,
             "step": jnp.zeros((), jnp.int32)}
    if _wire_needs_ef(spec):
        state["wire_err"] = _init_wire_err(pan, spec)
    mstat = _init_merge_stats(pan, spec)
    if mstat is not None:
        state["merge_stat"] = mstat
    return state, spec


def panel_state_shardings(state, spec):
    """NamedSharding pytree for a panel train state on a sharded spec —
    the ``in_shardings`` a caller hands to jit when lowering the segment
    driver against ShapeDtypeStructs (launch/dryrun.py, sharded tests)."""
    assert spec.sharded, "panel_state_shardings needs a shard_spec'ed spec"
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    repl = NamedSharding(spec.mesh, P())

    def group_sh(panel_like):
        return {k: (spec.sharding(k) or repl) for k in panel_like}

    opt = {k: (group_sh(v) if k in _MOMENT_KEYS
               else jax.tree.map(lambda _: repl, v))
           for k, v in state["opt"].items()}
    out = {"panel": group_sh(state["panel"]), "opt": opt, "step": repl}
    if "wire_err" in state:
        out["wire_err"] = group_sh(state["wire_err"])
    if "merge_stat" in state:
        out["merge_stat"] = {name: group_sh(v)
                             for name, v in state["merge_stat"].items()}
    return out


def panelize_state(state, spec):
    """Tree state (init_state) -> panel state (same numbers). A spec with
    an error-feedback wire policy gets a fresh zero residual panel; a
    statistical merge operator gets fresh statistics panels."""
    opt = {k: (panel_mod.to_panel(v, spec) if k in _MOMENT_KEYS else v)
           for k, v in state["opt"].items()}
    pan = panel_mod.to_panel(state["params"], spec)
    out = {"panel": pan, "opt": opt, "step": state["step"]}
    if _wire_needs_ef(spec):
        out["wire_err"] = _init_wire_err(pan, spec)
    mstat = _init_merge_stats(pan, spec)
    if mstat is not None:
        out["merge_stat"] = mstat
    return out


def unpanelize_state(state, spec):
    """Panel state -> tree state (same numbers; the wire_err residual and
    merge_stat panels are panel-engine carries and are dropped)."""
    opt = {k: (panel_mod.from_panel(v, spec) if k in _MOMENT_KEYS else v)
           for k, v in state["opt"].items()}
    return {"params": panel_mod.from_panel(state["panel"], spec), "opt": opt,
            "step": state["step"]}


def make_panel_segment(loss_fn: Callable, optimizer: Optimizer,
                       local_steps: int, spec, *, wire_dtype=None,
                       monitor: bool = True, telemetry: bool = False,
                       use_pallas: bool = False,
                       interpret: bool = True, donate: bool = True,
                       param_shardings=None, in_shardings=None):
    """Donated, scanned panel driver: one dispatch per SCHEDULE SEGMENT.

    segment(state, batches, Ws, rng, active=None, global_rounds=None,
            live=None)
    -> (state, metrics) with
      batches leaves (S, H, m, b, ...)  — H DISTINCT batches per round,
      Ws (S, m, m)                      — precomputed mixing matrices,
      active (S,) bool or None          — padding mask (see below),
      global_rounds (S,) bool or None   — which rounds are GLOBAL (see
                                          Merge operators below),
      live (S, m) int or None           — per-round per-agent liveness
                                          (see Liveness below),
      metrics dict of (S,) arrays      — one device_get per segment.

    **Metrics.** ``loss`` and ``grad_norm``/``grad_norm_max`` are the
    per-round mean/max over the H local steps (the old driver reported
    only the FINAL local step's grad norm, hiding any earlier spike);
    ``monitor=True`` adds the consensus ``Xi``. ``telemetry=True``
    extends the scalars to per-agent (S, m) METRIC PANELS — stacked by
    the same scan, still one device_get per segment:

      loss_agent      (S, m) f32 — per-agent mean loss over the round,
      grad_norm_agent (S, m) f32 — per-agent mean grad l2 norm,
      dist_to_mean    (S, m) f32 — per-agent distance to the (live)
                                   panel mean after the mix: the
                                   consensus decomposition
                                   (Xi == sqrt(live-mean(dist**2))),
      live            (S, m) i32 — the round's DEAD/LIVE/RESYNC trits,
      wire_bytes      (S, m) i32 — exact codec wire bytes each agent
                                   paid (PanelSpec.wire_total_bytes
                                   model; idle rows 0, a delta codec's
                                   global round and RESYNC pulls at
                                   full-precision cost).

    All telemetry values are pure reads of arrays the round already
    materialized — the trajectory is bit-identical with telemetry on or
    off (pinned by tests/test_telemetry.py).

    ``jax.lax.scan`` runs the S rounds (each an inner scan over the H
    local steps) entirely on device; ``donate_argnums=(0,)`` lets XLA
    update the panel state in place instead of copying the full
    agent-stacked state every round. The dense-W fused matmul covers every
    scheduler (W=I for idle rounds, fully-connected for merge rounds), so
    a segment needs no host-side dispatch on the round kind.

    **Wire codecs.** The spec's wire policy (panel_mod.with_wire /
    init_panel_state(wire=...)) compresses the gossip payload; the legacy
    ``wire_dtype`` cast survives as an explicit override (not both). A
    stochastic codec (int8/int4) draws its per-round key by folding a
    fixed tag into the round rng, so the local-step key schedule — and
    therefore any non-stochastic run — is bit-identical to the pre-codec
    engine. An error-feedback codec (int8_ef/int4_ef residuals, the topk
    mirror) carries ``state["wire_err"]`` (from init_panel_state) through
    the scan as one more donated panel; it is updated only on
    communicating rounds — idle W = I rounds bypass the codec entirely
    for EVERY codec family, so the residual/mirror passes through
    untouched and the round stays bit-exact.

    **Folded consensus.** With ``monitor=True`` the per-round consensus
    mean rides the mixing matmul itself (an extra 1^T/m row on W —
    panel_mod.mix_dense_mean), so the monitor costs one deviation pass
    instead of a second full mean reduce. Idle (W == I) rounds skip the
    matmul entirely — no payload travels, no codec touches the state —
    and keep the standalone consensus_distance reduce.

    ``active`` lets the host pad a PARTIAL tail segment up to the common
    segment length instead of retracing/recompiling the whole scan for a
    one-off smaller S: rounds with ``active[s] == False`` are full no-ops
    (state passes through untouched, metrics report 0) and their
    Ws/batches entries are ignored.

    **Liveness (elastic runs).** ``live`` extends the per-round ``active``
    mask to a per-round PER-AGENT (S, m) trit mask (core.faults:
    DEAD=0 / LIVE=1 / RESYNC=2 — the launcher stacks
    ``Schedule.last_live``). LIVE agents run the round normally. A DEAD
    agent's parameter, moment, EF-residual and merge-statistics rows
    pass through the round bit-exactly: it takes no local steps (its
    rows of the vmapped grad/optimizer update are discarded — the rng
    stream is consumed identically, so survivors' draws match the
    fault-free run), and the caller must hand in the matching DEGRADED W
    (Schedule does: topology.degrade_to_live / fully_connected_live), so
    its row is an identity row and the per-row idle rule keeps every
    codec off it. A RESYNC agent (its rejoin round) takes no local steps
    either; after the round's mix it receives a full-precision pull of
    the live agents' post-mix mean, its optimizer-moment rows are
    reset to zero and its EF-residual / merge-statistics rows are
    re-initialized from the synced parameters (its own state is stale by
    construction) — survivors are never perturbed. Metrics average over
    the live agents; ``consensus`` is the live-only Xi. With a
    non-uniform merge operator under faults, pass ``global_rounds``
    explicitly — a degraded global W no longer fingerprints as the 1/m
    matrix. ``live=None`` keeps the engine byte-identical to the
    pre-liveness path.

    **Merge operators.** The spec's merge operator
    (panel_mod.with_merger / init_panel_state(merger=...), repro.merging)
    is applied on GLOBAL rounds (the paper's single final merging,
    windowed/periodic AllReduce rounds). ``global_rounds`` marks them
    explicitly — the launcher reads the schedule's own knowledge
    (Schedule.last_kind). When None, the driver falls back to
    fingerprinting W against the fully-connected 1/m matrix; that is
    correct for every scheduler-emitted global round, but a gossip
    topology can COINCIDE with the 1/m average (m=2 matched pair,
    3-agent ring) and would then be routed through the operator — pass
    the explicit mask when running non-uniform operators on such
    topologies. 'uniform' keeps the byte-for-byte pre-subsystem path:
    global rounds stay inside the same fused matmul as every other
    round. A non-uniform operator dispatches those rounds through
    ``merging.merge_panel`` (payload still wire-codec encoded; one merged
    row broadcast back), and a STATISTICAL operator (var/fisher/swa)
    carries its per-agent stats panels as ``state["merge_stat"]`` —
    donated through the scan and updated every local step
    (``update_local``: fisher sees the grad panel) and/or once per round
    (``update_round``: var/swa see the param panel).

    On a sharded ``spec`` (shard_spec / init_panel_state(mesh=...)) every
    fused op keeps the panels in their mesh layout, so mixing lowers to
    per-fsdp-shard matmuls with agent-axis collectives that carry only the
    local column shard. ``param_shardings`` (NamedSharding pytree matching
    the model params, agent-stacked) re-pins the rebuilt per-leaf params
    for the grad compute; ``in_shardings`` is forwarded to jax.jit for
    lowering against ShapeDtypeStructs."""
    if wire_dtype is not None and spec.wire:
        raise ValueError("pass either wire_dtype= (legacy cast) or a spec "
                         "wire policy (with_wire), not both")
    needs_key = wire_dtype is None and _wire_needs_key(spec)
    needs_ef = wire_dtype is None and _wire_needs_ef(spec)
    merger = merging_mod.get_merger(spec.merger)
    # a delta (mirror) codec must route GLOBAL rounds through
    # merging.merge_panel even for the uniform operator: the one-shot
    # merge is its full-bandwidth round (panel.global_merge delta rule)
    # and cannot stay inside the sparse damped fused matmul
    has_delta = wire_dtype is None and _wire_has_delta(spec)
    plain_merge = merger.name == "uniform" and not has_delta
    needs_stats = bool(merger.stat_panels)
    if telemetry:
        # host constants of the exact codec cost model, baked into the
        # traced wire_bytes column
        t_bytes_wire, t_bytes_full = tmetrics.wire_bytes_model(
            spec, wire_dtype)

    def one(p, b, r):
        (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b, r)
        return g, l

    def segment(state, batches, Ws, rng, active=None, global_rounds=None,
                live=None):
        m = next(iter(state["panel"].values())).shape[0]
        S = Ws.shape[0]
        if needs_ef and "wire_err" not in state:
            raise ValueError(
                "spec's wire policy uses error feedback but the state has "
                "no 'wire_err' residual panel; build the state with "
                "init_panel_state(..., wire=...)")
        if needs_stats and "merge_stat" not in state:
            raise ValueError(
                f"spec's merge operator '{merger.name}' maintains "
                "statistics panels but the state has no 'merge_stat'; "
                "build the state with init_panel_state(..., merger=...)")

        def row_mask(mask, a):
            """(m,) bool mask broadcast against a leading-(m,) leaf."""
            return mask.reshape((m,) + (1,) * (a.ndim - 1))

        def agent_mets(out_pan, la, ga, lv, alive, W, full_bw):
            # the per-agent metric panel: pure reads of arrays the round
            # already materialized (la/ga are (H, m) stacks from the
            # local scan; out_pan is the post-mix panel)
            return {
                "loss_agent": jnp.mean(la, axis=0),
                "grad_norm_agent": jnp.mean(ga, axis=0),
                "dist_to_mean": tmetrics.agent_dist_to_mean(
                    out_pan, live=alive),
                "live": tmetrics.live_trits(lv, m),
                "wire_bytes": tmetrics.round_wire_bytes(
                    W, bytes_wire=t_bytes_wire, bytes_full=t_bytes_full,
                    full_bandwidth=full_bw, lv=lv),
            }

        def make_local_body(alive):
            # alive=None compiles the exact pre-liveness body; a (m,)
            # bool mask keeps non-live rows' params/moments/stats frozen
            # while consuming the SAME rng stream (survivor draws match
            # the fault-free twin)
            if alive is not None:
                lf = alive.astype(jnp.float32)
                n_live = jnp.maximum(jnp.sum(lf), 1.0)

                def freeze(new, old):
                    return jax.tree.map(
                        lambda a, b: jnp.where(row_mask(alive, a), a, b),
                        new, old)

            def local_body(carry, xs):
                pan, opt, mstat = carry
                batch, r = xs
                rngs = jax.random.split(r, m)
                params = panel_mod.from_panel(
                    pan, spec, leaf_shardings=param_shardings)
                with scope("dsgd.local_grad"):
                    grads, losses = jax.vmap(one)(params, batch, rngs)
                gpan = panel_mod.to_panel(grads, spec)
                if not plain_merge and merger.local_stat:
                    upd = merger.update_local(mstat, gpan)
                    mstat = upd if alive is None else freeze(upd, mstat)
                with scope("dsgd.local_update"):
                    new_pan, new_opt = jax.vmap(optimizer.update)(
                        gpan, opt, pan)
                if alive is None:
                    loss = jnp.mean(losses)
                    gn = panel_mod.panel_norm(gpan, axis_mean=True)
                else:
                    new_pan = freeze(new_pan, pan)
                    new_opt = freeze(new_opt, opt)
                    loss = jnp.sum(lf * losses) / n_live
                    gn = panel_mod.panel_norm(gpan, axis_mean=True,
                                              rows=lf / n_live)
                ys = (loss, gn)
                if telemetry:
                    ys = ys + (tmetrics.agent_loss(losses, alive),
                               tmetrics.agent_grad_norm(gpan, alive))
                return (new_pan, new_opt, mstat), ys

            return local_body

        def _live_comm(pan, opt, werr, mstat, W, wkey, lv, alive, glob,
                       losses, gns, la=None, ga=None):
            # elastic round: mix over the (already degraded) W, then
            # apply the liveness mask — DEAD rows pass through, RESYNC
            # rows pull the live agents' post-mix mean and restart their
            # carried state from it
            sync = lv == 2
            not_live = ~alive
            kw = dict(wire_dtype=wire_dtype, use_pallas=use_pallas,
                      interpret=interpret, spec=spec, key=wkey)
            idle = jnp.all(W == jnp.eye(m, dtype=W.dtype))
            is_full = (None if plain_merge else
                       (glob if glob is not None else
                        jnp.all(W == jnp.full((m, m), 1.0 / m, W.dtype))))

            def comm(args):
                # monitor's folded-mean matmul (an extra 1^T/m row on W)
                # mirrors the live=None path bit-for-bit: an all-live
                # mask must not perturb the numerics. The folded mean
                # itself is unused — the live-only Xi is computed below
                p, e = args
                if monitor:
                    mixed, _, ne = panel_mod.mix_dense_mean(p, W, err=e,
                                                            **kw)
                    return mixed, ne
                if needs_ef:
                    return panel_mod.mix_dense(p, W, err=e, **kw)
                return panel_mod.mix_dense(p, W, **kw), e

            def gossip_fn(args):
                return jax.lax.cond(idle, lambda a: a, comm, args)

            def merge_fn(args):
                p, e = args
                mixed, _, ne = merging_mod.merge_panel(
                    p, merger, stats=mstat, spec=spec,
                    wire_dtype=wire_dtype, key=wkey, err=e,
                    use_pallas=use_pallas, interpret=interpret,
                    live=alive)
                return mixed, ne

            werr_in = werr
            if plain_merge:
                mixed, werr_m = jax.lax.cond(idle, lambda a: a, comm,
                                             (pan, werr))
            else:
                mixed, werr_m = jax.lax.cond(is_full, merge_fn, gossip_fn,
                                             (pan, werr))

            lf = alive.astype(jnp.float32)
            lw = lf / jnp.maximum(jnp.sum(lf), 1.0)
            out_pan = {}
            for k, x in mixed.items():
                # dead AND resync agents did not participate in the mix:
                # their rows are identity rows of the degraded W
                # (defense in depth — the per-row idle rule already
                # restores them under a lossy codec)
                y = jnp.where(row_mask(not_live, x), pan[k], x)
                mu = jnp.tensordot(lw, y.astype(jnp.float32), axes=1)
                y = jnp.where(row_mask(sync, y), mu[None].astype(y.dtype),
                              y)
                out_pan[k] = panel_mod._constrain_group(y, spec, k)
            # resync rows restart their carried state from the synced
            # params: zero moments, codec-fresh residual, fresh stats
            opt = jax.tree.map(
                lambda a: jnp.where(row_mask(sync, a), jnp.zeros_like(a),
                                    a), opt)
            if werr_m is not None:
                new_werr = {}
                for k, e in werr_m.items():
                    e = jnp.where(row_mask(not_live, e), werr_in[k], e)
                    fresh = wire_mod.get_codec(spec.wire_of(k)).init_err(
                        out_pan[k]).astype(e.dtype)
                    new_werr[k] = panel_mod._constrain_group(
                        jnp.where(row_mask(sync, e), fresh, e), spec, k)
                werr_m = new_werr
            if mstat is not None:
                fresh = merger.init_stats(out_pan)
                mstat = {
                    name: {k: panel_mod._constrain_group(
                        jnp.where(row_mask(sync, v), fresh[name][k], v),
                        spec, k) for k, v in grp.items()}
                    for name, grp in mstat.items()}
            mets = {"loss": jnp.mean(losses), "grad_norm": jnp.mean(gns),
                    "grad_norm_max": jnp.max(gns)}
            if monitor:
                mets["consensus"] = panel_mod.consensus_distance(
                    out_pan, use_pallas=use_pallas, interpret=interpret,
                    spec=spec, live=alive)
            if telemetry:
                mets.update(agent_mets(
                    out_pan, la, ga, lv, alive, W,
                    is_full if has_delta else None))
            return (out_pan, opt, werr_m, mstat), mets

        def run_round(carry, W, batch_r, r, glob, lv):
            pan, opt, werr, mstat = carry
            alive = None if lv is None else lv == 1
            rs = jax.random.split(r, local_steps)
            (pan, opt, mstat), step_ys = jax.lax.scan(
                make_local_body(alive), (pan, opt, mstat), (batch_r, rs))
            if telemetry:
                losses, gns, la, ga = step_ys
            else:
                (losses, gns), la, ga = step_ys, None, None
            if not plain_merge and merger.round_stat:
                upd = merger.update_round(mstat, pan)
                if alive is not None:
                    upd = jax.tree.map(
                        lambda a, b: jnp.where(row_mask(alive, a), a, b),
                        upd, mstat)
                mstat = upd
            wkey = _wire_key(r, needs_key)
            if lv is not None:
                return _live_comm(pan, opt, werr, mstat, W, wkey, lv,
                                  alive, glob, losses, gns, la, ga)
            # W == I rounds communicate nothing: skip the matmul AND the
            # codec (no payload travels, so nothing may be quantized and
            # the error-feedback residual must pass through untouched)
            idle = jnp.all(W == jnp.eye(m, dtype=W.dtype))
            # non-uniform operators take over the GLOBAL rounds: the
            # explicit per-round mask when given, else the W fingerprint
            # (the 1/m matrix the schedulers emit for global merging —
            # see the docstring caveat); after the broadcast every row
            # is identical, so Xi == 0
            is_full = (None if plain_merge else
                       (glob if glob is not None else
                        jnp.all(W == jnp.full((m, m), 1.0 / m, W.dtype))))
            kw = dict(wire_dtype=wire_dtype, use_pallas=use_pallas,
                      interpret=interpret, spec=spec, key=wkey)

            if monitor:
                def comm(args):
                    p, e = args
                    mixed, mean, ne = panel_mod.mix_dense_mean(
                        p, W, err=e, **kw)
                    return mixed, ne, panel_mod.consensus_from_mean(
                        mixed, mean)

                def idle_fn(args):
                    p, e = args
                    return p, e, panel_mod.consensus_distance(
                        p, use_pallas=use_pallas, interpret=interpret,
                        spec=spec)

                def gossip_fn(args):
                    return jax.lax.cond(idle, idle_fn, comm, args)

                def merge_fn(args):
                    p, e = args
                    mixed, _, ne = merging_mod.merge_panel(
                        p, merger, stats=mstat, spec=spec,
                        wire_dtype=wire_dtype, key=wkey, err=e,
                        use_pallas=use_pallas, interpret=interpret)
                    return mixed, ne, jnp.zeros((), jnp.float32)

                if plain_merge:
                    mixed, werr, xi = jax.lax.cond(
                        idle, idle_fn, comm, (pan, werr))
                else:
                    mixed, werr, xi = jax.lax.cond(
                        is_full, merge_fn, gossip_fn, (pan, werr))
                mets = {"loss": jnp.mean(losses),
                        "grad_norm": jnp.mean(gns),
                        "grad_norm_max": jnp.max(gns), "consensus": xi}
            else:
                def comm(args):
                    p, e = args
                    if needs_ef:
                        return panel_mod.mix_dense(p, W, err=e, **kw)
                    return panel_mod.mix_dense(p, W, **kw), e

                def gossip_fn(args):
                    return jax.lax.cond(idle, lambda a: a, comm, args)

                def merge_fn(args):
                    p, e = args
                    mixed, _, ne = merging_mod.merge_panel(
                        p, merger, stats=mstat, spec=spec,
                        wire_dtype=wire_dtype, key=wkey, err=e,
                        use_pallas=use_pallas, interpret=interpret)
                    return mixed, ne

                if plain_merge:
                    mixed, werr = jax.lax.cond(
                        idle, lambda a: a, comm, (pan, werr))
                else:
                    mixed, werr = jax.lax.cond(
                        is_full, merge_fn, gossip_fn, (pan, werr))
                mets = {"loss": jnp.mean(losses),
                        "grad_norm": jnp.mean(gns),
                        "grad_norm_max": jnp.max(gns)}
            if telemetry:
                mets.update(agent_mets(
                    mixed, la, ga, lv, alive, W,
                    is_full if has_delta else None))
            return (mixed, opt, werr, mstat), mets

        def round_body(carry, xs):
            W, batch_r, r = xs[:3]
            rest = list(xs[3:])
            glob = rest.pop(0) if global_rounds is not None else None
            lv = rest.pop(0) if live is not None else None
            act = rest.pop(0) if active is not None else None
            if act is None:
                return run_round(carry, W, batch_r, r, glob, lv)

            def inactive(c):
                # zeros matching run_round's metric schema exactly
                mets_sds = jax.eval_shape(
                    lambda cc: run_round(cc, W, batch_r, r, glob, lv)[1],
                    c)
                return c, jax.tree.map(
                    lambda s: jnp.zeros(s.shape, s.dtype), mets_sds)

            return jax.lax.cond(
                act, lambda c: run_round(c, W, batch_r, r, glob, lv),
                inactive, carry)

        rngs = jax.random.split(rng, S)
        xs = (Ws, batches, rngs)
        if global_rounds is not None:
            xs = xs + (global_rounds,)
        if live is not None:
            xs = xs + (live,)
        if active is not None:
            xs = xs + (active,)
        werr0 = state.get("wire_err") if needs_ef else None
        mstat0 = state.get("merge_stat") if needs_stats else None
        (pan, opt, werr, mstat), metrics = jax.lax.scan(
            round_body, (state["panel"], state["opt"], werr0, mstat0), xs)
        steps = (S if active is None
                 else jnp.sum(active.astype(jnp.int32))) * local_steps
        out = {"panel": pan, "opt": opt, "step": state["step"] + steps}
        if werr is not None:
            out["wire_err"] = werr
        if mstat is not None:
            out["merge_stat"] = mstat
        return out, metrics

    jit_kw = {} if in_shardings is None else {"in_shardings": in_shardings}
    return jax.jit(segment, donate_argnums=(0,) if donate else (), **jit_kw)


def make_parallel_step(loss_fn: Callable, optimizer: Optimizer):
    """Parallel SGD / FedAvg(H=1) baseline: one shared model; gradients are
    averaged over the m per-agent batches every step (the paper's reference
    rate O(sigma^2/(m eps^2) + 1/eps))."""

    def step(state, batch, rng):
        m = jax.tree.leaves(batch)[0].shape[0]
        rngs = jax.random.split(rng, m)

        def one(b, r):
            (l, aux), g = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"], b, r)
            return g, l

        grads, losses = jax.vmap(one)(batch, rngs)
        gbar = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        new_p, new_opt = optimizer.update(gbar, state["opt"], state["params"])
        return {"params": new_p, "opt": new_opt,
                "step": state["step"] + 1}, {"loss": jnp.mean(losses)}

    return step


def init_parallel_state(init_params: Callable, optimizer: Optimizer, rng):
    p = init_params(rng)
    return {"params": p, "opt": optimizer.init(p),
            "step": jnp.zeros((), jnp.int32)}
