"""Decentralized training engine (Algorithm 1 of the paper).

Two state layouts:

* **Tree state** (:func:`init_state` + :func:`make_dsgd_step` /
  :func:`make_dsgd_round`) — every leaf of params/opt_state carries a
  leading (m,) agent axis (sharded over ('pod','agent') on the production
  mesh). Mixing is per-leaf (``gossip.*_tree``): the right lowering when
  leaves carry heterogeneous shardings (launch/dryrun.py), and the
  reference baseline for the panel engine.

* **Panel state** (:func:`init_panel_state` + :func:`make_panel_segment`)
  — params and optimizer moments live as persistent per-dtype (m, D)
  panels (core/panel.py). The segment driver scans a whole SCHEDULE
  SEGMENT of rounds on device (mixing matrices precomputed and stacked),
  donates the state buffers (in-place update, no per-round host
  dispatch), mixes with ONE fused matmul per dtype group, and returns
  per-round metrics as stacked arrays — a single device_get per segment.
  This is the hot path used by launch/train.py and benchmarked in
  benchmarks/panel_bench.py.

One round = per-agent local step(s) (vmapped grad + optimizer; zero
cross-agent traffic) followed by gossip mixing with the scheduler's W^(t).

``loss_fn(params, batch, rng) -> (loss, aux)`` is any per-agent objective
(an LM from repro.models, or the benchmark classifiers).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro import merging as merging_mod
from repro import residency as residency_mod
from repro import wire as wire_mod
from repro.kernels import opt_fused as opt_fused_mod
from repro.core import gossip
from repro.core import panel as panel_mod
from repro.core.consensus import consensus_distance_tree
from repro.optim.optim import Optimizer
from repro.telemetry import metrics as tmetrics
from repro.telemetry.trace import scope


def _init_agent_params(init_params: Callable, m: int, rng,
                       same_init: bool):
    """``same_init=True`` matches the theory (theta_k^0 = theta^0); False
    matches the paper's main experiments (independent inits — the harder
    cross-initialization merge)."""
    if same_init:
        p = init_params(rng)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), p)
    return jax.vmap(init_params)(jax.random.split(rng, m))


def _place(tree, shardings):
    """device_put (concrete) / sharding-constrain (traced) a pytree onto a
    matching tree of NamedSharding (panel_mod.place per leaf)."""
    return jax.tree.map(panel_mod.place, tree, shardings)


def init_state(init_params: Callable, optimizer: Optimizer, m: int, rng,
               same_init: bool = False, shardings=None):
    """Agent-stacked train state (see _init_agent_params for same_init).

    ``shardings`` (a pytree of NamedSharding matching the params tree,
    e.g. models.sharding.resolve(...) wrapped on a training mesh) places
    the params AND the parameter-shaped optimizer moments; step counters
    stay replicated."""
    params = _init_agent_params(init_params, m, rng, same_init)
    if shardings is not None:
        params = _place(params, shardings)
    opt_state = jax.vmap(optimizer.init)(params)
    if shardings is not None:
        opt_state = {k: (_place(v, shardings) if k in _MOMENT_KEYS else v)
                     for k, v in opt_state.items()}
    return {"params": params, "opt": opt_state,
            "step": jnp.zeros((), jnp.int32)}


# fold_in tag deriving the wire-codec key from a round's rng WITHOUT
# disturbing the local-step key schedule (so f32/bf16 runs stay bit-exact
# with the pre-codec engine, and idle rounds under any codec match them)
_WIRE_KEY_TAG = 0x77697265  # "wire"


def _wire_key(rng, needed: bool):
    return jax.random.fold_in(rng, _WIRE_KEY_TAG) if needed else None


def _tree_wire_check(wire) -> bool:
    """Validate a codec name for the tree-state drivers at build time
    (error feedback needs the panel engine's residual state); returns
    whether the codec draws a stochastic-rounding key."""
    if wire is None:
        return False
    codec = wire_mod.get_codec(wire)
    if codec.error_feedback:
        raise ValueError(
            f"codec '{codec.name}' needs an error-feedback residual; the "
            "tree-state drivers carry none — use the panel engine "
            "(make_panel_segment + init_panel_state(wire=...)) or 'int8'")
    return codec.needs_key


def _mix(params, W, impl: str, wire_dtype, wire=None, key=None):
    # Per-leaf mixing: tree-state steps are the sharding-aware reference
    # path (see module docstring); the fused panel path is make_panel_segment.
    # For impl == "pairwise" the step's W argument IS the (m,) int32
    # partner array (see topology.partner_array), not an (m, m) matrix.
    if impl == "dense":
        if wire_dtype is None and wire is None:
            return gossip.mix_dense_tree(params, W)
        # W == I rounds communicate nothing, so no codec may touch the
        # state (mirrors the panel engine's idle guard; pairwise idles
        # per-row inside mix_pairwise_tree)
        m = jax.tree.leaves(params)[0].shape[0]
        idle = jnp.all(W == jnp.eye(m, dtype=W.dtype))
        return jax.lax.cond(
            idle, lambda p: p,
            lambda p: gossip.mix_dense_tree(p, W, wire_dtype, wire, key),
            params)
    if impl == "pairwise":
        return gossip.mix_pairwise_tree(params, W, wire_dtype=wire_dtype,
                                        wire=wire, key=key)
    if impl == "merge":
        return gossip.global_merge_tree(params, wire_dtype, wire, key)
    if impl == "none":
        return params
    raise ValueError(impl)


def make_dsgd_step(loss_fn: Callable, optimizer: Optimizer, *,
                   gossip_impl: str = "dense",
                   wire_dtype=None, wire=None, monitor: bool = True):
    """One communication round with ONE local step per agent.

    step(state, batch, W, rng) -> (state, metrics); batch leaves (m, b, ...).
    With gossip_impl="pairwise", pass the (m,) int32 partner array as W.
    ``wire`` names a codec from repro.wire for the gossip payload (the
    stochastic int8 codecs draw their key from the step rng via fold_in).
    Error-feedback codecs are panel-engine-only (the tree state carries
    no residual) and are refused here.
    """
    needs_key = _tree_wire_check(wire)

    def step(state, batch, W, rng):
        m = jax.tree.leaves(state["params"])[0].shape[0]
        rngs = jax.random.split(rng, m)

        def one(p, b, r):
            (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b, r)
            return g, l

        grads, losses = jax.vmap(one)(state["params"], batch, rngs)
        new_p, new_opt = jax.vmap(optimizer.update)(
            grads, state["opt"], state["params"])
        mixed = _mix(new_p, W, gossip_impl, wire_dtype, wire,
                     _wire_key(rng, needs_key))
        metrics = {"loss": jnp.mean(losses)}
        if monitor:
            gbar = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
            metrics["grad_norm"] = jnp.sqrt(sum(
                jnp.sum(jnp.square(x)) for x in jax.tree.leaves(gbar)))
            metrics["consensus"] = consensus_distance_tree(mixed)
        return {"params": mixed, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return step


def make_dsgd_round(loss_fn: Callable, optimizer: Optimizer, local_steps: int,
                    *, gossip_impl: str = "dense", wire_dtype=None,
                    wire=None, monitor: bool = True):
    """One communication round with H local steps (paper: H=100).

    step(state, batches, W, rng): batches leaves (H, m, b, ...) — scanned.
    ``wire`` as in :func:`make_dsgd_step` (error-feedback codecs refused).
    """
    needs_key = _tree_wire_check(wire)

    def round_fn(state, batches, W, rng):
        m = jax.tree.leaves(state["params"])[0].shape[0]

        def body(carry, xs):
            params, opt = carry
            batch, r = xs
            rngs = jax.random.split(r, m)

            def one(p, b, rr):
                (l, aux), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(p, b, rr)
                return g, l

            grads, losses = jax.vmap(one)(params, batch, rngs)
            new_p, new_opt = jax.vmap(optimizer.update)(grads, opt, params)
            gbar = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                              for x in jax.tree.leaves(gbar)))
            return (new_p, new_opt), (jnp.mean(losses), gn)

        rngs = jax.random.split(rng, local_steps)
        (p, o), (losses, gns) = jax.lax.scan(
            body, (state["params"], state["opt"]), (batches, rngs))
        mixed = _mix(p, W, gossip_impl, wire_dtype, wire,
                     _wire_key(rng, needs_key))
        # mean AND max over the round's H local steps: reporting gns[-1]
        # alone silently dropped a gradient spike at any earlier local
        # step (tests/test_telemetry.py pins the regression)
        metrics = {"loss": jnp.mean(losses), "grad_norm": jnp.mean(gns),
                   "grad_norm_max": jnp.max(gns)}
        if monitor:
            metrics["consensus"] = consensus_distance_tree(mixed)
        return {"params": mixed, "opt": o,
                "step": state["step"] + local_steps}, metrics

    return round_fn


# ---------------------------------------------------------------------------
# Flat-panel engine: persistent (m, D) state, donated + scanned rounds.
# ---------------------------------------------------------------------------

# Optimizer-state entries that are parameter-shaped moment trees (AdamW m/v,
# SGD momentum mu); everything else (step_count) passes through unchanged.
_MOMENT_KEYS = ("m", "v", "mu")

# fold_in tag deriving the storage-codec stochastic-rounding keys from a
# round/step rng WITHOUT disturbing the local-step or wire key schedules
# (a non-stochastic residency policy never folds, so f32/bf16 storage
# runs keep the pre-residency key schedule bit-exactly); each state kind
# then folds its own index so moments/stats/wire_err draw independent
# streams from the same rng
_RES_KEY_TAG = 0x68626d00  # "hbm\0"
_RES_KIND_IDX = {"moments": 0, "stats": 1, "wire_err": 2}


def _res_key(rng, kind: str, needed: bool):
    if not needed:
        return None
    return jax.random.fold_in(jax.random.fold_in(rng, _RES_KEY_TAG),
                              _RES_KIND_IDX[kind])


def _res_plan(spec):
    """{state kind: {dtype group: Storage}} — the static application
    table of the spec's residency policy. Storage codecs act on f32
    state only: moment panels mirror each group's native dtype, so only
    the 'float32' group's moments are stored; merge stats and EF
    residuals are f32 for EVERY group (Merger.init_stats /
    Codec.init_err build them f32), so those kinds store across all
    groups. Resolved once at build time — the plan is trace-static."""
    plan = {}
    for kind, name in spec.residency:
        st = residency_mod.get_storage(name)
        if kind == "moments":
            groups = [g for g, _ in spec.groups if g == "float32"]
        else:
            groups = [g for g, _ in spec.groups]
        if groups:
            plan[kind] = {g: st for g in groups}
    return plan


def _res_constrain(v, spec, k: str):
    """Sharding constraint for one group's state leaf: a stored dict
    pins q to the group layout and the scale sidecar to rows-only; a
    plain array takes the group constraint (panel_mod._constrain_group,
    a no-op on unsharded specs)."""
    if isinstance(v, dict):
        return {"q": panel_mod._constrain_group(v["q"], spec, k),
                "scale": panel_mod.place(v["scale"],
                                         spec.sidecar_sharding(k))}
    return panel_mod._constrain_group(v, spec, k)


def _res_read(stored, sts, *, use_pallas: bool = False,
              interpret: bool = True):
    """Decode a stored state-panel group dict to its f32 compute view
    (groups without a storage entry pass through)."""
    return {k: (sts[k].read(v, use_pallas=use_pallas, interpret=interpret)
                if k in sts else v)
            for k, v in stored.items()}


def _res_write(panel, sts, key, spec=None, *, use_pallas: bool = False,
               interpret: bool = True):
    """Encode an f32 state-panel group dict into storage (per-group SR
    keys via residency.storage_keys — sorted-group fold order, the
    _wire_keys discipline); ``spec`` adds the sharding constraints."""
    keys = residency_mod.storage_keys(sts, key)
    out = {}
    for k, v in panel.items():
        if k in sts:
            v = sts[k].write(v, key=keys[k], use_pallas=use_pallas,
                             interpret=interpret)
        out[k] = _res_constrain(v, spec, k) if spec is not None else v
    return out


def _res_init(panel, sts):
    """Deterministic encode of a fresh state-panel group dict (state
    build / RESYNC re-init — reproducible without a key schedule)."""
    return {k: (sts[k].init(v) if k in sts else v)
            for k, v in panel.items()}


def _opt_read(opt, sts, mom_keys, *, use_pallas: bool = False,
              interpret: bool = True):
    """Optimizer state -> its f32 compute view: moment entries decode
    through the storage, everything else (step_count) passes through."""
    return {k: (_res_read(v, sts, use_pallas=use_pallas,
                          interpret=interpret)
                if k in mom_keys else v)
            for k, v in opt.items()}


def _opt_write(opt, sts, mom_keys, key, spec, *, use_pallas: bool = False,
               interpret: bool = True):
    """Encode the updated f32 moments back into storage, one folded key
    per moment entry (sorted order) so m/v draw independent SR bits."""
    present = sorted(k for k in opt if k in mom_keys)
    out = dict(opt)
    for i, k in enumerate(present):
        mk = None if key is None else jax.random.fold_in(key, i)
        out[k] = _res_write(opt[k], sts, mk, spec, use_pallas=use_pallas,
                            interpret=interpret)
    return out


def _fused_opt_update(gpan, opt, pan, optimizer, sts, spec, key, *,
                      use_pallas: bool = False, interpret: bool = True):
    """Fused moment update: the stored int8 groups run the single-sweep
    Pallas kernel (kernels/opt_fused.py) — decode, the optimizer's
    shared elementwise core, and the SR re-encode all in VMEM, HBM
    touching only int8 q + scales. No f32 moment view is ever
    materialized, which is both the bandwidth win and the peak-memory
    fix (resident_bytes_model's ``transient_bytes`` term is zero on
    this path).

    Groups without a storage entry (non-f32 dtype groups) take the
    legacy vmapped ``optimizer.update`` on their rest-subtree — same
    expression, same step_count bookkeeping, bit-identical to the
    unfused engine. SR keys replicate ``_opt_write``'s folds exactly
    (fold_in(key, i) over sorted present moment entries, then
    ``storage_keys``'s sorted-group fold), so the fused ref path is the
    unfused decode->update->encode composition bit-for-bit.

    lr/bc1/bc2 come from ``optimizer.hyper`` on the per-agent (m,)
    step_count — agent rows diverge after a RESYNC re-init, so the bias
    corrections ride the kernel as (m, 1) columns."""
    from repro.wire.codec import _uniform
    count = opt["step_count"] + 1
    lr, bc1, bc2 = optimizer.hyper(count)
    present = sorted(k for k in opt if k in optimizer.moment_keys)
    gkeys = {k: residency_mod.storage_keys(
        sts, None if key is None else jax.random.fold_in(key, i))
        for i, k in enumerate(present)}
    rest = [k for k in pan if k not in sts]
    new_pan, new_m, new_v = {}, {}, {}
    if rest:
        sub = lambda d: {k: d[k] for k in rest}
        opt_r = {k: (sub(v) if k in optimizer.moment_keys else v)
                 for k, v in opt.items()}
        pan_r, opt_r = jax.vmap(optimizer.update)(
            sub(gpan), opt_r, sub(pan))
        new_pan.update(pan_r)
        new_m.update(opt_r["m"])
        new_v.update(opt_r["v"])
    for k in pan:
        if k not in sts:
            continue
        st = sts[k]
        um = _uniform(gkeys["m"][k], gpan[k].shape)
        uv = _uniform(gkeys["v"][k], gpan[k].shape)
        p2, qm2, sm2, qv2, sv2 = opt_fused_mod.adamw_fused_int8(
            gpan[k], pan[k],
            opt["m"][k]["q"], opt["m"][k]["scale"],
            opt["v"][k]["q"], opt["v"][k]["scale"],
            um, uv, lr, bc1, bc2, group=st.group, core=optimizer.core,
            transform_fwd=st.transform_fwd, transform_inv=st.transform_inv,
            use_pallas=use_pallas, interpret=interpret)
        new_pan[k] = p2
        new_m[k] = _res_constrain({"q": qm2, "scale": sm2}, spec, k)
        new_v[k] = _res_constrain({"q": qv2, "scale": sv2}, spec, k)
    new_pan = {k: new_pan[k] for k in pan}
    new_opt = dict(opt)
    new_opt["m"] = {k: new_m[k] for k in opt["m"]}
    new_opt["v"] = {k: new_v[k] for k in opt["v"]}
    new_opt["step_count"] = count
    return new_pan, new_opt


def _wire_needs_ef(spec) -> bool:
    return any(wire_mod.get_codec(name).error_feedback
               for _, name in spec.wire)


def _init_wire_err(pan, spec, sts=None):
    """Fresh spec-sharded error-feedback panels: each dtype group's codec
    seeds its own state (zeros for the quantization residuals, a copy of
    the panel for the topk mirror — Codec.init_err). ``sts`` (the
    residency plan's wire_err storages) encodes them deterministically."""
    werr = {k: wire_mod.get_codec(spec.wire_of(k)).init_err(v)
            for k, v in pan.items()}
    if sts:
        werr = _res_init(werr, sts)
    return {k: _res_constrain(v, spec, k) for k, v in werr.items()}


def _wire_needs_key(spec) -> bool:
    return any(wire_mod.get_codec(name).needs_key for _, name in spec.wire)


def _wire_has_delta(spec) -> bool:
    return any(getattr(wire_mod.get_codec(name), "delta_mix", False)
               for _, name in spec.wire)


def _init_merge_stats(pan, spec, sts=None):
    """Fresh, spec-sharded statistics panels for the spec's merge operator
    (None when the operator keeps no statistics). ``sts`` (the residency
    plan's stats storages) encodes them deterministically."""
    mg = merging_mod.get_merger(spec.merger)
    if not mg.stat_panels:
        return None
    out = {}
    for name, stat in mg.init_stats(pan).items():
        if sts:
            stat = _res_init(stat, sts)
        out[name] = {k: _res_constrain(v, spec, k)
                     for k, v in stat.items()}
    return out


def init_panel_state(init_params: Callable, optimizer: Optimizer, m: int,
                     rng, same_init: bool = False, mesh=None, wire=None,
                     merger=None, residency=None):
    """Panel train state: params AND optimizer moments as per-dtype (m, D)
    panels. Returns (state, spec); the static spec is what turns panels
    back into model pytrees. The optimizer transforms are elementwise, so
    they run directly on the panel leaves — no per-leaf dispatch.

    ``mesh`` shards the panels: rows over ('pod','agent'), D over 'fsdp'
    (panel_mod.shard_spec); the optimizer-moment panels mirror the
    parameter panel layout exactly.

    ``wire`` attaches a wire-codec policy to the spec (panel_mod.with_wire:
    a codec name for every dtype group, or a per-group dict). An
    error-feedback codec adds ``state["wire_err"]`` — one f32 panel per
    dtype group, laid out exactly like the parameter panel, seeded by the
    group's codec (Codec.init_err) and donated through the segment scan.
    For int8_ef/int4_ef that panel is the zero-initialised quantization
    residual; for the topk codec it is the MIRROR x̂ — the receive-side
    reconstruction every peer accumulates from past sparse innovations,
    seeded with a copy of the initial panel (one full-precision sync).

    ``merger`` names the merge operator global rounds apply
    (panel_mod.with_merger, repro.merging). A statistical operator
    (var/fisher/swa) adds ``state["merge_stat"]`` — its per-agent f32
    statistics panels, parameter-panel layout, donated through the scan
    and updated by the segment driver.

    ``residency`` attaches a storage-codec policy to the spec
    (panel_mod.with_residency, repro.residency — a {kind: storage} dict
    or a 'moments=int8,stats=bf16' policy string). The named state
    panels are allocated DIRECTLY in their stored representation
    (deterministic encode — int8/int8g panels become {'q', 'scale'}
    dicts with f32 scale sidecars); no resident f32 copy ever
    materializes, here or inside the segment."""
    params = _init_agent_params(init_params, m, rng, same_init)
    spec = panel_mod.make_spec(params)
    if mesh is not None:
        spec = panel_mod.shard_spec(spec, mesh)
    if wire is not None:
        spec = panel_mod.with_wire(spec, wire)
    if merger is not None:
        spec = panel_mod.with_merger(spec, merger)
    if residency is not None:
        spec = panel_mod.with_residency(spec, residency)
    plan = _res_plan(spec)
    pan = panel_mod.to_panel(params, spec)
    opt_state = jax.vmap(optimizer.init)(pan)
    mom_sts = plan.get("moments")
    if mom_sts:
        opt_state = {k: (_res_init(v, mom_sts)
                         if k in optimizer.moment_keys else v)
                     for k, v in opt_state.items()}
    if spec.sharded:
        opt_state = {k: ({g: _res_constrain(x, spec, g)
                          for g, x in v.items()}
                         if k in _MOMENT_KEYS else v)
                     for k, v in opt_state.items()}
    state = {"panel": pan, "opt": opt_state,
             "step": jnp.zeros((), jnp.int32)}
    if _wire_needs_ef(spec):
        state["wire_err"] = _init_wire_err(pan, spec, plan.get("wire_err"))
    mstat = _init_merge_stats(pan, spec, plan.get("stats"))
    if mstat is not None:
        state["merge_stat"] = mstat
    return state, spec


def panel_state_shardings(state, spec):
    """NamedSharding pytree for a panel train state on a sharded spec —
    the ``in_shardings`` a caller hands to jit when lowering the segment
    driver against ShapeDtypeStructs (launch/dryrun.py, sharded tests)."""
    assert spec.sharded, "panel_state_shardings needs a shard_spec'ed spec"
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    repl = NamedSharding(spec.mesh, P())

    def group_sh(panel_like):
        out = {}
        for k, v in panel_like.items():
            gs = spec.sharding(k) or repl
            if isinstance(v, dict):
                # stored rep: q follows the group layout, the scale
                # sidecar shards rows-only (PanelSpec.sidecar_sharding)
                out[k] = {"q": gs,
                          "scale": spec.sidecar_sharding(k) or repl}
            else:
                out[k] = gs
        return out

    opt = {k: (group_sh(v) if k in _MOMENT_KEYS
               else jax.tree.map(lambda _: repl, v))
           for k, v in state["opt"].items()}
    out = {"panel": group_sh(state["panel"]), "opt": opt, "step": repl}
    if "wire_err" in state:
        out["wire_err"] = group_sh(state["wire_err"])
    if "merge_stat" in state:
        out["merge_stat"] = {name: group_sh(v)
                             for name, v in state["merge_stat"].items()}
    return out


def panelize_state(state, spec):
    """Tree state (init_state) -> panel state (same numbers, encoded per
    the spec's residency policy). A spec with an error-feedback wire
    policy gets a fresh zero residual panel; a statistical merge
    operator gets fresh statistics panels."""
    plan = _res_plan(spec)
    mom_sts = plan.get("moments")

    def mom(v):
        p = panel_mod.to_panel(v, spec)
        if mom_sts:
            p = {k: _res_constrain(x, spec, k)
                 for k, x in _res_init(p, mom_sts).items()}
        return p

    opt = {k: (mom(v) if k in _MOMENT_KEYS else v)
           for k, v in state["opt"].items()}
    pan = panel_mod.to_panel(state["params"], spec)
    out = {"panel": pan, "opt": opt, "step": state["step"]}
    if _wire_needs_ef(spec):
        out["wire_err"] = _init_wire_err(pan, spec, plan.get("wire_err"))
    mstat = _init_merge_stats(pan, spec, plan.get("stats"))
    if mstat is not None:
        out["merge_stat"] = mstat
    return out


def unpanelize_state(state, spec):
    """Panel state -> tree state (same numbers up to storage precision —
    stored moments decode through their codec; the wire_err residual and
    merge_stat panels are panel-engine carries and are dropped)."""
    mom_sts = _res_plan(spec).get("moments")

    def mom(v):
        if mom_sts:
            v = _res_read(v, mom_sts)
        return panel_mod.from_panel(v, spec)

    opt = {k: (mom(v) if k in _MOMENT_KEYS else v)
           for k, v in state["opt"].items()}
    return {"params": panel_mod.from_panel(state["panel"], spec), "opt": opt,
            "step": state["step"]}


def make_panel_segment(loss_fn: Callable, optimizer: Optimizer,
                       local_steps: int, spec, *, wire_dtype=None,
                       monitor: bool = True, telemetry: bool = False,
                       use_pallas: bool = False,
                       interpret: bool = True, donate: bool = True,
                       fused=None,
                       param_shardings=None, in_shardings=None):
    """Donated, scanned panel driver: one dispatch per SCHEDULE SEGMENT.

    segment(state, batches, Ws, rng, active=None, global_rounds=None,
            live=None)
    -> (state, metrics) with
      batches leaves (S, H, m, b, ...)  — H DISTINCT batches per round,
      Ws (S, m, m)                      — precomputed mixing matrices,
      active (S,) bool or None          — padding mask (see below),
      global_rounds (S,) bool or None   — which rounds are GLOBAL (see
                                          Merge operators below),
      live (S, m) int or None           — per-round per-agent liveness
                                          (see Liveness below),
      metrics dict of (S,) arrays      — one device_get per segment.

    **Metrics.** ``loss`` and ``grad_norm``/``grad_norm_max`` are the
    per-round mean/max over the H local steps (the old driver reported
    only the FINAL local step's grad norm, hiding any earlier spike);
    ``monitor=True`` adds the consensus ``Xi``. ``telemetry=True``
    extends the scalars to per-agent (S, m) METRIC PANELS — stacked by
    the same scan, still one device_get per segment:

      loss_agent      (S, m) f32 — per-agent mean loss over the round,
      grad_norm_agent (S, m) f32 — per-agent mean grad l2 norm,
      dist_to_mean    (S, m) f32 — per-agent distance to the (live)
                                   panel mean after the mix: the
                                   consensus decomposition
                                   (Xi == sqrt(live-mean(dist**2))),
      live            (S, m) i32 — the round's DEAD/LIVE/RESYNC trits,
      wire_bytes      (S, m) i32 — exact codec wire bytes each agent
                                   paid (PanelSpec.wire_total_bytes
                                   model; idle rows 0, a delta codec's
                                   global round and RESYNC pulls at
                                   full-precision cost).

    All telemetry values are pure reads of arrays the round already
    materialized — the trajectory is bit-identical with telemetry on or
    off (pinned by tests/test_telemetry.py).

    ``jax.lax.scan`` runs the S rounds (each an inner scan over the H
    local steps) entirely on device; ``donate_argnums=(0,)`` lets XLA
    update the panel state in place instead of copying the full
    agent-stacked state every round. The dense-W fused matmul covers every
    scheduler (W=I for idle rounds, fully-connected for merge rounds), so
    a segment needs no host-side dispatch on the round kind.

    **Wire codecs.** The spec's wire policy (panel_mod.with_wire /
    init_panel_state(wire=...)) compresses the gossip payload; the legacy
    ``wire_dtype`` cast survives as an explicit override (not both). A
    stochastic codec (int8/int4) draws its per-round key by folding a
    fixed tag into the round rng, so the local-step key schedule — and
    therefore any non-stochastic run — is bit-identical to the pre-codec
    engine. An error-feedback codec (int8_ef/int4_ef residuals, the topk
    mirror) carries ``state["wire_err"]`` (from init_panel_state) through
    the scan as one more donated panel; it is updated only on
    communicating rounds — idle W = I rounds bypass the codec entirely
    for EVERY codec family, so the residual/mirror passes through
    untouched and the round stays bit-exact.

    **Folded consensus.** With ``monitor=True`` the per-round consensus
    mean rides the mixing matmul itself (an extra 1^T/m row on W —
    panel_mod.mix_dense_mean), so the monitor costs one deviation pass
    instead of a second full mean reduce. Idle (W == I) rounds skip the
    matmul entirely — no payload travels, no codec touches the state —
    and keep the standalone consensus_distance reduce.

    ``active`` lets the host pad a PARTIAL tail segment up to the common
    segment length instead of retracing/recompiling the whole scan for a
    one-off smaller S: rounds with ``active[s] == False`` are full no-ops
    (state passes through untouched, metrics report 0) and their
    Ws/batches entries are ignored.

    **Liveness (elastic runs).** ``live`` extends the per-round ``active``
    mask to a per-round PER-AGENT (S, m) trit mask (core.faults:
    DEAD=0 / LIVE=1 / RESYNC=2 — the launcher stacks
    ``Schedule.last_live``). LIVE agents run the round normally. A DEAD
    agent's parameter, moment, EF-residual and merge-statistics rows
    pass through the round bit-exactly: it takes no local steps (its
    rows of the vmapped grad/optimizer update are discarded — the rng
    stream is consumed identically, so survivors' draws match the
    fault-free run), and the caller must hand in the matching DEGRADED W
    (Schedule does: topology.degrade_to_live / fully_connected_live), so
    its row is an identity row and the per-row idle rule keeps every
    codec off it. A RESYNC agent (its rejoin round) takes no local steps
    either; after the round's mix it receives a full-precision pull of
    the live agents' post-mix mean, its optimizer-moment rows are
    reset to zero and its EF-residual / merge-statistics rows are
    re-initialized from the synced parameters (its own state is stale by
    construction) — survivors are never perturbed. Metrics average over
    the live agents; ``consensus`` is the live-only Xi. With a
    non-uniform merge operator under faults, pass ``global_rounds``
    explicitly — a degraded global W no longer fingerprints as the 1/m
    matrix. ``live=None`` keeps the engine byte-identical to the
    pre-liveness path.

    **Merge operators.** The spec's merge operator
    (panel_mod.with_merger / init_panel_state(merger=...), repro.merging)
    is applied on GLOBAL rounds (the paper's single final merging,
    windowed/periodic AllReduce rounds). ``global_rounds`` marks them
    explicitly — the launcher reads the schedule's own knowledge
    (Schedule.last_kind). When None, the driver falls back to
    fingerprinting W against the fully-connected 1/m matrix; that is
    correct for every scheduler-emitted global round, but a gossip
    topology can COINCIDE with the 1/m average (m=2 matched pair,
    3-agent ring) and would then be routed through the operator — pass
    the explicit mask when running non-uniform operators on such
    topologies. 'uniform' keeps the byte-for-byte pre-subsystem path:
    global rounds stay inside the same fused matmul as every other
    round. A non-uniform operator dispatches those rounds through
    ``merging.merge_panel`` (payload still wire-codec encoded; one merged
    row broadcast back), and a STATISTICAL operator (var/fisher/swa)
    carries its per-agent stats panels as ``state["merge_stat"]`` —
    donated through the scan and updated every local step
    (``update_local``: fisher sees the grad panel) and/or once per round
    (``update_round``: var/swa see the param panel).

    **Storage residency.** The spec's residency policy
    (panel_mod.with_residency / init_panel_state(residency=...),
    repro.residency) keeps the named state panels — optimizer moments,
    merge stats, the EF residual/mirror — in compressed storage (bf16,
    int8 + scale sidecars) for the WHOLE segment; the f32 compute view
    exists only transiently inside the round. Fusion points: moments
    decode immediately before the vmapped optimizer update and the
    updated moments encode back in the same donated local step (SR keys
    folded off the step rng via a residency tag — non-stochastic runs
    never fold, keeping the pre-residency key schedule bit-exact);
    stats decode once at round entry and encode once at round exit;
    the EF residual decodes/encodes strictly INSIDE the communicating
    branches, so idle (W == I) rounds pass the stored bits through
    verbatim. Composition with liveness is bit-predictable: DEAD rows
    keep their stored bits (q AND scale) unchanged through the round,
    RESYNC rows re-encode deterministically (Storage.init /
    Storage.zero_like) so a rejoin bit-matches a freshly initialised
    agent. An empty/f32 policy compiles the exact pre-residency trace.

    On a sharded ``spec`` (shard_spec / init_panel_state(mesh=...)) every
    fused op keeps the panels in their mesh layout, so mixing lowers to
    per-fsdp-shard matmuls with agent-axis collectives that carry only the
    local column shard. ``param_shardings`` (NamedSharding pytree matching
    the model params, agent-stacked) re-pins the rebuilt per-leaf params
    for the grad compute; ``in_shardings`` is forwarded to jax.jit for
    lowering against ShapeDtypeStructs."""
    if wire_dtype is not None and spec.wire:
        raise ValueError("pass either wire_dtype= (legacy cast) or a spec "
                         "wire policy (with_wire), not both")
    needs_key = wire_dtype is None and _wire_needs_key(spec)
    needs_ef = wire_dtype is None and _wire_needs_ef(spec)
    merger = merging_mod.get_merger(spec.merger)
    # a delta (mirror) codec must route GLOBAL rounds through
    # merging.merge_panel even for the uniform operator: the one-shot
    # merge is its full-bandwidth round (panel.global_merge delta rule)
    # and cannot stay inside the sparse damped fused matmul
    has_delta = wire_dtype is None and _wire_has_delta(spec)
    plain_merge = merger.name == "uniform" and not has_delta
    needs_stats = bool(merger.stat_panels)
    res_plan = _res_plan(spec)
    res_mom = res_plan.get("moments")
    res_stat = res_plan.get("stats")
    res_err = res_plan.get("wire_err")
    res_mom_key = bool(res_mom) and any(s.needs_key
                                        for s in res_mom.values())
    res_stat_key = bool(res_stat) and any(s.needs_key
                                          for s in res_stat.values())
    res_err_key = bool(res_err) and any(s.needs_key
                                        for s in res_err.values())
    res_pallas = panel_mod._pallas_ok(use_pallas, spec)
    mom_keys = tuple(optimizer.moment_keys)
    # fused moment update (kernels/opt_fused.py): None auto-enables
    # whenever the policy/optimizer qualify (grouped int8 moments +
    # optimizer.core), True requires it, False forces the unfused
    # decode->update->encode. The fused ref path is the unfused
    # composition bit-for-bit, so auto-on is trajectory-preserving.
    fused_ok = tmetrics.fused_moments_auto(spec, optimizer)
    if fused and not fused_ok:
        raise ValueError(
            "fused=True but the fused moment update does not apply: it "
            "needs a grouped-int8 moments storage (fused_update "
            f"capability; policy has '{spec.residency_of('moments')}') "
            "and an optimizer exposing core/hyper with (m, v) moments "
            f"(got '{optimizer.name}')")
    res_fused = fused_ok if fused is None else bool(fused)
    if telemetry:
        # host constants of the exact codec cost model, baked into the
        # traced wire_bytes column
        t_bytes_wire, t_bytes_full = tmetrics.wire_bytes_model(
            spec, wire_dtype)

    def one(p, b, r):
        (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b, r)
        return g, l

    def segment(state, batches, Ws, rng, active=None, global_rounds=None,
                live=None):
        m = next(iter(state["panel"].values())).shape[0]
        S = Ws.shape[0]
        if needs_ef and "wire_err" not in state:
            raise ValueError(
                "spec's wire policy uses error feedback but the state has "
                "no 'wire_err' residual panel; build the state with "
                "init_panel_state(..., wire=...)")
        if needs_stats and "merge_stat" not in state:
            raise ValueError(
                f"spec's merge operator '{merger.name}' maintains "
                "statistics panels but the state has no 'merge_stat'; "
                "build the state with init_panel_state(..., merger=...)")

        def row_mask(mask, a):
            """(m,) bool mask broadcast against a leading-(m,) leaf."""
            return mask.reshape((m,) + (1,) * (a.ndim - 1))

        def err_dec(e):
            # EF residual storage: decode ONLY inside the communicating
            # branches — idle rounds never touch the stored bits
            if not res_err or e is None:
                return e
            return _res_read(e, res_err, use_pallas=res_pallas,
                             interpret=interpret)

        def err_enc(ne, ekey, eold, W):
            # re-encode the post-mix residual; idle ROWS of W (unmatched
            # agents — their residual value is untouched by the mix)
            # keep their OLD stored bits instead of re-quantizing the
            # decoded value: strictly better precision, and it preserves
            # the per-row idle rule bit-exactly through storage
            if not res_err or ne is None:
                return ne
            enc = _res_write(ne, res_err, ekey, spec,
                             use_pallas=res_pallas, interpret=interpret)
            if eold is not None:
                ir = jnp.all(W == jnp.eye(m, dtype=W.dtype), axis=1)
                enc = {k: (jax.tree.map(
                    lambda a, b: jnp.where(row_mask(ir, a), b, a),
                    v, eold[k]) if k in res_err else v)
                    for k, v in enc.items()}
            return enc

        def agent_mets(out_pan, la, ga, lv, alive, W, full_bw):
            # the per-agent metric panel: pure reads of arrays the round
            # already materialized (la/ga are (H, m) stacks from the
            # local scan; out_pan is the post-mix panel)
            return {
                "loss_agent": jnp.mean(la, axis=0),
                "grad_norm_agent": jnp.mean(ga, axis=0),
                "dist_to_mean": tmetrics.agent_dist_to_mean(
                    out_pan, live=alive),
                "live": tmetrics.live_trits(lv, m),
                "wire_bytes": tmetrics.round_wire_bytes(
                    W, bytes_wire=t_bytes_wire, bytes_full=t_bytes_full,
                    full_bandwidth=full_bw, lv=lv),
            }

        def make_local_body(alive):
            # alive=None compiles the exact pre-liveness body; a (m,)
            # bool mask keeps non-live rows' params/moments/stats frozen
            # while consuming the SAME rng stream (survivor draws match
            # the fault-free twin)
            if alive is not None:
                lf = alive.astype(jnp.float32)
                n_live = jnp.maximum(jnp.sum(lf), 1.0)

                def freeze(new, old):
                    return jax.tree.map(
                        lambda a, b: jnp.where(row_mask(alive, a), a, b),
                        new, old)

            def local_body(carry, xs):
                pan, opt, mstat = carry
                batch, r = xs
                rngs = jax.random.split(r, m)
                params = panel_mod.from_panel(
                    pan, spec, leaf_shardings=param_shardings)
                with scope("dsgd.local_grad"):
                    grads, losses = jax.vmap(one)(params, batch, rngs)
                gpan = panel_mod.to_panel(grads, spec)
                if not plain_merge and merger.local_stat:
                    upd = merger.update_local(mstat, gpan)
                    mstat = upd if alive is None else freeze(upd, mstat)
                with scope("dsgd.local_update"):
                    if not res_mom:
                        new_pan, new_opt = jax.vmap(optimizer.update)(
                            gpan, opt, pan)
                    elif res_fused:
                        # single-sweep fused kernel: no f32 moment view
                        # ever hits HBM; same SR key folds as the
                        # unfused branch below, so trajectories match
                        new_pan, new_opt = _fused_opt_update(
                            gpan, opt, pan, optimizer, res_mom, spec,
                            _res_key(r, "moments", res_mom_key),
                            use_pallas=res_pallas, interpret=interpret)
                    else:
                        # moment storage fusion: decode -> update ->
                        # re-encode inside the SAME donated step (the f32
                        # view is a transient XLA temporary, never a
                        # carried buffer); the SR key folds off the
                        # LOCAL-STEP rng so every step draws fresh bits
                        opt_f = _opt_read(opt, res_mom, mom_keys,
                                          use_pallas=res_pallas,
                                          interpret=interpret)
                        new_pan, new_opt = jax.vmap(optimizer.update)(
                            gpan, opt_f, pan)
                        new_opt = _opt_write(
                            new_opt, res_mom, mom_keys,
                            _res_key(r, "moments", res_mom_key), spec,
                            use_pallas=res_pallas, interpret=interpret)
                if alive is None:
                    loss = jnp.mean(losses)
                    gn = panel_mod.panel_norm(gpan, axis_mean=True)
                else:
                    new_pan = freeze(new_pan, pan)
                    new_opt = freeze(new_opt, opt)
                    loss = jnp.sum(lf * losses) / n_live
                    gn = panel_mod.panel_norm(gpan, axis_mean=True,
                                              rows=lf / n_live)
                ys = (loss, gn)
                if telemetry:
                    ys = ys + (tmetrics.agent_loss(losses, alive),
                               tmetrics.agent_grad_norm(gpan, alive))
                return (new_pan, new_opt, mstat), ys

            return local_body

        def _live_comm(pan, opt, werr, mstat, W, wkey, ekey, lv, alive,
                       glob, losses, gns, la=None, ga=None):
            # elastic round: mix over the (already degraded) W, then
            # apply the liveness mask — DEAD rows pass through, RESYNC
            # rows pull the live agents' post-mix mean and restart their
            # carried state from it
            sync = lv == 2
            not_live = ~alive
            kw = dict(wire_dtype=wire_dtype, use_pallas=use_pallas,
                      interpret=interpret, spec=spec, key=wkey)
            idle = jnp.all(W == jnp.eye(m, dtype=W.dtype))
            is_full = (None if plain_merge else
                       (glob if glob is not None else
                        jnp.all(W == jnp.full((m, m), 1.0 / m, W.dtype))))

            def comm(args):
                # monitor's folded-mean matmul (an extra 1^T/m row on W)
                # mirrors the live=None path bit-for-bit: an all-live
                # mask must not perturb the numerics. The folded mean
                # itself is unused — the live-only Xi is computed below
                p, e = args
                if monitor:
                    mixed, _, ne = panel_mod.mix_dense_mean(
                        p, W, err=err_dec(e), **kw)
                    return mixed, err_enc(ne, ekey, e, W)
                if needs_ef:
                    mixed, ne = panel_mod.mix_dense(p, W, err=err_dec(e),
                                                    **kw)
                    return mixed, err_enc(ne, ekey, e, W)
                return panel_mod.mix_dense(p, W, **kw), e

            def gossip_fn(args):
                return jax.lax.cond(idle, lambda a: a, comm, args)

            def merge_fn(args):
                p, e = args
                mixed, _, ne = merging_mod.merge_panel(
                    p, merger, stats=mstat, spec=spec,
                    wire_dtype=wire_dtype, key=wkey, err=err_dec(e),
                    use_pallas=use_pallas, interpret=interpret,
                    live=alive)
                return mixed, err_enc(ne, ekey, None, None)

            werr_in = werr
            if plain_merge:
                mixed, werr_m = jax.lax.cond(idle, lambda a: a, comm,
                                             (pan, werr))
            else:
                mixed, werr_m = jax.lax.cond(is_full, merge_fn, gossip_fn,
                                             (pan, werr))

            lf = alive.astype(jnp.float32)
            lw = lf / jnp.maximum(jnp.sum(lf), 1.0)
            out_pan = {}
            for k, x in mixed.items():
                # dead AND resync agents did not participate in the mix:
                # their rows are identity rows of the degraded W
                # (defense in depth — the per-row idle rule already
                # restores them under a lossy codec)
                y = jnp.where(row_mask(not_live, x), pan[k], x)
                mu = jnp.tensordot(lw, y.astype(jnp.float32), axes=1)
                y = jnp.where(row_mask(sync, y), mu[None].astype(y.dtype),
                              y)
                out_pan[k] = panel_mod._constrain_group(y, spec, k)
            # resync rows restart their carried state from the synced
            # params: zero moments, codec-fresh residual, fresh stats
            if not res_mom:
                opt = jax.tree.map(
                    lambda a: jnp.where(row_mask(sync, a),
                                        jnp.zeros_like(a), a), opt)
            else:
                # stored moments zero to the CANONICAL stored zero
                # (Storage.zero_like == init(zeros) bit-for-bit), so a
                # rejoined row matches a freshly initialised agent's
                def zero_rows(k, v):
                    if k in mom_keys:
                        zero = {g: (res_mom[g].zero_like(x)
                                    if g in res_mom else
                                    jax.tree.map(jnp.zeros_like, x))
                                for g, x in v.items()}
                    else:
                        zero = jax.tree.map(jnp.zeros_like, v)
                    return jax.tree.map(
                        lambda a, z: jnp.where(row_mask(sync, a), z, a),
                        v, zero)

                opt = {k: zero_rows(k, v) for k, v in opt.items()}
            if werr_m is not None:
                new_werr = {}
                for k, e in werr_m.items():
                    if res_err and k in res_err:
                        # stored residual: dead rows take their OLD
                        # stored bits leafwise (q AND scale — the PR 6
                        # bit-exact passthrough through storage), resync
                        # rows a deterministic re-encode of the fresh
                        # codec state
                        e = jax.tree.map(
                            lambda a, b: jnp.where(
                                row_mask(not_live, a), b, a),
                            e, werr_in[k])
                        fresh = res_err[k].init(
                            wire_mod.get_codec(spec.wire_of(k)).init_err(
                                out_pan[k]).astype(jnp.float32))
                        e = jax.tree.map(
                            lambda a, b: jnp.where(row_mask(sync, a), b,
                                                   a), e, fresh)
                        new_werr[k] = _res_constrain(e, spec, k)
                    else:
                        e = jnp.where(row_mask(not_live, e), werr_in[k],
                                      e)
                        fresh = wire_mod.get_codec(
                            spec.wire_of(k)).init_err(
                                out_pan[k]).astype(e.dtype)
                        new_werr[k] = panel_mod._constrain_group(
                            jnp.where(row_mask(sync, e), fresh, e),
                            spec, k)
                werr_m = new_werr
            if mstat is not None:
                fresh = merger.init_stats(out_pan)
                mstat = {
                    name: {k: panel_mod._constrain_group(
                        jnp.where(row_mask(sync, v), fresh[name][k], v),
                        spec, k) for k, v in grp.items()}
                    for name, grp in mstat.items()}
            mets = {"loss": jnp.mean(losses), "grad_norm": jnp.mean(gns),
                    "grad_norm_max": jnp.max(gns)}
            if monitor:
                mets["consensus"] = panel_mod.consensus_distance(
                    out_pan, use_pallas=use_pallas, interpret=interpret,
                    spec=spec, live=alive)
            if telemetry:
                mets.update(agent_mets(
                    out_pan, la, ga, lv, alive, W,
                    is_full if has_delta else None))
            return (out_pan, opt, werr_m, mstat), mets

        def round_core(carry, W, batch_r, r, glob, lv):
            pan, opt, werr, mstat = carry
            alive = None if lv is None else lv == 1
            rs = jax.random.split(r, local_steps)
            (pan, opt, mstat), step_ys = jax.lax.scan(
                make_local_body(alive), (pan, opt, mstat), (batch_r, rs))
            if telemetry:
                losses, gns, la, ga = step_ys
            else:
                (losses, gns), la, ga = step_ys, None, None
            if not plain_merge and merger.round_stat:
                upd = merger.update_round(mstat, pan)
                if alive is not None:
                    upd = jax.tree.map(
                        lambda a, b: jnp.where(row_mask(alive, a), a, b),
                        upd, mstat)
                mstat = upd
            wkey = _wire_key(r, needs_key)
            ekey = _res_key(r, "wire_err", res_err_key)
            if lv is not None:
                return _live_comm(pan, opt, werr, mstat, W, wkey, ekey,
                                  lv, alive, glob, losses, gns, la, ga)
            # W == I rounds communicate nothing: skip the matmul AND the
            # codec (no payload travels, so nothing may be quantized and
            # the error-feedback residual must pass through untouched)
            idle = jnp.all(W == jnp.eye(m, dtype=W.dtype))
            # non-uniform operators take over the GLOBAL rounds: the
            # explicit per-round mask when given, else the W fingerprint
            # (the 1/m matrix the schedulers emit for global merging —
            # see the docstring caveat); after the broadcast every row
            # is identical, so Xi == 0
            is_full = (None if plain_merge else
                       (glob if glob is not None else
                        jnp.all(W == jnp.full((m, m), 1.0 / m, W.dtype))))
            kw = dict(wire_dtype=wire_dtype, use_pallas=use_pallas,
                      interpret=interpret, spec=spec, key=wkey)

            if monitor:
                def comm(args):
                    p, e = args
                    mixed, mean, ne = panel_mod.mix_dense_mean(
                        p, W, err=err_dec(e), **kw)
                    return (mixed, err_enc(ne, ekey, e, W),
                            panel_mod.consensus_from_mean(mixed, mean))

                def idle_fn(args):
                    p, e = args
                    return p, e, panel_mod.consensus_distance(
                        p, use_pallas=use_pallas, interpret=interpret,
                        spec=spec)

                def gossip_fn(args):
                    return jax.lax.cond(idle, idle_fn, comm, args)

                def merge_fn(args):
                    p, e = args
                    mixed, _, ne = merging_mod.merge_panel(
                        p, merger, stats=mstat, spec=spec,
                        wire_dtype=wire_dtype, key=wkey, err=err_dec(e),
                        use_pallas=use_pallas, interpret=interpret)
                    return (mixed, err_enc(ne, ekey, None, None),
                            jnp.zeros((), jnp.float32))

                if plain_merge:
                    mixed, werr, xi = jax.lax.cond(
                        idle, idle_fn, comm, (pan, werr))
                else:
                    mixed, werr, xi = jax.lax.cond(
                        is_full, merge_fn, gossip_fn, (pan, werr))
                mets = {"loss": jnp.mean(losses),
                        "grad_norm": jnp.mean(gns),
                        "grad_norm_max": jnp.max(gns), "consensus": xi}
            else:
                def comm(args):
                    p, e = args
                    if needs_ef:
                        mixed, ne = panel_mod.mix_dense(
                            p, W, err=err_dec(e), **kw)
                        return mixed, err_enc(ne, ekey, e, W)
                    return panel_mod.mix_dense(p, W, **kw), e

                def gossip_fn(args):
                    return jax.lax.cond(idle, lambda a: a, comm, args)

                def merge_fn(args):
                    p, e = args
                    mixed, _, ne = merging_mod.merge_panel(
                        p, merger, stats=mstat, spec=spec,
                        wire_dtype=wire_dtype, key=wkey, err=err_dec(e),
                        use_pallas=use_pallas, interpret=interpret)
                    return mixed, err_enc(ne, ekey, None, None)

                if plain_merge:
                    mixed, werr = jax.lax.cond(
                        idle, lambda a: a, comm, (pan, werr))
                else:
                    mixed, werr = jax.lax.cond(
                        is_full, merge_fn, gossip_fn, (pan, werr))
                mets = {"loss": jnp.mean(losses),
                        "grad_norm": jnp.mean(gns),
                        "grad_norm_max": jnp.max(gns)}
            if telemetry:
                mets.update(agent_mets(
                    mixed, la, ga, lv, alive, W,
                    is_full if has_delta else None))
            return (mixed, opt, werr, mstat), mets

        def run_round(carry, W, batch_r, r, glob, lv):
            if not res_stat or carry[3] is None:
                return round_core(carry, W, batch_r, r, glob, lv)
            # stat-panel storage: ONE decode to the f32 compute view at
            # round entry, one encode at round exit — every operator the
            # round runs (update_local/update_round/merge_panel) sees
            # f32. DEAD rows keep their stored bits verbatim (q AND
            # scale); RESYNC rows encode deterministically so a rejoin
            # bit-matches a fresh init of the synced params.
            pan, opt, werr, mstat = carry
            mstat_f = {name: _res_read(grp, res_stat,
                                       use_pallas=res_pallas,
                                       interpret=interpret)
                       for name, grp in mstat.items()}
            (pan, opt, werr, mstat_f), mets = round_core(
                (pan, opt, werr, mstat_f), W, batch_r, r, glob, lv)
            skey = _res_key(r, "stats", res_stat_key)
            sync = None if lv is None else lv == 2
            dead = None if lv is None else lv == 0
            new_mstat = {}
            for i, name in enumerate(sorted(mstat_f)):
                ki = None if skey is None else jax.random.fold_in(skey, i)
                enc = _res_write(mstat_f[name], res_stat, ki, None,
                                 use_pallas=res_pallas,
                                 interpret=interpret)
                if lv is not None:
                    det = _res_init(mstat_f[name], res_stat)
                    old = mstat[name]
                    enc = {g: jax.tree.map(
                        lambda a, d_, o_: jnp.where(
                            row_mask(dead, a), o_,
                            jnp.where(row_mask(sync, a), d_, a)),
                        v, det[g], old[g]) for g, v in enc.items()}
                new_mstat[name] = {g: _res_constrain(v, spec, g)
                                   for g, v in enc.items()}
            return (pan, opt, werr, new_mstat), mets

        def round_body(carry, xs):
            W, batch_r, r = xs[:3]
            rest = list(xs[3:])
            glob = rest.pop(0) if global_rounds is not None else None
            lv = rest.pop(0) if live is not None else None
            act = rest.pop(0) if active is not None else None
            if act is None:
                return run_round(carry, W, batch_r, r, glob, lv)

            def inactive(c):
                # zeros matching run_round's metric schema exactly
                mets_sds = jax.eval_shape(
                    lambda cc: run_round(cc, W, batch_r, r, glob, lv)[1],
                    c)
                return c, jax.tree.map(
                    lambda s: jnp.zeros(s.shape, s.dtype), mets_sds)

            return jax.lax.cond(
                act, lambda c: run_round(c, W, batch_r, r, glob, lv),
                inactive, carry)

        rngs = jax.random.split(rng, S)
        xs = (Ws, batches, rngs)
        if global_rounds is not None:
            xs = xs + (global_rounds,)
        if live is not None:
            xs = xs + (live,)
        if active is not None:
            xs = xs + (active,)
        werr0 = state.get("wire_err") if needs_ef else None
        mstat0 = state.get("merge_stat") if needs_stats else None
        (pan, opt, werr, mstat), metrics = jax.lax.scan(
            round_body, (state["panel"], state["opt"], werr0, mstat0), xs)
        steps = (S if active is None
                 else jnp.sum(active.astype(jnp.int32))) * local_steps
        out = {"panel": pan, "opt": opt, "step": state["step"] + steps}
        if werr is not None:
            out["wire_err"] = werr
        if mstat is not None:
            out["merge_stat"] = mstat
        return out, metrics

    jit_kw = {} if in_shardings is None else {"in_shardings": in_shardings}
    return jax.jit(segment, donate_argnums=(0,) if donate else (), **jit_kw)


def make_parallel_step(loss_fn: Callable, optimizer: Optimizer):
    """Parallel SGD / FedAvg(H=1) baseline: one shared model; gradients are
    averaged over the m per-agent batches every step (the paper's reference
    rate O(sigma^2/(m eps^2) + 1/eps))."""

    def step(state, batch, rng):
        m = jax.tree.leaves(batch)[0].shape[0]
        rngs = jax.random.split(rng, m)

        def one(b, r):
            (l, aux), g = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"], b, r)
            return g, l

        grads, losses = jax.vmap(one)(batch, rngs)
        gbar = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        new_p, new_opt = optimizer.update(gbar, state["opt"], state["params"])
        return {"params": new_p, "opt": new_opt,
                "step": state["step"] + 1}, {"loss": jnp.mean(losses)}

    return step


def init_parallel_state(init_params: Callable, optimizer: Optimizer, rng):
    p = init_params(rng)
    return {"params": p, "opt": optimizer.init(p),
            "step": jnp.zeros((), jnp.int32)}
