"""Decentralized training engine (Algorithm 1 of the paper).

State is agent-stacked: every leaf of params/opt_state carries a leading
(m,) agent axis (sharded over ('pod','agent') on the production mesh).
One round = per-agent local step(s) (vmapped grad + optimizer; zero
cross-agent traffic) followed by gossip mixing with the scheduler's W^(t).

``loss_fn(params, batch, rng) -> (loss, aux)`` is any per-agent objective
(an LM from repro.models, or the benchmark classifiers).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import gossip
from repro.core.consensus import consensus_distance
from repro.optim.optim import Optimizer


def init_state(init_params: Callable, optimizer: Optimizer, m: int, rng,
               same_init: bool = False):
    """Agent-stacked train state. ``same_init=True`` matches the theory
    (theta_k^0 = theta^0); False matches the paper's main experiments
    (independent inits — the harder cross-initialization merge)."""
    if same_init:
        p = init_params(rng)
        params = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), p)
    else:
        params = jax.vmap(init_params)(jax.random.split(rng, m))
    opt_state = jax.vmap(optimizer.init)(params)
    return {"params": params, "opt": opt_state,
            "step": jnp.zeros((), jnp.int32)}


def _mix(params, W, impl: str, wire_dtype, partner=None):
    if impl == "dense":
        return gossip.mix_dense(params, W, wire_dtype)
    if impl == "pairwise":
        return gossip.mix_pairwise(params, partner, wire_dtype=wire_dtype)
    if impl == "merge":
        return gossip.global_merge(params, wire_dtype)
    if impl == "none":
        return params
    raise ValueError(impl)


def make_dsgd_step(loss_fn: Callable, optimizer: Optimizer, *,
                   gossip_impl: str = "dense",
                   wire_dtype=None, monitor: bool = True):
    """One communication round with ONE local step per agent.

    step(state, batch, W, rng) -> (state, metrics); batch leaves (m, b, ...).
    """

    def step(state, batch, W, rng):
        m = jax.tree.leaves(state["params"])[0].shape[0]
        rngs = jax.random.split(rng, m)

        def one(p, b, r):
            (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b, r)
            return g, l

        grads, losses = jax.vmap(one)(state["params"], batch, rngs)
        new_p, new_opt = jax.vmap(optimizer.update)(
            grads, state["opt"], state["params"])
        mixed = _mix(new_p, W, gossip_impl, wire_dtype)
        metrics = {"loss": jnp.mean(losses)}
        if monitor:
            gbar = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
            metrics["grad_norm"] = jnp.sqrt(sum(
                jnp.sum(jnp.square(x)) for x in jax.tree.leaves(gbar)))
            metrics["consensus"] = consensus_distance(mixed)
        return {"params": mixed, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return step


def make_dsgd_round(loss_fn: Callable, optimizer: Optimizer, local_steps: int,
                    *, gossip_impl: str = "dense", wire_dtype=None,
                    monitor: bool = True):
    """One communication round with H local steps (paper: H=100).

    step(state, batches, W, rng): batches leaves (H, m, b, ...) — scanned.
    """

    def round_fn(state, batches, W, rng):
        m = jax.tree.leaves(state["params"])[0].shape[0]

        def body(carry, xs):
            params, opt = carry
            batch, r = xs
            rngs = jax.random.split(r, m)

            def one(p, b, rr):
                (l, aux), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(p, b, rr)
                return g, l

            grads, losses = jax.vmap(one)(params, batch, rngs)
            new_p, new_opt = jax.vmap(optimizer.update)(grads, opt, params)
            gbar = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                              for x in jax.tree.leaves(gbar)))
            return (new_p, new_opt), (jnp.mean(losses), gn)

        rngs = jax.random.split(rng, local_steps)
        (p, o), (losses, gns) = jax.lax.scan(
            body, (state["params"], state["opt"]), (batches, rngs))
        mixed = _mix(p, W, gossip_impl, wire_dtype)
        metrics = {"loss": jnp.mean(losses), "grad_norm": gns[-1]}
        if monitor:
            metrics["consensus"] = consensus_distance(mixed)
        return {"params": mixed, "opt": o,
                "step": state["step"] + local_steps}, metrics

    return round_fn


def make_parallel_step(loss_fn: Callable, optimizer: Optimizer):
    """Parallel SGD / FedAvg(H=1) baseline: one shared model; gradients are
    averaged over the m per-agent batches every step (the paper's reference
    rate O(sigma^2/(m eps^2) + 1/eps))."""

    def step(state, batch, rng):
        m = jax.tree.leaves(batch)[0].shape[0]
        rngs = jax.random.split(rng, m)

        def one(b, r):
            (l, aux), g = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"], b, r)
            return g, l

        grads, losses = jax.vmap(one)(batch, rngs)
        gbar = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        new_p, new_opt = optimizer.update(gbar, state["opt"], state["params"])
        return {"params": new_p, "opt": new_opt,
                "step": state["step"] + 1}, {"loss": jnp.mean(losses)}

    return step


def init_parallel_state(init_params: Callable, optimizer: Optimizer, rng):
    p = init_params(rng)
    return {"params": p, "opt": optimizer.init(p),
            "step": jnp.zeros((), jnp.int32)}
