"""Temporal communication schedulers — the paper's object of study.

A scheduler maps round t -> mixing matrix W^(t) (numpy, host side). The
communication *budget* of a run is the accumulated per-round wire cost; the
paper's question is how to place that budget over time. Schedulers:

* ConstantSchedule      — sparse gossip every round (baseline DSGD).
* LocalOnlySchedule     — no communication at all (paper's ablation).
* WindowedSchedule      — fully-connected AllReduce inside [start, end),
                          sparse gossip elsewhere (Fig. 2a/2b).
* FinalMergeSchedule    — sparse gossip + ONE global merging at the last
                          round (the paper's headline method, Fig. 1).
* PeriodicGlobalSchedule— global averaging every H rounds (Chen et al. 2021
                          comparison baseline).
* AdaptiveEdgeSchedule  — beyond-paper: monitors the critical-consensus-edge
                          condition (Prop. 3): go fully-connected when
                          Xi_t > kappa * mu_t, else sparse gossip. This is
                          the adaptive algorithm the paper's §6 calls for.

Every scheduler reports per-round cost in model-size units P:
dense AllReduce ~ 2P (ring), pairwise exchange ~ P, idle ~ 0 — matching the
paper's cost model O(mRPT + 2mP).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import topology as topo


class Schedule:
    """Base: sparse random-matching gossip every round.

    ``merger`` names the merge OPERATOR applied on this schedule's global
    rounds (repro.merging: uniform/weighted/var/fisher/ties/swa) — for
    FinalMergeSchedule that is the paper's single final merging itself.
    The schedule only carries the name; the panel engine
    (dsgd.make_panel_segment via PanelSpec.merger) applies it, and the
    cost model is unchanged (every operator is one AllReduce-shaped
    exchange)."""

    def __init__(self, m: int, rounds: int, kind: str = "random",
                 prob: float = 0.2, seed: int = 0,
                 merger: str = "uniform"):
        self.m, self.rounds = m, rounds
        self.sampler = topo.make_sampler(kind, m, prob)
        self.rng = np.random.default_rng(seed)
        self.merger = merger
        # kind of the last mixing_matrix() call: 'global' | 'idle' |
        # 'gossip'. The launcher reads this to tell the panel engine
        # WHICH rounds are global (dsgd.make_panel_segment
        # global_rounds=): inferring it from the W values alone
        # false-positives when a gossip matrix coincides with the 1/m
        # average (m=2 matched pair, 3-ring, ...)
        self.last_kind = None

    # -- override points ---------------------------------------------------
    def is_global(self, t: int, monitor: Optional[dict] = None) -> bool:
        return False

    def is_local_only(self, t: int) -> bool:
        return False

    # -- public API ---------------------------------------------------------
    def mixing_matrix(self, t: int, monitor: Optional[dict] = None
                      ) -> np.ndarray:
        if self.is_global(t, monitor):
            self.last_kind = "global"
            return topo.fully_connected(self.m)
        if self.is_local_only(t):
            self.last_kind = "idle"
            return topo.identity(self.m)
        self.last_kind = "gossip"
        return self.sampler(t, self.rng)

    def round_cost(self, W: np.ndarray) -> float:
        """Wire cost of one round in units of model size P (per agent)."""
        if np.allclose(W, np.eye(self.m)):
            return 0.0
        if np.allclose(W, topo.fully_connected(self.m)):
            return 2.0  # ring AllReduce
        # pairwise matching: 1 P per participating agent
        active = np.sum(np.diag(W) < 1.0 - 1e-12) / self.m
        return float(active)


class ConstantSchedule(Schedule):
    pass


class LocalOnlySchedule(Schedule):
    def is_local_only(self, t: int) -> bool:
        return True


class WindowedSchedule(Schedule):
    """Fully-connected inside [start, end); sparse gossip elsewhere."""

    def __init__(self, m, rounds, start: int, end: int, **kw):
        super().__init__(m, rounds, **kw)
        self.start, self.end = start, end

    def is_global(self, t, monitor=None):
        return self.start <= t < self.end


class FinalMergeSchedule(Schedule):
    """The paper's method: sparse gossip + a single final global merging
    (performed by this schedule's ``merger`` operator)."""

    def is_global(self, t, monitor=None):
        return t == self.rounds - 1


class PeriodicGlobalSchedule(Schedule):
    def __init__(self, m, rounds, period: int = 48, **kw):
        super().__init__(m, rounds, **kw)
        self.period = period

    def is_global(self, t, monitor=None):
        return (t + 1) % self.period == 0


class AdaptiveEdgeSchedule(Schedule):
    """Critical-consensus-edge controller (Prop. 3, Eq. 11).

    Goes fully-connected when the measured consensus distance Xi_t exceeds
    ``kappa * mu_t`` where mu_t is an EMA of the global gradient norm at the
    averaged model; otherwise sparse gossip. As training converges, mu_t
    shrinks, the allowed Xi_t band tightens, and communication automatically
    concentrates in the late phase — exactly the behaviour the paper finds
    optimal empirically.
    """

    def __init__(self, m, rounds, kappa: float = 0.5, ema: float = 0.9, **kw):
        super().__init__(m, rounds, **kw)
        self.kappa, self.ema = kappa, ema
        self._mu = None
        self.global_rounds = []

    def is_global(self, t, monitor=None):
        if not monitor:
            return False
        mu_obs = monitor.get("grad_norm")
        xi = monitor.get("consensus")
        if mu_obs is None or xi is None:
            return False
        self._mu = (mu_obs if self._mu is None
                    else self.ema * self._mu + (1 - self.ema) * mu_obs)
        hit = bool(xi > self.kappa * self._mu)
        if hit:
            self.global_rounds.append(t)
        return hit


SCHEDULES = {"constant": ConstantSchedule, "local": LocalOnlySchedule,
             "windowed": WindowedSchedule,
             "final_merge": FinalMergeSchedule,
             "periodic": PeriodicGlobalSchedule,
             "adaptive": AdaptiveEdgeSchedule}


def make_schedule(name: str, m: int, rounds: int, **kw) -> Schedule:
    """Build a scheduler by registry name (``SCHEDULES`` — the registry
    the property suite round-trips; mirrors wire.CODECS /
    merging.MERGERS)."""
    try:
        cls = SCHEDULES[name]
    except KeyError:
        raise ValueError(
            f"unknown schedule {name!r}; known: {sorted(SCHEDULES)}"
        ) from None
    return cls(m, rounds, **kw)
