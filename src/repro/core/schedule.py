"""Temporal communication schedulers — the paper's object of study.

A scheduler maps round t -> mixing matrix W^(t) (numpy, host side). The
communication *budget* of a run is the accumulated per-round wire cost; the
paper's question is how to place that budget over time. Schedulers:

* ConstantSchedule      — sparse gossip every round (baseline DSGD).
* LocalOnlySchedule     — no communication at all (paper's ablation).
* WindowedSchedule      — fully-connected AllReduce inside [start, end),
                          sparse gossip elsewhere (Fig. 2a/2b).
* FinalMergeSchedule    — sparse gossip + ONE global merging at the last
                          round (the paper's headline method, Fig. 1).
* PeriodicGlobalSchedule— global averaging every H rounds (Chen et al. 2021
                          comparison baseline).
* AdaptiveEdgeSchedule  — beyond-paper: monitors the critical-consensus-edge
                          condition (Prop. 3): go fully-connected when
                          Xi_t > kappa * mu_t, else sparse gossip. This is
                          the adaptive algorithm the paper's §6 calls for.

Every scheduler reports per-round cost in model-size units P:
dense AllReduce ~ 2P (ring), pairwise exchange ~ P, idle ~ 0 — matching the
paper's cost model O(mRPT + 2mP).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import faults as faults_mod
from repro.core import topology as topo


class Schedule:
    """Base: sparse random-matching gossip every round.

    ``merger`` names the merge OPERATOR applied on this schedule's global
    rounds (repro.merging: uniform/weighted/var/fisher/ties/swa) — for
    FinalMergeSchedule that is the paper's single final merging itself.
    The schedule only carries the name; the panel engine
    (dsgd.make_panel_segment via PanelSpec.merger) applies it, and the
    cost model is unchanged (every operator is one AllReduce-shaped
    exchange).

    ``faults`` (a core.faults.FaultPlan) degrades every emitted W to the
    round's surviving subgraph: gossip matrices through
    topology.degrade_to_live (dead agents become identity rows, the
    survivors' lost mass folds into their self-loops), global rounds
    through topology.fully_connected_live (the sub-AllReduce over the
    live agents). An agent on its RESYNC round is treated as dead for
    the MATRIX — the engine performs the rejoin pull itself from the
    per-round mask (``last_live``), so the W stream stays doubly
    stochastic. The topology sampler's rng is consumed identically with
    or without faults, so a faulted run and its fault-free twin share
    the same underlying W draws — and a resumed run replays the same
    stream."""

    def __init__(self, m: int, rounds: int, kind: str = "random",
                 prob: float = 0.2, seed: int = 0,
                 merger: str = "uniform", faults=None):
        self.m, self.rounds = m, rounds
        self.sampler = topo.make_sampler(kind, m, prob)
        self.rng = np.random.default_rng(seed)
        self.merger = merger
        self.faults = faults
        # kind of the last mixing_matrix() call: 'global' | 'idle' |
        # 'gossip'. The launcher reads this to tell the panel engine
        # WHICH rounds are global (dsgd.make_panel_segment
        # global_rounds=): inferring it from the W values alone
        # false-positives when a gossip matrix coincides with the 1/m
        # average (m=2 matched pair, 3-ring, ...)
        self.last_kind = None
        # liveness mask of the last mixing_matrix() call ((m,) int8 of
        # faults.DEAD/LIVE/RESYNC, None without a fault plan) — the
        # launcher stacks these into the engine's (S, m) live argument
        self.last_live = None

    # -- override points ---------------------------------------------------
    def is_global(self, t: int, monitor: Optional[dict] = None) -> bool:
        return False

    def is_local_only(self, t: int) -> bool:
        return False

    # -- public API ---------------------------------------------------------
    def mixing_matrix(self, t: int, monitor: Optional[dict] = None
                      ) -> np.ndarray:
        lv = None if self.faults is None else self.faults.mask(t)
        self.last_live = lv
        # only fully-LIVE agents appear in the matrix: a RESYNC agent's
        # row stays identity (the engine pulls it to the live mean from
        # the mask, outside the wire), a DEAD agent's row/col is e_k
        alive = None if lv is None else lv == faults_mod.LIVE
        if self.is_global(t, monitor):
            self.last_kind = "global"
            if alive is None:
                return topo.fully_connected(self.m)
            return topo.fully_connected_live(alive)
        if self.is_local_only(t):
            self.last_kind = "idle"
            return topo.identity(self.m)
        self.last_kind = "gossip"
        W = self.sampler(t, self.rng)
        return W if alive is None else topo.degrade_to_live(W, alive)

    def round_cost(self, W: np.ndarray) -> float:
        """Wire cost of one round in units of model size P (per agent)."""
        if np.allclose(W, np.eye(self.m)):
            return 0.0
        if np.allclose(W, topo.fully_connected(self.m)):
            return 2.0  # ring AllReduce
        # pairwise matching: 1 P per participating agent
        active = np.sum(np.diag(W) < 1.0 - 1e-12) / self.m
        return float(active)


class ConstantSchedule(Schedule):
    pass


class LocalOnlySchedule(Schedule):
    def is_local_only(self, t: int) -> bool:
        return True


class WindowedSchedule(Schedule):
    """Fully-connected inside [start, end); sparse gossip elsewhere."""

    def __init__(self, m, rounds, start: int, end: int, **kw):
        super().__init__(m, rounds, **kw)
        self.start, self.end = start, end

    def is_global(self, t, monitor=None):
        return self.start <= t < self.end


class FinalMergeSchedule(Schedule):
    """The paper's method: sparse gossip + a single final global merging
    (performed by this schedule's ``merger`` operator)."""

    def is_global(self, t, monitor=None):
        return t == self.rounds - 1


class PeriodicGlobalSchedule(Schedule):
    def __init__(self, m, rounds, period: int = 48, **kw):
        super().__init__(m, rounds, **kw)
        self.period = period

    def is_global(self, t, monitor=None):
        return (t + 1) % self.period == 0


class AdaptiveEdgeSchedule(Schedule):
    """Critical-consensus-edge controller (Prop. 3, Eq. 11).

    Goes fully-connected when the measured consensus distance Xi_t exceeds
    ``kappa * mu_t`` where mu_t is an EMA of the global gradient norm at the
    averaged model; otherwise sparse gossip. As training converges, mu_t
    shrinks, the allowed Xi_t band tightens, and communication automatically
    concentrates in the late phase — exactly the behaviour the paper finds
    optimal empirically.
    """

    def __init__(self, m, rounds, kappa: float = 0.5, ema: float = 0.9, **kw):
        super().__init__(m, rounds, **kw)
        self.kappa, self.ema = kappa, ema
        self._mu = None
        self.global_rounds = []

    def is_global(self, t, monitor=None):
        if not monitor:
            return False
        mu_obs = monitor.get("grad_norm")
        xi = monitor.get("consensus")
        if mu_obs is None or xi is None:
            return False
        self._mu = (mu_obs if self._mu is None
                    else self.ema * self._mu + (1 - self.ema) * mu_obs)
        hit = bool(xi > self.kappa * self._mu)
        if hit:
            self.global_rounds.append(t)
        return hit


SCHEDULES = {"constant": ConstantSchedule, "local": LocalOnlySchedule,
             "windowed": WindowedSchedule,
             "final_merge": FinalMergeSchedule,
             "periodic": PeriodicGlobalSchedule,
             "adaptive": AdaptiveEdgeSchedule}


def make_schedule(name: str, m: int, rounds: int, **kw) -> Schedule:
    """Build a scheduler by registry name (``SCHEDULES`` — the registry
    the property suite round-trips; mirrors wire.CODECS /
    merging.MERGERS)."""
    try:
        cls = SCHEDULES[name]
    except KeyError:
        raise ValueError(
            f"unknown schedule {name!r}; known: {sorted(SCHEDULES)}"
        ) from None
    return cls(m, rounds, **kw)
