"""Flat-panel parameter engine: the fused communication layer.

Agent-stacked pytrees (every leaf (m, ...)) are flattened ONCE into a
*panel*: a dict ``{dtype_name: (m, D_dtype) array}`` — one row per agent,
one column per scalar parameter — described by a static :class:`PanelSpec`
(per-leaf offsets/shapes/dtypes). Grouping by dtype preserves every leaf's
storage dtype exactly (``jnp.concatenate`` over mixed-dtype leaves would
silently promote bf16 to f32 and double the wire bytes).

All communication primitives then become ONE fused op per dtype group over
the panel instead of one op per pytree leaf:

* :func:`mix_dense`       — Theta <- W Theta, a single (m,m)x(m,D) matmul
                            with f32 accumulation (Pallas ``gossip_mix``
                            kernel when ``use_pallas=True``).
* :func:`mix_pairwise`    — one gather + lerp along the agent axis.
* :func:`global_merge`    — one mean-reduce broadcast back to all rows.
* :func:`merged`          — the averaged model as a (D,) panel.
* :func:`consensus_distance` — Xi_t in one pass (Pallas ``panel_reduce``
                            kernel when ``use_pallas=True``).

**Wire codecs.** Every communication op compresses its payload through the
pluggable codec subsystem (repro/wire): ``f32`` identity, ``bf16`` cast
(the original lever), ``int8``/``int8_ef`` per-row scales + stochastic
rounding (+ error feedback), ``int4``/``int4_ef`` packed nibbles with
grouped scales, and ``topk`` sparse innovations over a mirror panel. The
per-dtype-group policy lives on the spec (:func:`with_wire` — e.g.
embeddings stay bf16 while dense blocks go int8) and
:attr:`PanelSpec.wire_payload_bytes` / :attr:`wire_total_bytes` report
the codec-aware payload and payload+metadata wire cost; the legacy
``wire_dtype=`` argument on the mix ops survives as an explicit
per-call cast override. Stochastic codecs take an explicit ``key=``;
error feedback threads a residual panel via ``err=``. A ``delta_mix``
codec (topk) breaks the single W @ payload matmul: its sparse payload
reconstructs a mirror panel and the mix runs in damped delta form
``x + gamma (W - I) @ x̂`` — the first codec whose mixing cannot lower
to one dense MXU pass over the payload. The per-leaf
tree-map originals survive in core/gossip.py as ``*_tree`` — they remain
the right lowering when leaves carry heterogeneous shardings
(launch/dryrun.py pod meshes), and they are the parity oracle the panel
path is validated/benchmarked against (tests/test_panel_sharded.py,
benchmarks/panel_bench.py).

**Storage residency.** :attr:`PanelSpec.residency` (:func:`with_residency`)
carries the per-state-kind storage-codec policy (repro/residency): the
moment / merge-stat / EF-residual panels can live in HBM as bf16 or int8
(+ f32 scale sidecars) and be decoded to f32 only inside the fused round.
The spec owns the policy and the exact byte accounting
(:meth:`PanelSpec.storage_bytes`, :meth:`PanelSpec.sidecar_sharding`); the
encode/decode placement is the segment driver's (core/dsgd.py). The
fused ops here never see stored reps — they operate on the decoded view.

**Merge operators.** :attr:`PanelSpec.merger` (:func:`with_merger`) names
the operator GLOBAL rounds apply — uniform mean, weighted, inverse
variance, diagonal Fisher, TIES, SWA (repro/merging). 'uniform' keeps the
fused matmul path here bit-exact; non-uniform operators are dispatched by
the segment driver (dsgd.make_panel_segment) through
``merging.merge_panel``, which encodes the payload with the same wire
policy and broadcasts one merged row.

**Multi-device panels.** :func:`shard_spec` attaches a mesh and one
PartitionSpec per dtype group to the spec — rows over the ('pod','agent')
communication axes, the flat D columns over 'fsdp' (models/sharding.py:
``panel_pspec``). Every fused op then constrains its output to the group
sharding, so the mix lowers to per-fsdp-shard (m,m)x(m, D/fsdp) matmuls
whose collectives move only the LOCAL column shard (gossip traffic /fsdp
per device), and the consensus scalar finishes with a single cross-shard
reduce. The Pallas kernels are single-device bodies — a sharded spec
routes those ops through the plain-XLA path so SPMD can partition them.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import wire as wire_mod
from repro.kernels.gossip_mix import gossip_mix_panel
from repro.kernels.panel_reduce import panel_mean_consensus
from repro.telemetry.trace import scope


@dataclass(frozen=True)
class LeafSpec:
    group: str            # dtype-group key ('float32', 'bfloat16', ...)
    offset: int           # column offset inside the group panel
    size: int             # number of scalars per agent
    shape: Tuple[int, ...]  # per-agent (trailing) shape
    dtype: str            # leaf storage dtype name


@dataclass(frozen=True)
class PanelSpec:
    """Static description of a panelised pytree. Hashable — safe to close
    over in jitted functions or pass as a static argument.

    ``mesh``/``pspecs`` (set by :func:`shard_spec`) describe how each
    (m, D_g) group panel is laid out on a device mesh; unset means the
    single-device / fully-replicated layout."""
    treedef: object
    leaves: Tuple[LeafSpec, ...]
    groups: Tuple[Tuple[str, int], ...]  # (dtype key, group width D_g)
    rows: int = 0                        # m (agents); 0 on legacy specs
    mesh: Optional[jax.sharding.Mesh] = None
    pspecs: Tuple[Tuple[str, P], ...] = ()  # (dtype key, group PartitionSpec)
    wire: Tuple[Tuple[str, str], ...] = ()  # (dtype key, codec name) policy
    merger: str = "uniform"                 # merge operator (repro.merging)
    # (state kind, storage name) residency policy over the RESIDENT state
    # panels — 'moments' / 'stats' / 'wire_err' (repro.residency); params
    # always keep their native dtypes. () means everything stays f32
    # (with_residency drops explicit 'f32' entries so an f32 policy IS
    # the empty policy — byte-identical specs, byte-identical traces)
    residency: Tuple[Tuple[str, str], ...] = ()

    @property
    def width(self) -> int:
        """Total scalars per agent across all dtype groups."""
        return sum(w for _, w in self.groups)

    def wire_of(self, key: str) -> str:
        """Codec name for one dtype group ('f32' when no policy is set)."""
        for k, name in self.wire:
            if k == key:
                return name
        return "f32"

    @property
    def wire_payload_bytes(self) -> int:
        """Per-agent wire bytes of the quantized VALUES alone for one
        full-panel exchange: packed int4 nibbles pay D/2, int8 one byte
        per scalar, top-k only its k values — scale/index metadata
        excluded (see :attr:`wire_total_bytes`)."""
        return sum(
            wire_mod.get_codec(self.wire_of(k)).payload_bytes(1, w, k)
            for k, w in self.groups)

    @property
    def wire_total_bytes(self) -> int:
        """Per-agent wire bytes INCLUDING codec metadata — per-row int8
        scales, grouped int4 scales, packed top-k indices. This is what
        actually crosses the interconnect per exchange."""
        return sum(
            wire_mod.get_codec(self.wire_of(k)).total_bytes(1, w, k)
            for k, w in self.groups)

    @property
    def wire_bytes(self) -> int:
        """Back-compat alias of :attr:`wire_total_bytes` (codec-aware:
        an int8 group pays 1 byte/scalar + its per-row scale, a bf16 wire
        2 bytes/scalar, and only the f32 identity codec pays the storage
        itemsize)."""
        return self.wire_total_bytes

    def residency_of(self, kind: str) -> str:
        """Storage-codec name for one state-panel kind ('moments',
        'stats', 'wire_err'); 'f32' when no policy is set."""
        for k, name in self.residency:
            if k == kind:
                return name
        return "f32"

    def storage_bytes(self, kind: str, state_dtype: Optional[str] = None
                      ) -> int:
        """Exact per-agent resident HBM bytes of ONE state panel of
        ``kind`` under the residency policy, scale sidecars included.

        Storage codecs apply to f32 state only; a group whose state
        rides in another dtype (``state_dtype=None`` means the state
        mirrors each group's native dtype, as optimizer moments do) pays
        its plain itemsize. ``state_dtype='float32'`` models the panels
        that are f32 for EVERY group (merge stats, EF residuals)."""
        from repro import residency as residency_mod
        st = residency_mod.get_storage(self.residency_of(kind))
        total = 0
        for g, w in self.groups:
            dt = state_dtype or g
            if dt == "float32":
                total += st.resident_bytes(1, w)
            else:
                total += jnp.dtype(dt).itemsize * w
        return total

    @property
    def sharded(self) -> bool:
        return self.mesh is not None and bool(self.pspecs)

    def pspec(self, key: str) -> Optional[P]:
        for k, ps in self.pspecs:
            if k == key:
                return ps
        return None

    def sharding(self, key: str) -> Optional[NamedSharding]:
        """NamedSharding of one dtype group's (m, D_g) panel, or None."""
        ps = self.pspec(key)
        if self.mesh is None or ps is None:
            return None
        return NamedSharding(self.mesh, ps)

    def merged_sharding(self, key: str) -> Optional[NamedSharding]:
        """NamedSharding of a merged (D_g,) panel: column axes only."""
        ps = self.pspec(key)
        if self.mesh is None or ps is None:
            return None
        return NamedSharding(self.mesh, P(*ps[1:2]))

    def sidecar_sharding(self, key: str) -> Optional[NamedSharding]:
        """NamedSharding of a per-row storage sidecar (the int8 scale
        columns, (m, n_scales)): rows follow the group's agent axes, the
        tiny scale columns stay replicated (they don't divide by fsdp
        and aren't worth sharding)."""
        ps = self.pspec(key)
        if self.mesh is None or ps is None:
            return None
        return NamedSharding(self.mesh, P(ps[0]))


def make_spec(tree) -> PanelSpec:
    """Build the static spec for an agent-stacked pytree (leaves (m, ...))."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    offsets: dict = {}
    specs = []
    for x in leaves:
        key = jnp.dtype(x.dtype).name
        off = offsets.get(key, 0)
        size = int(np.prod(x.shape[1:], dtype=np.int64))
        specs.append(LeafSpec(group=key, offset=off, size=size,
                              shape=tuple(x.shape[1:]), dtype=key))
        offsets[key] = off + size
    groups = tuple(sorted(offsets.items()))
    rows = int(leaves[0].shape[0]) if leaves else 0
    return PanelSpec(treedef=treedef, leaves=tuple(specs), groups=groups,
                     rows=rows)


def shard_spec(spec: PanelSpec, mesh, row_axes=None, col_axes=None
               ) -> PanelSpec:
    """Attach a mesh + per-group PartitionSpecs to ``spec``.

    Rows go on the ('pod','agent') communication axes, columns on 'fsdp'
    (overridable); either is dropped per group when the dim does not divide
    by the axis size — that group stays replicated along it."""
    from repro.models.sharding import (PANEL_COL_AXES, PANEL_ROW_AXES,
                                       panel_pspec)
    row_axes = PANEL_ROW_AXES if row_axes is None else row_axes
    col_axes = PANEL_COL_AXES if col_axes is None else col_axes
    pspecs = tuple(
        (k, panel_pspec(mesh, spec.rows, w, row_axes, col_axes))
        for k, w in spec.groups)
    return replace(spec, mesh=mesh, pspecs=pspecs)


def with_wire(spec: PanelSpec, wire) -> PanelSpec:
    """Attach a wire-codec policy to ``spec``.

    ``wire`` is a codec name applied to EVERY dtype group (a
    ``repro.wire.CODECS`` key: 'f32', 'bf16', 'int8', 'int8_ef', 'int4',
    'int4_ef', 'topk'), or a {dtype-group: codec-name} dict for per-group
    policies (unlisted groups fall back to 'f32'); None clears the policy.
    Names are validated here so a typo fails at spec-build time, not
    mid-trace."""
    if wire is None:
        return replace(spec, wire=())
    if isinstance(wire, str):
        mapping = {k: wire for k, _ in spec.groups}
    else:
        unknown = set(wire) - {k for k, _ in spec.groups}
        if unknown:
            raise ValueError(
                f"wire policy names unknown dtype groups {sorted(unknown)}"
                f"; this spec's groups: {[k for k, _ in spec.groups]}")
        mapping = {k: wire.get(k, "f32") for k, _ in spec.groups}
    for name in mapping.values():
        wire_mod.get_codec(name)
    return replace(spec, wire=tuple(sorted(mapping.items())))


def with_residency(spec: PanelSpec, residency) -> PanelSpec:
    """Attach a storage-codec residency policy to ``spec``.

    ``residency`` is a {state-kind: storage-name} dict or a CLI policy
    string for ``residency.parse_policy`` ('moments=int8,stats=bf16', or
    a bare storage name for the moments); kinds are 'moments' / 'stats'
    / 'wire_err' (params always keep their native dtypes — compressing
    what the mixing matmul reads every round is a WIRE question), names
    are ``repro.residency.STORAGE`` keys ('f32', 'bf16', 'int8',
    'int8g', 'int8r'). Explicit 'f32' entries are dropped — the f32 policy IS the
    empty policy, so the resulting spec (and every trace keyed on it) is
    byte-identical to one that never saw a policy. None clears. Like
    with_merger, only registry NAMES can live on the hashable spec."""
    if residency is None:
        return replace(spec, residency=())
    from repro import residency as residency_mod
    mapping = residency_mod.parse_policy(residency)
    named = {}
    for kind, name in mapping.items():
        if not isinstance(name, str):
            raise ValueError(
                "with_residency takes registry NAMES (the spec stays "
                "hashable); register custom Storage instances in "
                "residency.STORAGE first")
        st = residency_mod.get_storage(name)
        if st.name != "f32":
            named[kind] = st.name
    return replace(spec, residency=tuple(sorted(named.items())))


def with_merger(spec: PanelSpec, merger) -> PanelSpec:
    """Attach a merge-operator name (repro.merging registry) to ``spec``:
    the operator every GLOBAL round applies (the paper's single final
    merging included). Validated here so a typo fails at spec-build time;
    None resets to 'uniform'. Custom Merger INSTANCES cannot live on the
    hashable spec — register them in ``merging.MERGERS`` or call
    ``merging.merge_panel`` directly."""
    if merger is None:
        return replace(spec, merger="uniform")
    from repro import merging as merging_mod
    resolved = merging_mod.get_merger(merger)
    if not isinstance(merger, str):
        raise ValueError(
            "with_merger takes a registry NAME (the spec stays hashable). "
            "To use a custom-configured instance, register it first — "
            f"merging.MERGERS['my_{resolved.name}'] = instance — and pass "
            "that name; the registry default under "
            f"{resolved.name!r} may carry different hyperparameters than "
            "your instance")
    return replace(spec, merger=resolved.name)


def place(x, ns: Optional[NamedSharding]):
    """Pin one array to a sharding. Inside a trace this is a
    with_sharding_constraint (the SPMD partitioner boundary); on concrete
    arrays it is a device_put (initialization / host-side resharding).
    Shared by the panel ops here and dsgd.init_state's tree placement."""
    if ns is None:
        return x
    if isinstance(x, jax.core.Tracer):
        return jax.lax.with_sharding_constraint(x, ns)
    return jax.device_put(x, ns)


def _constrain_group(x, spec: Optional[PanelSpec], key: str,
                     merged_panel: bool = False):
    if spec is None:
        return x
    return place(x, spec.merged_sharding(key) if merged_panel
                 else spec.sharding(key))


def shard_panel(panel, spec: PanelSpec):
    """Apply the spec's group shardings to an existing panel dict (used for
    optimizer-moment panels, which mirror the parameter panel layout)."""
    return {k: _constrain_group(x, spec, k) for k, x in panel.items()}


def to_panel(tree, spec: PanelSpec):
    """Flatten an agent-stacked pytree into {dtype: (m, D_dtype)} panels.
    On a sharded spec the group panels are pinned to their mesh layout."""
    leaves = jax.tree_util.tree_leaves(tree)
    m = leaves[0].shape[0]
    parts: dict = {}
    for x, ls in zip(leaves, spec.leaves):
        parts.setdefault(ls.group, []).append(x.reshape(m, ls.size))
    panel = {k: (fl[0] if len(fl) == 1 else jnp.concatenate(fl, axis=1))
             for k, fl in parts.items()}
    return shard_panel(panel, spec) if spec.sharded else panel


def from_panel(panel, spec: PanelSpec, cast: bool = True,
               leaf_shardings=None):
    """Rebuild the pytree from panels. Accepts (m, D) panels (stacked tree)
    or (D,) panels (a merged model — leaves drop the agent axis).
    ``cast=False`` keeps the panel dtype (e.g. the f32 merged model).
    ``leaf_shardings`` (a matching pytree of NamedSharding/PartitionSpec)
    re-pins each rebuilt leaf to its model-natural layout — the compute-side
    boundary of a D-sharded panel, whose flat columns cut across leaf dims."""
    outs = []
    for ls in spec.leaves:
        g = panel[ls.group]
        if g.ndim == 2:
            x = g[:, ls.offset:ls.offset + ls.size]
            x = x.reshape((g.shape[0],) + ls.shape)
        else:
            x = g[ls.offset:ls.offset + ls.size].reshape(ls.shape)
        outs.append(x.astype(ls.dtype) if cast else x)
    tree = jax.tree_util.tree_unflatten(spec.treedef, outs)
    if leaf_shardings is not None:
        tree = jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            leaf_shardings)
    return tree


# ------------------------------------------------------------ fused ops


def _codecs(panel, spec: Optional[PanelSpec], wire_dtype):
    """Effective codec per dtype group for one communication op: the
    explicit legacy ``wire_dtype`` argument wins (and refuses to combine
    with a spec policy — one compression authority per call); else the
    spec's wire policy; else the f32 identity.

    NOTE: _codecs/_wire_keys/_pallas_ok/_constrain_group are the
    engine-internal plumbing CONTRACT shared with repro/merging
    (merge_panel runs the same encode→reduce→broadcast round as
    global_merge); refactors here must keep those call sites in step."""
    if wire_dtype is not None:
        if spec is not None and spec.wire:
            raise ValueError("pass either wire_dtype= (legacy cast) or a "
                             "spec wire policy (with_wire), not both")
        c = wire_mod.dtype_codec(wire_dtype)
        return {k: c for k in panel}
    if spec is not None and spec.wire:
        return {k: wire_mod.get_codec(spec.wire_of(k)) for k in panel}
    f32 = wire_mod.CODECS["f32"]
    return {k: f32 for k in panel}


def _wire_keys(codecs, key):
    """One key per dtype group that needs one, folded in sorted-group
    order so sharded and replicated runs draw identical randomness."""
    names = sorted(k for k, c in codecs.items() if c.needs_key)
    if not names:
        return {k: None for k in codecs}
    if key is None:
        raise ValueError(f"wire codecs for groups {names} use stochastic "
                         "rounding and need an explicit key=")
    folded = {k: jax.random.fold_in(key, i) for i, k in enumerate(names)}
    return {k: folded.get(k) for k in codecs}


def _pallas_ok(use_pallas: bool, spec: Optional[PanelSpec]) -> bool:
    # Pallas kernel bodies are single-device programs; on a sharded spec the
    # op must stay plain XLA so the SPMD partitioner can split it into the
    # per-shard matmuls + local collectives this layout exists for.
    return use_pallas and not (spec is not None and spec.sharded)


def _mix_dense_groups(panel, W, *, wire_dtype, use_pallas, block_d,
                      interpret, spec, key, err, with_mean):
    """Shared body of mix_dense / mix_dense_mean. Returns (mixed, means,
    new_err); means/new_err are None unless requested.

    ``with_mean`` augments W with a 1^T/m row so the column mean comes out
    of the SAME matmul (the MXU pass the mix already pays): for any
    doubly-stochastic W the mean of the transmitted panel IS the mean of
    the mixed panel, so the consensus monitor no longer needs its own
    mean reduce. On a sharded spec the (m+1)-row product cannot shard
    over the agent axes, so the mean falls back to a separate fsdp-local
    reduce there. The first m output rows are bit-identical to the
    unaugmented matmul either way (row-independent dot products).

    Idle ROWS of W (rows equal to the identity row — e.g. unmatched
    agents inside a random matching) communicate nothing, so under a
    lossy codec those agents' params and EF residuals are restored
    exactly after the matmul: no codec may touch a row that never hits
    the wire. (The folded mean is the mean of the TRANSMITTED panel, so
    it deviates from the restored panel's mean by at most one
    quantization step per idle row — monitor-precision only.)"""
    m = W.shape[0]
    W32 = W.astype(jnp.float32)
    pallas = _pallas_ok(use_pallas, spec)
    codecs = _codecs(panel, spec, wire_dtype)
    keys = _wire_keys(codecs, key)
    lossy = any(not isinstance(c, wire_mod.F32Codec)
                for c in codecs.values())
    idle_rows = (jnp.all(W == jnp.eye(m, dtype=W.dtype), axis=1)[:, None]
                 if lossy else None)
    fold = with_mean and not (spec is not None and spec.sharded)
    Wop = (jnp.concatenate([W32, jnp.full((1, m), 1.0 / m, jnp.float32)])
           if fold else W32)

    mixed, means = {}, ({} if with_mean else None)
    new_err = {} if err is not None else None
    for k, x in panel.items():
        e = err[k] if err is not None else None
        with scope(f"wire.encode.{k}"):
            xw, back, ne = codecs[k].encode(x, key=keys[k], err=e,
                                            use_pallas=pallas,
                                            interpret=interpret)
        if getattr(codecs[k], "delta_mix", False):
            # sparse-innovation codecs (topk): xw is the updated MIRROR
            # panel and the mix runs in CHOCO's damped delta form
            # x + gamma (W - I) @ x̂ — a round trips through a
            # scatter-reconstructed mirror + one delta matmul instead of
            # the single dense W @ payload MXU pass (a sparse payload
            # mixed as W @ Q(x) would zero every untransmitted
            # coordinate, and an undamped pull on a stale mirror
            # diverges — see TopKCodec.gamma). Doubly-stochastic W
            # preserves the column mean EXACTLY for any gamma: the
            # sparsification error lives in the per-agent deviations
            # only, so the eventual global merge absorbs it. The
            # consensus mean is read off the mixed panel itself: the
            # transmitted mirror never enters the mean.
            x32 = x.astype(jnp.float32)
            Wd = W32 - jnp.eye(m, dtype=jnp.float32)
            if pallas:
                d32 = gossip_mix_panel(Wd, xw, block_d=block_d,
                                       interpret=interpret)
            else:
                d32 = Wd @ xw.astype(jnp.float32)
            gamma = getattr(codecs[k], "gamma", 1.0)
            y32 = x32 + gamma * d32.astype(jnp.float32)
            with scope(f"wire.decode.{k}"):
                yb = back(y32)
            if with_mean:
                mu = jnp.mean(y32, axis=0)
                if not fold:
                    mu = _constrain_group(mu, spec, k, merged_panel=True)
            if idle_rows is not None:
                yb = jnp.where(idle_rows, x, yb)
                if e is not None:
                    ne = jnp.where(idle_rows, e, ne)
            mixed[k] = _constrain_group(yb, spec, k)
            if with_mean:
                means[k] = mu
            if err is not None:
                new_err[k] = _constrain_group(ne, spec, k)
            continue
        # the Pallas kernel stores its output in the payload dtype, which
        # would round the folded mean row for non-f32 payloads — those
        # groups skip the augmented row (no wasted kernel work) and take
        # one plain f32 mean of the transmitted panel instead (the same
        # quantity for doubly-stochastic W, at XLA-fold precision)
        fold_k = fold and not (pallas and xw.dtype != jnp.float32)
        Wk = Wop if fold_k else W32
        if pallas:
            y = gossip_mix_panel(Wk, xw, block_d=block_d,
                                 interpret=interpret)
            if fold_k:
                y, mu = y[:m], y[m].astype(jnp.float32)
        else:
            y32 = Wk @ xw.astype(jnp.float32)
            if fold_k:
                y32, mu = y32[:m], y32[m]
            y = y32.astype(xw.dtype)
        if fold and not fold_k:
            mu = jnp.mean(xw.astype(jnp.float32), axis=0)
        with scope(f"wire.decode.{k}"):
            yb = back(y)
        if idle_rows is not None:
            yb = jnp.where(idle_rows, x, yb)
            if e is not None:
                ne = jnp.where(idle_rows, e, ne)
        mixed[k] = _constrain_group(yb, spec, k)
        if with_mean:
            if not fold:
                mu = _constrain_group(
                    jnp.mean(xw.astype(jnp.float32), axis=0), spec, k,
                    merged_panel=True)
            means[k] = mu
        if err is not None:
            new_err[k] = _constrain_group(ne, spec, k)
    return mixed, means, new_err


@scope("panel.mix")
def mix_dense(panel, W, *, wire_dtype=None, use_pallas: bool = False,
              block_d: int = 512, interpret: bool = True,
              spec: Optional[PanelSpec] = None, key=None, err=None):
    """Theta <- W Theta: one f32-accumulating matmul per dtype group.

    With a sharded ``spec`` the output is constrained to the group layout,
    so each fsdp shard runs its own (m,m)x(m, D_g/fsdp) matmul and the
    cross-agent collective carries only that shard's columns. The payload
    is compressed per the spec's wire policy (or the legacy ``wire_dtype``
    cast); stochastic codecs need ``key=``. Passing ``err=`` (the
    error-feedback residual panel, {group: (m, D_g) f32}) switches the
    return to ``(mixed, new_err)``."""
    mixed, _, new_err = _mix_dense_groups(
        panel, W, wire_dtype=wire_dtype, use_pallas=use_pallas,
        block_d=block_d, interpret=interpret, spec=spec, key=key, err=err,
        with_mean=False)
    return mixed if err is None else (mixed, new_err)


@scope("panel.mix_mean")
def mix_dense_mean(panel, W, *, wire_dtype=None, use_pallas: bool = False,
                   block_d: int = 512, interpret: bool = True,
                   spec: Optional[PanelSpec] = None, key=None, err=None):
    """mix_dense with the consensus mean folded into the mixing matmul.

    Returns ``(mixed, mean, new_err)`` — mean is {group: (D_g,) f32}, the
    column mean of the mixed panel (exact for doubly-stochastic W), ready
    for :func:`consensus_from_mean`; new_err is None when ``err`` is."""
    return _mix_dense_groups(
        panel, W, wire_dtype=wire_dtype, use_pallas=use_pallas,
        block_d=block_d, interpret=interpret, spec=spec, key=key, err=err,
        with_mean=True)


@scope("panel.mix_pairwise")
def mix_pairwise(panel, partner, weight=0.5, *, wire_dtype=None,
                 spec: Optional[PanelSpec] = None, key=None, err=None):
    """theta_k <- (1-w) theta_k + w theta_{partner[k]}: one gather + lerp
    per dtype group. partner[k] == k means agent k idles this round —
    idle rows keep their EXACT parameters (and error-feedback residual):
    nothing travels their wire, so no codec may touch them.
    Wire codecs as in :func:`mix_dense` (err= switches the return to
    ``(mixed, new_err)``)."""
    codecs = _codecs(panel, spec, wire_dtype)
    keys = _wire_keys(codecs, key)
    m = next(iter(panel.values())).shape[0]
    idle = (partner == jnp.arange(m))[:, None]

    def one(k, x):
        e = err[k] if err is not None else None
        xw, back, ne = codecs[k].encode(x, key=keys[k], err=e)
        peer = jnp.take(xw, partner, axis=0)
        if getattr(codecs[k], "delta_mix", False):
            # mirror codecs exchange in damped delta form: pull toward
            # the partner's mirror, keep the untransmitted rest of x
            gamma = getattr(codecs[k], "gamma", 1.0)
            mixed = back(x.astype(jnp.float32)
                         + gamma * weight * (peer - xw))
        else:
            mixed = back((1.0 - weight) * xw + weight * peer)
        y = jnp.where(idle, x, mixed)
        if e is not None:
            ne = jnp.where(idle, e, ne)
        return _constrain_group(y, spec, k), ne

    out = {k: one(k, x) for k, x in panel.items()}
    mixed = {k: v[0] for k, v in out.items()}
    if err is None:
        return mixed
    return mixed, {k: _constrain_group(v[1], spec, k)
                   for k, v in out.items()}


@scope("panel.global_merge")
def global_merge(panel, *, wire_dtype=None,
                 spec: Optional[PanelSpec] = None, key=None, err=None):
    """theta_k <- mean_l theta_l: one mean-reduce + broadcast per group.
    Sharded: an all-reduce over the agent axes per fsdp column shard.
    Wire codecs as in :func:`mix_dense` — EXCEPT delta (mirror) codecs:
    a sparse payload cannot sync a one-shot merge, so the global merge
    is their FULL-BANDWIDTH round by design (the paper's point is to
    concentrate the budget into the single global merging): the exact
    panel travels, the merge is bit-identical to the uncompressed one,
    and the mirror is reset to the post-merge state."""
    codecs = _codecs(panel, spec, wire_dtype)
    keys = _wire_keys(codecs, key)

    def one(k, x):
        e = err[k] if err is not None else None
        if getattr(codecs[k], "delta_mix", False):
            if e is None:
                raise ValueError(
                    f"codec '{codecs[k].name}' carries a mirror panel and "
                    "needs it (err=...)")
            x32 = x.astype(jnp.float32)
            y32 = jnp.broadcast_to(
                jnp.mean(x32, axis=0, keepdims=True), x32.shape)
            return (_constrain_group(y32.astype(x.dtype), spec, k), y32)
        xw, back, ne = codecs[k].encode(x, key=keys[k], err=e)
        mean = jnp.mean(xw.astype(jnp.float32), axis=0, keepdims=True)
        y = back(jnp.broadcast_to(mean, xw.shape).astype(xw.dtype))
        return _constrain_group(y, spec, k), ne

    out = {k: one(k, x) for k, x in panel.items()}
    mixed = {k: v[0] for k, v in out.items()}
    if err is None:
        return mixed
    return mixed, {k: _constrain_group(v[1], spec, k)
                   for k, v in out.items()}


def _live_weights(live, m):
    """(m,) f32 convex weights over the live rows (all-dead guards to a
    zero vector rather than NaN)."""
    lf = live.astype(jnp.float32)
    return lf / jnp.maximum(jnp.sum(lf), 1.0)


@scope("panel.merged")
def merged(panel, *, use_pallas: bool = False, block_d: int = 512,
           interpret: bool = True, spec: Optional[PanelSpec] = None,
           live=None):
    """The (counterfactual) averaged model as {dtype: (D_dtype,)} f32.

    ``live`` ((m,) bool) restricts the mean to the live rows — the
    elastic-run merge, where a dead agent's stale row must not pollute
    the average. The masked path is plain XLA (the Pallas reduce kernel
    is unmasked)."""
    if live is not None:
        w = _live_weights(live, next(iter(panel.values())).shape[0])
        return {k: _constrain_group(
            jnp.tensordot(w, x.astype(jnp.float32), axes=1), spec, k,
            merged_panel=True) for k, x in panel.items()}
    if _pallas_ok(use_pallas, spec):
        return {k: panel_mean_consensus(x, block_d=block_d,
                                        interpret=interpret)[0]
                for k, x in panel.items()}
    return {k: _constrain_group(jnp.mean(x.astype(jnp.float32), axis=0),
                                spec, k, merged_panel=True)
            for k, x in panel.items()}


def merged_tree(panel, spec: PanelSpec):
    """Averaged model as a (non-stacked) pytree with f32 leaves — the panel
    equivalent of gossip.merged_model."""
    return from_panel(merged(panel, spec=spec), spec, cast=False)


@scope("panel.consensus")
def consensus_distance(panel, *, use_pallas: bool = False,
                       block_d: int = 512, interpret: bool = True,
                       spec: Optional[PanelSpec] = None, live=None):
    """Xi_t = sqrt((1/m) sum_k ||theta_k - bar||^2) in one fused pass.
    Sharded: per-shard partial sums of squares + ONE scalar reduce.

    ``live`` ((m,) bool) computes the consensus of the LIVE rows only —
    mean and deviations both restricted, normalized by the live count
    (dead agents' stale rows are not part of the run's consensus)."""
    m = next(iter(panel.values())).shape[0]
    total = jnp.zeros((), jnp.float32)
    if live is not None:
        lf = live.astype(jnp.float32)
        n = jnp.maximum(jnp.sum(lf), 1.0)
        for x in panel.values():
            x32 = x.astype(jnp.float32)
            mean = jnp.tensordot(lf / n, x32, axes=1)
            total = total + jnp.sum(
                lf[:, None] * jnp.square(x32 - mean[None]))
        return jnp.sqrt(total / n)
    pallas = _pallas_ok(use_pallas, spec)
    for x in panel.values():
        if pallas:
            _, sq = panel_mean_consensus(x, block_d=block_d,
                                         interpret=interpret)
        else:
            x32 = x.astype(jnp.float32)
            mean = jnp.mean(x32, axis=0, keepdims=True)
            sq = jnp.sum(jnp.square(x32 - mean))
        total = total + sq
    return jnp.sqrt(total / m)


@scope("panel.consensus")
def consensus_from_mean(panel, means):
    """Xi_t from a PRECOMPUTED column-mean panel ({group: (D_g,) f32},
    e.g. the folded row of :func:`mix_dense_mean`): one deviation pass,
    no second mean reduce over the panel."""
    m = next(iter(panel.values())).shape[0]
    total = jnp.zeros((), jnp.float32)
    for k, x in panel.items():
        x32 = x.astype(jnp.float32)
        total = total + jnp.sum(jnp.square(x32 - means[k][None]))
    return jnp.sqrt(total / m)


def panel_norm(panel, axis_mean: bool = False, rows=None):
    """Global l2 norm of the panel (f32). With ``axis_mean`` the rows are
    averaged first (norm of the agent-mean, e.g. for grad-norm metrics);
    ``rows`` ((m,) f32 convex weights, e.g. the live mask's
    :func:`_live_weights`) replaces the uniform mean with a weighted
    one — the grad norm of an elastic round averages live agents only."""
    total = jnp.zeros((), jnp.float32)
    for x in panel.values():
        x32 = x.astype(jnp.float32)
        if axis_mean:
            if rows is None:
                x32 = jnp.mean(x32, axis=0)
            else:
                x32 = jnp.tensordot(rows, x32, axes=1)
        total = total + jnp.sum(jnp.square(x32))
    return jnp.sqrt(total)


