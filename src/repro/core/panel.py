"""Flat-panel parameter engine: the fused communication layer.

Agent-stacked pytrees (every leaf (m, ...)) are flattened ONCE into a
*panel*: a dict ``{dtype_name: (m, D_dtype) array}`` — one row per agent,
one column per scalar parameter — described by a static :class:`PanelSpec`
(per-leaf offsets/shapes/dtypes). Grouping by dtype preserves every leaf's
storage dtype exactly (``jnp.concatenate`` over mixed-dtype leaves would
silently promote bf16 to f32 and double the wire bytes).

All communication primitives then become ONE fused op per dtype group over
the panel instead of one op per pytree leaf:

* :func:`mix_dense`       — Theta <- W Theta, a single (m,m)x(m,D) matmul
                            with f32 accumulation (Pallas ``gossip_mix``
                            kernel when ``use_pallas=True``).
* :func:`mix_pairwise`    — one gather + lerp along the agent axis.
* :func:`global_merge`    — one mean-reduce broadcast back to all rows.
* :func:`merged`          — the averaged model as a (D,) panel.
* :func:`consensus_distance` — Xi_t in one pass (Pallas ``panel_reduce``
                            kernel when ``use_pallas=True``).

``wire_dtype`` casts a group's payload for the communication only (the
beyond-paper bf16-wire compression lever). The per-leaf tree-map originals
survive in core/gossip.py as ``*_tree`` — they remain the right lowering
when leaves carry heterogeneous shardings (launch/dryrun.py pod meshes),
and they are the baseline the panel path is benchmarked against
(benchmarks/panel_bench.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gossip_mix import gossip_mix_panel
from repro.kernels.panel_reduce import panel_mean_consensus


@dataclass(frozen=True)
class LeafSpec:
    group: str            # dtype-group key ('float32', 'bfloat16', ...)
    offset: int           # column offset inside the group panel
    size: int             # number of scalars per agent
    shape: Tuple[int, ...]  # per-agent (trailing) shape
    dtype: str            # leaf storage dtype name


@dataclass(frozen=True)
class PanelSpec:
    """Static description of a panelised pytree. Hashable — safe to close
    over in jitted functions or pass as a static argument."""
    treedef: object
    leaves: Tuple[LeafSpec, ...]
    groups: Tuple[Tuple[str, int], ...]  # (dtype key, group width D_g)

    @property
    def width(self) -> int:
        """Total scalars per agent across all dtype groups."""
        return sum(w for _, w in self.groups)

    @property
    def wire_bytes(self) -> int:
        """Per-agent payload bytes of one full-panel exchange."""
        return sum(w * jnp.dtype(k).itemsize for k, w in self.groups)


def make_spec(tree) -> PanelSpec:
    """Build the static spec for an agent-stacked pytree (leaves (m, ...))."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    offsets: dict = {}
    specs = []
    for x in leaves:
        key = jnp.dtype(x.dtype).name
        off = offsets.get(key, 0)
        size = int(np.prod(x.shape[1:], dtype=np.int64))
        specs.append(LeafSpec(group=key, offset=off, size=size,
                              shape=tuple(x.shape[1:]), dtype=key))
        offsets[key] = off + size
    groups = tuple(sorted(offsets.items()))
    return PanelSpec(treedef=treedef, leaves=tuple(specs), groups=groups)


def to_panel(tree, spec: PanelSpec):
    """Flatten an agent-stacked pytree into {dtype: (m, D_dtype)} panels."""
    leaves = jax.tree_util.tree_leaves(tree)
    m = leaves[0].shape[0]
    parts: dict = {}
    for x, ls in zip(leaves, spec.leaves):
        parts.setdefault(ls.group, []).append(x.reshape(m, ls.size))
    return {k: (fl[0] if len(fl) == 1 else jnp.concatenate(fl, axis=1))
            for k, fl in parts.items()}


def from_panel(panel, spec: PanelSpec, cast: bool = True):
    """Rebuild the pytree from panels. Accepts (m, D) panels (stacked tree)
    or (D,) panels (a merged model — leaves drop the agent axis).
    ``cast=False`` keeps the panel dtype (e.g. the f32 merged model)."""
    outs = []
    for ls in spec.leaves:
        g = panel[ls.group]
        if g.ndim == 2:
            x = g[:, ls.offset:ls.offset + ls.size]
            x = x.reshape((g.shape[0],) + ls.shape)
        else:
            x = g[ls.offset:ls.offset + ls.size].reshape(ls.shape)
        outs.append(x.astype(ls.dtype) if cast else x)
    return jax.tree_util.tree_unflatten(spec.treedef, outs)


# ------------------------------------------------------------ fused ops


def _wire(x, wire_dtype):
    if wire_dtype is None or x.dtype == wire_dtype:
        return x, lambda y: y
    return x.astype(wire_dtype), lambda y: y.astype(x.dtype)


def mix_dense(panel, W, *, wire_dtype=None, use_pallas: bool = False,
              block_d: int = 512, interpret: bool = True):
    """Theta <- W Theta: one f32-accumulating matmul per dtype group."""
    W32 = W.astype(jnp.float32)

    def one(x):
        xw, back = _wire(x, wire_dtype)
        if use_pallas:
            y = gossip_mix_panel(W32, xw, block_d=block_d,
                                 interpret=interpret)
        else:
            y = (W32 @ xw.astype(jnp.float32)).astype(xw.dtype)
        return back(y)

    return {k: one(x) for k, x in panel.items()}


def mix_pairwise(panel, partner, weight=0.5, *, wire_dtype=None):
    """theta_k <- (1-w) theta_k + w theta_{partner[k]}: one gather + lerp
    per dtype group. partner[k] == k means agent k idles this round."""
    def one(x):
        xw, back = _wire(x, wire_dtype)
        peer = jnp.take(xw, partner, axis=0)
        return back((1.0 - weight) * xw + weight * peer)

    return {k: one(x) for k, x in panel.items()}


def global_merge(panel, *, wire_dtype=None):
    """theta_k <- mean_l theta_l: one mean-reduce + broadcast per group."""
    def one(x):
        xw, back = _wire(x, wire_dtype)
        mean = jnp.mean(xw.astype(jnp.float32), axis=0, keepdims=True)
        return back(jnp.broadcast_to(mean, xw.shape).astype(xw.dtype))

    return {k: one(x) for k, x in panel.items()}


def merged(panel, *, use_pallas: bool = False, block_d: int = 512,
           interpret: bool = True):
    """The (counterfactual) averaged model as {dtype: (D_dtype,)} f32."""
    if use_pallas:
        return {k: panel_mean_consensus(x, block_d=block_d,
                                        interpret=interpret)[0]
                for k, x in panel.items()}
    return {k: jnp.mean(x.astype(jnp.float32), axis=0)
            for k, x in panel.items()}


def merged_tree(panel, spec: PanelSpec):
    """Averaged model as a (non-stacked) pytree with f32 leaves — the panel
    equivalent of gossip.merged_model."""
    return from_panel(merged(panel), spec, cast=False)


def consensus_distance(panel, *, use_pallas: bool = False,
                       block_d: int = 512, interpret: bool = True):
    """Xi_t = sqrt((1/m) sum_k ||theta_k - bar||^2) in one fused pass."""
    m = next(iter(panel.values())).shape[0]
    total = jnp.zeros((), jnp.float32)
    for x in panel.values():
        if use_pallas:
            _, sq = panel_mean_consensus(x, block_d=block_d,
                                         interpret=interpret)
        else:
            x32 = x.astype(jnp.float32)
            mean = jnp.mean(x32, axis=0, keepdims=True)
            sq = jnp.sum(jnp.square(x32 - mean))
        total = total + sq
    return jnp.sqrt(total / m)


def panel_norm(panel, axis_mean: bool = False):
    """Global l2 norm of the panel (f32). With ``axis_mean`` the rows are
    averaged first (norm of the agent-mean, e.g. for grad-norm metrics)."""
    total = jnp.zeros((), jnp.float32)
    for x in panel.values():
        x32 = x.astype(jnp.float32)
        if axis_mean:
            x32 = jnp.mean(x32, axis=0)
        total = total + jnp.sum(jnp.square(x32))
    return jnp.sqrt(total)


