"""Global merging operators and counterfactual evaluation (paper §4.2-4.3).

Tree-level entry points over the panel-native merge-operator subsystem
(repro/merging): :func:`merge_stacked` merges an agent-stacked pytree
under any registered operator (the oracle the engine-internal path is
tested against), :func:`counterfactual_eval` evaluates the hypothetical
merged model without touching training state (Fig. 2c's light-blue
curve — ``launch/train.py --eval-merged-every``), and
:func:`gossip_merge_rounds` approximates the final merging with a
scanned, codec-aware segment of gossip rounds (Appendix C.3.4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import merging as merging_mod
from repro import wire as wire_mod
from repro.core import panel as panel_mod
from repro.core.gossip import merged_model


def weighted_merge(params_stacked, weights):
    """sum_k w_k theta_k with convex weights (Def. 2's general merge)."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)
    return jax.tree.map(
        lambda x: jnp.tensordot(w, x.astype(jnp.float32), axes=1), params_stacked)


def uniform_merge(params_stacked):
    return merged_model(params_stacked)


def merge_stacked(params_stacked, merger="uniform", stats=None,
                  weights=None, live=None):
    """The merged (non-stacked, f32-leaf) model of an agent-stacked tree
    under a named merge operator (repro.merging) — the tree-path oracle
    of the segment engine's global rounds.

    ``stats`` are the operator's statistics PANELS
    (``{stat_name: {dtype-group: (m, D_g) f32}}`` — e.g.
    ``state["merge_stat"]`` from the panel engine; statistics live in
    panel layout because they are engine state). ``weights`` is the
    per-agent (m,) weight vector of the 'weighted' operator. ``live``
    ((m,) bool) merges the live agents only (an elastic run's final
    merge must not average in dead agents' stale rows)."""
    spec = panel_mod.make_spec(params_stacked)
    return merged_panel_tree(panel_mod.to_panel(params_stacked, spec),
                             spec, merger=merger, stats=stats,
                             weights=weights, live=live)


def counterfactual_eval(eval_fn, params_stacked, merger="uniform",
                        stats=None, weights=None, live=None):
    """Evaluate the hypothetical globally-merged model WITHOUT modifying
    training state (the light-blue curve of Fig. 2c), under any merge
    operator (``stats``/``weights`` as in :func:`merge_stacked`).

    Tree-level (replicated state / oracle use). For the engine's
    (possibly mesh-sharded) panel state use
    :func:`counterfactual_eval_panel` — re-panelising a sharded panel
    through a fresh unsharded spec inside jit miscompiles on meshes with
    an idle 'model' axis (unreduced replication doubles the values; the
    engine-spec path below keeps every op constrained)."""
    return eval_fn(merge_stacked(params_stacked, merger=merger,
                                 stats=stats, weights=weights, live=live))


def merged_panel_tree(panel, spec, merger=None, stats=None, weights=None,
                      live=None):
    """Merged (non-stacked, f32-leaf) model of an ENGINE panel under the
    spec's (or an explicit) operator — the panel-layout counterpart of
    :func:`merge_stacked`. Every op stays constrained to the spec's mesh
    layout, so this is safe to jit on sharded panel states (see
    :func:`counterfactual_eval`)."""
    mg = merging_mod.get_merger(spec.merger if merger is None else merger)
    stats = merging_mod.decode_stats(stats, spec)
    row = mg.merge_row(panel, stats=stats, weights=weights, spec=spec,
                       live=live)
    return panel_mod.from_panel(row, spec, cast=False)


def counterfactual_eval_panel(eval_fn, panel, spec, merger=None,
                              stats=None, weights=None, live=None):
    """:func:`counterfactual_eval` for the engine's panel state
    (``stats`` = ``state["merge_stat"]``): evaluates the hypothetical
    merged model without modifying the panel — what
    ``launch/train.py --eval-merged-every`` measures."""
    return eval_fn(merged_panel_tree(panel, spec, merger=merger,
                                     stats=stats, weights=weights,
                                     live=live))


def gossip_merge_rounds(params_stacked, sampler, rounds: int, rng,
                        wire=None, key=None, return_xi: bool = False):
    """Approximate the final global merging by multiple rounds of gossip
    on a (e.g. exponential) topology — paper Appendix C.3.4.

    Panelises once, samples every W^(t) up front (host side), and SCANS
    the fused FOLDED-MEAN mix (panel.mix_dense_mean — the engine's round
    primitive; its first m rows are bit-identical to plain mix_dense)
    over the stacked (rounds, m, m) matrices in ONE jitted dispatch —
    instead of the old host loop of per-round ``mix_dense`` dispatches
    that also bypassed the wire policy. ``wire`` names a codec from
    repro.wire for the gossip payload (stochastic codecs need ``key=``;
    error-feedback codecs are refused — this stateless approximation
    path carries no residual). ``return_xi=True`` additionally returns
    the per-round consensus-distance trace (rounds,) read off the folded
    mean — how fast the approximation is converging to the true merge."""
    spec = panel_mod.make_spec(params_stacked)
    if wire is not None:
        if wire_mod.get_codec(wire).error_feedback:
            raise ValueError(
                f"codec '{wire}' needs an error-feedback residual, which "
                "this stateless approximation path cannot carry; use the "
                "panel engine (dsgd.make_panel_segment) or 'int8'")
        spec = panel_mod.with_wire(spec, wire)
    Ws = jnp.asarray(np.stack([np.asarray(sampler(t, rng), np.float32)
                               for t in range(rounds)]))
    needs_key = any(wire_mod.get_codec(name).needs_key
                    for _, name in spec.wire)
    if needs_key and key is None:
        raise ValueError(
            f"wire codec '{wire}' uses stochastic rounding and needs an "
            "explicit key= for the scanned gossip rounds")
    keys = jax.random.split(key, rounds) if needs_key else None
    pan, xis = _scanned_gossip(spec)(
        panel_mod.to_panel(params_stacked, spec), (Ws, keys))
    out = panel_mod.from_panel(pan, spec)
    return (out, xis) if return_xi else out


@functools.lru_cache(maxsize=64)
def _scanned_gossip(spec):
    """Jitted folded-mean gossip scan, cached on the (hashable) spec so
    repeated gossip_merge_rounds calls (figures.py sweeps k) reuse one
    traced function instead of recompiling a fresh lambda per call."""

    def body(pan, xs):
        W, k = xs
        mixed, mean, _ = panel_mod.mix_dense_mean(pan, W, spec=spec, key=k)
        return mixed, panel_mod.consensus_from_mean(mixed, mean)

    return jax.jit(lambda pan, xs: jax.lax.scan(body, pan, xs))
