"""Global merging operators and counterfactual evaluation (paper §4.2-4.3)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gossip import merged_model


def weighted_merge(params_stacked, weights):
    """sum_k w_k theta_k with convex weights (Def. 2's general merge)."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)
    return jax.tree.map(
        lambda x: jnp.tensordot(w, x.astype(jnp.float32), axes=1), params_stacked)


def uniform_merge(params_stacked):
    return merged_model(params_stacked)


def counterfactual_eval(eval_fn, params_stacked):
    """Evaluate the hypothetical globally-averaged model WITHOUT modifying
    training state (the light-blue curve of Fig. 2c)."""
    return eval_fn(merged_model(params_stacked))


def gossip_merge_rounds(params_stacked, sampler, rounds: int, rng):
    """Approximate the final global merging by multiple rounds of gossip on
    a (e.g. exponential) topology — paper Appendix C.3.4. Panelises once,
    mixes all rounds on the panel, unpanelises once."""
    from repro.core import panel as panel_mod
    spec = panel_mod.make_spec(params_stacked)
    pan = panel_mod.to_panel(params_stacked, spec)
    for t in range(rounds):
        W = sampler(t, rng)
        pan = panel_mod.mix_dense(pan, jnp.asarray(W, jnp.float32))
    return panel_mod.from_panel(pan, spec)
