from repro.data.dirichlet import dirichlet_partition  # noqa: F401
from repro.data.synthetic import (SyntheticClassification,  # noqa: F401
                                  SyntheticLM, make_agent_batches)
