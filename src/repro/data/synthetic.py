"""Synthetic datasets with controllable heterogeneity.

Two families (both run on CPU at paper-validation scale):

* :class:`SyntheticClassification` — gaussian-blob classification; labels are
  Dirichlet-partitioned across agents, mirroring the paper's CIFAR/TinyIN
  setup. Used by the benchmarks that reproduce Figures 1/2.
* :class:`SyntheticLM` — per-domain Markov-chain token streams; each agent's
  domain mixture is Dirichlet-skewed, giving non-IID next-token statistics.
  Used by LM training examples.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.data.dirichlet import dirichlet_partition


@dataclass
class SyntheticClassification:
    num_classes: int = 10
    dim: int = 32
    n_train: int = 8192
    n_test: int = 2048
    margin: float = 2.0
    noise: float = 1.2
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.centers = rng.normal(size=(self.num_classes, self.dim))
        self.centers *= self.margin / np.linalg.norm(
            self.centers, axis=1, keepdims=True)

        def draw(n):
            y = rng.integers(0, self.num_classes, size=n)
            x = self.centers[y] + self.noise * rng.normal(size=(n, self.dim))
            return x.astype(np.float32), y.astype(np.int32)

        self.x_train, self.y_train = draw(self.n_train)
        self.x_test, self.y_test = draw(self.n_test)

    def partition(self, num_agents: int, alpha: float, seed: int = 0):
        rng = np.random.default_rng(seed)
        return dirichlet_partition(self.y_train, num_agents, alpha, rng,
                                   min_per_agent=8)


@dataclass
class SyntheticLM:
    vocab: int = 256
    num_domains: int = 8
    order_skew: float = 4.0
    seed: int = 0
    _trans: Optional[np.ndarray] = field(default=None, repr=False)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # per-domain Markov transition matrices concentrated on a domain-
        # specific token subset => strongly domain-skewed statistics
        self._trans = np.empty((self.num_domains, self.vocab, self.vocab),
                               np.float32)
        for d in range(self.num_domains):
            conc = np.full(self.vocab, 0.05)
            lo = (d * self.vocab) // self.num_domains
            hi = ((d + 1) * self.vocab) // self.num_domains
            conc[lo:hi] = self.order_skew
            self._trans[d] = rng.dirichlet(conc, size=self.vocab)

    def domain_mixtures(self, num_agents: int, alpha: float, seed: int = 0):
        rng = np.random.default_rng(seed)
        return rng.dirichlet([alpha] * self.num_domains, size=num_agents)

    def sample(self, domain_probs, batch: int, seq_len: int,
               rng: np.random.Generator):
        """Sample (batch, seq_len+1) token streams from a domain mixture."""
        doms = rng.choice(self.num_domains, size=batch, p=domain_probs)
        out = np.empty((batch, seq_len + 1), np.int32)
        out[:, 0] = rng.integers(0, self.vocab, size=batch)
        for t in range(seq_len):
            probs = self._trans[doms, out[:, t]]
            cum = probs.cumsum(axis=1)
            u = rng.random((batch, 1))
            out[:, t + 1] = (u < cum).argmax(axis=1)
        return out


def make_agent_batches(ds: SyntheticClassification, partitions: List[np.ndarray],
                       batch: int, rng: np.random.Generator):
    """One (m, batch, ...) step of per-agent classification batches."""
    xs, ys = [], []
    for ids in partitions:
        pick = rng.choice(ids, size=batch, replace=len(ids) < batch)
        xs.append(ds.x_train[pick])
        ys.append(ds.y_train[pick])
    return np.stack(xs), np.stack(ys)


def make_agent_lm_batches(lm: SyntheticLM, mixtures, batch: int,
                          seq_len: int, rng: np.random.Generator):
    toks = np.stack([lm.sample(mix, batch, seq_len, rng) for mix in mixtures])
    return {"tokens": toks[:, :, :-1], "targets": toks[:, :, 1:],
            "mask": np.ones(toks[:, :, 1:].shape, np.float32)}
