"""Dirichlet label-skew partitioning (Hsu et al. 2019; paper Appendix C.1).

Each agent k draws a class-mixture q_k ~ Dir(alpha * 1); examples are
assigned to agents proportionally to q_k per class. Small alpha => highly
non-IID (some agents see only a few classes), the regime where the paper's
single-global-merging effect is most dramatic.
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, num_agents: int, alpha: float,
                        rng: np.random.Generator, min_per_agent: int = 1):
    """Returns a list of index arrays, one per agent."""
    labels = np.asarray(labels)
    classes = np.unique(labels)
    agent_idx = [[] for _ in range(num_agents)]
    for c in classes:
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        # proportions over agents for this class
        props = rng.dirichlet([alpha] * num_agents)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for k, part in enumerate(np.split(idx, cuts)):
            agent_idx[k].extend(part.tolist())
    out = []
    for k in range(num_agents):
        ids = np.array(sorted(agent_idx[k]), dtype=np.int64)
        if len(ids) < min_per_agent:  # guarantee non-empty agents
            extra = rng.integers(0, len(labels), size=min_per_agent - len(ids))
            ids = np.concatenate([ids, extra])
        out.append(ids)
    return out


def heterogeneity(partitions, labels, num_classes) -> float:
    """Mean total-variation distance between agent label dists and global."""
    labels = np.asarray(labels)
    glob = np.bincount(labels, minlength=num_classes) / len(labels)
    tvs = []
    for ids in partitions:
        if len(ids) == 0:
            continue
        loc = np.bincount(labels[ids], minlength=num_classes) / len(ids)
        tvs.append(0.5 * np.abs(loc - glob).sum())
    return float(np.mean(tvs))
