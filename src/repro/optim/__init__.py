from repro.optim.optim import (Optimizer, adamw, make_optimizer, sgd,  # noqa: F401
                               cosine_schedule, constant_schedule,
                               warmup_cosine)
