"""Optimizers as pure pytree transforms (SGD/momentum, AdamW) + LR schedules.

Implemented from scratch (no optax in this environment). All transforms are
vmap-compatible: core/dsgd.py vmaps ``update`` over the leading agent axis so
every agent maintains an independent optimizer state, as required by
decentralized learning (Algorithm 1 of the paper, "Optimizer" line).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


# ------------------------------------------------------------- LR schedules


def constant_schedule(lr):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr, total_steps, final_frac=0.1):
    def f(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.asarray(lr * (final_frac + (1 - final_frac) * cos),
                           jnp.float32)
    return f


def warmup_cosine(lr, total_steps, warmup=100, final_frac=0.1):
    cos = cosine_schedule(lr, total_steps, final_frac)
    def f(step):
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        return w * cos(jnp.maximum(step - warmup, 0))
    return f


# ---------------------------------------------------------------- optimizers


@dataclass(frozen=True)
class Optimizer:
    init: Callable  # params -> state
    update: Callable  # (grads, state, params, step) -> (new_params, new_state)
    name: str = ""
    # state keys holding per-parameter MOMENT pytrees (the (m, D)-panel
    # state a residency policy may store quantized; scalars like
    # step_count are excluded). The segment driver routes exactly these
    # keys through the storage view — ``update`` itself always sees the
    # decoded panels, so optimizers stay storage-agnostic.
    moment_keys: tuple = ()
    # elementwise update math, (g, m, v, p, *, lr, bc1, bc2) ->
    # (p, m, v), shared verbatim by ``update`` and the fused Pallas
    # kernel (kernels/opt_fused.py) so both paths run the identical
    # floating-point expression. None when no fused form exists.
    core: Callable = None
    # (count, step=None) -> (lr, bc1, bc2) hyperparameter schedule,
    # mirroring ``update``'s step bookkeeping; accepts vector counts so
    # the fused path can feed per-agent step_count rows (they diverge
    # after RESYNC).
    hyper: Callable = None


def sgd(schedule, momentum: float = 0.0, weight_decay: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    sched = schedule if callable(schedule) else constant_schedule(schedule)

    def init(params):
        if momentum == 0.0:
            return {"step_count": jnp.zeros((), jnp.int32)}
        return {"mu": jax.tree.map(jnp.zeros_like, params),
                "step_count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step=None):
        step = state["step_count"] if step is None else step
        lr = sched(step)
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads,
                                 params)
        if momentum == 0.0:
            new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
            return new_params, {"step_count": state["step_count"] + 1}
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        upd = (jax.tree.map(lambda m, g: momentum * m + g, mu, grads)
               if nesterov else mu)
        new_params = jax.tree.map(lambda p, u: p - lr * u, params, upd)
        return new_params, {"mu": mu, "step_count": state["step_count"] + 1}

    return Optimizer(init=init, update=update, name="sgd",
                     moment_keys=("mu",) if momentum else ())


def adamw_core(g, m, v, p, *, lr, bc1, bc2, b1=0.9, b2=0.999, eps=1e-8,
               weight_decay: float = 0.0):
    """Elementwise AdamW step: (grad, moments, param) -> (param, moments).

    Pure jnp arithmetic on same-shape arrays (``lr``/``bc1``/``bc2``
    broadcast — scalars on the tree path, (m, 1) per-agent columns in the
    fused kernel). Both the pytree ``update`` and the fused int8 kernel
    call exactly this function, so the two paths are the same
    floating-point expression by construction.
    """
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * jnp.square(g)
    mhat = m / bc1
    vhat = v / bc2
    p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
    return p, m, v


def adamw(schedule, b1=0.9, b2=0.999, eps=1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    sched = schedule if callable(schedule) else constant_schedule(schedule)

    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params),
                "step_count": jnp.zeros((), jnp.int32)}

    def core(g, m, v, p, *, lr, bc1, bc2):
        return adamw_core(g, m, v, p, lr=lr, bc1=bc1, bc2=bc2, b1=b1, b2=b2,
                          eps=eps, weight_decay=weight_decay)

    def hyper(count, step=None):
        step = count if step is None else step + 1
        lr = sched(step - 1)
        c = count.astype(jnp.float32)
        return lr, 1 - b1 ** c, 1 - b2 ** c

    def update(grads, state, params, step=None):
        count = state["step_count"] + 1
        lr, bc1, bc2 = hyper(count, step)
        res = jax.tree.map(
            lambda g, m_, v_, p: core(g, m_, v_, p, lr=lr, bc1=bc1, bc2=bc2),
            grads, state["m"], state["v"], params)
        new_params, m, v = jax.tree.transpose(
            jax.tree.structure(params), jax.tree.structure((0, 0, 0)), res)
        return new_params, {"m": m, "v": v, "step_count": count}

    return Optimizer(init=init, update=update, name="adamw",
                     moment_keys=("m", "v"), core=core, hyper=hyper)


def make_optimizer(name: str, lr, total_steps: int = 1000,
                   weight_decay: float = 5e-4, momentum: float = 0.9,
                   schedule: str = "constant") -> Optimizer:
    sched = {"constant": constant_schedule(lr),
             "cosine": cosine_schedule(lr, total_steps),
             "warmup_cosine": warmup_cosine(lr, total_steps)}[schedule]
    if name == "sgd":
        return sgd(sched, momentum=momentum, weight_decay=weight_decay)
    if name == "adamw":
        return adamw(sched, weight_decay=weight_decay)
    raise ValueError(name)
