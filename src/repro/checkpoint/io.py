"""Versioned, manifest-based checkpointing for panel train states.

Blob format (``FORMAT_VERSION`` 2, msgpack): a map with

* ``version`` — this format version,
* ``meta``    — a JSON-encoded bytes blob of host-side metadata (JSON,
  not msgpack, because a numpy PCG64 bit-generator state carries
  128-bit integers that msgpack cannot represent),
* ``payload`` — the msgpack-encoded flat array table
  ``{key-path: {dtype: name, shape, data}}`` (dtype by NAME so bf16 and
  the other ml_dtypes round-trip),
* ``crc``     — CRC-32 over ``meta`` + ``payload``; a torn or corrupted
  file fails the checksum and raises :class:`CheckpointCorruptError`.

Writes are atomic (tmp file + fsync + ``os.replace``), so a crash
mid-save never leaves a torn checkpoint at the target path. The legacy
pre-versioned format (a bare flat array table) still restores, as do
version-1 blobs.

Version 2 marks the first format carrying residency STORAGE panels
(repro.residency): a quantized state leaf is a nested ``{q, scale}``
dict whose int8 codes and f32 scale sidecars land in the flat array
table as ordinary keyed arrays — the packed bytes are saved DIRECTLY
(an int8 moment panel costs ~1/4 of its f32 decode), and restore
rebuilds the stored representation bit-exactly, so ``--resume`` under
any storage codec continues the exact quantized trajectory. The table
schema itself is unchanged from v1 (dtype-by-name already covers int8
and bf16), so v1 readers of plain states and v2 readers of v1 blobs
interoperate; the bump records that stored-layout states exist.

:class:`Checkpointer` manages a DIRECTORY of ``step_*.ckpt`` files plus
a ``MANIFEST.json`` (fingerprint of the run configuration + the ordered
checkpoint list): retention of the last ``keep`` checkpoints,
background-thread async commits off a caller-thread host snapshot
(donation-safe: the device buffers are copied to host before ``save``
returns), and :meth:`restore_latest` with automatic fallback to the
previous good checkpoint when the newest one is corrupt.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time
import warnings
import zlib

import jax
import msgpack
import numpy as np

FORMAT_VERSION = 2
# every blob version this build restores (2 = residency storage panels;
# the array-table schema is identical, see the module docstring)
READABLE_VERSIONS = (1, 2)
MANIFEST_NAME = "MANIFEST.json"
_STEP_FILE = re.compile(r"step_(\d+)\.ckpt$")


class CheckpointCorruptError(RuntimeError):
    """A checkpoint file failed its checksum or could not be decoded."""


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _resolve_dtype(name: str) -> np.dtype:
    """dtype from its stored name; ml_dtypes names (bfloat16, float8_*)
    are not numpy-native and resolve through the ml_dtypes registry."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _flatten_to_host(tree) -> dict:
    """{key-path: host ndarray}. np.asarray COPIES device buffers to
    host, so the snapshot survives later donation of the live state."""
    return {_key_str(kp): np.asarray(leaf)
            for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]}


def _pack_blob(flat: dict, meta) -> tuple:
    payload = msgpack.packb(
        {k: {"dtype": np.dtype(a.dtype).name, "shape": list(a.shape),
             "data": a.tobytes()} for k, a in flat.items()})
    meta_bytes = json.dumps(meta if meta is not None else {}).encode()
    crc = zlib.crc32(meta_bytes + payload) & 0xFFFFFFFF
    blob = msgpack.packb({"version": FORMAT_VERSION, "meta": meta_bytes,
                          "crc": crc, "payload": payload})
    return blob, crc


def _unpack_blob(raw: bytes) -> tuple:
    """(flat array table, meta dict); CheckpointCorruptError on any
    decode/checksum failure. A map without a 'version' key is the legacy
    flat format (no meta, no checksum)."""
    try:
        obj = msgpack.unpackb(raw)
    except Exception as exc:
        raise CheckpointCorruptError(
            f"undecodable checkpoint: {exc}") from None
    if not isinstance(obj, dict):
        raise CheckpointCorruptError("checkpoint is not a msgpack map")
    if "version" not in obj:
        return obj, {}
    if obj["version"] not in READABLE_VERSIONS:
        raise CheckpointCorruptError(
            f"unsupported checkpoint format version {obj['version']!r} "
            f"(this build reads {list(READABLE_VERSIONS)})")
    try:
        meta_bytes, payload = obj["meta"], obj["payload"]
    except KeyError as exc:
        raise CheckpointCorruptError(
            f"checkpoint missing section {exc}") from None
    if zlib.crc32(meta_bytes + payload) & 0xFFFFFFFF != obj.get("crc"):
        raise CheckpointCorruptError(
            "checksum mismatch (torn or corrupted write)")
    try:
        return msgpack.unpackb(payload), json.loads(meta_bytes.decode())
    except Exception as exc:
        raise CheckpointCorruptError(
            f"undecodable checkpoint sections: {exc}") from None


def _rebuild(flat: dict, like):
    """Writable arrays in the structure of ``like``; errors name the
    offending key on missing/extra keys and shape/dtype drift."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves, used = [], set()
    for kp, ref in paths:
        key = _key_str(kp)
        if key not in flat:
            raise KeyError(f"checkpoint missing key '{key}'")
        used.add(key)
        rec = flat[key]
        dtype = _resolve_dtype(rec["dtype"])
        shape = tuple(rec["shape"])
        ref_shape = tuple(np.shape(ref))
        ref_dtype = np.dtype(getattr(ref, "dtype", np.asarray(ref).dtype))
        if shape != ref_shape:
            raise ValueError(
                f"checkpoint key '{key}' has shape {shape}, the "
                f"reference tree expects {ref_shape}")
        if dtype != ref_dtype:
            raise ValueError(
                f"checkpoint key '{key}' has dtype {dtype.name}, the "
                f"reference tree expects {ref_dtype.name}")
        # .copy(): frombuffer views are read-only and would break
        # donation/in-place update downstream
        leaves.append(np.frombuffer(rec["data"], dtype=dtype)
                      .reshape(shape).copy())
    extra = sorted(set(flat) - used)
    if extra:
        raise ValueError(
            f"checkpoint carries keys the reference tree does not: "
            f"{extra} (stale or mismatched checkpoint?)")
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _atomic_write(path: str, blob: bytes) -> None:
    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


# reserved meta key recording the residency policy whose stored-layout
# panels the blob carries ({kind: storage name}); written only when the
# caller passes residency=, so user meta dicts round-trip untouched
RESIDENCY_META_KEY = "_residency_policy"


def _stamp_residency(meta, residency):
    if residency is None:
        return meta
    meta = dict(meta) if meta else {}
    meta[RESIDENCY_META_KEY] = {str(k): str(v)
                                for k, v in dict(residency).items()}
    return meta


def check_residency(meta, expected) -> None:
    """Refuse a stored-layout restore under the wrong residency policy.

    A v2 blob's quantized panels are raw int8 codes + scales; rebuilding
    them into an engine whose ``--residency`` names a DIFFERENT storage
    would decode those bits with the wrong codec (or, structure
    permitting, treat them as plain arrays) and silently corrupt the
    trajectory. Compares the blob's recorded policy against
    ``expected`` ({kind: storage name}) over the union of kinds (a kind
    absent from a policy is the f32 identity) and raises ValueError
    naming every mismatched kind. Blobs predating the stamp (no
    recorded policy) pass — structure drift still trips ``_rebuild``'s
    keyed errors."""
    if expected is None:
        return
    recorded = (meta or {}).get(RESIDENCY_META_KEY)
    if recorded is None:
        return
    expected = {str(k): str(v) for k, v in dict(expected).items()}
    bad = []
    for kind in sorted(set(recorded) | set(expected)):
        got = recorded.get(kind, "f32")
        want = expected.get(kind, "f32")
        if got != want:
            bad.append(f"{kind}: checkpoint stores '{got}', engine "
                       f"configured '{want}'")
    if bad:
        raise ValueError(
            "checkpoint residency policy does not match the engine's "
            "--residency; restoring would decode stored panels with the "
            "wrong codec (" + "; ".join(bad) + ")")


def save(path: str, tree, meta=None, residency=None) -> None:
    """Atomic single-file save (versioned format; ``meta`` is any
    JSON-serializable host-side dict riding next to the arrays).
    ``residency`` ({kind: storage name}) stamps the policy whose
    stored-layout panels the blob carries, enabling the restore-side
    mismatch guard (:func:`check_residency`)."""
    blob, _ = _pack_blob(_flatten_to_host(tree),
                         _stamp_residency(meta, residency))
    _atomic_write(path, blob)


def restore(path: str, like, with_meta: bool = False,
            expect_residency=None):
    """Rebuild ``like``'s structure from a checkpoint file (writable
    arrays). Raises CheckpointCorruptError on torn/corrupt files,
    KeyError/ValueError naming the offending key on structure drift;
    ``expect_residency`` ({kind: storage name}) additionally refuses a
    blob stamped with a different residency policy
    (:func:`check_residency`)."""
    with open(path, "rb") as f:
        raw = f.read()
    flat, meta = _unpack_blob(raw)
    check_residency(meta, expect_residency)
    tree = _rebuild(flat, like)
    return (tree, meta) if with_meta else tree


class Checkpointer:
    """Retention + manifest + async commit over a checkpoint directory.

    ``fingerprint`` (a flat JSON-serializable dict describing the run
    configuration) guards resumes: reopening a non-empty directory with
    a different fingerprint raises, naming the differing keys.

    ``save(step, tree, meta, block=False)`` snapshots the device state
    to host ON THE CALLER THREAD (so the caller may immediately donate
    the live buffers) and packs/writes on a background thread; the next
    ``save``/``wait``/``restore_latest`` joins it and re-raises any
    stored error.

    ``events`` may be set to a :class:`repro.telemetry.EventLog`; saves
    then record operational ``checkpoint_save`` lines (step, bytes, wall
    time) in its wall-clock SIDECAR — never the deterministic stream,
    whose byte-identity across baseline/resumed runs checkpointing must
    not break.
    """

    def __init__(self, directory: str, keep: int = 3, fingerprint=None,
                 events=None, residency=None):
        self.directory = os.path.abspath(directory)
        os.makedirs(self.directory, exist_ok=True)
        self.keep = int(keep)
        if self.keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.fingerprint = fingerprint
        self.events = events
        # {kind: storage name} of the run's residency policy: stamped
        # into every save's meta and enforced by restore_latest
        self.residency = dict(residency) if residency else None
        self._thread = None
        self._error = None
        self._manifest = self._load_manifest()
        if fingerprint is not None and self._manifest["checkpoints"]:
            old = self._manifest.get("fingerprint") or {}
            diff = sorted(k for k in set(old) | set(fingerprint)
                          if old.get(k) != fingerprint.get(k))
            if diff:
                raise ValueError(
                    f"checkpoint directory {self.directory} belongs to a "
                    f"different run configuration; differing keys: {diff}")

    # ------------------------------------------------------- manifest
    def _manifest_path(self) -> str:
        return os.path.join(self.directory, MANIFEST_NAME)

    def _load_manifest(self) -> dict:
        try:
            with open(self._manifest_path(), "r") as f:
                man = json.load(f)
            if isinstance(man, dict) and isinstance(
                    man.get("checkpoints"), list):
                return man
        except (OSError, ValueError):
            pass
        return {"version": FORMAT_VERSION, "fingerprint": None,
                "checkpoints": []}

    # ----------------------------------------------------------- save
    def save(self, step: int, tree, meta=None, block: bool = True) -> None:
        self.wait()
        flat = _flatten_to_host(tree)
        if block:
            self._commit(int(step), flat, meta)
            return
        self._thread = threading.Thread(
            target=self._commit_guarded, args=(int(step), flat, meta),
            daemon=True)
        self._thread.start()

    def _commit_guarded(self, step, flat, meta):
        try:
            self._commit(step, flat, meta)
        except BaseException as exc:  # re-raised from wait()
            self._error = exc

    def _commit(self, step, flat, meta):
        t0 = time.perf_counter()
        blob, crc = _pack_blob(flat, _stamp_residency(meta, self.residency))
        fname = f"step_{step:08d}.ckpt"
        _atomic_write(os.path.join(self.directory, fname), blob)
        if self.events is not None:  # sidecar-only (emit_op is thread-safe)
            self.events.emit_op("checkpoint_save", step=int(step),
                                bytes=len(blob),
                                dt=time.perf_counter() - t0)
        ckpts = [c for c in self._manifest["checkpoints"]
                 if c["step"] != step]
        ckpts.append({"step": step, "file": fname, "bytes": len(blob),
                      "crc": crc})
        ckpts.sort(key=lambda c: c["step"])
        while len(ckpts) > self.keep:
            old = ckpts.pop(0)
            try:
                os.remove(os.path.join(self.directory, old["file"]))
            except OSError:
                pass
        self._manifest["checkpoints"] = ckpts
        if self.fingerprint is not None:
            self._manifest["fingerprint"] = self.fingerprint
        _atomic_write(self._manifest_path(),
                      json.dumps(self._manifest, indent=1).encode())

    def wait(self) -> None:
        """Join a pending async commit; re-raise its error, if any."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            exc, self._error = self._error, None
            raise exc

    # -------------------------------------------------------- restore
    def latest_step(self):
        cks = self._manifest["checkpoints"]
        return cks[-1]["step"] if cks else None

    def restore_latest(self, like):
        """(step, tree, meta) from the newest GOOD checkpoint, or None.

        Scans the manifest plus any on-disk ``step_*.ckpt`` orphans
        (e.g. a checkpoint whose manifest update was lost), newest
        first; a corrupt/torn file warns (RuntimeWarning) and falls back
        to the previous one. A residency-policy mismatch
        (:func:`check_residency` against this Checkpointer's
        ``residency``) raises instead of falling back: every sibling
        checkpoint carries the same stamp, and silently resuming from an
        older blob would hide the misconfiguration."""
        self.wait()
        cands = {c["file"]: c["step"]
                 for c in self._manifest["checkpoints"]}
        try:
            names = os.listdir(self.directory)
        except OSError:
            names = []
        for fn in names:
            mobj = _STEP_FILE.fullmatch(fn)
            if mobj and fn not in cands:
                cands[fn] = int(mobj.group(1))
        for fn, step in sorted(cands.items(), key=lambda kv: -kv[1]):
            path = os.path.join(self.directory, fn)
            try:
                tree, meta = restore(path, like, with_meta=True,
                                     expect_residency=self.residency)
            except FileNotFoundError:
                continue
            except CheckpointCorruptError as exc:
                warnings.warn(
                    f"checkpoint {fn} is corrupt ({exc}); falling back "
                    "to the previous good checkpoint", RuntimeWarning,
                    stacklevel=2)
                continue
            return step, tree, meta
        return None
