"""Minimal checkpointing: pytrees -> msgpack (+ raw array payloads).

No external deps beyond msgpack (installed). Arrays are stored as
(dtype, shape, bytes) triples keyed by their flattened key path; restore
rebuilds into the structure of a reference pytree.
"""
from __future__ import annotations

import os

import jax
import msgpack
import numpy as np


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(path: str, tree) -> None:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        flat[_key_str(kp)] = {
            "dtype": arr.dtype.str, "shape": list(arr.shape),
            "data": arr.tobytes()}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "wb") as f:
        f.write(msgpack.packb(flat))


def restore(path: str, like):
    with open(path, "rb") as f:
        flat = msgpack.unpackb(f.read())
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kp, ref in paths:
        key = _key_str(kp)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        rec = flat[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        leaves.append(arr.reshape(rec["shape"]))
    return jax.tree_util.tree_unflatten(treedef, leaves)
