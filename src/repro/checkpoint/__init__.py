from repro.checkpoint.io import (  # noqa: F401
    CheckpointCorruptError,
    Checkpointer,
    restore,
    save,
)
