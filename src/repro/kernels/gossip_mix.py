"""Pallas TPU kernel for gossip parameter mixing: Theta_out = W @ Theta.

The hot loop of the paper's communication step once gathered parameters are
on-chip: a skinny (m x m) mixing matrix applied to a huge (m x D) parameter
panel. TPU adaptation: D is tiled into MXU-aligned VMEM blocks
(block_d columns); W (tiny) is resident per grid step; accumulation in f32.
The wrapper flattens any parameter pytree into a (m, D) panel, pads D to the
block size, and unflattens after mixing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix_kernel(w_ref, t_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)  # (m, m)
    t = t_ref[...].astype(jnp.float32)  # (m, block_d)
    o_ref[...] = jnp.dot(w, t, preferred_element_type=jnp.float32).astype(
        o_ref.dtype)


def gossip_mix_panel(W, theta, *, block_d: int = 512, interpret: bool = True):
    """W: (n, m); theta: (m, D) -> W @ theta, D tiled into VMEM blocks.

    n == m for a plain mixing matrix; the consensus-folded path passes
    n == m + 1 (W augmented with a 1^T/m row, see panel.mix_dense_mean)
    and reads the column mean off the extra output row."""
    n, m = W.shape
    D = theta.shape[1]
    block_d = min(block_d, D)
    pad = (-D) % block_d
    if pad:
        theta = jnp.pad(theta, ((0, 0), (0, pad)))
    Dp = D + pad
    nd = Dp // block_d
    out = pl.pallas_call(
        _mix_kernel,
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((n, m), lambda i: (0, 0)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, Dp), theta.dtype),
        interpret=interpret,
    )(W, theta)
    return out[:, :D]
