"""Pallas TPU kernels for the panel-native merge operators (repro/merging).

The heavy per-coordinate reductions of a global merge round on an (m, D)
parameter panel:

* :func:`weighted_colmerge` — precision-weighted column merge
  ``out_j = sum_k w_kj x_kj / sum_k w_kj`` with a per-coordinate weight
  panel (inverse-variance and diagonal-Fisher merging; the weights are
  cheap XLA elementwise transforms of the stat panels, the reduction over
  agents is the bandwidth-bound pass that belongs in the kernel).
* :func:`ties_colmerge` — the TIES merge body: per-row magnitude trim of
  the deviation panel, per-column sign election over the survivors, and
  the agreeing (disjoint) mean. The per-row trim THRESHOLDS are computed
  outside (``kernels/ref.py: ties_thresh_ref`` — a row quantile needs a
  full pass over D before any block can trim, exactly like the int8
  scales in ``kernels/wire_quant.py``).

TPU adaptation mirrors kernels/panel_reduce.py: D is tiled into VMEM
blocks (``block_d`` columns), the tiny (m, 1) per-row sidecar (thresholds)
is resident per grid step, math in f32 on the VPU. Columns are
independent, so there is no cross-block accumulation. Zero-padded tail
columns are sliced off after the call (a padded weighted column divides
0/0 — the NaN never escapes the discarded slice).

Both kernels are bit-identical to the ``kernels/ref.py`` oracles
(tests/test_merge_props.py); sharded specs keep the plain-XLA oracle path
so SPMD can partition the reduction, mirroring the other panel kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _weighted_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)            # (m, block_d)
    w = w_ref[...].astype(jnp.float32)            # (m, block_d)
    num = jnp.sum(w * x, axis=0, keepdims=True)   # (1, block_d)
    den = jnp.sum(w, axis=0, keepdims=True)
    o_ref[...] = num / den


def _ties_kernel(t_ref, th_ref, o_ref):
    t = t_ref[...].astype(jnp.float32)            # (m, block_d)
    keep = jnp.abs(t) >= th_ref[...]              # (m, 1) thresholds
    tk = jnp.where(keep, t, 0.0)
    col = jnp.sum(tk, axis=0, keepdims=True)
    s = jnp.where(col >= 0.0, 1.0, -1.0)          # elected sign (ties -> +)
    agree = (tk * s) > 0.0
    cnt = jnp.sum(agree.astype(jnp.float32), axis=0, keepdims=True)
    dev = jnp.sum(jnp.where(agree, tk, 0.0), axis=0, keepdims=True)
    o_ref[...] = jnp.where(cnt > 0.0, dev / jnp.maximum(cnt, 1.0), 0.0)


def _pad_cols(x, block_d):
    m, D = x.shape
    pad = (-D) % block_d
    return (jnp.pad(x, ((0, 0), (0, pad))) if pad else x), D + pad


def weighted_colmerge(x, w, *, block_d: int = 512, interpret: bool = True):
    """x: (m, D) panel; w: (m, D) per-coordinate weights -> (D,) f32
    weighted column merge sum_k w_kj x_kj / sum_k w_kj.

    Callers keep the denominator positive by folding their eps into w
    (the merge operators add it to the variance/Fisher stat)."""
    m, D = x.shape
    block_d = min(block_d, D)
    xp, Dp = _pad_cols(x, block_d)
    wp, _ = _pad_cols(w, block_d)
    nd = Dp // block_d
    data_spec = pl.BlockSpec((m, block_d), lambda i: (0, i))
    out = pl.pallas_call(
        _weighted_kernel,
        grid=(nd,),
        in_specs=[data_spec, data_spec],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Dp), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[0, :D]


def ties_colmerge(tau, thresh, *, block_d: int = 512,
                  interpret: bool = True):
    """tau: (m, D) deviation panel; thresh: (m, 1) f32 per-row trim
    thresholds (kernels/ref.py: ties_thresh_ref) -> (D,) f32 sign-elected
    agreeing mean of the trimmed deviations (0 where nothing survives)."""
    m, D = tau.shape
    block_d = min(block_d, D)
    tp, Dp = _pad_cols(tau, block_d)
    nd = Dp // block_d
    out = pl.pallas_call(
        _ties_kernel,
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Dp), jnp.float32),
        interpret=interpret,
    )(tp, thresh)
    return out[0, :D]
