"""Pallas TPU kernel for fused panel statistics: column mean + consensus.

The monitoring half of the communication layer: given the (m, D) parameter
panel, one pass over the D axis produces BOTH the merged (averaged) model
``mean_j = (1/m) sum_k theta[k, j]`` and the consensus sum of squares
``sum_{k,j} (theta[k, j] - mean_j)^2`` (Xi_t^2 * m). The per-leaf tree-map
path re-reads every parameter twice (once for the mean, once for the
deviation); this kernel reads each VMEM block once and accumulates the
scalar across sequential grid steps.

TPU adaptation: D is tiled into VMEM blocks; the scalar accumulator is a
(1, 1) output block that every grid step maps to — TPU grids execute
sequentially, so read-modify-write accumulation across steps is safe
(initialised at step 0 via ``pl.when``). Zero-padding of the last block is
harmless: padded columns have mean 0 and deviation 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _reduce_kernel(t_ref, mean_ref, acc_ref):
    i = pl.program_id(0)
    t = t_ref[...].astype(jnp.float32)             # (m, block_d)
    mu = jnp.mean(t, axis=0, keepdims=True)        # (1, block_d)
    mean_ref[...] = mu
    sq = jnp.sum(jnp.square(t - mu))

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += sq


def panel_mean_consensus(theta, *, block_d: int = 512,
                         interpret: bool = True):
    """theta: (m, D) -> (mean (D,) f32, sq scalar f32).

    ``sq`` is the total squared deviation sum_{k,j} (theta_kj - mean_j)^2;
    the consensus distance Xi is sqrt(sq / m).
    """
    m, D = theta.shape
    block_d = min(block_d, D)
    pad = (-D) % block_d
    if pad:
        theta = jnp.pad(theta, ((0, 0), (0, pad)))
    Dp = D + pad
    nd = Dp // block_d
    mean, acc = pl.pallas_call(
        _reduce_kernel,
        grid=(nd,),
        in_specs=[pl.BlockSpec((m, block_d), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((1, block_d), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, Dp), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(theta)
    return mean[0, :D], acc[0, 0]
