"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True, window=None,
                  scale=None):
    """q,k,v: (B, S, H, hd) (same H; GQA is expanded by the wrapper).

    Returns (B, S, H, hd). Masking: causal and/or sliding window."""
    B, S, H, hd = q.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    scores = jnp.where(ok[None, None], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", attn, v)


def gossip_mix_ref(W, theta):
    """W: (m, m); theta: (m, D) -> W @ theta in f32 accumulation."""
    return (W.astype(jnp.float32) @ theta.astype(jnp.float32)).astype(theta.dtype)


def panel_mean_consensus_ref(theta):
    """theta: (m, D) -> (column mean (D,) f32, total squared deviation).

    Oracle for kernels/panel_reduce.py: mean_j = (1/m) sum_k theta_kj and
    sq = sum_{k,j} (theta_kj - mean_j)^2 (= m * Xi^2)."""
    t = theta.astype(jnp.float32)
    mean = jnp.mean(t, axis=0)
    sq = jnp.sum(jnp.square(t - mean[None]))
    return mean, sq


def int8_scale_ref(x):
    """Per-row symmetric int8 scale for an (m, D) panel: amax_k / 127 in
    f32, with all-zero rows mapped to scale 1/127 so dequantization is
    always a plain multiply."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1, keepdims=True)
    return jnp.where(amax > 0, amax, 1.0) / 127.0


def quantize_int8_ref(x, scale, u=None):
    """x: (m, D); scale: (m, 1) f32 -> int8 values in [-127, 127].

    Oracle for kernels/wire_quant.py. ``u`` (same shape as x, uniform in
    [0, 1)) selects stochastic rounding floor(x/scale + u) — unbiased in
    expectation over u; ``u=None`` rounds to nearest (ties to even,
    matching jnp.round). The clip guards the float boundary rows where
    x/scale lands an ulp outside +/-127."""
    s = x.astype(jnp.float32) / scale
    q = jnp.floor(s + u) if u is not None else jnp.round(s)
    return jnp.clip(q, -127.0, 127.0).astype(jnp.int8)


def dequantize_int8_ref(q, scale):
    """q: (m, D) int8; scale: (m, 1) f32 -> f32 panel q * scale."""
    return q.astype(jnp.float32) * scale


def int4_group_scale_ref(x, group: int = 128):
    """Grouped symmetric int4 scales for an (m, D) panel: one amax/7 scale
    per row per ``group``-column block -> (m, ceil(D/group)) f32. A
    partial tail group reduces over its real columns only; all-zero
    groups map to scale 1/7 (dequantization stays a plain multiply)."""
    m, D = x.shape
    gn = -(-D // group)
    pad = gn * group - D
    mag = jnp.abs(x.astype(jnp.float32))
    if pad:
        mag = jnp.pad(mag, ((0, 0), (0, pad)))
    amax = jnp.max(mag.reshape(m, gn, group), axis=2)
    return jnp.where(amax > 0, amax, 1.0) / 7.0


def expand_group_scale(scale, D: int, group: int = 128):
    """(m, ceil(D/group)) grouped scales -> (m, D): each scale repeated
    over its column group (tail group truncated to the real width)."""
    return jnp.repeat(scale, group, axis=1)[:, :D]


def quantize_int4_ref(x, scale, u=None, group: int = 128):
    """x: (m, D); scale: (m, ceil(D/group)) f32 -> int8 values in [-7, 7]
    (the int4 staging dtype before nibble packing).

    Oracle for kernels/wire_quant.py:quantize_int4_panel. ``u`` (same
    shape as x, uniform [0, 1)) selects stochastic rounding
    floor(x/scale + u); ``u=None`` rounds to nearest."""
    s = x.astype(jnp.float32) / expand_group_scale(scale, x.shape[1], group)
    q = jnp.floor(s + u) if u is not None else jnp.round(s)
    return jnp.clip(q, -7.0, 7.0).astype(jnp.int8)


def dequantize_int4_ref(q, scale, group: int = 128):
    """q: (m, D) int4-valued int8; scale: (m, ceil(D/group)) f32 -> f32."""
    return (q.astype(jnp.float32)
            * expand_group_scale(scale, q.shape[1], group))


def int8_group_scale_ref(x, group: int = 128):
    """Grouped symmetric int8 scales for an (m, D) panel: one amax/127
    scale per row per ``group``-column block -> (m, ceil(D/group)) f32
    (the int4 grouped-scale layout at int8 range — the 'int8g' storage
    codec). Partial tail groups reduce over their real columns only;
    all-zero groups map to scale 1/127."""
    m, D = x.shape
    gn = -(-D // group)
    pad = gn * group - D
    mag = jnp.abs(x.astype(jnp.float32))
    if pad:
        mag = jnp.pad(mag, ((0, 0), (0, pad)))
    amax = jnp.max(mag.reshape(m, gn, group), axis=2)
    return jnp.where(amax > 0, amax, 1.0) / 127.0


def quantize_int8_grouped_ref(x, scale, u=None, group: int = 128):
    """x: (m, D); scale: (m, ceil(D/group)) f32 -> int8 in [-127, 127].

    Oracle for kernels/wire_quant.py:quantize_int8_grouped_panel (the
    'int8g' residency storage). Same rounding contract as
    quantize_int8_ref: ``u`` selects stochastic floor(x/scale + u),
    ``u=None`` rounds to nearest."""
    s = x.astype(jnp.float32) / expand_group_scale(scale, x.shape[1], group)
    q = jnp.floor(s + u) if u is not None else jnp.round(s)
    return jnp.clip(q, -127.0, 127.0).astype(jnp.int8)


def dequantize_int8_grouped_ref(q, scale, group: int = 128):
    """q: (m, D) int8; scale: (m, ceil(D/group)) f32 -> f32 panel."""
    return (q.astype(jnp.float32)
            * expand_group_scale(scale, q.shape[1], group))


def pack_int4_ref(q):
    """(m, D) int4-valued int8 -> (m, ceil(D/2)) uint8 packed nibbles:
    even column in the LOW nibble, odd column in the HIGH nibble (an odd
    tail packs against a zero nibble). This IS the wire byte layout —
    two quantized values per byte."""
    m, D = q.shape
    if D % 2:
        q = jnp.pad(q, ((0, 0), (0, 1)))
    pair = q.reshape(m, -1, 2).astype(jnp.uint8) & 0xF
    return (pair[:, :, 0] | (pair[:, :, 1] << 4)).astype(jnp.uint8)


def unpack_int4_ref(p, D: int):
    """(m, ceil(D/2)) uint8 packed nibbles -> (m, D) int8, sign-extended
    ((n ^ 8) - 8 maps the nibble back to [-8, 7]). Exact inverse of
    pack_int4_ref for values in [-8, 7]."""
    m = p.shape[0]
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    q = jnp.stack([lo, hi], axis=2).reshape(m, -1)[:, :D]
    return ((q ^ 8) - 8).astype(jnp.int8)


def topk_threshold_ref(x, k: int):
    """Per-row magnitude threshold of the top-k sparsifier: the k-th
    largest |x| per row. x: (m, D) -> (m, 1) f32. Computed OUTSIDE the
    sparsify kernel (a full row pass, like the int8 scales)."""
    mag = jnp.abs(x.astype(jnp.float32))
    vals = jax.lax.top_k(mag, k)[0]
    return vals[:, -1:]


def sparsify_topk_ref(x, thresh):
    """Zero every entry whose magnitude is below its row threshold.
    x: (m, D); thresh: (m, 1) f32 -> f32 panel.

    Oracle for kernels/wire_quant.py:sparsify_topk_panel. Ties AT the
    threshold all survive (measure-zero for continuous inputs; the wire
    payload accounting assumes exactly k survivors per row)."""
    x32 = x.astype(jnp.float32)
    return jnp.where(jnp.abs(x32) >= thresh, x32, 0.0)


def weighted_colmerge_ref(x, w):
    """x: (m, D) panel; w: (m, D) per-coordinate nonneg weights ->
    (D,) f32 weighted column merge sum_k w_kj x_kj / sum_k w_kj.

    Oracle for kernels/merge_ops.py:weighted_colmerge (the variance- and
    Fisher-weighted merge operators). Callers keep the denominator
    positive by folding their eps into w BEFORE the merge."""
    x32 = x.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    return jnp.sum(w32 * x32, axis=0) / jnp.sum(w32, axis=0)


def ties_thresh_ref(tau, trim):
    """Per-agent-row magnitude threshold of the TIES trim step: keep the
    top ``trim`` fraction of |tau| per row (trim=1.0 keeps everything).
    tau: (m, D) deviations -> (m, 1) f32 thresholds (row quantiles).
    Computed OUTSIDE the merge kernel (a full row pass, like the int8
    scales in wire_quant)."""
    if not 0.0 < trim <= 1.0:
        raise ValueError(f"trim fraction must be in (0, 1], got {trim}")
    mag = jnp.abs(tau.astype(jnp.float32))
    return jnp.quantile(mag, 1.0 - trim, axis=1, keepdims=True)


def ties_colmerge_ref(tau, thresh):
    """TIES column merge of trimmed deviations (sign election + agreeing
    mean). tau: (m, D) deviations from the reference row; thresh: (m, 1)
    per-row magnitude thresholds (ties_thresh_ref) -> (D,) f32.

    Per column j: trim entries below their row threshold, elect the sign
    of the trimmed column sum (ties -> +), and average ONLY the surviving
    entries that agree with the elected sign (the disjoint mean of TIES);
    columns with no survivor merge to 0 (pure reference).

    Oracle for kernels/merge_ops.py:ties_colmerge."""
    t = tau.astype(jnp.float32)
    keep = jnp.abs(t) >= thresh
    tk = jnp.where(keep, t, 0.0)
    col = jnp.sum(tk, axis=0)
    s = jnp.where(col >= 0.0, 1.0, -1.0)
    agree = (tk * s[None]) > 0.0
    cnt = jnp.sum(agree.astype(jnp.float32), axis=0)
    dev = jnp.sum(jnp.where(agree, tk, 0.0), axis=0)
    return jnp.where(cnt > 0.0, dev / jnp.maximum(cnt, 1.0), 0.0)


def adamw_fused_int8_ref(g, p, qm, sm, qv, sv, um, uv, lr, bc1, bc2, *,
                         group: int = 128, transform_fwd=None,
                         transform_inv=None, core=None):
    """Oracle for kernels/opt_fused.py: fused int8 Adam moment update.

    Decodes the companded int8 moments (dequant -> inverse transform),
    runs the shared elementwise optimizer ``core`` (optim.Optimizer.core
    — the exact expression the pytree path executes), then re-encodes
    the new moments (forward transform -> fresh grouped scales ->
    stochastic floor with the supplied uniforms). By construction this
    is the unfused decode->update->encode composition on the ref path,
    so fused-off and fused-on-ref trajectories are bit-identical.

    g, p: (m, D) f32 grads/params; qm, qv: (m, D) int8; sm, sv:
    (m, ceil(D/group)) f32 scales; um, uv: (m, D) uniforms in [0, 1);
    lr, bc1, bc2: broadcastable to (m, D) — (m, 1) columns carry the
    per-agent step_count divergence after RESYNC.
    Returns (p_new, qm_new, sm_new, qv_new, sv_new).
    """
    fwd = transform_fwd if transform_fwd is not None else (lambda x: x)
    inv = transform_inv if transform_inv is not None else (lambda z: z)
    m_dec = inv(dequantize_int8_grouped_ref(qm, sm, group=group))
    v_dec = inv(dequantize_int8_grouped_ref(qv, sv, group=group))
    p_new, m_new, v_new = core(g, m_dec, v_dec, p, lr=lr, bc1=bc1, bc2=bc2)
    zm = fwd(m_new)
    zv = fwd(v_new)
    sm_new = int8_group_scale_ref(zm, group=group)
    qm_new = quantize_int8_grouped_ref(zm, sm_new, um, group=group)
    sv_new = int8_group_scale_ref(zv, group=group)
    qv_new = quantize_int8_grouped_ref(zv, sv_new, uv, group=group)
    return p_new, qm_new, sm_new, qv_new, sv_new
