"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True, window=None,
                  scale=None):
    """q,k,v: (B, S, H, hd) (same H; GQA is expanded by the wrapper).

    Returns (B, S, H, hd). Masking: causal and/or sliding window."""
    B, S, H, hd = q.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    scores = jnp.where(ok[None, None], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", attn, v)


def gossip_mix_ref(W, theta):
    """W: (m, m); theta: (m, D) -> W @ theta in f32 accumulation."""
    return (W.astype(jnp.float32) @ theta.astype(jnp.float32)).astype(theta.dtype)


def panel_mean_consensus_ref(theta):
    """theta: (m, D) -> (column mean (D,) f32, total squared deviation).

    Oracle for kernels/panel_reduce.py: mean_j = (1/m) sum_k theta_kj and
    sq = sum_{k,j} (theta_kj - mean_j)^2 (= m * Xi^2)."""
    t = theta.astype(jnp.float32)
    mean = jnp.mean(t, axis=0)
    sq = jnp.sum(jnp.square(t - mean[None]))
    return mean, sq


def int8_scale_ref(x):
    """Per-row symmetric int8 scale for an (m, D) panel: amax_k / 127 in
    f32, with all-zero rows mapped to scale 1/127 so dequantization is
    always a plain multiply."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1, keepdims=True)
    return jnp.where(amax > 0, amax, 1.0) / 127.0


def quantize_int8_ref(x, scale, u=None):
    """x: (m, D); scale: (m, 1) f32 -> int8 values in [-127, 127].

    Oracle for kernels/wire_quant.py. ``u`` (same shape as x, uniform in
    [0, 1)) selects stochastic rounding floor(x/scale + u) — unbiased in
    expectation over u; ``u=None`` rounds to nearest (ties to even,
    matching jnp.round). The clip guards the float boundary rows where
    x/scale lands an ulp outside +/-127."""
    s = x.astype(jnp.float32) / scale
    q = jnp.floor(s + u) if u is not None else jnp.round(s)
    return jnp.clip(q, -127.0, 127.0).astype(jnp.int8)


def dequantize_int8_ref(q, scale):
    """q: (m, D) int8; scale: (m, 1) f32 -> f32 panel q * scale."""
    return q.astype(jnp.float32) * scale
