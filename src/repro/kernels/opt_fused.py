"""Fused int8 AdamW panel kernel: decode -> update -> re-encode in VMEM.

The unfused residency engine round-trips every stored moment panel
through HBM-resident f32 views each local step: decode (int8 read + f32
write), optimizer update (f32 read + f32 write), encode (f32 read + int8
write). For an (m, D) panel that is 16·m·D bytes of transient f32
traffic on top of the ~2·m·D bytes the stored int8 rep itself moves.
This kernel performs the whole companded decode, the shared elementwise
AdamW core (optim.Optimizer.core — the exact expression the pytree path
runs), and the stochastic-rounding re-encode inside one Pallas grid
sweep: HBM sees only the stored int8 q + grouped scales (plus the grad
and param panels the update must touch anyway) — no f32 moment panel is
ever materialized.

Why the re-encode can fuse at all: ``_int4_blocking`` snaps ``block_d``
to a whole number of scale groups, so every scale group lies entirely
inside one grid block and the fresh per-group amax/127 scales of the
UPDATED moments are computable block-locally — no second sweep, unlike
the per-row (group=None) layout, whose row amax needs all of D. Hence
the fused path exists only for GROUPED int8 storages ('int8'/'int8g');
per-row 'int8r' and f32/bf16 keep the unfused decode->update->encode.

Hyperparameters lr/bc1/bc2 arrive as (m, 1) per-agent columns, not
scalars: step_count diverges across agent rows after a RESYNC re-init,
so the bias corrections do too. They ride the same resident (m, 1)
BlockSpec as the wire kernels' row scales.

Randomness follows wire_quant's portable contract: the uniforms are
INPUT panels threaded from the jax PRNG key schedule (bit-identical to
the kernels/ref.py oracle, runnable in interpret mode on CPU). The
uniform inputs' HBM traffic is identical in the fused and unfused paths
(both draw the same panels), so it cancels from the traffic comparison;
a TPU-native variant would draw bits on-chip via pltpu.prng_random_bits
exactly as quantize_int8_panel_native does.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import adamw_fused_int8_ref
from repro.kernels.wire_quant import (_int4_blocking, _pad_cols,
                                      _pad_group_scale)


def _identity(x):
    return x


def _adamw_fused_kernel(group, core, fwd, inv,
                        g_ref, p_ref, qm_ref, sm_ref, qv_ref, sv_ref,
                        um_ref, uv_ref, lr_ref, bc1_ref, bc2_ref,
                        po_ref, qmo_ref, smo_ref, qvo_ref, svo_ref):
    # decode: grouped dequant (scale expand is a VMEM repeat) + inverse
    # companding — bitwise the ref dequantize_int8_grouped_ref
    sm = jnp.repeat(sm_ref[...], group, axis=1)
    sv = jnp.repeat(sv_ref[...], group, axis=1)
    m = inv(qm_ref[...].astype(jnp.float32) * sm)
    v = inv(qv_ref[...].astype(jnp.float32) * sv)
    # the shared optimizer core; lr/bc1/bc2 are resident (m, 1) columns
    p, m, v = core(g_ref[...], m, v, p_ref[...],
                   lr=lr_ref[...], bc1=bc1_ref[...], bc2=bc2_ref[...])
    po_ref[...] = p
    mloc, bd = p.shape
    sg = bd // group

    def encode(z, u, s_out, q_out):
        # fresh block-local grouped scales of the UPDATED moment — the
        # block holds whole groups, so this matches the ref's global
        # int8_group_scale_ref exactly (max is order-independent)
        amax = jnp.max(jnp.abs(z).reshape(mloc, sg, group), axis=2)
        s = jnp.where(amax > 0, amax, 1.0) / 127.0
        s_out[...] = s
        se = jnp.repeat(s, group, axis=1)
        q_out[...] = jnp.clip(jnp.floor(z / se + u),
                              -127.0, 127.0).astype(jnp.int8)

    encode(fwd(m), um_ref[...], smo_ref, qmo_ref)
    encode(fwd(v), uv_ref[...], svo_ref, qvo_ref)


def _col(a, m):
    """Normalize a scalar / (m,) / (m, 1) hyperparameter to an (m, 1)
    f32 column."""
    a = jnp.asarray(a, jnp.float32)
    if a.ndim == 0:
        a = a[None]
    return jnp.broadcast_to(a.reshape(-1, 1), (m, 1))


def adamw_fused_int8_panel(g, p, qm, sm, qv, sv, um, uv, lr, bc1, bc2, *,
                           group: int = 128, core, transform_fwd=None,
                           transform_inv=None, block_d: int = 512,
                           interpret: bool = True):
    """Fused AdamW step on companded grouped-int8 moments.

    g, p: (m, D) f32; qm/qv: (m, D) int8; sm/sv: (m, ceil(D/group)) f32
    scales; um/uv: (m, D) uniforms in [0, 1) for the stochastic
    re-encode; lr/bc1/bc2: scalar, (m,), or (m, 1) per-agent
    hyperparameters. Returns (p_new, qm_new, sm_new, qv_new, sv_new) —
    bit-identical to kernels/ref.py:adamw_fused_int8_ref."""
    m, D = g.shape
    fwd = transform_fwd if transform_fwd is not None else _identity
    inv = transform_inv if transform_inv is not None else _identity
    bd = _int4_blocking(D, group, block_d)
    gp, Dp = _pad_cols(g.astype(jnp.float32), bd)
    pp, _ = _pad_cols(p, bd)
    qmp, _ = _pad_cols(qm, bd)
    qvp, _ = _pad_cols(qv, bd)
    ump, _ = _pad_cols(um, bd)
    uvp, _ = _pad_cols(uv, bd)
    smp = _pad_group_scale(sm, Dp, group)
    svp = _pad_group_scale(sv, Dp, group)
    nd = Dp // bd
    sg = bd // group
    G = -(-D // group)
    data = pl.BlockSpec((m, bd), lambda i: (0, i))
    scale = pl.BlockSpec((m, sg), lambda i: (0, i))
    col = pl.BlockSpec((m, 1), lambda i: (0, 0))
    p_new, qm_new, sm_new, qv_new, sv_new = pl.pallas_call(
        functools.partial(_adamw_fused_kernel, group, core, fwd, inv),
        grid=(nd,),
        in_specs=[data, data, data, scale, data, scale,
                  data, data, col, col, col],
        out_specs=[data, data, scale, data, scale],
        out_shape=(jax.ShapeDtypeStruct((m, Dp), jnp.float32),
                   jax.ShapeDtypeStruct((m, Dp), jnp.int8),
                   jax.ShapeDtypeStruct((m, Dp // group), jnp.float32),
                   jax.ShapeDtypeStruct((m, Dp), jnp.int8),
                   jax.ShapeDtypeStruct((m, Dp // group), jnp.float32)),
        interpret=interpret,
    )(gp, pp, qmp, smp, qvp, svp, ump, uvp,
      _col(lr, m), _col(bc1, m), _col(bc2, m))
    return (p_new[:, :D], qm_new[:, :D], sm_new[:, :G],
            qv_new[:, :D], sv_new[:, :G])


def adamw_fused_int8(g, p, qm, sm, qv, sv, um, uv, lr, bc1, bc2, *,
                     group: int = 128, core, transform_fwd=None,
                     transform_inv=None, use_pallas: bool = True,
                     interpret: bool = True, block_d: int = 512):
    """Dispatch wrapper: the Pallas kernel when ``use_pallas`` (the
    replicated/interpret path), else the shardable XLA ref composition —
    SPMD specs fall back here exactly as the storage codecs do via
    ``_pallas_ok``. Both branches return identical bits."""
    if use_pallas:
        return adamw_fused_int8_panel(
            g, p, qm, sm, qv, sv, um, uv, lr, bc1, bc2, group=group,
            core=core, transform_fwd=transform_fwd,
            transform_inv=transform_inv, block_d=block_d,
            interpret=interpret)
    m = g.shape[0]
    return adamw_fused_int8_ref(
        g, p, qm, sm, qv, sv, um, uv,
        _col(lr, m), _col(bc1, m), _col(bc2, m), group=group,
        transform_fwd=transform_fwd, transform_inv=transform_inv,
        core=core)
