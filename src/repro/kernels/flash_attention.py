"""Flash attention Pallas TPU kernel (causal, optional sliding window).

TPU adaptation: 2D grid (q-block, k-block) with the k dimension iterated
sequentially ("arbitrary" dimension semantics) so the online-softmax running
max / denominator / accumulator live in VMEM scratch across k steps.
BlockSpecs tile Q/K/V into (block, head_dim) VMEM windows; MXU-aligned
block sizes (multiples of 128) are chosen by the wrapper in ops.py.

Validated in interpret mode against kernels/ref.py (CPU container); on a
real TPU the same pallas_call lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, causal: bool, window,
                  scale: float, num_k_blocks: int):
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)  # (block_q, hd)
    k = k_ref[...].astype(jnp.float32)  # (block_k, hd)
    v = v_ref[...].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = jnp.ones_like(q_pos, dtype=bool)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]  # (block_q, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == num_k_blocks - 1)
    def _flush():
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bh(q, k, v, *, causal=True, window=None, scale=None,
                       block_q=128, block_k=128, interpret=True):
    """Single (batch*head)-merged call. q,k,v: (BH, S, hd)."""
    BH, S, hd = q.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal,
        window=window, scale=scale, num_k_blocks=nk)

    def one(qi, ki_, vi):
        return pl.pallas_call(
            kernel,
            grid=(nq, nk),
            in_specs=[
                pl.BlockSpec((block_q, hd), lambda i, j: (i, 0)),
                pl.BlockSpec((block_k, hd), lambda i, j: (j, 0)),
                pl.BlockSpec((block_k, hd), lambda i, j: (j, 0)),
            ],
            out_specs=pl.BlockSpec((block_q, hd), lambda i, j: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((S, hd), qi.dtype),
            scratch_shapes=[
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, hd), jnp.float32),
            ],
            interpret=interpret,
        )(qi, ki_, vi)

    return jax.vmap(one)(q, k, v)
