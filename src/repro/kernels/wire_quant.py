"""Pallas TPU kernels for the quantized-wire codec: int8 panel (de)quant.

The wire codec's hot ops on an (m, D) parameter panel: quantize each
agent's row to int8 against a per-row symmetric scale (optionally with
stochastic rounding), and dequantize back to f32 on the receive side.
TPU adaptation mirrors kernels/gossip_mix.py: D is tiled into VMEM blocks
(``block_d`` columns), the tiny (m, 1) scale column is resident per grid
step, math in f32 on the VPU.

Randomness: stochastic rounding is floor(x/scale + u) with u uniform in
[0, 1). The portable entry point takes ``u`` as an INPUT panel (threaded
from a jax PRNG key by the codec layer — bit-identical to the
``kernels/ref.py`` oracle, and runnable in interpret mode on CPU where
``pltpu.prng_seed`` has no lowering). ``quantize_int8_panel_native`` is
the TPU-only variant that draws the bits on-chip from a scalar seed
(``pltpu.prng_random_bits``), saving the (m, D) uniform input's HBM
traffic on real hardware.

Scales are computed OUTSIDE the kernels (``kernels/ref.py:
int8_scale_ref`` — one cheap XLA row-reduce): the row amax needs a full
pass over D before any block can quantize, so fusing it in would force a
second grid sweep for no bandwidth win.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import int8_scale_ref


def _round_kernel(x_ref, s_ref, o_ref):
    s = x_ref[...].astype(jnp.float32) / s_ref[...]
    o_ref[...] = jnp.clip(jnp.round(s), -127.0, 127.0).astype(jnp.int8)


def _stoch_kernel(x_ref, s_ref, u_ref, o_ref):
    s = x_ref[...].astype(jnp.float32) / s_ref[...]
    o_ref[...] = jnp.clip(jnp.floor(s + u_ref[...]),
                          -127.0, 127.0).astype(jnp.int8)


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def _pad_cols(x, block_d):
    m, D = x.shape
    pad = (-D) % block_d
    return (jnp.pad(x, ((0, 0), (0, pad))) if pad else x), D + pad


def quantize_int8_panel(x, scale=None, u=None, *, block_d: int = 512,
                        interpret: bool = True):
    """x: (m, D) float panel -> (q int8 (m, D), scale (m, 1) f32).

    ``scale`` defaults to the per-row amax/127 (int8_scale_ref). ``u``
    (uniform [0, 1), same shape as x) switches round-to-nearest to
    stochastic rounding; zero-padded tail columns quantize to 0."""
    m, D = x.shape
    if scale is None:
        scale = int8_scale_ref(x)
    block_d = min(block_d, D)
    xp, Dp = _pad_cols(x, block_d)
    nd = Dp // block_d
    scale_spec = pl.BlockSpec((m, 1), lambda i: (0, 0))
    data_spec = pl.BlockSpec((m, block_d), lambda i: (0, i))
    if u is None:
        kernel, ops = _round_kernel, (xp, scale)
        in_specs = [data_spec, scale_spec]
    else:
        up, _ = _pad_cols(u, block_d)
        kernel, ops = _stoch_kernel, (xp, scale, up)
        in_specs = [data_spec, scale_spec, data_spec]
    q = pl.pallas_call(
        kernel,
        grid=(nd,),
        in_specs=in_specs,
        out_specs=data_spec,
        out_shape=jax.ShapeDtypeStruct((m, Dp), jnp.int8),
        interpret=interpret,
    )(*ops)
    return q[:, :D], scale


def quantize_int8_panel_native(x, seed, scale=None, *, block_d: int = 512):
    """TPU-only stochastic quantize drawing bits on-chip from ``seed``
    (int32 scalar): no (m, D) uniform input, so the only HBM traffic is
    x in / q out. ``pltpu.prng_seed`` has no CPU/interpret lowering —
    this path never runs in the test container; the portable
    ``quantize_int8_panel(u=...)`` is the verified oracle-parity path."""
    from jax.experimental.pallas import tpu as pltpu

    m, D = x.shape
    if scale is None:
        scale = int8_scale_ref(x)
    block_d = min(block_d, D)
    xp, Dp = _pad_cols(x, block_d)
    nd = Dp // block_d

    def kernel(seed_ref, x_ref, s_ref, o_ref):
        # distinct stream per grid step: the block index is passed as a
        # SEPARATE seed word so pltpu.prng_seed hashes (seed, block)
        # together — seed + program_id would alias consecutive caller
        # seeds onto shifted copies of the same streams (round t block i
        # == round t+1 block i-1), correlating the rounding across
        # rounds. Low 24 bits -> f32-exact uniform.
        pltpu.prng_seed(seed_ref[0], pl.program_id(0))
        bits = pltpu.prng_random_bits(x_ref.shape)
        u = (bits & 0xFFFFFF).astype(jnp.float32) * (1.0 / (1 << 24))
        s = x_ref[...].astype(jnp.float32) / s_ref[...]
        o_ref[...] = jnp.clip(jnp.floor(s + u),
                              -127.0, 127.0).astype(jnp.int8)

    q = pl.pallas_call(
        kernel,
        grid=(nd,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, Dp), jnp.int8),
        interpret=False,
    )(jnp.asarray([seed], jnp.int32), xp, scale)
    return q[:, :D], scale


def dequantize_int8_panel(q, scale, *, block_d: int = 512,
                          interpret: bool = True):
    """q: (m, D) int8; scale: (m, 1) f32 -> f32 panel q * scale."""
    m, D = q.shape
    block_d = min(block_d, D)
    qp, Dp = _pad_cols(q, block_d)
    nd = Dp // block_d
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, Dp), jnp.float32),
        interpret=interpret,
    )(qp, scale)
    return out[:, :D]
