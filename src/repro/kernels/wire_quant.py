"""Pallas TPU kernels for the quantized-wire codecs: int8/int4 panel
(de)quant, int4 nibble (un)packing, and the top-k sparsifier.

The wire codecs' hot ops on an (m, D) parameter panel: quantize each
agent's row to int8 against a per-row symmetric scale (optionally with
stochastic rounding), to int4 against GROUPED per-row/per-``group``-column
scales with the values packed two-per-byte on the wire, sparsify a row to
its top-k-magnitude entries against a per-row threshold, and dequantize
back to f32 on the receive side. TPU adaptation mirrors
kernels/gossip_mix.py: D is tiled into VMEM blocks (``block_d`` columns),
the tiny per-row scale/threshold columns are resident per grid step, math
in f32 on the VPU. The int4 ``block_d`` is snapped to a multiple of the
scale group so each grid step sees whole groups.

Randomness: stochastic rounding is floor(x/scale + u) with u uniform in
[0, 1). The portable entry point takes ``u`` as an INPUT panel (threaded
from a jax PRNG key by the codec layer — bit-identical to the
``kernels/ref.py`` oracle, and runnable in interpret mode on CPU where
``pltpu.prng_seed`` has no lowering). ``quantize_int8_panel_native`` is
the TPU-only variant that draws the bits on-chip from a scalar seed
(``pltpu.prng_random_bits``), saving the (m, D) uniform input's HBM
traffic on real hardware.

Scales and top-k thresholds are computed OUTSIDE the kernels
(``kernels/ref.py``: int8_scale_ref / int4_group_scale_ref /
topk_threshold_ref — cheap XLA row-reduces): the row amax / k-th-largest
needs a full pass over D before any block can quantize, so fusing it in
would force a second grid sweep for no bandwidth win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import (int4_group_scale_ref, int8_group_scale_ref,
                               int8_scale_ref, topk_threshold_ref)


def _round_kernel(x_ref, s_ref, o_ref):
    s = x_ref[...].astype(jnp.float32) / s_ref[...]
    o_ref[...] = jnp.clip(jnp.round(s), -127.0, 127.0).astype(jnp.int8)


def _stoch_kernel(x_ref, s_ref, u_ref, o_ref):
    s = x_ref[...].astype(jnp.float32) / s_ref[...]
    o_ref[...] = jnp.clip(jnp.floor(s + u_ref[...]),
                          -127.0, 127.0).astype(jnp.int8)


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def _pad_cols(x, block_d):
    m, D = x.shape
    pad = (-D) % block_d
    return (jnp.pad(x, ((0, 0), (0, pad))) if pad else x), D + pad


def quantize_int8_panel(x, scale=None, u=None, *, block_d: int = 512,
                        interpret: bool = True):
    """x: (m, D) float panel -> (q int8 (m, D), scale (m, 1) f32).

    ``scale`` defaults to the per-row amax/127 (int8_scale_ref). ``u``
    (uniform [0, 1), same shape as x) switches round-to-nearest to
    stochastic rounding; zero-padded tail columns quantize to 0."""
    m, D = x.shape
    if scale is None:
        scale = int8_scale_ref(x)
    block_d = min(block_d, D)
    xp, Dp = _pad_cols(x, block_d)
    nd = Dp // block_d
    scale_spec = pl.BlockSpec((m, 1), lambda i: (0, 0))
    data_spec = pl.BlockSpec((m, block_d), lambda i: (0, i))
    if u is None:
        kernel, ops = _round_kernel, (xp, scale)
        in_specs = [data_spec, scale_spec]
    else:
        up, _ = _pad_cols(u, block_d)
        kernel, ops = _stoch_kernel, (xp, scale, up)
        in_specs = [data_spec, scale_spec, data_spec]
    q = pl.pallas_call(
        kernel,
        grid=(nd,),
        in_specs=in_specs,
        out_specs=data_spec,
        out_shape=jax.ShapeDtypeStruct((m, Dp), jnp.int8),
        interpret=interpret,
    )(*ops)
    return q[:, :D], scale


def quantize_int8_panel_native(x, seed, scale=None, *, block_d: int = 512):
    """TPU-only stochastic quantize drawing bits on-chip from ``seed``
    (int32 scalar): no (m, D) uniform input, so the only HBM traffic is
    x in / q out. ``pltpu.prng_seed`` has no CPU/interpret lowering —
    this path never runs in the test container; the portable
    ``quantize_int8_panel(u=...)`` is the verified oracle-parity path."""
    from jax.experimental.pallas import tpu as pltpu

    m, D = x.shape
    if scale is None:
        scale = int8_scale_ref(x)
    block_d = min(block_d, D)
    xp, Dp = _pad_cols(x, block_d)
    nd = Dp // block_d

    def kernel(seed_ref, x_ref, s_ref, o_ref):
        # distinct stream per grid step: the block index is passed as a
        # SEPARATE seed word so pltpu.prng_seed hashes (seed, block)
        # together — seed + program_id would alias consecutive caller
        # seeds onto shifted copies of the same streams (round t block i
        # == round t+1 block i-1), correlating the rounding across
        # rounds. Low 24 bits -> f32-exact uniform.
        pltpu.prng_seed(seed_ref[0], pl.program_id(0))
        bits = pltpu.prng_random_bits(x_ref.shape)
        u = (bits & 0xFFFFFF).astype(jnp.float32) * (1.0 / (1 << 24))
        s = x_ref[...].astype(jnp.float32) / s_ref[...]
        o_ref[...] = jnp.clip(jnp.floor(s + u),
                              -127.0, 127.0).astype(jnp.int8)

    q = pl.pallas_call(
        kernel,
        grid=(nd,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, Dp), jnp.int8),
        interpret=False,
    )(jnp.asarray([seed], jnp.int32), xp, scale)
    return q[:, :D], scale


def dequantize_int8_panel(q, scale, *, block_d: int = 512,
                          interpret: bool = True):
    """q: (m, D) int8; scale: (m, 1) f32 -> f32 panel q * scale."""
    m, D = q.shape
    block_d = min(block_d, D)
    qp, Dp = _pad_cols(q, block_d)
    nd = Dp // block_d
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, Dp), jnp.float32),
        interpret=interpret,
    )(qp, scale)
    return out[:, :D]


# --------------------------------------------------------------- int4


def _int4_blocking(D: int, group: int, block_d: int):
    """block_d snapped to a whole number of scale groups (>= one group)."""
    bd = max(group, (min(block_d, max(D, 1)) // group) * group)
    return bd


def _pad_group_scale(scale, Dp: int, group: int):
    """Pad grouped scales to cover the column-padded panel (pad groups
    get scale 1.0 — their values are zero, so any nonzero scale works)."""
    gp = Dp // group
    pad = gp - scale.shape[1]
    return (jnp.pad(scale, ((0, 0), (0, pad)), constant_values=1.0)
            if pad else scale)


def _round4_kernel(group, x_ref, s_ref, o_ref):
    se = jnp.repeat(s_ref[...], group, axis=1)
    s = x_ref[...].astype(jnp.float32) / se
    o_ref[...] = jnp.clip(jnp.round(s), -7.0, 7.0).astype(jnp.int8)


def _stoch4_kernel(group, x_ref, s_ref, u_ref, o_ref):
    se = jnp.repeat(s_ref[...], group, axis=1)
    s = x_ref[...].astype(jnp.float32) / se
    o_ref[...] = jnp.clip(jnp.floor(s + u_ref[...]),
                          -7.0, 7.0).astype(jnp.int8)


def _dequant4_kernel(group, q_ref, s_ref, o_ref):
    se = jnp.repeat(s_ref[...], group, axis=1)
    o_ref[...] = q_ref[...].astype(jnp.float32) * se


def quantize_int4_panel(x, scale=None, u=None, *, group: int = 128,
                        block_d: int = 512, interpret: bool = True):
    """x: (m, D) float panel -> (q int4-valued int8 (m, D),
    scale (m, ceil(D/group)) f32).

    ``scale`` defaults to the grouped amax/7 (int4_group_scale_ref); one
    scale per row per ``group`` columns is resident per grid step and
    broadcast over its group on the VPU. ``u`` (uniform [0, 1), shape of
    x) switches round-to-nearest to stochastic rounding."""
    m, D = x.shape
    if scale is None:
        scale = int4_group_scale_ref(x, group)
    bd = _int4_blocking(D, group, block_d)
    xp, Dp = _pad_cols(x, bd)
    nd = Dp // bd
    sp = _pad_group_scale(scale, Dp, group)
    sg = bd // group
    scale_spec = pl.BlockSpec((m, sg), lambda i: (0, i))
    data_spec = pl.BlockSpec((m, bd), lambda i: (0, i))
    if u is None:
        kernel = functools.partial(_round4_kernel, group)
        ops, in_specs = (xp, sp), [data_spec, scale_spec]
    else:
        up, _ = _pad_cols(u, bd)
        kernel = functools.partial(_stoch4_kernel, group)
        ops, in_specs = (xp, sp, up), [data_spec, scale_spec, data_spec]
    q = pl.pallas_call(
        kernel,
        grid=(nd,),
        in_specs=in_specs,
        out_specs=data_spec,
        out_shape=jax.ShapeDtypeStruct((m, Dp), jnp.int8),
        interpret=interpret,
    )(*ops)
    return q[:, :D], scale


def dequantize_int4_panel(q, scale, *, group: int = 128,
                          block_d: int = 512, interpret: bool = True):
    """q: (m, D) int4-valued int8; scale (m, ceil(D/group)) f32 -> f32."""
    m, D = q.shape
    bd = _int4_blocking(D, group, block_d)
    qp, Dp = _pad_cols(q, bd)
    nd = Dp // bd
    sp = _pad_group_scale(scale, Dp, group)
    sg = bd // group
    out = pl.pallas_call(
        functools.partial(_dequant4_kernel, group),
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((m, bd), lambda i: (0, i)),
            pl.BlockSpec((m, sg), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bd), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, Dp), jnp.float32),
        interpret=interpret,
    )(qp, sp)
    return out[:, :D]


# ------------------------------------------------------- grouped int8
# the 'int8g' residency storage layout: int8 range against the int4
# kernels' grouped-scale blocking (one scale per row per ``group``
# columns), for state panels whose row amax is dominated by a few
# coordinates


def _round8g_kernel(group, x_ref, s_ref, o_ref):
    se = jnp.repeat(s_ref[...], group, axis=1)
    s = x_ref[...].astype(jnp.float32) / se
    o_ref[...] = jnp.clip(jnp.round(s), -127.0, 127.0).astype(jnp.int8)


def _stoch8g_kernel(group, x_ref, s_ref, u_ref, o_ref):
    se = jnp.repeat(s_ref[...], group, axis=1)
    s = x_ref[...].astype(jnp.float32) / se
    o_ref[...] = jnp.clip(jnp.floor(s + u_ref[...]),
                          -127.0, 127.0).astype(jnp.int8)


def _dequant8g_kernel(group, q_ref, s_ref, o_ref):
    se = jnp.repeat(s_ref[...], group, axis=1)
    o_ref[...] = q_ref[...].astype(jnp.float32) * se


def quantize_int8_grouped_panel(x, scale=None, u=None, *, group: int = 128,
                                block_d: int = 512, interpret: bool = True):
    """x: (m, D) float panel -> (q int8 (m, D),
    scale (m, ceil(D/group)) f32).

    ``scale`` defaults to the grouped amax/127 (int8_group_scale_ref);
    blocking and scale residency as in quantize_int4_panel. ``u``
    (uniform [0, 1), shape of x) selects stochastic rounding. Matches
    kernels/ref.py:quantize_int8_grouped_ref bit-for-bit."""
    m, D = x.shape
    if scale is None:
        scale = int8_group_scale_ref(x, group)
    bd = _int4_blocking(D, group, block_d)
    xp, Dp = _pad_cols(x, bd)
    nd = Dp // bd
    sp = _pad_group_scale(scale, Dp, group)
    sg = bd // group
    scale_spec = pl.BlockSpec((m, sg), lambda i: (0, i))
    data_spec = pl.BlockSpec((m, bd), lambda i: (0, i))
    if u is None:
        kernel = functools.partial(_round8g_kernel, group)
        ops, in_specs = (xp, sp), [data_spec, scale_spec]
    else:
        up, _ = _pad_cols(u, bd)
        kernel = functools.partial(_stoch8g_kernel, group)
        ops, in_specs = (xp, sp, up), [data_spec, scale_spec, data_spec]
    q = pl.pallas_call(
        kernel,
        grid=(nd,),
        in_specs=in_specs,
        out_specs=data_spec,
        out_shape=jax.ShapeDtypeStruct((m, Dp), jnp.int8),
        interpret=interpret,
    )(*ops)
    return q[:, :D], scale


def dequantize_int8_grouped_panel(q, scale, *, group: int = 128,
                                  block_d: int = 512,
                                  interpret: bool = True):
    """q: (m, D) int8; scale (m, ceil(D/group)) f32 -> f32 panel."""
    m, D = q.shape
    bd = _int4_blocking(D, group, block_d)
    qp, Dp = _pad_cols(q, bd)
    nd = Dp // bd
    sp = _pad_group_scale(scale, Dp, group)
    sg = bd // group
    out = pl.pallas_call(
        functools.partial(_dequant8g_kernel, group),
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((m, bd), lambda i: (0, i)),
            pl.BlockSpec((m, sg), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bd), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, Dp), jnp.float32),
        interpret=interpret,
    )(qp, sp)
    return out[:, :D]


def _pack4_kernel(x_ref, o_ref):
    m, bd = x_ref.shape
    pair = x_ref[...].reshape(m, bd // 2, 2).astype(jnp.uint8) & 0xF
    o_ref[...] = (pair[:, :, 0] | (pair[:, :, 1] << 4)).astype(jnp.uint8)


def _unpack4_kernel(p_ref, o_ref):
    m, bp = p_ref.shape
    p = p_ref[...]
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    q = jnp.stack([lo, hi], axis=2).reshape(m, bp * 2)
    o_ref[...] = ((q ^ 8) - 8).astype(jnp.int8)


def pack_int4_panel(q, *, block_d: int = 512, interpret: bool = True):
    """(m, D) int4-valued int8 -> (m, ceil(D/2)) uint8 packed nibbles
    (even column low, odd column high — the wire byte layout). Matches
    kernels/ref.py:pack_int4_ref bit-for-bit."""
    m, D = q.shape
    bd = max(2, (min(block_d, max(D, 2)) // 2) * 2)
    qp, Dp = _pad_cols(q, bd)
    nd = Dp // bd
    out = pl.pallas_call(
        _pack4_kernel,
        grid=(nd,),
        in_specs=[pl.BlockSpec((m, bd), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m, bd // 2), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, Dp // 2), jnp.uint8),
        interpret=interpret,
    )(qp)
    return out[:, :(D + 1) // 2]


def unpack_int4_panel(p, D: int, *, block_d: int = 512,
                      interpret: bool = True):
    """(m, ceil(D/2)) uint8 packed nibbles -> (m, D) int8, sign-extended.
    Exact inverse of pack_int4_panel."""
    m, P = p.shape
    bp = max(1, min(block_d // 2, P))
    pp, Pp = _pad_cols(p, bp)
    nd = Pp // bp
    out = pl.pallas_call(
        _unpack4_kernel,
        grid=(nd,),
        in_specs=[pl.BlockSpec((m, bp), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m, bp * 2), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, Pp * 2), jnp.int8),
        interpret=interpret,
    )(pp)
    return out[:, :D]


# -------------------------------------------------------------- top-k


def _sparsify_kernel(x_ref, t_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.where(jnp.abs(x) >= t_ref[...], x, 0.0)


def sparsify_topk_panel(x, thresh=None, *, k: int = None,
                        block_d: int = 512, interpret: bool = True):
    """Zero every entry below its per-row top-k magnitude threshold.

    ``thresh`` (m, 1) defaults to the k-th largest |x| per row
    (topk_threshold_ref — computed outside the kernel like the int8
    scales). The threshold column is resident per grid step; zero-padded
    tail columns stay zero. Matches sparsify_topk_ref bit-for-bit."""
    m, D = x.shape
    if thresh is None:
        if k is None:
            raise ValueError("sparsify_topk_panel needs thresh= or k=")
        thresh = topk_threshold_ref(x, k)
    bd = min(block_d, D)
    xp, Dp = _pad_cols(x, bd)
    nd = Dp // bd
    out = pl.pallas_call(
        _sparsify_kernel,
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((m, bd), lambda i: (0, i)),
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, bd), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, Dp), jnp.float32),
        interpret=interpret,
    )(xp, thresh)
    return out[:, :D]
