"""Jit'd public wrappers around the Pallas kernels.

``interpret=True`` (default here) runs the kernel bodies in Python on CPU —
the validation mode for this container; pass ``interpret=False`` on real
TPU hardware. Model code keeps ``use_pallas=False`` by default so the same
graph lowers for the CPU dry-run client (see DESIGN.md §8).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention_bh
from repro.kernels.gossip_mix import gossip_mix_panel


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=128,
                    block_k=128, interpret=True):
    """q: (B,S,H,hd); k,v: (B,S,Kv,hd) with H % Kv == 0 (GQA expanded here).

    Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    Kv = k.shape[2]
    if Kv != H:
        rep = H // Kv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kb = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vb = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    out = flash_attention_bh(qb, kb, vb, causal=causal, window=window,
                             block_q=block_q, block_k=block_k,
                             interpret=interpret)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


def _flatten_panel(tree):
    leaves = jax.tree.leaves(tree)
    m = leaves[0].shape[0]
    flats = [x.reshape(m, -1) for x in leaves]
    sizes = [f.shape[1] for f in flats]
    return jnp.concatenate(flats, axis=1), sizes


def _unflatten_panel(panel, tree, sizes):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    outs = []
    off = 0
    for leaf, sz in zip(leaves, sizes):
        outs.append(panel[:, off:off + sz].reshape(leaf.shape).astype(leaf.dtype))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, outs)


@partial(jax.jit, static_argnames=("block_d", "interpret"))
def gossip_mix(W, params_stacked, *, block_d=512, interpret=True):
    """Kernel-backed Theta <- W Theta over an agent-stacked pytree."""
    panel, sizes = _flatten_panel(params_stacked)
    mixed = gossip_mix_panel(W, panel, block_d=block_d, interpret=interpret)
    return _unflatten_panel(mixed, params_stacked, sizes)
