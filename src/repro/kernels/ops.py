"""Jit'd public wrappers around the Pallas kernels.

``interpret=True`` (default here) runs the kernel bodies in Python on CPU —
the validation mode for this container; pass ``interpret=False`` on real
TPU hardware. Model code keeps ``use_pallas=False`` by default so the same
graph lowers for the CPU dry-run client (see DESIGN.md §8).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention_bh


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=128,
                    block_k=128, interpret=True):
    """q: (B,S,H,hd); k,v: (B,S,Kv,hd) with H % Kv == 0 (GQA expanded here).

    Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    Kv = k.shape[2]
    if Kv != H:
        rep = H // Kv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kb = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vb = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    out = flash_attention_bh(qb, kb, vb, causal=causal, window=window,
                             block_q=block_q, block_k=block_k,
                             interpret=interpret)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("block_d", "interpret"))
def gossip_mix(W, params_stacked, *, block_d=512, interpret=True):
    """Kernel-backed Theta <- W Theta over an agent-stacked pytree.

    Flattening goes through the PanelSpec engine (core/panel.py): leaves are
    grouped by dtype, so a bf16+f32 pytree mixes as one kernel call per
    dtype group with NO silent promotion (the old ``jnp.concatenate`` over
    all leaves upcast everything to the widest dtype, doubling wire bytes).
    """
    from repro.core import panel as panel_mod
    spec = panel_mod.make_spec(params_stacked)
    panel = panel_mod.to_panel(params_stacked, spec)
    mixed = panel_mod.mix_dense(panel, W, use_pallas=True, block_d=block_d,
                                interpret=interpret)
    return panel_mod.from_panel(mixed, spec)


@partial(jax.jit, static_argnames=("block_d", "interpret"))
def panel_stats(params_stacked, *, block_d=512, interpret=True):
    """Kernel-backed fused panel statistics over an agent-stacked pytree:
    (merged f32 pytree, consensus distance Xi). One panel_reduce kernel
    call per dtype group — single pass over the parameters."""
    from repro.core import panel as panel_mod
    from repro.kernels.panel_reduce import panel_mean_consensus
    spec = panel_mod.make_spec(params_stacked)
    panel = panel_mod.to_panel(params_stacked, spec)
    m = next(iter(panel.values())).shape[0]
    means = {}
    total = jnp.zeros((), jnp.float32)
    for k, x in panel.items():
        mean, sq = panel_mean_consensus(x, block_d=block_d,
                                        interpret=interpret)
        means[k] = mean
        total = total + sq
    merged = panel_mod.from_panel(means, spec, cast=False)
    return merged, jnp.sqrt(total / m)
