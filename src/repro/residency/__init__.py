"""Quantized panel residency: storage codecs for the resident state
panels (see residency/storage.py for the contract).

The panel engine (core/panel.py) carries a per-state-kind policy — a
``(kind, storage-name)`` table on ``PanelSpec.residency`` via
``panel.with_residency`` — resolved through :func:`get_storage`; the
segment driver (core/dsgd.py) fuses the encode/decode into the donated
round so the optimizer update reads dequantized moments and writes back
quantized storage in the same step."""
from repro.residency.storage import (KINDS, STORAGE,  # noqa: F401
                                     Bf16Storage, F32Storage, Int8Storage,
                                     Storage, get_storage, parse_policy,
                                     storage_keys)
