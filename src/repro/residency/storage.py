"""Storage codecs: the quantized-wire machinery pointed at HBM.

Every agent costs 4+ f32 (m, D) rows of panel HBM (params, two AdamW
moments, plus the ``wire_err``/``merge_stat`` panels when active), so
resident bytes — not FLOPs — cap the agent count m per chip. This
subsystem mirrors the ``repro/wire`` codec registry but compresses the
RESIDENT state panels instead of the communication payload: a
:data:`STORAGE` / :func:`get_storage` registry of storage codecs applied
per state-panel KIND (``moments`` / ``stats`` / ``wire_err`` — params
always stay in their native dtypes) via a residency policy carried on
``PanelSpec`` (``panel.with_residency``, e.g.
``--residency moments=int8,stats=bf16``).

Contract (each entry is a :class:`Storage`):

* ``init(x)``  — deterministic encode (round-to-nearest) of an f32
  (m, D) panel into its stored representation. Used at state build and
  for RESYNC re-initialization, so a rejoining agent's stored rows
  bit-match a fresh init.
* ``write(x, key=...)`` — the hot-path encode fused into the scanned
  segment: stochastic-rounding storages REQUIRE a key (unbiased over
  keys, like the wire codecs' SR).
* ``read(stored)`` — decode back to the f32 compute view.
* ``zero_like(stored)`` — the CANONICAL zero representation
  (bit-identical to ``init(zeros)``): int8 stores q=0 with scale 1/127
  (the ``int8_scale_ref`` zero-row rule), so RESYNC moment zeroing
  produces the same bits as a fresh state.
* ``resident_bytes(rows, width)`` — exact HBM bytes of the stored rep
  (values + scale sidecars) for an f32 (rows, width) panel.

Stored representations: ``f32`` is the IDENTITY (the raw array passes
through untouched — an f32 policy is byte-identical to no policy, and
non-f32 dtype groups always ride the identity). ``bf16`` stores the
cast array. The int8 entries store ``{"q": int8 (m, D),
"scale": f32 sidecar}`` dicts — per-row scales (m, 1) or grouped scales
(m, ceil(D/group)) — reusing the conformance-tested
``kernels/wire_quant`` quantize kernels (ref oracles in
``kernels/ref.py``) with the wire codecs' partitionable-threefry
uniform draw, so sharded and replicated runs store identical bits.

Int8 moment storage NEEDS companding. Linear int8 symmetric
quantization (per-row or grouped) stochastically rounds Adam's small
second-moment entries to zero; the next update then divides by
``sqrt(0) + eps`` and amplifies those coordinates ~1e8x — at real LM
widths the run NaNs within two rounds (observed, not hypothetical;
this is exactly why production 8-bit optimizers use nonlinear/dynamic
maps). The fix shipped here: the ``int8``/``int8g`` entries encode in
the SIGNED-SQRT domain — quantize ``sign(x)*sqrt(|x|)`` linearly,
decode ``sign(z)*z**2`` — which allocates relative (not absolute)
precision near zero. SR stays unbiased in the sqrt domain; the Jensen
term makes the decoded second moment a hair LARGER on average, which
is the safe direction for Adam (it shrinks steps rather than blowing
them up). Grouped scales are also required: one per-row scale is too
coarse for moment panels even in the sqrt domain (``int8r`` keeps the
raw linear per-row layout for residual-like panels such as
``wire_err``/``stats``, where values are parameter-scaled and a
zeroed small entry is harmless).

Like ``repro/wire``, everything here is engine-agnostic: the segment
driver (core/dsgd.py) owns WHERE the encode/decode fuses into the round
(decode moments before the optimizer update, write back quantized in
the same donated step — no resident f32 copy survives the round).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_mod
from repro.kernels import wire_quant
# the wire codecs' uniform draw (jax.threefry_partitionable scope):
# storage SR must be bit-identical between sharded and replicated runs
# for exactly the same reason the wire codecs' is
from repro.wire.codec import _uniform

# state-panel kinds a residency policy may name; params are deliberately
# NOT a kind — the mixing matmul/merge operators read them every round,
# so quantizing them is a wire question (repro/wire), not a storage one
KINDS = ("moments", "stats", "wire_err")


class Storage:
    """Base storage codec: the f32 identity (raw arrays pass through)."""

    name = "f32"
    needs_key = False  # write() draws stochastic-rounding bits from key=
    # whether the stored rep supports the fused in-VMEM optimizer update
    # (kernels/opt_fused.py): decode->update->re-encode without an HBM
    # f32 view. Requires block-local re-scaling, i.e. GROUPED scales —
    # per-row scales need a full-D amax pass, so only grouped int8
    # qualifies; everything else keeps the unfused path.
    fused_update = False

    # ------------------------------------------------------------ codec
    def init(self, x):
        """Deterministic encode (state build / RESYNC re-init)."""
        return x

    def write(self, x, *, key=None, use_pallas: bool = False,
              interpret: bool = True):
        """Hot-path encode of an f32 panel into its stored rep."""
        return x

    def read(self, stored, *, use_pallas: bool = False,
             interpret: bool = True):
        """Decode a stored rep back to the f32 compute view."""
        return stored

    def maybe_read(self, v, *, use_pallas: bool = False,
                   interpret: bool = True):
        """``read`` that tolerates an ALREADY-DECODED f32 leaf — the
        out-of-engine entry point (merging.merge_panel's stat reads may
        see either the stored rep or the engine's decoded view)."""
        return v

    # the domain the quantizer (and its stochastic rounding) operates
    # in: identity for linear codecs, signed-sqrt for companded int8.
    # SR unbiasedness holds in THIS domain (conformance tests check it
    # here; the value domain picks up a small Jensen bias on decode).
    def transform_fwd(self, x):
        return x

    def transform_inv(self, y):
        return y

    def zero_like(self, stored):
        """Canonical zero stored rep (bit-identical to init(zeros))."""
        return jax.tree.map(jnp.zeros_like, stored)

    # ------------------------------------------------------- accounting
    def resident_bytes(self, rows: int, width: int) -> int:
        """Exact HBM bytes of the stored rep of an f32 (rows, width)
        panel, scale sidecars included."""
        return rows * width * 4


class F32Storage(Storage):
    """The identity: byte-identical to the pre-residency engine."""


class Bf16Storage(Storage):
    """bf16 cast storage: 2 bytes/scalar, no sidecar (the original
    optimizer-state halving lever — cf. olmax's bf16 momentum)."""

    name = "bf16"

    def init(self, x):
        return x.astype(jnp.bfloat16)

    def write(self, x, *, key=None, use_pallas: bool = False,
              interpret: bool = True):
        return x.astype(jnp.bfloat16)

    def read(self, stored, *, use_pallas: bool = False,
             interpret: bool = True):
        return stored.astype(jnp.float32)

    def maybe_read(self, v, *, use_pallas: bool = False,
                   interpret: bool = True):
        # state panels are f32 by construction, so a bf16 leaf can only
        # be this storage's rep; an already-decoded f32 view passes
        return v.astype(jnp.float32) if v.dtype == jnp.bfloat16 else v

    def resident_bytes(self, rows: int, width: int) -> int:
        return rows * width * 2


class Int8Storage(Storage):
    """Symmetric int8 storage with f32 scale sidecars: 1 byte/scalar +
    4 bytes per scale. ``group=None`` keeps one scale per row (m, 1);
    an int ``group`` stores one scale per ``group`` columns
    (m, ceil(D/group)) — tighter scales for wide panels whose row amax
    is dominated by a few coordinates. Stored rep:
    ``{"q": int8 (m, D), "scale": f32 sidecar}``.

    ``write`` uses key-driven stochastic rounding (unbiased over keys —
    a biased round-to-nearest would systematically shrink EMA moments);
    ``init`` rounds to nearest (deterministic, so state build and
    RESYNC re-init are reproducible without a key schedule).

    ``transform="sqrt"`` composes signed-sqrt companding around the
    linear quantizer: encode quantizes ``sign(x)*sqrt(|x|)``, decode
    squares back. The transform is a pair of cheap elementwise jnp ops
    OUTSIDE the Pallas kernels (XLA fuses them into the surrounding
    segment), so the conformance-tested linear kernels are reused
    untouched. This is what makes int8 safe for Adam's second moment —
    see the module docstring for the failure mode it prevents."""

    SCALE_BYTES = 4
    needs_key = True

    def __init__(self, name: str = "int8", group=None, transform=None):
        if transform not in (None, "sqrt"):
            raise ValueError(f"unknown transform {transform!r}")
        self.name = name
        self.group = group
        self.transform = transform
        # grouped scales are block-local in the fused kernel's grid, so
        # the re-encode can compute them in-VMEM; per-row scales can't
        self.fused_update = group is not None

    def transform_fwd(self, x):
        if self.transform is None:
            return x
        x = x.astype(jnp.float32)
        return jnp.sign(x) * jnp.sqrt(jnp.abs(x))

    def transform_inv(self, y):
        if self.transform is None:
            return y
        return jnp.sign(y) * jnp.square(y)

    # ------------------------------------------------------------ codec
    def _scale(self, x32):
        if self.group is None:
            return ref_mod.int8_scale_ref(x32)
        return ref_mod.int8_group_scale_ref(x32, self.group)

    def _quantize(self, x, u, use_pallas, interpret):
        x32 = self.transform_fwd(x.astype(jnp.float32))
        scale = self._scale(x32)
        if use_pallas:
            if self.group is None:
                q, _ = wire_quant.quantize_int8_panel(
                    x32, scale, u, interpret=interpret)
            else:
                q, _ = wire_quant.quantize_int8_grouped_panel(
                    x32, scale, u, group=self.group, interpret=interpret)
        elif self.group is None:
            q = ref_mod.quantize_int8_ref(x32, scale, u)
        else:
            q = ref_mod.quantize_int8_grouped_ref(x32, scale, u,
                                                  self.group)
        return {"q": q, "scale": scale}

    def init(self, x):
        return self._quantize(x, None, False, True)

    def write(self, x, *, key=None, use_pallas: bool = False,
              interpret: bool = True):
        if key is None:
            raise ValueError(
                f"storage '{self.name}' uses stochastic rounding and "
                "needs an explicit key= (use init() for the "
                "deterministic encode)")
        u = _uniform(key, x.shape)
        return self._quantize(x, u, use_pallas, interpret)

    def read(self, stored, *, use_pallas: bool = False,
             interpret: bool = True):
        q, scale = stored["q"], stored["scale"]
        if use_pallas:
            if self.group is None:
                y = wire_quant.dequantize_int8_panel(
                    q, scale, interpret=interpret)
            else:
                y = wire_quant.dequantize_int8_grouped_panel(
                    q, scale, group=self.group, interpret=interpret)
        elif self.group is None:
            y = ref_mod.dequantize_int8_ref(q, scale)
        else:
            y = ref_mod.dequantize_int8_grouped_ref(q, scale, self.group)
        return self.transform_inv(y)

    def maybe_read(self, v, *, use_pallas: bool = False,
                   interpret: bool = True):
        if isinstance(v, dict):
            return self.read(v, use_pallas=use_pallas,
                             interpret=interpret)
        return v

    def zero_like(self, stored):
        # q=0 at scale 1/127 IS init(zeros): the scale refs map all-zero
        # rows/groups to amax 1.0 -> scale 1/127 (dequant stays a plain
        # multiply), so a canonically-zeroed RESYNC row bit-matches a
        # freshly initialised one. Companding preserves this: the sqrt
        # transform fixes 0 in both directions.
        return {"q": jnp.zeros_like(stored["q"]),
                "scale": jnp.full_like(stored["scale"], 1.0 / 127.0)}

    # ------------------------------------------------------- accounting
    def scale_count(self, width: int) -> int:
        return 1 if self.group is None else -(-width // self.group)

    def resident_bytes(self, rows: int, width: int) -> int:
        return rows * (width + self.scale_count(width) * self.SCALE_BYTES)


STORAGE = {
    "f32": F32Storage(),
    "bf16": Bf16Storage(),
    # moment-safe int8: signed-sqrt companded, grouped scales. "int8g"
    # trades extra scale sidecar (g=32 vs g=128) for tighter groups.
    "int8": Int8Storage("int8", group=128, transform="sqrt"),
    "int8g": Int8Storage("int8g", group=32, transform="sqrt"),
    # raw linear per-row int8 (the wire codec's storage layout): fine
    # for parameter-scaled residual panels (wire_err, stats), UNSAFE
    # for Adam moments — see the module docstring
    "int8r": Int8Storage("int8r"),
}


def get_storage(name):
    """Resolve a storage codec by registry name; Storage instances pass
    through (mirrors wire.get_codec / merging.get_merger)."""
    if not isinstance(name, str) and hasattr(name, "resident_bytes"):
        return name
    try:
        return STORAGE[name]
    except KeyError:
        raise ValueError(
            f"unknown storage codec {name!r}; known: {sorted(STORAGE)}"
        ) from None


def storage_keys(storages: dict, key):
    """One SR key per dtype group that needs one, folded in sorted-group
    order so sharded and replicated runs store identical bits (the
    exact discipline of ``panel._wire_keys``)."""
    names = sorted(k for k, s in storages.items() if s.needs_key)
    if not names:
        return {k: None for k in storages}
    if key is None:
        raise ValueError(
            f"storage codecs for groups {names} use stochastic rounding "
            "and need an explicit key=")
    folded = {k: jax.random.fold_in(key, i) for i, k in enumerate(names)}
    return {k: folded.get(k) for k in storages}


def parse_policy(policy):
    """CLI residency policy -> {kind: storage-name}.

    ``None``/empty -> {} (no policy); ``'kind=name,kind=name'`` pairs
    (``--residency moments=int8,stats=bf16``); a bare storage name
    applies to the moments (the dominant state panels). Kinds and names
    are validated here so a typo fails at parse time."""
    if not policy:
        return {}
    if isinstance(policy, dict):
        mapping = dict(policy)
    elif "=" in policy:
        mapping = {}
        for part in policy.split(","):
            kind, _, name = part.partition("=")
            mapping[kind.strip()] = name.strip()
    else:
        mapping = {"moments": policy.strip()}
    unknown = set(mapping) - set(KINDS)
    if unknown:
        raise ValueError(
            f"residency policy names unknown state kinds "
            f"{sorted(unknown)}; known kinds: {list(KINDS)}")
    for name in mapping.values():
        get_storage(name)
    return mapping
