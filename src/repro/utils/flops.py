"""Analytic parameter counts and MODEL_FLOPS (6·N·D) for the roofline table.

N (and N_active for MoE) are derived from the *actual* initialised shapes
(via jax.eval_shape over Model.init_params) so they track the real configs,
not hand-derived formulas. D is the number of trained tokens in the step.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _count(tree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def param_counts(model) -> dict:
    """{'total': N, 'active': N_active} from the init shapes."""
    cfg: ModelConfig = model.cfg
    shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    total = _count(shapes)
    active = total
    if cfg.moe is not None:
        # routed experts: only top_k/E of expert params are active per token
        def moe_leaves(tree):
            n = 0
            if isinstance(tree, dict):
                for k, v in tree.items():
                    if k == "ffn" and isinstance(v, dict) and "router" in v:
                        for kk in ("w_in", "w_gate", "w_out"):
                            if kk in v:
                                n += int(np.prod(v[kk].shape))
                    else:
                        n += moe_leaves(v)
            return n
        routed = moe_leaves(shapes)
        frac = cfg.moe.top_k / cfg.moe.num_experts
        active = total - routed + int(routed * frac)
    return {"total": total, "active": active}


def model_flops(model, shape: ShapeConfig) -> dict:
    """MODEL_FLOPS for one step: 6*N_active*D train, 2*N_active*D inference
    (+ attention term reported separately)."""
    cfg: ModelConfig = model.cfg
    counts = param_counts(model)
    n_act = counts["active"]
    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        base = 6 * n_act * D
    elif shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        base = 2 * n_act * D
    else:  # decode: one token per request
        D = shape.global_batch
        base = 2 * n_act * D
    # attention score/value FLOPs (full attention; window caps the length)
    S = shape.seq_len
    a = cfg.attn
    eff = S
    win = cfg.layer_period[0].window
    n_attn_layers = sum(1 for s in cfg.layer_specs()
                        if s.mixer in ("gqa", "mla"))
    if all(s.window for s in cfg.layer_specs() if s.mixer == "gqa"):
        eff = min(S, max((s.window or S) for s in cfg.layer_specs()))
    if shape.kind == "decode":
        attn = (4 * shape.global_batch * eff * a.num_heads * a.head_dim
                * n_attn_layers)
    else:
        mult = 12 if shape.kind == "train" else 4
        attn = (mult * shape.global_batch * S * eff // 2 * a.num_heads
                * a.head_dim * n_attn_layers)
    return {"model_flops": int(base), "attn_flops": int(attn),
            "tokens": D, **counts}
