"""Parse collective traffic out of optimized HLO text.

``collective_bytes(hlo_text)`` builds a symbol table of result shapes, then
sums *operand* bytes of every communication op:
  all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
counting ``-start`` ops once (their ``-done`` twins are skipped). Tuple
shapes are summed over components. Ops inside while-loop bodies are
multiplied by the loop trip count when it is statically recoverable from
the HLO (scan-over-layers makes this essential).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^)=]*\)?)\s*"
                     r"([\w\-]+)\(")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo: str):
    """Returns (per_kind_bytes: dict, total_bytes: int).

    Bytes = result bytes of each collective op (for all-gather this is the
    gathered size; for all-reduce the tensor size; both are what crosses the
    wire per participating device up to the ring factor)."""
    lines = hlo.splitlines()

    # trip counts: find while ops with known trip count in backend config
    # XLA optimized HLO annotates known trip counts as
    # "known_trip_count":{"n":"12"} inside while backend_config.
    per_kind = defaultdict(int)
    count = defaultdict(int)

    # build nested computation -> trip count map
    comp_trip = {}
    cur_comp = None
    comp_re = re.compile(r"^(%?[\w.\-]+)\s*(\([^)]*\))?\s*->.*{$|^ENTRY")
    body_of = {}
    for ln in lines:
        mwhile = re.search(r"while\(", ln)
        if mwhile:
            mtrip = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ln)
            mbody = re.search(r"body=%?([\w.\-]+)", ln)
            if mbody:
                body_of[mbody.group(1)] = (
                    int(mtrip.group(1)) if mtrip else 1)

    cur = None
    cur_mult = 1
    for ln in lines:
        mdef = re.match(r"^%?([\w.\-]+)\s*(\([^{]*\))?\s*->\s*.*\{\s*$", ln)
        if mdef:
            cur = mdef.group(1)
            cur_mult = body_of.get(cur, 1)
            continue
        if ln.startswith("ENTRY"):
            cur = "__entry__"
            cur_mult = 1
            continue
        stripped = ln.strip()
        for kind in _COLLECTIVES:
            # match "= <shape> <kind>(" or "<kind>-start("
            m = re.search(r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
                          + kind + r"(-start)?\(", stripped)
            if m:
                b = shape_bytes(m.group(1))
                per_kind[kind] += b * cur_mult
                count[kind] += cur_mult
                break
            if re.search(kind + r"-done\(", stripped):
                break
    total = sum(per_kind.values())
    return dict(per_kind), total, dict(count)
