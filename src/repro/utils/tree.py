"""Pytree helpers used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_stack(trees):
    """Stack a list of identical pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree, n):
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_vdot(a, b):
    leaves = jax.tree.leaves(jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b))
    return sum(leaves)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(tree)))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def param_count(tree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def param_bytes(tree) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    oks = jax.tree.leaves(jax.tree.map(
        lambda x, y: bool(jnp.allclose(x, y, rtol=rtol, atol=atol)), a, b))
    return all(oks)
