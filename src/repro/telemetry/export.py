"""Periodic JSON snapshot export of a run's telemetry state.

:class:`SnapshotExporter` is a live reduction over the deterministic
event stream: attach it as ``EventLog(sink=...)`` and it folds every
emitted record into a compact summary — event counts by type, the
latest round metrics, eval history, fault tally, the policy's
per-agent resident bytes — and rewrites ONE JSON snapshot file
atomically every ``every`` round events. Dashboards and schedulers poll
the snapshot instead of tailing and re-parsing the full JSONL stream;
the stream stays the byte-identical record (the exporter never writes
into it).

Latency histograms from other subsystems (the serving engine's TTFT /
decode panels) fold in via :meth:`SnapshotExporter.merge_hist`, which
accumulates through :meth:`repro.telemetry.latency.Histogram.merge` —
snapshots carry their compact ``summary()`` rows.

The module is also the offline CLI for finished runs::

    python -m repro.telemetry.export events.jsonl \
        [--out snapshot.json] [--every 0]

which replays a recorded stream through the same reduction and writes
the final snapshot (``--every N`` additionally writes every N rounds
while replaying, mirroring the live cadence).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional

from repro.telemetry.events import SCHEMA_VERSION, read_events


def _atomic_json(path: str, obj) -> None:
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1, sort_keys=True)
        f.write("\n")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


class SnapshotExporter:
    """Fold deterministic events into a periodically-written snapshot.

    ``every=N`` rewrites the snapshot after every N ``round`` events
    (and on :meth:`close`); ``every=0`` disables the cadence — only
    explicit :meth:`write` / :meth:`close` calls touch the file.
    ``path=None`` keeps the reduction in memory (``snapshot()`` for
    tests and the CLI)."""

    def __init__(self, path: Optional[str] = None, *, every: int = 1):
        if every < 0:
            raise ValueError(f"every must be >= 0, got {every}")
        self.path = path
        self.every = int(every)
        self.counts: dict = {}
        self.last_round: Optional[dict] = None
        self.run: dict = {}
        self.evals: list = []
        self.faults: int = 0
        self.resident_bytes: Optional[int] = None
        self.hists: dict = {}
        self._rounds_since_write = 0

    # ------------------------------------------------------------ folding
    def __call__(self, ev: dict) -> None:
        """The ``EventLog.sink`` entry point: fold one event record."""
        t = ev.get("type")
        self.counts[t] = self.counts.get(t, 0) + 1
        if t in ("run_start", "serve_start"):
            self.run = {"run_id": ev.get("run_id"),
                        "schema": ev.get("schema"),
                        "config": ev.get("config")}
        elif t == "round":
            self.last_round = {k: v for k, v in ev.items()
                               if k not in ("type", "seq")
                               and not isinstance(v, list)}
            if ev.get("resident_bytes") is not None:
                self.resident_bytes = ev["resident_bytes"]
            self._rounds_since_write += 1
            if (self.path is not None and self.every
                    and self._rounds_since_write >= self.every):
                self.write()
        elif t == "eval":
            self.evals.append({"round": ev.get("round"),
                               "merged_eval": ev.get("merged_eval"),
                               "local_eval": ev.get("local_eval")})
        elif t == "fault":
            self.faults += 1
        elif t in ("run_end", "serve_end"):
            self.run = {**self.run, "end": {
                k: v for k, v in ev.items() if k not in ("type", "seq")}}

    def merge_hist(self, name: str, hist) -> None:
        """Accumulate a latency histogram under ``name`` (snapshots carry
        its summary row); repeated merges fold via Histogram.merge."""
        if name in self.hists:
            self.hists[name].merge(hist)
        else:
            # a private accumulator: merging into the caller's live
            # histogram would double-count its future updates
            import copy
            self.hists[name] = copy.deepcopy(hist)

    # ------------------------------------------------------------- output
    def snapshot(self) -> dict:
        out = {
            "schema": SCHEMA_VERSION,
            "events": dict(sorted(self.counts.items())),
            "run": self.run,
            "last_round": self.last_round,
            "faults": self.faults,
        }
        if self.resident_bytes is not None:
            out["resident_bytes_per_agent"] = self.resident_bytes
        if self.evals:
            out["evals"] = self.evals
        if self.hists:
            out["latency"] = {k: h.summary()
                              for k, h in sorted(self.hists.items())}
        return out

    def write(self) -> dict:
        """Atomically rewrite the snapshot file; returns the snapshot."""
        snap = self.snapshot()
        if self.path is not None:
            _atomic_json(self.path, snap)
        self._rounds_since_write = 0
        return snap

    def close(self) -> dict:
        """Final write (the run's last state always lands on disk)."""
        return self.write()


def export_stream(events_path: str, out_path: Optional[str] = None, *,
                  every: int = 0) -> dict:
    """Replay a recorded events JSONL through the snapshot reduction;
    returns (and optionally writes) the final snapshot."""
    exp = SnapshotExporter(out_path, every=every)
    for ev in read_events(events_path):
        exp(ev)
    return exp.close() if out_path is not None else exp.snapshot()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Reduce an events JSONL stream to a JSON snapshot")
    ap.add_argument("events", help="deterministic events .jsonl file")
    ap.add_argument("--out", default=None,
                    help="snapshot path (default: <events>.snapshot.json)")
    ap.add_argument("--every", type=int, default=0,
                    help="also rewrite the snapshot every N rounds while "
                         "replaying (0 = final only)")
    args = ap.parse_args(argv)
    out = args.out
    if out is None:
        base = args.events
        if base.endswith(".jsonl"):
            base = base[:-len(".jsonl")]
        out = base + ".snapshot.json"
    snap = export_stream(args.events, out, every=args.every)
    n = sum(snap["events"].values())
    print(f"{out}: {n} events "
          f"({snap['events'].get('round', 0)} rounds) reduced")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
