"""Unified telemetry subsystem: the observability layer every later
direction (schedule search, async gossip, scenario matrix) reports
through.

* :mod:`repro.telemetry.events`  — versioned JSONL event log (typed,
  deterministic payload + wall-clock sidecar, console sink,
  truncate-on-resume) and its schema validator.
* :mod:`repro.telemetry.metrics` — per-agent (m,) metric panels computed
  on-device inside the segment scan (loss, grad norm, distance-to-mean,
  liveness, exact codec wire bytes).
* :mod:`repro.telemetry.latency` — fixed-bucket latency histograms for
  the serving engine (TTFT, queue wait, decode step, per-token).
* :mod:`repro.telemetry.trace`   — ``named_scope`` / ``TraceAnnotation``
  / profiler-capture hooks.
* :mod:`repro.telemetry.export`  — periodic JSON snapshot reduction over
  the event stream (``EventLog(sink=SnapshotExporter(...))``) and the
  offline ``python -m repro.telemetry.export`` CLI.
"""
from repro.telemetry.events import (EVENT_SCHEMAS, SCHEMA_VERSION, EventLog,
                                    format_event, make_run_id, read_events,
                                    validate_event, validate_stream,
                                    wall_path)
from repro.telemetry.export import SnapshotExporter, export_stream
from repro.telemetry.latency import Histogram, default_bounds, histogram_set
from repro.telemetry.trace import annotate, profile_trace, scope

__all__ = [
    "EVENT_SCHEMAS", "SCHEMA_VERSION", "EventLog", "format_event",
    "make_run_id", "read_events", "validate_event", "validate_stream",
    "wall_path", "SnapshotExporter", "export_stream",
    "Histogram", "default_bounds", "histogram_set",
    "annotate", "profile_trace", "scope",
]
