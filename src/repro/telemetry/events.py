"""Structured, versioned event log: the run's trajectory as typed JSONL.

Two streams per run:

* **Deterministic events** (``events.jsonl``) — the trajectory record:
  run/round/merge/eval/fault lifecycle for training, request lifecycle for
  serving. Every record is a single JSON line with sorted keys, a
  monotonically increasing ``seq``, and a ``type`` validated against
  :data:`EVENT_SCHEMAS` at emit time. The payload carries NO wall-clock
  values, so two runs of the same configuration — including a baseline vs
  a SIGKILL + ``--resume`` pair — produce BYTE-IDENTICAL streams (the
  contract ``scripts/fault_smoke.py`` checks).
* **Wall-clock sidecar** (``events.wall.jsonl``) — operational records
  (:meth:`EventLog.emit_op`): per-event timestamps, segment wall times,
  checkpoint save/restore, profiler start/stop, serve latency notes.
  Free-schema, append-only, never compared across runs.

Appends are a SINGLE ``write()`` of the full line on a file opened in
append mode, flushed per event, so a crash never leaves a torn line and
concurrent emitters (the async checkpoint thread) interleave whole
records. :meth:`EventLog.truncate` rewrites the deterministic stream to
its first ``n`` records — the resume hook: the launcher checkpoints
``seq`` with the train state and truncates back to it before continuing,
giving exactly-once round events across kill/resume.

``run_id`` is a HASH of the run configuration (:func:`make_run_id`), not
a uuid/timestamp — determinism extends to the id itself.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import time
from typing import Callable, Dict, List, Optional

# v2: 'round' gains the optional 'resident_bytes' field — the exact
# per-agent resident-HBM cost of the run's residency policy
# (metrics.resident_bytes_model), a host constant stamped on every
# round. v1 streams (no such field) still validate.
# v3: 'round' gains the optional 'transient_bytes' field — the in-round
# peak of the f32 decode views the unfused storage path materializes
# (zero when the fused moment kernel is active); 'resident_bytes' stays
# the STORED total, so peak per-agent HBM is the sum of the two. Older
# streams still validate.
SCHEMA_VERSION = 3

# Field types: int / float / str / bool / dict / id (int-or-str) /
# list[float] / list[int]; a '?' prefix marks the field optional.
EVENT_SCHEMAS: Dict[str, Dict[str, str]] = {
    # ---------------------------------------------------------- training
    "run_start": {"run_id": "str", "schema": "int", "config": "dict"},
    "round": {
        "round": "int", "loss": "float", "grad_norm": "float",
        "grad_norm_max": "float", "consensus": "float",
        "comm_cost_P": "float",
        # per-agent metric panels (--telemetry): one entry per agent
        "loss_agent": "?list[float]", "grad_norm_agent": "?list[float]",
        "dist_to_mean": "?list[float]", "live": "?list[int]",
        "wire_bytes": "?list[int]",
        # per-agent resident HBM bytes under the residency policy (v2)
        "resident_bytes": "?int",
        # per-agent transient f32 decode-view bytes of the unfused
        # storage path; 0 under the fused moment kernel (v3)
        "transient_bytes": "?int",
    },
    "merge": {"round": "int", "operator": "str"},
    "eval": {"round": "int", "merged_eval": "float", "local_eval": "float"},
    "fault": {"round": "int", "agent": "int", "kind": "str"},  # kill|rejoin
    "run_end": {"rounds": "int", "final_loss": "float",
                "comm_cost_P": "float"},
    # ----------------------------------------------------------- serving
    "serve_start": {"run_id": "str", "schema": "int", "config": "dict"},
    "request_submit": {"rid": "id", "prompt_len": "int", "max_new": "int"},
    "request_admit": {"rid": "id", "slot": "int", "tick": "int"},
    "request_retire": {"rid": "id", "slot": "int", "tick": "int",
                       "tokens": "int"},
    "serve_end": {"requests": "int", "tokens": "int", "ticks": "int",
                  "occupancy": "float"},
}

# fields every record carries, written by the log itself
_RESERVED = ("type", "seq")


def wall_path(path: str) -> str:
    """Sidecar path for an events file: ``x.jsonl`` -> ``x.wall.jsonl``."""
    if path.endswith(".jsonl"):
        return path[:-len(".jsonl")] + ".wall.jsonl"
    return path + ".wall"


def make_run_id(config: dict) -> str:
    """Deterministic 12-hex run id from the run configuration (the same
    config — baseline or resumed — maps to the same id)."""
    blob = json.dumps(config, sort_keys=True, separators=(",", ":"),
                      default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:12]


def _jsonable(v):
    """numpy scalars/arrays -> plain Python so json emits canonical text."""
    if hasattr(v, "item") and not hasattr(v, "__len__"):
        return v.item()
    if hasattr(v, "tolist"):
        return v.tolist()
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    return v


def _check_type(v, spec: str) -> bool:
    if spec.startswith("list["):
        inner = spec[5:-1]
        return (isinstance(v, list)
                and all(_check_type(x, inner) for x in v))
    if spec == "int":
        return isinstance(v, int) and not isinstance(v, bool)
    if spec == "float":  # json ints are acceptable floats
        return (isinstance(v, (int, float))
                and not isinstance(v, bool))
    if spec == "str":
        return isinstance(v, str)
    if spec == "bool":
        return isinstance(v, bool)
    if spec == "dict":
        return isinstance(v, dict)
    if spec == "id":
        return isinstance(v, (int, str)) and not isinstance(v, bool)
    raise ValueError(f"unknown schema field type {spec!r}")


def validate_event(ev: dict) -> List[str]:
    """Schema errors for ONE decoded event record ([] = valid): unknown
    type, missing/unknown fields, wrong field types, bad seq."""
    errors = []
    etype = ev.get("type")
    if not isinstance(etype, str) or etype not in EVENT_SCHEMAS:
        return [f"unknown event type {etype!r}"]
    if not isinstance(ev.get("seq"), int):
        errors.append(f"{etype}: missing/non-int 'seq'")
    schema = EVENT_SCHEMAS[etype]
    for name, spec in schema.items():
        optional = spec.startswith("?")
        tspec = spec[1:] if optional else spec
        if name not in ev:
            if not optional:
                errors.append(f"{etype}: missing required field {name!r}")
            continue
        if not _check_type(ev[name], tspec):
            errors.append(f"{etype}: field {name!r} is not a {tspec}: "
                          f"{ev[name]!r}")
    for name in ev:
        if name not in schema and name not in _RESERVED:
            errors.append(f"{etype}: unknown field {name!r}")
    return errors


def validate_stream(path: str) -> List[str]:
    """Validate a whole events JSONL file. Checks every record's schema,
    that ``seq`` increments from 0 with no gaps or duplicates, and that
    ``round`` events' rounds are strictly increasing (no duplicated or
    missing rounds across a resume)."""
    errors = []
    last_round = None
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                errors.append(f"line {i}: empty line")
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"line {i}: bad JSON ({e})")
                continue
            errors += [f"line {i}: {e}" for e in validate_event(ev)]
            if ev.get("seq") != i:
                errors.append(f"line {i}: seq {ev.get('seq')!r} != line "
                              "index (gap or duplicate)")
            if ev.get("type") == "round":
                r = ev.get("round")
                if last_round is not None and r != last_round + 1:
                    errors.append(
                        f"line {i}: round {r} after round {last_round} "
                        "(duplicated or missing round event)")
                last_round = r
    return errors


def read_events(path: str) -> List[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def format_event(ev: dict) -> Optional[str]:
    """Human console line for a deterministic event (None = silent)."""
    t = ev.get("type")
    if t == "round":
        s = (f"[{ev['round']:4d}] loss={ev['loss']:.4f} "
             f"gn={ev['grad_norm']:.3f}/{ev['grad_norm_max']:.3f} "
             f"Xi={ev['consensus']:.3f} comm={ev['comm_cost_P']:.1f}P")
        if "live" in ev:
            s += f" live={sum(1 for x in ev['live'] if x == 1)}"
        return s
    if t == "eval":
        return (f"[{ev['round']:4d}] local={ev['local_eval']:.4f} "
                f"merged={ev['merged_eval']:.4f}")
    if t == "merge":
        return f"[{ev['round']:4d}] global merge ({ev['operator']})"
    if t == "fault":
        return f"[{ev['round']:4d}] fault: agent {ev['agent']} {ev['kind']}"
    if t == "run_start":
        return f"run {ev['run_id']} (events schema v{ev['schema']})"
    if t == "run_end":
        return (f"run end: {ev['rounds']} rounds, final loss "
                f"{ev['final_loss']:.4f}, comm {ev['comm_cost_P']:.1f}P")
    if t == "serve_end":
        return (f"serve end: {ev['requests']} requests / {ev['tokens']} "
                f"tokens in {ev['ticks']} ticks, occupancy "
                f"{ev['occupancy']:.2f}")
    return None


class EventLog:
    """Versioned JSONL event stream + wall-clock sidecar (module doc).

    ``path=None`` keeps the log console-only (events are validated and
    echoed but nothing is written) — the launcher's default sink when no
    ``--events`` file is requested. ``echo`` prints
    :func:`format_event`'s line for each deterministic event.
    ``resume_at=n`` truncates an existing stream to its first ``n``
    records and continues appending at ``seq=n`` (sidecar untouched —
    operational history keeps both lives of the run).
    """

    def __init__(self, path: Optional[str] = None, *, run_id: str = "",
                 echo: bool = False, resume_at: Optional[int] = None,
                 sidecar: bool = True,
                 sink: Optional[Callable[[dict], None]] = None):
        self.path = path
        self.run_id = run_id
        self.echo = echo
        self.sink = sink
        self.seq = 0
        self._lock = threading.Lock()
        self._f = self._wf = None
        if path is not None:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            if resume_at is not None:
                self.seq = self.truncate_file(path, resume_at)
                mode = "a"
            else:
                mode = "w"
            self._f = open(path, mode)
            if sidecar:
                self._wf = open(wall_path(path), "a")

    # ------------------------------------------------------------- emit
    def emit(self, etype: str, **fields) -> dict:
        """Append one validated deterministic event; returns the record."""
        ev = {"type": etype, "seq": self.seq}
        ev.update({k: _jsonable(v) for k, v in fields.items()})
        errors = validate_event(ev)
        if errors:
            raise ValueError("invalid event: " + "; ".join(errors))
        line = json.dumps(ev, sort_keys=True, separators=(",", ":"))
        with self._lock:
            if self._f is not None:
                self._f.write(line + "\n")  # one write: no torn lines
                self._f.flush()
            if self._wf is not None:
                self._wf.write(json.dumps(
                    {"seq": ev["seq"], "type": etype, "t": time.time()},
                    sort_keys=True, separators=(",", ":")) + "\n")
                self._wf.flush()
            self.seq += 1
        if self.echo:
            line = format_event(ev)
            if line:
                print(line, flush=True)
        if self.sink is not None:
            self.sink(ev)
        return ev

    def emit_op(self, etype: str, **fields) -> None:
        """Append an OPERATIONAL record to the wall-clock sidecar only:
        wall times welcome, schema free, never part of the deterministic
        stream. Thread-safe (the async checkpoint thread calls this)."""
        rec = {"op": etype, "t": time.time()}
        rec.update({k: _jsonable(v) for k, v in fields.items()})
        with self._lock:
            if self._wf is not None:
                self._wf.write(json.dumps(rec, sort_keys=True,
                                          separators=(",", ":")) + "\n")
                self._wf.flush()

    # ------------------------------------------------------------ resume
    @staticmethod
    def truncate_file(path: str, n: int) -> int:
        """Rewrite ``path`` to its first ``n`` records (atomic replace).
        Returns ``n``. A missing file is only acceptable at ``n == 0``."""
        if n < 0:
            raise ValueError(f"cannot truncate to {n} events")
        if not os.path.exists(path):
            if n == 0:
                return 0
            raise FileNotFoundError(
                f"resume expects {n} events at {path}, found no file")
        with open(path) as f:
            lines = f.readlines()
        if len(lines) < n:
            raise ValueError(
                f"resume expects {n} events at {path}, found {len(lines)}")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.writelines(lines[:n])
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        return n

    def close(self) -> None:
        with self._lock:
            for f in (self._f, self._wf):
                if f is not None:
                    f.close()
            self._f = self._wf = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
