"""Trace and profiler hooks.

Three layers, all safe to leave in hot code:

* :func:`scope` — ``jax.named_scope``: names the ops a traced region
  emits, so HLO dumps and profiler timelines show ``panel.mix/float32``
  instead of ``dot_general.127``. Zero runtime cost (trace-time only).
* :func:`annotate` — ``jax.profiler.TraceAnnotation``: a HOST-side span
  on the profiler timeline (scheduler work: admit, step, checkpoint).
  Nullcontext when the profiler backend is unavailable.
* :func:`profile_trace` — capture a jax profiler trace into a logdir
  (``--profile`` in the launchers). Degrades to a warning + no-op if the
  profiler cannot start in this environment (it must never take down a
  training run).
"""
from __future__ import annotations

import contextlib
import warnings

import jax


def scope(name: str):
    """Trace-time op-name scope (see module docstring)."""
    return jax.named_scope(name)


def annotate(name: str, **kwargs):
    """Host-side profiler span; no-op where TraceAnnotation is missing."""
    try:
        return jax.profiler.TraceAnnotation(name, **kwargs)
    except Exception:
        return contextlib.nullcontext()


class profile_trace:
    """Context manager capturing a jax profiler trace into ``logdir``.

    ``enabled=False`` makes it a no-op (so call sites can pass the CLI
    flag straight through); a profiler that fails to start or stop only
    warns. ``bool(ctx)`` inside the block reports whether a trace is
    actually being captured."""

    def __init__(self, logdir: str, enabled: bool = True):
        self.logdir = logdir
        self.enabled = enabled
        self.active = False

    def __bool__(self):
        return self.active

    def start(self):
        if not self.enabled or self.active:
            return self
        try:
            jax.profiler.start_trace(self.logdir)
            self.active = True
        except Exception as e:  # missing backend, busy profiler, ...
            warnings.warn(f"jax profiler trace could not start: {e}",
                          RuntimeWarning)
        return self

    def stop(self):
        if not self.active:
            return
        self.active = False
        try:
            jax.profiler.stop_trace()
        except Exception as e:
            warnings.warn(f"jax profiler trace could not stop: {e}",
                          RuntimeWarning)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
