"""Fixed-bucket latency histograms for the serving path.

Prometheus-style: a fixed, log-spaced bucket ladder chosen ONCE at
construction (8 buckets per decade, 1 us .. ~100 s by default), so
recording is O(log B) with no allocation, snapshots are mergeable, and
percentiles are estimated by linear interpolation inside the bucket —
exactly the shape a scrape/export layer wants, unlike a growing list of
raw samples. Values are plain floats in SECONDS; summaries report
microseconds where the serving bench wants them.
"""
from __future__ import annotations

import math
from typing import List, Optional

import numpy as np


def default_bounds() -> np.ndarray:
    """Bucket upper bounds: 1 us .. ~100 s, 8 per decade (65 bounds)."""
    return 1e-6 * (10.0 ** (np.arange(65) / 8.0))


class Histogram:
    """Fixed-bucket histogram of nonnegative floats (seconds)."""

    def __init__(self, bounds: Optional[np.ndarray] = None):
        self.bounds = np.asarray(
            default_bounds() if bounds is None else bounds, np.float64)
        if self.bounds.ndim != 1 or len(self.bounds) < 1 or not np.all(
                np.diff(self.bounds) > 0):
            raise ValueError("bounds must be a 1-D increasing array")
        # counts[i] <= bounds[i]; counts[-1] is the overflow bucket
        self.counts = np.zeros(len(self.bounds) + 1, np.int64)
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = 0.0

    def record(self, value: float, n: int = 1) -> None:
        """Record ``value`` (``n`` times — e.g. one decode-step latency
        counted once per live slot for the per-token view)."""
        v = float(value)
        i = int(np.searchsorted(self.bounds, v, side="left"))
        self.counts[i] += n
        self.n += n
        self.total += v * n
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def reset(self) -> None:
        self.counts[:] = 0
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = 0.0

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def percentile(self, p: float) -> float:
        """Bucket-interpolated p-quantile (p in [0, 100]), clamped to the
        observed [min, max]."""
        if not self.n:
            return 0.0
        target = (p / 100.0) * self.n
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            lo = self.bounds[i - 1] if i > 0 else 0.0
            hi = self.bounds[i] if i < len(self.bounds) else self.vmax
            if cum + c >= target:
                frac = (target - cum) / c
                est = lo + frac * (hi - lo)
                return float(min(max(est, self.vmin), self.vmax))
            cum += c
        return float(self.vmax)

    def summary(self) -> dict:
        """Compact export row: count, mean, p50/p90/p99, min/max (s)."""
        if not self.n:
            return {"count": 0}
        return {"count": int(self.n),
                "mean_s": float(self.mean),
                "p50_s": self.percentile(50),
                "p90_s": self.percentile(90),
                "p99_s": self.percentile(99),
                "min_s": float(self.vmin),
                "max_s": float(self.vmax)}

    def summary_us(self) -> dict:
        """summary() with latencies in rounded microseconds (bench/CLI)."""
        return {k.replace("_s", "_us"):
                (round(v * 1e6, 1) if k.endswith("_s") else v)
                for k, v in self.summary().items()}

    def to_dict(self, sparse: bool = True) -> dict:
        """Full export incl. bucket counts; ``sparse`` keeps only nonzero
        buckets as {upper-bound: count} (readable in BENCH json)."""
        out = self.summary()
        if sparse:
            out["buckets"] = {
                ("+inf" if i == len(self.bounds)
                 else f"{self.bounds[i]:.3g}"): int(c)
                for i, c in enumerate(self.counts) if c}
        else:
            out["bounds"] = [float(b) for b in self.bounds]
            out["counts"] = [int(c) for c in self.counts]
        return out

    def merge(self, other: "Histogram") -> "Histogram":
        # adding counts bucket-by-bucket is only meaningful on identical
        # ladders: merging a custom-``bounds`` snapshot into a default
        # one would silently mis-bin every sample, so refuse loudly and
        # name the first divergence
        if len(other.bounds) != len(self.bounds):
            raise ValueError(
                "cannot merge histograms with different bucket ladders: "
                f"{len(self.bounds)} bounds vs {len(other.bounds)}")
        if not np.all(other.bounds == self.bounds):
            i = int(np.argmax(other.bounds != self.bounds))
            raise ValueError(
                "cannot merge histograms with different bucket ladders: "
                f"bounds diverge at index {i} "
                f"({self.bounds[i]!r} vs {other.bounds[i]!r})")
        self.counts += other.counts
        self.n += other.n
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self


def histogram_set(names: List[str]) -> dict:
    """{name: fresh Histogram} — the engine's standard latency panel."""
    return {name: Histogram() for name in names}
