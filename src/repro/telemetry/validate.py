"""Schema-validate telemetry event streams (the CI gate).

    python -m repro.telemetry.validate events.jsonl [more.jsonl ...]

Exit 0 iff every file parses, every record matches its
:data:`repro.telemetry.events.EVENT_SCHEMAS` entry (unknown types,
missing required fields and UNKNOWN fields all fail), seq is gapless
from 0, and round events are contiguous. Prints a per-file verdict and
the first errors."""
from __future__ import annotations

import argparse
import json
import sys

from repro.telemetry.events import read_events, validate_stream


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate telemetry events JSONL files")
    ap.add_argument("paths", nargs="+")
    ap.add_argument("--max-errors", type=int, default=10,
                    help="errors printed per file")
    args = ap.parse_args(argv)

    failed = False
    for path in args.paths:
        try:
            errors = validate_stream(path)
            n = len(read_events(path))
        except OSError as e:
            print(f"{path}: UNREADABLE ({e})")
            failed = True
            continue
        if errors:
            failed = True
            print(f"{path}: INVALID ({len(errors)} errors over {n} events)")
            for e in errors[:args.max_errors]:
                print(f"  - {e}")
            if len(errors) > args.max_errors:
                print(f"  ... {len(errors) - args.max_errors} more")
        else:
            print(f"{path}: ok ({n} events)")
    print(json.dumps({"ok": not failed, "files": len(args.paths)}))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
