"""Per-agent metric panels: on-device (m,) observables for the segment
scan.

The segment driver (``dsgd.make_panel_segment(telemetry=True)``) stacks
these per-round vectors into (S, m) metric arrays — per-agent loss, grad
norm, distance-to-mean (the consensus decomposition), liveness trit and
wire bytes — returned alongside the scalar metrics in the SAME single
``device_get`` per segment. Everything here is a pure read of panels the
round already materialized: telemetry must never perturb the trajectory
(pinned by tests/test_telemetry.py).

Wire-byte accounting reuses the exact codec cost model
(:attr:`PanelSpec.wire_total_bytes` — payload + scales/indices): a row
of W equal to the identity row communicates nothing and pays 0; a delta
(mirror) codec's GLOBAL round is full bandwidth by design
(``panel.global_merge``), so it pays the storage bytes; a RESYNC agent
pays the full-precision pull. Bytes are int32 — exact up to 2 GiB per
agent-round, which covers every panel this repo ships (a 1B-param f32
panel is ~4 GB and would need the dryrun byte model instead).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def agent_loss(losses, alive=None):
    """(m,) per-agent loss; non-live rows report 0 (they took no step)."""
    if alive is None:
        return losses.astype(jnp.float32)
    return jnp.where(alive, losses.astype(jnp.float32), 0.0)


def agent_grad_norm(gpan, alive=None):
    """(m,) per-agent gradient l2 norm across all dtype groups of a grad
    panel; non-live rows report 0."""
    total = None
    for x in gpan.values():
        x32 = x.astype(jnp.float32)
        sq = jnp.sum(x32 * x32, axis=tuple(range(1, x32.ndim)))
        total = sq if total is None else total + sq
    gn = jnp.sqrt(total)
    if alive is None:
        return gn
    return jnp.where(alive, gn, 0.0)


def agent_dist_to_mean(panel, live=None):
    """(m,) per-agent distance to the panel mean — the consensus
    decomposition: ``consensus_distance`` is exactly
    ``sqrt(mean(dist**2))`` of these rows (live-weighted under a
    liveness mask). Dead/stale rows still report their distance to the
    LIVE mean: how far a stale agent has drifted is precisely the
    straggler signal the per-agent panel exists for."""
    first = next(iter(panel.values()))
    m = first.shape[0]
    if live is None:
        w = jnp.full((m,), 1.0 / m, jnp.float32)
    else:
        lf = live.astype(jnp.float32)
        w = lf / jnp.maximum(jnp.sum(lf), 1.0)
    total = jnp.zeros((m,), jnp.float32)
    for x in panel.values():
        x32 = x.astype(jnp.float32)
        mean = jnp.tensordot(w, x32, axes=1)
        total = total + jnp.sum(jnp.square(x32 - mean[None]), axis=1)
    return jnp.sqrt(total)


def wire_bytes_model(spec, wire_dtype=None):
    """Host-side (bytes_wire, bytes_full) per agent per full-panel
    exchange: the codec-aware wire cost (``spec.wire_total_bytes``, or
    the legacy cast's itemsize model) and the full-precision storage
    cost (what a delta codec's global round or a RESYNC pull moves)."""
    bytes_full = sum(jnp.dtype(k).itemsize * w for k, w in spec.groups)
    if wire_dtype is not None:
        it = jnp.dtype(wire_dtype).itemsize
        return sum(it * w for _, w in spec.groups), bytes_full
    return spec.wire_total_bytes, bytes_full


def fused_moments_auto(spec, optimizer) -> bool:
    """Whether the fused in-VMEM moment update (kernels/opt_fused.py)
    applies to this spec+optimizer — the single eligibility predicate
    the segment driver, the accounting models and the launcher all
    consult. True iff the moments policy storage advertises
    ``fused_update`` (grouped int8), the optimizer exposes the shared
    elementwise ``core``/``hyper`` with the (m, v) moment layout the
    kernel hardcodes, and the spec has an f32 group for the policy to
    act on."""
    from repro import residency as residency_mod
    if optimizer is None or optimizer.core is None or optimizer.hyper is None:
        return False
    if tuple(optimizer.moment_keys) != ("m", "v"):
        return False
    st = residency_mod.get_storage(spec.residency_of("moments"))
    if not (getattr(st, "fused_update", False) and st.needs_key):
        return False
    return any(g == "float32" for g, _ in spec.groups)


def resident_bytes_model(spec, optimizer=None, wire_dtype=None, fused=None):
    """Host-side exact per-agent resident HBM bytes of the engine's
    panel state under the spec's residency policy — the storage-codec
    counterpart of :func:`wire_bytes_model`.

    Returns ``{"params", "moments", "wire_err", "merge_stat", "total",
    "transient_bytes", "peak"}`` in bytes per agent, scale sidecars
    included (:meth:`PanelSpec.storage_bytes`). Moments count
    ``optimizer.moment_keys`` panels (AdamW's two when ``optimizer`` is
    None) and mirror each group's native dtype, so only f32 groups pay
    the storage codec; the wire-error residual exists only when the wire
    policy runs error feedback (and the legacy ``wire_dtype`` cast,
    which disables EF, zeroes it); merge statistics count the spec
    merger's ``stat_panels``. This model is pinned exact against
    ``jax.eval_shape`` of the real state by the residency conformance
    tests.

    ``total`` is the STORED footprint that persists across the whole
    segment. ``transient_bytes`` is the in-round peak of the f32 decode
    views the unfused path materializes for non-f32 stored panels
    (moments each local step, stats at round entry, the EF residual
    inside the communicating branches) — the term the pre-fusion
    accounting silently dropped, understating peak HBM. The moments
    term is zero when the fused kernel is active (``fused=None`` infers
    :func:`fused_moments_auto`; pass the launcher's resolved flag to
    pin it). ``peak = total + transient_bytes`` is what capacity
    planning (agents-per-HBM-budget) must use for the unfused engine."""
    from repro import merging as merging_mod
    from repro import residency as residency_mod
    from repro import wire as wire_mod
    params = sum(jnp.dtype(k).itemsize * w for k, w in spec.groups)
    n_mom = 2 if optimizer is None else len(optimizer.moment_keys)
    moments = n_mom * spec.storage_bytes("moments")
    needs_ef = wire_dtype is None and any(
        wire_mod.get_codec(spec.wire_of(k)).error_feedback
        for k, _ in spec.groups)
    wire_err = (spec.storage_bytes("wire_err", state_dtype="float32")
                if needs_ef else 0)
    merger = merging_mod.get_merger(spec.merger)
    merge_stat = (len(merger.stat_panels)
                  * spec.storage_bytes("stats", state_dtype="float32"))
    out = {"params": params, "moments": moments, "wire_err": wire_err,
           "merge_stat": merge_stat}
    out["total"] = sum(out.values())
    if fused is None:
        fused = fused_moments_auto(spec, optimizer)
    f32_w = sum(w for g, w in spec.groups if g == "float32")
    all_w = sum(w for _, w in spec.groups)
    transient = 0
    if not fused and residency_mod.get_storage(
            spec.residency_of("moments")).name != "f32":
        transient += n_mom * 4 * f32_w
    if needs_ef and residency_mod.get_storage(
            spec.residency_of("wire_err")).name != "f32":
        transient += 4 * all_w
    if merger.stat_panels and residency_mod.get_storage(
            spec.residency_of("stats")).name != "f32":
        transient += len(merger.stat_panels) * 4 * all_w
    out["transient_bytes"] = transient
    out["peak"] = out["total"] + transient
    return out


def moment_traffic_model(spec, optimizer=None, local_steps: int = 1,
                         fused=None):
    """Host-side per-agent HBM bytes MOVED per round by the optimizer
    moment panels — the bandwidth counterpart of
    :func:`resident_bytes_model` (which counts bytes held).

    Every local step, each moment panel pays a stored-rep read + write
    (both paths). The unfused path additionally round-trips a
    materialized f32 view per stored panel: decode write + update
    read + update write + encode read = 16 bytes/scalar of transient
    traffic on top of the ~2 bytes/scalar the int8 rep itself moves —
    the gap the fused kernel closes. Uniform SR-input traffic is
    identical in both paths (both draw the same (m, D) panels from the
    same keys) so it cancels from the comparison; the TPU-native
    variant draws its bits on-chip (wire_quant.quantize_int8_panel_
    native) and pays it in neither.

    Returns ``{"stored_bytes_per_step", "transient_bytes_per_step",
    "bytes_per_step", "bytes_per_round"}``."""
    from repro import residency as residency_mod
    n_mom = 2 if optimizer is None else len(optimizer.moment_keys)
    st = residency_mod.get_storage(spec.residency_of("moments"))
    if fused is None:
        fused = fused_moments_auto(spec, optimizer)
    stored = transient = 0
    for g, w in spec.groups:
        if g == "float32":
            stored += 2 * st.resident_bytes(1, w)
            if st.name != "f32" and not fused:
                transient += 16 * w
        else:
            stored += 2 * jnp.dtype(g).itemsize * w
    per_step = n_mom * (stored + transient)
    return {"stored_bytes_per_step": n_mom * stored,
            "transient_bytes_per_step": n_mom * transient,
            "bytes_per_step": per_step,
            "bytes_per_round": per_step * local_steps}


def round_wire_bytes(W, *, bytes_wire: int, bytes_full: int,
                     full_bandwidth=None, lv=None):
    """(m,) int32 wire bytes each agent paid this round.

    Identity rows of W (idle agents, unmatched partners, the degraded
    rows of dead agents) pay 0 — nothing travels their wire, mirroring
    the engine's per-row idle rule. ``full_bandwidth`` (traced bool; a
    delta codec's global round) switches communicating rows to the
    full-precision cost; ``lv`` (the (m,) liveness trit) zeroes DEAD
    rows and charges RESYNC rows the full-precision pull."""
    m = W.shape[0]
    idle = jnp.all(W == jnp.eye(m, dtype=W.dtype), axis=1)
    per = jnp.where(idle, 0, bytes_wire)
    if full_bandwidth is not None:
        per = jnp.where(jnp.logical_and(full_bandwidth, ~idle),
                        bytes_full, per)
    if lv is not None:
        per = jnp.where(lv == 0, 0, per)
        per = jnp.where(lv == 2, bytes_full, per)
    return per.astype(jnp.int32)


def live_trits(lv, m: int):
    """(m,) int32 liveness column for the metric panel (all-LIVE when the
    round carries no mask)."""
    if lv is None:
        return jnp.ones((m,), jnp.int32)
    return lv.astype(jnp.int32)
