"""CI residency smoke check: quantized moments must not move the run.

Compares two finished ``repro.launch.train`` output directories — the
f32 baseline and a ``--residency`` run at MATCHED seeds/schedule — and
fails unless the final merged evals agree within the wire-merge
tolerance (the same quality bar ``benchmarks.panel_bench`` asserts).
Also checks the residency run's round stream actually recorded a
SMALLER per-agent resident footprint than the baseline.

    python scripts/residency_smoke.py results/residency_smoke/f32 \
        results/residency_smoke/int8 [--tol 0.05]

``--fused-pair`` flips the comparison to a ``--fused-moments off`` vs
``--fused-moments on`` pair at matched seeds AND matched residency
policy: final evals must agree within the same tolerance (the fused
kernel is trajectory-preserving, so the delta should in fact be 0),
the STORED footprint must be identical, and the fused run's recorded
``transient_bytes`` must be strictly smaller (the f32 decode views the
kernel eliminates).

    python scripts/residency_smoke.py results/fused_smoke/off \
        results/fused_smoke/on --fused-pair
"""
import argparse
import glob
import json
import os
import sys

TOL = 0.05  # benchmarks.panel_bench.WIRE_MERGE_TOL


def _load_run(outdir):
    paths = sorted(glob.glob(os.path.join(outdir, "*.json")))
    paths = [p for p in paths if not p.endswith("snapshot.json")]
    if len(paths) != 1:
        raise SystemExit(f"{outdir}: expected one run record, found {paths}")
    with open(paths[0]) as f:
        return json.load(f)


def _final_eval(rec, outdir):
    evals = [h["merged_eval"] for h in rec["history"]
             if h.get("merged_eval") is not None]
    if not evals:
        raise SystemExit(f"{outdir}: run recorded no merged evals")
    return evals[-1]


def _round_field(outdir, field, default=None):
    for path in glob.glob(os.path.join(outdir, "events_*.jsonl")):
        with open(path) as f:
            for line in f:
                ev = json.loads(line)
                if ev.get("type") == "round" and ev.get(field) is not None:
                    return ev[field]
    return default


def _resident_bytes(outdir):
    return _round_field(outdir, "resident_bytes") or None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="f32 run output dir "
                    "(--fused-pair: the --fused-moments off run)")
    ap.add_argument("residency", help="--residency run output dir "
                    "(--fused-pair: the --fused-moments on run)")
    ap.add_argument("--tol", type=float, default=TOL)
    ap.add_argument("--fused-pair", action="store_true",
                    help="compare a fused-off vs fused-on pair sharing a "
                    "residency policy instead of f32 vs quantized")
    args = ap.parse_args(argv)

    base, res = _load_run(args.baseline), _load_run(args.residency)
    pol = res["args"].get("residency")
    if not pol:
        raise SystemExit(f"{args.residency}: run carried no residency policy")
    matched = ("seed", "rounds", "agents", "schedule", "merge")
    if args.fused_pair:
        matched += ("residency",)
    for k in matched:
        if base["args"].get(k) != res["args"].get(k):
            raise SystemExit(f"runs are not matched on --{k}: "
                             f"{base['args'].get(k)} vs {res['args'].get(k)}")
    eb, er = _final_eval(base, args.baseline), _final_eval(res,
                                                           args.residency)
    delta = abs(er - eb)
    rb_base = _resident_bytes(args.baseline)
    rb_res = _resident_bytes(args.residency)
    if args.fused_pair:
        print(f"final merged eval: unfused={eb:.4f} fused={er:.4f} "
              f"delta={delta:.4f} (tol {args.tol})")
        tb_base = _round_field(args.baseline, "transient_bytes")
        tb_res = _round_field(args.residency, "transient_bytes")
        if rb_base != rb_res:
            raise SystemExit("fused run changed the STORED footprint: "
                             f"{rb_base} vs {rb_res}")
        if tb_base is None or tb_res is None:
            raise SystemExit("round events carry no transient_bytes "
                             "(schema v3) — cannot check the fused saving")
        print(f"transient bytes/agent: unfused={tb_base} fused={tb_res}")
        if not tb_res < tb_base:
            raise SystemExit("fused run did not shrink transient decode "
                             f"traffic: {tb_res} vs {tb_base}")
    else:
        print(f"final merged eval: f32={eb:.4f} {pol}={er:.4f} "
              f"delta={delta:.4f} (tol {args.tol})")
        if rb_base and rb_res:
            print(f"resident bytes/agent: f32={rb_base} {pol}={rb_res} "
                  f"({rb_base / rb_res:.2f}x)")
            if rb_res >= rb_base:
                raise SystemExit("residency run did not shrink resident "
                                 "bytes")
    if delta > args.tol:
        raise SystemExit(f"quantized-residency eval drifted: {delta:.4f} > "
                         f"{args.tol}")
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
