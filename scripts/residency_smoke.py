"""CI residency smoke check: quantized moments must not move the run.

Compares two finished ``repro.launch.train`` output directories — the
f32 baseline and a ``--residency`` run at MATCHED seeds/schedule — and
fails unless the final merged evals agree within the wire-merge
tolerance (the same quality bar ``benchmarks.panel_bench`` asserts).
Also checks the residency run's round stream actually recorded a
SMALLER per-agent resident footprint than the baseline.

    python scripts/residency_smoke.py results/residency_smoke/f32 \
        results/residency_smoke/int8 [--tol 0.05]
"""
import argparse
import glob
import json
import os
import sys

TOL = 0.05  # benchmarks.panel_bench.WIRE_MERGE_TOL


def _load_run(outdir):
    paths = sorted(glob.glob(os.path.join(outdir, "*.json")))
    paths = [p for p in paths if not p.endswith("snapshot.json")]
    if len(paths) != 1:
        raise SystemExit(f"{outdir}: expected one run record, found {paths}")
    with open(paths[0]) as f:
        return json.load(f)


def _final_eval(rec, outdir):
    evals = [h["merged_eval"] for h in rec["history"]
             if h.get("merged_eval") is not None]
    if not evals:
        raise SystemExit(f"{outdir}: run recorded no merged evals")
    return evals[-1]


def _resident_bytes(outdir):
    for path in glob.glob(os.path.join(outdir, "events_*.jsonl")):
        with open(path) as f:
            for line in f:
                ev = json.loads(line)
                if ev.get("type") == "round" and ev.get("resident_bytes"):
                    return ev["resident_bytes"]
    return None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="f32 run output dir")
    ap.add_argument("residency", help="--residency run output dir")
    ap.add_argument("--tol", type=float, default=TOL)
    args = ap.parse_args(argv)

    base, res = _load_run(args.baseline), _load_run(args.residency)
    pol = res["args"].get("residency")
    if not pol:
        raise SystemExit(f"{args.residency}: run carried no residency policy")
    for k in ("seed", "rounds", "agents", "schedule", "merge"):
        if base["args"].get(k) != res["args"].get(k):
            raise SystemExit(f"runs are not matched on --{k}: "
                             f"{base['args'].get(k)} vs {res['args'].get(k)}")
    eb, er = _final_eval(base, args.baseline), _final_eval(res,
                                                           args.residency)
    delta = abs(er - eb)
    rb_base = _resident_bytes(args.baseline)
    rb_res = _resident_bytes(args.residency)
    print(f"final merged eval: f32={eb:.4f} {pol}={er:.4f} "
          f"delta={delta:.4f} (tol {args.tol})")
    if rb_base and rb_res:
        print(f"resident bytes/agent: f32={rb_base} {pol}={rb_res} "
              f"({rb_base / rb_res:.2f}x)")
        if rb_res >= rb_base:
            raise SystemExit("residency run did not shrink resident bytes")
    if delta > args.tol:
        raise SystemExit(f"quantized-residency eval drifted: {delta:.4f} > "
                         f"{args.tol}")
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
