#!/usr/bin/env python
"""Fault-injection smoke for the elastic checkpoint/resume path.

Drives three subprocess runs of ``repro.launch.train`` with identical
hyperparameters:

1. **baseline** — the uninterrupted run,
2. **interrupted** — ``--checkpoint-every 1 --die-after-segments 1``:
   the launcher SIGKILLs itself between segments, after flushing the
   async checkpoint (expected exit: -SIGKILL),
3. **resumed** — ``--resume`` on the interrupted run's checkpoint
   directory, continuing to completion.

The resumed run's full history JSON (per-round train loss, consensus,
grad norm, merged/local evals, comm cost) must equal the baseline's
BIT-EXACTLY — resume restores the panel state, both host rng streams
and the schedule rng, so the trajectories are the same floats.

All three runs also emit the telemetry event stream (``--telemetry
--events``, per-agent metrics included): the interrupted and resumed
runs share ONE events path — resume truncates it back to the
checkpointed seq and re-emits the replayed rounds — and the final file
must be BYTE-identical to the baseline's (wall-clock timing lives in the
``.wall.jsonl`` sidecar, never in the deterministic stream). Both
streams are schema-validated (repro.telemetry.validate).

Prints a one-line JSON verdict on the last stdout line; exit 0 iff ok.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys

CFG = ["--rounds", "6", "--segment", "2", "--agents", "4",
       "--local-steps", "2", "--batch", "4", "--seq", "32",
       "--wire", "int8_ef", "--merge", "fisher",
       "--schedule", "final_merge", "--seed", "0", "--telemetry"]
TAG = "olmo-1b_final_merge_a0.1_mfisher.json"


def run(out, extra, expect_rc=0):
    cmd = [sys.executable, "-m", "repro.launch.train",
           *CFG, "--out", out, *extra]
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    if proc.returncode != expect_rc:
        sys.stderr.write(proc.stdout + proc.stderr)
        raise SystemExit(
            f"{' '.join(extra) or 'baseline'}: exit {proc.returncode}, "
            f"expected {expect_rc}")
    return proc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default="results/fault_smoke")
    args = ap.parse_args()
    base = os.path.join(args.workdir, "baseline")
    intr = os.path.join(args.workdir, "interrupted")
    shutil.rmtree(args.workdir, ignore_errors=True)
    ev_base = os.path.join(base, "events.jsonl")
    # interrupted + resumed share ONE stream: resume truncates it back to
    # the checkpointed seq and re-emits the replayed rounds exactly once
    ev_intr = os.path.join(intr, "events.jsonl")

    run(base, ["--events", ev_base])
    # the interrupted run dies by SIGKILL between segments — a real
    # crash, not a clean shutdown; only the flushed checkpoint survives
    run(intr, ["--checkpoint-every", "1", "--die-after-segments", "1",
               "--events", ev_intr], expect_rc=-signal.SIGKILL)
    manifest = os.path.join(intr, "ckpt_" + TAG[:-5], "MANIFEST.json")
    if not os.path.exists(manifest):
        raise SystemExit(f"no checkpoint manifest at {manifest}")
    resumed = run(intr, ["--checkpoint-every", "1", "--resume",
                         "--events", ev_intr])
    if "resumed from checkpoint" not in resumed.stdout:
        raise SystemExit("resumed run did not restore a checkpoint")

    with open(os.path.join(base, TAG)) as f:
        hb = json.load(f)["history"]
    with open(os.path.join(intr, TAG)) as f:
        hr = json.load(f)["history"]
    ok = hb == hr
    diff = [r for r, (a, b) in enumerate(zip(hb, hr)) if a != b]

    # the deterministic event stream must survive the kill+resume cycle
    # byte-for-byte, and both copies must be schema-valid
    with open(ev_base, "rb") as f:
        eb = f.read()
    with open(ev_intr, "rb") as f:
        er = f.read()
    events_ok = eb == er and len(eb) > 0
    validate = subprocess.run(
        [sys.executable, "-m", "repro.telemetry.validate", ev_base,
         ev_intr],
        env={**os.environ, "PYTHONPATH": os.environ.get("PYTHONPATH",
                                                        "src")},
        capture_output=True, text=True)
    events_valid = validate.returncode == 0
    if not events_valid:
        sys.stderr.write(validate.stdout + validate.stderr)

    ok = ok and events_ok and events_valid
    print(json.dumps({"ok": ok, "rounds": len(hb),
                      "final_merged_eval": hb[-1]["merged_eval"],
                      "diff_rounds": diff,
                      "events_ok": events_ok,
                      "events_valid": events_valid,
                      "events_bytes": len(eb),
                      "manifest": manifest}))
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
