"""Shared harness for the paper-figure benchmarks (CPU-scale instances of
the paper's experiments: m agents, Dirichlet alpha=0.1, sparse random gossip
R=0.2, schedules from repro.core.schedule)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dsgd, gossip
from repro.core.schedule import make_schedule
from repro.data.synthetic import SyntheticClassification, make_agent_batches
from repro.optim import make_optimizer

M = 8
ROUNDS = 80
ALPHA = 0.1


def timed(fn, *args, reps=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def make_problem(seed=0, dim=32, classes=10, depth=2, width=128):
    """Depth-2 ReLU MLP on Dirichlet(0.1)-partitioned gaussian blobs.

    A genuinely non-convex instance: with ZERO communication, averaging
    independently-initialised local models lands below chance (permutation
    misalignment), while limited gossip keeps models mergeable — the paper's
    core phenomenon at CPU scale."""
    ds = SyntheticClassification(num_classes=classes, dim=dim, n_train=4096,
                                 n_test=1024, seed=seed)
    parts = ds.partition(M, alpha=ALPHA, seed=seed + 1)
    dims = [dim] + [width] * depth + [classes]

    def init_params(rng):
        ks = jax.random.split(rng, depth + 1)
        p = {}
        for i in range(depth + 1):
            p[f"w{i}"] = (jax.random.normal(ks[i], (dims[i], dims[i + 1]))
                          / np.sqrt(dims[i]))
            p[f"b{i}"] = jnp.zeros(dims[i + 1])
        return p

    def fwd(p, x):
        h = x
        for i in range(depth):
            h = jax.nn.relu(h @ p[f"w{i}"] + p[f"b{i}"])
        return h @ p[f"w{depth}"] + p[f"b{depth}"]

    def loss_fn(p, batch, rng=None):
        x, y = batch
        lg = fwd(p, x)
        nll = jnp.mean(jax.nn.logsumexp(lg, -1) - jnp.take_along_axis(
            lg, y[:, None].astype(jnp.int32), -1)[:, 0])
        return nll, {}

    def acc(p):
        lg = fwd(p, ds.x_test)
        return jnp.mean((jnp.argmax(lg, -1) == ds.y_test).astype(jnp.float32))

    return ds, parts, init_params, loss_fn, jax.jit(acc)


def run_schedule(schedule_name, rounds=ROUNDS, seed=0, track=False,
                 batch=32, lr=0.1, **kw):
    """Returns dict with local/merged accuracy (+curves if track)."""
    ds, parts, init_params, loss_fn, acc = make_problem(seed)
    opt = make_optimizer("sgd", lr, weight_decay=0.0)
    state = dsgd.init_state(init_params, opt, M, jax.random.PRNGKey(seed))
    step = jax.jit(dsgd.make_dsgd_step(loss_fn, opt))
    sched = make_schedule(schedule_name, M, rounds, prob=0.2, seed=seed, **kw)
    rng_np = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed + 1)
    monitor = {}
    curves = {"local": [], "merged": [], "xi": []}
    comm = 0.0
    vacc = jax.jit(jax.vmap(acc))
    for t in range(rounds):
        W = sched.mixing_matrix(t, monitor)
        comm += sched.round_cost(W)
        xb, yb = make_agent_batches(ds, parts, batch, rng_np)
        key, k = jax.random.split(key)
        state, mets = step(state, (jnp.asarray(xb), jnp.asarray(yb)),
                           jnp.asarray(W, jnp.float32), k)
        monitor = {"grad_norm": float(mets["grad_norm"]),
                   "consensus": float(mets["consensus"])}
        if track and (t % 5 == 0 or t == rounds - 1):
            curves["local"].append(float(jnp.mean(vacc(state["params"]))))
            # per-round tracking loop: per-leaf variant, no panel copy
            curves["merged"].append(float(acc(gossip.merged_model_tree(
                state["params"]))))
            curves["xi"].append(monitor["consensus"])
    local = float(jnp.mean(vacc(state["params"])))
    merged = float(acc(gossip.merged_model(state["params"])))
    out = {"local": local, "merged": merged, "comm_P": comm}
    if track:
        out["curves"] = curves
    return out
