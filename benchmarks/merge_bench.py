"""Merge-operator quality bench: what does the paper's SINGLE global
merging gain from a richer operator under heterogeneity?

Per operator (repro.merging — uniform/weighted/var/fisher/ties/swa) this
trains the SAME decentralized run on the cpu-preset olmo-1b-family LM —
synthetic non-IID token streams at Dirichlet alpha (default 0.1,
the paper's hardest setting), independent inits, sparse random-matching
gossip, final_merge schedule, identical seeds/batches/W sequence — with
the operator installed on the spec (``init_panel_state(merger=...)``), so
the one final global round is the ONLY thing that differs: the pre-merge
trajectories are bit-identical (stat panels never touch the params).
After the in-engine merge it records the merged model's eval loss on a
held-out GLOBAL-mixture batch, next to the uniform baseline.

``python -m benchmarks.merge_bench`` (add ``--merge ties,var`` for a
subset — 'uniform' is always included as the reference) merges the
records into BENCH_panel.json under "merge"; ``--artifact PATH``
additionally writes the full per-operator record (committed as
results/train/olmo-1b_merge_ops_a0.1.json). CI runs the ties,var smoke
at a reduced round count.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import merging as merging_mod
from repro.configs import get_config
from repro.core import dsgd
from repro.core import panel as panel_mod
from repro.core.schedule import make_schedule
from repro.data.synthetic import SyntheticLM, make_agent_lm_batches
from repro.models import build_model
from repro.optim import make_optimizer


def _setup(arch, m, rounds, local_steps, batch, seq, alpha, lr, seed):
    """Shared run inputs: config/model/opt + the identical W sequence and
    batch stream every operator trains on."""
    cfg = get_config(arch).reduced(d_model=128, layers=2, vocab=256)
    model = build_model(cfg)
    opt = make_optimizer("adamw", lr, weight_decay=5e-4,
                         total_steps=rounds * local_steps)
    sched = make_schedule("final_merge", m, rounds, prob=0.2, seed=seed)
    Ws, glob = [], []
    for t in range(rounds):
        Ws.append(sched.mixing_matrix(t))
        glob.append(sched.last_kind == "global")
    Ws = jnp.asarray(np.stack(Ws), jnp.float32)
    glob = jnp.asarray(glob)
    lm = SyntheticLM(vocab=cfg.vocab_size, num_domains=8, seed=seed)
    mixtures = lm.domain_mixtures(m, alpha, seed=seed + 1)
    rng_np = np.random.default_rng(seed + 2)
    per_round = []
    for _ in range(rounds):
        hs = [make_agent_lm_batches(lm, mixtures, batch, seq, rng_np)
              for _ in range(local_steps)]
        per_round.append({k: np.stack([h[k] for h in hs]) for k in hs[0]})
    batches = {k: jnp.asarray(np.stack([r[k] for r in per_round]))
               for k in per_round[0]}
    # held-out eval batch from the GLOBAL (uniform) domain mixture
    gmix = np.ones(lm.num_domains) / lm.num_domains
    eval_batch = jax.tree.map(jnp.asarray, {
        k: v[0] for k, v in make_agent_lm_batches(
            lm, [gmix], 4 * batch, seq, np.random.default_rng(999)).items()})
    return model, opt, Ws, glob, batches, eval_batch


def run_operator(name, model, opt, Ws, glob, batches, eval_batch, m,
                 local_steps, seed):
    """One full e2e training run through make_panel_segment with the
    operator on the spec; returns the record for BENCH_panel.json."""
    state, spec = dsgd.init_panel_state(
        model.init_params, opt, m, jax.random.PRNGKey(seed), merger=name)
    seg_fn = dsgd.make_panel_segment(model.loss_fn, opt, local_steps, spec)
    t0 = time.perf_counter()
    state, mets = seg_fn(state, batches, Ws, jax.random.PRNGKey(seed + 1),
                         None, glob)
    mets = jax.device_get(mets)
    # after the in-engine final merge all rows are identical; evaluate
    # the merged model (row mean of an identical-row panel == the row)
    merged = panel_mod.merged_tree(state["panel"], spec)
    loss, _ = jax.jit(model.loss_fn)(merged, eval_batch, None)
    dt = time.perf_counter() - t0
    assert float(mets["consensus"][-1]) < 1e-3, (name, "merge did not run")
    return {
        "final_eval_loss": round(float(loss), 5),
        "train_loss_last": round(float(mets["loss"][-1]), 5),
        "consensus_pre_merge": round(float(mets["consensus"][-2]), 5),
        "run_s": round(dt, 1),
    }, state["panel"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--merge", default="all",
                    help="comma list of operators (repro.merging) or "
                         "'all'; 'uniform' is always included as the "
                         "reference")
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=24)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--artifact", default="",
                    help="also write the full per-operator record here "
                         "(e.g. results/train/olmo-1b_merge_ops_a0.1.json)")
    args = ap.parse_args()

    if args.merge == "all":
        names = sorted(merging_mod.MERGERS)
    else:
        names = sorted({"uniform", *args.merge.split(",")})
        for n in names:
            merging_mod.get_merger(n)

    model, opt, Ws, glob, batches, eval_batch = _setup(
        args.arch, args.agents, args.rounds, args.local_steps, args.batch,
        args.seq, args.alpha, args.lr, args.seed)

    records, panels = {}, {}
    for name in names:
        records[name], panels[name] = run_operator(
            name, model, opt, Ws, glob, batches, eval_batch, args.agents,
            args.local_steps, args.seed)
    uni = records["uniform"]["final_eval_loss"]
    for name in names:
        r = records[name]
        r["delta_vs_uniform"] = round(r["final_eval_loss"] - uni, 5)
        r["merged_max_dev_vs_uniform"] = round(max(
            float(jnp.max(jnp.abs(panels[name][k] - panels["uniform"][k])))
            for k in panels[name]), 6)
        # a zero deviation would mean the operator branch never ran and
        # the round fell through to the plain gossip matmul (e.g. a
        # regressed is_full detection) — uniform numbers under the
        # operator's name
        assert name == "uniform" or r["merged_max_dev_vs_uniform"] > 0, (
            name, "operator produced the uniform merge — merge branch "
                  "did not dispatch")
        print(f"merge {name:9s}: eval={r['final_eval_loss']:.4f} "
              f"(delta {r['delta_vs_uniform']:+.4f} vs uniform) "
              f"dev={r['merged_max_dev_vs_uniform']:.4f} "
              f"{r['run_s']}s", flush=True)

    rec = {"backend": jax.default_backend(), "arch": args.arch,
           "m": args.agents, "rounds": args.rounds,
           "local_steps": args.local_steps, "alpha": args.alpha,
           "lr": args.lr, "seed": args.seed, "schedule": "final_merge",
           "operators": records}
    out = {}
    if os.path.exists("BENCH_panel.json"):
        with open("BENCH_panel.json") as f:
            out = json.load(f)
    # REPLACE the whole section: operator records are only comparable
    # within one invocation (same rounds/seed/batches), so merging a
    # partial run into stale entries would mix incompatible configs
    out["merge"] = rec
    with open("BENCH_panel.json", "w") as f:
        json.dump(out, f, indent=1)
    print("wrote BENCH_panel.json")
    if args.artifact:
        os.makedirs(os.path.dirname(args.artifact) or ".", exist_ok=True)
        with open(args.artifact, "w") as f:
            json.dump(rec, f, indent=1)
        print("wrote", args.artifact)


if __name__ == "__main__":
    main()
