"""Microbench: fused panel communication round vs per-leaf tree-map path.

One "round" of the communication layer = gossip mixing with a random
matching W + the consensus-distance monitor; the run finishes with the
paper's single global merging. Two engines, identical math:

* **tree** — the per-leaf reference path: one tensordot per pytree leaf,
  a Python loop over leaves for the consensus monitor, one jitted dispatch
  AND one host sync per round (how launch/train.py drove rounds before the
  panel engine).
* **panel** — the flat-panel engine: state flattened once to an (m, D)
  panel, all rounds scanned on device in ONE donated dispatch, mixing as a
  single fused matmul per round, consensus as a fused reduction, a single
  device_get for the whole segment.

``python -m benchmarks.panel_bench`` writes BENCH_panel.json with
us_per_round for both paths at two sizes.

``--sharded`` adds a third engine: the SAME fused round with the panel's D
axis sharded over 'fsdp' on the (1,2,2,2) debug training mesh
(core/panel.shard_spec) — per-shard matmuls, fsdp-local collectives — and
records its us_per_round + the per-round collective bytes of the lowered
scan next to the replicated numbers (merged into BENCH_panel.json under
"sharded"). Needs 8 host devices; when the process has fewer it re-execs
itself in a subprocess with ``--xla_force_host_platform_device_count``.

Both panel engines run the consensus monitor FOLDED into the mixing
matmul (panel.mix_dense_mean: W augmented with a 1^T/m row, the mean read
off the extra output row, consensus_from_mean finishing with one deviation
pass) — no separate full-panel mean reduce per round.

``--wire <codec>[,<codec>...]|all`` benches the quantized-wire codec
subsystem (repro/wire) on the default olmo-1b-family size: per codec it
records the codec-aware wire bytes/agent/round — both the VALUES payload
(PanelSpec.wire_payload_bytes: packed int4 nibbles = 8x fewer than f32,
top-k = 1/density x) and the payload+metadata total
(wire_total_bytes: grouped scales, packed indices) — the byte ratios vs
f32, us_per_round, and the final-single-global-merge parity vs the f32
run — merged into BENCH_panel.json under "wire". The f32 codec row is
asserted BIT-exact against the no-policy engine (the identity codec
must not perturb the pre-codec path). The topk row also records its
byte-model inputs (k, density, idx_bytes, gamma) per dtype group; its
per-round bytes model the sparse gossip rounds — the single global
merge is deliberately the full-bandwidth round (see
wire/codec.py:TopKCodec).

``--residency`` benches the storage-codec residency subsystem
(repro/residency) — quantized panel residency so HBM stops capping the
agent count. Per (wire, residency) configuration it records the EXACT
per-agent resident-HBM bytes (telemetry.metrics.resident_bytes_model,
scale sidecars included) at the default olmo-1b-family size, the max
agent count per fixed memory budget, segment runtime and the
matched-seed quality delta vs the f32 engine at the cpu-preset size —
merged into BENCH_panel.json under "residency". The f32-policy row is
asserted BIT-identical to the no-policy engine; the headline row
(int8_ef wire + int8 moments/residual storage) is asserted to fit >= 2x
more agents per budget than the same wire at f32 residency, with final
eval within WIRE_MERGE_TOL.

``--telemetry`` benches the per-agent telemetry metric panels on the FULL
segment driver (core/dsgd.make_panel_segment) at the cpu-preset size:
``telemetry=False`` vs ``telemetry=True`` us_per_round (the latter adds
the five (S, m) per-agent columns — loss, grad norm, distance-to-mean,
liveness, codec wire bytes — to the single per-segment device_get),
asserting the final panels stay BIT-identical (telemetry is pure reads)
— merged into BENCH_panel.json under "telemetry".
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gossip, topology
from repro.core import panel as panel_mod
from repro.core.consensus import consensus_distance_tree

SIZES = {
    # ~7.2M params/agent (x16 agents = 461MB state): the donation win —
    # the undonated tree path copies the full stacked state every round
    "default": dict(m=16, d_model=256, layers=8, vocab=512, rounds=8),
    # the CPU-preset training tree (what launch/train.py --preset cpu
    # runs). At this tiny scale both paths are dominated by the shared
    # memory-bound consensus reduction, so the win is smaller.
    "cpu_preset": dict(m=8, d_model=128, layers=2, vocab=256, rounds=32),
}


def _make_tree(m, d_model, layers, vocab, seed=0):
    """Agent-stacked params of a real reduced LM (olmo-1b family) — the
    honest leaf composition (embeddings, per-layer stacks, norms)."""
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("olmo-1b").reduced(d_model=d_model, layers=layers,
                                        vocab=vocab)
    model = build_model(cfg)
    return jax.vmap(model.init_params)(
        jax.random.split(jax.random.PRNGKey(seed), m))


def bench_size(m, d_model, layers, vocab, rounds, reps=3):
    tree = _make_tree(m, d_model, layers, vocab)
    spec = panel_mod.make_spec(tree)
    Ws = jnp.asarray(np.stack([
        topology.random_matching(m, 0.5, np.random.default_rng(t))
        for t in range(rounds)]), jnp.float32)

    # ---- per-leaf tree-map path: dispatch + host sync per round
    @jax.jit
    def tree_round(t, W):
        mixed = gossip.mix_dense_tree(t, W)
        return mixed, consensus_distance_tree(mixed)

    def run_tree():
        t = tree
        xi = 0.0
        for r in range(rounds):
            t, x = tree_round(t, Ws[r])
            xi = float(x)  # per-round monitor readback (old driver)
        merged = gossip.global_merge_tree(t)
        jax.block_until_ready(jax.tree.leaves(merged)[0])
        return xi

    # ---- fused panel path: one donated, scanned dispatch per segment;
    # consensus mean folded into the mixing matmul (no separate reduce)
    def seg(pan, Ws):
        def body(p, W):
            mixed, mean, _ = panel_mod.mix_dense_mean(p, W)
            return mixed, panel_mod.consensus_from_mean(mixed, mean)
        pan, xis = jax.lax.scan(body, pan, Ws)
        return panel_mod.global_merge(pan), xis

    seg_fn = jax.jit(seg, donate_argnums=(0,))

    def run_panel(pan):
        merged, xis = seg_fn(pan, Ws)
        xis = jax.device_get(xis)  # ONE transfer for the segment
        jax.block_until_ready(list(merged.values()))
        return float(xis[-1])

    def fresh_panel():
        pan = {k: v + 0.0 for k, v in  # copy: seg_fn donates its input
               panel_mod.to_panel(tree, spec).items()}
        jax.block_until_ready(list(pan.values()))
        return pan

    # numerical parity of the two engines on the same W sequence
    xi_tree = run_tree()
    xi_panel = run_panel(fresh_panel())
    assert abs(xi_tree - xi_panel) <= 1e-4 * max(abs(xi_tree), 1.0), (
        xi_tree, xi_panel)

    t_tree = []
    for _ in range(reps):
        t0 = time.perf_counter()
        run_tree()
        t_tree.append(time.perf_counter() - t0)
    t_panel = []
    for _ in range(reps):
        pan = fresh_panel()
        t0 = time.perf_counter()
        run_panel(pan)
        t_panel.append(time.perf_counter() - t0)

    us_tree = min(t_tree) / rounds * 1e6
    us_panel = min(t_panel) / rounds * 1e6
    return {"m": m, "leaves": len(jax.tree.leaves(tree)),
            "D": spec.width, "rounds": rounds,
            "us_per_round_tree": round(us_tree, 1),
            "us_per_round_panel": round(us_panel, 1),
            "speedup": round(us_tree / us_panel, 2),
            "xi_parity_gap": round(abs(xi_tree - xi_panel), 6)}


# debug training mesh used by --sharded: (pod=1, agent=2, fsdp=2, model=2)
SHARDED_DEVICES = 8


def bench_sharded(m=16, d_model=256, layers=8, vocab=512, rounds=8, reps=3):
    """Fused panel round with D sharded over 'fsdp' on the debug training
    mesh vs the replicated fused round on the same host. Returns the record
    merged into BENCH_panel.json["sharded"]."""
    from repro.launch import mesh as mesh_mod
    from repro.utils.hlo import collective_bytes

    mesh = mesh_mod.make_debug_mesh(agents=2, fsdp=2, model=2)
    tree = _make_tree(m, d_model, layers, vocab)
    repl_spec = panel_mod.make_spec(tree)
    spec = panel_mod.shard_spec(repl_spec, mesh)
    Ws = jnp.asarray(np.stack([
        topology.random_matching(m, 0.5, np.random.default_rng(t))
        for t in range(rounds)]), jnp.float32)

    def make_seg(use_spec):
        def seg(pan, Ws):
            def body(p, W):
                mixed, mean, _ = panel_mod.mix_dense_mean(p, W,
                                                          spec=use_spec)
                return mixed, panel_mod.consensus_from_mean(mixed, mean)
            pan, xis = jax.lax.scan(body, pan, Ws)
            return panel_mod.global_merge(pan, spec=use_spec), xis
        return jax.jit(seg, donate_argnums=(0,))

    def run(fn, pan):
        merged, xis = fn(pan, Ws)
        xis = jax.device_get(xis)
        jax.block_until_ready(list(merged.values()))
        return float(xis[-1])

    def fresh(use_spec):
        pan = {k: v + 0.0
               for k, v in panel_mod.to_panel(tree, repl_spec).items()}
        if use_spec is not None and use_spec.sharded:
            pan = panel_mod.shard_panel(pan, use_spec)
        jax.block_until_ready(list(pan.values()))
        return pan

    seg_repl, seg_shard = make_seg(None), make_seg(spec)
    xi_repl = run(seg_repl, fresh(None))
    xi_shard = run(seg_shard, fresh(spec))
    assert abs(xi_repl - xi_shard) <= 1e-4 * max(abs(xi_repl), 1.0), (
        xi_repl, xi_shard)

    def clock(fn, use_spec):
        ts = []
        for _ in range(reps):
            pan = fresh(use_spec)
            t0 = time.perf_counter()
            run(fn, pan)
            ts.append(time.perf_counter() - t0)
        return min(ts) / rounds * 1e6

    us_repl = clock(seg_repl, None)
    us_shard = clock(seg_shard, spec)
    txt = seg_shard.lower(fresh(spec), Ws).compile().as_text()
    per_kind, coll_total, _ = collective_bytes(txt)
    return {"backend": jax.default_backend(), "mesh": dict(mesh.shape),
            "devices": SHARDED_DEVICES, "m": m,
            "D": spec.width, "rounds": rounds,
            "pspecs": {k: str(ps) for k, ps in spec.pspecs},
            "us_per_round_replicated": round(us_repl, 1),
            "us_per_round_sharded": round(us_shard, 1),
            "coll_bytes_per_round": int(coll_total // rounds),
            "coll_kinds": sorted(per_kind),
            "xi_parity_gap": round(abs(xi_repl - xi_shard), 6)}


WIRE_CODECS = ("f32", "bf16", "int8", "int8_ef", "int4", "int4_ef",
               "topk")

# documented tolerance for the quantized final-merge parity on the
# olmo-1b reduced config: int8 error per element is <= one per-row scale
# (amax/127), int4 one GROUP scale (amax_128cols/7), and both gossip
# mixing and the global merge are convex combinations of rows, so the
# merged-model deviation stays O(scale); the EF variants carry the
# residual into the final exchange and land tighter. topk lands tightest
# of all: its damped delta mix preserves the column mean exactly and its
# global merge is the full-bandwidth round, so the merged model deviates
# from f32 only by accumulated f32 rounding.
WIRE_MERGE_TOL = 0.05


def bench_wire(codecs, m=16, d_model=256, layers=8, vocab=512, rounds=8,
               reps=3):
    """Fused panel segment per wire codec on the default olmo-1b-family
    size: codec-aware payload bytes + runtime + final-merge parity vs the
    f32 identity codec. Returns the records keyed by codec name (merged
    into BENCH_panel.json["wire"])."""
    from repro import wire as wire_mod

    tree = _make_tree(m, d_model, layers, vocab)
    base_spec = panel_mod.make_spec(tree)
    Ws = jnp.asarray(np.stack([
        topology.random_matching(m, 0.5, np.random.default_rng(t))
        for t in range(rounds)]), jnp.float32)
    wire_key = jax.random.PRNGKey(7)

    def make_seg(spec, codec):
        ef = codec is not None and codec.error_feedback

        def seg(pan, err, Ws, key):
            def body(carry, xs):
                p, e = carry
                W, k = xs
                kw = dict(spec=spec, key=k)
                if ef:
                    mixed, mean, e = panel_mod.mix_dense_mean(
                        p, W, err=e, **kw)
                else:
                    mixed, mean, _ = panel_mod.mix_dense_mean(p, W, **kw)
                return (mixed, e), panel_mod.consensus_from_mean(mixed,
                                                                 mean)
            keys = jax.random.split(key, Ws.shape[0])
            (pan, err), xis = jax.lax.scan(body, (pan, err), (Ws, keys))
            merge_key = jax.random.fold_in(key, Ws.shape[0])
            if ef:  # final exchange transmits Q(x + e): residual included
                merged, _ = panel_mod.global_merge(pan, spec=spec,
                                                   key=merge_key, err=err)
                return merged, xis
            return panel_mod.global_merge(pan, spec=spec,
                                          key=merge_key), xis
        return jax.jit(seg, donate_argnums=(0, 1))

    def fresh(codec):
        pan = {k: v + 0.0
               for k, v in panel_mod.to_panel(tree, base_spec).items()}
        # codec-seeded EF state (zeros for residuals, a panel copy for
        # the topk mirror — matches dsgd.init_panel_state)
        err = ({k: codec.init_err(v) for k, v in pan.items()}
               if codec is not None and codec.error_feedback else None)
        jax.block_until_ready(list(pan.values()))
        return pan, err

    def run(fn, codec):
        pan, err = fresh(codec)
        t0 = time.perf_counter()
        merged, xis = fn(pan, err, Ws, wire_key)
        jax.device_get(xis)
        jax.block_until_ready(list(merged.values()))
        return merged, time.perf_counter() - t0

    def clock(fn, codec):
        ts, merged = [], None
        for _ in range(reps):
            merged, dt = run(fn, codec)
            ts.append(dt)
        return merged, min(ts) / rounds * 1e6

    # no-policy engine: the pre-codec bit-exactness reference for f32
    merged_plain, _ = run(make_seg(base_spec, None), None)

    out = {}
    f32 = None
    for name in ("f32",) + tuple(c for c in codecs if c != "f32"):
        codec = wire_mod.get_codec(name)
        spec = panel_mod.with_wire(base_spec, name)
        merged, us = clock(make_seg(spec, codec), codec)
        if name == "f32":
            f32 = {"merged": merged, "us": us,
                   "payload": spec.wire_payload_bytes,
                   "total": spec.wire_total_bytes}
            gap = max(float(jnp.max(jnp.abs(a - merged_plain[k])))
                      for k, a in merged.items())
            assert gap == 0.0, (
                f"f32 identity codec perturbed the engine (max err {gap})")
        merge_err = max(
            float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - f32["merged"][k].astype(jnp.float32))))
            for k, a in merged.items())
        assert merge_err <= WIRE_MERGE_TOL, (name, merge_err)
        out[name] = {
            # per-agent bytes of one full-panel exchange: the quantized
            # VALUES payload and the payload+metadata total (grouped
            # int4 scales, packed top-k indices). For topk the per-round
            # numbers model the k-sparse gossip rounds; its single
            # global merge is the full-bandwidth round by design.
            "wire_bytes_per_agent": spec.wire_total_bytes,
            "payload_bytes_per_agent": spec.wire_payload_bytes,
            "bytes_ratio_vs_f32": round(
                f32["total"] / spec.wire_total_bytes, 2),
            "payload_ratio_vs_f32": round(
                f32["payload"] / spec.wire_payload_bytes, 2),
            "us_per_round": round(us, 1),
            "speedup_vs_f32": round(f32["us"] / us, 2),
            "merge_max_err_vs_f32": round(merge_err, 6),
            "merge_tol": WIRE_MERGE_TOL,
        }
        if hasattr(codec, "k_of"):  # record the top-k byte model inputs
            out[name]["topk_model"] = {
                "density": codec.density, "gamma": codec.gamma,
                "groups": {g: {"k": codec.k_of(w),
                               "idx_bytes": codec.idx_bytes(w)}
                           for g, w in base_spec.groups}}
    return {"backend": jax.default_backend(), "m": m, "D": base_spec.width,
            "rounds": rounds, "codecs": out}


# fixed HBM budget of the residency accounting: how many agents fit
RESIDENCY_BUDGET_GB = 8.0


def bench_residency(m=8, d_model=128, layers=2, vocab=256, rounds=8,
                    local_steps=2, batch=4, seq=32, reps=3):
    """Storage-codec residency (repro.residency) on the full segment
    driver. Two measurements per (wire, residency) row:

    * resident-HBM accounting at the DEFAULT olmo-1b-family bench size —
      the exact per-agent bytes model (params + moments + EF residual +
      merge stats, scale sidecars included) and the max agent count
      inside a fixed ``RESIDENCY_BUDGET_GB`` budget. The spec comes from
      ``jax.eval_shape``, so no default-size state is materialized.
    * matched-seed training quality + runtime at the cpu-preset size —
      same seeds, same batches, same W sequence; the uniform merged row
      and final loss are compared against the f32 engine.

    Asserts: the f32 policy is BIT-identical to the no-policy engine
    (state and metrics), every row's final loss is within
    ``WIRE_MERGE_TOL`` of f32, and the headline configuration (int8_ef
    wire + int8 moments/residual storage) fits >= 2x more agents per
    budget than the same wire at f32 residency."""
    from repro.configs import get_config
    from repro.core import dsgd
    from repro.data.synthetic import SyntheticLM, make_agent_lm_batches
    from repro.models import build_model
    from repro.optim import make_optimizer
    from repro.telemetry.metrics import (moment_traffic_model,
                                         resident_bytes_model)

    ROWS = (("f32", "f32", None),
            ("moments_bf16", "f32", "moments=bf16"),
            ("moments_int8", "f32", "moments=int8"),
            ("moments_int8g", "f32", "moments=int8g"),
            ("int8_ef_f32", "int8_ef", None),
            ("int8_ef_int8res", "int8_ef", "moments=int8,wire_err=int8"))

    # ---- analytic resident-bytes table at the default bench size
    big = SIZES["default"]
    big_tree = jax.eval_shape(
        lambda: _make_tree(big["m"], big["d_model"], big["layers"],
                           big["vocab"]))
    opt = make_optimizer("adamw", 1e-2)
    budget = int(RESIDENCY_BUDGET_GB * (1 << 30))
    table = {}
    big_width = None
    for name, wire, pol in ROWS:
        spec = panel_mod.make_spec(big_tree)
        big_width = spec.width
        if wire != "f32":
            spec = panel_mod.with_wire(spec, wire)
        spec = panel_mod.with_residency(spec, pol)
        rb = resident_bytes_model(spec, opt)
        tr = moment_traffic_model(spec, opt, local_steps=local_steps)
        # agents-per-budget off PEAK bytes (stored + the unfused path's
        # transient f32 decode views, zero under the fused kernel) —
        # the stored-only sizing the pre-fusion table used overstated
        # capacity for every unfused non-f32 policy
        table[name] = dict(rb,
                           max_agents_at_budget=budget // rb["peak"],
                           max_agents_stored_only=budget // rb["total"],
                           moment_traffic_bytes_per_round=tr[
                               "bytes_per_round"])
    ef_ratio = (table["int8_ef_f32"]["total"]
                / table["int8_ef_int8res"]["total"])
    assert ef_ratio >= 2.0, (
        "headline residency config (int8_ef wire + int8 moments/residual)"
        f" must fit >= 2x more agents per budget, got {ef_ratio:.4f}x")
    mom_ratio = table["f32"]["total"] / table["moments_int8"]["total"]

    # ---- matched-seed quality + runtime at the cpu-preset-ish size
    cfg = get_config("olmo-1b").reduced(d_model=d_model, layers=layers,
                                        vocab=vocab)
    model = build_model(cfg)
    lm = SyntheticLM(vocab=cfg.vocab_size, num_domains=4, seed=0)
    mixtures = lm.domain_mixtures(m, 0.5, seed=1)
    rng_np = np.random.default_rng(2)
    per_round = []
    for _ in range(rounds):
        hs = [make_agent_lm_batches(lm, mixtures, batch, seq, rng_np)
              for _ in range(local_steps)]
        per_round.append({k: np.stack([h[k] for h in hs]) for k in hs[0]})
    batches = {k: jnp.asarray(np.stack([r[k] for r in per_round]))
               for k in per_round[0]}
    Ws = jnp.asarray(np.stack([
        topology.random_matching(m, 0.5, np.random.default_rng(t))
        for t in range(rounds)]), jnp.float32)
    key = jax.random.PRNGKey(3)

    def fresh(wire, pol):
        state, spec = dsgd.init_panel_state(
            model.init_params, opt, m, jax.random.PRNGKey(0), wire=wire,
            residency=pol)
        jax.block_until_ready(jax.tree.leaves(state))
        return state, spec

    def clock(wire, pol):
        state, spec = fresh(wire, pol)
        seg_fn = dsgd.make_panel_segment(model.loss_fn, opt, local_steps,
                                         spec)
        final = mets = None
        ts = []
        for rep in range(reps + 1):  # rep 0 = compile
            t0 = time.perf_counter()
            final, mets = seg_fn(state, batches, Ws, key)
            mets = jax.device_get(mets)
            jax.block_until_ready(jax.tree.leaves(final))
            ts.append(time.perf_counter() - t0)
            if rep < reps:
                state, _ = fresh(wire, pol)
        row = panel_mod.merged(final["panel"], spec=spec)
        return min(ts[1:]) / rounds * 1e6, final, mets, row

    us0, fin0, mets0, row0 = clock("f32", None)
    # the f32 POLICY must compile the exact pre-residency engine
    _, fin_id, mets_id, _ = clock("f32",
                                  "moments=f32,stats=f32,wire_err=f32")
    for a, b in zip(jax.tree.leaves(fin0), jax.tree.leaves(fin_id)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            "f32 residency policy perturbed the engine state")
    for k in mets0:
        assert np.array_equal(np.asarray(mets0[k]),
                              np.asarray(mets_id[k])), k

    base_loss = float(mets0["loss"][-1])
    rows = {}
    for name, wire, pol in ROWS:
        if name == "f32":
            us, mets, row = us0, mets0, row0
        else:
            us, _, mets, row = clock(wire, pol)
        merge_err = max(float(jnp.max(jnp.abs(row[g] - row0[g])))
                        for g in row)
        loss_delta = abs(float(mets["loss"][-1]) - base_loss)
        assert loss_delta <= WIRE_MERGE_TOL, (name, loss_delta)
        rows[name] = dict(table[name], wire=wire, residency=pol or "f32",
                          us_per_round=round(us, 1),
                          final_loss=round(float(mets["loss"][-1]), 5),
                          loss_delta_vs_f32=round(loss_delta, 5),
                          merge_max_err_vs_f32=round(merge_err, 6),
                          quality_tol=WIRE_MERGE_TOL)
    return {"backend": jax.default_backend(),
            "model_size": {"m": big["m"], "D": big_width},
            "bench_size": {"m": m, "rounds": rounds,
                           "local_steps": local_steps},
            "budget_bytes": budget,
            "agents_ratio_moments_int8": round(mom_ratio, 4),
            "agents_ratio_int8_ef_int8res": round(ef_ratio, 4),
            "f32_policy_bit_identical": True,
            "rows": rows}


def bench_residency_fused(m=8, d_model=128, layers=2, vocab=256, rounds=8,
                          local_steps=2, batch=4, seq=32, reps=3):
    """The fused int8 moment kernel (kernels/opt_fused.py) vs the PR-9
    unfused decode->update->encode path, on the same harness as
    bench_residency (same seeds, same batches, same W sequence).

    * analytic per-round moment HBM traffic at the default bench size
      (metrics.moment_traffic_model): the unfused path's 16 B/scalar of
      transient f32 view traffic per stored panel vs the fused kernel's
      stored-rep-only reads/writes. Asserts the ~4x (>= 3x) reduction.
    * matched-seed training: fused and unfused int8 runs must produce
      BIT-identical final state (the fused ref path is the unfused
      composition by construction), and the fused run's final loss must
      sit within WIRE_MERGE_TOL of the f32 engine.
    * fallback byte-identity: an f32-policy engine and a bf16-moments
      engine are bit-unchanged by the fused dispatch (auto-off — the
      PR-9 paths compile verbatim).
    * measured bytes accessed per segment from XLA cost_analysis on the
      compiled fused/unfused segments — informational on CPU (interpret
      -mode Pallas inflates the fused number; the analytic model is the
      HBM-traffic headline, cf. the dryrun cost model).
    """
    from repro.configs import get_config
    from repro.core import dsgd
    from repro.data.synthetic import SyntheticLM, make_agent_lm_batches
    from repro.models import build_model
    from repro.optim import make_optimizer
    from repro.telemetry.metrics import (fused_moments_auto,
                                         moment_traffic_model,
                                         resident_bytes_model)

    # ---- analytic moment-traffic model at the default bench size
    big = SIZES["default"]
    big_tree = jax.eval_shape(
        lambda: _make_tree(big["m"], big["d_model"], big["layers"],
                           big["vocab"]))
    opt = make_optimizer("adamw", 1e-2)
    spec_big = panel_mod.with_residency(panel_mod.make_spec(big_tree),
                                        "moments=int8")
    assert fused_moments_auto(spec_big, opt), \
        "int8 moments + adamw must auto-qualify for the fused kernel"
    tr_fused = moment_traffic_model(spec_big, opt, local_steps=local_steps,
                                    fused=True)
    tr_unfused = moment_traffic_model(spec_big, opt,
                                      local_steps=local_steps, fused=False)
    traffic_ratio = (tr_unfused["bytes_per_round"]
                     / tr_fused["bytes_per_round"])
    assert traffic_ratio >= 3.0, (
        "fused int8 moment update must cut per-round moment HBM traffic "
        f">= 3x vs the unfused path, model says {traffic_ratio:.2f}x")
    rb_fused = resident_bytes_model(spec_big, opt, fused=True)
    rb_unfused = resident_bytes_model(spec_big, opt, fused=False)

    # ---- matched-seed fused vs unfused vs f32 at the cpu-preset size
    cfg = get_config("olmo-1b").reduced(d_model=d_model, layers=layers,
                                        vocab=vocab)
    model = build_model(cfg)
    lm = SyntheticLM(vocab=cfg.vocab_size, num_domains=4, seed=0)
    mixtures = lm.domain_mixtures(m, 0.5, seed=1)
    rng_np = np.random.default_rng(2)
    per_round = []
    for _ in range(rounds):
        hs = [make_agent_lm_batches(lm, mixtures, batch, seq, rng_np)
              for _ in range(local_steps)]
        per_round.append({k: np.stack([h[k] for h in hs]) for k in hs[0]})
    batches = {k: jnp.asarray(np.stack([r[k] for r in per_round]))
               for k in per_round[0]}
    Ws = jnp.asarray(np.stack([
        topology.random_matching(m, 0.5, np.random.default_rng(t))
        for t in range(rounds)]), jnp.float32)
    key = jax.random.PRNGKey(3)

    def fresh(pol):
        state, spec = dsgd.init_panel_state(
            model.init_params, opt, m, jax.random.PRNGKey(0),
            residency=pol)
        jax.block_until_ready(jax.tree.leaves(state))
        return state, spec

    def clock(pol, fused):
        state, spec = fresh(pol)
        seg_fn = dsgd.make_panel_segment(model.loss_fn, opt, local_steps,
                                         spec, fused=fused)
        compiled = seg_fn.lower(state, batches, Ws, key).compile()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, list):
            ca = ca[0] if ca else {}
        bytes_acc = float(ca.get("bytes accessed", 0.0))
        final = mets = None
        ts = []
        for rep in range(reps + 1):  # rep 0 = compile
            t0 = time.perf_counter()
            final, mets = seg_fn(state, batches, Ws, key)
            mets = jax.device_get(mets)
            jax.block_until_ready(jax.tree.leaves(final))
            ts.append(time.perf_counter() - t0)
            if rep < reps:
                state, _ = fresh(pol)
        return min(ts[1:]) / rounds * 1e6, final, mets, bytes_acc

    us_f32, fin_f32, mets_f32, _ = clock(None, None)
    us_fused, fin_fused, mets_fused, ba_fused = clock("moments=int8", True)
    us_unf, fin_unf, mets_unf, ba_unf = clock("moments=int8", False)

    # fused vs unfused: same SR keys, same core expression -> same bits
    for a, b in zip(jax.tree.leaves(fin_fused), jax.tree.leaves(fin_unf)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            "fused int8 moment update diverged from the unfused path")
    loss_delta = abs(float(mets_fused["loss"][-1])
                     - float(mets_f32["loss"][-1]))
    assert loss_delta <= WIRE_MERGE_TOL, (
        f"fused int8 final loss drifted {loss_delta} from f32")

    # fallback byte-identity: policies outside the fused capability
    # (f32 identity, bf16 moments) must compile the PR-9 engine verbatim
    # whether the dispatch default (auto) or an explicit off is used
    for pol in (None, "moments=bf16"):
        _, fin_a, mets_a, _ = clock(pol, None)
        _, fin_b, mets_b, _ = clock(pol, False)
        for a, b in zip(jax.tree.leaves(fin_a), jax.tree.leaves(fin_b)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                f"fused auto-dispatch perturbed the fallback path {pol}")
        for k in mets_a:
            assert np.array_equal(np.asarray(mets_a[k]),
                                  np.asarray(mets_b[k])), (pol, k)

    return {"backend": jax.default_backend(),
            "model_size": {"m": big["m"], "D": spec_big.width},
            "bench_size": {"m": m, "rounds": rounds,
                           "local_steps": local_steps},
            "moment_traffic_bytes_per_round": {
                "fused": tr_fused["bytes_per_round"],
                "unfused": tr_unfused["bytes_per_round"]},
            "moment_traffic_ratio": round(traffic_ratio, 4),
            "resident_peak_bytes": {"fused": rb_fused["peak"],
                                    "unfused": rb_unfused["peak"]},
            "transient_bytes": {"fused": rb_fused["transient_bytes"],
                                "unfused": rb_unfused["transient_bytes"]},
            "us_per_round": {"f32": round(us_f32, 1),
                             "int8_fused": round(us_fused, 1),
                             "int8_unfused": round(us_unf, 1)},
            "measured_bytes_accessed_per_segment": {
                "int8_fused": ba_fused, "int8_unfused": ba_unf},
            "final_loss": {
                "f32": round(float(mets_f32["loss"][-1]), 5),
                "int8_fused": round(float(mets_fused["loss"][-1]), 5),
                "int8_unfused": round(float(mets_unf["loss"][-1]), 5)},
            "loss_delta_vs_f32": round(loss_delta, 5),
            "quality_tol": WIRE_MERGE_TOL,
            "fused_vs_unfused_bit_identical": True,
            "fallback_bit_identical": True}


def bench_telemetry(m=8, d_model=128, layers=2, vocab=256, rounds=8,
                    local_steps=2, batch=4, seq=32, reps=3):
    """Per-agent telemetry overhead on the full segment driver
    (dsgd.make_panel_segment): the same donated scanned segment with
    ``telemetry=False`` vs ``telemetry=True`` (which adds the five (S, m)
    metric panels — per-agent loss / grad norm / distance-to-mean /
    liveness trit / codec wire bytes — to the single per-segment
    device_get). Asserts the no-perturbation invariant (final panels
    BIT-identical) and records both runtimes + the extra metric payload
    bytes per round. Merged into BENCH_panel.json["telemetry"]."""
    from repro.configs import get_config
    from repro.core import dsgd
    from repro.data.synthetic import SyntheticLM, make_agent_lm_batches
    from repro.models import build_model
    from repro.optim import make_optimizer

    cfg = get_config("olmo-1b").reduced(d_model=d_model, layers=layers,
                                        vocab=vocab)
    model = build_model(cfg)
    opt = make_optimizer("adamw", 1e-2)

    lm = SyntheticLM(vocab=cfg.vocab_size, num_domains=4, seed=0)
    mixtures = lm.domain_mixtures(m, 0.5, seed=1)
    rng_np = np.random.default_rng(2)
    per_round = []
    for _ in range(rounds):
        hs = [make_agent_lm_batches(lm, mixtures, batch, seq, rng_np)
              for _ in range(local_steps)]
        per_round.append({k: np.stack([h[k] for h in hs]) for k in hs[0]})
    batches = {k: jnp.asarray(np.stack([r[k] for r in per_round]))
               for k in per_round[0]}
    Ws = jnp.asarray(np.stack([
        topology.random_matching(m, 0.5, np.random.default_rng(t))
        for t in range(rounds)]), jnp.float32)
    key = jax.random.PRNGKey(3)

    def fresh():  # segment donates its state: rebuild per rep (same key)
        state, spec = dsgd.init_panel_state(model.init_params, opt, m,
                                            jax.random.PRNGKey(0))
        jax.block_until_ready(jax.tree.leaves(state))
        return state, spec

    def run(seg_fn, state):
        state, mets = seg_fn(state, batches, Ws, key)
        mets = jax.device_get(mets)  # the segment's ONE transfer
        jax.block_until_ready(jax.tree.leaves(state))
        return state, mets

    def clock(telemetry):
        state, spec = fresh()
        seg_fn = dsgd.make_panel_segment(model.loss_fn, opt, local_steps,
                                         spec, telemetry=telemetry)
        state, mets = run(seg_fn, state)  # compile
        final = state
        ts = []
        for _ in range(reps):
            state, _ = fresh()
            t0 = time.perf_counter()
            final, mets = run(seg_fn, state)
            ts.append(time.perf_counter() - t0)
        return min(ts) / rounds * 1e6, final, mets

    us_off, pan_off, _ = clock(False)
    us_on, pan_on, mets = clock(True)
    for k, a in pan_off["panel"].items():  # no-perturbation invariant
        assert np.array_equal(np.asarray(a),
                              np.asarray(pan_on["panel"][k])), k
    # the five per-agent columns: 3x f32 + 2x int32 per agent per round
    extra = sorted(k for k in mets
                   if k in ("loss_agent", "grad_norm_agent", "dist_to_mean",
                            "live", "wire_bytes"))
    return {"backend": jax.default_backend(), "m": m, "rounds": rounds,
            "local_steps": local_steps,
            "us_per_round_off": round(us_off, 1),
            "us_per_round_on": round(us_on, 1),
            "overhead_pct": round((us_on / us_off - 1.0) * 100, 1),
            "agent_metrics": extra,
            "extra_bytes_per_round": int(m * (3 * 4 + 2 * 4)),
            "panels_bit_identical": True}


def bench_checkpoint(m=16, d_model=256, layers=8, vocab=512, reps=3):
    """Checkpoint subsystem on the default-size panel train state
    (int8_ef residuals + fisher stats panels included): blob size,
    blocking save / restore wall time, and the ASYNC handoff time — how
    long Checkpointer.save(block=False) holds the caller (the host
    snapshot) before the training loop may continue into the next
    donated segment. Merged into BENCH_panel.json["checkpoint"]."""
    import shutil
    import tempfile

    from repro.checkpoint import Checkpointer, restore
    from repro.configs import get_config
    from repro.core import dsgd
    from repro.models import build_model
    from repro.optim import make_optimizer

    cfg = get_config("olmo-1b").reduced(d_model=d_model, layers=layers,
                                        vocab=vocab)
    model = build_model(cfg)
    opt = make_optimizer("adamw", 1e-2)
    state, spec = dsgd.init_panel_state(model.init_params, opt, m,
                                        jax.random.PRNGKey(0),
                                        wire="int8_ef", merger="fisher")
    jax.block_until_ready(jax.tree.leaves(state))
    tmp = tempfile.mkdtemp(prefix="ckpt_bench_")
    try:
        ck = Checkpointer(tmp, keep=2)
        save_s, restore_s, handoff_s = [], [], []
        for step in range(reps):
            t0 = time.perf_counter()
            ck.save(step, state, block=True)
            save_s.append(time.perf_counter() - t0)
        path = os.path.join(tmp, f"step_{reps - 1:08d}.ckpt")
        nbytes = os.path.getsize(path)
        for _ in range(reps):
            t0 = time.perf_counter()
            restore(path, state)
            restore_s.append(time.perf_counter() - t0)
        for step in range(reps):
            t0 = time.perf_counter()
            ck.save(100 + step, state, block=False)
            handoff_s.append(time.perf_counter() - t0)
            ck.wait()
        return {"m": m, "D": spec.width, "bytes": nbytes,
                "save_s": round(min(save_s), 4),
                "restore_s": round(min(restore_s), 4),
                "async_handoff_s": round(min(handoff_s), 4)}
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def _load_existing():
    if os.path.exists("BENCH_panel.json"):
        with open("BENCH_panel.json") as f:
            return json.load(f)
    return {}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sharded", action="store_true",
                    help="bench the fsdp-sharded panel on the debug mesh "
                         "(re-execs with forced host devices if needed)")
    ap.add_argument("--wire",
                    help="bench wire codecs (repro.wire) against the f32 "
                         "identity: codec-aware bytes/agent/round "
                         "(payload + total) + runtime + final-merge "
                         "parity. A codec name, a comma-separated list "
                         "('int8,int4,topk'), or 'all'")
    ap.add_argument("--telemetry", action="store_true",
                    help="bench the per-agent telemetry metric panels on "
                         "the full segment driver: telemetry off vs on "
                         "us_per_round, overhead pct, and the bit-"
                         "identical-panels invariant")
    ap.add_argument("--residency", action="store_true",
                    help="bench the storage-codec residency subsystem "
                         "(repro.residency): exact resident bytes/agent "
                         "per policy, max agents per memory budget, and "
                         "matched-seed quality vs the f32 engine "
                         "(f32 policy asserted bit-identical; int8_ef + "
                         "int8 moments/residual asserted >= 2x agents)")
    ap.add_argument("--checkpoint", action="store_true",
                    help="bench the checkpoint subsystem on the default-"
                         "size train state: blob bytes, save/restore wall "
                         "time, async-save handoff time")
    args = ap.parse_args()
    if args.wire and args.wire != "all":
        unknown = [c for c in args.wire.split(",") if c not in WIRE_CODECS]
        if unknown:
            ap.error(f"unknown wire codecs {unknown}; "
                     f"known: {list(WIRE_CODECS)} or 'all'")

    if args.sharded and jax.device_count() < SHARDED_DEVICES:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            " --xla_force_host_platform_device_count="
                            f"{SHARDED_DEVICES}").strip()
        env.setdefault("JAX_PLATFORMS", "cpu")
        argv = [sys.executable, "-m", "benchmarks.panel_bench", "--sharded"]
        if args.wire:  # keep a combined --sharded --wire request intact
            argv += ["--wire", args.wire]
        raise SystemExit(subprocess.run(argv, env=env).returncode)

    out = _load_existing()
    out.setdefault("description",
                   "fused panel gossip+merge round vs per-leaf tree-map "
                   "path (us_per_round)")

    if args.wire:
        names = (WIRE_CODECS if args.wire == "all"
                 else tuple(args.wire.split(",")))
        rec = bench_wire(names, **SIZES["default"])
        wire = out.setdefault("wire", {})
        wire.update({k: v for k, v in rec.items() if k != "codecs"})
        wire.setdefault("codecs", {}).update(rec["codecs"])
        for name, r in rec["codecs"].items():
            print(f"wire {name}: {r['payload_bytes_per_agent']}B payload "
                  f"/{r['wire_bytes_per_agent']}B total per agent "
                  f"({r['payload_ratio_vs_f32']}x/"
                  f"{r['bytes_ratio_vs_f32']}x vs f32) "
                  f"{r['us_per_round']:.0f}us/round "
                  f"merge_err={r['merge_max_err_vs_f32']}", flush=True)
    if args.sharded:
        out["sharded"] = bench_sharded(**{k: v for k, v in
                                          SIZES["default"].items()})
        r = out["sharded"]
        print(f"sharded: replicated={r['us_per_round_replicated']:.0f}us "
              f"fsdp-sharded={r['us_per_round_sharded']:.0f}us "
              f"coll={r['coll_bytes_per_round']}B/round", flush=True)
    if args.telemetry:
        out["telemetry"] = bench_telemetry()
        r = out["telemetry"]
        print(f"telemetry: off={r['us_per_round_off']:.0f}us "
              f"on={r['us_per_round_on']:.0f}us "
              f"overhead={r['overhead_pct']}% "
              f"(+{r['extra_bytes_per_round']}B/round host readback)",
              flush=True)
    if args.residency:
        out["residency"] = bench_residency()
        r = out["residency"]
        hl = r["rows"]["int8_ef_int8res"]
        print(f"residency: int8_ef + int8 moments/residual = "
              f"{hl['total']}B/agent resident vs "
              f"{r['rows']['int8_ef_f32']['total']}B at f32 "
              f"({r['agents_ratio_int8_ef_int8res']}x agents per "
              f"{r['budget_bytes'] >> 30}GiB: "
              f"{hl['max_agents_at_budget']} vs "
              f"{r['rows']['int8_ef_f32']['max_agents_at_budget']}), "
              f"loss_delta={hl['loss_delta_vs_f32']}", flush=True)
        out["residency_fused"] = bench_residency_fused()
        rf = out["residency_fused"]
        tb = rf["moment_traffic_bytes_per_round"]
        print(f"residency_fused: moment traffic "
              f"{tb['unfused']}B -> {tb['fused']}B per round "
              f"({rf['moment_traffic_ratio']}x less), "
              f"fused==unfused bits: "
              f"{rf['fused_vs_unfused_bit_identical']}, "
              f"loss_delta_vs_f32={rf['loss_delta_vs_f32']}", flush=True)
    if args.checkpoint:
        out["checkpoint"] = bench_checkpoint(
            **{k: v for k, v in SIZES["default"].items() if k != "rounds"})
        r = out["checkpoint"]
        print(f"checkpoint: {r['bytes'] / 1e6:.1f}MB "
              f"save={r['save_s'] * 1e3:.0f}ms "
              f"restore={r['restore_s'] * 1e3:.0f}ms "
              f"async_handoff={r['async_handoff_s'] * 1e3:.0f}ms",
              flush=True)
    if (not args.wire and not args.sharded and not args.checkpoint
            and not args.telemetry and not args.residency):
        # default: the sizes sweep
        out["backend"] = jax.default_backend()  # labels the "sizes" runs
        out.setdefault("sizes", {})
        for name, kw in SIZES.items():
            out["sizes"][name] = bench_size(**kw)
            r = out["sizes"][name]
            print(f"{name}: tree={r['us_per_round_tree']:.0f}us "
                  f"panel={r['us_per_round_panel']:.0f}us "
                  f"speedup={r['speedup']}x", flush=True)
    with open("BENCH_panel.json", "w") as f:
        json.dump(out, f, indent=1)
    print("wrote BENCH_panel.json")


if __name__ == "__main__":
    main()
