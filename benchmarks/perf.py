"""§Perf helper: compare dry-run variants for one (arch, shape, mesh) pair.

Usage:
  PYTHONPATH=src python -m benchmarks.perf olmo-1b train_4k 16x16
prints per-variant roofline terms and deltas vs baseline from
results/dryrun/*.json.
"""
from __future__ import annotations

import glob
import json
import os
import sys


def compare(arch, shape, mesh, outdir="results/dryrun"):
    paths = glob.glob(os.path.join(outdir, f"{arch}_{shape}_{mesh}_*.json"))
    recs = {}
    for p in sorted(paths):
        with open(p) as f:
            r = json.load(f)
        if r["status"] == "OK":
            recs[r["variant"]] = r
    if "baseline" not in recs:
        raise SystemExit(f"no baseline record for {arch} {shape} {mesh}")
    base = recs["baseline"]["roofline"]
    base_mem = recs["baseline"]["memory"]["per_device_total"]
    rows = []
    hdr = (f"{'variant':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'dominant':>12s} {'mem_GB':>8s} "
           f"{'Δdom%':>7s}")
    rows.append(hdr)
    dom_key = base["dominant"]
    for v, r in sorted(recs.items(), key=lambda kv: kv[0] != "baseline"):
        ro = r["roofline"]
        mem = r["memory"]["per_device_total"] / 1e9
        delta = (ro[dom_key] - base[dom_key]) / max(base[dom_key], 1e-12) * 100
        rows.append(f"{v:12s} {ro['compute_s']:10.3f} {ro['memory_s']:10.3f} "
                    f"{ro['collective_s']:10.3f} {ro['dominant']:>12s} "
                    f"{mem:8.1f} {delta:+6.1f}%")
    return "\n".join(rows)


if __name__ == "__main__":
    print(compare(*sys.argv[1:4]))
