"""Benchmark runner — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Each benchmark is a CPU-scale
instance of the corresponding paper experiment (see benchmarks/figures.py);
``roofline`` summarises the TPU dry-run artifacts when present.
"""
from __future__ import annotations

import json
import sys

from benchmarks import figures

BENCHES = [
    ("fig1_single_global_merging", figures.fig1_single_global_merging),
    ("fig2ab_window_allocation", figures.fig2ab_window_allocation),
    ("fig2c_counterfactual_mergeability",
     figures.fig2c_counterfactual_mergeability),
    ("table1_convergence_rates", figures.table1_convergence_rates),
    ("corollary_d2_consensus_bound", figures.consensus_bound_corollary_d2),
    ("appendix_c34_gossip_merge", figures.appendix_c34_gossip_merge),
    ("beyond_adaptive_schedule", figures.beyond_adaptive_schedule),
    ("beyond_bf16_gossip", figures.beyond_bf16_gossip),
    ("kernels_microbench", figures.kernels_microbench),
    ("panel_microbench", figures.panel_microbench),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in BENCHES:
        if only and only not in name:
            continue
        try:
            us, derived = fn()
            print(f"{name},{us:.0f},\"{json.dumps(derived)}\"", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},-1,\"ERROR: {type(e).__name__}: {e}\"", flush=True)
    # roofline summary (non-fatal when dry-run artifacts are absent)
    try:
        from benchmarks.roofline import summary_csv
        for line in summary_csv("results/dryrun"):
            print(line, flush=True)
    except Exception as e:  # noqa: BLE001
        print(f"roofline,-1,\"(no dry-run artifacts: {e})\"", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
