"""One benchmark per paper figure/table (CPU-scale reproductions).

Each returns (us_per_call, derived) where derived is the figure's headline
quantity; ``benchmarks.run`` prints the CSV.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import M, make_problem, run_schedule, timed


def fig1_single_global_merging():
    """Fig. 1a/1b: sparse gossip + ONE final global merging vs local-only.
    derived = merged-over-local accuracy gain under R=0.2 gossip."""
    t0 = time.perf_counter()
    const = run_schedule("constant", seed=0)
    local_only = run_schedule("local", seed=0)
    us = (time.perf_counter() - t0) * 1e6
    derived = {
        "gossip_local_acc": round(const["local"], 4),
        "gossip_merged_acc": round(const["merged"], 4),
        "merge_gain": round(const["merged"] - const["local"], 4),
        "localonly_merged_acc": round(local_only["merged"], 4),
    }
    return us, derived


def fig2ab_window_allocation():
    """Fig. 2a/2b: fully-connected communication inside ONE window of 1/5 of
    training; later windows should win on final accuracy.
    derived = final acc per window position + late-early gap."""
    t0 = time.perf_counter()
    rounds = 80
    win = rounds // 5
    finals = []
    for wpos in range(5):
        out = run_schedule("windowed", rounds=rounds, seed=0,
                           start=wpos * win, end=(wpos + 1) * win)
        finals.append(round(out["merged"], 4))
    us = (time.perf_counter() - t0) * 1e6
    derived = {"final_acc_by_window": finals,
               "late_minus_early": round(finals[-1] - finals[0], 4)}
    return us, derived


def fig2c_counterfactual_mergeability():
    """Fig. 2c: counterfactual merged-model accuracy vs local accuracy over
    training, with and without communication.
    derived = mean merged-local gap (comm) vs (no-comm)."""
    t0 = time.perf_counter()
    comm = run_schedule("constant", seed=1, track=True)
    nocomm = run_schedule("local", seed=1, track=True)
    us = (time.perf_counter() - t0) * 1e6
    gap = np.mean(np.array(comm["curves"]["merged"])
                  - np.array(comm["curves"]["local"]))
    gap0 = np.mean(np.array(nocomm["curves"]["merged"])
                   - np.array(nocomm["curves"]["local"]))
    derived = {"mean_gap_comm": round(float(gap), 4),
               "mean_gap_nocomm": round(float(gap0), 4),
               "merged_curve_comm": comm["curves"]["merged"][-4:],
               "merged_curve_nocomm": nocomm["curves"]["merged"][-4:]}
    return us, derived


def table1_convergence_rates():
    """Table 1: DSGD's merged model matches parallel SGD's convergence.
    derived = mean ||grad L(theta_bar)||^2 over the last 20 rounds for
    parallel SGD vs DSGD(merged), same lr/batch — their ratio should be
    O(1) (ours) rather than diverging (classic bound's extra 1/p terms)."""
    from repro.core import dsgd
    from repro.data.synthetic import make_agent_batches
    from repro.optim import make_optimizer
    t0 = time.perf_counter()
    ds, parts, init_params, loss_fn, acc = make_problem(seed=2)
    opt = make_optimizer("sgd", 0.05, weight_decay=0.0)
    rounds = 100

    def grad_norm_at(p, batch):
        g = jax.grad(lambda pp: loss_fn(pp, batch)[0])(p)
        return sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(g))

    # parallel SGD
    pstate = dsgd.init_parallel_state(init_params, opt, jax.random.PRNGKey(0))
    pstep = jax.jit(dsgd.make_parallel_step(loss_fn, opt))
    # DSGD sparse gossip
    dstate = dsgd.init_state(init_params, opt, M, jax.random.PRNGKey(0),
                             same_init=True)
    dstep = jax.jit(dsgd.make_dsgd_step(loss_fn, opt))
    from repro.core.schedule import make_schedule
    sched = make_schedule("constant", M, rounds, prob=0.2, seed=0)
    # per-round eval loop: the per-leaf variant avoids re-panelising the
    # full stacked state on every call
    from repro.core.gossip import merged_model_tree

    rng_np = np.random.default_rng(2)
    key = jax.random.PRNGKey(1)
    gn_fn = jax.jit(grad_norm_at)
    gpar, gmerged = [], []
    xe, ye = make_agent_batches(ds, parts, 256, rng_np)
    eval_batch = (jnp.asarray(xe.reshape(-1, xe.shape[-1])),
                  jnp.asarray(ye.reshape(-1)))
    for t in range(rounds):
        xb, yb = make_agent_batches(ds, parts, 32, rng_np)
        batch = (jnp.asarray(xb), jnp.asarray(yb))
        key, k1, k2 = jax.random.split(key, 3)
        pstate, _ = pstep(pstate, batch, k1)
        W = sched.mixing_matrix(t)
        dstate, _ = dstep(dstate, batch, jnp.asarray(W, jnp.float32), k2)
        if t >= rounds - 20:
            gpar.append(float(gn_fn(pstate["params"], eval_batch)))
            gmerged.append(float(gn_fn(merged_model_tree(dstate["params"]),
                                       eval_batch)))
    us = (time.perf_counter() - t0) * 1e6
    derived = {"parallel_sgd_gradsq": round(float(np.mean(gpar)), 6),
               "dsgd_merged_gradsq": round(float(np.mean(gmerged)), 6),
               "ratio": round(float(np.mean(gmerged) / max(np.mean(gpar),
                                                           1e-12)), 3)}
    return us, derived


def consensus_bound_corollary_d2():
    """Corollary D.2: E[Xi^2] <= 24 (1-p) eta^2 (phi^2 + sigma^2) / p^2.
    derived = empirical Xi^2 vs the bound for the R=0.2 random topology."""
    from repro.core import consensus, dsgd, topology
    from repro.optim import make_optimizer
    from repro.data.synthetic import make_agent_batches
    t0 = time.perf_counter()
    ds, parts, init_params, loss_fn, acc = make_problem(seed=3)
    eta = 0.05
    opt = make_optimizer("sgd", eta, weight_decay=0.0)
    state = dsgd.init_state(init_params, opt, M, jax.random.PRNGKey(0),
                            same_init=True)
    step = jax.jit(dsgd.make_dsgd_step(loss_fn, opt))
    rng_np = np.random.default_rng(3)
    key = jax.random.PRNGKey(4)
    p_est = topology.expected_p(topology.make_sampler("random", M, 0.2), M,
                                400, np.random.default_rng(0))
    xis, phis = [], []
    grad_fn = jax.jit(jax.vmap(
        lambda p, b: jax.grad(lambda pp: loss_fn(pp, b)[0])(p)))
    for t in range(120):
        W = topology.random_matching(M, 0.2, rng_np)
        xb, yb = make_agent_batches(ds, parts, 32, rng_np)
        batch = (jnp.asarray(xb), jnp.asarray(yb))
        key, k = jax.random.split(key)
        state, mets = step(state, batch, jnp.asarray(W, jnp.float32), k)
        xis.append(float(mets["consensus"]) ** 2)
        gs = grad_fn(state["params"], batch)
        phis.append(float(np.mean([float(jnp.sum(jnp.square(x)))
                                   for x in jax.tree.leaves(gs)])))
    phi2 = float(np.mean(phis)) * 1.0
    sigma2 = phi2  # conservative: noise bounded by gradient scale here
    bound = 24 * (1 - p_est) * eta ** 2 * (phi2 + sigma2) / p_est ** 2
    emp = float(np.mean(xis[20:]))
    us = (time.perf_counter() - t0) * 1e6
    derived = {"p_estimate": round(p_est, 4), "empirical_xi2": round(emp, 5),
               "bound": round(bound, 5),
               "satisfied": bool(emp <= bound)}
    return us, derived


def appendix_c34_gossip_merge():
    """Appendix C.3.4: final merge approximated by k rounds of exponential
    gossip. derived = accuracy of 1-round vs log2(m)-round gossip merge vs
    exact global merge."""
    from repro.core import dsgd, gossip, topology
    from repro.core.merge import gossip_merge_rounds
    from repro.core.schedule import make_schedule
    from repro.data.synthetic import make_agent_batches
    from repro.optim import make_optimizer
    t0 = time.perf_counter()
    ds, parts, init_params, loss_fn, acc = make_problem(seed=4)
    opt = make_optimizer("sgd", 0.1, weight_decay=0.0)
    state = dsgd.init_state(init_params, opt, M, jax.random.PRNGKey(0))
    step = jax.jit(dsgd.make_dsgd_step(loss_fn, opt))
    sched = make_schedule("constant", M, 60, prob=0.2, seed=4)
    rng_np = np.random.default_rng(4)
    key = jax.random.PRNGKey(5)
    for t in range(60):
        W = sched.mixing_matrix(t)
        xb, yb = make_agent_batches(ds, parts, 32, rng_np)
        key, k = jax.random.split(key)
        state, _ = step(state, (jnp.asarray(xb), jnp.asarray(yb)),
                        jnp.asarray(W, jnp.float32), k)
    sampler = topology.make_sampler("exponential", M)
    vacc = jax.jit(jax.vmap(acc))
    accs = {}
    for k_rounds in (1, int(np.log2(M))):
        merged = gossip_merge_rounds(state["params"], sampler, k_rounds,
                                     np.random.default_rng(0))
        accs[f"gossip_{k_rounds}r"] = round(float(jnp.mean(vacc(merged))), 4)
    accs["exact_merge"] = round(float(acc(gossip.merged_model(
        state["params"]))), 4)
    accs["local"] = round(float(jnp.mean(vacc(state["params"]))), 4)
    us = (time.perf_counter() - t0) * 1e6
    return us, accs


def beyond_adaptive_schedule():
    """BEYOND-PAPER: the adaptive critical-consensus-edge controller the
    paper's §6 calls for (Prop. 3 operationalised). Compare, at the SAME
    final-merge protocol: constant R=0.2 gossip vs the adaptive controller
    (sparse gossip, fully-connected only when Xi_t > kappa*mu_t).
    derived = accuracy and communication budget of each."""
    import jax
    import jax.numpy as jnp
    from repro.core import dsgd, gossip
    from repro.core.schedule import make_schedule
    from repro.data.synthetic import make_agent_batches
    from repro.optim import make_optimizer
    t0 = time.perf_counter()
    rounds = 80
    out = {}
    for name, kw in (("constant", {}), ("adaptive", {"kappa": 8.0})):
        ds, parts, init_params, loss_fn, acc = make_problem(seed=5)
        opt = make_optimizer("sgd", 0.1, weight_decay=0.0)
        state = dsgd.init_state(init_params, opt, M, jax.random.PRNGKey(0))
        step = jax.jit(dsgd.make_dsgd_step(loss_fn, opt))
        sched = make_schedule(name, M, rounds, prob=0.2, seed=5, **kw)
        rng_np = np.random.default_rng(5)
        key = jax.random.PRNGKey(6)
        monitor = {}
        comm = 0.0
        for t in range(rounds):
            W = sched.mixing_matrix(t, monitor)
            comm += sched.round_cost(W)
            xb, yb = make_agent_batches(ds, parts, 32, rng_np)
            key, k = jax.random.split(key)
            state, mets = step(state, (jnp.asarray(xb), jnp.asarray(yb)),
                               jnp.asarray(W, jnp.float32), k)
            monitor = {"grad_norm": float(mets["grad_norm"]),
                       "consensus": float(mets["consensus"])}
        merged = float(acc(gossip.merged_model(state["params"])))
        out[name] = {"merged_acc": round(merged, 4),
                     "comm_P": round(comm, 1)}
        if name == "adaptive":
            out[name]["global_rounds"] = getattr(sched, "global_rounds", [])[:8]
    us = (time.perf_counter() - t0) * 1e6
    return us, out


def beyond_bf16_gossip():
    """BEYOND-PAPER: CocktailSGD-flavoured wire compression — run the same
    final-merge protocol with bf16 gossip payloads and verify accuracy
    parity (the §Perf bf16wire lever is quality-safe)."""
    import jax
    import jax.numpy as jnp
    from repro.core import dsgd, gossip
    from repro.core.schedule import make_schedule
    from repro.data.synthetic import make_agent_batches
    from repro.optim import make_optimizer
    t0 = time.perf_counter()
    out = {}
    for name, wire in (("f32", None), ("bf16", jnp.bfloat16)):
        ds, parts, init_params, loss_fn, acc = make_problem(seed=6)
        opt = make_optimizer("sgd", 0.1, weight_decay=0.0)
        state = dsgd.init_state(init_params, opt, M, jax.random.PRNGKey(0))
        step = jax.jit(dsgd.make_dsgd_step(loss_fn, opt, wire_dtype=wire))
        sched = make_schedule("final_merge", M, 80, prob=0.2, seed=6)
        rng_np = np.random.default_rng(6)
        key = jax.random.PRNGKey(7)
        for t in range(80):
            W = sched.mixing_matrix(t)
            xb, yb = make_agent_batches(ds, parts, 32, rng_np)
            key, k = jax.random.split(key)
            state, _ = step(state, (jnp.asarray(xb), jnp.asarray(yb)),
                            jnp.asarray(W, jnp.float32), k)
        out[name] = round(float(acc(gossip.merged_model(state["params"]))), 4)
    out["parity_gap"] = round(out["bf16"] - out["f32"], 4)
    us = (time.perf_counter() - t0) * 1e6
    return us, out


def panel_microbench():
    """Flat-panel engine vs per-leaf tree-map path: one communication round
    (gossip mix + consensus monitor) + final global merge on a real reduced
    LM tree. derived = us_per_round for both engines and the speedup (the
    acceptance bar is >=1.5x at the default size; see BENCH_panel.json for
    the committed trajectory)."""
    from benchmarks.panel_bench import SIZES, bench_size
    t0 = time.perf_counter()
    derived = bench_size(reps=2, **SIZES["default"])
    us = (time.perf_counter() - t0) * 1e6
    return us, derived


def kernels_microbench():
    """Kernel wrappers: correctness vs oracle (interpret mode) + XLA-path
    timing of the same math on CPU. derived = max abs err of both kernels."""
    from repro.kernels.ops import flash_attention, gossip_mix
    from repro.kernels.ref import attention_ref, gossip_mix_ref
    from repro.core.topology import random_matching
    B, S, H, hd = 1, 256, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, hd)) for kk in ks)
    ref_fn = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    us_attn = timed(ref_fn, q, k, v)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    err_attn = float(jnp.max(jnp.abs(out - ref_fn(q, k, v))))

    m, D = 16, 1 << 16
    W = jnp.asarray(random_matching(m, 0.5, np.random.default_rng(0)),
                    jnp.float32)
    theta = jax.random.normal(jax.random.PRNGKey(1), (m, D))
    ref_mix = jax.jit(gossip_mix_ref)
    us_mix = timed(ref_mix, W, theta)
    from repro.kernels.gossip_mix import gossip_mix_panel
    err_mix = float(jnp.max(jnp.abs(gossip_mix_panel(W, theta)
                                    - ref_mix(W, theta))))
    from repro.kernels.panel_reduce import panel_mean_consensus
    from repro.kernels.ref import panel_mean_consensus_ref
    mean_k, sq_k = panel_mean_consensus(theta)
    mean_r, sq_r = panel_mean_consensus_ref(theta)
    err_reduce = max(float(jnp.max(jnp.abs(mean_k - mean_r))),
                     abs(float(sq_k - sq_r)) / max(float(sq_r), 1e-9))
    return us_attn + us_mix, {"attn_ref_us": round(us_attn, 1),
                              "mix_ref_us": round(us_mix, 1),
                              "flash_err": err_attn, "mix_err": err_mix,
                              "panel_reduce_err": err_reduce}
