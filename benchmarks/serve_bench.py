"""Serving benchmark: prefill throughput, per-step decode latency, and
continuous-batching slot occupancy for the merged-model engine.

``python -m benchmarks.serve_bench`` writes BENCH_serve.json with three
sections per arch:

* **prefill** — tokens/s through the jitted exact-length prefill (the
  engine's admission path), post-compile, at the demo prompt length;
* **decode** — per ``ServingEngine.step()`` latency at FULL slot
  occupancy (every slot live, one (C,) token fetch per tick — the fetch is
  the tick's only host sync, so the timing includes the whole jitted
  decode+sample dispatch): mean / p50 / p90 microseconds read from the
  engine's OWN ``decode_step_s`` histogram (telemetry.latency) after a
  post-compile ``reset()``, and the derived decode tokens/s;
* **engine** — an end-to-end heterogeneous serve run (2 prompt-length
  buckets, staggered max_new): requests/s, tokens/s, slot-occupancy
  (live-slot-steps over capacity-steps), scheduler stats, and the
  engine's TTFT / queue-wait / per-token latency histogram summaries.

CI runs this on the cpu-preset reduced configs and uploads the JSON as an
artifact next to BENCH_panel.json; the committed copy is the reference.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServingEngine

ARCHS = ["olmo-1b", "recurrentgemma-2b", "qwen2-vl-72b"]
REDUCED = {"recurrentgemma-2b": {"layers": 3}}


def _requests(cfg, n, lengths, max_new, seed=1):
    k_prompt, k_mm, k_frames = jax.random.split(jax.random.PRNGKey(seed), 3)
    reqs = []
    for i in range(n):
        S = lengths[i % len(lengths)]
        toks = np.asarray(jax.random.randint(
            jax.random.fold_in(k_prompt, i), (S,), 0, cfg.vocab_size),
            np.int32)
        extras = {}
        if cfg.mm_prefix > 0:
            extras["patch_embeds"] = np.asarray(jax.random.normal(
                jax.random.fold_in(k_mm, i), (cfg.mm_prefix, cfg.d_model)))
        if cfg.encoder_layers:
            extras["frame_embeds"] = np.asarray(jax.random.normal(
                jax.random.fold_in(k_frames, i), (S, cfg.d_model)))
        reqs.append(Request(rid=i, tokens=toks, max_new=max_new[i % len(
            max_new)], extras=extras))
    return reqs


def bench_arch(arch, *, concurrency=4, prompt_len=32, max_new=16, reps=16):
    cfg = get_config(arch).reduced(d_model=128, vocab=256,
                                   **REDUCED.get(arch, {}))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    long_new = reps + 8  # decode-latency fill keeps slots live all reps
    max_len = prompt_len + max(0, cfg.mm_prefix) + max(max_new, long_new)
    eng = ServingEngine(model, params, max_concurrency=concurrency,
                        max_len=max_len)

    # -- prefill throughput (post-compile, exact-length admission path)
    req = _requests(cfg, 1, [prompt_len], [max_new])[0]
    batch = {"tokens": jax.numpy.asarray(req.tokens[None])}
    for k, v in req.extras.items():
        batch[k] = jax.numpy.asarray(v)[None]
    jax.block_until_ready(eng._prefill(params, batch))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = eng._prefill(params, batch)
    jax.block_until_ready(out)
    prefill_us = (time.perf_counter() - t0) / reps * 1e6
    n_prefill_tok = prompt_len + max(0, cfg.mm_prefix)
    prefill = {"prompt_len": prompt_len, "tokens": n_prefill_tok,
               "us_per_prefill": round(prefill_us, 1),
               "tokens_per_s": round(n_prefill_tok / (prefill_us / 1e6), 1)}

    # -- per-step decode latency at FULL occupancy, measured by the
    # engine's OWN decode_step_s histogram (telemetry.latency): the
    # compile tick is discarded by reset(), so the summary covers only
    # post-compile steps
    fill = _requests(cfg, concurrency, [prompt_len], [long_new])
    for r in fill:
        eng.submit(r)
    eng.admit()
    assert len(eng.live_slots()) == concurrency
    eng.step()  # compile the slotted decode step
    eng.reset()  # drop warmup/compile from the histograms
    for _ in range(reps):
        eng.step()  # blocks on the (C,) token fetch — full step latency
    lat = eng.hists["decode_step_s"].summary_us()
    decode = {"slots": concurrency,
              "us_per_step_mean": round(lat["mean_us"], 1),
              "us_per_step_p50": round(lat["p50_us"], 1),
              "us_per_step_p90": round(lat["p90_us"], 1),
              "decode_tokens_per_s": round(
                  concurrency / (lat["mean_us"] / 1e6), 1)}
    for s in eng.live_slots():
        eng.evict(s)

    # -- end-to-end heterogeneous serve (reset: fresh stats + histograms)
    eng.reset()
    reqs = _requests(cfg, 2 * concurrency,
                     [prompt_len, max(1, prompt_len // 2)],
                     [max_new, max(1, max_new // 2), max_new - 2], seed=2)
    t0 = time.perf_counter()
    served = eng.serve(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(v) for v in served.values())
    snap = eng.snapshot()
    engine = {"requests": len(served), "tokens": n_tok,
              "seconds": round(dt, 2),
              "tokens_per_s": round(n_tok / dt, 1),
              "requests_per_s": round(len(served) / dt, 1),
              "slot_occupancy": round(snap["occupancy"], 3),
              "ticks": snap["ticks"],
              "prefill_tokens": snap["prefill_tokens"],
              # request-level latency histograms from the engine's own
              # counters (fixed log-spaced buckets, microsecond summaries)
              "latency_us": {k: {kk: round(vv, 1) for kk, vv in
                                 eng.hists[k].summary_us().items()}
                             for k in ("ttft_s", "queue_wait_s",
                                       "per_token_s", "decode_step_s")}}

    return {"d_model": cfg.d_model, "layers": cfg.num_layers,
            "vocab": cfg.vocab_size, "padded_vocab": cfg.padded_vocab,
            "max_len": max_len, "prefill": prefill, "decode": decode,
            "engine": engine}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default=",".join(ARCHS))
    ap.add_argument("--concurrency", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--reps", type=int, default=16)
    args = ap.parse_args()

    out = {"backend": jax.default_backend(),
           "description": ("continuous-batching serving engine: prefill "
                           "tokens/s, per-step decode latency (us) at full "
                           "occupancy, end-to-end slot occupancy"),
           "concurrency": args.concurrency,
           "archs": {}}
    for arch in args.archs.split(","):
        print(f"[serve_bench] {arch} ...", flush=True)
        out["archs"][arch] = bench_arch(
            arch, concurrency=args.concurrency, prompt_len=args.prompt_len,
            max_new=args.max_new, reps=args.reps)
        e = out["archs"][arch]
        lat = e["engine"]["latency_us"]
        print(f"  prefill {e['prefill']['tokens_per_s']:.0f} tok/s | "
              f"decode {e['decode']['us_per_step_mean']:.0f} us/step "
              f"(p50 {e['decode']['us_per_step_p50']:.0f}) | "
              f"occupancy {e['engine']['slot_occupancy']:.2f} | "
              f"ttft p50 {lat['ttft_s']['p50_us']:.0f} us | per-token "
              f"p50 {lat['per_token_s']['p50_us']:.0f} us")

    with open("BENCH_serve.json", "w") as f:
        json.dump(out, f, indent=1)
    print("wrote BENCH_serve.json")


if __name__ == "__main__":
    main()
