"""Roofline table renderer: reads results/dryrun/*.json into (a) CSV lines
for benchmarks.run and (b) the markdown table for EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
import os


def load(outdir="results/dryrun", variant=None):
    recs = []
    for path in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if variant and r.get("variant") != variant:
            continue
        recs.append(r)
    return recs


def summary_csv(outdir="results/dryrun"):
    recs = load(outdir)
    if not recs:
        raise FileNotFoundError(f"no dry-run records in {outdir}")
    lines = []
    for r in recs:
        tag = f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}.{r.get('variant','baseline')}"
        if r["status"] != "OK":
            lines.append(f"{tag},0,\"{r['status']}: "
                         f"{r.get('reason', r.get('error', ''))[:80]}\"")
            continue
        ro = r["roofline"]
        d = {"compute_s": round(ro["compute_s"], 4),
             "memory_s": round(ro["memory_s"], 4),
             "collective_s": round(ro["collective_s"], 4),
             "dominant": ro["dominant"],
             "useful_flops_ratio": (round(ro["useful_flops_ratio"], 3)
                                    if ro.get("useful_flops_ratio") else None),
             "fits_16gb": r["memory"]["fits_16gb"]}
        lines.append(f"{tag},{r.get('compile_s', 0) * 1e6:.0f},"
                     f"\"{json.dumps(d)}\"")
    return lines


def markdown_table(outdir="results/dryrun", variant="baseline"):
    recs = [r for r in load(outdir, variant)]
    hdr = ("| arch | shape | mesh | status | compute (s) | memory (s) | "
           "collective (s) | dominant | useful-FLOPs | per-dev GB | fits 16GB |")
    sep = "|" + "---|" * 11
    rows = [hdr, sep]
    for r in recs:
        if r["status"] != "OK":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"{r['status']} | - | - | - | - | - | - | - |")
            continue
        ro = r["roofline"]
        mem_gb = r["memory"]["per_device_total"] / 1e9
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK "
            f"| {ro['compute_s']:.3f} | {ro['memory_s']:.3f} "
            f"| {ro['collective_s']:.3f} | {ro['dominant'].replace('_s','')} "
            f"| {ro['useful_flops_ratio']:.2f} "
            f"| {mem_gb:.1f} | {'✅' if r['memory']['fits_16gb'] else '⚠️'} |")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys
    out = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    print(markdown_table(out))
